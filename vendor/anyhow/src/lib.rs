//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The offline vendor set for this project does not include the real
//! `anyhow`, so this crate provides the small API surface the codebase
//! uses: [`Error`], [`Result`], the [`anyhow!`] and [`bail!`] macros, and
//! the [`Context`] extension trait for results whose error type implements
//! [`std::error::Error`].
//!
//! Semantics follow upstream anyhow where it matters:
//! - `Error` does **not** implement `std::error::Error` (so the blanket
//!   `From<E: std::error::Error>` conversion used by `?` stays coherent);
//! - `{:?}` formatting prints the message followed by the `Caused by`
//!   chain, which is what `fn main() -> anyhow::Result<()>` shows on exit.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with an overridable error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: a message plus an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Error from a displayable message (what [`anyhow!`] produces).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Error wrapping a concrete `std::error::Error`.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        Error {
            msg: error.to_string(),
            source: Some(Box::new(error)),
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error {
            msg: format!("{context}: {}", self.msg),
            source: self.source,
        }
    }

    /// The wrapped source error, if any.
    pub fn source(&self) -> Option<&(dyn StdError + 'static)> {
        match &self.source {
            Some(e) => Some(&**e),
            None => None,
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut cause = self.source();
        while let Some(e) = cause {
            write!(f, "\n\nCaused by:\n    {e}")?;
            cause = e.source();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to results
/// whose error type implements `std::error::Error`.
pub trait Context<T, E> {
    /// Wrap the error with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn macro_and_display() {
        let e = anyhow!("bad thing {}", 7);
        assert_eq!(e.to_string(), "bad thing 7");
    }

    #[test]
    fn bail_returns_err() {
        fn f() -> Result<()> {
            bail!("nope {}", "x");
        }
        assert_eq!(f().unwrap_err().to_string(), "nope x");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(e.to_string().contains("missing"));
        assert!(e.source().is_some());
    }

    #[test]
    fn context_wraps_message_and_keeps_source() {
        let r: std::result::Result<(), _> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert!(e.to_string().starts_with("reading manifest: "));
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: std::result::Result<u32, std::io::Error> = Ok(5);
        let v = ok
            .with_context(|| -> String { panic!("must not evaluate on Ok") })
            .unwrap();
        assert_eq!(v, 5);
    }
}
