//! Design explorer: sweep dataflows and memory hierarchies for *your*
//! layer from the command line — the workflow the paper's optimization
//! framework (§6.3) is built for.
//!
//! Run, e.g.:
//! ```text
//! cargo run --release --example design_explorer -- \
//!     --k 384 --c 256 --x 13 --f 3 --batch 8 --rows 16 --cols 16
//! ```

use interstellar::arch::{eyeriss_like, ArrayShape};
use interstellar::dataflow::{best_replication, enumerate_dataflows, utilization};
use interstellar::energy::Table3;
use interstellar::loopnest::Shape;
use interstellar::search::{default_threads, optimize_layer, search_hierarchy, SearchOpts};
use interstellar::util::{table::Table, Args};
use interstellar::nn::{network, Network};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let shape = Shape::new(
        args.get_u64("batch", 4),
        args.get_u64("k", 384),
        args.get_u64("c", 256),
        args.get_u64("x", 13),
        args.get_u64("y", args.get_u64("x", 13)),
        args.get_u64("f", 3),
        args.get_u64("f", 3),
        args.get_u64("stride", 1) as u32,
    );
    let array = ArrayShape {
        rows: args.get_u64("rows", 16) as u32,
        cols: args.get_u64("cols", 16) as u32,
    };
    let threads = args.get_usize("threads", default_threads());
    let opts = SearchOpts::capped(args.get_usize("max-blockings", 800), 6);

    println!(
        "layer: B={} K={} C={} X=Y={} F={} stride={}  ({} MACs)",
        shape.bounds[0], shape.bounds[1], shape.bounds[2], shape.bounds[3],
        shape.bounds[5], shape.stride, shape.macs()
    );

    // dataflow sweep with optimal blocking on the Eyeriss-like config
    let arch = eyeriss_like();
    let mut t = Table::new(vec!["dataflow", "repl map", "util %", "energy (uJ)"]);
    let mut best: Option<(String, f64)> = None;
    for df in enumerate_dataflows(&shape) {
        let repl = best_replication(&shape, &df, &array);
        let util = utilization(&shape, &repl, &array);
        let cell = match optimize_layer(&shape, &arch, &df, &Table3, &opts, threads) {
            Some(lo) => {
                let e = lo.result.energy_pj;
                if best.as_ref().map(|(_, b)| e < *b).unwrap_or(true) {
                    best = Some((df.to_string(), e));
                }
                format!("{:.2}", lo.result.energy_uj())
            }
            None => "-".into(),
        };
        t.row(vec![
            df.to_string(),
            repl.to_string(),
            format!("{:.0}", 100.0 * util),
            cell,
        ]);
    }
    println!("\n== dataflow sweep on {} ==", arch.describe());
    print!("{}", t.to_text());
    if let Some((name, e)) = &best {
        println!("\nbest dataflow: {name} at {:.2} uJ", e / 1e6);
    }

    // hierarchy search for a single-layer "network"
    println!("\n== memory-hierarchy search ==");
    let net = Network {
        name: "custom".into(),
        layers: vec![interstellar::nn::Layer::conv(
            "LAYER",
            shape.bounds[0],
            shape.bounds[1],
            shape.bounds[2],
            shape.bounds[3],
            shape.bounds[4],
            shape.bounds[5],
            shape.stride,
        )],
        batch: shape.bounds[0],
    };
    let results = search_hierarchy(&net, array, &Table3, &opts, threads);
    let mut ht = Table::new(vec!["hierarchy", "energy (uJ)"]);
    for r in results.iter().take(8) {
        ht.row(vec![
            r.arch.name.clone(),
            format!("{:.2}", r.opt.total_energy_pj / 1e6),
        ]);
    }
    print!("{}", ht.to_text());

    let _ = network("alexnet", 1); // keep the nn API exercised in docs
    Ok(())
}
