//! Schedule tour: re-create the prior-work accelerators of §2/Fig 6 as
//! Halide-style schedules, print their lowered IR, and compare their
//! energy on the same layer and hardware budget — the paper's "fair
//! comparison" exercise.
//!
//! Run: `cargo run --release --example schedule_tour`

use interstellar::arch::{eyeriss_like, no_local_reuse, Arch};
use interstellar::energy::Table3;
use interstellar::halide::{
    diannao_tree, eyeriss_rs, nvdla_like, print_ir, shidiannao_os, tpu_ck, Schedule,
};
use interstellar::loopnest::Shape;
use interstellar::util::table::Table;
use interstellar::xmodel::evaluate;

fn main() -> anyhow::Result<()> {
    let conv3 = Shape::new(4, 384, 256, 13, 13, 3, 3, 1);
    let systolic = eyeriss_like();
    let broadcast = no_local_reuse();

    let cases: Vec<(Schedule, &Arch)> = vec![
        (eyeriss_rs(conv3, 16, 16), &systolic),
        (tpu_ck(conv3, 16, 16), &systolic),
        (shidiannao_os(conv3, 16, 16), &systolic),
        (diannao_tree(conv3, 16), &broadcast),
        (nvdla_like(conv3, 16, 16), &broadcast),
    ];

    let mut table = Table::new(vec![
        "schedule", "dataflow", "PEs", "energy (uJ)", "util %", "RF %", "DRAM %",
    ]);

    for (schedule, arch) in cases {
        println!("=== {} ===", schedule.name);
        println!("{}", print_ir(&schedule));
        let (mapping, smap) = schedule.lower(arch)?;
        let r = evaluate(&mapping, &smap, arch, &Table3)?;
        table.row(vec![
            schedule.name.clone(),
            smap.label().to_string(),
            format!("{}", mapping.pe_count()),
            format!("{:.1}", r.energy_uj()),
            format!("{:.0}", 100.0 * r.utilization),
            format!("{:.0}", 100.0 * r.level_fraction(0)),
            format!("{:.0}", 100.0 * r.level_fraction(arch.num_levels() - 1)),
        ]);
    }

    println!("=== fair comparison on AlexNet CONV3 (batch 4) ===");
    print!("{}", table.to_text());
    println!(
        "\nObservation 1 in action: with each design's own blocking these\n\
         energies differ; §6 shows that once blocking is *optimized per\n\
         dataflow* the spread nearly vanishes (see `cargo bench --bench\n\
         fig8_dataflow`)."
    );
    Ok(())
}
