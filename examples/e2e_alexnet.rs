//! End-to-end driver: every layer of the stack composes.
//!
//! 1. **Optimize** (L3): the auto-optimizer picks a memory hierarchy +
//!    blocking for AlexNet (fix `C|K`, ratio rule) and reports the gain
//!    over the Eyeriss-like baseline.
//! 2. **Validate** (L3): the winning mapping's analytical energy is
//!    cross-checked against the exact trace simulator.
//! 3. **Execute** (L1/L2 via PJRT): the scheduled layer's *numerics* run
//!    through the AOT-compiled JAX/Pallas artifact on the PJRT CPU
//!    client and are checked against the Rust functional simulator.
//! 4. **Serve** (L3 runtime): a mixed trace of a few hundred layer
//!    requests is served by worker threads over the artifact registry;
//!    latency and throughput are reported.
//!
//! Run: `make artifacts && cargo run --release --example e2e_alexnet`

use std::path::Path;

use interstellar::arch::{eyeriss_like, ArrayShape};
use interstellar::coordinator::serve::{mixed_trace, serve};
use interstellar::dataflow::Dataflow;
use interstellar::energy::Table3;
use interstellar::loopnest::Shape;
use interstellar::nn::network;
use interstellar::runtime::Runtime;
use interstellar::search::{
    default_threads, optimize_network, search_hierarchy, SearchOpts,
};
use interstellar::sim::{reference_conv, simulate, ConvData};
use interstellar::util::fmt_sig;

fn main() -> anyhow::Result<()> {
    let threads = default_threads();
    let df = Dataflow::parse("C|K").unwrap();
    let opts = SearchOpts::capped(1200, 6);

    // ---- 1. auto-optimizer ------------------------------------------------
    println!("[1/4] auto-optimizing AlexNet (batch 4) on a 16x16 array...");
    let net = network("alexnet", 4).unwrap();
    let baseline = optimize_network(&net, &eyeriss_like(), &df, &Table3, &opts, threads);
    let results = search_hierarchy(&net, ArrayShape { rows: 16, cols: 16 }, &Table3, &opts, threads);
    let best = results.first().expect("hierarchy search found nothing");
    println!(
        "  baseline (Eyeriss-like): {} uJ",
        fmt_sig(baseline.total_energy_pj / 1e6)
    );
    println!(
        "  optimized:              {} uJ on {}  -> {:.2}x better, {:.2} TOPS/W",
        fmt_sig(best.opt.total_energy_pj / 1e6),
        best.arch.name,
        baseline.total_energy_pj / best.opt.total_energy_pj,
        best.opt.tops_per_watt()
    );

    // ---- 2. model vs simulator -------------------------------------------
    println!("[2/4] validating the winning CONV3 mapping against the trace simulator...");
    let conv3_idx = net.layers.iter().position(|l| l.name == "CONV3").unwrap();
    let lo = best.opt.per_layer[conv3_idx]
        .as_ref()
        .expect("CONV3 mapping");
    let sim = simulate(&lo.mapping, &lo.smap, &best.arch, &Table3, 3_000_000_000)?;
    let err = 100.0 * (lo.result.energy_pj - sim.energy_pj).abs() / sim.energy_pj;
    println!(
        "  model {} uJ vs sim {} uJ  (err {:.4}% — paper requires < 2%)",
        fmt_sig(lo.result.energy_uj()),
        fmt_sig(sim.energy_uj()),
        err
    );
    assert!(err < 2.0, "validation failed");

    // ---- 3. numerics through PJRT -----------------------------------------
    let artifacts = Path::new("artifacts");
    if !artifacts.join("manifest.txt").exists() {
        println!("[3/4] SKIPPED: artifacts/ not built (run `make artifacts`)");
        println!("[4/4] SKIPPED");
        return Ok(());
    }
    println!("[3/4] executing the conv3x3 artifact via PJRT and cross-checking numerics...");
    let rt = Runtime::load(artifacts)?;
    let entry = rt.entry("conv3x3").unwrap().clone();
    let (b, xh, c) = (
        entry.inputs[0].dims[0] as u64,
        entry.inputs[0].dims[1] as u64,
        entry.inputs[0].dims[3] as u64,
    );
    let (fx, k) = (entry.inputs[1].dims[0] as u64, entry.inputs[1].dims[3] as u64);
    let x = xh - fx + 1;
    let shape = Shape::new(b, k, c, x, x, fx, fx, 1);
    let data = ConvData::random(shape, 2024);
    // repack the simulator's [B][C][H][W] / [K][C][FX][FY] layouts to NHWC/HWIO
    let ix = shape.input_x();
    let mut inp = vec![0.0f32; data.input.len()];
    for bb in 0..b {
        for cc in 0..c {
            for i in 0..ix {
                for j in 0..ix {
                    inp[(((bb * ix + i) * ix + j) * c + cc) as usize] =
                        data.input[(((bb * c + cc) * ix + i) * ix + j) as usize];
                }
            }
        }
    }
    let mut w = vec![0.0f32; data.weight.len()];
    for kk in 0..k {
        for cc in 0..c {
            for i in 0..fx {
                for j in 0..fx {
                    w[(((i * fx + j) * c + cc) * k + kk) as usize] =
                        data.weight[(((kk * c + cc) * fx + i) * fx + j) as usize];
                }
            }
        }
    }
    let outs = rt.execute_f32("conv3x3", &[inp, w])?;
    let want = reference_conv(&data);
    let mut max_err = 0.0f32;
    for bb in 0..b {
        for kk in 0..k {
            for i in 0..x {
                for j in 0..x {
                    let g = outs[0][(((bb * x + i) * x + j) * k + kk) as usize];
                    let e = want[(((bb * k + kk) * x + i) * x + j) as usize];
                    max_err = max_err.max((g - e).abs());
                }
            }
        }
    }
    println!("  PJRT (JAX/Pallas) vs Rust functional simulator: max |err| = {max_err:.2e}");
    assert!(max_err < 1e-2, "numerics mismatch");

    // ---- 4. batched serving ------------------------------------------------
    println!("[4/4] serving 300 mixed layer requests over the artifact registry...");
    let stats = serve(artifacts, mixed_trace(300, 7), threads)?;
    println!(
        "  {} requests in {:.2}s  mean {:.2} ms  p95 {:.2} ms  {:.1} req/s",
        stats.completed, stats.wall_s, stats.mean_latency_ms, stats.p95_latency_ms, stats.rps
    );
    println!("\nE2E OK: optimizer -> model==sim -> PJRT numerics -> serving all compose.");
    Ok(())
}
