//! Quickstart: schedule AlexNet CONV3 with the Halide-style DSL, lower it
//! onto the Eyeriss-like architecture, and evaluate energy/performance
//! with the analytical model — the paper's §4 flow in ~40 lines.
//!
//! Run: `cargo run --release --example quickstart`

use interstellar::arch::eyeriss_like;
use interstellar::energy::Table3;
use interstellar::halide::{print_ir, tpu_ck};
use interstellar::loopnest::Shape;
use interstellar::sim::simulate;
use interstellar::xmodel::evaluate;

fn main() -> anyhow::Result<()> {
    // AlexNet CONV3 at batch 4: B=4, K=384, C=256, 13x13 out, 3x3 filter.
    let conv3 = Shape::new(4, 384, 256, 13, 13, 3, 3, 1);
    let arch = eyeriss_like();
    println!("layer: AlexNet CONV3, {} MACs", conv3.macs());
    println!("arch:  {}\n", arch.describe());

    // A TPU-style C|K schedule, written with the scheduling primitives
    // (split / reorder / in+compute_at / unroll / systolic) and lowered.
    let schedule = tpu_ck(conv3, 16, 16);
    println!("=== schedule IR (Listing-2 style) ===");
    println!("{}", print_ir(&schedule));

    let (mapping, smap) = schedule.lower(&arch)?;
    println!("dataflow: {} on a 16x16 systolic array", smap.label());
    println!("PEs used: {}\n", mapping.pe_count());

    // Analytical model: access counts -> energy -> performance.
    let result = evaluate(&mapping, &smap, &arch, &Table3)?;
    println!("=== energy breakdown (analytical model) ===");
    print!("{}", result.breakdown_table(&arch).to_text());
    println!(
        "\ntotal: {:.1} uJ, {:.0} cycles, utilization {:.1}%, {:.2} TOPS/W",
        result.energy_uj(),
        result.cycles,
        100.0 * result.utilization,
        result.tops_per_watt(0.4),
    );

    // Cross-check against the exact trace simulator (same counts).
    let sim = simulate(&mapping, &smap, &arch, &Table3, 3_000_000_000)?;
    println!(
        "simulator cross-check: {:.1} uJ (diff {:.4}%)",
        sim.energy_uj(),
        100.0 * (result.energy_pj - sim.energy_pj).abs() / sim.energy_pj
    );
    Ok(())
}
