//! Functional simulation: compute the layer's actual outputs by walking
//! the scheduled (blocked / reordered / unrolled) nest, and a naive
//! seven-loop reference.
//!
//! Test data is small-integer-valued f32 so every sum is exact regardless
//! of accumulation order — schedule equivalence can then be asserted
//! bit-for-bit.

use crate::loopnest::{Dim, Mapping, Shape, ALL_DIMS, NDIMS};
use crate::util::XorShift;

/// Input + weight data for one conv-shaped layer.
#[derive(Debug, Clone)]
pub struct ConvData {
    /// Layer shape.
    pub shape: Shape,
    /// Input `[B][C][IX][IY]`, row-major.
    pub input: Vec<f32>,
    /// Weights `[K][C][FX][FY]`, row-major.
    pub weight: Vec<f32>,
}

impl ConvData {
    /// Random small-integer data (values in `{-4..4}`) from a seed.
    pub fn random(shape: Shape, seed: u64) -> Self {
        let mut rng = XorShift::new(seed);
        let isz = (shape.bound(Dim::B) * shape.bound(Dim::C) * shape.input_x() * shape.input_y())
            as usize;
        let wsz = (shape.bound(Dim::K)
            * shape.bound(Dim::C)
            * shape.bound(Dim::FX)
            * shape.bound(Dim::FY)) as usize;
        let gen = |rng: &mut XorShift, n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.range(0, 8) as f32 - 4.0).collect()
        };
        ConvData {
            shape,
            input: gen(&mut rng, isz),
            weight: gen(&mut rng, wsz),
        }
    }

    #[inline]
    fn in_idx(&self, b: u64, c: u64, ix: u64, iy: u64) -> usize {
        let s = &self.shape;
        (((b * s.bound(Dim::C) + c) * s.input_x() + ix) * s.input_y() + iy) as usize
    }

    #[inline]
    fn w_idx(&self, k: u64, c: u64, fx: u64, fy: u64) -> usize {
        let s = &self.shape;
        (((k * s.bound(Dim::C) + c) * s.bound(Dim::FX) + fx) * s.bound(Dim::FY) + fy) as usize
    }

    #[inline]
    fn out_idx(&self, b: u64, k: u64, x: u64, y: u64) -> usize {
        let s = &self.shape;
        (((b * s.bound(Dim::K) + k) * s.bound(Dim::X) + x) * s.bound(Dim::Y) + y) as usize
    }

    /// Output element count.
    pub fn out_len(&self) -> usize {
        let s = &self.shape;
        (s.bound(Dim::B) * s.bound(Dim::K) * s.bound(Dim::X) * s.bound(Dim::Y)) as usize
    }
}

/// Naive seven-loop reference (Algorithm 1 order).
pub fn reference_conv(data: &ConvData) -> Vec<f32> {
    let s = data.shape;
    let mut out = vec![0.0f32; data.out_len()];
    for b in 0..s.bound(Dim::B) {
        for k in 0..s.bound(Dim::K) {
            for c in 0..s.bound(Dim::C) {
                for x in 0..s.bound(Dim::X) {
                    for y in 0..s.bound(Dim::Y) {
                        for fx in 0..s.bound(Dim::FX) {
                            for fy in 0..s.bound(Dim::FY) {
                                let ix = x * s.stride as u64 + fx;
                                let iy = y * s.stride as u64 + fy;
                                out[data.out_idx(b, k, x, y)] += data.input
                                    [data.in_idx(b, c, ix, iy)]
                                    * data.weight[data.w_idx(k, c, fx, fy)];
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// One loop in the flattened schedule: dim + factor + the multiplier this
/// loop's digit contributes to the dim's global index.
#[derive(Debug, Clone, Copy)]
struct IdxLoop {
    dim: Dim,
    factor: u64,
    stride: u64,
}

/// Execute the scheduled nest: walk every loop of the mapping — temporal
/// levels outermost-first, the spatial loops in their array position
/// (serialized; parallel semantics are order-independent) — computing the
/// same MACs as Algorithm 1 in the schedule's order.
pub fn functional_conv(m: &Mapping, data: &ConvData) -> Vec<f32> {
    assert_eq!(m.shape, data.shape, "mapping and data shapes differ");
    m.validate().expect("mapping must validate");

    // Per-dim index strides: levels are significance-ordered inner→outer
    // (level 0 digit least significant, spatial digit sits between
    // spatial_at-1 and spatial_at).
    let mut strides: Vec<[u64; NDIMS]> = Vec::with_capacity(m.levels());
    let mut spatial_stride = [0u64; NDIMS];
    {
        let mut acc = [1u64; NDIMS];
        for level in 0..m.levels() {
            if level == m.spatial_at {
                for d in ALL_DIMS {
                    spatial_stride[d.idx()] = acc[d.idx()];
                    acc[d.idx()] *= m.spatial[d.idx()];
                }
            }
            let mut row = [0u64; NDIMS];
            for d in ALL_DIMS {
                row[d.idx()] = acc[d.idx()];
                acc[d.idx()] *= m.blocking.factor(level, d);
            }
            strides.push(row);
        }
        if m.spatial_at == m.levels() {
            for d in ALL_DIMS {
                spatial_stride[d.idx()] = acc[d.idx()];
            }
        }
    }

    // Flatten outermost-first: top temporal levels, then (at the array
    // position) the spatial loops, then inner temporal levels.
    let mut loops: Vec<IdxLoop> = Vec::new();
    for level in (0..m.levels()).rev() {
        if level + 1 == m.spatial_at {
            // spatial loops sit just outside temporal level spatial_at - 1
            for d in ALL_DIMS {
                if m.spatial[d.idx()] > 1 {
                    loops.push(IdxLoop {
                        dim: d,
                        factor: m.spatial[d.idx()],
                        stride: spatial_stride[d.idx()],
                    });
                }
            }
        }
        for &d in m.orders[level].0.iter().rev() {
            let f = m.blocking.factor(level, d);
            if f > 1 {
                loops.push(IdxLoop {
                    dim: d,
                    factor: f,
                    stride: strides[level][d.idx()],
                });
            }
        }
    }

    let mut idx = [0u64; NDIMS]; // current global index per dim
    let mut digits = vec![0u64; loops.len()];
    let mut out = vec![0.0f32; data.out_len()];
    let s = data.shape;

    loop {
        let (b, k, c, x, y, fx, fy) = (
            idx[0], idx[1], idx[2], idx[3], idx[4], idx[5], idx[6],
        );
        let ix = x * s.stride as u64 + fx;
        let iy = y * s.stride as u64 + fy;
        out[data.out_idx(b, k, x, y)] +=
            data.input[data.in_idx(b, c, ix, iy)] * data.weight[data.w_idx(k, c, fx, fy)];

        // increment mixed-radix counter, innermost digit last
        let mut p = loops.len();
        loop {
            if p == 0 {
                return out;
            }
            p -= 1;
            digits[p] += 1;
            idx[loops[p].dim.idx()] += loops[p].stride;
            if digits[p] < loops[p].factor {
                break;
            }
            idx[loops[p].dim.idx()] -= loops[p].factor * loops[p].stride;
            digits[p] = 0;
        }
    }
}
