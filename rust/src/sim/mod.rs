//! Trace-driven simulator: executes the scheduled loop nest and counts
//! every tile (re)load exactly, by walking the loops — no refetch
//! formulas. This is the project's stand-in for the paper's
//! post-synthesis validation (Fig 7): the analytical model must agree
//! with these counts (the paper reports < 2 % error; ours is exact-match
//! because both sides model the same machine, which the tests assert).
//!
//! Also provides a **functional mode** that computes the layer's actual
//! outputs by walking the blocked nest, proving that blocking/reordering/
//! unrolling never changes semantics, and giving a reference to
//! cross-check the PJRT-executed artifact in the e2e example.

mod functional;
mod walk;

pub use functional::{functional_conv, reference_conv, ConvData};
pub use walk::{count_rounds, simulate, SimError};

#[cfg(test)]
mod tests;
