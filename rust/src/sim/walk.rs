//! Exact access counting by loop-nest walking.

use crate::arch::Arch;
use crate::dataflow::SpatialMap;
use crate::energy::CostModel;
use crate::loopnest::{Mapping, ALL_TENSORS};
use crate::xmodel::{ModelResult, RoundTables};

/// Simulator failure modes.
#[derive(Debug, Clone, PartialEq)]
pub enum SimError {
    /// The walk would exceed the step budget.
    TooManySteps {
        /// Steps the walk would need.
        need: u64,
        /// Budget given.
        budget: u64,
    },
    /// The mapping is inconsistent.
    BadMapping(String),
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::TooManySteps { need, budget } => {
                write!(f, "walk needs {need} steps, budget {budget}")
            }
            SimError::BadMapping(e) => write!(f, "bad mapping: {e}"),
        }
    }
}

impl std::error::Error for SimError {}

/// One temporal loop in the flattened nest (outermost first).
#[derive(Debug, Clone, Copy)]
struct LoopSpec {
    factor: u64,
    /// Bit `t` set when the loop's dim is relevant to tensor `t`.
    relevance: u8,
}

/// Flatten the temporal loops at levels `>= boundary`, outermost first
/// (levels top-down; within a level the order is reversed because
/// [`crate::loopnest::LevelOrder`] lists dims innermost-first).
/// Factor-1 loops are dropped (they never change any tuple).
fn flatten(m: &Mapping, boundary: usize) -> Vec<LoopSpec> {
    let mut out = Vec::new();
    for level in (boundary..m.levels()).rev() {
        for &d in m.orders[level].0.iter().rev() {
            let f = m.blocking.factor(level, d);
            if f > 1 {
                let mut rel = 0u8;
                for t in ALL_TENSORS {
                    if t.relevant(d) {
                        rel |= 1 << t.idx();
                    }
                }
                out.push(LoopSpec {
                    factor: f,
                    relevance: rel,
                });
            }
        }
    }
    out
}

/// Walk one boundary's loops and count, per tensor, the number of runs of
/// constant relevant-coordinate tuple — i.e. the exact number of times
/// the tile below `boundary` is (re)loaded.
fn walk_boundary(loops: &[LoopSpec]) -> [u64; 3] {
    let n = loops.len();
    if n == 0 {
        return [1, 1, 1];
    }
    let mut digits = vec![0u64; n];
    let mut runs = [1u64; 3];
    'outer: loop {
        // increment the mixed-radix counter (innermost digit = last)
        let mut changed: u8 = 0;
        let mut p = n;
        loop {
            if p == 0 {
                break 'outer;
            }
            p -= 1;
            digits[p] += 1;
            if digits[p] < loops[p].factor {
                changed |= loops[p].relevance;
                break;
            }
            // rollover to 0: a change only if it was not already 0
            // (it was factor-1 >= 1, so it did change)
            digits[p] = 0;
            changed |= loops[p].relevance;
        }
        for t in 0..3 {
            if changed & (1 << t) != 0 {
                runs[t] += 1;
            }
        }
    }
    runs
}

/// Exact per-boundary round tables by loop walking. `budget` bounds the
/// total walk steps (the innermost boundary costs `Π temporal factors`
/// steps — for one PE, that's `MACs / PEs`).
pub fn count_rounds(m: &Mapping, budget: u64) -> Result<RoundTables, SimError> {
    m.validate().map_err(SimError::BadMapping)?;
    let nlv = m.levels();

    // cost check: sum over boundaries of product of factors above
    let mut need: u64 = 0;
    for i in 0..nlv {
        let p: u64 = flatten(m, i).iter().map(|l| l.factor).product();
        need = need.saturating_add(p);
    }
    if need > budget {
        return Err(SimError::TooManySteps { need, budget });
    }

    let mut tables = RoundTables::default();
    for i in 0..nlv {
        let loops = flatten(m, i);
        let runs = walk_boundary(&loops);
        for t in ALL_TENSORS {
            tables.rounds[t.idx()][i] = runs[t.idx()] as f64;
            // every combination of relevant digits is visited, so the
            // distinct count is exactly the product of relevant factors
            tables.distinct[t.idx()][i] = loops
                .iter()
                .filter(|l| l.relevance & (1 << t.idx()) != 0)
                .map(|l| l.factor as f64)
                .product();
        }
    }
    Ok(tables)
}

/// Full simulation: exact round counting + the shared assembly into
/// energy/performance (the engine's stage-3/4 back half — the same
/// accumulation and roll-up the analytical model uses, so any
/// disagreement is in the round counts — the part being validated).
pub fn simulate(
    m: &Mapping,
    smap: &SpatialMap,
    arch: &Arch,
    cost: &dyn CostModel,
    budget: u64,
) -> Result<ModelResult, SimError> {
    let tables = count_rounds(m, budget)?;
    Ok(crate::engine::assemble(m, smap, arch, cost, &tables))
}

#[cfg(test)]
mod unit {
    use super::*;
    use crate::loopnest::{Dim, LevelOrder, Shape, Tensor};

    #[test]
    fn flatten_drops_unit_loops_and_orders_outermost_first() {
        let shape = Shape::new(1, 4, 2, 1, 1, 1, 1, 1);
        let mut m = Mapping::trivial(shape, 1, 1);
        // level 1 (DRAM) holds K=4, C=2; level 0 nothing
        let loops = flatten(&m, 0);
        assert_eq!(loops.len(), 2);
        // canonical order is [FX,FY,C,X,Y,K,B] innermost-first, so
        // outermost-first the K loop precedes the C loop
        assert_eq!(loops[0].factor, 4);
        assert_eq!(loops[1].factor, 2);
        // boundary above DRAM sees nothing
        m.orders[1] = LevelOrder::canonical();
        assert_eq!(flatten(&m, m.levels()).len(), 0);
        let _ = Dim::B;
    }

    #[test]
    fn walk_small_nest_by_hand() {
        // loops: K=2 outer, C=3 inner (canonical order has K outside C)
        // W (relevant both): 6 runs. O (K only): C changes don't count
        // while K constant -> runs = 2. I (C only): every C change and
        // every K rollover changes C..., K irrelevant but C resets:
        // tuple is (c); sequence c=0,1,2,0,1,2 -> changes at each step
        // except the repeat 2->0 boundary? 2->0 IS a change. runs = 6.
        let shape = Shape::new(1, 2, 3, 1, 1, 1, 1, 1);
        let m = Mapping::trivial(shape, 1, 1);
        let loops = flatten(&m, 0);
        let runs = walk_boundary(&loops);
        assert_eq!(runs[Tensor::Weight.idx()], 6);
        assert_eq!(runs[Tensor::Output.idx()], 2);
        assert_eq!(runs[Tensor::Input.idx()], 6);
    }

    #[test]
    fn stationarity_depends_on_order() {
        // Same factors, two orders at DRAM level: K outside C vs C outside K.
        // For O (K relevant, C irrelevant): K-outer -> 2 runs; C-outer ->
        // the O tuple (k) cycles 0,1,0,1..: 6 runs.
        let shape = Shape::new(1, 2, 3, 1, 1, 1, 1, 1);
        let mut m = Mapping::trivial(shape, 1, 1);
        // order innermost-first: C inner, K outer
        m.orders[1] = LevelOrder([Dim::C, Dim::K, Dim::B, Dim::X, Dim::Y, Dim::FX, Dim::FY]);
        let runs = walk_boundary(&flatten(&m, 0));
        assert_eq!(runs[Tensor::Output.idx()], 2);

        // K inner, C outer
        m.orders[1] = LevelOrder([Dim::K, Dim::C, Dim::B, Dim::X, Dim::Y, Dim::FX, Dim::FY]);
        let runs = walk_boundary(&flatten(&m, 0));
        assert_eq!(runs[Tensor::Output.idx()], 6);
        // W relevant to both: 6 either way
        assert_eq!(runs[Tensor::Weight.idx()], 6);
    }

    #[test]
    fn budget_enforced() {
        let shape = Shape::new(8, 64, 64, 32, 32, 3, 3, 1);
        let m = Mapping::trivial(shape, 1, 1);
        match count_rounds(&m, 1000) {
            Err(SimError::TooManySteps { .. }) => {}
            other => panic!("expected TooManySteps, got {other:?}"),
        }
    }
}
