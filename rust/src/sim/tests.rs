//! Simulator validation: model == sim on round tables, and functional
//! schedule-equivalence.

use super::*;
use crate::energy::Table3;
use crate::loopnest::{Shape, ALL_TENSORS};
use crate::util::prop;
use crate::xmodel::RoundTables;

fn random_shape(rng: &mut crate::util::XorShift) -> Shape {
    Shape::new(
        rng.range(1, 3),
        rng.range(1, 12),
        rng.range(1, 12),
        rng.range(1, 7),
        rng.range(1, 7),
        rng.range(1, 3),
        rng.range(1, 3),
        rng.range(1, 2) as u32,
    )
}

#[test]
fn prop_model_rounds_equal_sim_rounds() {
    // THE core validation: the analytical refetch formula must equal the
    // exact loop-walk counts for arbitrary blockings, orders, and
    // spatial splits (Fig 7's purpose, made exact).
    prop::for_cases(0x510, 300, |rng| {
        let shape = random_shape(rng);
        let levels = rng.range(2, 4) as usize;
        let m = crate::search::random_mapping(shape, levels, 1, rng);
        let analytic = RoundTables::analytic(&m);
        let exact = count_rounds(&m, 50_000_000).expect("budget");
        for t in ALL_TENSORS {
            for i in 0..m.levels() {
                assert_eq!(
                    analytic.rounds[t.idx()][i], exact.rounds[t.idx()][i],
                    "rounds {t} boundary {i}\nmapping: {m:?}"
                );
                assert_eq!(
                    analytic.distinct[t.idx()][i], exact.distinct[t.idx()][i],
                    "distinct {t} boundary {i}\nmapping: {m:?}"
                );
            }
        }
    });
}

#[test]
fn prop_functional_conv_matches_reference() {
    // Blocking / reordering / unrolling never changes semantics: the
    // scheduled walk computes bit-identical outputs (integer-valued data).
    prop::for_cases(0xf1, 60, |rng| {
        let shape = random_shape(rng);
        let levels = rng.range(2, 3) as usize;
        let m = crate::search::random_mapping(shape, levels, 1, rng);
        let data = ConvData::random(shape, rng.next_u64());
        let got = functional_conv(&m, &data);
        let want = reference_conv(&data);
        assert_eq!(got, want, "schedule changed semantics: {m:?}");
    });
}

#[test]
fn functional_strided_conv() {
    let shape = Shape::new(1, 4, 3, 5, 5, 3, 3, 2);
    let mut rng = crate::util::XorShift::new(7);
    let m = crate::search::random_mapping(shape, 3, 1, &mut rng);
    let data = ConvData::random(shape, 99);
    assert_eq!(functional_conv(&m, &data), reference_conv(&data));
}

#[test]
fn simulate_assembles_same_as_model_on_matching_tables() {
    // When tables agree, energies agree exactly.
    let shape = Shape::new(2, 8, 8, 4, 4, 3, 3, 1);
    let mut rng = crate::util::XorShift::new(13);
    let arch = crate::arch::eyeriss_like();
    for _ in 0..10 {
        let (m, smap) = crate::search::random_mapping_for_arch(shape, &arch, &mut rng);
        let model = match crate::xmodel::evaluate(&m, &smap, &arch, &Table3) {
            Ok(r) => r,
            Err(_) => continue, // capacity misses are fine here
        };
        let sim = simulate(&m, &smap, &arch, &Table3, 100_000_000).unwrap();
        assert!(
            (model.energy_pj - sim.energy_pj).abs() <= 1e-6 * model.energy_pj.max(1.0),
            "model {} != sim {}",
            model.energy_pj,
            sim.energy_pj
        );
    }
}

#[test]
fn reference_conv_known_values() {
    // 1x1x1 output with 2x2 filter over constant data
    let shape = Shape::new(1, 1, 1, 1, 1, 2, 2, 1);
    let data = ConvData {
        shape,
        input: vec![1.0, 2.0, 3.0, 4.0],
        weight: vec![1.0, 1.0, 1.0, 1.0],
    };
    assert_eq!(reference_conv(&data), vec![10.0]);
}
