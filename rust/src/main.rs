//! `interstellar` — leader binary: CLI over the coordinator.

use anyhow::Result;
use interstellar::coordinator::cli;
use interstellar::util::Args;

fn main() -> Result<()> {
    cli::run(Args::from_env())
}
