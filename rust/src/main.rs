//! `interstellar` — leader binary: CLI over the coordinator.

use anyhow::Result;
use interstellar::coordinator::cli;
use interstellar::telemetry;
use interstellar::util::Args;

fn main() -> Result<()> {
    // Tracing is opt-in via INTERSTELLAR_TRACE; spawned workers inherit
    // the environment, so one env var traces a whole fleet/sweep. The
    // final flush runs on the error path too — a failing command still
    // leaves a readable trace.
    telemetry::init_from_env();
    let result = cli::run(Args::from_env());
    telemetry::flush();
    result
}
