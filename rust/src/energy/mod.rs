//! The Table 3 energy cost model: energy per 16-bit access for register
//! files and SRAMs of various sizes, plus MAC, inter-PE hop, and DRAM
//! costs. All values in picojoules, 28 nm.
//!
//! Interpolation beyond the table's anchor points follows the table's own
//! structure: RF energy is **linear** in size (each doubling doubles the
//! cost: 16 B = 0.03 → 512 B = 0.96), SRAM energy grows **×1.5 per
//! doubling** (32 KB = 6 → 512 KB = 30.375), i.e. `size^log2(1.5)`.

use crate::arch::{Arch, LevelKind};

/// Energy cost provider (pJ). Pluggable so different technology nodes can
/// be studied (§5: "it is easy to supply new cost models").
pub trait CostModel: Send + Sync {
    /// Energy per access of a register file of `size_bytes`.
    fn reg_access(&self, size_bytes: u64) -> f64;
    /// Energy per access of an SRAM of `size_bytes`.
    fn sram_access(&self, size_bytes: u64) -> f64;
    /// Energy per DRAM access.
    fn dram_access(&self) -> f64;
    /// Energy per 16-bit MAC.
    fn mac(&self) -> f64;
    /// Energy per one-hop inter-PE word transfer.
    fn hop(&self) -> f64;

    /// Energy per access of architecture level `i`.
    fn level_access(&self, arch: &Arch, i: usize) -> f64 {
        let l = &arch.levels[i];
        match l.kind {
            LevelKind::Reg => self.reg_access(l.size_bytes),
            LevelKind::Sram => self.sram_access(l.size_bytes),
            LevelKind::Dram => self.dram_access(),
        }
    }
}

/// The paper's Table 3 (28 nm, 16-bit words).
#[derive(Debug, Clone, Default)]
pub struct Table3;

/// RF anchor: 16 B costs 0.03 pJ, linear in size.
const RF_BASE_BYTES: f64 = 16.0;
const RF_BASE_PJ: f64 = 0.03;
/// SRAM anchor: 32 KB costs 6 pJ, ×1.5 per doubling.
const SRAM_BASE_BYTES: f64 = 32.0 * 1024.0;
const SRAM_BASE_PJ: f64 = 6.0;
const SRAM_DOUBLING: f64 = 1.5;

impl CostModel for Table3 {
    fn reg_access(&self, size_bytes: u64) -> f64 {
        // Linear: E = 0.03 * size/16. Clamp below 8 B to the 8 B value so
        // the TPU-like 8 B register costs 0.015 pJ, not ~0.
        let s = (size_bytes as f64).max(8.0);
        RF_BASE_PJ * s / RF_BASE_BYTES
    }

    fn sram_access(&self, size_bytes: u64) -> f64 {
        // E = 6 * 1.5^(log2(size/32K)) = 6 * (size/32K)^log2(1.5) within
        // the table's range. Beyond 512 KB the growth flattens to x1.2
        // per doubling: very large buffers are heavily banked (the
        // per-access cost approaches the bank cost plus wire energy), so
        // the TPU-like 28 MB L2 stays cheaper than DRAM.
        let s = (size_bytes as f64).max(1024.0);
        let table_top = 512.0 * 1024.0;
        if s <= table_top {
            let ratio = s / SRAM_BASE_BYTES;
            SRAM_BASE_PJ * ratio.powf(SRAM_DOUBLING.log2())
        } else {
            let top = SRAM_BASE_PJ * (table_top / SRAM_BASE_BYTES).powf(SRAM_DOUBLING.log2());
            top * (s / table_top).powf(1.2f64.log2())
        }
    }

    fn dram_access(&self) -> f64 {
        200.0
    }

    fn mac(&self) -> f64 {
        0.075
    }

    fn hop(&self) -> f64 {
        0.035
    }
}

/// The anchor rows of Table 3, for the `table3_energy` bench and tests:
/// `(kind, size_bytes, pJ)`.
pub fn table3_anchors() -> Vec<(LevelKind, u64, f64)> {
    vec![
        (LevelKind::Reg, 16, 0.03),
        (LevelKind::Reg, 32, 0.06),
        (LevelKind::Reg, 64, 0.12),
        (LevelKind::Reg, 128, 0.24),
        (LevelKind::Reg, 256, 0.48),
        (LevelKind::Reg, 512, 0.96),
        (LevelKind::Sram, 32 << 10, 6.0),
        (LevelKind::Sram, 64 << 10, 9.0),
        (LevelKind::Sram, 128 << 10, 13.5),
        (LevelKind::Sram, 256 << 10, 20.25),
        (LevelKind::Sram, 512 << 10, 30.375),
    ]
}

/// A scaled cost model for studying other technology nodes: multiplies
/// every memory cost by `mem_scale` and the MAC cost by `mac_scale`
/// relative to Table 3. Used by the "different energy cost models"
/// robustness sweep (§6.1 claims the conclusions are cost-model
/// independent).
#[derive(Debug, Clone)]
pub struct ScaledCost {
    /// Multiplier on all memory access costs.
    pub mem_scale: f64,
    /// Multiplier on MAC cost.
    pub mac_scale: f64,
    /// Multiplier on DRAM cost.
    pub dram_scale: f64,
}

impl CostModel for ScaledCost {
    fn reg_access(&self, size_bytes: u64) -> f64 {
        Table3.reg_access(size_bytes) * self.mem_scale
    }
    fn sram_access(&self, size_bytes: u64) -> f64 {
        Table3.sram_access(size_bytes) * self.mem_scale
    }
    fn dram_access(&self) -> f64 {
        Table3.dram_access() * self.dram_scale
    }
    fn mac(&self) -> f64 {
        Table3.mac() * self.mac_scale
    }
    fn hop(&self) -> f64 {
        Table3.hop() * self.mem_scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_anchor_points_exact() {
        let m = Table3;
        for (kind, size, pj) in table3_anchors() {
            let got = match kind {
                LevelKind::Reg => m.reg_access(size),
                LevelKind::Sram => m.sram_access(size),
                LevelKind::Dram => unreachable!(),
            };
            assert!(
                (got - pj).abs() < 1e-9,
                "{kind:?} {size}: got {got}, want {pj}"
            );
        }
    }

    #[test]
    fn scalar_costs_match_table3() {
        let m = Table3;
        assert_eq!(m.mac(), 0.075);
        assert_eq!(m.hop(), 0.035);
        assert_eq!(m.dram_access(), 200.0);
    }

    #[test]
    fn rf_linear_interpolation() {
        let m = Table3;
        // 96 B sits between 64 (0.12) and 128 (0.24): linear -> 0.18
        assert!((m.reg_access(96) - 0.18).abs() < 1e-9);
        // 8 B (TPU-like) = half of 16 B
        assert!((m.reg_access(8) - 0.015).abs() < 1e-9);
        // below 8 B clamps
        assert_eq!(m.reg_access(2), m.reg_access(8));
    }

    #[test]
    fn sram_doubling_rule() {
        let m = Table3;
        // each doubling is x1.5 within the table's range
        assert!((m.sram_access(256 << 10) / m.sram_access(128 << 10) - 1.5).abs() < 1e-9);
        // beyond 512 KB growth flattens to x1.2 per doubling
        assert!((m.sram_access(1 << 20) / m.sram_access(512 << 10) - 1.2).abs() < 1e-9);
        // 28 MB L2 (TPU-like) stays below DRAM cost
        let e28 = m.sram_access(28 << 20);
        assert!(e28 > 30.375 && e28 < 200.0, "{e28}");
    }

    #[test]
    fn monotone_in_size() {
        let m = Table3;
        let mut prev = 0.0;
        for s in [8u64, 16, 64, 512, 4096] {
            let e = m.reg_access(s);
            assert!(e >= prev);
            prev = e;
        }
        let mut prev = 0.0;
        for s in [16u64 << 10, 64 << 10, 256 << 10, 4 << 20] {
            let e = m.sram_access(s);
            assert!(e > prev);
            prev = e;
        }
    }

    #[test]
    fn dram_dominates_everything_onchip() {
        let m = Table3;
        assert!(m.dram_access() > m.sram_access(28 << 20));
        assert!(m.sram_access(32 << 10) > m.reg_access(512));
    }

    #[test]
    fn level_access_dispatch() {
        let a = crate::arch::eyeriss_like();
        let m = Table3;
        assert!((m.level_access(&a, 0) - 0.96).abs() < 1e-9); // 512 B RF
        assert!((m.level_access(&a, 1) - 13.5).abs() < 1e-9); // 128 KB
        assert_eq!(m.level_access(&a, 2), 200.0);
    }

    #[test]
    fn scaled_model_scales() {
        let s = ScaledCost {
            mem_scale: 2.0,
            mac_scale: 0.5,
            dram_scale: 1.0,
        };
        assert!((s.reg_access(512) - 1.92).abs() < 1e-9);
        assert!((s.mac() - 0.0375).abs() < 1e-9);
        assert_eq!(s.dram_access(), 200.0);
    }
}
