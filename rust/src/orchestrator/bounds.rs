//! The append-only bounds file: how live workers share pruning bounds
//! without shared memory.
//!
//! One small file, line-delimited JSON, one record per line. Every
//! worker appends (`O_APPEND`, one `write_all` per record — atomic
//! enough on every platform we target for the small records involved)
//! and periodically re-reads the whole file, which stays tiny: scalar
//! records are one line, frontier records publish only points not yet
//! in the file. The reader is deliberately forgiving — a torn or
//! half-written trailing line, or any line that fails to parse, is
//! skipped, never an error — so a reader racing a writer (or a worker
//! SIGKILLed mid-append) can never poison the sweep. Bounds are
//! *hints*: losing one costs pruning, never correctness.
//!
//! ## Record formats (v1)
//!
//! ```json
//! {"v": 1, "worker": 3, "kind": "incumbent", "energy_pj": 1234.5}
//! {"v": 1, "worker": 3, "kind": "frontier",
//!  "points": [{"index": 17, "energy_pj": 1.5, "cycles": 2.0}, ...]}
//! ```
//!
//! Floats use the shortest-round-trip formatting of
//! [`crate::util::json`], so a bound read back has exactly the bits the
//! publisher observed — the admissibility argument (see the parent
//! module) needs published bounds to be real completed energies, not
//! approximations of them.

use std::fs::OpenOptions;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::pareto::FrontierPoint;
use crate::util::json::Json;

/// Protocol version stamped on every record; readers skip other
/// versions (forward compatibility across a mixed-version fleet).
const BOUNDS_VERSION: u64 = 1;

/// Aggregated view of every well-formed record published so far.
#[derive(Debug, Clone)]
pub struct BoundsSnapshot {
    /// Minimum published incumbent energy (+inf when none yet).
    pub incumbent_pj: f64,
    /// Every published frontier point (duplicates included — callers
    /// fold them through [`crate::pareto::LiveFrontier::absorb`] or
    /// [`keyed`](Self::keyed), both of which deduplicate).
    pub frontier: Vec<FrontierPoint>,
    /// Well-formed records seen (telemetry).
    pub records: usize,
}

impl BoundsSnapshot {
    /// The empty snapshot (no bounds published yet).
    pub fn empty() -> BoundsSnapshot {
        BoundsSnapshot {
            incumbent_pj: f64::INFINITY,
            frontier: Vec::new(),
            records: 0,
        }
    }

    /// The published frontier points as a deduplicating key set —
    /// `(index, energy bits, cycles bits)` — for publish-only-fresh
    /// filtering.
    pub fn keyed(&self) -> std::collections::HashSet<(usize, u64, u64)> {
        self.frontier.iter().map(point_key).collect()
    }
}

/// The deduplication key of a published frontier point: candidate index
/// plus exact vector bits.
pub fn point_key(p: &FrontierPoint) -> (usize, u64, u64) {
    (p.index, p.energy_pj.to_bits(), p.cycles.to_bits())
}

/// One worker's handle on a shared bounds file: where it is, who is
/// writing, and how often the streaming loop wakes.
#[derive(Debug, Clone)]
pub struct BoundsLink {
    path: PathBuf,
    worker: usize,
    interval: Duration,
}

impl BoundsLink {
    /// A handle for `worker` on the bounds file at `path`, with the
    /// given publish/refresh interval.
    pub fn new(path: impl Into<PathBuf>, worker: usize, interval: Duration) -> BoundsLink {
        BoundsLink {
            path: path.into(),
            worker,
            interval,
        }
    }

    /// The bounds-file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// The streaming loop's wake interval.
    pub fn interval(&self) -> Duration {
        self.interval
    }

    /// Append a scalar incumbent record (the energy of a *completed*
    /// feasible point — the admissibility contract).
    pub fn publish_incumbent(&self, energy_pj: f64) -> Result<()> {
        self.append(Json::Obj(vec![
            ("v".into(), Json::int(BOUNDS_VERSION)),
            ("worker".into(), Json::int(self.worker as u64)),
            ("kind".into(), Json::str("incumbent")),
            ("energy_pj".into(), Json::num(energy_pj)),
        ]))
    }

    /// Append a frontier record (each point a *completed* feasible
    /// point's exact totals).
    pub fn publish_frontier(&self, points: &[FrontierPoint]) -> Result<()> {
        let pts = points
            .iter()
            .map(|p| {
                Json::Obj(vec![
                    ("index".into(), Json::int(p.index as u64)),
                    ("energy_pj".into(), Json::num(p.energy_pj)),
                    ("cycles".into(), Json::num(p.cycles)),
                ])
            })
            .collect();
        self.append(Json::Obj(vec![
            ("v".into(), Json::int(BOUNDS_VERSION)),
            ("worker".into(), Json::int(self.worker as u64)),
            ("kind".into(), Json::str("frontier")),
            ("points".into(), Json::Arr(pts)),
        ]))
    }

    /// Read and aggregate every well-formed record (see
    /// [`read_bounds`]).
    pub fn read(&self) -> BoundsSnapshot {
        read_bounds(&self.path)
    }

    fn append(&self, record: Json) -> Result<()> {
        append_framed(&self.path, &record)
    }
}

/// Append one record to a line-delimited JSON file with the
/// torn-write-safe `\n{record}\n` framing — the one framing every
/// append-only protocol in the repo shares (this bounds log, the bench
/// history, the fleet's `mix.jsonl` / `plans.jsonl`). Leading newline:
/// if the previous writer was killed mid-append and left a torn tail,
/// this record still starts on a fresh line — only the torn record is
/// lost, never the one after it. Readers skip the blank lines this
/// produces in the common case. One `O_APPEND` `write_all` per record,
/// so concurrent appenders never interleave within a line (for the
/// small records involved, on every platform we target).
pub fn append_framed(path: &Path, record: &Json) -> Result<()> {
    let line = format!("\n{record}\n");
    let mut f = OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .with_context(|| format!("open append-only log {}", path.display()))?;
    f.write_all(line.as_bytes())
        .with_context(|| format!("append record to {}", path.display()))?;
    Ok(())
}

/// Read a bounds file into an aggregated snapshot. A missing file is an
/// empty snapshot; unparseable or truncated lines (a writer mid-append,
/// a worker killed mid-write) are skipped.
pub fn read_bounds(path: &Path) -> BoundsSnapshot {
    let mut snap = BoundsSnapshot::empty();
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(_) => return snap,
    };
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        if parse_record(line, &mut snap).is_some() {
            snap.records += 1;
        }
    }
    snap
}

/// Fold one record line into the snapshot; `None` (skip) on any
/// malformed or foreign-version line.
fn parse_record(line: &str, snap: &mut BoundsSnapshot) -> Option<()> {
    let v = Json::parse(line).ok()?;
    if v.field("v").ok()?.as_u64().ok()? != BOUNDS_VERSION {
        return None;
    }
    match v.field("kind").ok()?.as_str().ok()? {
        "incumbent" => {
            let e = v.field("energy_pj").ok()?.as_f64().ok()?;
            if e.is_finite() {
                snap.incumbent_pj = snap.incumbent_pj.min(e);
            }
            Some(())
        }
        "frontier" => {
            // Parse the whole record before folding any of it in, so a
            // torn line never contributes half a snapshot.
            let mut pts = Vec::new();
            for p in v.field("points").ok()?.as_arr().ok()? {
                let fp = FrontierPoint {
                    index: p.field("index").ok()?.as_usize().ok()?,
                    energy_pj: p.field("energy_pj").ok()?.as_f64().ok()?,
                    cycles: p.field("cycles").ok()?.as_f64().ok()?,
                };
                if !fp.energy_pj.is_finite() || !fp.cycles.is_finite() {
                    return None;
                }
                pts.push(fp);
            }
            snap.frontier.extend(pts);
            Some(())
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("interstellar-bounds-{}-{}", std::process::id(), name))
    }

    #[test]
    fn round_trips_scalar_and_frontier_records() {
        let path = tmp("roundtrip");
        let _ = std::fs::remove_file(&path);
        let link = BoundsLink::new(&path, 7, Duration::from_millis(10));
        link.publish_incumbent(0.1 + 0.2).unwrap();
        link.publish_incumbent(5.0).unwrap();
        let pts = [
            FrontierPoint {
                index: 3,
                energy_pj: 10.0,
                cycles: 2.5,
            },
            FrontierPoint {
                index: 9,
                energy_pj: f64::from_bits(0x3FF5_5555_5555_5555),
                cycles: 1.0,
            },
        ];
        link.publish_frontier(&pts).unwrap();

        let snap = link.read();
        assert_eq!(snap.records, 3);
        // min over published incumbents, exact bits preserved
        assert_eq!(snap.incumbent_pj.to_bits(), (0.1f64 + 0.2).to_bits());
        assert_eq!(snap.frontier.len(), 2);
        assert_eq!(point_key(&snap.frontier[1]), point_key(&pts[1]));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn reader_skips_torn_and_garbage_lines() {
        let path = tmp("torn");
        let _ = std::fs::remove_file(&path);
        let link = BoundsLink::new(&path, 0, Duration::from_millis(10));
        link.publish_incumbent(42.0).unwrap();
        // a torn append (no newline, cut mid-number) and plain garbage
        std::fs::OpenOptions::new()
            .append(true)
            .open(&path)
            .unwrap()
            .write_all(b"not json at all\n{\"v\":1,\"worker\":0,\"kind\":\"incumbent\",\"energy_pj\":12.")
            .unwrap();
        let snap = link.read();
        assert_eq!(snap.records, 1);
        assert_eq!(snap.incumbent_pj, 42.0);
        // the newline-prefixed append isolates the torn tail: the next
        // record lands on its own line and is read back fine
        link.publish_incumbent(7.0).unwrap();
        let snap = link.read();
        assert_eq!(snap.records, 2);
        assert_eq!(snap.incumbent_pj, 7.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_file_is_empty_snapshot() {
        let snap = read_bounds(Path::new("/nonexistent/interstellar-bounds.jsonl"));
        assert_eq!(snap.records, 0);
        assert!(snap.incumbent_pj.is_infinite());
        assert!(snap.frontier.is_empty());
    }
}
