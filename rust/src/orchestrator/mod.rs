//! Distributed sweep orchestrator: work-stealing shard fan-out with live
//! bound streaming.
//!
//! [`orchestrate`] fans a co-optimization or frontier sweep out across
//! OS processes: each worker is an ordinary `co-opt --shard I/N` /
//! `pareto --shard I/N` invocation of the `interstellar` binary (or any
//! launcher-prefixed command — `ssh host interstellar ...` works the
//! same, the protocol never assumes shared memory), writing its
//! [`ShardCheckpoint`] / [`FrontierCheckpoint`] to a file the
//! orchestrator parses when the process exits. Two mechanisms ride on
//! top of that plain fan-out:
//!
//! - **Live bound streaming** (`bounds` module): workers append their
//!   incumbent / fresh frontier points to a shared append-only bounds
//!   file and periodically fold the freshest global bound back into
//!   their own pruning gates, so late shards start tight instead of
//!   cold. Bounds are admissible hints (completed feasible points of
//!   the same sweep — the `NetOptConfig::prime` argument), so the
//!   merged winner and frontier keep their single-process bits; only
//!   the amount of work changes.
//!
//! - **Work stealing over sub-sharded grids**: `shard(i, n)` composes —
//!   sub-shard `j` of `m` of shard `(i, n)` is exactly shard
//!   `(i + j·n, n·m)`, and the union over `j` recovers the parent (see
//!   `netopt::shard`). When a worker dies (or, with speculation
//!   enabled, straggles), its class is re-split into `steal_split`
//!   sub-classes and redistributed to idle workers. A straggler that
//!   finishes *after* its replacements produces duplicate coverage; the
//!   checkpoint merges deduplicate it under a bit-identity check, so an
//!   interrupted-and-stolen sweep still merges to the exact
//!   single-process result.
//!
//! ## Crash-tolerance model
//!
//! Workers are stateless and idempotent: a shard class is either fully
//! covered by a parsed checkpoint or not covered at all. A SIGKILLed
//! worker leaves at most a torn bounds-file line (isolated by the
//! append protocol, see `bounds`) and a missing/unparseable checkpoint
//! — both handled by re-splitting the class and re-running it. The
//! orchestrator itself keeps no on-disk state beyond the checkpoint and
//! bounds files; completed coverage is re-derived from the checkpoint
//! files it has parsed.

pub mod bounds;
pub mod worker;

pub use bounds::{append_framed, point_key, read_bounds, BoundsLink, BoundsSnapshot};
pub use worker::{run_coopt_shard_streamed, run_pareto_shard_streamed};

use std::collections::{HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::netopt::shard::{gcd, MAX_MERGE_GRANULARITY};
use crate::netopt::{merge_all, ShardCheckpoint};
use crate::pareto::{merge_all_frontiers, FrontierCheckpoint};
use crate::telemetry;
use crate::util::json::Json;

/// Which sweep the workers run — selects the subcommand, the checkpoint
/// format parsed back, and the merge used at the end.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SweepMode {
    /// `co-opt --shard`: scalar energy minimization, merged through
    /// [`merge_all`].
    CoOpt,
    /// `pareto --shard`: energy/latency frontier, merged through
    /// [`merge_all_frontiers`].
    Pareto,
}

impl SweepMode {
    fn subcommand(self) -> &'static str {
        match self {
            SweepMode::CoOpt => "co-opt",
            SweepMode::Pareto => "pareto",
        }
    }
}

/// Everything [`orchestrate`] needs to run a sweep. Build with
/// [`new`](Self::new), then adjust the public knobs.
#[derive(Debug, Clone)]
pub struct OrchestrateConfig {
    /// Sweep family (co-opt or pareto).
    pub mode: SweepMode,
    /// Path to the `interstellar` binary workers execute.
    pub bin: PathBuf,
    /// Scratch directory for checkpoint files and the bounds file
    /// (created if missing).
    pub dir: PathBuf,
    /// Maximum concurrently running workers.
    pub workers: usize,
    /// Initial shard partition width (defaults to `workers`; more shards
    /// than workers gives the scheduler waves to balance across).
    pub nshards: usize,
    /// Arguments forwarded verbatim to every worker between the
    /// subcommand and the `--shard` spec (network, space, search knobs —
    /// identical configuration across workers is the merge contract).
    pub worker_args: Vec<String>,
    /// Optional launcher prefixes, round-robined over workers: each is
    /// prepended to the worker argv (e.g. `["ssh", "host1"]`). Empty
    /// means plain local processes.
    pub launchers: Vec<Vec<String>>,
    /// Re-split failed/straggling classes into sub-shards instead of
    /// retrying them whole.
    pub steal: bool,
    /// How many sub-classes a stolen class splits into (≥ 2).
    pub steal_split: usize,
    /// Cap on re-split events (runaway guard; beyond it, failures fall
    /// back to whole-class retries).
    pub max_steals: usize,
    /// Whole-class retries allowed per class when stealing is off or
    /// exhausted.
    pub max_retries: usize,
    /// Speculative re-split: when idle capacity exists and a running
    /// task has taken more than this factor times the median completed
    /// wall time, its class is re-split for idle workers to race.
    /// `0.0` disables speculation.
    pub straggler_factor: f64,
    /// Bounds-file streaming interval; `None` disables streaming (no
    /// `--bounds` flags are passed).
    pub bounds_interval: Option<Duration>,
    /// Scheduler poll period.
    pub poll: Duration,
    /// Test hook: SIGKILL the worker with this launch sequence number
    /// after it has run for the given duration (crash-tolerance gate).
    pub fault_kill: Option<(usize, Duration)>,
}

impl OrchestrateConfig {
    /// A config with the default scheduling knobs: `nshards = workers`,
    /// stealing on (split 2, 64 steals, 2 retries), speculation off,
    /// 50 ms bound streaming, 5 ms poll.
    pub fn new(
        mode: SweepMode,
        bin: impl Into<PathBuf>,
        dir: impl Into<PathBuf>,
        workers: usize,
    ) -> OrchestrateConfig {
        OrchestrateConfig {
            mode,
            bin: bin.into(),
            dir: dir.into(),
            workers,
            nshards: workers.max(1),
            worker_args: Vec::new(),
            launchers: Vec::new(),
            steal: true,
            steal_split: 2,
            max_steals: 64,
            max_retries: 2,
            straggler_factor: 0.0,
            bounds_interval: Some(Duration::from_millis(50)),
            poll: Duration::from_millis(5),
            fault_kill: None,
        }
    }

    fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            bail!("orchestrate needs at least one worker");
        }
        if self.nshards == 0 {
            bail!("orchestrate needs at least one shard");
        }
        if self.steal_split < 2 {
            bail!("--steal-split must be at least 2");
        }
        Ok(())
    }
}

/// How one launched worker ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskOutcome {
    /// Exited cleanly with a parseable checkpoint.
    Done,
    /// Exited nonzero, was killed, or left an unparseable checkpoint;
    /// its class was re-split or retried.
    Failed,
    /// Killed by the orchestrator after its coverage was already
    /// complete elsewhere (a raced straggler or post-coverage cancel).
    Cancelled,
}

/// Telemetry for one launched worker process.
#[derive(Debug, Clone)]
pub struct TaskRecord {
    /// Launch sequence number (also the bounds-file worker id).
    pub seq: usize,
    /// The shard class `(index, nshards)` this worker ran.
    pub class: (usize, usize),
    /// 1-based attempt number for this class at launch: whole-class
    /// retries bump it, a re-split's sub-classes start back at 1.
    pub attempt: usize,
    /// How it ended.
    pub outcome: TaskOutcome,
    /// Wall time from spawn to reap.
    pub wall: Duration,
}

/// The merged sweep result — one variant per [`SweepMode`].
#[derive(Debug, Clone)]
pub enum MergedSweep {
    /// Merged co-optimization checkpoint (global winner, stats, seeds).
    CoOpt(ShardCheckpoint),
    /// Merged frontier checkpoint (global frontier, stats, seeds).
    Pareto(FrontierCheckpoint),
}

/// Everything [`orchestrate`] hands back: the merged result plus
/// scheduling telemetry.
#[derive(Debug, Clone)]
pub struct OrchestrateReport {
    /// The merged checkpoint (bit-identical winner/frontier to the
    /// single-process sweep).
    pub merged: MergedSweep,
    /// One record per launched worker process, in launch order.
    pub tasks: Vec<TaskRecord>,
    /// Worker processes launched.
    pub launched: usize,
    /// Workers that failed (crashed, nonzero exit, bad checkpoint).
    pub failures: usize,
    /// Re-split events (failure-driven and speculative).
    pub steals: usize,
    /// Workers cancelled after their coverage completed elsewhere.
    pub cancelled: usize,
    /// Sum of `stats.evaluated_full` over the checkpoints that made it
    /// into the merge (the streaming-efficiency metric; duplicates from
    /// raced stragglers are deduplicated by the merge but still counted
    /// here as work actually done).
    pub aggregate_evaluated_full: usize,
    /// End-to-end orchestration wall time.
    pub wall: Duration,
}

struct RunningTask {
    seq: usize,
    class: (usize, usize),
    attempt: usize,
    child: Child,
    checkpoint: PathBuf,
    started: Instant,
    split: bool,
    /// Task lifecycle span (dispatch → reap); ends with the outcome, or
    /// plainly on drop, so a killed sweep never strands an open span.
    span: telemetry::ManualSpan,
}

enum Parsed {
    CoOpt(Box<ShardCheckpoint>),
    Pareto(Box<FrontierCheckpoint>),
}

struct State {
    pending: VecDeque<(usize, usize)>,
    running: Vec<RunningTask>,
    done: Vec<Parsed>,
    done_classes: Vec<(usize, usize)>,
    done_walls: Vec<Duration>,
    tasks: Vec<TaskRecord>,
    attempts: HashMap<(usize, usize), usize>,
    next_seq: usize,
    failures: usize,
    steals: usize,
    cancelled: usize,
    fault_fired: bool,
}

/// Run the configured sweep to completion and merge the checkpoints.
///
/// Errors when a class exhausts its retries without stealing headroom,
/// when a worker cannot be spawned repeatedly, or when the merged
/// coverage is incomplete (which the scheduler prevents unless every
/// recovery path is exhausted). Running children are killed on every
/// error path.
pub fn orchestrate(cfg: &OrchestrateConfig) -> Result<OrchestrateReport> {
    cfg.validate()?;
    std::fs::create_dir_all(&cfg.dir)
        .with_context(|| format!("create orchestrator dir {}", cfg.dir.display()))?;
    let bounds_path = cfg.bounds_interval.map(|_| cfg.dir.join("bounds.jsonl"));
    let t0 = Instant::now();
    let ospan = telemetry::begin("orchestrator", "orchestrate", || {
        vec![
            ("mode".into(), Json::str(cfg.mode.subcommand())),
            ("workers".into(), Json::int(cfg.workers as u64)),
            ("nshards".into(), Json::int(cfg.nshards as u64)),
        ]
    });

    let mut st = State {
        pending: (0..cfg.nshards).map(|i| (i, cfg.nshards)).collect(),
        running: Vec::new(),
        done: Vec::new(),
        done_classes: Vec::new(),
        done_walls: Vec::new(),
        tasks: Vec::new(),
        attempts: HashMap::new(),
        next_seq: 0,
        failures: 0,
        steals: 0,
        cancelled: 0,
        fault_fired: false,
    };

    let looped = run_loop(cfg, bounds_path.as_deref(), &mut st, ospan.id());
    // Safety net: no error path may leak worker processes.
    for t in &mut st.running {
        let _ = t.child.kill();
        let _ = t.child.wait();
    }
    looped?;

    let mut aggregate_evaluated_full = 0usize;
    let mspan = telemetry::begin_under("orchestrator", "merge", ospan.id(), || {
        vec![("checkpoints".into(), Json::int(st.done.len() as u64))]
    });
    let merged = match cfg.mode {
        SweepMode::CoOpt => {
            let mut ckpts = Vec::with_capacity(st.done.len());
            for p in &st.done {
                match p {
                    Parsed::CoOpt(c) => {
                        aggregate_evaluated_full += c.stats.evaluated_full;
                        ckpts.push((**c).clone());
                    }
                    Parsed::Pareto(_) => bail!("pareto checkpoint in a co-opt sweep"),
                }
            }
            MergedSweep::CoOpt(merge_all(&ckpts)?)
        }
        SweepMode::Pareto => {
            let mut ckpts = Vec::with_capacity(st.done.len());
            for p in &st.done {
                match p {
                    Parsed::Pareto(c) => {
                        aggregate_evaluated_full += c.stats.evaluated_full;
                        ckpts.push((**c).clone());
                    }
                    Parsed::CoOpt(_) => bail!("co-opt checkpoint in a pareto sweep"),
                }
            }
            MergedSweep::Pareto(merge_all_frontiers(&ckpts)?)
        }
    };
    let (nshards, covered) = match &merged {
        MergedSweep::CoOpt(c) => (c.nshards, c.shards.len()),
        MergedSweep::Pareto(c) => (c.nshards, c.shards.len()),
    };
    drop(mspan);
    if covered != nshards {
        bail!("merged coverage incomplete: {covered}/{nshards} shards");
    }

    ospan.end_with(|| {
        vec![
            ("launched".into(), Json::int(st.next_seq as u64)),
            ("failures".into(), Json::int(st.failures as u64)),
            ("steals".into(), Json::int(st.steals as u64)),
            ("cancelled".into(), Json::int(st.cancelled as u64)),
        ]
    });
    Ok(OrchestrateReport {
        merged,
        tasks: st.tasks,
        launched: st.next_seq,
        failures: st.failures,
        steals: st.steals,
        cancelled: st.cancelled,
        aggregate_evaluated_full,
        wall: t0.elapsed(),
    })
}

fn run_loop(
    cfg: &OrchestrateConfig,
    bounds: Option<&Path>,
    st: &mut State,
    root: u64,
) -> Result<()> {
    while !(st.pending.is_empty() && st.running.is_empty()) {
        // Launch up to the worker cap.
        while st.running.len() < cfg.workers {
            let Some(class) = st.pending.pop_front() else {
                break;
            };
            launch(cfg, bounds, st, class, root)?;
        }

        inject_fault(cfg, st);
        reap(cfg, st)?;
        speculate(cfg, st);

        // Early exit: once the parsed checkpoints already cover the full
        // grid (a stolen class's original finished, say), anything still
        // running is redundant — kill it rather than wait it out.
        if coverage_full(&st.done_classes) {
            for mut t in st.running.drain(..) {
                let _ = t.child.kill();
                let _ = t.child.wait();
                st.cancelled += 1;
                t.span
                    .end_with(|| vec![("outcome".into(), Json::str("cancelled"))]);
                st.tasks.push(TaskRecord {
                    seq: t.seq,
                    class: t.class,
                    attempt: t.attempt,
                    outcome: TaskOutcome::Cancelled,
                    wall: t.started.elapsed(),
                });
            }
            st.pending.clear();
            break;
        }

        if !st.running.is_empty() {
            std::thread::sleep(cfg.poll);
        }
    }
    if !coverage_full(&st.done_classes) {
        bail!("sweep drained without covering the full grid");
    }
    Ok(())
}

/// Build a worker `Command` the orchestrator way: the round-robined
/// launcher prefix for attempt `seq` (empty `launchers` = plain local
/// process), then the binary, the subcommand, and `args` — stdout/stderr
/// nulled (workers talk through files, never pipes). Shared with the
/// serving fleet ([`crate::fleet`]), which fans out `fleet-worker`
/// processes under the same ssh-style launcher contract.
pub fn launcher_command(
    launchers: &[Vec<String>],
    seq: usize,
    bin: &Path,
    subcommand: &str,
    args: &[String],
) -> Command {
    let mut argv: Vec<String> = Vec::new();
    if !launchers.is_empty() {
        argv.extend(launchers[seq % launchers.len()].iter().cloned());
    }
    argv.push(bin.display().to_string());
    argv.push(subcommand.to_string());
    argv.extend(args.iter().cloned());
    let mut cmd = Command::new(&argv[0]);
    cmd.args(&argv[1..]).stdout(Stdio::null()).stderr(Stdio::null());
    cmd
}

fn launch(
    cfg: &OrchestrateConfig,
    bounds: Option<&Path>,
    st: &mut State,
    class: (usize, usize),
    root: u64,
) -> Result<()> {
    let seq = st.next_seq;
    st.next_seq += 1;
    // 1-based attempt: `attempts` counts prior whole-class retries, so a
    // relaunch is distinguishable from a first launch in the checkpoint
    // filename, the task span, and `orchestrate --json`.
    let attempt = st.attempts.get(&class).copied().unwrap_or(0) + 1;
    let checkpoint = cfg.dir.join(format!(
        "task-{seq}-shard-{}of{}-try{attempt}.json",
        class.0, class.1
    ));
    // A retry must not parse a stale file from a previous attempt.
    let _ = std::fs::remove_file(&checkpoint);

    let mut args: Vec<String> = cfg.worker_args.clone();
    args.push("--shard".into());
    args.push(format!("{}/{}", class.0, class.1));
    args.push("--checkpoint".into());
    args.push(checkpoint.display().to_string());
    if let (Some(path), Some(interval)) = (bounds, cfg.bounds_interval) {
        args.push("--bounds".into());
        args.push(path.display().to_string());
        args.push("--bounds-interval".into());
        args.push(interval.as_millis().to_string());
        args.push("--worker-id".into());
        args.push(seq.to_string());
    }

    let span = telemetry::begin_under("orchestrator", "task", root, || {
        vec![
            ("seq".into(), Json::int(seq as u64)),
            ("shard".into(), Json::str(format!("{}/{}", class.0, class.1))),
            ("attempt".into(), Json::int(attempt as u64)),
            ("mode".into(), Json::str(cfg.mode.subcommand())),
        ]
    });
    let mut cmd = launcher_command(&cfg.launchers, seq, &cfg.bin, cfg.mode.subcommand(), &args);
    match cmd.spawn() {
        Ok(child) => {
            st.running.push(RunningTask {
                seq,
                class,
                attempt,
                child,
                checkpoint,
                started: Instant::now(),
                split: false,
                span,
            });
            Ok(())
        }
        Err(e) => {
            // Spawn failure (bad launcher, missing binary on a host):
            // treated like a worker failure so the class is retried or
            // re-split elsewhere instead of aborting the sweep.
            st.failures += 1;
            span.end_with(|| vec![("outcome".into(), Json::str("spawn_failed"))]);
            st.tasks.push(TaskRecord {
                seq,
                class,
                attempt,
                outcome: TaskOutcome::Failed,
                wall: Duration::ZERO,
            });
            requeue(cfg, st, class).with_context(|| format!("spawn worker: {e}"))
        }
    }
}

fn inject_fault(cfg: &OrchestrateConfig, st: &mut State) {
    let Some((victim, after)) = cfg.fault_kill else {
        return;
    };
    if st.fault_fired {
        return;
    }
    if let Some(t) = st.running.iter_mut().find(|t| t.seq == victim) {
        if t.started.elapsed() >= after {
            let _ = t.child.kill();
            st.fault_fired = true;
        }
    } else if st.next_seq > victim {
        // The victim already exited on its own; nothing left to kill.
        st.fault_fired = true;
    }
}

fn reap(cfg: &OrchestrateConfig, st: &mut State) -> Result<()> {
    let mut i = 0;
    while i < st.running.len() {
        match st.running[i].child.try_wait() {
            Ok(None) => i += 1,
            Ok(Some(status)) => {
                let mut t = st.running.swap_remove(i);
                let _ = t.child.wait();
                let wall = t.started.elapsed();
                let parsed = if status.success() {
                    parse_checkpoint(cfg.mode, &t.checkpoint).ok()
                } else {
                    None
                };
                match parsed {
                    Some(p) => {
                        st.done.push(p);
                        st.done_classes.push(t.class);
                        st.done_walls.push(wall);
                        t.span
                            .end_with(|| vec![("outcome".into(), Json::str("done"))]);
                        st.tasks.push(TaskRecord {
                            seq: t.seq,
                            class: t.class,
                            attempt: t.attempt,
                            outcome: TaskOutcome::Done,
                            wall,
                        });
                    }
                    None => {
                        st.failures += 1;
                        t.span
                            .end_with(|| vec![("outcome".into(), Json::str("failed"))]);
                        st.tasks.push(TaskRecord {
                            seq: t.seq,
                            class: t.class,
                            attempt: t.attempt,
                            outcome: TaskOutcome::Failed,
                            wall,
                        });
                        // A replacement may already have covered it.
                        if !class_covered(t.class, &st.done_classes) {
                            requeue(cfg, st, t.class)?;
                        }
                    }
                }
            }
            Err(e) => return Err(e).context("wait on worker process"),
        }
    }
    Ok(())
}

/// Speculative stealing: with idle capacity and nothing pending, re-split
/// the longest-running unsplit task once it exceeds `straggler_factor`
/// times the median completed wall time, letting idle workers race the
/// straggler. Whichever finishes first wins; the loser is cancelled (or
/// deduplicated by the merge if both complete).
fn speculate(cfg: &OrchestrateConfig, st: &mut State) {
    if !cfg.steal
        || cfg.straggler_factor <= 0.0
        || st.steals >= cfg.max_steals
        || !st.pending.is_empty()
        || st.running.len() >= cfg.workers
        || st.done_walls.is_empty()
    {
        return;
    }
    let mut walls = st.done_walls.clone();
    walls.sort();
    let median = walls[walls.len() / 2].as_secs_f64().max(0.001);
    let Some(t) = st
        .running
        .iter_mut()
        .filter(|t| !t.split && splittable(t.class, cfg.steal_split))
        .max_by_key(|t| t.started.elapsed())
    else {
        return;
    };
    if t.started.elapsed().as_secs_f64() > cfg.straggler_factor * median {
        t.split = true;
        let class = t.class;
        let elapsed = t.started.elapsed();
        telemetry::event("orchestrator", "speculate", || {
            vec![
                ("shard".into(), Json::str(format!("{}/{}", class.0, class.1))),
                ("split".into(), Json::int(cfg.steal_split as u64)),
                ("elapsed_ms".into(), Json::num(elapsed.as_secs_f64() * 1e3)),
            ]
        });
        split_into(&mut st.pending, class, cfg.steal_split);
        st.steals += 1;
    }
}

fn requeue(cfg: &OrchestrateConfig, st: &mut State, class: (usize, usize)) -> Result<()> {
    if cfg.steal && st.steals < cfg.max_steals && splittable(class, cfg.steal_split) {
        st.steals += 1;
        telemetry::event("orchestrator", "steal", || {
            vec![
                ("shard".into(), Json::str(format!("{}/{}", class.0, class.1))),
                ("split".into(), Json::int(cfg.steal_split as u64)),
            ]
        });
        split_into(&mut st.pending, class, cfg.steal_split);
        return Ok(());
    }
    let tries = st.attempts.entry(class).or_insert(0);
    *tries += 1;
    let next_attempt = *tries + 1;
    if *tries > cfg.max_retries {
        bail!(
            "shard {}/{} failed {} retries and cannot be re-split further",
            class.0,
            class.1,
            cfg.max_retries
        );
    }
    telemetry::event("orchestrator", "retry", || {
        vec![
            ("shard".into(), Json::str(format!("{}/{}", class.0, class.1))),
            ("attempt".into(), Json::int(next_attempt as u64)),
        ]
    });
    st.pending.push_back(class);
    Ok(())
}

/// Sub-shard composition: class `(i, n)` splits into
/// `(i + j·n, n·split)` for `j in 0..split`, whose union is exactly the
/// parent's grid indices (see `netopt::shard`'s composition docs).
fn split_into(pending: &mut VecDeque<(usize, usize)>, class: (usize, usize), split: usize) {
    for j in 0..split {
        pending.push_back((class.0 + j * class.1, class.1 * split));
    }
}

fn splittable(class: (usize, usize), split: usize) -> bool {
    class
        .1
        .checked_mul(split)
        .is_some_and(|n| n <= MAX_MERGE_GRANULARITY)
}

/// True when `class`'s residues are a subset of the already-completed
/// coverage (so a failed straggler whose replacements finished needs no
/// requeue).
fn class_covered(class: (usize, usize), done: &[(usize, usize)]) -> bool {
    let mut with = done.to_vec();
    with.push(class);
    let Some(l) = lcm_all(&with) else {
        return false;
    };
    let mut mask = vec![false; l];
    for &(i, n) in done {
        let mut g = i;
        while g < l {
            mask[g] = true;
            g += n;
        }
    }
    let mut g = class.0;
    while g < l {
        if !mask[g] {
            return false;
        }
        g += class.1;
    }
    true
}

/// True when the completed classes cover every residue of their common
/// refinement — i.e. every raw grid index has a finished checkpoint.
fn coverage_full(done: &[(usize, usize)]) -> bool {
    if done.is_empty() {
        return false;
    }
    let Some(l) = lcm_all(done) else {
        return false;
    };
    let mut mask = vec![false; l];
    for &(i, n) in done {
        let mut g = i;
        while g < l {
            mask[g] = true;
            g += n;
        }
    }
    mask.iter().all(|&b| b)
}

fn lcm_all(classes: &[(usize, usize)]) -> Option<usize> {
    let mut l = 1usize;
    for &(_, n) in classes {
        l = l.checked_mul(n / gcd(l, n))?;
        if l > MAX_MERGE_GRANULARITY {
            return None;
        }
    }
    Some(l)
}

fn parse_checkpoint(mode: SweepMode, path: &Path) -> Result<Parsed> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read worker checkpoint {}", path.display()))?;
    Ok(match mode {
        SweepMode::CoOpt => Parsed::CoOpt(Box::new(ShardCheckpoint::from_json(&text)?)),
        SweepMode::Pareto => Parsed::Pareto(Box::new(FrontierCheckpoint::from_json(&text)?)),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_composition_recovers_parent_residues() {
        // (1, 3) split by 2 → (1, 6) and (4, 6); union over g < 12 must
        // equal the parent's residues.
        let mut pending = VecDeque::new();
        split_into(&mut pending, (1, 3), 2);
        assert_eq!(pending, VecDeque::from(vec![(1, 6), (4, 6)]));
        let parent: Vec<usize> = (0..12).filter(|g| g % 3 == 1).collect();
        let mut union: Vec<usize> = (0..12)
            .filter(|g| pending.iter().any(|&(i, n)| g % n == i))
            .collect();
        union.sort_unstable();
        assert_eq!(union, parent);
    }

    #[test]
    fn coverage_full_accepts_mixed_granularity() {
        // shard (0, 2) plus the re-split halves of (1, 2).
        assert!(coverage_full(&[(0, 2), (1, 4), (3, 4)]));
        assert!(!coverage_full(&[(0, 2), (1, 4)]));
        assert!(!coverage_full(&[]));
        // duplicates are fine
        assert!(coverage_full(&[(0, 1), (1, 2)]));
    }

    #[test]
    fn class_covered_spots_redundant_stragglers() {
        // (1, 2)'s replacements finished → the straggler is covered.
        assert!(class_covered((1, 2), &[(1, 4), (3, 4)]));
        assert!(!class_covered((1, 2), &[(1, 4)]));
        // disjoint class is not covered
        assert!(!class_covered((0, 2), &[(1, 2)]));
    }

    #[test]
    fn splittable_respects_granularity_cap() {
        assert!(splittable((0, 4), 2));
        assert!(!splittable((0, MAX_MERGE_GRANULARITY), 2));
    }

    #[test]
    fn config_validation() {
        let mut cfg = OrchestrateConfig::new(SweepMode::CoOpt, "/bin/true", "/tmp/x", 0);
        assert!(cfg.validate().is_err());
        cfg.workers = 2;
        cfg.steal_split = 1;
        assert!(cfg.validate().is_err());
        cfg.steal_split = 2;
        assert!(cfg.validate().is_ok());
    }
}
