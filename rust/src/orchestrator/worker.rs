//! Streamed shard workers: the bodies behind `co-opt --shard --bounds`
//! and `pareto --shard --bounds`.
//!
//! Each wraps the ordinary shard runner with a streaming side-thread
//! that, every [`BoundsLink::interval`]:
//!
//! - **folds** the freshest global bound from the bounds file into the
//!   run's shared [`Incumbent`] / [`LiveFrontier`] (so this shard prunes
//!   against everything any worker has completed), and
//! - **publishes** whatever this shard has newly completed (so later
//!   workers start tight instead of cold).
//!
//! Both runners also fold once *before* the sweep starts — a worker
//! launched after others finished is guaranteed their final bounds, not
//! subject to refresher timing — and publish once *after* it ends, so a
//! finished shard's bound survives for workers that have not started
//! yet.
//!
//! ## Why streaming cannot change the merged result
//!
//! Scalar mode: every published energy is the exact total of a
//! *completed, feasible* point of the same global sweep, so it is an
//! admissible network-level bound — pruning against it (with the
//! engine's strict-beyond-slack comparison) discards only points that
//! can neither beat nor index-tie the global winner. This is precisely
//! the `NetOptConfig::prime` argument with the priming point completed
//! in another process. Frontier mode: a published vector is a real
//! completed point's exact totals, so anything it strictly dominates
//! beyond slack is strictly dominated globally and was never on the
//! frontier; the home shard of the dominating point retains it (or
//! something dominating it), so the merged union re-filter reproduces
//! the single-process frontier bit-for-bit. Shard-*local* winners and
//! local frontiers may legitimately shrink under foreign bounds — the
//! merge only promises the **global** winner/frontier keeps its bits.

use std::sync::atomic::{AtomicBool, Ordering};

use crate::energy::CostModel;
use crate::engine::Incumbent;
use crate::netopt::{co_optimize_shard_with, DesignSpace, NetOptConfig, ShardRun};
use crate::nn::Network;
use crate::pareto::{pareto_optimize_shard_with, FrontierCheckpoint, FrontierPoint, LiveFrontier};

use super::bounds::{point_key, BoundsLink};

/// Run one co-optimization shard with live scalar-bound streaming (see
/// the module docs). Returns exactly what
/// [`co_optimize_shard`](crate::netopt::co_optimize_shard) returns; the
/// checkpoint's `incumbent_pj` reflects the global streamed bound.
pub fn run_coopt_shard_streamed(
    net: &Network,
    space: &DesignSpace,
    cost: &dyn CostModel,
    cfg: &NetOptConfig,
    index: usize,
    nshards: usize,
    link: &BoundsLink,
) -> ShardRun {
    let incumbent = Incumbent::new();
    // Deterministic pre-seed: everything already published folds in
    // before the first evaluation.
    let pre = link.read();
    if pre.incumbent_pj.is_finite() {
        incumbent.observe(pre.incumbent_pj);
    }
    let stop = AtomicBool::new(false);
    let run = std::thread::scope(|s| {
        s.spawn(|| {
            let mut published = incumbent.get();
            while !stop.load(Ordering::Relaxed) {
                let snap = link.read();
                if snap.incumbent_pj.is_finite() {
                    incumbent.observe(snap.incumbent_pj);
                }
                let cur = incumbent.get();
                if cur < published {
                    // Publish improvements only — re-broadcasting a
                    // foreign bound is harmless (readers take minima)
                    // but pointless.
                    if link.publish_incumbent(cur).is_ok() {
                        published = cur;
                    }
                }
                std::thread::sleep(link.interval());
            }
        });
        let run = co_optimize_shard_with(net, space, cost, cfg, index, nshards, &incumbent);
        stop.store(true, Ordering::Relaxed);
        run
    });
    // Durable final publish: workers launched after this process exits
    // must see this shard's bound even if the refresher never got a
    // wake-up between the last completion and `stop`.
    let done = incumbent.get();
    if done.is_finite() {
        let _ = link.publish_incumbent(done);
    }
    run
}

/// Run one frontier shard with live frontier-snapshot streaming (see
/// the module docs). Returns exactly what
/// [`pareto_optimize_shard`](crate::pareto::pareto_optimize_shard)
/// returns, modulo legitimately fewer *locally surviving* points when a
/// foreign point dominates them (the merged union is unchanged).
pub fn run_pareto_shard_streamed(
    net: &Network,
    space: &DesignSpace,
    cost: &dyn CostModel,
    cfg: &NetOptConfig,
    index: usize,
    nshards: usize,
    link: &BoundsLink,
) -> FrontierCheckpoint {
    let live = LiveFrontier::new();
    let pre = link.read();
    let mut known = pre.keyed();
    for p in pre.frontier {
        live.absorb(p);
    }
    let stop = AtomicBool::new(false);
    let ckpt = std::thread::scope(|s| {
        s.spawn(|| {
            // `known` tracks every point either read from the file or
            // already published by this worker, so each point is
            // appended at most once per worker.
            while !stop.load(Ordering::Relaxed) {
                let snap = link.read();
                for p in snap.frontier {
                    if known.insert(point_key(&p)) {
                        live.absorb(p);
                    }
                }
                let fresh: Vec<FrontierPoint> = live
                    .snapshot()
                    .into_iter()
                    .filter(|p| !known.contains(&point_key(p)))
                    .collect();
                if !fresh.is_empty() && link.publish_frontier(&fresh).is_ok() {
                    for p in &fresh {
                        known.insert(point_key(p));
                    }
                }
                std::thread::sleep(link.interval());
            }
        });
        let ckpt = pareto_optimize_shard_with(net, space, cost, cfg, index, nshards, &live);
        stop.store(true, Ordering::Relaxed);
        ckpt
    });
    // Durable final publish of this shard's exact local frontier.
    let seen = link.read().keyed();
    let fresh: Vec<FrontierPoint> = ckpt
        .frontier
        .iter()
        .map(|(idx, r)| FrontierPoint {
            index: *idx,
            energy_pj: r.opt.total_energy_pj,
            cycles: r.opt.total_cycles,
        })
        .filter(|p| !seen.contains(&point_key(p)))
        .collect();
    if !fresh.is_empty() {
        let _ = link.publish_frontier(&fresh);
    }
    ckpt
}
