//! Hardware resource allocation: PE arrays, memory hierarchies, and the
//! paper's reference configurations (§6).

use crate::util::fmt_bytes;

/// Kind of a storage level — selects the energy formula and whether the
/// level is per-PE or shared.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LevelKind {
    /// Per-PE register file (linear energy in size).
    Reg,
    /// Shared on-chip SRAM buffer (×1.5 per size doubling).
    Sram,
    /// Off-chip DRAM (flat per-access cost).
    Dram,
}

/// One storage level.
#[derive(Debug, Clone, PartialEq)]
pub struct MemLevel {
    /// Display name ("RF", "RF2", "GBUF", "DRAM").
    pub name: String,
    /// Kind (see [`LevelKind`]).
    pub kind: LevelKind,
    /// Capacity in bytes **per instance** (per PE for `Reg`, total for
    /// `Sram`). Ignored for DRAM.
    pub size_bytes: u64,
}

impl MemLevel {
    /// Per-PE register file of `size` bytes.
    pub fn reg(name: &str, size: u64) -> Self {
        MemLevel {
            name: name.into(),
            kind: LevelKind::Reg,
            size_bytes: size,
        }
    }

    /// Shared SRAM buffer of `size` bytes.
    pub fn sram(name: &str, size: u64) -> Self {
        MemLevel {
            name: name.into(),
            kind: LevelKind::Sram,
            size_bytes: size,
        }
    }

    /// Off-chip DRAM.
    pub fn dram() -> Self {
        MemLevel {
            name: "DRAM".into(),
            kind: LevelKind::Dram,
            size_bytes: u64::MAX,
        }
    }
}

/// PE array dimensions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArrayShape {
    /// Vertical dimension (the `U` axis of `U | V`).
    pub rows: u32,
    /// Horizontal dimension (the `V` axis). 1 for 1D arrays.
    pub cols: u32,
}

impl ArrayShape {
    /// Total PEs.
    pub fn pes(&self) -> u64 {
        self.rows as u64 * self.cols as u64
    }
}

/// On-chip interconnect style between the shared buffer and the PEs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrayBus {
    /// Systolic / neighbor forwarding: shared data moves PE-to-PE at hop
    /// cost (the paper's default; enables the inter-PE "level").
    Systolic,
    /// Broadcast-only bus: no inter-PE communication, every delivery comes
    /// from the shared buffer (the red configuration in Fig 8).
    Broadcast,
}

/// A complete accelerator resource allocation.
///
/// `levels` is ordered innermost → outermost and must be: one or more
/// `Reg` levels (per-PE), then zero or more `Sram` levels, then exactly
/// one `Dram`. The PE array sits between the outermost `Reg` and the
/// first shared level.
#[derive(Debug, Clone, PartialEq)]
pub struct Arch {
    /// Display name.
    pub name: String,
    /// Storage levels, innermost first, DRAM last.
    pub levels: Vec<MemLevel>,
    /// PE array shape.
    pub array: ArrayShape,
    /// Interconnect style.
    pub bus: ArrayBus,
    /// Word size in bytes (paper: 16-bit = 2).
    pub word_bytes: u32,
    /// DRAM bandwidth in bytes per cycle (for the performance bound).
    pub dram_bw_bytes_per_cycle: f64,
}

impl Arch {
    /// Number of per-PE register levels (== `Mapping::spatial_at`).
    pub fn rf_levels(&self) -> usize {
        self.levels
            .iter()
            .take_while(|l| l.kind == LevelKind::Reg)
            .count()
    }

    /// Total temporal levels (register + shared, incl. DRAM).
    pub fn num_levels(&self) -> usize {
        self.levels.len()
    }

    /// Words that fit in level `i` (per instance).
    pub fn level_words(&self, i: usize) -> u64 {
        if self.levels[i].kind == LevelKind::Dram {
            u64::MAX
        } else {
            self.levels[i].size_bytes / self.word_bytes as u64
        }
    }

    /// Aggregate on-chip size of each non-DRAM level, innermost first:
    /// per-PE register levels count `size × PEs`, shared SRAM levels
    /// their plain size. The single source of truth for both the
    /// capacity budget ([`onchip_bytes`]) and `netopt`'s Observation-2
    /// inter-level ratio filter.
    ///
    /// [`onchip_bytes`]: Arch::onchip_bytes
    pub fn onchip_level_bytes(&self) -> Vec<u64> {
        let pes = self.array.pes();
        self.levels
            .iter()
            .filter_map(|l| match l.kind {
                LevelKind::Reg => Some(l.size_bytes * pes),
                LevelKind::Sram => Some(l.size_bytes),
                LevelKind::Dram => None,
            })
            .collect()
    }

    /// Total on-chip storage in bytes: per-PE register levels times the
    /// PE count plus shared SRAM levels (DRAM excluded). The capacity
    /// measure `netopt`'s design-space budget is checked against.
    pub fn onchip_bytes(&self) -> u64 {
        self.onchip_level_bytes().iter().sum()
    }

    /// Validate the level ordering contract.
    pub fn validate(&self) -> Result<(), String> {
        let mut seen_sram = false;
        let mut seen_dram = false;
        if self.levels.is_empty() {
            return Err("no levels".into());
        }
        for l in &self.levels {
            match l.kind {
                LevelKind::Reg => {
                    if seen_sram || seen_dram {
                        return Err(format!("Reg level {} after shared levels", l.name));
                    }
                }
                LevelKind::Sram => {
                    if seen_dram {
                        return Err(format!("Sram level {} after DRAM", l.name));
                    }
                    seen_sram = true;
                }
                LevelKind::Dram => {
                    if seen_dram {
                        return Err("multiple DRAM levels".into());
                    }
                    seen_dram = true;
                }
            }
        }
        if !seen_dram {
            return Err("missing DRAM level".into());
        }
        if self.rf_levels() == 0 {
            return Err("need at least one Reg level".into());
        }
        Ok(())
    }

    /// One-line description for reports.
    pub fn describe(&self) -> String {
        let levels = self
            .levels
            .iter()
            .map(|l| {
                if l.kind == LevelKind::Dram {
                    l.name.clone()
                } else {
                    format!("{} {}", l.name, fmt_bytes(l.size_bytes))
                }
            })
            .collect::<Vec<_>>()
            .join(" / ");
        format!(
            "{}: {}x{} PEs ({:?}), {}",
            self.name, self.array.rows, self.array.cols, self.bus, levels
        )
    }
}

/// The Eyeriss-like baseline (blue config in Fig 8): 512 B RF per PE,
/// 128 KB global buffer, 16×16 systolic array.
pub fn eyeriss_like() -> Arch {
    Arch {
        name: "eyeriss-like".into(),
        levels: vec![
            MemLevel::reg("RF", 512),
            MemLevel::sram("GBUF", 128 << 10),
            MemLevel::dram(),
        ],
        array: ArrayShape { rows: 16, cols: 16 },
        bus: ArrayBus::Systolic,
        word_bytes: 2,
        dram_bw_bytes_per_cycle: 16.0,
    }
}

/// The red config in Fig 8: same resources but a broadcast-only bus
/// (inter-PE communication disabled).
pub fn no_local_reuse() -> Arch {
    Arch {
        name: "broadcast-bus".into(),
        bus: ArrayBus::Broadcast,
        ..eyeriss_like()
    }
}

/// The green config in Fig 8: a 64 B RF to lower per-access energy.
pub fn small_rf() -> Arch {
    let mut a = eyeriss_like();
    a.name = "small-rf".into();
    a.levels[0] = MemLevel::reg("RF", 64);
    a
}

/// The paper's large cloud-class baseline (§6.3): 128×128 PEs, 8 B
/// register per PE, 64 KB L1 buffer, 28 MB L2 buffer.
pub fn tpu_like() -> Arch {
    Arch {
        name: "tpu-like".into(),
        levels: vec![
            MemLevel::reg("RF", 8),
            MemLevel::sram("L1", 64 << 10),
            MemLevel::sram("L2", 28 << 20),
            MemLevel::dram(),
        ],
        array: ArrayShape { rows: 128, cols: 128 },
        bus: ArrayBus::Systolic,
        word_bytes: 2,
        dram_bw_bytes_per_cycle: 64.0,
    }
}

/// The paper's optimized mobile configuration (§6.3 result): two-level
/// register file (16 B + 128 B) and a 256 KB global double buffer.
pub fn optimized_mobile() -> Arch {
    Arch {
        name: "optimized-mobile".into(),
        levels: vec![
            MemLevel::reg("RF1", 16),
            MemLevel::reg("RF2", 128),
            MemLevel::sram("GBUF", 256 << 10),
            MemLevel::dram(),
        ],
        array: ArrayShape { rows: 16, cols: 16 },
        bus: ArrayBus::Systolic,
        word_bytes: 2,
        dram_bw_bytes_per_cycle: 16.0,
    }
}

/// Table 4 validation designs: OS4, OS8, WS16.
pub fn validation_designs() -> Vec<(Arch, &'static str)> {
    vec![
        (
            Arch {
                name: "OS4".into(),
                levels: vec![
                    MemLevel::reg("RF", 32),
                    MemLevel::sram("GBUF", 32 << 10),
                    MemLevel::dram(),
                ],
                array: ArrayShape { rows: 4, cols: 1 },
                bus: ArrayBus::Systolic,
                word_bytes: 2,
                dram_bw_bytes_per_cycle: 8.0,
            },
            "X", // output-stationary: X unrolled on the 1D array
        ),
        (
            Arch {
                name: "OS8".into(),
                levels: vec![
                    MemLevel::reg("RF", 64),
                    MemLevel::sram("GBUF", 64 << 10),
                    MemLevel::dram(),
                ],
                array: ArrayShape { rows: 8, cols: 1 },
                bus: ArrayBus::Systolic,
                word_bytes: 2,
                dram_bw_bytes_per_cycle: 8.0,
            },
            "X",
        ),
        (
            Arch {
                name: "WS16".into(),
                levels: vec![
                    MemLevel::reg("RF", 64),
                    MemLevel::sram("GBUF", 32 << 10),
                    MemLevel::dram(),
                ],
                array: ArrayShape { rows: 4, cols: 4 },
                bus: ArrayBus::Systolic,
                word_bytes: 2,
                dram_bw_bytes_per_cycle: 8.0,
            },
            "C|K",
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_configs_validate() {
        for a in [
            eyeriss_like(),
            no_local_reuse(),
            small_rf(),
            tpu_like(),
            optimized_mobile(),
        ] {
            a.validate().unwrap_or_else(|e| panic!("{}: {e}", a.name));
        }
        for (a, _) in validation_designs() {
            a.validate().unwrap();
        }
    }

    #[test]
    fn eyeriss_config_matches_paper() {
        let a = eyeriss_like();
        assert_eq!(a.levels[0].size_bytes, 512);
        assert_eq!(a.levels[1].size_bytes, 128 << 10);
        assert_eq!(a.array.pes(), 256);
        assert_eq!(a.rf_levels(), 1);
        // 512 B RF at 2 B words = 256 words
        assert_eq!(a.level_words(0), 256);
        assert_eq!(a.level_words(2), u64::MAX);
    }

    #[test]
    fn tpu_config_matches_paper() {
        let a = tpu_like();
        assert_eq!(a.array.pes(), 16384);
        assert_eq!(a.levels[2].size_bytes, 28 << 20);
        assert_eq!(a.num_levels(), 4);
    }

    #[test]
    fn two_level_rf_counts() {
        assert_eq!(optimized_mobile().rf_levels(), 2);
    }

    #[test]
    fn onchip_bytes_aggregates_registers() {
        // eyeriss-like: 512 B x 256 PEs + 128 KB shared
        assert_eq!(eyeriss_like().onchip_bytes(), 512 * 256 + (128 << 10));
        // optimized mobile: (16 + 128) B x 256 PEs + 256 KB shared
        assert_eq!(
            optimized_mobile().onchip_bytes(),
            (16 + 128) * 256 + (256 << 10)
        );
        // per-level aggregates, innermost first, DRAM excluded
        assert_eq!(
            optimized_mobile().onchip_level_bytes(),
            vec![16 * 256, 128 * 256, 256 << 10]
        );
    }

    #[test]
    fn validate_rejects_bad_orders() {
        let bad = Arch {
            name: "bad".into(),
            levels: vec![MemLevel::sram("S", 1024), MemLevel::reg("R", 64), MemLevel::dram()],
            array: ArrayShape { rows: 1, cols: 1 },
            bus: ArrayBus::Systolic,
            word_bytes: 2,
            dram_bw_bytes_per_cycle: 1.0,
        };
        assert!(bad.validate().is_err());

        let no_dram = Arch {
            name: "nodram".into(),
            levels: vec![MemLevel::reg("R", 64)],
            array: ArrayShape { rows: 1, cols: 1 },
            bus: ArrayBus::Systolic,
            word_bytes: 2,
            dram_bw_bytes_per_cycle: 1.0,
        };
        assert!(no_dram.validate().is_err());
    }

    #[test]
    fn describe_mentions_sizes() {
        let d = eyeriss_like().describe();
        assert!(d.contains("512 B"));
        assert!(d.contains("128 KB"));
        assert!(d.contains("16x16"));
    }
}
