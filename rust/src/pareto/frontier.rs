//! The dominance archive: a 2-D `(energy, cycles)` Pareto frontier over
//! indexed points, with deterministic tie-breaking and the pruning
//! predicate the branch-and-bound consults.
//!
//! ## Semantics
//!
//! Point `a` **dominates** `b` when `a.energy <= b.energy`,
//! `a.cycles <= b.cycles`, and at least one inequality is strict. Two
//! points with bit-identical vectors are deduplicated by the lower
//! candidate index (the same deterministic key every other tie in the
//! codebase breaks on). The retained set is therefore a pure function of
//! the inserted *set* — insertion order never matters — which is what
//! makes the shard-merge contract (`checkpoint::merge_frontiers`) hold
//! bit for bit.
//!
//! ## Invariants
//!
//! The archive keeps its points sorted by strictly ascending energy;
//! dominance then forces strictly descending cycles. Both lookups exploit
//! this: [`insert`](Frontier::insert) is two binary searches plus a
//! splice, and [`dominates_bound`](Frontier::dominates_bound) is one
//! binary search (the candidate dominator of a bound is always the
//! cheapest-in-cycles point among those strictly below it in energy).

use crate::engine::PRUNE_SLACK;

/// One archived point: the global candidate index (deterministic
/// tie-break key) and its completed `(energy, cycles)` totals.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FrontierPoint {
    /// Global candidate (raw-grid) index.
    pub index: usize,
    /// Completed network energy, pJ.
    pub energy_pj: f64,
    /// Completed network cycles.
    pub cycles: f64,
}

/// A 2-D dominance archive (see the module docs). `Default` is the empty
/// frontier.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Frontier {
    /// Strictly ascending energy, strictly descending cycles.
    points: Vec<FrontierPoint>,
}

impl Frontier {
    /// The empty frontier.
    pub fn new() -> Frontier {
        Frontier::default()
    }

    /// Build from arbitrary points (order-independent result).
    pub fn from_points<I: IntoIterator<Item = FrontierPoint>>(points: I) -> Frontier {
        let mut f = Frontier::new();
        for p in points {
            f.insert(p);
        }
        f
    }

    /// Insert a completed point. Returns whether it was retained (it may
    /// be dominated on arrival; retaining it may evict points it
    /// dominates). Equal-vector duplicates keep the lower index.
    pub fn insert(&mut self, p: FrontierPoint) -> bool {
        // The only candidate dominator is the cheapest-in-cycles point
        // with energy <= p's — the last of that (sorted) prefix.
        let j = self.points.partition_point(|q| q.energy_pj <= p.energy_pj);
        if j > 0 {
            let q = self.points[j - 1];
            let equal_vec = q.energy_pj == p.energy_pj && q.cycles == p.cycles;
            if q.cycles < p.cycles
                || (q.cycles == p.cycles && q.energy_pj < p.energy_pj)
                || (equal_vec && q.index <= p.index)
            {
                return false;
            }
        }
        // Evict everything p dominates: within the energy >= p region
        // (cycles descending) that is exactly the prefix with
        // cycles >= p's — including an equal-vector twin with a higher
        // index, which the check above deliberately let through.
        let k = self.points.partition_point(|q| q.energy_pj < p.energy_pj);
        let mut end = k;
        while end < self.points.len() && self.points[end].cycles >= p.cycles {
            end += 1;
        }
        self.points.splice(k..end, std::iter::once(p));
        true
    }

    /// The pruning predicate: is the admissible lower-bound vector
    /// `(energy_lb, cycles_lb)` of a partially evaluated point strictly
    /// dominated — beyond the relative [`PRUNE_SLACK`], in **both**
    /// coordinates — by an archived point? If so, the point's final
    /// totals (componentwise `>=` the bound in real arithmetic) are
    /// strictly dominated too: it can neither join the frontier nor win
    /// an equal-vector tie, so abandoning it preserves exactness. The
    /// slack absorbs the f64 rounding of the floor terms, mirroring the
    /// engine's scalar pruning contract.
    pub fn dominates_bound(&self, energy_lb_pj: f64, cycles_lb: f64) -> bool {
        // Points strictly below the bound in energy (with slack) form a
        // prefix; its last element has the fewest cycles of them all.
        let j = self
            .points
            .partition_point(|q| q.energy_pj * (1.0 + PRUNE_SLACK) < energy_lb_pj);
        j > 0 && self.points[j - 1].cycles * (1.0 + PRUNE_SLACK) < cycles_lb
    }

    /// The archived points, ascending in energy (descending in cycles).
    pub fn points(&self) -> &[FrontierPoint] {
        &self.points
    }

    /// Number of archived points.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// True when nothing has been archived.
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Deterministic reporting-time thinning — the `--eps` / `--points`
    /// knobs. The archive itself (and every checkpoint) is always exact;
    /// thinning is presentation and plan-selection economy for huge
    /// frontiers:
    ///
    /// - `eps > 0`: walk ascending energy and keep a point only when it
    ///   improves cycles over the last kept one by more than the factor
    ///   `1 + eps` (the min-energy endpoint is always kept, and the
    ///   min-cycles endpoint is re-appended if the walk dropped it);
    /// - `max_points`: evenly spaced ranks over what survives, both
    ///   endpoints included.
    ///
    /// Both passes are pure functions of the (sorted) point list, so a
    /// thinned view is as deterministic as the exact archive.
    pub fn thin(&self, eps: f64, max_points: Option<usize>) -> Frontier {
        let mut pts: Vec<FrontierPoint> = Vec::new();
        if eps > 0.0 {
            for p in &self.points {
                match pts.last() {
                    Some(last) if p.cycles * (1.0 + eps) > last.cycles => {}
                    _ => pts.push(*p),
                }
            }
            let (last_kept, tail) = (pts.last().copied(), self.points.last().copied());
            if let (Some(last_kept), Some(tail)) = (last_kept, tail) {
                if last_kept.index != tail.index {
                    pts.push(tail); // keep the min-cycles endpoint
                }
            }
        } else {
            pts = self.points.clone();
        }
        if let Some(cap) = max_points {
            if cap >= 1 && pts.len() > cap {
                if cap == 1 {
                    pts = vec![pts[0]];
                } else {
                    let n = pts.len();
                    pts = (0..cap).map(|i| pts[i * (n - 1) / (cap - 1)]).collect();
                }
            }
        }
        Frontier { points: pts }
    }

    /// The structural invariants (test hook): strictly ascending energy,
    /// strictly descending cycles — which together imply no archived
    /// point dominates another.
    pub fn invariants_hold(&self) -> bool {
        self.points
            .windows(2)
            .all(|w| w[0].energy_pj < w[1].energy_pj && w[0].cycles > w[1].cycles)
    }
}
