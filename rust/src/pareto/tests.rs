//! Pareto tests: the dominance-pruned frontier equals the exhaustive
//! sweep + dominance filter bit for bit on small spaces × {alexnet head,
//! lstm-m, mlp-m}; sharded frontiers merge to the single-process run;
//! merge and archive properties hold under the randomized harness; and
//! budget selection collapses to the scalar `min_tops` winner.

use super::*;
use crate::arch::ArrayShape;
use crate::energy::Table3;
use crate::engine::{cycle_floor, PRUNE_SLACK};
use crate::netopt::co_optimize;
use crate::nn::network;
use crate::search::SearchOpts;
use crate::util::prop::for_cases;

/// The compact widened grid the netopt equivalence tests use: the
/// deliberately-bad rf512 points stay in play and must be dominated or
/// vector-pruned, never mis-ranked.
fn small_space() -> DesignSpace {
    let mut s = DesignSpace::paper_default(ArrayShape { rows: 8, cols: 8 });
    s.rf1_sizes = vec![16, 64, 512];
    s.rf2_ratios = vec![8];
    s.gbuf_sizes = vec![64 << 10, 256 << 10];
    s.ratio_min = 0.25;
    s.ratio_max = 64.0;
    s
}

fn small_opts() -> SearchOpts {
    let mut o = SearchOpts::capped(150, 4);
    o.max_order_combos = 9;
    o
}

fn workloads() -> Vec<Network> {
    vec![
        network("alexnet", 1).unwrap().head(3),
        network("lstm-m", 1).unwrap(),
        network("mlp-m", 16).unwrap(),
    ]
}

/// Bit-level equality on the frontier-point contract surface:
/// architecture, totals, and every per-layer (mapping, smap, model
/// result). Search *counters* are excluded — seed and pruning histories
/// legitimately differ across shard layouts; the frontier must not.
fn assert_point_eq(tag: &str, a: &HierarchyResult, b: &HierarchyResult) {
    assert_eq!(a.arch, b.arch, "{tag}: arch differs");
    assert_eq!(
        a.opt.total_energy_pj.to_bits(),
        b.opt.total_energy_pj.to_bits(),
        "{tag}: energy bits differ"
    );
    assert_eq!(
        a.opt.total_cycles.to_bits(),
        b.opt.total_cycles.to_bits(),
        "{tag}: cycle bits differ"
    );
    assert_eq!(a.opt.total_macs, b.opt.total_macs, "{tag}: macs differ");
    assert_eq!(a.opt.unmapped, 0, "{tag}: frontier points are fully mapped");
    assert_eq!(b.opt.unmapped, 0, "{tag}: frontier points are fully mapped");
    assert_eq!(a.opt.per_layer.len(), b.opt.per_layer.len());
    for (x, y) in a.opt.per_layer.iter().zip(b.opt.per_layer.iter()) {
        let (x, y) = (x.as_ref().unwrap(), y.as_ref().unwrap());
        assert_eq!(x.mapping, y.mapping, "{tag}: mapping differs");
        assert_eq!(x.smap, y.smap, "{tag}: spatial map differs");
        assert_eq!(x.result, y.result, "{tag}: model result differs");
    }
}

/// Reference implementation: O(n²) dominance filter over the feasible
/// exhaustive ranking (already ascending `(energy, index)`, so for equal
/// energies the earlier entry has the lower grid index).
fn exhaustive_frontier(ranked: &[HierarchyResult]) -> Vec<&HierarchyResult> {
    let feas: Vec<&HierarchyResult> = ranked.iter().filter(|r| r.opt.unmapped == 0).collect();
    let mut out = Vec::new();
    for (i, p) in feas.iter().enumerate() {
        let (pe, pc) = (p.opt.total_energy_pj, p.opt.total_cycles);
        let dominated = feas.iter().enumerate().any(|(j, q)| {
            let (qe, qc) = (q.opt.total_energy_pj, q.opt.total_cycles);
            (qe < pe && qc <= pc) || (qe == pe && (qc < pc || (qc == pc && j < i)))
        });
        if !dominated {
            out.push(*p);
        }
    }
    out
}

#[test]
fn frontier_matches_exhaustive_filter_on_small_spaces() {
    let space = small_space();
    for net in workloads() {
        let ex = co_optimize(
            &net,
            &space,
            &Table3,
            &NetOptConfig::exhaustive(small_opts(), 2),
        );
        let reference = exhaustive_frontier(&ex.ranked);
        assert!(!reference.is_empty(), "{}: no feasible point", net.name);
        for threads in [1usize, 3] {
            let par = pareto_optimize(
                &net,
                &space,
                &Table3,
                &NetOptConfig::new(small_opts(), threads),
                &ParetoConfig::default(),
            );
            assert_eq!(
                par.frontier.len(),
                reference.len(),
                "{}: frontier size differs (t={threads})",
                net.name
            );
            for (e, r) in par.frontier.iter().zip(reference.iter()) {
                assert_point_eq(&format!("{} t={threads}", net.name), &e.result, r);
            }
            // frontier order is ascending energy, strictly
            for w in par.frontier.windows(2) {
                assert!(
                    w[0].result.opt.total_energy_pj < w[1].result.opt.total_energy_pj
                        && w[0].result.opt.total_cycles > w[1].result.opt.total_cycles,
                    "{}: frontier not strictly ordered",
                    net.name
                );
            }
            // the vector bound never adds work, and every candidate is
            // accounted for
            assert!(par.stats.invariants_hold(), "{}", par.stats);
            assert_eq!(par.stats.candidates, ex.stats.candidates);
            assert!(par.stats.evaluated_full <= ex.stats.evaluated_full);
        }
    }
}

#[test]
fn frontier_min_energy_point_is_the_scalar_winner() {
    let space = small_space();
    for net in workloads() {
        let scalar = co_optimize(&net, &space, &Table3, &NetOptConfig::new(small_opts(), 2));
        let par = pareto_optimize(
            &net,
            &space,
            &Table3,
            &NetOptConfig::new(small_opts(), 2),
            &ParetoConfig::default(),
        );
        let w = scalar.best().expect("scalar winner");
        let f = par.frontier.first().expect("non-empty frontier");
        assert_point_eq(&format!("{} min-energy", net.name), &f.result, w);
    }
}

#[test]
fn cycle_floor_is_admissible_on_every_evaluated_point() {
    let space = small_space();
    let net = network("mlp-m", 16).unwrap();
    let ex = co_optimize(
        &net,
        &space,
        &Table3,
        &NetOptConfig::exhaustive(small_opts(), 2),
    );
    let mut checked = 0usize;
    for r in &ex.ranked {
        for (lo, layer) in r.opt.per_layer.iter().zip(net.layers.iter()) {
            let Some(lo) = lo else { continue };
            let floor = cycle_floor(&layer.shape, &r.arch);
            assert!(
                floor <= lo.result.cycles * (1.0 + PRUNE_SLACK),
                "{} / {}: cycle floor {} above achieved {}",
                r.arch.name,
                layer.name,
                floor,
                lo.result.cycles
            );
            checked += 1;
        }
    }
    assert!(checked > 0);
}

#[test]
fn sharded_frontier_merges_to_single_process() {
    let space = small_space();
    for net in [network("mlp-m", 16).unwrap(), network("lstm-m", 1).unwrap()] {
        let single = pareto_optimize(
            &net,
            &space,
            &Table3,
            &NetOptConfig::new(small_opts(), 2),
            &ParetoConfig::default(),
        );
        for nshards in [1usize, 2, 3, 5] {
            let sharded = pareto_optimize_sharded(
                &net,
                &space,
                &Table3,
                &NetOptConfig::new(small_opts(), 2),
                &ParetoConfig::default(),
                nshards,
            );
            assert_eq!(
                sharded.frontier.len(),
                single.frontier.len(),
                "{} n={nshards}: frontier size differs",
                net.name
            );
            // Indices are compared only relatively: shards tag points by
            // raw-grid index while the single process tags by filtered
            // position (same relative order — filtering preserves it —
            // exactly like the scalar shard contract). The payload is
            // the contract surface.
            for (a, b) in sharded.frontier.iter().zip(single.frontier.iter()) {
                assert_point_eq(&format!("{} n={nshards}", net.name), &a.result, &b.result);
            }
            assert!(sharded.stats.invariants_hold(), "{}", sharded.stats);
            assert_eq!(sharded.stats.generated, single.stats.generated);
            assert_eq!(sharded.stats.candidates, single.stats.candidates);
        }
    }
}

#[test]
fn frontier_merge_is_associative_commutative_and_order_free() {
    let net = network("mlp-m", 16).unwrap();
    let space = small_space();
    let cfg = NetOptConfig::new(small_opts(), 1);
    let ckpts: Vec<FrontierCheckpoint> = (0..4)
        .map(|i| pareto_optimize_shard(&net, &space, &Table3, &cfg, i, 4))
        .collect();
    let canonical = merge_all_frontiers(&ckpts).unwrap();
    assert_eq!(canonical.shards, vec![0, 1, 2, 3]);
    assert!(canonical.stats.invariants_hold(), "{}", canonical.stats);
    // commutative and associative on concrete pairs/triples
    let ab = merge_frontiers(&ckpts[0], &ckpts[1]).unwrap();
    let ba = merge_frontiers(&ckpts[1], &ckpts[0]).unwrap();
    assert_eq!(ab, ba, "merge must be commutative");
    let left = merge_frontiers(&ab, &ckpts[2]).unwrap();
    let right = merge_frontiers(&ckpts[0], &merge_frontiers(&ckpts[1], &ckpts[2]).unwrap())
        .unwrap();
    assert_eq!(left, right, "merge must be associative");
    // randomized merge orders all reproduce the canonical result
    for_cases(0xF405, 12, |rng| {
        let mut order: Vec<usize> = (0..4).collect();
        for i in (1..order.len()).rev() {
            let j = rng.below(i as u64 + 1) as usize;
            order.swap(i, j);
        }
        let shuffled: Vec<FrontierCheckpoint> =
            order.iter().map(|&i| ckpts[i].clone()).collect();
        let m = merge_all_frontiers(&shuffled).unwrap();
        assert_eq!(m, canonical, "merge order {order:?} diverged");
    });
}

#[test]
fn frontier_merge_rejects_mismatches() {
    let net = network("mlp-m", 16).unwrap();
    let space = small_space();
    let cfg = NetOptConfig::new(small_opts(), 1);
    let c0 = pareto_optimize_shard(&net, &space, &Table3, &cfg, 0, 2);
    let c1 = pareto_optimize_shard(&net, &space, &Table3, &cfg, 1, 2);
    // duplicate coverage deduplicates idempotently (re-split stragglers,
    // speculative duplicates) — the merge is the checkpoint itself
    assert_eq!(merge_frontiers(&c0, &c0).unwrap(), c0, "self-merge must be idempotent");
    // partial overlap remains a hard error: (0,2) covers residues {0,2,4}
    // of 6, (1,3) covers {1,4} — they share 4 but neither contains the other
    let c_partial = pareto_optimize_shard(&net, &space, &Table3, &cfg, 1, 3);
    let err = merge_frontiers(&c0, &c_partial).unwrap_err().to_string();
    assert!(
        err.contains("partially overlapping"),
        "partial overlap must be rejected, got: {err}"
    );
    let other = network("lstm-m", 1).unwrap();
    let c_other_net = pareto_optimize_shard(&other, &space, &Table3, &cfg, 1, 2);
    assert!(merge_frontiers(&c0, &c_other_net).is_err(), "network");
    assert!(merge_frontiers(&c0, &c1).is_ok());
}

#[test]
fn mixed_granularity_frontier_merge_matches_parent_merge() {
    let net = network("mlp-m", 16).unwrap();
    let space = small_space();
    let cfg = NetOptConfig::new(small_opts(), 1);
    let c0 = pareto_optimize_shard(&net, &space, &Table3, &cfg, 0, 2);
    let c1 = pareto_optimize_shard(&net, &space, &Table3, &cfg, 1, 2);
    // sub-shards of c1 under the (i + j·n, n·m) composition: together
    // they cover exactly shard 1 of 2, re-expressed at granularity 4
    let s1 = pareto_optimize_shard(&net, &space, &Table3, &cfg, 1, 4);
    let s3 = pareto_optimize_shard(&net, &space, &Table3, &cfg, 3, 4);
    let whole = merge_frontiers(&c0, &c1).unwrap();
    for (tag, set) in [
        ("via-subs", vec![c0.clone(), s1.clone(), s3.clone()]),
        ("interleaved", vec![s3.clone(), c0.clone(), s1.clone()]),
        ("with-dup", vec![c0.clone(), c1.clone(), s1, s3]),
    ] {
        let merged = merge_all_frontiers(&set).unwrap();
        assert_eq!(merged.nshards, 4, "{tag}: granularity normalizes to lcm");
        assert_eq!(merged.shards, vec![0, 1, 2, 3], "{tag}: full coverage");
        assert_eq!(
            merged.frontier.len(),
            whole.frontier.len(),
            "{tag}: frontier size differs from the parent-partition merge"
        );
        for ((ia, a), (ib, b)) in merged.frontier.iter().zip(whole.frontier.iter()) {
            assert_eq!(ia, ib, "{tag}: frontier grid index differs");
            assert_point_eq(tag, a, b);
        }
        assert!(merged.stats.invariants_hold(), "{tag}: {}", merged.stats);
        assert_eq!(merged.stats.generated, whole.stats.generated, "{tag}");
        assert_eq!(merged.stats.candidates, whole.stats.candidates, "{tag}");
        // seeds are deliberately NOT compared across partitions: they
        // record energies observed along the pruning history, and a
        // sub-shard may complete a point its parent shard pruned — they
        // are admissible hints, not results.
    }
}

#[test]
fn frontier_checkpoint_json_roundtrip_is_lossless() {
    let net = network("mlp-m", 16).unwrap();
    let space = small_space();
    let cfg = NetOptConfig::new(small_opts(), 1);
    for (index, nshards) in [(0usize, 1usize), (0, 2), (2, 7)] {
        let ckpt = pareto_optimize_shard(&net, &space, &Table3, &cfg, index, nshards);
        let text = ckpt.to_json();
        let back = FrontierCheckpoint::from_json(&text)
            .unwrap_or_else(|e| panic!("shard {index}/{nshards}: {e}\n{text}"));
        assert_eq!(ckpt, back, "shard {index}/{nshards} round-trip");
        assert_eq!(text, back.to_json(), "serialized form must be stable");
    }
    assert!(FrontierCheckpoint::from_json("{\"format\":\"bogus\"}").is_err());
}

#[test]
fn archive_invariants_under_random_insertion_orders() {
    for_cases(0xFA127, 300, |rng| {
        let n = 1 + rng.below(20) as usize;
        // small integer grids force plenty of exact vector ties
        let original: Vec<FrontierPoint> = (0..n)
            .map(|i| FrontierPoint {
                index: i,
                energy_pj: 1.0 + rng.below(8) as f64,
                cycles: 1.0 + rng.below(8) as f64,
            })
            .collect();
        let a = Frontier::from_points(original.iter().copied());
        assert!(a.invariants_hold(), "archive violates invariants: {a:?}");
        let mut shuffled = original.clone();
        for i in (1..shuffled.len()).rev() {
            let j = rng.below(i as u64 + 1) as usize;
            shuffled.swap(i, j);
        }
        let b = Frontier::from_points(shuffled.iter().copied());
        assert_eq!(a, b, "insertion order changed the archive");
        // brute-force reference: strict dominance + lowest-index dedup
        let mut reference: Vec<FrontierPoint> = original
            .iter()
            .copied()
            .filter(|p| {
                !original.iter().any(|q| {
                    q.index != p.index
                        && ((q.energy_pj <= p.energy_pj
                            && q.cycles <= p.cycles
                            && (q.energy_pj < p.energy_pj || q.cycles < p.cycles))
                            || (q.energy_pj == p.energy_pj
                                && q.cycles == p.cycles
                                && q.index < p.index))
                })
            })
            .collect();
        reference.sort_by(|x, y| x.energy_pj.partial_cmp(&y.energy_pj).unwrap());
        assert_eq!(a.points(), reference.as_slice(), "archive != brute force");
        // the pruning predicate agrees with brute force on random bounds
        for _ in 0..5 {
            let (e, c) = (1.0 + rng.below(10) as f64, 1.0 + rng.below(10) as f64);
            let expect = a.points().iter().any(|q| {
                q.energy_pj * (1.0 + PRUNE_SLACK) < e && q.cycles * (1.0 + PRUNE_SLACK) < c
            });
            assert_eq!(a.dominates_bound(e, c), expect, "bound ({e},{c}) on {a:?}");
        }
    });
}

#[test]
fn thinning_is_a_deterministic_subset_with_endpoints() {
    let pts: Vec<FrontierPoint> = (0..10)
        .map(|i| FrontierPoint {
            index: i,
            energy_pj: 100.0 + 10.0 * i as f64,
            cycles: 1000.0 / (1.0 + i as f64),
        })
        .collect();
    let f = Frontier::from_points(pts.iter().copied());
    assert_eq!(f.len(), 10);
    // eps keeps the extremes and only sufficiently-improving interior
    let eps = f.thin(0.5, None);
    assert!(eps.len() < f.len());
    assert!(eps.invariants_hold());
    assert_eq!(eps.points().first().unwrap().index, 0, "min-energy endpoint");
    assert_eq!(eps.points().last().unwrap().index, 9, "min-cycles endpoint");
    // cap keeps exactly cap points, endpoints included
    let capped = f.thin(0.0, Some(4));
    assert_eq!(capped.len(), 4);
    assert!(capped.invariants_hold());
    assert_eq!(capped.points().first().unwrap().index, 0);
    assert_eq!(capped.points().last().unwrap().index, 9);
    // every thinned point is an original frontier point
    for p in capped.points().iter().chain(eps.points()) {
        assert!(f.points().contains(p));
    }
    // exact mode is the identity
    assert_eq!(f.thin(0.0, None), f);
}

#[test]
fn selector_budget_matches_scalar_min_tops_winner() {
    let net = network("mlp-m", 16).unwrap();
    let space = small_space();
    let par = pareto_optimize(
        &net,
        &space,
        &Table3,
        &NetOptConfig::new(small_opts(), 2),
        &ParetoConfig::default(),
    );
    let sel = PlanSelector::new(par.frontier.clone());
    assert!(!sel.is_empty());
    // unconstrained selection is the min-energy point
    assert_point_eq(
        "select(None)",
        &sel.select(None).unwrap().result,
        &par.frontier[0].result,
    );
    // an unmeetable budget selects nothing
    assert!(sel.select(Some(0.0)).is_none());
    // for each frontier point's throughput, the iso-throughput scalar
    // winner is exactly what the selector picks (cap the cost on long
    // frontiers)
    for entry in sel.entries().iter().take(3) {
        let tops = entry.result.opt.tops(1.0);
        let scalar = co_optimize(
            &net,
            &space,
            &Table3,
            &NetOptConfig::new(small_opts(), 2).with_min_tops(tops),
        );
        let w = scalar.best().expect("constrained scalar winner");
        let picked = sel.select_min_tops(tops, 1.0).expect("selector hit");
        assert_point_eq("min-tops selection", &picked.result, w);
        // and the cycle-budget phrasing agrees with the tops phrasing
        let budget = entry.result.opt.total_cycles;
        let by_budget = sel.select(Some(budget)).expect("budget hit");
        assert_eq!(by_budget.index, picked.index);
    }
}

#[test]
fn seeded_frontier_is_bit_identical_to_cold() {
    use crate::netopt::LayerKey;
    let net = network("mlp-m", 16).unwrap();
    let space = small_space();
    let cfg = NetOptConfig::new(small_opts(), 1);
    let cold = pareto_optimize(&net, &space, &Table3, &cfg, &ParetoConfig::default());
    let layer_e: Vec<(LayerKey, f64)> = cold.frontier[0]
        .result
        .opt
        .per_layer
        .iter()
        .zip(net.layers.iter())
        .map(|(lo, l)| {
            (
                (l.shape.bounds, l.shape.stride),
                lo.as_ref().unwrap().result.energy_pj,
            )
        })
        .collect();
    for_cases(0x5EEDF, 4, |rng| {
        let mut entries: Vec<(LayerKey, f64)> = Vec::new();
        for (k, e) in &layer_e {
            match rng.below(4) {
                0 => {}
                1 => entries.push((*k, e * 1e-6)), // absurdly low: forces reruns
                2 => entries.push((*k, e * (0.5 + rng.below(150) as f64 / 100.0))),
                _ => entries.push((*k, e * 1e6)),
            }
        }
        let warm = SeedTable::from_entries(entries);
        let seeded =
            pareto_optimize_seeded(&net, &space, &Table3, &cfg, &ParetoConfig::default(), &warm);
        assert_eq!(seeded.frontier.len(), cold.frontier.len());
        for (a, b) in seeded.frontier.iter().zip(cold.frontier.iter()) {
            assert_eq!(a.index, b.index, "seeded-vs-cold: index differs");
            assert_point_eq("seeded-vs-cold", &a.result, &b.result);
        }
        assert!(
            seeded.stats.evaluated_full <= cold.stats.evaluated_full,
            "seeds must never add full evaluations"
        );
    });
}

#[test]
fn empty_space_yields_empty_frontier() {
    let mut space = small_space();
    space.rf1_sizes.clear();
    let res = pareto_optimize(
        &network("mlp-m", 16).unwrap(),
        &space,
        &Table3,
        &NetOptConfig::new(small_opts(), 2),
        &ParetoConfig::default(),
    );
    assert!(res.frontier.is_empty());
    assert_eq!(res.stats.generated, 0);
    assert!(PlanSelector::new(res.frontier).select(None).is_none());
}
