//! Shard-mergeable frontier checkpoints.
//!
//! A `pareto --shard I/N` worker persists its slice's **exact** local
//! frontier — full [`HierarchyResult`] payloads, not just the vectors —
//! as JSON, and [`merge_frontiers`] unions checkpoints back into the
//! global frontier. The union-then-refilter is associative and
//! commutative (the retained set is a pure function of the point set,
//! see `frontier`), and every per-point payload is evaluated identically
//! whether a shard or the single process visited it, so the merged
//! frontier is **bit-for-bit** the single-process
//! [`pareto_optimize`](super::pareto_optimize) frontier, point for
//! point. (Shard checkpoints tag points by *raw-grid* index, the single
//! process by filtered position — filtering preserves order, so the two
//! keys induce the same ranking and tie-breaks; the payloads are the
//! contract surface.) The argument that no global-frontier point can be
//! lost shard-locally:
//!
//! - a point is *pruned* inside a shard only when its admissible bound
//!   vector is strictly dominated by a completed point of that same
//!   shard — which then strictly dominates the point's final totals, so
//!   the point was never on the global frontier;
//! - a completed feasible point missing from its shard's local frontier
//!   is dominated (or index-tied) by another point of that shard, which
//!   dominates it globally too.
//!
//! Hence every global-frontier point survives in its own shard's
//! checkpoint, and the union filter removes exactly the shard-local
//! survivors that a point from another shard dominates.
//!
//! ## Checkpoint JSON format (v1)
//!
//! ```json
//! {
//!   "format": "interstellar-frontier-checkpoint-v1",
//!   "network": "mlp-m", "batch": 16,
//!   "nshards": 3, "shards": [0],
//!   "stats": { ...NetOptStats fields..., "engine": {...} },
//!   "seeds": [ {"bounds": [7 ints], "stride": 1, "energy_pj": 12.5}, ... ],
//!   "frontier": [ { "index": 17, "arch": {...}, "opt": {...} }, ... ]
//! }
//! ```
//!
//! `arch` / `opt` / `stats` / `seeds` reuse the shard-checkpoint v1
//! codecs (`netopt::shard`), so floats round-trip losslessly and the two
//! checkpoint families can never drift. Bump [`FRONTIER_FORMAT`] on any
//! incompatible change.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::netopt::shard::{
    arch_from_json, arch_to_json, merge_coverage, opt_from_json, opt_to_json, stats_from_json,
    stats_to_json, CoverageRelation,
};
use crate::netopt::{NetOptStats, SeedTable};
use crate::search::HierarchyResult;
use crate::util::json::Json;

use super::frontier::{Frontier, FrontierPoint};

/// Frontier-checkpoint schema identifier; readers reject anything else.
pub const FRONTIER_FORMAT: &str = "interstellar-frontier-checkpoint-v1";

/// Everything one `pareto --shard` worker (or a merge of workers) knows
/// about its slice of a frontier run: the exact local frontier with full
/// result payloads, the seeds table, and the stats roll-up.
#[derive(Debug, Clone, PartialEq)]
pub struct FrontierCheckpoint {
    /// Network name the run was over (merge identity guard).
    pub network: String,
    /// Batch size of the run (merge identity guard).
    pub batch: u64,
    /// Total shard count of the partition this checkpoint belongs to.
    pub nshards: usize,
    /// Shard indices covered (sorted; the union after merging — possibly
    /// re-expressed at a finer granularity when checkpoints with
    /// different shard counts merge). Duplicate coverage deduplicates
    /// under an identity check; partial overlap is an error (see
    /// `netopt::shard`'s module docs on shard composition).
    pub shards: Vec<usize>,
    /// Stats over the covered shards (space counters included).
    pub stats: NetOptStats,
    /// Best-known `(shape, stride) → energy` seeds.
    pub seeds: SeedTable,
    /// The covered shards' exact frontier: ascending energy, each entry
    /// `(global candidate index, full result)`.
    pub frontier: Vec<(usize, HierarchyResult)>,
}

impl FrontierCheckpoint {
    /// Serialize to the v1 frontier-checkpoint JSON (module docs).
    pub fn to_json(&self) -> String {
        let frontier = self
            .frontier
            .iter()
            .map(|(idx, r)| {
                Json::Obj(vec![
                    ("index".into(), Json::int(*idx as u64)),
                    ("arch".into(), arch_to_json(&r.arch)),
                    ("opt".into(), opt_to_json(&r.opt)),
                ])
            })
            .collect();
        Json::Obj(vec![
            ("format".into(), Json::str(FRONTIER_FORMAT)),
            ("network".into(), Json::str(&self.network)),
            ("batch".into(), Json::int(self.batch)),
            ("nshards".into(), Json::int(self.nshards as u64)),
            (
                "shards".into(),
                Json::Arr(self.shards.iter().map(|s| Json::int(*s as u64)).collect()),
            ),
            ("stats".into(), stats_to_json(&self.stats)),
            ("seeds".into(), self.seeds.to_json()),
            ("frontier".into(), Json::Arr(frontier)),
        ])
        .to_string()
    }

    /// Parse a v1 frontier-checkpoint JSON document.
    pub fn from_json(text: &str) -> Result<FrontierCheckpoint> {
        let v = Json::parse(text).map_err(|e| e.context("checkpoint is not valid JSON"))?;
        let format = v.field("format")?.as_str()?;
        if format != FRONTIER_FORMAT {
            bail!("unknown checkpoint format `{format}` (want `{FRONTIER_FORMAT}`)");
        }
        let mut frontier = Vec::new();
        for e in v.field("frontier")?.as_arr()? {
            frontier.push((
                e.field("index")?.as_usize()?,
                HierarchyResult {
                    arch: arch_from_json(e.field("arch")?)?,
                    opt: opt_from_json(e.field("opt")?)?,
                },
            ));
        }
        let mut shards = Vec::new();
        for s in v.field("shards")?.as_arr()? {
            shards.push(s.as_usize()?);
        }
        Ok(FrontierCheckpoint {
            network: v.field("network")?.as_str()?.to_string(),
            batch: v.field("batch")?.as_u64()?,
            nshards: v.field("nshards")?.as_usize()?,
            shards,
            stats: stats_from_json(v.field("stats")?)?,
            seeds: SeedTable::from_json(v.field("seeds")?)?,
            frontier,
        })
    }
}

/// Combine two frontier checkpoints of the same run: seeds min-merge,
/// the frontier is the dominance-filtered union (lowest index on equal
/// vectors), and stats add when the coverages are disjoint. Checkpoints
/// at different shard granularities merge through
/// `netopt::shard::merge_coverage`: nested (duplicate) coverage
/// deduplicates — the duplicate side's stats are dropped so no grid
/// point double-counts, and any index both frontiers carry must have
/// bit-equal totals (completed totals are deterministic per grid index,
/// whatever bounds were streamed in). Errors on mismatched run identity,
/// partially overlapping coverage, or a failed identity check.
pub fn merge_frontiers(
    a: &FrontierCheckpoint,
    b: &FrontierCheckpoint,
) -> Result<FrontierCheckpoint> {
    if a.network != b.network || a.batch != b.batch {
        bail!(
            "checkpoint mismatch: {}@{} vs {}@{}",
            a.network,
            a.batch,
            b.network,
            b.batch
        );
    }
    let cov = merge_coverage(&a.shards, a.nshards, &b.shards, b.nshards)?;

    let stats = match cov.relation {
        CoverageRelation::Disjoint => {
            let mut s = a.stats.clone();
            s.merge(&b.stats);
            s
        }
        CoverageRelation::AContainsB => a.stats.clone(),
        CoverageRelation::BContainsA => b.stats.clone(),
    };
    let mut seeds = a.seeds.clone();
    seeds.merge(&b.seeds);

    // Union + re-filter. Disjoint coverage means disjoint candidate
    // indices; duplicate coverage (a re-split straggler finishing after
    // its replacements, a speculative duplicate) may present the same
    // index twice — then both payloads must agree bit-for-bit, and the
    // archive's equal-vector dedup keeps exactly one.
    let mut by_idx: HashMap<usize, &HierarchyResult> = HashMap::new();
    let mut archive = Frontier::new();
    for (idx, r) in a.frontier.iter().chain(b.frontier.iter()) {
        if let Some(prev) = by_idx.insert(*idx, r) {
            if prev.opt.total_energy_pj.to_bits() != r.opt.total_energy_pj.to_bits()
                || prev.opt.total_cycles.to_bits() != r.opt.total_cycles.to_bits()
            {
                bail!(
                    "duplicate-coverage identity check failed: frontier payloads disagree at \
                     grid index {idx}"
                );
            }
        }
        archive.insert(FrontierPoint {
            index: *idx,
            energy_pj: r.opt.total_energy_pj,
            cycles: r.opt.total_cycles,
        });
    }
    let frontier = archive
        .points()
        .iter()
        .map(|p| (p.index, by_idx[&p.index].clone()))
        .collect();

    Ok(FrontierCheckpoint {
        network: a.network.clone(),
        batch: a.batch,
        nshards: cov.nshards,
        shards: cov.shards,
        stats,
        seeds,
        frontier,
    })
}

/// Merge a whole set of frontier checkpoints. Same-granularity disjoint
/// sets merge identically in any order (union + re-filter is a pure
/// function of the point set; every other field is an associative,
/// commutative fold). Mixed-granularity sets — re-split stolen shards,
/// speculative duplicates — are folded coarsest-first (ascending shard
/// count, then lowest shard index), so a duplicate checkpoint always
/// meets an accumulated coverage that contains it and deduplicates,
/// instead of tripping the partial-overlap error an unlucky fold order
/// could produce. Errors on an empty set.
pub fn merge_all_frontiers(ckpts: &[FrontierCheckpoint]) -> Result<FrontierCheckpoint> {
    if ckpts.is_empty() {
        bail!("no checkpoints to merge");
    }
    let mut order: Vec<&FrontierCheckpoint> = ckpts.iter().collect();
    order.sort_by_key(|c| (c.nshards, c.shards.first().copied().unwrap_or(0)));
    let mut acc = order[0].clone();
    for c in &order[1..] {
        acc = merge_frontiers(&acc, c)?;
    }
    Ok(acc)
}
