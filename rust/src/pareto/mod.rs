//! Multi-objective (energy × latency) co-optimization — the Pareto
//! frontier subsystem.
//!
//! Every earlier search path collapsed the design space to a single
//! `min_tops`-constrained scalar winner, hiding exactly the
//! energy-vs-throughput trade curve the paper's §6.3 iso-throughput
//! analysis sweeps across. This module makes the frontier the
//! first-class output, layered on the same machinery as everything else
//! (`engine::Engine` per-layer searches, `netopt`'s shared point
//! evaluator and sharded parallel evaluation — never the `xmodel` /
//! `search_hierarchy` shims):
//!
//! 1. **[`Frontier`]** — a dominance archive in `(energy, cycles)` with
//!    deterministic tie-breaking by candidate index, generalizing the
//!    scalar `Incumbent`. During a run it is shared across worker chunks
//!    through the `netopt::FrontierGate` hook: a point is abandoned only
//!    when its admissible lower-bound vector (spent prefix + energy and
//!    [`cycle_floor`](crate::engine::cycle_floor) suffixes) is strictly
//!    dominated, in both coordinates beyond the pruning slack, by a
//!    completed point.
//! 2. **[`pareto_optimize`]** — the frontier run over a
//!    [`DesignSpace`], reusing `run_points`' chunked parallel
//!    evaluation; [`pareto_optimize_arches`] takes explicit lists
//!    (serving candidates, grid-inexpressible points), and the `_seeded`
//!    variants warm-start from a [`SeedTable`] exactly like the scalar
//!    co-optimizer (hints only — the rerun fallback keeps every
//!    completed point's totals bit-exact).
//! 3. **[`FrontierCheckpoint`]** — per-shard JSON with an associative,
//!    commutative [`merge_frontiers`], so `pareto --shard I/N` workers
//!    merge bit-identically to the single-process frontier (see
//!    `checkpoint`'s module docs for the no-lost-point argument).
//! 4. **[`PlanSelector`]** — budget-aware selection for serving: the
//!    min-energy frontier point within a latency budget, which under an
//!    iso-throughput phrasing is exactly the scalar `co_optimize`
//!    winner.
//!
//! ## Exactness contract
//!
//! [`pareto_optimize`]'s frontier equals — as a set, bit for bit per
//! point — exhaustively evaluating the space and filtering dominated
//! points, while fully evaluating no more (and usually strictly fewer)
//! architecture points:
//!
//! - per-layer searches run with **no scalar network bound** (a
//!   high-energy point may be frontier-optimal in cycles), so every
//!   completed point's totals are bit-identical to the exhaustive
//!   evaluation; cross-architecture seeds remain as rerun-corrected
//!   hints that can only skip layer-search work;
//! - the vector prune only fires on strict both-coordinate dominance of
//!   an admissible bound, so a pruned point's final vector is strictly
//!   dominated — it was never on the frontier and can never win an
//!   equal-vector index tie;
//! - the reported frontier is rebuilt deterministically from the
//!   completed points, never read from the racy in-run archive, so
//!   thread timing can affect counters but never the result.
//!
//! Scout priming (`NetOptConfig::prime`, see [`crate::fastmap`])
//! composes with all of this: the heuristically best candidate is
//! evaluated first so the archive opens with a real completed point —
//! the heuristic is **never** inserted into the archive as a
//! pseudo-point (its cycles could strictly dominate, and thereby
//! wrongly prune, a true frontier point), it only chooses which
//! official evaluation runs first. The frontier is therefore
//! bit-identical with priming on or off.
//!
//! `pareto::tests` asserts the equivalence on small spaces ×
//! {alexnet head, lstm-m, mlp-m}; `benches/perf_pareto.rs` gates it in
//! CI together with the strict full-evaluation reduction and the
//! `min_tops` selection identity, emitting `BENCH_pareto.json`.

mod checkpoint;
mod frontier;
mod select;

pub use checkpoint::{merge_all_frontiers, merge_frontiers, FrontierCheckpoint, FRONTIER_FORMAT};
pub use frontier::{Frontier, FrontierPoint};
pub use select::PlanSelector;

use std::collections::HashMap;
use std::sync::Mutex;

use crate::arch::Arch;
use crate::energy::CostModel;
use crate::netopt::{run_points_gated, DesignSpace, NetOptConfig, NetOptStats, SeedTable};
use crate::nn::Network;
use crate::search::HierarchyResult;

/// Reporting-time frontier controls (the `--eps` / `--points` CLI
/// knobs). The pruning archive and every checkpoint stay **exact**
/// regardless — thinning only trims what is returned, so the merge and
/// equivalence contracts are untouched. `Default` reports the exact
/// frontier.
#[derive(Debug, Clone, Default)]
pub struct ParetoConfig {
    /// Epsilon-grid thinning: keep a point only when it improves cycles
    /// over the previously kept one by more than the factor `1 + eps`
    /// (see [`Frontier::thin`]). `0.0` keeps every frontier point.
    pub eps: f64,
    /// Cap on reported points (evenly spaced ranks, endpoints kept).
    pub max_points: Option<usize>,
}

/// One reported frontier point: the global candidate index (the
/// deterministic tie-break and checkpoint key) and the full result.
#[derive(Debug, Clone)]
pub struct FrontierEntry {
    /// Global candidate (raw-grid) index.
    pub index: usize,
    /// The architecture point and its per-layer optimization.
    pub result: HierarchyResult,
}

/// The outcome of a frontier run.
#[derive(Debug, Clone)]
pub struct ParetoResult {
    /// The (possibly thinned) frontier, ascending in energy.
    pub frontier: Vec<FrontierEntry>,
    /// Arch-point and engine counter roll-up (`pruned` counts points
    /// abandoned by the vector bound).
    pub stats: NetOptStats,
    /// Final best-known per-layer-shape energies — feed back into the
    /// `_seeded` variants to warm-start the next run.
    pub seeds: SeedTable,
}

/// The in-run dominance archive behind the `netopt::FrontierGate` hook:
/// pruning only — the reported frontier is rebuilt from the completed
/// points, so archive race timing can never change the result, only how
/// much work later points skip.
///
/// Public so the orchestrator's streaming workers (`crate::orchestrator`)
/// can share one archive with a live run: [`absorb`](Self::absorb) folds
/// completed points from *other* workers of the same global sweep into
/// the pruning archive, and [`snapshot`](Self::snapshot) reads the
/// current archive for publishing. Admissibility of a foreign point is
/// the same argument as a local completion: it is a real completed total
/// of the same run, so anything its vector strictly dominates (beyond
/// the pruning slack) is strictly dominated globally and was never on
/// the frontier — the merged frontier keeps its exact bits.
#[derive(Default)]
pub struct LiveFrontier(Mutex<Frontier>);

impl LiveFrontier {
    /// An empty archive.
    pub fn new() -> LiveFrontier {
        LiveFrontier::default()
    }

    /// Fold a completed point from another worker into the pruning
    /// archive (pruning-only: never reported, only used as a bound).
    pub fn absorb(&self, p: FrontierPoint) {
        self.0.lock().expect("pareto archive lock").insert(p);
    }

    /// The current archive contents, ascending in energy.
    pub fn snapshot(&self) -> Vec<FrontierPoint> {
        self.0.lock().expect("pareto archive lock").points().to_vec()
    }
}

impl crate::netopt::FrontierGate for LiveFrontier {
    fn dominated(&self, energy_lb_pj: f64, cycles_lb: f64) -> bool {
        self.0
            .lock()
            .expect("pareto archive lock")
            .dominates_bound(energy_lb_pj, cycles_lb)
    }

    fn observe(&self, index: usize, energy_pj: f64, cycles: f64) {
        self.0.lock().expect("pareto archive lock").insert(FrontierPoint {
            index,
            energy_pj,
            cycles,
        });
    }
}

/// Shared core: run indexed candidates under a dominance gate and
/// rebuild the exact frontier (full payloads) from the completed feasible
/// points.
fn pareto_points(
    net: &Network,
    cands: Vec<(usize, Arch)>,
    cost: &dyn CostModel,
    cfg: &NetOptConfig,
    warm: Option<&SeedTable>,
    gate: &LiveFrontier,
) -> (Vec<FrontierEntry>, NetOptStats, SeedTable) {
    let out = run_points_gated(net, cands, cost, cfg, warm, Some(gate), None);
    let mut archive = Frontier::new();
    for (idx, r) in &out.ranked {
        if r.opt.unmapped == 0 {
            archive.insert(FrontierPoint {
                index: *idx,
                energy_pj: r.opt.total_energy_pj,
                cycles: r.opt.total_cycles,
            });
        }
    }
    let mut by_idx: HashMap<usize, HierarchyResult> = out.ranked.into_iter().collect();
    let entries = archive
        .points()
        .iter()
        .map(|p| FrontierEntry {
            index: p.index,
            result: by_idx.remove(&p.index).expect("frontier point was ranked"),
        })
        .collect();
    (entries, out.stats, out.seeds)
}

/// Apply the reporting-time thinning knobs to an exact frontier.
fn thin_entries(entries: Vec<FrontierEntry>, pcfg: &ParetoConfig) -> Vec<FrontierEntry> {
    if pcfg.eps <= 0.0 && pcfg.max_points.is_none() {
        return entries;
    }
    let archive = Frontier::from_points(entries.iter().map(|e| FrontierPoint {
        index: e.index,
        energy_pj: e.result.opt.total_energy_pj,
        cycles: e.result.opt.total_cycles,
    }));
    let keep: std::collections::HashSet<usize> = archive
        .thin(pcfg.eps, pcfg.max_points)
        .points()
        .iter()
        .map(|p| p.index)
        .collect();
    entries.into_iter().filter(|e| keep.contains(&e.index)).collect()
}

/// Compute the exact `(energy, cycles)` frontier of a design space:
/// every architecture point is evaluated through the shared netopt point
/// evaluator under the dominance bound, and the surviving fully-mapped,
/// throughput-passing points are dominance-filtered. See the module docs
/// for the exactness contract.
pub fn pareto_optimize(
    net: &Network,
    space: &DesignSpace,
    cost: &dyn CostModel,
    cfg: &NetOptConfig,
    pcfg: &ParetoConfig,
) -> ParetoResult {
    pareto_optimize_seeded(net, space, cost, cfg, pcfg, &SeedTable::new())
}

/// [`pareto_optimize`] warm-started from a [`SeedTable`] — seeds are
/// rerun-corrected hints, so the frontier is bit-identical to the cold
/// run with at most as much layer-search work.
pub fn pareto_optimize_seeded(
    net: &Network,
    space: &DesignSpace,
    cost: &dyn CostModel,
    cfg: &NetOptConfig,
    pcfg: &ParetoConfig,
    warm: &SeedTable,
) -> ParetoResult {
    let enumeration = space.enumerate();
    let cands: Vec<(usize, Arch)> = enumeration.candidates.into_iter().enumerate().collect();
    let (entries, mut stats, seeds) =
        pareto_points(net, cands, cost, cfg, Some(warm), &LiveFrontier::new());
    stats.generated = enumeration.generated;
    stats.budget_filtered = enumeration.budget_filtered;
    stats.ratio_filtered = enumeration.ratio_filtered;
    ParetoResult {
        frontier: thin_entries(entries, pcfg),
        stats,
        seeds,
    }
}

/// [`pareto_optimize`] over an explicit architecture list — the serving
/// entry point (remap candidates, grid-inexpressible points). The list
/// is the whole "space": `generated == candidates == arches.len()`.
pub fn pareto_optimize_arches(
    net: &Network,
    arches: &[Arch],
    cost: &dyn CostModel,
    cfg: &NetOptConfig,
    pcfg: &ParetoConfig,
) -> ParetoResult {
    pareto_optimize_arches_seeded(net, arches, cost, cfg, pcfg, &SeedTable::new())
}

/// [`pareto_optimize_arches`] warm-started from a [`SeedTable`].
pub fn pareto_optimize_arches_seeded(
    net: &Network,
    arches: &[Arch],
    cost: &dyn CostModel,
    cfg: &NetOptConfig,
    pcfg: &ParetoConfig,
    warm: &SeedTable,
) -> ParetoResult {
    let cands: Vec<(usize, Arch)> = arches.iter().cloned().enumerate().collect();
    let (entries, mut stats, seeds) =
        pareto_points(net, cands, cost, cfg, Some(warm), &LiveFrontier::new());
    stats.generated = arches.len();
    ParetoResult {
        frontier: thin_entries(entries, pcfg),
        stats,
        seeds,
    }
}

/// Run shard `index` of `nshards` of a frontier computation — the worker
/// body behind `pareto --shard I/N`. The checkpoint's frontier is always
/// exact (thinning is a reporting concern); identical configuration
/// across workers is the caller's contract, and the merge re-checks the
/// cheap identity fields.
pub fn pareto_optimize_shard(
    net: &Network,
    space: &DesignSpace,
    cost: &dyn CostModel,
    cfg: &NetOptConfig,
    index: usize,
    nshards: usize,
) -> FrontierCheckpoint {
    pareto_optimize_shard_with(net, space, cost, cfg, index, nshards, &LiveFrontier::new())
}

/// [`pareto_optimize_shard`] sharing an externally owned [`LiveFrontier`]
/// — the orchestrator's frontier-streaming hook. Foreign completed
/// points absorbed into `live` before or during the run are admissible
/// dominance bounds (see [`LiveFrontier`]), so the *merged* global
/// frontier keeps its exact bits; the local checkpoint may legitimately
/// omit locally-surviving points that a foreign point dominates — the
/// union re-filter would have removed them anyway.
pub fn pareto_optimize_shard_with(
    net: &Network,
    space: &DesignSpace,
    cost: &dyn CostModel,
    cfg: &NetOptConfig,
    index: usize,
    nshards: usize,
    live: &LiveFrontier,
) -> FrontierCheckpoint {
    let se = space.shard(index, nshards);
    let (entries, mut stats, seeds) = pareto_points(net, se.candidates, cost, cfg, None, live);
    stats.generated = se.generated;
    stats.budget_filtered = se.budget_filtered;
    stats.ratio_filtered = se.ratio_filtered;
    FrontierCheckpoint {
        network: net.name.clone(),
        batch: net.batch,
        nshards,
        shards: vec![index],
        stats,
        seeds,
        frontier: entries.into_iter().map(|e| (e.index, e.result)).collect(),
    }
}

/// In-process sharded frontier computation: run every shard (archives
/// are deliberately **not** shared across shards, mirroring the
/// process-isolated deployment), merge the checkpoints, and return the
/// global [`ParetoResult`]. With `nshards == 1` this is
/// [`pareto_optimize`] with shard bookkeeping.
pub fn pareto_optimize_sharded(
    net: &Network,
    space: &DesignSpace,
    cost: &dyn CostModel,
    cfg: &NetOptConfig,
    pcfg: &ParetoConfig,
    nshards: usize,
) -> ParetoResult {
    assert!(nshards >= 1, "need at least one shard");
    let ckpts: Vec<FrontierCheckpoint> = (0..nshards)
        .map(|i| pareto_optimize_shard(net, space, cost, cfg, i, nshards))
        .collect();
    let merged = merge_all_frontiers(&ckpts).expect("same-run shard checkpoints must merge");
    let entries = merged
        .frontier
        .into_iter()
        .map(|(index, result)| FrontierEntry { index, result })
        .collect();
    ParetoResult {
        frontier: thin_entries(entries, pcfg),
        stats: merged.stats,
        seeds: merged.seeds,
    }
}

#[cfg(test)]
mod tests;
