//! Budget-aware plan selection over a computed frontier — the piece
//! serving consults (`serve --latency-budget`, `coordinator::remap`).
//!
//! A frontier answers "what does each unit of latency buy in energy?";
//! the selector turns that into a decision: the **min-energy point whose
//! cycles fit a latency budget**. Because a frontier is ascending in
//! energy and descending in cycles, that is simply the first entry (in
//! frontier order) meeting the constraint — and, by the dominance
//! argument in `pareto`'s module docs, it is exactly the point the
//! scalar `min_tops`-constrained [`co_optimize`](crate::netopt) winner
//! collapses to when the budget is phrased as a throughput floor.

use crate::search::HierarchyResult;

use super::FrontierEntry;

/// Selects serving plans from a frontier. Entries are held in frontier
/// order (ascending energy, descending cycles); construction re-sorts
/// defensively so a caller-assembled list behaves identically.
#[derive(Debug, Clone, Default)]
pub struct PlanSelector {
    entries: Vec<FrontierEntry>,
}

impl PlanSelector {
    /// A selector over frontier entries.
    pub fn new(mut entries: Vec<FrontierEntry>) -> PlanSelector {
        entries.sort_by(|a, b| {
            a.result
                .opt
                .total_energy_pj
                .partial_cmp(&b.result.opt.total_energy_pj)
                .expect("frontier energies are finite")
                .then(a.index.cmp(&b.index))
        });
        PlanSelector { entries }
    }

    /// The min-energy entry whose total cycles fit `budget_cycles`
    /// (`None` budget = unconstrained, i.e. the min-energy point).
    /// Returns `None` when no frontier point meets the budget — callers
    /// keep their current plan. For mix-weighted frontiers (serving),
    /// `total_cycles` is the weighted sum over the mix window, so the
    /// budget reads as "cycles to serve one full window".
    pub fn select(&self, budget_cycles: Option<f64>) -> Option<&FrontierEntry> {
        match budget_cycles {
            None => self.entries.first(),
            Some(b) => self.entries.iter().find(|e| e.result.opt.total_cycles <= b),
        }
    }

    /// The min-energy entry achieving at least `min_tops` at `clock_ghz`
    /// — the iso-throughput phrasing of [`select`](Self::select) (total
    /// MACs are architecture-independent, so a TOPS floor *is* a cycle
    /// budget). Matches the scalar `co_optimize` winner under the same
    /// `min_tops`, bit for bit (asserted by `benches/perf_pareto.rs`).
    pub fn select_min_tops(&self, min_tops: f64, clock_ghz: f64) -> Option<&FrontierEntry> {
        self.entries
            .iter()
            .find(|e| e.result.opt.tops(clock_ghz) >= min_tops)
    }

    /// Convenience: the selected winning result under a cycle budget.
    pub fn select_result(&self, budget_cycles: Option<f64>) -> Option<&HierarchyResult> {
        self.select(budget_cycles).map(|e| &e.result)
    }

    /// The entries in frontier order.
    pub fn entries(&self) -> &[FrontierEntry] {
        &self.entries
    }

    /// Number of frontier points available to select from.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when the frontier was empty (no feasible point).
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}
