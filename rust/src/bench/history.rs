//! The append-only perf-trajectory history: every perf gate appends one
//! schema-versioned record per run into `bench_history.jsonl`, so the
//! `BENCH_*.json` snapshots that used to be validated and thrown away
//! accumulate into a trend line (`bench-report` renders and gates it).
//!
//! Records reuse the torn-write-safe framing of
//! [`crate::orchestrator::bounds`]: each append is a single `O_APPEND`
//! `write_all` of `\n{record}\n`, so a writer SIGKILLed mid-append can
//! glue at most one unparseable fragment onto the file, the leading
//! newline isolates the *next* record from that fragment, and readers
//! skip blank or unparseable lines — a torn tail can never poison the
//! records that follow it. Records carry a `v` field
//! ([`HISTORY_VERSION`]); foreign versions are skipped on read so a
//! future schema bump does not invalidate old files.
//!
//! Serialization is the hand-rolled [`crate::util::json`] codec (no new
//! deps); the record layout is documented in `BENCHMARKS.md`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Schema version stamped into every record's `v` field. Readers skip
/// records from other versions instead of erroring, so history files
/// survive schema evolution.
pub const HISTORY_VERSION: u64 = 1;

/// Default history location, relative to the process cwd (the workspace
/// root under `cargo bench` and `./ci.sh`).
pub const DEFAULT_HISTORY_PATH: &str = "bench_history.jsonl";

/// One perf-gate run: the flat `BENCH_*.json` fields split into numeric
/// metrics (trended and regression-gated by `bench-report`) and string
/// labels (carried for context — winner names, fixture labels), stamped
/// with the producing git revision and a unix timestamp supplied by the
/// harness (`ci.sh` exports both; see [`git_rev`] / [`unix_ts`] for the
/// fallbacks).
#[derive(Debug, Clone, PartialEq)]
pub struct HistoryRecord {
    /// Emitting gate, e.g. `perf_search` (matches the `bench` field of
    /// the corresponding `BENCH_*.json`).
    pub bench: String,
    /// Git revision the metrics were measured at.
    pub git_rev: String,
    /// Seconds since the unix epoch, from the harness.
    pub unix_ts: u64,
    /// Metric slug → finite value, in emission order.
    pub metrics: Vec<(String, f64)>,
    /// Label slug → string (bools are stored as `"true"`/`"false"`).
    pub labels: Vec<(String, String)>,
}

impl HistoryRecord {
    /// Serialize to the on-disk JSON layout (one line of the history).
    pub fn to_json(&self) -> Json {
        let metrics = self
            .metrics
            .iter()
            .map(|(k, v)| (k.clone(), Json::num(*v)))
            .collect();
        let labels = self
            .labels
            .iter()
            .map(|(k, v)| (k.clone(), Json::str(v.as_str())))
            .collect();
        Json::Obj(vec![
            ("v".into(), Json::int(HISTORY_VERSION)),
            ("bench".into(), Json::str(self.bench.as_str())),
            ("git_rev".into(), Json::str(self.git_rev.as_str())),
            ("unix_ts".into(), Json::int(self.unix_ts)),
            ("metrics".into(), Json::Obj(metrics)),
            ("labels".into(), Json::Obj(labels)),
        ])
    }

    /// Parse a record, rejecting foreign versions and any metric that is
    /// not a finite number (the flat-scalar discipline of
    /// [`crate::util::bench::validate_bench_json`] carried into the
    /// history).
    pub fn from_json(v: &Json) -> Result<HistoryRecord> {
        let ver = v.field("v")?.as_u64()?;
        if ver != HISTORY_VERSION {
            bail!("history record version {ver} (this build reads v{HISTORY_VERSION})");
        }
        let bench = v.field("bench")?.as_str()?.to_string();
        if bench.is_empty() {
            bail!("history record has an empty `bench` name");
        }
        let git_rev = v.field("git_rev")?.as_str()?.to_string();
        let unix_ts = v.field("unix_ts")?.as_u64()?;
        let mut metrics = Vec::new();
        for (k, m) in v.field("metrics")?.as_obj()? {
            let x = m
                .as_f64()
                .map_err(|e| e.context(format!("metric `{k}` must be a number")))?;
            if !x.is_finite() {
                bail!("metric `{k}` is not finite");
            }
            metrics.push((k.clone(), x));
        }
        let mut labels = Vec::new();
        for (k, l) in v.field("labels")?.as_obj()? {
            let s = l
                .as_str()
                .map_err(|e| e.context(format!("label `{k}` must be a string")))?;
            labels.push((k.clone(), s.to_string()));
        }
        Ok(HistoryRecord {
            bench,
            git_rev,
            unix_ts,
            metrics,
            labels,
        })
    }

    /// Build a record from the flat `BENCH_*.json` field list a perf
    /// gate emits: the `bench` string names the record, finite numbers
    /// become metrics, strings and bools become labels; anything else
    /// (nested values, non-finite numbers) is a producer bug.
    pub fn from_bench_fields(
        fields: &[(String, Json)],
        git_rev: String,
        unix_ts: u64,
    ) -> Result<HistoryRecord> {
        let mut bench = String::new();
        let mut metrics = Vec::new();
        let mut labels = Vec::new();
        for (k, v) in fields {
            match (k.as_str(), v) {
                ("bench", Json::Str(s)) => bench = s.clone(),
                (_, Json::Num(x)) if x.is_finite() => metrics.push((k.clone(), *x)),
                (_, Json::Str(s)) => labels.push((k.clone(), s.clone())),
                (_, Json::Bool(b)) => labels.push((k.clone(), b.to_string())),
                (_, other) => bail!("bench field `{k}` is not a flat scalar: {other:?}"),
            }
        }
        if bench.is_empty() {
            bail!("bench fields are missing a non-empty `bench` name");
        }
        Ok(HistoryRecord {
            bench,
            git_rev,
            unix_ts,
            metrics,
            labels,
        })
    }
}

/// Append one record with the bounds-file framing: leading newline (so a
/// predecessor killed mid-append cannot glue its torn tail onto this
/// record), one `O_APPEND` `write_all` (so this record itself lands
/// atomically or not at all).
pub fn append_record(path: &Path, rec: &HistoryRecord) -> Result<()> {
    crate::orchestrator::append_framed(path, &rec.to_json())
        .with_context(|| format!("append history record to {}", path.display()))
}

/// A parsed history file: valid records in append (= time) order, plus
/// the count of lines that were skipped (torn tails, foreign versions,
/// malformed records — the forgiving-reader contract).
#[derive(Debug, Default)]
pub struct History {
    /// Valid records, oldest first.
    pub records: Vec<HistoryRecord>,
    /// Lines that did not parse as v1 records and were skipped.
    pub skipped: usize,
}

/// Read a history file. A missing file is an empty history, not an
/// error (the first CI run starts from nothing); any unusable line is
/// counted in [`History::skipped`] and otherwise ignored.
pub fn read_history(path: &Path) -> History {
    let mut h = History::default();
    let Ok(text) = std::fs::read_to_string(path) else {
        return h;
    };
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match parse_history_line(line) {
            Ok(Some(rec)) => h.records.push(rec),
            Ok(None) | Err(_) => h.skipped += 1,
        }
    }
    h
}

/// Line-level validation, shared with the `bench_schema` CI gate:
/// `Ok(Some)` is a valid record, `Ok(None)` is a line that is not JSON
/// at all (a torn tail — tolerated everywhere), `Err` is well-formed
/// JSON that violates the record schema (a real producer bug; the gate
/// fails on it, while [`read_history`] just skips it).
pub fn parse_history_line(line: &str) -> std::result::Result<Option<HistoryRecord>, String> {
    let Ok(v) = Json::parse(line) else {
        return Ok(None);
    };
    HistoryRecord::from_json(&v).map(Some).map_err(|e| e.to_string())
}

/// History destination: `INTERSTELLAR_BENCH_HISTORY` overrides the
/// default [`DEFAULT_HISTORY_PATH`]; setting it to `off`, `0`, or the
/// empty string disables history appends entirely (`None`).
pub fn history_path() -> Option<PathBuf> {
    match std::env::var("INTERSTELLAR_BENCH_HISTORY") {
        Ok(v) if v.is_empty() || v == "off" || v == "0" => None,
        Ok(v) => Some(PathBuf::from(v)),
        Err(_) => Some(PathBuf::from(DEFAULT_HISTORY_PATH)),
    }
}

/// Revision stamp for new records: `INTERSTELLAR_BENCH_GIT_REV` if the
/// harness exported it (`ci.sh` does), else `git rev-parse --short
/// HEAD`, else `"unknown"` — the history must keep appending even
/// outside a checkout.
pub fn git_rev() -> String {
    if let Ok(v) = std::env::var("INTERSTELLAR_BENCH_GIT_REV") {
        if !v.is_empty() {
            return v;
        }
    }
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".into())
}

/// Timestamp for new records: `INTERSTELLAR_BENCH_UNIX_TS` if the
/// harness exported one (keeps a whole CI run on one stamp), else the
/// system clock.
pub fn unix_ts() -> u64 {
    if let Ok(v) = std::env::var("INTERSTELLAR_BENCH_UNIX_TS") {
        if let Ok(t) = v.parse() {
            return t;
        }
    }
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs::OpenOptions;
    use std::io::Write;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "interstellar-history-{}-{name}.jsonl",
            std::process::id()
        ))
    }

    fn sample(bench: &str, ts: u64, v: f64) -> HistoryRecord {
        HistoryRecord {
            bench: bench.into(),
            git_rev: format!("rev{ts}"),
            unix_ts: ts,
            metrics: vec![("probe_mean_ns".into(), v), ("count".into(), ts as f64)],
            labels: vec![("winner".into(), "rf64".into()), ("ok".into(), "true".into())],
        }
    }

    #[test]
    fn append_read_round_trip() {
        let path = tmp("roundtrip");
        let _ = std::fs::remove_file(&path);
        let recs = vec![sample("perf_a", 1, 10.5), sample("perf_b", 2, 20.25)];
        for r in &recs {
            append_record(&path, r).unwrap();
        }
        let h = read_history(&path);
        assert_eq!(h.skipped, 0);
        assert_eq!(h.records, recs);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn torn_tail_does_not_poison_later_records() {
        let path = tmp("torn");
        let _ = std::fs::remove_file(&path);
        append_record(&path, &sample("perf_a", 1, 10.0)).unwrap();
        // simulate a writer SIGKILLed mid-append: an unterminated
        // fragment with no trailing newline
        {
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(b"\n{\"v\":1,\"bench\":\"per").unwrap();
        }
        // the next writer's leading newline isolates its record
        append_record(&path, &sample("perf_b", 2, 20.0)).unwrap();
        let h = read_history(&path);
        assert_eq!(h.skipped, 1, "exactly the torn fragment is skipped");
        assert_eq!(h.records.len(), 2);
        assert_eq!(h.records[0].bench, "perf_a");
        assert_eq!(h.records[1].bench, "perf_b");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn foreign_versions_and_schema_violations_are_skipped_on_read() {
        let path = tmp("foreign");
        let _ = std::fs::remove_file(&path);
        std::fs::write(
            &path,
            "{\"v\":99,\"bench\":\"future\",\"git_rev\":\"r\",\"unix_ts\":1,\
             \"metrics\":{},\"labels\":{}}\n\
             {\"v\":1,\"bench\":\"\",\"git_rev\":\"r\",\"unix_ts\":1,\
             \"metrics\":{},\"labels\":{}}\n",
        )
        .unwrap();
        append_record(&path, &sample("perf_a", 3, 30.0)).unwrap();
        let h = read_history(&path);
        assert_eq!(h.skipped, 2, "foreign version + empty bench both skipped");
        assert_eq!(h.records.len(), 1);
        assert_eq!(h.records[0].bench, "perf_a");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn parse_history_line_distinguishes_torn_from_invalid() {
        // not JSON at all: a torn tail, tolerated
        assert_eq!(parse_history_line("{\"v\":1,\"ben").unwrap(), None);
        // well-formed JSON violating the schema: a producer bug
        assert!(parse_history_line("{\"v\":1,\"bench\":\"x\"}").is_err());
        let ok = parse_history_line(&sample("perf_a", 1, 1.0).to_json().to_string());
        assert!(matches!(ok, Ok(Some(_))));
    }

    #[test]
    fn from_bench_fields_splits_metrics_and_labels() {
        let fields = vec![
            ("bench".to_string(), Json::str("perf_x")),
            ("mean_ns".to_string(), Json::num(12.5)),
            ("winner".to_string(), Json::str("rf64")),
            ("identical".to_string(), Json::Bool(true)),
        ];
        let rec = HistoryRecord::from_bench_fields(&fields, "abc".into(), 7).unwrap();
        assert_eq!(rec.bench, "perf_x");
        assert_eq!(rec.metrics, vec![("mean_ns".to_string(), 12.5)]);
        assert_eq!(
            rec.labels,
            vec![
                ("winner".to_string(), "rf64".to_string()),
                ("identical".to_string(), "true".to_string())
            ]
        );
        // nested values are producer bugs, not silently dropped
        let bad = vec![
            ("bench".to_string(), Json::str("perf_x")),
            ("xs".to_string(), Json::Arr(vec![Json::int(1)])),
        ];
        assert!(HistoryRecord::from_bench_fields(&bad, "abc".into(), 7).is_err());
    }

    #[test]
    fn missing_file_is_an_empty_history() {
        let h = read_history(Path::new("/nonexistent/interstellar-history.jsonl"));
        assert!(h.records.is_empty());
        assert_eq!(h.skipped, 0);
    }
}
