//! Measurement backbone: the perf-trajectory database behind every
//! `BENCH_*.json` gate and the reporting/regression views over it.
//!
//! The eight perf gates (`perf_search` … `perf_hotpath`) used to write
//! per-run `BENCH_*.json` snapshots that `bench_schema` validated and CI
//! threw away — no trend line existed. This module gives each run a
//! durable row:
//!
//! - [`history`] — the append-only `bench_history.jsonl` store:
//!   schema-versioned records (git rev, harness timestamp, metric and
//!   label slugs) appended with the torn-write-safe framing of
//!   [`crate::orchestrator::bounds`] and read forgivingly.
//! - [`report`] — per-`(bench, metric)` trajectory series, the robust
//!   median/MAD regression rule, and the [`Table`]-rendered trajectory
//!   view the `bench-report` CLI (and its `--check` CI gate) prints.
//! - [`emit`] — the one-call emitter every perf bench uses: validate
//!   the flat-scalar fields, write `BENCH_<name>.json`, append the
//!   history record.
//!
//! `BENCHMARKS.md` documents the schemas and the regression rule;
//! ARCHITECTURE.md ("Measurement backbone") covers the design.
//!
//! [`Table`]: crate::util::table::Table

pub mod history;
pub mod report;

pub use history::{
    append_record, git_rev, history_path, parse_history_line, read_history, unix_ts, History,
    HistoryRecord, DEFAULT_HISTORY_PATH, HISTORY_VERSION,
};
pub use report::{
    assess, direction, regressions, trajectory, trajectory_table, Direction, TrajectoryRow,
    Verdict, MAD_SIGMAS, MIN_BASELINE, REL_FLOOR,
};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Slug a free-form case name into a JSON-key-friendly metric name:
/// every non-alphanumeric byte becomes `_` (so `perf/optimize conv3`
/// → `perf_optimize_conv3`). Shared by the bench emitters so slugs stay
/// stable across gates.
pub fn slug(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect()
}

/// Emit one perf gate's trajectory: validate `fields` against the
/// flat-scalar `BENCH_*.json` schema
/// ([`crate::util::bench::validate_bench_json`]), write
/// `BENCH_<name>.json` in the cwd (the `bench` field `perf_<name>`
/// names the file), and append a [`HistoryRecord`] to the perf history
/// (skipped when `INTERSTELLAR_BENCH_HISTORY=off`; see
/// [`history::history_path`]).
pub fn emit(fields: Vec<(String, Json)>) -> Result<()> {
    let doc = Json::Obj(fields);
    let text = doc.to_string();
    crate::util::bench::validate_bench_json(&text)
        .map_err(|e| anyhow!("BENCH fields violate the flat-scalar schema: {e}"))?;
    let Json::Obj(fields) = &doc else {
        unreachable!("constructed as an object above")
    };
    let bench = fields
        .iter()
        .find_map(|(k, v)| match (k.as_str(), v) {
            ("bench", Json::Str(s)) => Some(s.clone()),
            _ => None,
        })
        .expect("validated to contain a bench string above");
    let name = bench.strip_prefix("perf_").unwrap_or(&bench);
    let path = format!("BENCH_{name}.json");
    std::fs::write(&path, &text).with_context(|| format!("write {path}"))?;
    println!("wrote {path}");
    if let Some(hpath) = history::history_path() {
        let rec = HistoryRecord::from_bench_fields(fields, history::git_rev(), history::unix_ts())?;
        append_record(&hpath, &rec)?;
        println!("appended {bench} perf-trajectory record to {}", hpath.display());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slug_is_json_key_friendly() {
        assert_eq!(
            slug("perf/optimize_layer conv3 (1 thread)"),
            "perf_optimize_layer_conv3__1_thread_"
        );
        assert_eq!(slug("CONV1"), "CONV1");
    }

    #[test]
    fn emit_rejects_schema_violations_before_writing() {
        // no `bench` field — must fail without touching the filesystem
        let fields = vec![("n".to_string(), Json::int(3))];
        assert!(emit(fields).is_err());
        // nested field — same
        let fields = vec![
            ("bench".to_string(), Json::str("perf_nonexistent_gate")),
            ("xs".to_string(), Json::Arr(vec![Json::int(1)])),
        ];
        assert!(emit(fields).is_err());
    }
}
