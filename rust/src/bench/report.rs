//! Trajectory views over the perf history and the regression rule the
//! `bench-report --check` CI gate enforces.
//!
//! Each `(bench, metric)` pair forms a series in append order. The
//! latest sample is judged against the *historical distribution* of the
//! prior samples, not a fixed threshold: with baseline median `m` and
//! scaled MAD `s` (median absolute deviation × 1.4826, a robust stddev
//! estimate that one past outlier cannot inflate), the sample regresses
//! when it moves in the metric's bad direction by more than
//! `max(MAD_SIGMAS · s, REL_FLOOR · |m|)`. The relative floor keeps
//! near-constant series (MAD ≈ 0) from flagging on timer jitter; the
//! MAD term adapts the band to each metric's real run-to-run noise.
//!
//! Guard rails: fewer than [`MIN_BASELINE`] prior samples is
//! [`Verdict::Insufficient`] (a fresh history bootstraps instead of
//! failing CI), metrics with no better/worse direction (counters,
//! frontier sizes) are [`Verdict::Informational`], and a series whose
//! metric stopped being emitted is [`Verdict::Stale`] — only the
//! metrics present in a bench's newest record gate the build.

use std::collections::BTreeMap;

use crate::util::table::Table;
use crate::util::{fmt_sig, stats};

use super::history::History;

/// Prior samples required before a series is gated at all.
pub const MIN_BASELINE: usize = 4;

/// Width of the dispersion band, in scaled-MAD units.
pub const MAD_SIGMAS: f64 = 4.0;

/// Relative noise floor: a sample within this fraction of the baseline
/// median never flags, however tight the historical spread.
pub const REL_FLOOR: f64 = 0.25;

/// MAD → stddev scale under normality.
const MAD_SCALE: f64 = 1.4826;

/// Whether a metric improves by going down, up, or is not a quality
/// signal at all.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Times and energies: `_ns` / `_us` / `_ms` / `_pj` / `_pct`.
    LowerIsBetter,
    /// Speedup ratios.
    HigherIsBetter,
    /// Counters, sizes, identifiers — trended but never gated (their
    /// contracts are asserted per-run by the perf gates themselves).
    Informational,
}

/// Classify a metric slug by suffix convention (documented in
/// BENCHMARKS.md; emitters opt into gating by naming metrics
/// accordingly).
pub fn direction(metric: &str) -> Direction {
    const LOWER: &[&str] = &["_ns", "_us", "_ms", "_pj", "_pct"];
    if LOWER.iter().any(|s| metric.ends_with(s)) {
        Direction::LowerIsBetter
    } else if metric.contains("speedup") {
        Direction::HigherIsBetter
    } else {
        Direction::Informational
    }
}

/// Outcome of judging one series' latest sample.
#[derive(Debug, Clone, PartialEq)]
pub enum Verdict {
    /// Gated and inside the historical band.
    Ok,
    /// Gated and outside the band in the bad direction.
    Regressed {
        /// Median of the prior samples the latest was judged against.
        baseline_median: f64,
        /// Allowed deviation in the bad direction.
        threshold: f64,
    },
    /// Fewer than [`MIN_BASELINE`] prior samples — building a baseline.
    Insufficient,
    /// Metric has no better/worse direction; never gated.
    Informational,
    /// Metric absent from the bench's newest record (renamed or
    /// dropped); its old samples no longer gate anything.
    Stale,
}

impl Verdict {
    /// Short cell text for the trajectory table.
    pub fn label(&self) -> String {
        match self {
            Verdict::Ok => "ok".into(),
            Verdict::Regressed { .. } => "REGRESSED".into(),
            Verdict::Insufficient => format!("baseline<{MIN_BASELINE}"),
            Verdict::Informational => "info".into(),
            Verdict::Stale => "stale".into(),
        }
    }
}

fn median(xs: &[f64]) -> f64 {
    stats::percentile(xs, 50.0)
}

/// Scaled median absolute deviation around `med`.
fn scaled_mad(xs: &[f64], med: f64) -> f64 {
    let dev: Vec<f64> = xs.iter().map(|x| (x - med).abs()).collect();
    MAD_SCALE * median(&dev)
}

/// Judge `latest` against the `prior` samples of a series (the
/// regression rule in the module docs).
pub fn assess(prior: &[f64], latest: f64, dir: Direction) -> Verdict {
    if dir == Direction::Informational {
        return Verdict::Informational;
    }
    if prior.len() < MIN_BASELINE {
        return Verdict::Insufficient;
    }
    let med = median(prior);
    let threshold = (MAD_SIGMAS * scaled_mad(prior, med)).max(REL_FLOOR * med.abs());
    let delta = match dir {
        Direction::LowerIsBetter => latest - med,
        Direction::HigherIsBetter => med - latest,
        Direction::Informational => unreachable!("handled above"),
    };
    if delta > threshold {
        Verdict::Regressed {
            baseline_median: med,
            threshold,
        }
    } else {
        Verdict::Ok
    }
}

/// One `(bench, metric)` series summarized for the trajectory table.
#[derive(Debug, Clone)]
pub struct TrajectoryRow {
    /// Emitting gate.
    pub bench: String,
    /// Metric slug.
    pub metric: String,
    /// Total samples, including the latest.
    pub samples: usize,
    /// Baseline median (prior samples; the latest value itself when the
    /// series has a single sample).
    pub median: f64,
    /// Minimum over the whole series.
    pub min: f64,
    /// Maximum over the whole series.
    pub max: f64,
    /// Scaled MAD of the prior samples (the dispersion band half-width
    /// before the [`MAD_SIGMAS`] multiplier).
    pub dispersion: f64,
    /// Newest sample.
    pub latest: f64,
    /// Git revision that produced the newest sample.
    pub latest_rev: String,
    /// Gating direction of the metric.
    pub direction: Direction,
    /// The judgement on the newest sample.
    pub verdict: Verdict,
}

/// Build the per-series trajectory rows from a parsed history, applying
/// the regression rule to each series whose latest sample comes from
/// its bench's newest record (older series go [`Verdict::Stale`]).
pub fn trajectory(h: &History) -> Vec<TrajectoryRow> {
    let mut series: BTreeMap<(&str, &str), Vec<(usize, f64)>> = BTreeMap::new();
    let mut newest_record: BTreeMap<&str, usize> = BTreeMap::new();
    for (i, r) in h.records.iter().enumerate() {
        newest_record.insert(r.bench.as_str(), i);
        for (m, x) in &r.metrics {
            series
                .entry((r.bench.as_str(), m.as_str()))
                .or_default()
                .push((i, *x));
        }
    }
    let mut rows = Vec::new();
    for ((bench, metric), samples) in &series {
        let values: Vec<f64> = samples.iter().map(|&(_, x)| x).collect();
        let (&(last_idx, latest), prior_samples) =
            samples.split_last().expect("series are never empty");
        let prior: Vec<f64> = prior_samples.iter().map(|&(_, x)| x).collect();
        let dir = direction(metric);
        let verdict = if newest_record.get(bench) != Some(&last_idx) {
            Verdict::Stale
        } else {
            assess(&prior, latest, dir)
        };
        let (med, dispersion) = if prior.is_empty() {
            (latest, 0.0)
        } else {
            let m = median(&prior);
            (m, scaled_mad(&prior, m))
        };
        rows.push(TrajectoryRow {
            bench: bench.to_string(),
            metric: metric.to_string(),
            samples: values.len(),
            median: med,
            min: stats::min(&values),
            max: stats::max(&values),
            dispersion,
            latest,
            latest_rev: h.records[last_idx].git_rev.clone(),
            direction: dir,
            verdict,
        });
    }
    rows
}

/// The rows currently flagged as regressions.
pub fn regressions(rows: &[TrajectoryRow]) -> Vec<&TrajectoryRow> {
    rows.iter()
        .filter(|r| matches!(r.verdict, Verdict::Regressed { .. }))
        .collect()
}

/// Render trajectory rows as a table (text/markdown/CSV via
/// [`Table`]): baseline median, whole-series min/max, the scaled-MAD
/// dispersion band, the newest sample and its signed drift from the
/// baseline, and the verdict.
pub fn trajectory_table(rows: &[TrajectoryRow]) -> Table {
    let mut t = Table::new(vec![
        "bench", "metric", "n", "median", "min", "max", "disp", "latest", "drift %", "rev",
        "verdict",
    ]);
    for r in rows {
        let drift = if r.median != 0.0 {
            format!("{:+.1}", 100.0 * (r.latest - r.median) / r.median.abs())
        } else {
            "-".into()
        };
        t.row(vec![
            r.bench.clone(),
            r.metric.clone(),
            format!("{}", r.samples),
            fmt_sig(r.median),
            fmt_sig(r.min),
            fmt_sig(r.max),
            fmt_sig(r.dispersion),
            fmt_sig(r.latest),
            drift,
            r.latest_rev.clone(),
            r.verdict.label(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::super::history::HistoryRecord;
    use super::*;
    use crate::util::prop::for_cases;
    use crate::util::rng::XorShift;

    fn unit(rng: &mut XorShift) -> f64 {
        rng.unit_f32() as f64
    }

    /// A sample within ±5% of `base` — the stationary-noise model.
    fn noisy(base: f64, rng: &mut XorShift) -> f64 {
        base * (1.0 + 0.05 * (2.0 * unit(rng) - 1.0))
    }

    #[test]
    fn direction_follows_slug_conventions() {
        assert_eq!(direction("co_opt_mean_ns"), Direction::LowerIsBetter);
        assert_eq!(direction("winner_energy_pj"), Direction::LowerIsBetter);
        assert_eq!(direction("gap_pct_alexnet"), Direction::LowerIsBetter);
        assert_eq!(direction("speedup_4w"), Direction::HigherIsBetter);
        assert_eq!(direction("layer_speedup"), Direction::HigherIsBetter);
        assert_eq!(direction("candidates"), Direction::Informational);
        assert_eq!(direction("frontier_points"), Direction::Informational);
    }

    #[test]
    fn stationary_noise_is_never_flagged() {
        for_cases(0xB5EC, 128, |rng| {
            let base = 1.0 + unit(rng) * 1e6;
            let n = MIN_BASELINE + rng.below(12) as usize;
            let prior: Vec<f64> = (0..n).map(|_| noisy(base, rng)).collect();
            let latest = noisy(base, rng);
            // ±5% noise stays far inside the 25% relative floor, so the
            // verdict is deterministic, not merely probable
            for dir in [Direction::LowerIsBetter, Direction::HigherIsBetter] {
                assert_eq!(
                    assess(&prior, latest, dir),
                    Verdict::Ok,
                    "noise flagged: base {base}, prior {prior:?}, latest {latest}"
                );
            }
        });
    }

    #[test]
    fn injected_step_change_is_flagged() {
        for_cases(0xB5ED, 128, |rng| {
            let base = 1.0 + unit(rng) * 1e6;
            let n = MIN_BASELINE + rng.below(12) as usize;
            let prior: Vec<f64> = (0..n).map(|_| noisy(base, rng)).collect();
            // lower-is-better: a 2–3x step up clears the worst-case band
            // (nearest-rank median ≤ 1.05·base, MAD ≤ 0.1·base, so
            // threshold ≤ max(4·1.4826·0.1, 0.25·1.05)·base ≈ 0.6·base)
            let worse_up = base * (2.0 + unit(rng));
            assert!(
                matches!(
                    assess(&prior, worse_up, Direction::LowerIsBetter),
                    Verdict::Regressed { .. }
                ),
                "step up not flagged: base {base}, latest {worse_up}"
            );
            // higher-is-better: collapsing to 10–20% of baseline
            let worse_down = base * (0.1 + 0.1 * unit(rng));
            assert!(
                matches!(
                    assess(&prior, worse_down, Direction::HigherIsBetter),
                    Verdict::Regressed { .. }
                ),
                "step down not flagged: base {base}, latest {worse_down}"
            );
        });
    }

    #[test]
    fn short_baselines_and_info_metrics_never_gate() {
        for_cases(0xB5EE, 64, |rng| {
            let base = 1.0 + unit(rng) * 1e3;
            let prior: Vec<f64> = (0..MIN_BASELINE - 1).map(|_| noisy(base, rng)).collect();
            // even a 100x step cannot flag with a short baseline
            assert_eq!(
                assess(&prior, base * 100.0, Direction::LowerIsBetter),
                Verdict::Insufficient
            );
            let long: Vec<f64> = (0..MIN_BASELINE + 4).map(|_| noisy(base, rng)).collect();
            assert_eq!(
                assess(&long, base * 100.0, Direction::Informational),
                Verdict::Informational
            );
        });
    }

    fn rec(bench: &str, ts: u64, metrics: Vec<(&str, f64)>) -> HistoryRecord {
        HistoryRecord {
            bench: bench.into(),
            git_rev: format!("r{ts}"),
            unix_ts: ts,
            metrics: metrics
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
            labels: Vec::new(),
        }
    }

    #[test]
    fn trajectory_gates_only_the_newest_record_per_bench() {
        let mut h = History::default();
        // 6 stable runs, each also carrying a metric that later vanishes
        for ts in 0..6 {
            h.records.push(rec(
                "perf_x",
                ts,
                vec![("probe_mean_ns", 100.0 + ts as f64), ("old_mean_ns", 50.0)],
            ));
        }
        // newest record: probe regresses hard, old_mean_ns is gone
        h.records.push(rec("perf_x", 6, vec![("probe_mean_ns", 400.0)]));
        let rows = trajectory(&h);
        let probe = rows
            .iter()
            .find(|r| r.metric == "probe_mean_ns")
            .expect("probe series");
        assert!(matches!(probe.verdict, Verdict::Regressed { .. }));
        assert_eq!(probe.samples, 7);
        assert_eq!(probe.latest, 400.0);
        assert_eq!(probe.latest_rev, "r6");
        let old = rows
            .iter()
            .find(|r| r.metric == "old_mean_ns")
            .expect("old series");
        assert_eq!(old.verdict, Verdict::Stale, "dropped metric must not gate");
        assert_eq!(regressions(&rows).len(), 1);
    }

    #[test]
    fn trajectory_table_renders_every_series() {
        let mut h = History::default();
        for ts in 0..3 {
            h.records.push(rec("perf_x", ts, vec![("probe_mean_ns", 100.0)]));
        }
        let rows = trajectory(&h);
        let t = trajectory_table(&rows);
        assert_eq!(t.len(), rows.len());
        let csv = t.to_csv();
        assert!(csv.contains("probe_mean_ns"));
        assert!(csv.contains("baseline<"), "short series labeled: {csv}");
    }
}
