//! Result structures of an analytical-model evaluation.

use crate::arch::Arch;
use crate::loopnest::{Tensor, ALL_TENSORS};
use crate::util::{fmt_sig, table::Table};

/// Word accesses at one storage level, split by tensor and direction.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LevelCounts {
    /// Reads per tensor `[I, W, O]`.
    pub reads: [f64; 3],
    /// Writes per tensor (only outputs write in inference).
    pub writes: [f64; 3],
}

impl LevelCounts {
    /// Total accesses at this level.
    pub fn total(&self) -> f64 {
        self.reads.iter().sum::<f64>() + self.writes.iter().sum::<f64>()
    }

    /// Accesses of one tensor.
    pub fn tensor(&self, t: Tensor) -> f64 {
        self.reads[t.idx()] + self.writes[t.idx()]
    }
}

/// Full evaluation result for one (layer, mapping, arch) triple.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelResult {
    /// Per-temporal-level access counts (same indexing as `arch.levels`).
    pub levels: Vec<LevelCounts>,
    /// Words delivered over the array fabric per tensor.
    pub fabric_words: [f64; 3],
    /// Hop-weighted fabric transfers (words × hop distance).
    pub fabric_hops: f64,
    /// Total MACs.
    pub macs: u64,
    /// PEs doing useful work (product of spatial extents).
    pub active_pes: u64,
    /// Energy per temporal level, pJ.
    pub energy_by_level: Vec<f64>,
    /// Fabric (inter-PE / bus) energy, pJ.
    pub fabric_energy: f64,
    /// MAC energy, pJ.
    pub mac_energy: f64,
    /// Total energy, pJ.
    pub energy_pj: f64,
    /// Execution cycles (max of compute and DRAM-bandwidth bound).
    pub cycles: f64,
    /// PE-array utilization for the mapping's spatial extents
    /// (ceil-fragmentation-aware).
    pub utilization: f64,
}

impl ModelResult {
    /// Energy in micro-joules.
    pub fn energy_uj(&self) -> f64 {
        self.energy_pj / 1e6
    }

    /// Throughput in TOPS at a given clock, counting 2 ops per MAC.
    pub fn tops(&self, freq_ghz: f64) -> f64 {
        2.0 * self.macs as f64 / self.cycles / 1e3 * freq_ghz
    }

    /// Efficiency in TOPS/W at a given clock (paper reports 0.35–1.85).
    pub fn tops_per_watt(&self, freq_ghz: f64) -> f64 {
        // energy per op (pJ) -> TOPS/W = 1 / (pJ/op)
        let pj_per_op = self.energy_pj / (2.0 * self.macs as f64);
        let _ = freq_ghz; // efficiency is frequency-independent here
        1.0 / pj_per_op
    }

    /// Fraction of total energy at temporal level `i`.
    pub fn level_fraction(&self, i: usize) -> f64 {
        self.energy_by_level[i] / self.energy_pj
    }

    /// Render the energy breakdown as a table (Fig 11-style rows).
    pub fn breakdown_table(&self, arch: &Arch) -> Table {
        let mut t = Table::new(vec!["level", "I", "W", "O", "acc(words)", "energy(pJ)", "frac"]);
        for (i, lc) in self.levels.iter().enumerate() {
            t.row(vec![
                arch.levels[i].name.clone(),
                fmt_sig(lc.tensor(Tensor::Input)),
                fmt_sig(lc.tensor(Tensor::Weight)),
                fmt_sig(lc.tensor(Tensor::Output)),
                fmt_sig(lc.total()),
                fmt_sig(self.energy_by_level[i]),
                format!("{:.1}%", 100.0 * self.level_fraction(i)),
            ]);
        }
        t.row(vec![
            "fabric".to_string(),
            fmt_sig(self.fabric_words[0]),
            fmt_sig(self.fabric_words[1]),
            fmt_sig(self.fabric_words[2]),
            fmt_sig(self.fabric_words.iter().sum::<f64>()),
            fmt_sig(self.fabric_energy),
            format!("{:.1}%", 100.0 * self.fabric_energy / self.energy_pj),
        ]);
        t.row(vec![
            "MAC".to_string(),
            String::new(),
            String::new(),
            String::new(),
            fmt_sig(self.macs as f64),
            fmt_sig(self.mac_energy),
            format!("{:.1}%", 100.0 * self.mac_energy / self.energy_pj),
        ]);
        t
    }

    /// Sum of access counts per tensor over all temporal levels — used by
    /// validation to compare against the simulator.
    pub fn total_accesses(&self) -> [f64; 3] {
        let mut out = [0.0; 3];
        for lc in &self.levels {
            for t in ALL_TENSORS {
                out[t.idx()] += lc.tensor(t);
            }
        }
        out
    }
}
