//! Analytical model tests: hand-checked access counts and invariants.

use super::*;
use crate::arch::{eyeriss_like, no_local_reuse, Arch, ArrayShape, MemLevel};
use crate::dataflow::SpatialMap;
use crate::energy::Table3;
use crate::loopnest::{Dim, LevelOrder, Mapping, Shape, Tensor};

/// A 3-level arch with one PE and roomy buffers, for hand calculations.
fn tiny_arch() -> Arch {
    Arch {
        name: "tiny".into(),
        levels: vec![
            MemLevel::reg("RF", 4096),
            MemLevel::sram("GBUF", 1 << 20),
            MemLevel::dram(),
        ],
        array: ArrayShape { rows: 1, cols: 1 },
        bus: crate::arch::ArrayBus::Systolic,
        word_bytes: 2,
        dram_bw_bytes_per_cycle: 16.0,
    }
}

#[test]
fn refetch_factor_order_awareness() {
    // K=4, C=3 at one level. For O (C irrelevant):
    //   C innermost -> r = 4; C outermost -> r = 12.
    let shape = Shape::new(1, 4, 3, 1, 1, 1, 1, 1);
    let mut m = Mapping::trivial(shape, 1, 2);
    m.orders[2] = LevelOrder([Dim::C, Dim::K, Dim::B, Dim::X, Dim::Y, Dim::FX, Dim::FY]);
    assert_eq!(refetch_factor(&m, Tensor::Output, 2, false), (4, true));
    m.orders[2] = LevelOrder([Dim::K, Dim::C, Dim::B, Dim::X, Dim::Y, Dim::FX, Dim::FY]);
    assert_eq!(refetch_factor(&m, Tensor::Output, 2, false), (12, true));
    // W relevant to both: 12 either way
    assert_eq!(refetch_factor(&m, Tensor::Weight, 2, false), (12, true));
    // with a relevant loop already seen below, even a leading irrelevant
    // dim counts: C-innermost now contributes fully
    m.orders[2] = LevelOrder([Dim::C, Dim::K, Dim::B, Dim::X, Dim::Y, Dim::FX, Dim::FY]);
    assert_eq!(refetch_factor(&m, Tensor::Output, 2, true), (12, true));
}

#[test]
fn refetch_factor_all_irrelevant_is_one() {
    // only B and X iterate -> W is fully stationary
    let shape = Shape::new(4, 1, 1, 5, 1, 1, 1, 1);
    let m = Mapping::trivial(shape, 1, 2);
    assert_eq!(refetch_factor(&m, Tensor::Weight, 2, false), (1, false));
    assert_eq!(refetch_factor(&m, Tensor::Output, 2, false).0, 20);
    // ...but the same loops above a W-relevant loop do force refetches
    assert_eq!(refetch_factor(&m, Tensor::Weight, 2, true).0, 20);
}

#[test]
fn matmul_hand_count() {
    // FC: B=2, K=3, C=4, single PE, everything iterated at the RF level
    // (tiles all fit). Boundary 0 rounds = r_0; canonical order is
    // [FX,FY,C,X,Y,K,B] so the nest is B { K { C } }.
    let shape = Shape::new(2, 3, 4, 1, 1, 1, 1, 1);
    let mut m = Mapping::trivial(shape, 1, 2);
    // move all iteration into level 0
    for d in [Dim::B, Dim::K, Dim::C] {
        m.blocking.set(0, d, shape.bound(d));
        m.blocking.set(2, d, 1);
    }
    m.validate().unwrap();

    let smap = SpatialMap::scalar();
    let r = evaluate(&m, &smap, &tiny_arch(), &Table3).unwrap();

    // RF (level 0) reads:
    //   W: C innermost (relevant) then K relevant, B irrelevant above:
    //      r_0(W) = 4*3*2 = 24 = MACs
    //   I: C relevant, K irrelevant above C -> counts, B relevant:
    //      24 = MACs
    //   O: C irrelevant innermost (accumulates in operand reg), K, B:
    //      writes per boundary-0 = 6 rounds; re-reads = rounds- distinct = 0
    let macs = 24.0;
    assert_eq!(r.macs, 24);
    assert_eq!(r.levels[0].reads[Tensor::Weight.idx()], macs);
    assert_eq!(r.levels[0].reads[Tensor::Input.idx()], macs);
    assert_eq!(r.levels[0].writes[Tensor::Output.idx()], 6.0);
    // no partial-sum re-reads from the MAC side, but the writeback to
    // GBUF reads the RF once per output element
    assert_eq!(r.levels[0].reads[Tensor::Output.idx()], 6.0);

    // level 1 (GBUF): whole tensors pass once: reads I = 8, W = 12;
    // O: 6 written up from RF... wait: boundary-1 rounds for O = 1,
    // tile below = 6 -> writes at level1 = 6, reads at level0 += 6.
    assert_eq!(r.levels[1].reads[Tensor::Input.idx()], 8.0);
    assert_eq!(r.levels[1].reads[Tensor::Weight.idx()], 12.0);
    assert_eq!(r.levels[1].writes[Tensor::Output.idx()], 6.0);
    // DRAM: same (compulsory)
    assert_eq!(r.levels[2].reads[Tensor::Input.idx()], 8.0);
    assert_eq!(r.levels[2].reads[Tensor::Weight.idx()], 12.0);
    assert_eq!(r.levels[2].writes[Tensor::Output.idx()], 6.0);
    assert_eq!(r.levels[2].reads[Tensor::Output.idx()], 0.0);
}

#[test]
fn output_partial_sum_rereads() {
    // Split C across the top level with C *outside* K: the K-tile outputs
    // are revisited per C chunk -> partial sums must be re-read.
    let shape = Shape::new(1, 4, 6, 1, 1, 1, 1, 1);
    let mut m = Mapping::trivial(shape, 1, 2);
    // level 0: K=4, C=3; level 2: C=2 (outer), order K innermost then C
    m.blocking.set(0, Dim::K, 4);
    m.blocking.set(0, Dim::C, 3);
    m.blocking.set(2, Dim::K, 1);
    m.blocking.set(2, Dim::C, 2);
    m.orders[2] = LevelOrder([Dim::K, Dim::C, Dim::B, Dim::X, Dim::Y, Dim::FX, Dim::FY]);
    m.validate().unwrap();

    let r = evaluate(&m, &SpatialMap::scalar(), &tiny_arch(), &Table3).unwrap();
    // boundary 1: rounds(O) = r_2(O): K innermost relevant f=1 ... C outer
    // irrelevant f=2 -> no relevant dim iterates with f>1 -> r = 1?
    // Careful: K factor at level 2 is 1 so seen_relevant never fires and
    // rounds = 1 -> O written up once, no re-reads at GBUF.
    // boundary 2 (into GBUF from DRAM): rounds(O) = 1 by same logic; BUT
    // boundary at level 2 counts r_2 itself = 1 -> writes at DRAM = 4.
    assert_eq!(r.levels[2].writes[Tensor::Output.idx()], 4.0);

    // Now force the revisit: put K at the top level too (K=2 inside, C=2
    // outside). distinct = 2 (K tiles), rounds = 4 -> re-reads > 0.
    let mut m2 = Mapping::trivial(shape, 1, 2);
    m2.blocking.set(0, Dim::K, 2);
    m2.blocking.set(0, Dim::C, 3);
    m2.blocking.set(2, Dim::K, 2);
    m2.blocking.set(2, Dim::C, 2);
    m2.orders[2] = LevelOrder([Dim::K, Dim::C, Dim::B, Dim::X, Dim::Y, Dim::FX, Dim::FY]);
    m2.validate().unwrap();
    let r2 = evaluate(&m2, &SpatialMap::scalar(), &tiny_arch(), &Table3).unwrap();
    // boundary 2: rounds(O) = r_2(O) = 2(K) * 2(C above) = 4; distinct = 2
    // tile below = 2 outputs -> DRAM writes 4*2 = 8, DRAM reads (4-2)*2 = 4
    assert_eq!(r2.levels[2].writes[Tensor::Output.idx()], 8.0);
    assert_eq!(r2.levels[2].reads[Tensor::Output.idx()], 4.0);
}

#[test]
fn multicast_at_array_boundary() {
    // 2x2 array, C|K: I multicast along K (2 copies per word), W unique,
    // O merged... with all temporal factors trivial at RF.
    let shape = Shape::new(1, 2, 2, 1, 1, 1, 1, 1);
    let mut m = Mapping::trivial(shape, 1, 2);
    m.spatial[Dim::C.idx()] = 2;
    m.spatial[Dim::K.idx()] = 2;
    m.blocking.set(2, Dim::C, 1);
    m.blocking.set(2, Dim::K, 1);
    m.validate().unwrap();
    let smap = SpatialMap {
        u: vec![(Dim::C, 2)],
        v: vec![(Dim::K, 2)],
    };
    let mut arch = tiny_arch();
    arch.array = ArrayShape { rows: 2, cols: 2 };

    let r = evaluate(&m, &smap, &arch, &Table3).unwrap();
    // 4 MACs on 4 PEs. Each PE reads 1 I, 1 W from its RF.
    assert_eq!(r.macs, 4);
    assert_eq!(r.levels[0].reads[Tensor::Input.idx()], 4.0);
    // GBUF serves unique words: I has 2 unique (C extent), W has 4.
    assert_eq!(r.levels[1].reads[Tensor::Input.idx()], 2.0);
    assert_eq!(r.levels[1].reads[Tensor::Weight.idx()], 4.0);
    // O: 2 unique outputs (K extent), spatially merged over C:
    // GBUF sees 2 writes.
    assert_eq!(r.levels[1].writes[Tensor::Output.idx()], 2.0);
    // fabric carried everything to 4 PEs
    assert_eq!(r.fabric_words[Tensor::Input.idx()], 4.0);
    assert_eq!(r.fabric_words[Tensor::Weight.idx()], 4.0);
}

#[test]
fn broadcast_bus_costs_more() {
    let shape = Shape::new(2, 16, 16, 6, 6, 3, 3, 1);
    let mut rng = crate::util::XorShift::new(3);
    for _ in 0..20 {
        let (m, smap) = crate::search::random_mapping_for_arch(shape, &eyeriss_like(), &mut rng);
        let sys = evaluate(&m, &smap, &eyeriss_like(), &Table3);
        let bc = evaluate(&m, &smap, &no_local_reuse(), &Table3);
        if let (Ok(s), Ok(b)) = (sys, bc) {
            assert!(
                b.energy_pj >= s.energy_pj,
                "broadcast {} < systolic {}",
                b.energy_pj,
                s.energy_pj
            );
        }
    }
}

#[test]
fn energy_includes_all_components() {
    let shape = Shape::new(1, 4, 4, 2, 2, 1, 1, 1);
    let m = Mapping::trivial(shape, 1, 2);
    let r = evaluate(&m, &SpatialMap::scalar(), &tiny_arch(), &Table3).unwrap();
    let sum: f64 = r.energy_by_level.iter().sum::<f64>() + r.fabric_energy + r.mac_energy;
    assert!((r.energy_pj - sum).abs() < 1e-9);
    assert!(r.mac_energy > 0.0);
    assert_eq!(r.macs, 64);
}

#[test]
fn fits_rejects_oversized_tiles() {
    let shape = Shape::new(1, 64, 64, 8, 8, 3, 3, 1);
    let mut m = Mapping::trivial(shape, 1, 2);
    // RF tile of W = 64*64*9 elems >> 4096-word RF
    for d in [Dim::K, Dim::C, Dim::FX, Dim::FY] {
        m.blocking.set(0, d, shape.bound(d));
        m.blocking.set(2, d, 1);
    }
    m.validate().unwrap();
    match evaluate(&m, &SpatialMap::scalar(), &tiny_arch(), &Table3) {
        Err(EvalError::DoesNotFit { level: 0, .. }) => {}
        other => panic!("expected DoesNotFit, got {other:?}"),
    }
}

#[test]
fn level_and_spatial_mismatches_rejected() {
    let shape = Shape::new(1, 2, 2, 1, 1, 1, 1, 1);
    let m = Mapping::trivial(shape, 1, 1); // 2 levels vs arch's 3
    match evaluate(&m, &SpatialMap::scalar(), &tiny_arch(), &Table3) {
        Err(EvalError::LevelMismatch { .. }) => {}
        other => panic!("{other:?}"),
    }
    let m = Mapping::trivial(shape, 1, 2);
    let bad_smap = SpatialMap {
        u: vec![(Dim::K, 2)],
        v: vec![],
    };
    match evaluate(&m, &bad_smap, &tiny_arch(), &Table3) {
        Err(EvalError::SpatialMismatch) => {}
        other => panic!("{other:?}"),
    }
}

#[test]
fn dram_bound_cycles() {
    // FC with batch 1: DRAM-bound (paper: memory bound, Amdahl)
    let shape = Shape::new(1, 128, 256, 1, 1, 1, 1, 1);
    let m = Mapping::trivial(shape, 1, 2);
    let r = evaluate(&m, &SpatialMap::scalar(), &tiny_arch(), &Table3).unwrap();
    let compute = r.macs as f64; // 1 PE
    assert!(r.cycles >= compute, "cycles must cover compute");
    // weights alone are 32k words; at 8 words/cycle DRAM that dominates
    assert!(r.cycles >= 32768.0 * 2.0 / 16.0);
}

#[test]
fn breakdown_table_renders() {
    let shape = Shape::new(1, 4, 4, 2, 2, 1, 1, 1);
    let m = Mapping::trivial(shape, 1, 2);
    let arch = tiny_arch();
    let r = evaluate(&m, &SpatialMap::scalar(), &arch, &Table3).unwrap();
    let txt = r.breakdown_table(&arch).to_text();
    assert!(txt.contains("RF"));
    assert!(txt.contains("DRAM"));
    assert!(txt.contains("MAC"));
    let sums = r.total_accesses();
    assert!(sums.iter().all(|&s| s >= 0.0));
}

// (the tile-table property test lives with the engine now:
// `engine::footprint::tests::footprints_match_tile_elems_reference`)

#[test]
fn scaled_cost_model_shifts_balance() {
    // quadrupling memory cost must increase total energy but leave access
    // counts untouched
    use crate::energy::ScaledCost;
    let shape = Shape::new(2, 8, 8, 4, 4, 3, 3, 1);
    let m = Mapping::trivial(shape, 1, 2);
    let base = evaluate(&m, &SpatialMap::scalar(), &tiny_arch(), &Table3).unwrap();
    let scaled = evaluate(
        &m,
        &SpatialMap::scalar(),
        &tiny_arch(),
        &ScaledCost {
            mem_scale: 4.0,
            mac_scale: 1.0,
            dram_scale: 4.0,
        },
    )
    .unwrap();
    assert_eq!(base.total_accesses(), scaled.total_accesses());
    assert!(scaled.energy_pj > 3.0 * base.energy_pj);
    assert_eq!(base.mac_energy, scaled.mac_energy);
}

#[test]
fn evaluate_prechecked_equals_evaluate() {
    let shape = Shape::new(2, 8, 8, 4, 4, 3, 3, 1);
    let mut rng = crate::util::XorShift::new(77);
    for _ in 0..20 {
        let arch = eyeriss_like();
        let (m, smap) = crate::search::random_mapping_for_arch(shape, &arch, &mut rng);
        if let Ok(checked) = evaluate(&m, &smap, &arch, &Table3) {
            let fast = evaluate_prechecked(&m, &smap, &arch, &Table3);
            assert_eq!(checked.energy_pj, fast.energy_pj);
            assert_eq!(checked.cycles, fast.cycles);
        }
    }
}

#[test]
fn tops_per_watt_sane_range() {
    let shape = Shape::new(2, 16, 16, 6, 6, 3, 3, 1);
    let df = crate::dataflow::Dataflow::parse("C|K").unwrap();
    let lo = crate::search::optimize_layer(
        &shape,
        &crate::arch::small_rf(),
        &df,
        &Table3,
        &crate::search::SearchOpts::capped(500, 5),
        1,
    )
    .unwrap();
    let tw = lo.result.tops_per_watt(0.4);
    // 16-bit MACs at these costs land between 0.05 and 5 TOPS/W
    assert!(tw > 0.05 && tw < 5.0, "{tw}");
}

#[test]
fn utilization_consistent_with_dataflow_module() {
    let shape = Shape::new(4, 384, 256, 13, 13, 3, 3, 1);
    let df = crate::dataflow::Dataflow::parse("C|K").unwrap();
    let arch = eyeriss_like();
    let smap = crate::search::divisor_replication(&shape, &df, &arch.array);
    let spatial = smap.factors();
    let mut m = Mapping::trivial(shape, 1, 2);
    for d in crate::loopnest::ALL_DIMS {
        m.spatial[d.idx()] = spatial[d.idx()];
        m.blocking.set(2, d, shape.bound(d) / spatial[d.idx()]);
    }
    m.validate().unwrap();
    // won't fit RF? use a huge arch
    let r = evaluate(&m, &smap, &tiny_arch_with_array(arch.array), &Table3).unwrap();
    assert_eq!(
        r.utilization,
        crate::dataflow::utilization(&shape, &smap, &arch.array)
    );
}

fn tiny_arch_with_array(array: ArrayShape) -> Arch {
    let mut a = tiny_arch();
    a.array = array;
    a
}
