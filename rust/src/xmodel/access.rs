//! Access-count computation and energy/performance evaluation.
//!
//! Counting convention: a word moving from level `i` down to level `i-1`
//! charges one **read at level i** and one **write at level i-1**; a word
//! moving up (output writeback) charges one **read at i-1** and one
//! **write at i**. The consumer below level 0 is the free per-tensor
//! operand register inside the PE datapath (it models stationarity:
//! an irrelevant loop nested innermost reuses the operand without an RF
//! access). The trace simulator counts identically.
//!
//! Since the staged-engine refactor the heavy lifting lives in
//! [`crate::engine`]; [`evaluate`], [`evaluate_prechecked`] and
//! [`assemble`] are thin compatibility shims over the full pipeline.
//! [`fits`] keeps its original monolithic implementation as an
//! independent reference that the engine's footprint/fit path is
//! property-tested against.

use super::result::ModelResult;
use crate::arch::{Arch, LevelKind};
use crate::dataflow::SpatialMap;
use crate::energy::CostModel;
use crate::loopnest::{Mapping, Tensor, ALL_TENSORS};

/// Why a (mapping, arch) pair cannot be evaluated.
#[derive(Debug, Clone, PartialEq)]
pub enum EvalError {
    /// Mapping factors do not multiply to the layer bounds.
    BadMapping(String),
    /// Mapping level count does not match the architecture.
    LevelMismatch {
        /// Levels in the mapping.
        mapping: usize,
        /// Levels in the architecture.
        arch: usize,
    },
    /// Spatial factors disagree with the spatial map.
    SpatialMismatch,
    /// A tile does not fit its storage level (with double buffering).
    DoesNotFit {
        /// Offending level index.
        level: usize,
        /// Words required.
        need: u64,
        /// Words available.
        have: u64,
    },
}

impl std::fmt::Display for EvalError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EvalError::BadMapping(e) => write!(f, "bad mapping: {e}"),
            EvalError::LevelMismatch { mapping, arch } => {
                write!(f, "mapping has {mapping} levels, arch has {arch}")
            }
            EvalError::SpatialMismatch => write!(f, "spatial factors != spatial map"),
            EvalError::DoesNotFit { level, need, have } => {
                write!(f, "tiles need {need} words at level {level}, have {have}")
            }
        }
    }
}

impl std::error::Error for EvalError {}

/// Per-level refetch multiplier `r_j(t)` given whether a `t`-relevant
/// loop (factor > 1) already iterates **below** this level
/// (`seen_below`): the product of the factors at temporal level `j` of
/// every dim relevant to `t`, or irrelevant but ordered outside the
/// innermost relevant loop of the whole nest.
///
/// The stationarity window is global, not per-level: an irrelevant loop
/// only leaves the tile in place when **no** relevant loop iterates
/// anywhere inside it — including at lower levels. Returns the factor and
/// the updated flag.
pub fn refetch_factor(m: &Mapping, t: Tensor, level: usize, seen_below: bool) -> (u64, bool) {
    let order = &m.orders[level];
    let mut seen = seen_below;
    let mut r = 1u64;
    for &d in order.0.iter() {
        let f = m.blocking.factor(level, d);
        if t.relevant(d) {
            if f > 1 {
                seen = true;
            }
            r *= f;
        } else if seen {
            r *= f;
        }
    }
    (if seen { r } else { 1 }, seen)
}

/// Check capacity: at every on-chip level the three tiles (double
/// buffered, Fig 5) must fit. DRAM always fits. Independent reference
/// for [`crate::engine::Footprints::fit`].
pub fn fits(m: &Mapping, arch: &Arch) -> Result<(), EvalError> {
    for (i, lvl) in arch.levels.iter().enumerate() {
        if lvl.kind == LevelKind::Dram {
            continue;
        }
        let need: u64 = ALL_TENSORS
            .iter()
            .map(|&t| m.tile_elems(t, i))
            .sum::<u64>()
            * 2;
        let have = arch.level_words(i);
        if need > have {
            return Err(EvalError::DoesNotFit { level: i, need, have });
        }
    }
    Ok(())
}

/// Evaluate the analytical model for one (mapping, spatial map, arch)
/// triple. The mapping's `spatial` must equal `smap.factors()` and its
/// level count must match the architecture.
///
/// Compatibility shim over the staged pipeline
/// ([`crate::engine::Engine::evaluate`]) — identical checks, identical
/// results.
pub fn evaluate(
    m: &Mapping,
    smap: &SpatialMap,
    arch: &Arch,
    cost: &dyn CostModel,
) -> Result<ModelResult, EvalError> {
    crate::engine::Engine::new(arch, cost).evaluate(m, smap)
}

/// [`evaluate`] without the consistency/capacity checks — the legacy
/// fast path for callers that validated the blocking table once (orders
/// never affect validity or capacity). Shim over
/// [`crate::engine::Engine::evaluate_prechecked`].
pub fn evaluate_prechecked(
    m: &Mapping,
    smap: &SpatialMap,
    arch: &Arch,
    cost: &dyn CostModel,
) -> ModelResult {
    crate::engine::Engine::new(arch, cost).evaluate_prechecked(m, smap)
}

/// Maximum temporal levels supported (fixed-size tables keep the search's
/// inner loop allocation-free).
pub const MAX_LEVELS: usize = 8;

/// Per-boundary round counts: `rounds[t][i]` = times the tile below level
/// `i` is (re)loaded per lower-level instance; `distinct[t][i]` = distinct
/// tiles among those rounds. The analytical model computes them by
/// formula; the trace simulator ([`crate::sim`]) counts them exactly —
/// both feed [`assemble`].
#[derive(Debug, Clone, PartialEq)]
pub struct RoundTables {
    /// `rounds[tensor][boundary]` (boundaries beyond `levels()` unused).
    pub rounds: [[f64; MAX_LEVELS]; 3],
    /// `distinct[tensor][boundary]`.
    pub distinct: [[f64; MAX_LEVELS]; 3],
}

impl Default for RoundTables {
    fn default() -> Self {
        RoundTables {
            rounds: [[0.0; MAX_LEVELS]; 3],
            distinct: [[0.0; MAX_LEVELS]; 3],
        }
    }
}

impl RoundTables {
    /// Analytical tables from the refetch formulas — one
    /// [`crate::engine::analytic_rows`] row pair per tensor (the engine
    /// computes rows lazily so pruned candidates skip the rest; this
    /// assembles the full table for the simulator cross-checks).
    pub fn analytic(m: &Mapping) -> Self {
        let mut out = RoundTables::default();
        for t in ALL_TENSORS {
            let (rounds, distinct) = crate::engine::analytic_rows(m, t);
            out.rounds[t.idx()] = rounds;
            out.distinct[t.idx()] = distinct;
        }
        out
    }
}

/// Assemble a [`ModelResult`] from per-boundary round tables (shared by
/// the analytical model and the trace simulator). Shim over
/// [`crate::engine::assemble`].
pub fn assemble(
    m: &Mapping,
    smap: &SpatialMap,
    arch: &Arch,
    cost: &dyn CostModel,
    tables: &RoundTables,
) -> ModelResult {
    crate::engine::assemble(m, smap, arch, cost, tables)
}
