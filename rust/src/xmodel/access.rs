//! Access-count computation and energy/performance evaluation.
//!
//! Counting convention: a word moving from level `i` down to level `i-1`
//! charges one **read at level i** and one **write at level i-1**; a word
//! moving up (output writeback) charges one **read at i-1** and one
//! **write at i**. The consumer below level 0 is the free per-tensor
//! operand register inside the PE datapath (it models stationarity:
//! an irrelevant loop nested innermost reuses the operand without an RF
//! access). The trace simulator counts identically.

use super::result::{LevelCounts, ModelResult};
use crate::arch::{Arch, ArrayBus, LevelKind};
use crate::dataflow::{utilization, SpatialMap};
use crate::energy::CostModel;
use crate::loopnest::{Dim, Mapping, Tensor, ALL_TENSORS};

/// Why a (mapping, arch) pair cannot be evaluated.
#[derive(Debug, Clone, PartialEq)]
pub enum EvalError {
    /// Mapping factors do not multiply to the layer bounds.
    BadMapping(String),
    /// Mapping level count does not match the architecture.
    LevelMismatch {
        /// Levels in the mapping.
        mapping: usize,
        /// Levels in the architecture.
        arch: usize,
    },
    /// Spatial factors disagree with the spatial map.
    SpatialMismatch,
    /// A tile does not fit its storage level (with double buffering).
    DoesNotFit {
        /// Offending level index.
        level: usize,
        /// Words required.
        need: u64,
        /// Words available.
        have: u64,
    },
}

impl std::fmt::Display for EvalError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EvalError::BadMapping(e) => write!(f, "bad mapping: {e}"),
            EvalError::LevelMismatch { mapping, arch } => {
                write!(f, "mapping has {mapping} levels, arch has {arch}")
            }
            EvalError::SpatialMismatch => write!(f, "spatial factors != spatial map"),
            EvalError::DoesNotFit { level, need, have } => {
                write!(f, "tiles need {need} words at level {level}, have {have}")
            }
        }
    }
}

impl std::error::Error for EvalError {}

/// Per-level refetch multiplier `r_j(t)` given whether a `t`-relevant
/// loop (factor > 1) already iterates **below** this level
/// (`seen_below`): the product of the factors at temporal level `j` of
/// every dim relevant to `t`, or irrelevant but ordered outside the
/// innermost relevant loop of the whole nest.
///
/// The stationarity window is global, not per-level: an irrelevant loop
/// only leaves the tile in place when **no** relevant loop iterates
/// anywhere inside it — including at lower levels. Returns the factor and
/// the updated flag.
pub fn refetch_factor(m: &Mapping, t: Tensor, level: usize, seen_below: bool) -> (u64, bool) {
    let order = &m.orders[level];
    let mut seen = seen_below;
    let mut r = 1u64;
    for &d in order.0.iter() {
        let f = m.blocking.factor(level, d);
        if t.relevant(d) {
            if f > 1 {
                seen = true;
            }
            r *= f;
        } else if seen {
            r *= f;
        }
    }
    (if seen { r } else { 1 }, seen)
}

/// Precomputed per-level tile sizes: `tiles[t][i]` = elements of `t`
/// resident at temporal level `i` (one cumulative-product pass instead of
/// re-deriving `cum` per query — the search's hot loop).
pub(crate) fn tile_table(m: &Mapping) -> [[f64; MAX_LEVELS]; 3] {
    let nlv = m.levels();
    let stride = m.shape.stride as u64;
    let (in_x, in_y) = (m.shape.input_x(), m.shape.input_y());
    let mut cum = [1u64; 7];
    let mut tiles = [[0.0; MAX_LEVELS]; 3];
    for i in 0..nlv {
        for (d, c) in cum.iter_mut().enumerate() {
            *c *= m.blocking.factors[i][d];
        }
        // at or above the first shared level the aggregate (array-wide)
        // tile includes the spatial factors
        let with_spatial = |d: usize| -> u64 {
            if i >= m.spatial_at {
                cum[d] * m.spatial[d]
            } else {
                cum[d]
            }
        };
        let (b, k, c, x, y, fx, fy) = (
            with_spatial(0),
            with_spatial(1),
            with_spatial(2),
            with_spatial(3),
            with_spatial(4),
            with_spatial(5),
            with_spatial(6),
        );
        let ix = ((x - 1) * stride + fx).min(in_x);
        let iy = ((y - 1) * stride + fy).min(in_y);
        tiles[Tensor::Input.idx()][i] = (b * c * ix * iy) as f64;
        tiles[Tensor::Weight.idx()][i] = (k * c * fx * fy) as f64;
        tiles[Tensor::Output.idx()][i] = (b * k * x * y) as f64;
    }
    tiles
}

/// Check capacity: at every on-chip level the three tiles (double
/// buffered, Fig 5) must fit. DRAM always fits.
pub fn fits(m: &Mapping, arch: &Arch) -> Result<(), EvalError> {
    for (i, lvl) in arch.levels.iter().enumerate() {
        if lvl.kind == LevelKind::Dram {
            continue;
        }
        let need: u64 = ALL_TENSORS
            .iter()
            .map(|&t| m.tile_elems(t, i))
            .sum::<u64>()
            * 2;
        let have = arch.level_words(i);
        if need > have {
            return Err(EvalError::DoesNotFit { level: i, need, have });
        }
    }
    Ok(())
}

/// Evaluate the analytical model for one (mapping, spatial map, arch)
/// triple. The mapping's `spatial` must equal `smap.factors()` and its
/// level count must match the architecture.
pub fn evaluate(
    m: &Mapping,
    smap: &SpatialMap,
    arch: &Arch,
    cost: &dyn CostModel,
) -> Result<ModelResult, EvalError> {
    m.validate().map_err(EvalError::BadMapping)?;
    if m.levels() != arch.num_levels() {
        return Err(EvalError::LevelMismatch {
            mapping: m.levels(),
            arch: arch.num_levels(),
        });
    }
    if m.spatial != smap.factors() {
        return Err(EvalError::SpatialMismatch);
    }
    if m.spatial_at != arch.rf_levels() {
        return Err(EvalError::BadMapping(format!(
            "spatial_at {} != arch rf levels {}",
            m.spatial_at,
            arch.rf_levels()
        )));
    }
    fits(m, arch)?;
    Ok(evaluate_prechecked(m, smap, arch, cost))
}

/// [`evaluate`] without the consistency/capacity checks — the search's
/// inner loop calls this after validating each blocking table once
/// (orders never affect validity or capacity).
pub fn evaluate_prechecked(
    m: &Mapping,
    smap: &SpatialMap,
    arch: &Arch,
    cost: &dyn CostModel,
) -> ModelResult {
    let tables = RoundTables::analytic(m);
    assemble(m, smap, arch, cost, &tables)
}

/// Maximum temporal levels supported (fixed-size tables keep the search's
/// inner loop allocation-free).
pub const MAX_LEVELS: usize = 8;

/// Per-boundary round counts: `rounds[t][i]` = times the tile below level
/// `i` is (re)loaded per lower-level instance; `distinct[t][i]` = distinct
/// tiles among those rounds. The analytical model computes them by
/// formula; the trace simulator ([`crate::sim`]) counts them exactly —
/// both feed [`assemble`].
#[derive(Debug, Clone, PartialEq)]
pub struct RoundTables {
    /// `rounds[tensor][boundary]` (boundaries beyond `levels()` unused).
    pub rounds: [[f64; MAX_LEVELS]; 3],
    /// `distinct[tensor][boundary]`.
    pub distinct: [[f64; MAX_LEVELS]; 3],
}

impl Default for RoundTables {
    fn default() -> Self {
        RoundTables {
            rounds: [[0.0; MAX_LEVELS]; 3],
            distinct: [[0.0; MAX_LEVELS]; 3],
        }
    }
}

impl RoundTables {
    /// Analytical tables from the refetch formulas. Per tensor, one
    /// inner-to-outer pass precomputes each level's refetch factor in both
    /// seen-states, then boundary values are suffix products.
    pub fn analytic(m: &Mapping) -> Self {
        let nlv = m.levels();
        assert!(nlv <= MAX_LEVELS, "more than {MAX_LEVELS} levels");
        let mut out = RoundTables::default();
        for t in ALL_TENSORS {
            let ti = t.idx();
            // per level: (r when a relevant loop was already seen below,
            // r when not, does this level set the seen flag, relevant-only
            // product)
            let mut per: [(f64, f64, bool, f64); MAX_LEVELS] =
                [(1.0, 1.0, false, 1.0); MAX_LEVELS];
            for j in 0..nlv {
                let (r_unseen, sets) = refetch_factor(m, t, j, false);
                let (r_seen, _) = refetch_factor(m, t, j, true);
                let rel: f64 = (0..7)
                    .filter(|&i| t.relevant(Dim::from_idx(i)))
                    .map(|i| m.blocking.factors[j][i] as f64)
                    .product();
                per[j] = (r_seen as f64, r_unseen as f64, sets, rel);
            }
            for i in 0..nlv {
                let mut seen = false;
                let mut rounds = 1.0;
                let mut distinct = 1.0;
                for (r_seen, r_unseen, sets, rel) in per.iter().take(nlv).skip(i) {
                    rounds *= if seen { *r_seen } else { *r_unseen };
                    seen |= *sets;
                    distinct *= rel;
                }
                out.rounds[ti][i] = rounds;
                out.distinct[ti][i] = distinct;
            }
        }
        out
    }
}

/// Assemble a [`ModelResult`] from per-boundary round tables (shared by
/// the analytical model and the trace simulator).
pub fn assemble(
    m: &Mapping,
    smap: &SpatialMap,
    arch: &Arch,
    cost: &dyn CostModel,
    tables: &RoundTables,
) -> ModelResult {
    let pes = m.pe_count() as f64;
    let sp = m.spatial_at;
    let nlv = m.levels();
    let tiles = tile_table(m);
    let mut levels = vec![LevelCounts::default(); nlv];
    let mut fabric_words = [0.0f64; 3];
    let mut fabric_hops = 0.0f64;

    for t in ALL_TENSORS {
        let ti = t.idx();
        // Boundary i: between level i (upper) and level i-1 / operand
        // register (lower).
        for i in 0..nlv {
            let rounds = tables.rounds[ti][i];
            let tile = if i == 0 { 1.0 } else { tiles[ti][i - 1] };

            // Multiplicities on the two sides of the boundary.
            // lower_mult: copies delivered below; upper_mult: unique words
            // the upper level serves (multicast dedup at the array edge).
            let (lower_mult, upper_mult, crosses_fabric) = if i < sp {
                (pes, pes, false)
            } else if i == sp {
                (pes, smap.unique_factor(t) as f64, true)
            } else {
                (1.0, 1.0, false)
            };

            if t == Tensor::Output {
                let wb = rounds * tile; // writeback rounds (per lower instance)
                let rr = (rounds - tables.distinct[ti][i]).max(0.0) * tile; // partial re-reads

                // Up: lower reads, upper writes.
                levels[i].writes[ti] += wb * upper_mult;
                if i >= 1 {
                    levels[i - 1].reads[ti] += wb * lower_mult;
                }
                // Down (partial refill): upper reads, lower writes.
                levels[i].reads[ti] += rr * upper_mult;
                if i >= 1 {
                    levels[i - 1].writes[ti] += rr * lower_mult;
                }
                if crosses_fabric {
                    fabric_words[ti] += (wb + rr) * pes;
                    if arch.bus == ArrayBus::Broadcast {
                        // no in-fabric accumulation: the buffer absorbs and
                        // merges every PE's partial sums itself
                        let extra = (wb + rr) * (pes - upper_mult).max(0.0);
                        levels[i].writes[ti] += extra;
                        levels[i].reads[ti] += extra;
                    }
                }
            } else {
                let words = rounds * tile;
                // Down: upper reads, lower writes.
                levels[i].reads[ti] += words * upper_mult;
                if i >= 1 {
                    levels[i - 1].writes[ti] += words * lower_mult;
                }
                if crosses_fabric {
                    fabric_words[ti] += words * pes;
                }
            }
        }

        let hops_per_word = match arch.bus {
            ArrayBus::Systolic => 1.0 + smap.share_hops(t),
            ArrayBus::Broadcast => (arch.array.rows as f64 + arch.array.cols as f64) / 4.0,
        };
        fabric_hops += fabric_words[ti] * hops_per_word;
    }

    // Energy.
    let mut energy_by_level = Vec::with_capacity(nlv);
    for (i, lc) in levels.iter().enumerate() {
        energy_by_level.push(lc.total() * cost.level_access(arch, i));
    }
    let fabric_energy = fabric_hops * cost.hop();
    let macs = m.shape.macs();
    let mac_energy = macs as f64 * cost.mac();
    let energy_pj = energy_by_level.iter().sum::<f64>() + fabric_energy + mac_energy;

    // Performance.
    let util = utilization(&m.shape, smap, &arch.array);
    let compute_cycles = if util > 0.0 {
        macs as f64 / (arch.array.pes() as f64 * util)
    } else {
        f64::INFINITY
    };
    let dram = levels.last().map(|lc| lc.total()).unwrap_or(0.0);
    let dram_cycles = dram * arch.word_bytes as f64 / arch.dram_bw_bytes_per_cycle;
    let cycles = compute_cycles.max(dram_cycles);

    ModelResult {
        levels,
        fabric_words,
        fabric_hops,
        macs,
        active_pes: m.pe_count(),
        energy_by_level,
        fabric_energy,
        mac_energy,
        energy_pj,
        cycles,
        utilization: util,
    }
}
