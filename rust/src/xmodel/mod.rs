//! The analytical model (§5): per-level access counts from a scheduled
//! loop nest, energy `E = Σ_i #acc_i × e_i`, and the performance bound.
//!
//! ## Access-count semantics
//!
//! The hierarchy is: implicit per-tensor **operand registers** inside each
//! PE (level "-1", free — they model datapath stationarity), the temporal
//! storage levels of the [`crate::arch::Arch`] (per-PE register files,
//! then shared SRAMs, then DRAM), and the **array fabric** between the
//! outermost register level and the first shared level, priced in hops
//! (the paper's "neighbor PEs as an additional level in the hierarchy").
//!
//! For tensor `t`, the words fetched into level `i-1` during the whole
//! layer are `refetch(t, i) × tile(t, i-1)`, where
//! `refetch(t, i) = Π_{j ≥ i} r_j(t)` and `r_j(t)` is the product of the
//! factors at temporal level `j` of every dim that is *relevant* to `t`
//! or ordered **outside** the innermost relevant dim with factor > 1 at
//! that level (order-aware stationarity: an irrelevant loop nested
//! innermost does not evict `t`'s tile). The trace simulator
//! ([`crate::sim`]) counts the same quantities exactly, by construction
//! of the loop walk — the two are cross-validated in tests and in the
//! Fig 7 bench.

mod access;
mod result;

pub use access::{
    assemble, evaluate, evaluate_prechecked, fits, refetch_factor, EvalError, RoundTables,
    MAX_LEVELS,
};
pub use result::{LevelCounts, ModelResult};

#[cfg(test)]
mod tests;
