//! The production serving fleet: one serving loop, N workers, one
//! drift-driven co-optimizer.
//!
//! [`run_fleet`] scales [`crate::coordinator::serve`] from one process to
//! a fleet: `N` serving workers — real OS processes launched through the
//! orchestrator's [`launcher_command`] prefixes, or in-process threads
//! for tests — each expand the shared [`TraceSpec`] themselves and serve
//! the interleaved shard `global_index % N == worker` under
//! [`ServeConfig::with_index_map`]`(worker, N)`. No trace bytes cross a
//! process boundary: the spec's compact encoding on the worker command
//! line is the whole contract.
//!
//! ## The two append-only files
//!
//! Workers and controller share a directory and two line-delimited JSON
//! logs, both written with the orchestrator's torn-write-safe
//! `\n{json}\n` framing ([`append_framed`]) and read forgivingly (torn
//! or garbage lines are skipped, never an error):
//!
//! - **`mix.jsonl`** — upstream. After every scheduling batch a worker
//!   appends a [`MixRecord`] with its batch's artifact counts. The
//!   controller folds new records into the fleet-level mix window of a
//!   single [`Remapper`] — fleet drift is total variation over the
//!   *merged* traffic, not any one worker's view.
//! - **`plans.jsonl`** — downstream, the epoch broadcast. The remapper
//!   runs on its own controller thread (fed through an `mpsc` channel,
//!   the same plan-swap decoupling `serve_with` uses), so
//!   re-optimization never blocks any worker's batch loop; each plan it
//!   publishes is appended as a [`PlanRecord`]. Workers poll the file at
//!   batch boundaries and adopt the highest epoch seen — plan *bodies*
//!   stay with the controller; the synthetic executors' values never
//!   depend on plans ([`Executor::adopt_plan`] is metadata-only), so the
//!   broadcast carries exactly what adoption needs: the epoch and its
//!   energy summary.
//!
//! ## Crash + rejoin
//!
//! Workers write their [`WorkerReport`] only at successful exit, so a
//! crash (SIGKILL, injected batch-loop failure, nonzero exit) leaves no
//! stale report. The controller respawns crashed workers — optionally
//! deferred until `plans.jsonl` is non-empty ([`FaultSpec::await_plan`]),
//! which pins rejoin tests: the rejoined worker re-serves its full shard
//! and adopts the current epoch at its first batch boundary. Duplicate
//! `mix.jsonl` records from the worker's first life are harmless — the
//! mix stream is advisory (it drives *when* to re-optimize, never what a
//! request computes).
//!
//! ## Determinism
//!
//! The merged fleet digest is bit-identical to one process serving the
//! whole trace, at any worker count, under crashes, stragglers, and live
//! remaps: each worker's [`ServeStats::digest`] is an index-bound
//! wrapping sum over its disjoint shard
//! ([`crate::coordinator::serve::digest_term`]), so the fleet merge is
//! `wrapping_add` in any order; request values are pure functions of
//! `(artifact, seed)` from the spec expansion; plans and pacing never
//! touch values. The f64 `checksum` is the one fleet-level quantity that
//! is *not* worker-count-invariant (float addition is not associative),
//! which is exactly why the digest exists.

pub mod scenarios;
#[cfg(test)]
mod tests;

use std::path::{Path, PathBuf};
use std::process::Child;
use std::sync::mpsc::{self, Sender};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::remap::{MappingPlan, RemapPolicy, Remapper};
use crate::coordinator::serve::{
    serve_hooked, BatchHook, Executor, Request, ServeConfig, ServeStats, SyntheticExecutor,
};
use crate::coordinator::trace::TraceSpec;
use crate::netopt::{SeedTable, ShardCheckpoint};
use crate::orchestrator::{append_framed, launcher_command};
use crate::pareto::FrontierCheckpoint;
use crate::telemetry;
use crate::telemetry::hist::LogHistogram;
use crate::util::json::Json;

/// Cap on any single pacing sleep (arrival gaps are scenario shapes, not
/// real-time replays — tests must stay fast).
const PACE_CAP_NS: u64 = 2_000_000;

/// The shared mix stream (workers append, controller reads).
pub fn mix_path(dir: &Path) -> PathBuf {
    dir.join("mix.jsonl")
}

/// The plan-epoch broadcast (controller appends, workers read).
pub fn plans_path(dir: &Path) -> PathBuf {
    dir.join("plans.jsonl")
}

/// Worker `w`'s final report (written once, at successful exit).
pub fn report_path(dir: &Path, worker: usize) -> PathBuf {
    dir.join(format!("worker_{worker}.json"))
}

/// One worker batch's artifact counts — the upstream drift signal.
#[derive(Debug, Clone, PartialEq)]
pub struct MixRecord {
    /// Worker index.
    pub worker: usize,
    /// Worker-local batch index.
    pub batch: usize,
    /// `(artifact, requests served)` for the batch.
    pub counts: Vec<(String, usize)>,
}

impl MixRecord {
    fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("worker".into(), Json::int(self.worker as u64)),
            ("batch".into(), Json::int(self.batch as u64)),
            (
                "counts".into(),
                Json::Arr(
                    self.counts
                        .iter()
                        .map(|(name, n)| {
                            Json::Obj(vec![
                                ("artifact".into(), Json::str(name.clone())),
                                ("n".into(), Json::int(*n as u64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    fn parse(line: &str) -> Option<MixRecord> {
        let v = Json::parse(line).ok()?;
        let mut counts = Vec::new();
        for c in v.field("counts").ok()?.as_arr().ok()? {
            counts.push((
                c.field("artifact").ok()?.as_str().ok()?.to_string(),
                c.field("n").ok()?.as_usize().ok()?,
            ));
        }
        Some(MixRecord {
            worker: v.field("worker").ok()?.as_usize().ok()?,
            batch: v.field("batch").ok()?.as_usize().ok()?,
            counts,
        })
    }
}

/// Read every well-formed mix record (missing file = empty; torn lines
/// skipped — a worker may be appending, or may have died mid-append).
pub fn read_mix(path: &Path) -> Vec<MixRecord> {
    read_lines(path, MixRecord::parse)
}

/// One broadcast plan epoch — the downstream adoption signal.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanRecord {
    /// Plan epoch (monotone per remapper).
    pub epoch: usize,
    /// Winning hierarchy's total network energy, pJ.
    pub energy_pj: f64,
    /// Heuristic fast-path plan (deadline mode)?
    pub fast: bool,
}

impl PlanRecord {
    fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("epoch".into(), Json::int(self.epoch as u64)),
            ("energy_pj".into(), Json::num(self.energy_pj)),
            ("fast".into(), Json::Bool(self.fast)),
        ])
    }

    fn parse(line: &str) -> Option<PlanRecord> {
        let v = Json::parse(line).ok()?;
        Some(PlanRecord {
            epoch: v.field("epoch").ok()?.as_usize().ok()?,
            energy_pj: v.field("energy_pj").ok()?.as_f64().ok()?,
            fast: matches!(v.field("fast").ok()?, Json::Bool(true)),
        })
    }
}

/// Read every well-formed plan record.
pub fn read_plans(path: &Path) -> Vec<PlanRecord> {
    read_lines(path, PlanRecord::parse)
}

/// The highest broadcast epoch, if any plan has been published.
pub fn latest_epoch(path: &Path) -> Option<usize> {
    read_plans(path).iter().map(|p| p.epoch).max()
}

fn read_lines<T>(path: &Path, parse: fn(&str) -> Option<T>) -> Vec<T> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(_) => return Vec::new(),
    };
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .filter_map(parse)
        .collect()
}

/// An [`Executor`] wrapper that sleeps `delay` before every request — the
/// slow-executor straggler shape. Delay never touches the value, so a
/// straggler fleet's digest is bit-identical to a healthy one's.
pub struct SlowExecutor<E> {
    inner: E,
    delay: Duration,
}

impl<E> SlowExecutor<E> {
    /// Wrap `inner`, sleeping `delay_ns` nanoseconds per request.
    pub fn new(inner: E, delay_ns: u64) -> SlowExecutor<E> {
        SlowExecutor {
            inner,
            delay: Duration::from_nanos(delay_ns),
        }
    }
}

impl<E: Executor> Executor for SlowExecutor<E> {
    fn execute(&mut self, req: &Request) -> Result<f64> {
        if !self.delay.is_zero() {
            thread::sleep(self.delay);
        }
        self.inner.execute(req)
    }

    fn adopt_plan(&mut self, plan: &MappingPlan) {
        self.inner.adopt_plan(plan);
    }
}

/// One worker's configuration — everything [`run_worker`] needs, and
/// everything the `fleet-worker` CLI arm forwards.
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    /// This worker's index in `0..fleet`.
    pub worker: usize,
    /// Fleet size (the digest index stride).
    pub fleet: usize,
    /// The shared trace spec (each worker expands it itself).
    pub spec: TraceSpec,
    /// Serve threads inside this worker.
    pub threads: usize,
    /// Requests per scheduling batch (the mix-record granularity).
    pub batch: usize,
    /// Shared fleet directory (`mix.jsonl`, `plans.jsonl`, reports).
    pub dir: PathBuf,
    /// Per-request executor delay, nanoseconds (straggler injection).
    pub slow_ns: u64,
    /// Sleep out the spec's arrival gaps between batches (offered-load
    /// pacing; capped per batch, never affects values).
    pub pace: bool,
    /// Fail the batch loop after this many batches (in-process crash
    /// injection; OS-mode crashes use a real SIGKILL instead).
    pub crash_after_batches: Option<usize>,
}

impl WorkerConfig {
    /// Worker `worker` of `fleet` over `spec`, serving into `dir` with
    /// fault-free defaults.
    pub fn new(worker: usize, fleet: usize, spec: TraceSpec, dir: impl Into<PathBuf>) -> WorkerConfig {
        WorkerConfig {
            worker,
            fleet,
            spec,
            threads: 2,
            batch: 16,
            dir: dir.into(),
            slow_ns: 0,
            pace: false,
            crash_after_batches: None,
        }
    }
}

/// A worker's final self-report — the fleet merge input. Written to
/// [`report_path`] only at successful exit (crash ⇒ no report), with the
/// digest as a 16-hex-digit string (u64 does not fit JSON's exact-f64
/// integer range).
#[derive(Debug, Clone)]
pub struct WorkerReport {
    /// Worker index.
    pub worker: usize,
    /// Requests served.
    pub completed: usize,
    /// Shard checksum (trace-ordered f64 sum — association-dependent).
    pub checksum: f64,
    /// Shard digest (order-free merge term; module docs).
    pub digest: u64,
    /// Failover retries inside this worker's serve loop.
    pub failovers: usize,
    /// Scheduling batches served.
    pub batches: usize,
    /// Highest broadcast plan epoch adopted (`None` if none was ever
    /// published while this worker ran).
    pub plan_epoch: Option<usize>,
    /// Log-bucketed latency histogram, milliseconds (percentiles do not
    /// compose across workers; histograms merge exactly, in bounded
    /// memory — [`LogHistogram::merge`]).
    pub latency_hist: LogHistogram,
}

impl WorkerReport {
    fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("worker".into(), Json::int(self.worker as u64)),
            ("completed".into(), Json::int(self.completed as u64)),
            ("checksum".into(), Json::num(self.checksum)),
            ("digest".into(), Json::str(format!("{:016x}", self.digest))),
            ("failovers".into(), Json::int(self.failovers as u64)),
            ("batches".into(), Json::int(self.batches as u64)),
            (
                "plan_epoch".into(),
                match self.plan_epoch {
                    Some(e) => Json::int(e as u64),
                    None => Json::Null,
                },
            ),
            ("latency_hist".into(), self.latency_hist.to_json()),
        ])
    }

    /// Parse a report file's contents.
    pub fn from_json(text: &str) -> Result<WorkerReport> {
        let v = Json::parse(text).context("parse worker report")?;
        let digest_hex = v.field("digest")?.as_str()?;
        let digest = u64::from_str_radix(digest_hex, 16)
            .map_err(|_| anyhow!("bad worker digest `{digest_hex}`"))?;
        let plan_epoch = match v.field("plan_epoch")? {
            Json::Null => None,
            e => Some(e.as_usize()?),
        };
        let latency_hist = LogHistogram::from_json(v.field("latency_hist")?)
            .context("parse worker latency histogram")?;
        Ok(WorkerReport {
            worker: v.field("worker")?.as_usize()?,
            completed: v.field("completed")?.as_usize()?,
            checksum: v.field("checksum")?.as_f64()?,
            digest,
            failovers: v.field("failovers")?.as_usize()?,
            batches: v.field("batches")?.as_usize()?,
            plan_epoch,
            latency_hist,
        })
    }

    /// Load worker `worker`'s report from `dir`.
    pub fn load(dir: &Path, worker: usize) -> Result<WorkerReport> {
        let path = report_path(dir, worker);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read worker report {}", path.display()))?;
        WorkerReport::from_json(&text)
    }
}

/// The worker side of the fleet protocol as a [`BatchHook`]: append the
/// batch's [`MixRecord`], optionally crash (fault injection), poll the
/// plan broadcast, optionally sleep out the arrival gap.
struct FleetHook {
    worker: usize,
    mix: PathBuf,
    plans: PathBuf,
    batch_idx: usize,
    epoch: Option<usize>,
    crash_after: Option<usize>,
    /// Sleep after batch `b` (pacing; empty when unpaced).
    pace_ns: Vec<u64>,
}

impl FleetHook {
    fn poll_epoch(&mut self) {
        if let Some(e) = latest_epoch(&self.plans) {
            if self.epoch.map_or(true, |cur| e > cur) {
                let worker = self.worker;
                telemetry::event("fleet", "epoch_adopt", || {
                    vec![
                        ("worker".into(), Json::int(worker as u64)),
                        ("epoch".into(), Json::int(e as u64)),
                    ]
                });
            }
            // Adopt the highest epoch seen; epochs are monotone so this
            // never moves backwards.
            self.epoch = Some(self.epoch.map_or(e, |cur| cur.max(e)));
        }
    }
}

impl BatchHook for FleetHook {
    fn after_batch(&mut self, served: &[Request]) -> Result<Vec<Arc<MappingPlan>>> {
        let mut counts: Vec<(String, usize)> = Vec::new();
        for req in served {
            match counts.iter_mut().find(|(name, _)| *name == req.artifact) {
                Some((_, n)) => *n += 1,
                None => counts.push((req.artifact.clone(), 1)),
            }
        }
        append_framed(
            &self.mix,
            &MixRecord {
                worker: self.worker,
                batch: self.batch_idx,
                counts,
            }
            .to_json(),
        )?;
        let b = self.batch_idx;
        self.batch_idx += 1;
        if let Some(limit) = self.crash_after {
            if self.batch_idx >= limit {
                // The injected crash: the mix record above is already on
                // disk (the controller must see a half-run worker), the
                // report is not (crash ⇒ no report).
                bail!("fleet worker {}: injected crash after {limit} batches", self.worker);
            }
        }
        self.poll_epoch();
        if let Some(&ns) = self.pace_ns.get(b) {
            if ns > 0 {
                thread::sleep(Duration::from_nanos(ns.min(PACE_CAP_NS)));
            }
        }
        Ok(Vec::new())
    }

    fn finish(&mut self) -> Result<Vec<Arc<MappingPlan>>> {
        // One last poll so a plan broadcast during the final batch is
        // still adopted before the report is written.
        self.poll_epoch();
        Ok(Vec::new())
    }
}

/// Run one fleet worker to completion: expand the spec, serve the
/// interleaved shard `global % fleet == worker` through [`serve_hooked`]
/// with the fleet hook, and write the [`WorkerReport`]. This is what the
/// `fleet-worker` CLI arm calls in OS mode and what thread-mode spawns
/// directly.
pub fn run_worker(cfg: &WorkerConfig) -> Result<WorkerReport> {
    let fleet = cfg.fleet.max(1);
    let all = cfg.spec.requests()?;
    let shard: Vec<Request> = all
        .iter()
        .enumerate()
        .filter(|(i, _)| i % fleet == cfg.worker)
        .map(|(_, r)| r.clone())
        .collect();
    let batch = cfg.batch.max(1);

    let pace_ns = if cfg.pace {
        // Per-batch arrival gap of this worker's shard: time between its
        // first request of batch b and its first request of batch b+1 on
        // the spec's offered-load clock.
        let arrivals = cfg.spec.arrival_ns();
        let global = |local: usize| cfg.worker + local * fleet;
        let nbatches = shard.len().div_ceil(batch);
        (0..nbatches)
            .map(|b| {
                let here = arrivals.get(global(b * batch)).copied().unwrap_or(0);
                let next = arrivals
                    .get(global((b + 1) * batch).min(cfg.spec.n.saturating_sub(1)))
                    .copied()
                    .unwrap_or(here);
                next.saturating_sub(here)
            })
            .collect()
    } else {
        Vec::new()
    };

    let mut hook = FleetHook {
        worker: cfg.worker,
        mix: mix_path(&cfg.dir),
        plans: plans_path(&cfg.dir),
        batch_idx: 0,
        epoch: None,
        crash_after: cfg.crash_after_batches,
        pace_ns,
    };
    let serve_cfg = ServeConfig::new(cfg.threads)
        .with_batch(batch)
        .with_index_map(cfg.worker as u64, fleet as u64);
    let slow = cfg.slow_ns;
    let st: ServeStats = serve_hooked(
        shard,
        &serve_cfg,
        || Ok(SlowExecutor::new(SyntheticExecutor, slow)),
        Some(&mut hook),
    )?;

    let report = WorkerReport {
        worker: cfg.worker,
        completed: st.completed,
        checksum: st.checksum,
        digest: st.digest,
        failovers: st.failovers,
        batches: st.batches,
        plan_epoch: hook.epoch,
        latency_hist: st.latency_hist,
    };
    // Write-then-rename so a reader never sees a half-written report.
    let path = report_path(&cfg.dir, cfg.worker);
    let tmp = path.with_extension("json.tmp");
    std::fs::write(&tmp, report.to_json().to_string())
        .with_context(|| format!("write worker report {}", tmp.display()))?;
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("publish worker report {}", path.display()))?;
    Ok(report)
}

/// Crash injection for the scenario harness.
#[derive(Debug, Clone)]
pub struct FaultSpec {
    /// Which worker to crash.
    pub worker: usize,
    /// OS mode: SIGKILL the worker this long after fleet start.
    pub after: Duration,
    /// Thread mode: the worker's batch loop fails after this many
    /// batches instead (threads cannot be SIGKILLed).
    pub after_batches: Option<usize>,
    /// Defer the respawn until `plans.jsonl` is non-empty, so the
    /// rejoined worker deterministically adopts the broadcast epoch.
    pub await_plan: bool,
}

/// Fleet configuration — controller plus the template every worker is
/// spawned from. Fields are public: scenarios and the CLI build one with
/// [`FleetConfig::new`] and set what they need.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Worker count.
    pub workers: usize,
    /// The shared trace spec.
    pub spec: TraceSpec,
    /// Serve threads per worker.
    pub threads: usize,
    /// Requests per scheduling batch.
    pub batch: usize,
    /// Shared fleet directory.
    pub dir: PathBuf,
    /// Worker binary for OS-process mode (`None` = in-process threads).
    pub bin: Option<PathBuf>,
    /// Launcher prefixes, round-robined across workers (OS mode; same
    /// shape as the orchestrator's `--hosts`).
    pub launchers: Vec<Vec<String>>,
    /// Remapper mix-window size; `0` disables the controller remapper
    /// entirely (no drift signal, no broadcasts).
    pub window: usize,
    /// Total-variation drift threshold.
    pub drift: f64,
    /// Serve from the live design space under this latency budget
    /// (cycles) instead of the fixed candidate list.
    pub latency_budget: Option<f64>,
    /// Deadline remaps: broadcast the heuristic fast-path plan first.
    pub deadline: bool,
    /// Warm-start checkpoint (frontier or shard) whose [`SeedTable`]
    /// primes the remapper before the first request lands.
    pub warm_start: Option<PathBuf>,
    /// Crash injection.
    pub fault: Option<FaultSpec>,
    /// `(worker, delay_ns)` straggler injection.
    pub slow_worker: Option<(usize, u64)>,
    /// Pace workers by the spec's arrival pattern.
    pub pace: bool,
    /// Controller poll interval.
    pub poll: Duration,
    /// Give up (with a diagnostic) after this long.
    pub timeout: Duration,
    /// Abort after this many respawns — a persistently crashing worker
    /// is a bug, not a fault to absorb.
    pub max_respawns: usize,
}

impl FleetConfig {
    /// `workers` in-process workers over `spec` in `dir`, no remapper,
    /// no faults.
    pub fn new(workers: usize, spec: TraceSpec, dir: impl Into<PathBuf>) -> FleetConfig {
        FleetConfig {
            workers,
            spec,
            threads: 2,
            batch: 16,
            dir: dir.into(),
            bin: None,
            launchers: Vec::new(),
            window: 0,
            drift: 0.25,
            latency_budget: None,
            deadline: false,
            warm_start: None,
            fault: None,
            slow_worker: None,
            pace: false,
            poll: Duration::from_millis(5),
            timeout: Duration::from_secs(120),
            max_respawns: 2,
        }
    }

    fn worker_config(&self, worker: usize, crash: Option<usize>) -> WorkerConfig {
        let mut w = WorkerConfig::new(worker, self.workers, self.spec.clone(), &self.dir);
        w.threads = self.threads;
        w.batch = self.batch;
        w.pace = self.pace;
        w.crash_after_batches = crash;
        if let Some((slow, ns)) = self.slow_worker {
            if slow == worker {
                w.slow_ns = ns;
            }
        }
        w
    }
}

/// Merged fleet-level results.
#[derive(Debug, Clone)]
pub struct FleetStats {
    /// Worker count.
    pub workers: usize,
    /// Requests served (sum over workers; re-served shard requests from
    /// a crashed worker's first life are not double counted — only its
    /// final successful run reports).
    pub completed: usize,
    /// Merged fleet digest (`wrapping_add` over worker digests) —
    /// bit-identical to single-process [`ServeStats::digest`] on the
    /// same spec.
    pub digest: u64,
    /// Sum of worker checksums (association-dependent; see module docs).
    pub checksum: f64,
    /// Fleet latency percentiles over the histogram-merged worker
    /// samples, ms.
    pub p50_ms: f64,
    /// p99, ms.
    pub p99_ms: f64,
    /// p99.9, ms.
    pub p999_ms: f64,
    /// Mean latency, ms.
    pub mean_ms: f64,
    /// Executor failovers across the fleet.
    pub failovers: usize,
    /// Plans the controller remapper published.
    pub remaps: usize,
    /// Of those, heuristic fast-path plans.
    pub fast_remaps: usize,
    /// The controller's final broadcast epoch.
    pub plan_epoch: Option<usize>,
    /// Each worker's adopted epoch, indexed by worker.
    pub worker_epochs: Vec<Option<usize>>,
    /// Crashed workers respawned.
    pub respawns: usize,
    /// Fleet wall time, seconds.
    pub wall_s: f64,
    /// Mix records the controller consumed.
    pub mix_records: usize,
}

impl FleetStats {
    /// JSON view for the `fleet --json` CLI output (digest as hex — u64
    /// exceeds JSON's exact integer range).
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("workers".into(), Json::int(self.workers as u64)),
            ("completed".into(), Json::int(self.completed as u64)),
            ("digest".into(), Json::str(format!("{:016x}", self.digest))),
            ("checksum".into(), Json::num(self.checksum)),
            ("p50_ms".into(), Json::num(self.p50_ms)),
            ("p99_ms".into(), Json::num(self.p99_ms)),
            ("p99_9_ms".into(), Json::num(self.p999_ms)),
            ("mean_ms".into(), Json::num(self.mean_ms)),
            ("failovers".into(), Json::int(self.failovers as u64)),
            ("remaps".into(), Json::int(self.remaps as u64)),
            ("fast_remaps".into(), Json::int(self.fast_remaps as u64)),
            (
                "plan_epoch".into(),
                match self.plan_epoch {
                    Some(e) => Json::int(e as u64),
                    None => Json::Null,
                },
            ),
            ("respawns".into(), Json::int(self.respawns as u64)),
            ("wall_s".into(), Json::num(self.wall_s)),
            ("mix_records".into(), Json::int(self.mix_records as u64)),
        ])
    }
}

/// Load the warm-start [`SeedTable`] from a sweep checkpoint — either a
/// frontier checkpoint ([`FrontierCheckpoint`]) or a scalar shard
/// checkpoint ([`ShardCheckpoint`]); both carry the per-layer best-energy
/// seeds the remapper primes its searches with.
pub fn load_warm_seeds(path: &Path) -> Result<SeedTable> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read warm-start checkpoint {}", path.display()))?;
    if let Ok(ckpt) = FrontierCheckpoint::from_json(&text) {
        return Ok(ckpt.seeds);
    }
    match ShardCheckpoint::from_json(&text) {
        Ok(ckpt) => Ok(ckpt.seeds),
        Err(e) => bail!(
            "{} is neither a frontier nor a shard checkpoint: {e}",
            path.display()
        ),
    }
}

/// A live worker: an in-process thread or a real OS process.
enum Handle {
    Thread(JoinHandle<Result<()>>),
    Process(Child),
}

/// One poll's view of a worker.
enum Poll {
    Running,
    Finished,
    Crashed(String),
}

fn poll_handle(handle: &mut Option<Handle>) -> Poll {
    match handle {
        None => Poll::Finished,
        Some(Handle::Thread(h)) => {
            if !h.is_finished() {
                return Poll::Running;
            }
            let Some(Handle::Thread(h)) = handle.take() else {
                unreachable!("just matched a thread handle");
            };
            match h.join() {
                Ok(Ok(())) => Poll::Finished,
                Ok(Err(e)) => Poll::Crashed(format!("{e:#}")),
                Err(_) => Poll::Crashed("worker thread panicked".into()),
            }
        }
        Some(Handle::Process(child)) => match child.try_wait() {
            Ok(None) => Poll::Running,
            Ok(Some(status)) if status.success() => {
                *handle = None;
                Poll::Finished
            }
            Ok(Some(status)) => {
                *handle = None;
                Poll::Crashed(format!("exit status {status}"))
            }
            Err(e) => Poll::Crashed(format!("wait failed: {e}")),
        },
    }
}

fn spawn_worker(cfg: &FleetConfig, worker: usize, crash: Option<usize>) -> Result<Handle> {
    // A stale report would let the controller count a worker done before
    // its current life finishes.
    let _ = std::fs::remove_file(report_path(&cfg.dir, worker));
    match &cfg.bin {
        None => {
            let wcfg = cfg.worker_config(worker, crash);
            Ok(Handle::Thread(thread::spawn(move || {
                run_worker(&wcfg).map(|_| ())
            })))
        }
        Some(bin) => {
            let wcfg = cfg.worker_config(worker, crash);
            // `--key=value` form throughout: the greedy Args parser would
            // otherwise eat a following flag as a value. Flags go last.
            let mut args = vec![
                format!("--worker={}", wcfg.worker),
                format!("--fleet={}", wcfg.fleet),
                format!("--trace={}", wcfg.spec.encode()),
                format!("--dir={}", wcfg.dir.display()),
                format!("--threads={}", wcfg.threads),
                format!("--batch-requests={}", wcfg.batch),
            ];
            if wcfg.slow_ns > 0 {
                args.push(format!("--slow-ns={}", wcfg.slow_ns));
            }
            if let Some(after) = crash {
                args.push(format!("--crash-after={after}"));
            }
            if wcfg.pace {
                args.push("--pace".into());
            }
            let mut cmd = launcher_command(&cfg.launchers, worker, bin, "fleet-worker", &args);
            let child = cmd
                .spawn()
                .with_context(|| format!("spawn fleet worker {worker}"))?;
            Ok(Handle::Process(child))
        }
    }
}

/// The controller remapper thread: one [`Remapper`] over the merged
/// fleet mix, publishing every plan to `plans.jsonl`. Returns
/// `(remaps, fast_remaps, last_epoch)` at shutdown (sender dropped).
fn spawn_remapper(
    cfg: &FleetConfig,
) -> Result<(
    Option<Sender<Vec<String>>>,
    Option<JoinHandle<(usize, usize, Option<usize>)>>,
)> {
    if cfg.window == 0 {
        return Ok((None, None));
    }
    let mut policy = RemapPolicy::new(cfg.window, cfg.drift);
    if let Some(budget) = cfg.latency_budget {
        policy = policy.with_latency_budget(budget);
    }
    if cfg.deadline {
        policy = policy.with_deadline();
    }
    let mut remapper = if cfg.latency_budget.is_some() {
        Remapper::with_space(policy, Remapper::default_space())
    } else {
        Remapper::new(policy, Remapper::default_candidates())
    };
    if let Some(path) = &cfg.warm_start {
        remapper.prime_seeds(&load_warm_seeds(path)?);
    }
    let plans = plans_path(&cfg.dir);
    let (tx, rx) = mpsc::channel::<Vec<String>>();
    let handle = thread::spawn(move || {
        let mut remaps = 0usize;
        let mut fast = 0usize;
        let mut last_epoch = None;
        let mut publish = |remapper: &mut Remapper, remaps: &mut usize, fast: &mut usize| {
            while let Some(plan) = remapper.take_plan() {
                *remaps += 1;
                if plan.fast {
                    *fast += 1;
                }
                last_epoch = Some(plan.epoch);
                let rec = PlanRecord {
                    epoch: plan.epoch,
                    energy_pj: plan.winner.opt.total_energy_pj,
                    fast: plan.fast,
                };
                telemetry::event("fleet", "replan", || {
                    vec![
                        ("epoch".into(), Json::int(rec.epoch as u64)),
                        ("energy_pj".into(), Json::num(rec.energy_pj)),
                        ("fast".into(), Json::Bool(rec.fast)),
                    ]
                });
                // A failed broadcast only delays adoption (workers keep
                // their current epoch) — never fail the fleet for it.
                let _ = append_framed(&plans, &rec.to_json());
            }
        };
        while let Ok(artifacts) = rx.recv() {
            for a in &artifacts {
                remapper.observe(a);
            }
            remapper.maybe_remap();
            publish(&mut remapper, &mut remaps, &mut fast);
        }
        // Sender dropped: the fleet is done serving. Pay off any owed
        // deadline exact search so the final broadcast converges.
        remapper.flush_pending();
        publish(&mut remapper, &mut remaps, &mut fast);
        (remaps, fast, last_epoch)
    });
    Ok((Some(tx), Some(handle)))
}

/// Stream mix records past `cursor` to the remapper channel, one
/// `send` per record (counts expand back into the artifact stream the
/// mix window expects).
fn pump_mix(mix: &Path, tx: &Option<Sender<Vec<String>>>, cursor: &mut usize) {
    let records = read_mix(mix);
    if records.len() <= *cursor {
        return;
    }
    if let Some(tx) = tx {
        for rec in &records[*cursor..] {
            let mut artifacts = Vec::new();
            for (name, n) in &rec.counts {
                for _ in 0..*n {
                    artifacts.push(name.clone());
                }
            }
            let _ = tx.send(artifacts);
        }
    }
    *cursor = records.len();
}

/// Run a serving fleet to completion and merge the worker reports.
pub fn run_fleet(cfg: &FleetConfig) -> Result<FleetStats> {
    if cfg.workers == 0 {
        bail!("fleet needs at least one worker");
    }
    let _fspan = telemetry::span_with("fleet", "run_fleet", || {
        vec![
            ("workers".into(), Json::int(cfg.workers as u64)),
            ("requests".into(), Json::int(cfg.spec.n as u64)),
        ]
    });
    std::fs::create_dir_all(&cfg.dir)
        .with_context(|| format!("create fleet dir {}", cfg.dir.display()))?;
    let mix = mix_path(&cfg.dir);
    let plans = plans_path(&cfg.dir);
    let _ = std::fs::remove_file(&mix);
    let _ = std::fs::remove_file(&plans);

    let (mix_tx, remapper_handle) = spawn_remapper(cfg)?;

    let t0 = Instant::now();
    let mut handles: Vec<Option<Handle>> = Vec::with_capacity(cfg.workers);
    for w in 0..cfg.workers {
        let crash = cfg
            .fault
            .as_ref()
            .filter(|f| f.worker == w)
            .and_then(|f| f.after_batches);
        handles.push(Some(spawn_worker(cfg, w, crash)?));
    }

    let mut done = vec![false; cfg.workers];
    let mut pending_respawn: Vec<usize> = Vec::new();
    let mut respawns = 0usize;
    let mut killed = false;
    let mut mix_cursor = 0usize;

    loop {
        // Upstream: feed new mix records to the remapper (counts expand
        // back into the artifact stream the mix window expects).
        pump_mix(&mix, &mix_tx, &mut mix_cursor);

        // OS-mode fault: a real SIGKILL, mid-run.
        if let Some(fault) = &cfg.fault {
            if !killed && cfg.bin.is_some() && t0.elapsed() >= fault.after {
                if let Some(Some(Handle::Process(child))) = handles.get_mut(fault.worker) {
                    let _ = child.kill();
                }
                killed = true;
            }
        }

        for w in 0..cfg.workers {
            if done[w] || pending_respawn.contains(&w) {
                continue;
            }
            match poll_handle(&mut handles[w]) {
                Poll::Running => {}
                Poll::Finished => {
                    if report_path(&cfg.dir, w).exists() {
                        done[w] = true;
                    } else {
                        // Clean exit without a report is a protocol
                        // violation — treat it as a crash.
                        pending_respawn.push(w);
                    }
                }
                Poll::Crashed(why) => {
                    if respawns >= cfg.max_respawns {
                        bail!(
                            "fleet worker {w} crashed ({why}) after the respawn \
                             budget ({}) was spent",
                            cfg.max_respawns
                        );
                    }
                    pending_respawn.push(w);
                }
            }
        }

        // Rejoin: respawn crashed workers, fault-free. `await_plan`
        // defers until the broadcast exists, so the rejoined worker's
        // first batch boundary already sees the current epoch.
        let gate_open = cfg
            .fault
            .as_ref()
            .map_or(true, |f| !f.await_plan || !read_plans(&plans).is_empty());
        if gate_open {
            for w in std::mem::take(&mut pending_respawn) {
                respawns += 1;
                telemetry::event("fleet", "respawn", || {
                    vec![("worker".into(), Json::int(w as u64))]
                });
                handles[w] = Some(spawn_worker(cfg, w, None)?);
            }
        }

        if done.iter().all(|&d| d) {
            break;
        }
        if t0.elapsed() > cfg.timeout {
            let missing: Vec<usize> =
                (0..cfg.workers).filter(|&w| !done[w]).collect();
            bail!(
                "fleet timed out after {:.1}s waiting for workers {missing:?}",
                cfg.timeout.as_secs_f64()
            );
        }
        thread::sleep(cfg.poll);
    }

    // Final pump so the remapper sees every record, then shut it down.
    pump_mix(&mix, &mix_tx, &mut mix_cursor);
    drop(mix_tx);
    let (remaps, fast_remaps, plan_epoch) = match remapper_handle {
        Some(h) => h
            .join()
            .map_err(|_| anyhow!("fleet remapper thread panicked"))?,
        None => (0, 0, None),
    };

    // Merge. Latencies merge as histograms (exact integer bucket
    // addition, any order), so the controller's memory is bounded by the
    // bucket count, not the trace length.
    let mut digest = 0u64;
    let mut checksum = 0.0f64;
    let mut completed = 0usize;
    let mut failovers = 0usize;
    let mut latency_hist = LogHistogram::new();
    let mut worker_epochs = Vec::with_capacity(cfg.workers);
    for w in 0..cfg.workers {
        let report = WorkerReport::load(&cfg.dir, w)?;
        if report.worker != w {
            bail!("worker report {w} claims worker {}", report.worker);
        }
        digest = digest.wrapping_add(report.digest);
        checksum += report.checksum;
        completed += report.completed;
        failovers += report.failovers;
        latency_hist.merge(&report.latency_hist);
        worker_epochs.push(report.plan_epoch);
    }
    telemetry::event("fleet", "latency_hist", || {
        vec![
            ("hist".into(), latency_hist.to_json()),
            ("count".into(), Json::int(latency_hist.count())),
            ("merged".into(), Json::Bool(true)),
        ]
    });

    Ok(FleetStats {
        workers: cfg.workers,
        completed,
        digest,
        checksum,
        p50_ms: latency_hist.quantile(50.0),
        p99_ms: latency_hist.quantile(99.0),
        p999_ms: latency_hist.quantile(99.9),
        mean_ms: latency_hist.mean(),
        failovers,
        remaps,
        fast_remaps,
        plan_epoch,
        worker_epochs,
        respawns,
        wall_s: t0.elapsed().as_secs_f64(),
        mix_records: mix_cursor,
    })
}

/// Single-process reference digest/checksum for `spec` — what every
/// fleet configuration must merge back to, bit for bit (digest) on the
/// digest and what the scenario harness compares against.
pub fn baseline(spec: &TraceSpec) -> Result<(u64, f64)> {
    let requests = spec.requests()?;
    let st = serve_hooked(
        requests,
        &ServeConfig::new(2).with_batch(16),
        || Ok(SyntheticExecutor),
        None,
    )?;
    Ok((st.digest, st.checksum))
}
