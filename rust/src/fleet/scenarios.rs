//! The deterministic fleet scenario harness — the load-test shapes the
//! `perf_fleet` gate and the fleet tests drive.
//!
//! Every scenario is a fixed-seed [`FleetConfig`] (bursty arrivals,
//! adversarial mix flips, slow-executor stragglers, worker crash +
//! rejoin, an unsatisfiable latency budget) plus an invariant check.
//! [`run_scenario`] expands the spec, computes the single-process
//! [`baseline`] digest, runs the fleet, and fails loudly unless the
//! merged digest is bit-identical to the baseline *and* the scenario's
//! own invariant holds — load shaping, faults, and re-optimization must
//! never change what is served, only when and under which plan.
//!
//! Scenarios run in-process (threads) by default and as real OS
//! processes when a worker binary is supplied — same configs, same
//! invariants, which is how the bench gate exercises the process path
//! the tests smoke in-process.

use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{bail, Result};

use super::{baseline, read_plans, run_fleet, plans_path, FaultSpec, FleetConfig, FleetStats};
use crate::coordinator::trace::{ArrivalPattern, TraceSpec};

/// The scenario catalogue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// Steady uniform load, no remapper — the merge-identity floor.
    Steady,
    /// Bursty arrivals with pacing under a live (but quiet) remapper —
    /// the latency-under-load shape the gate reports percentiles from.
    Bursty,
    /// Adversarial mid-trace mix flip under a deadline remapper — the
    /// drift path end to end (fast plan then exact convergence).
    MixFlip,
    /// One slow-executor straggler worker — tail latency grows, the
    /// digest must not move.
    Straggler,
    /// A worker crashes mid-run and rejoins — it must re-serve its full
    /// shard and adopt the current broadcast epoch.
    CrashRejoin,
    /// An unsatisfiable (zero) latency budget — the fleet must degrade
    /// gracefully: zero plans broadcast, zero thrash, digest intact.
    ZeroBudget,
}

impl Scenario {
    /// Every scenario, in gate order.
    pub fn all() -> [Scenario; 6] {
        [
            Scenario::Steady,
            Scenario::Bursty,
            Scenario::MixFlip,
            Scenario::Straggler,
            Scenario::CrashRejoin,
            Scenario::ZeroBudget,
        ]
    }

    /// Stable name (subdirectory and report key).
    pub fn name(&self) -> &'static str {
        match self {
            Scenario::Steady => "steady",
            Scenario::Bursty => "bursty",
            Scenario::MixFlip => "mix_flip",
            Scenario::Straggler => "straggler",
            Scenario::CrashRejoin => "crash_rejoin",
            Scenario::ZeroBudget => "zero_budget",
        }
    }

    /// The scenario's fleet configuration for `workers` workers serving
    /// into `dir`. Specs are fixed-seed: the same scenario always serves
    /// the same requests, whatever the mode or worker count.
    pub fn config(&self, workers: usize, dir: &Path) -> FleetConfig {
        let mut cfg = match self {
            Scenario::Steady => FleetConfig::new(workers, TraceSpec::mixed(96, 11), dir),
            Scenario::Bursty => {
                let spec = TraceSpec::mixed(96, 13).with_arrival(ArrivalPattern::Bursty {
                    burst: 16,
                    gap_ns: 500_000,
                });
                let mut cfg = FleetConfig::new(workers, spec, dir);
                cfg.pace = true;
                // Live remapper, threshold high enough that only the
                // first (no-plan-yet) boundary triggers.
                cfg.window = 24;
                cfg.drift = 0.9;
                cfg
            }
            Scenario::MixFlip => {
                let spec = TraceSpec::flip(
                    120,
                    17,
                    60,
                    &["conv3x3", "conv1x1"],
                    &["lstm_cell", "fc"],
                );
                let mut cfg = FleetConfig::new(workers, spec, dir);
                cfg.window = 24;
                cfg.drift = 0.25;
                cfg.deadline = true;
                cfg
            }
            Scenario::Straggler => {
                let mut cfg = FleetConfig::new(workers, TraceSpec::mixed(72, 19), dir);
                cfg.slow_worker = Some((workers.saturating_sub(1), 400_000));
                cfg
            }
            Scenario::CrashRejoin => {
                let mut cfg = FleetConfig::new(workers, TraceSpec::mixed(96, 23), dir);
                // Static mix + high threshold ⇒ exactly one broadcast
                // (epoch 0): the rejoined worker's adopted epoch is
                // deterministic.
                cfg.window = 24;
                cfg.drift = 0.9;
                cfg.fault = Some(FaultSpec {
                    worker: workers.saturating_sub(1).min(1),
                    after: Duration::from_millis(30),
                    after_batches: Some(1),
                    await_plan: true,
                });
                cfg
            }
            Scenario::ZeroBudget => {
                let mut cfg = FleetConfig::new(workers, TraceSpec::mixed(72, 29), dir);
                cfg.window = 16;
                cfg.drift = 0.25;
                cfg.latency_budget = Some(0.0);
                cfg
            }
        };
        cfg.batch = 12;
        cfg
    }

    /// The scenario-specific invariant (over and above digest identity,
    /// which [`run_scenario`] checks for every scenario).
    pub fn check(&self, cfg: &FleetConfig, stats: &FleetStats) -> Result<()> {
        let expected: usize = cfg.spec.n;
        if stats.completed != expected {
            bail!(
                "{}: served {} of {expected} requests",
                self.name(),
                stats.completed
            );
        }
        match self {
            Scenario::Steady | Scenario::Bursty | Scenario::Straggler => Ok(()),
            Scenario::MixFlip => {
                // The flip must have driven at least the initial plan and
                // one drift re-plan, and some worker must have adopted one.
                if stats.remaps < 2 {
                    bail!("mix_flip: expected ≥ 2 broadcast plans, got {}", stats.remaps);
                }
                if stats.plan_epoch.is_none() {
                    bail!("mix_flip: no final plan epoch");
                }
                Ok(())
            }
            Scenario::CrashRejoin => {
                if stats.respawns == 0 {
                    bail!("crash_rejoin: the injected crash never happened");
                }
                let victim = cfg.fault.as_ref().expect("crash scenario has a fault").worker;
                if stats.plan_epoch.is_none() {
                    bail!("crash_rejoin: no plan was ever broadcast");
                }
                if stats.worker_epochs[victim] != stats.plan_epoch {
                    bail!(
                        "crash_rejoin: rejoined worker {victim} is on epoch {:?}, \
                         fleet is on {:?}",
                        stats.worker_epochs[victim],
                        stats.plan_epoch
                    );
                }
                Ok(())
            }
            Scenario::ZeroBudget => {
                // Graceful degradation: the budget is unsatisfiable, so
                // nothing may thrash — no plans, no adoptions.
                if stats.remaps != 0 || stats.plan_epoch.is_some() {
                    bail!(
                        "zero_budget: {} plans broadcast under an unsatisfiable budget",
                        stats.remaps
                    );
                }
                if stats.worker_epochs.iter().any(|e| e.is_some()) {
                    bail!("zero_budget: a worker adopted a plan that cannot exist");
                }
                if !read_plans(&plans_path(&cfg.dir)).is_empty() {
                    bail!("zero_budget: plans.jsonl is not empty");
                }
                Ok(())
            }
        }
    }
}

/// One scenario's verified result.
#[derive(Debug, Clone)]
pub struct ScenarioOutcome {
    /// Scenario name.
    pub name: &'static str,
    /// Merged fleet stats.
    pub stats: FleetStats,
    /// Single-process reference digest the fleet matched.
    pub baseline_digest: u64,
}

/// Run one scenario and verify it: digest identity against the
/// single-process baseline, full completion, and the scenario invariant.
/// `bin` switches the workers from in-process threads to OS processes.
pub fn run_scenario(
    scenario: Scenario,
    workers: usize,
    dir: &Path,
    bin: Option<PathBuf>,
) -> Result<ScenarioOutcome> {
    let mut cfg = scenario.config(workers, dir);
    cfg.bin = bin;
    let (want_digest, _) = baseline(&cfg.spec)?;
    let stats = run_fleet(&cfg)?;
    if stats.digest != want_digest {
        bail!(
            "{}: fleet digest {:016x} != single-process digest {want_digest:016x}",
            scenario.name(),
            stats.digest
        );
    }
    scenario.check(&cfg, &stats)?;
    Ok(ScenarioOutcome {
        name: scenario.name(),
        stats,
        baseline_digest: want_digest,
    })
}

/// Run the whole catalogue (each scenario in its own subdirectory of
/// `dir`), failing on the first violated invariant.
pub fn run_all(workers: usize, dir: &Path, bin: Option<PathBuf>) -> Result<Vec<ScenarioOutcome>> {
    Scenario::all()
        .into_iter()
        .map(|s| run_scenario(s, workers, &dir.join(s.name()), bin.clone()))
        .collect()
}
