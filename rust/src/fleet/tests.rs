//! Fleet tests: merge identity across worker counts, the file protocol,
//! crash + rejoin, and graceful degradation. Everything here runs
//! in-process (worker threads) — `CARGO_BIN_EXE_*` paths only exist for
//! benches/integration tests, so the real-OS-process and real-SIGKILL
//! variants of the same scenarios live in `benches/perf_fleet.rs`.

use std::sync::atomic::{AtomicUsize, Ordering};

use super::scenarios::{run_scenario, Scenario};
use super::*;
use crate::netopt::NetOptStats;
use crate::telemetry::hist::LogHistogram;
use crate::util::prop::for_cases;

static DIR_SEQ: AtomicUsize = AtomicUsize::new(0);

/// A fresh scratch directory per call (tests run concurrently).
fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "interstellar-fleet-{}-{name}-{}",
        std::process::id(),
        DIR_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn merged_fleet_digest_is_bit_identical_across_worker_counts() {
    for_cases(0xf1ee7, 3, |rng| {
        let n = 36 + rng.below(48) as usize;
        let spec = TraceSpec::mixed(n, rng.next_u64());
        let (want_digest, _) = baseline(&spec).expect("single-process baseline");
        // Also varies threads-per-worker: the digest must be invariant
        // to both the fleet layout and each worker's parallelism.
        for (workers, threads) in [(1usize, 3usize), (2, 2), (4, 1)] {
            let dir = tmp("merge");
            let mut cfg = FleetConfig::new(workers, spec.clone(), &dir);
            cfg.batch = 8;
            cfg.threads = threads;
            let stats = run_fleet(&cfg).expect("fleet run");
            assert_eq!(stats.completed, n, "{workers} workers served the trace");
            assert_eq!(
                stats.digest, want_digest,
                "{workers}x{threads}: fleet digest must match single-process"
            );
            assert_eq!(stats.respawns, 0);
            assert_eq!(stats.workers, workers);
            let _ = std::fs::remove_dir_all(&dir);
        }
    });
}

#[test]
fn mix_and_plan_records_round_trip_through_the_framed_log() {
    let dir = tmp("records");
    std::fs::create_dir_all(&dir).unwrap();
    let mix = mix_path(&dir);
    let rec = MixRecord {
        worker: 3,
        batch: 7,
        counts: vec![("conv3x3".into(), 5), ("fc".into(), 2)],
    };
    append_framed(&mix, &rec.to_json()).unwrap();
    // A torn tail (writer killed mid-append) must not poison the reader.
    use std::io::Write;
    std::fs::OpenOptions::new()
        .append(true)
        .open(&mix)
        .unwrap()
        .write_all(b"{\"worker\":9,\"batch\":0,\"coun")
        .unwrap();
    let rec2 = MixRecord {
        worker: 1,
        batch: 0,
        counts: vec![("lstm_cell".into(), 4)],
    };
    append_framed(&mix, &rec2.to_json()).unwrap();
    assert_eq!(read_mix(&mix), vec![rec, rec2]);

    let plans = plans_path(&dir);
    let plan = PlanRecord {
        epoch: 2,
        energy_pj: 1234.5,
        fast: true,
    };
    append_framed(&plans, &plan.to_json()).unwrap();
    assert_eq!(read_plans(&plans), vec![plan]);
    assert_eq!(latest_epoch(&plans), Some(2));
    assert_eq!(latest_epoch(&dir.join("absent.jsonl")), None);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn worker_reports_round_trip_with_full_u64_digests() {
    let mut latency_hist = LogHistogram::new();
    for v in [0.25, 1.5, 0.75] {
        latency_hist.record(v);
    }
    let report = WorkerReport {
        worker: 2,
        completed: 24,
        checksum: 0.1 + 0.2,
        digest: u64::MAX - 17, // above 2^53: must survive the hex path
        failovers: 1,
        batches: 3,
        plan_epoch: Some(4),
        latency_hist,
    };
    let round = WorkerReport::from_json(&report.to_json().to_string()).unwrap();
    assert_eq!(round.digest, report.digest);
    assert_eq!(round.checksum.to_bits(), report.checksum.to_bits());
    assert_eq!(round.plan_epoch, Some(4));
    assert_eq!(round.latency_hist, report.latency_hist);

    let none = WorkerReport {
        plan_epoch: None,
        ..report
    };
    let round = WorkerReport::from_json(&none.to_json().to_string()).unwrap();
    assert_eq!(round.plan_epoch, None);
}

#[test]
fn crashed_worker_rejoins_and_adopts_the_broadcast_epoch() {
    let dir = tmp("crash");
    let outcome =
        run_scenario(Scenario::CrashRejoin, 3, &dir, None).expect("crash scenario");
    let stats = &outcome.stats;
    assert!(stats.respawns >= 1, "the injected crash must respawn");
    assert!(stats.plan_epoch.is_some(), "one plan must have broadcast");
    // The victim re-served its full shard on the current epoch; the
    // merged digest already matched the single-process baseline inside
    // run_scenario.
    assert_eq!(stats.worker_epochs[1], stats.plan_epoch);
    assert_eq!(stats.digest, outcome.baseline_digest);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn unsatisfiable_latency_budget_degrades_gracefully() {
    let dir = tmp("budget");
    let outcome =
        run_scenario(Scenario::ZeroBudget, 2, &dir, None).expect("zero-budget scenario");
    assert_eq!(outcome.stats.remaps, 0, "no plan fits a zero budget");
    assert_eq!(outcome.stats.plan_epoch, None);
    assert!(outcome.stats.worker_epochs.iter().all(|e| e.is_none()));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn load_and_straggler_scenarios_hold_their_invariants() {
    // Steady, bursty (paced + live remapper) and straggler smoke — the
    // crash and budget scenarios have their own tests above; the OS
    // process variants run in `benches/perf_fleet.rs`.
    for scenario in [Scenario::Steady, Scenario::Bursty, Scenario::Straggler] {
        let dir = tmp(scenario.name());
        run_scenario(scenario, 2, &dir, None)
            .unwrap_or_else(|e| panic!("{} scenario: {e:#}", scenario.name()));
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn mix_flip_drives_fast_then_exact_replans() {
    let dir = tmp("flip");
    let outcome =
        run_scenario(Scenario::MixFlip, 2, &dir, None).expect("mix-flip scenario");
    assert!(outcome.stats.remaps >= 2);
    assert!(
        outcome.stats.fast_remaps >= 1,
        "deadline mode publishes the heuristic plan first"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn warm_start_seeds_load_from_a_frontier_checkpoint() {
    let dir = tmp("warm");
    std::fs::create_dir_all(&dir).unwrap();
    let key = ([1u64, 2, 3, 4, 5, 6, 7], 1u32);
    let ckpt = FrontierCheckpoint {
        network: "serving-mix".into(),
        batch: 1,
        nshards: 1,
        shards: vec![0],
        stats: NetOptStats::default(),
        seeds: SeedTable::from_entries(vec![(key, 42.5)]),
        frontier: Vec::new(),
    };
    let path = dir.join("frontier.ckpt.json");
    std::fs::write(&path, ckpt.to_json()).unwrap();
    let seeds = load_warm_seeds(&path).expect("frontier checkpoint seeds");
    assert_eq!(seeds.get(&key), Some(42.5));

    let garbage = dir.join("garbage.json");
    std::fs::write(&garbage, "{\"not\": \"a checkpoint\"}").unwrap();
    assert!(load_warm_seeds(&garbage).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}
