//! Unit + randomized property tests for the heuristic mapper: validity
//! through the real engine on arbitrary (shape, arch) draws, and
//! bit-identity of every primed exact search to its unprimed twin.

use super::*;
use crate::arch::{eyeriss_like, no_local_reuse, small_rf};
use crate::energy::Table3;
use crate::engine::{EvalSnapshot, Footprints};
use crate::netopt::{co_optimize_arches, NetOptConfig};
use crate::nn::{Layer, Network};
use crate::pareto::{pareto_optimize_arches, ParetoConfig};
use crate::search::optimize_layer;
use crate::util::prop::for_cases;
use crate::util::rng::XorShift;

fn ck() -> Dataflow {
    Dataflow::parse("C|K").unwrap()
}

fn arches() -> Vec<Arch> {
    vec![eyeriss_like(), small_rf(), no_local_reuse()]
}

fn random_shape(rng: &mut XorShift) -> Shape {
    let b = rng.range(1, 4);
    let k = rng.range(1, 32);
    let c = rng.range(1, 32);
    let (x, y, f, stride) = if rng.below(3) == 0 {
        (1, 1, 1, 1) // FC-like
    } else {
        let f = *rng.choose(&[1u64, 3, 5]);
        (rng.range(1, 14), rng.range(1, 14), f, *rng.choose(&[1u32, 2]) as u64)
    };
    Shape::new(b, k, c, x, y, f, f, stride as u32)
}

fn random_arch(rng: &mut XorShift) -> Arch {
    arches()[rng.below(3) as usize].clone()
}

/// A small random network with a deliberate repeated layer (exercises
/// the shape dedup in both the exact and the heuristic accumulation).
fn random_net(rng: &mut XorShift) -> Network {
    let n = rng.range(2, 3) as usize;
    let mut layers: Vec<Layer> = (0..n)
        .map(|i| Layer {
            name: format!("L{i}"),
            ..Layer::conv("x", 1, 1, 1, 1, 1, 1, 1)
        })
        .collect();
    for l in layers.iter_mut() {
        l.shape = random_shape(rng);
    }
    layers.push(layers[0].clone());
    Network {
        name: "prop-net".into(),
        layers,
        batch: 1,
    }
}

#[test]
fn reuse_priority_is_a_permutation_and_deterministic() {
    for_cases(0xFA57_0001, 40, |rng| {
        let s = random_shape(rng);
        let p = reuse_priority(&s);
        let mut seen = [false; NDIMS];
        for d in p {
            assert!(!seen[d], "dim {d} repeated in priority {p:?}");
            seen[d] = true;
        }
        assert_eq!(p, reuse_priority(&s), "priority must be deterministic");
    });
}

#[test]
fn heuristic_mappings_pass_validate_and_fit_on_random_draws() {
    for_cases(0xFA57_0002, 60, |rng| {
        let shape = random_shape(rng);
        let arch = random_arch(rng);
        let mut cache = DivisorCache::new();
        let Some(lo) = heuristic_layer(&shape, &arch, &ck(), &Table3, &mut cache) else {
            return;
        };
        // stage-2 fit on the real footprint code
        Footprints::compute(&lo.mapping)
            .fit(&arch)
            .expect("heuristic mapping must fit");
        // stage-1 validate + full rollup through the official engine;
        // the stored result must be the engine's own bits
        let r = Engine::new(&arch, &Table3)
            .evaluate(&lo.mapping, &lo.smap)
            .expect("heuristic mapping must validate");
        assert_eq!(r.energy_pj.to_bits(), lo.result.energy_pj.to_bits());
        assert_eq!(r.cycles.to_bits(), lo.result.cycles.to_bits());
        assert_eq!(r.macs, lo.result.macs);
        assert_eq!(lo.mapping.levels(), arch.num_levels());
    });
}

#[test]
fn heuristic_is_infeasible_exactly_when_the_exact_search_is() {
    // Shrink the register file to one word: the all-ones base tile (6
    // words double-buffered) cannot fit, so both mappers must return
    // None; on the stock arches both return Some for modest shapes.
    let mut tiny = small_rf();
    tiny.levels[0].size_bytes = 2;
    let opts = SearchOpts::capped(80, 3);
    for_cases(0xFA57_0003, 12, |rng| {
        let shape = random_shape(rng);
        for arch in [tiny.clone(), eyeriss_like()] {
            let mut cache = DivisorCache::new();
            let h = heuristic_layer(&shape, &arch, &ck(), &Table3, &mut cache);
            let e = optimize_layer(&shape, &arch, &ck(), &Table3, &opts, 1);
            assert_eq!(
                h.is_some(),
                e.is_some(),
                "feasibility must agree on {} for {:?}",
                arch.name,
                shape
            );
        }
    });
}

#[test]
fn primed_layer_search_is_bit_identical_to_the_unprimed_search() {
    let opts = SearchOpts::capped(80, 3);
    for_cases(0xFA57_0004, 20, |rng| {
        let shape = random_shape(rng);
        let arch = random_arch(rng);
        let plain = optimize_layer(&shape, &arch, &ck(), &Table3, &opts, 1);
        let primed = optimize_layer_primed(&shape, &arch, &ck(), &Table3, &opts, 1);
        match (plain, primed) {
            (None, None) => {}
            (Some(a), Some(b)) => {
                assert_eq!(a.mapping, b.mapping);
                assert_eq!(a.smap, b.smap);
                assert_eq!(a.result.energy_pj.to_bits(), b.result.energy_pj.to_bits());
                assert_eq!(a.result.cycles.to_bits(), b.result.cycles.to_bits());
                assert_eq!(a.result.macs, b.result.macs);
            }
            (a, b) => panic!(
                "primed/unprimed feasibility diverged: plain={} primed={}",
                a.is_some(),
                b.is_some()
            ),
        }
    });
}

#[test]
fn scout_priming_keeps_the_co_optimize_winner_bits() {
    for_cases(0xFA57_0005, 8, |rng| {
        let net = random_net(rng);
        let arches = arches();
        let mut cfg = NetOptConfig::new(SearchOpts::capped(60, 3), 1);
        if rng.below(2) == 0 {
            // exercise the tops-aware scout path with a floor low enough
            // that it never actually filters
            cfg = cfg.with_min_tops(1e-12);
        }
        let off = co_optimize_arches(&net, &arches, &Table3, &cfg);
        let on = co_optimize_arches(&net, &arches, &Table3, &cfg.clone().with_prime(true));
        match (off.best(), on.best()) {
            (None, None) => {}
            (Some(a), Some(b)) => {
                assert_eq!(a.arch, b.arch);
                assert_eq!(
                    a.opt.total_energy_pj.to_bits(),
                    b.opt.total_energy_pj.to_bits()
                );
                assert_eq!(a.opt.total_cycles.to_bits(), b.opt.total_cycles.to_bits());
                assert_eq!(a.opt.total_macs, b.opt.total_macs);
                for (la, lb) in a.opt.per_layer.iter().zip(&b.opt.per_layer) {
                    match (la, lb) {
                        (None, None) => {}
                        (Some(la), Some(lb)) => {
                            assert_eq!(la.mapping, lb.mapping);
                            assert_eq!(
                                la.result.energy_pj.to_bits(),
                                lb.result.energy_pj.to_bits()
                            );
                        }
                        _ => panic!("per-layer feasibility diverged"),
                    }
                }
            }
            (a, b) => panic!(
                "winner feasibility diverged: off={} on={}",
                a.is_some(),
                b.is_some()
            ),
        }
        assert!(
            on.stats.engine.full <= off.stats.engine.full,
            "priming must not add full evaluations ({} > {})",
            on.stats.engine.full,
            off.stats.engine.full
        );
    });
}

#[test]
fn scout_priming_keeps_the_pareto_frontier_bits() {
    for_cases(0xFA57_0006, 6, |rng| {
        let net = random_net(rng);
        let arches = arches();
        let cfg = NetOptConfig::new(SearchOpts::capped(60, 3), 1);
        let pcfg = ParetoConfig::default();
        let off = pareto_optimize_arches(&net, &arches, &Table3, &cfg, &pcfg);
        let on = pareto_optimize_arches(
            &net,
            &arches,
            &Table3,
            &cfg.clone().with_prime(true),
            &pcfg,
        );
        assert_eq!(off.frontier.len(), on.frontier.len(), "frontier size");
        for (a, b) in off.frontier.iter().zip(&on.frontier) {
            assert_eq!(a.index, b.index);
            assert_eq!(a.result.arch, b.result.arch);
            assert_eq!(
                a.result.opt.total_energy_pj.to_bits(),
                b.result.opt.total_energy_pj.to_bits()
            );
            assert_eq!(
                a.result.opt.total_cycles.to_bits(),
                b.result.opt.total_cycles.to_bits()
            );
        }
        assert!(
            on.stats.engine.full <= off.stats.engine.full,
            "priming must not add full evaluations"
        );
    });
}

#[test]
fn heuristic_network_uniform_weights_keep_unweighted_bits() {
    for_cases(0xFA57_0007, 10, |rng| {
        let net = random_net(rng);
        let arch = eyeriss_like();
        let mut cache = DivisorCache::new();
        let plain = heuristic_network(&net, &arch, &ck(), &Table3, None, &mut cache);
        let ones = vec![1.0; net.layers.len()];
        let weighted =
            heuristic_network(&net, &arch, &ck(), &Table3, Some(&ones), &mut cache);
        assert_eq!(
            plain.total_energy_pj.to_bits(),
            weighted.total_energy_pj.to_bits()
        );
        assert_eq!(plain.total_cycles.to_bits(), weighted.total_cycles.to_bits());
        assert_eq!(plain.total_macs, weighted.total_macs);
        assert_eq!(plain.unmapped, weighted.unmapped);
    });
}

#[test]
fn heuristic_network_dedups_repeated_shapes() {
    let net = random_net(&mut XorShift::new(0xFA57_0008));
    let arch = eyeriss_like();
    let mut cache = DivisorCache::new();
    let opt = heuristic_network(&net, &arch, &ck(), &Table3, None, &mut cache);
    // last layer is a clone of the first: identical per-layer bits
    let first = opt.per_layer.first().unwrap().as_ref().unwrap();
    let last = opt.per_layer.last().unwrap().as_ref().unwrap();
    assert_eq!(first.mapping, last.mapping);
    assert_eq!(
        first.result.energy_pj.to_bits(),
        last.result.energy_pj.to_bits()
    );
}

#[test]
fn heuristic_plan_picks_min_energy_and_respects_the_budget() {
    let net = random_net(&mut XorShift::new(0xFA57_0009));
    let arches = arches();
    let plan = heuristic_plan(&net, &arches, &ck(), &Table3, None, None)
        .expect("stock arches must map a modest net");
    assert_eq!(plan.opt.unmapped, 0);
    // the pick is min-energy among the feasible candidates
    let mut cache = DivisorCache::new();
    for arch in &arches {
        let opt = heuristic_network(&net, arch, &ck(), &Table3, None, &mut cache);
        if opt.unmapped == 0 {
            assert!(plan.opt.total_energy_pj <= opt.total_energy_pj);
        }
    }
    // an impossible latency budget filters everything
    assert!(heuristic_plan(&net, &arches, &ck(), &Table3, None, Some(0.0)).is_none());
}

#[test]
fn scout_returns_a_position_not_a_global_index() {
    let net = random_net(&mut XorShift::new(0xFA57_000A));
    // global indices deliberately offset from positions
    let cands: Vec<(usize, Arch)> = arches()
        .into_iter()
        .enumerate()
        .map(|(i, a)| (i + 100, a))
        .collect();
    let pos = scout_candidates(&net, &cands, &ck(), &Table3, None, None, 1.0)
        .expect("stock arches must be feasible");
    assert!(pos < cands.len(), "scout must return a position, got {pos}");
}

#[test]
fn heuristic_layer_reports_its_own_engine_counters() {
    let shape = Shape::new(2, 16, 16, 7, 7, 3, 3, 1);
    let mut cache = DivisorCache::new();
    let lo = heuristic_layer(&shape, &eyeriss_like(), &ck(), &Table3, &mut cache)
        .expect("feasible on eyeriss");
    let z = EvalSnapshot::default();
    assert!(lo.stats.stage2 > z.stage2, "footprints must be counted");
    assert!(lo.evaluated > 0 && lo.evaluated <= 4 * HEUR_ORDER_CAP);
}
