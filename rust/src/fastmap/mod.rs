//! Microsecond heuristic mapper — the serving fast path and the scout
//! that primes every exact search.
//!
//! Interstellar's central result is that good loop *blocking* — not
//! exotic dataflow — determines energy, which means a cheap analytical
//! blocking heuristic should land within a few percent of the exact
//! branch-and-bound winner (LOCAL and the Turbo-Charged Mapper make the
//! same observation). This module is that heuristic, built entirely from
//! existing engine pieces — no new evaluation model:
//!
//! - **Greedy divisor-guided blocking** ([`heuristic_layer`]): start
//!   from the all-residues-at-DRAM table (spatial factors from the same
//!   [`divisor_replication`] the exact search uses) and, innermost level
//!   outward, repeatedly move the largest (or, in the balanced variant,
//!   smallest) divisor of each dimension's DRAM residue down into the
//!   level while the stage-2 capacity check still passes. Every
//!   successful move at least halves a residue, so the construction is
//!   bounded by the bit-length of the layer bounds — microseconds, not
//!   the thousands of candidate tables the enumerator walks. The fit
//!   test is an allocation-free mirror of
//!   [`Footprints`](crate::engine::Footprints) `compute` + `fit`, so a
//!   heuristic table is *valid by construction* (stage-1 validate holds
//!   because moves preserve the per-dimension factor products).
//! - **Order heuristic**: dimension priority is the dominant-tensor
//!   reuse weight (dimensions irrelevant to the largest tensors first —
//!   blocking them buys the most per-level reuse), and the loop orders
//!   come from the same structured stationary set
//!   (`search::order_combos`) the optimizer uses, picked by evaluating
//!   the candidate tables through the normal staged engine.
//! - **Network/plan level** ([`heuristic_network`], [`heuristic_plan`]):
//!   the same shape-deduplicated, mix-weighted accumulation as the exact
//!   co-optimizer, so heuristic totals are directly comparable to (and
//!   feed) the exact machinery.
//!
//! ## Priming (exactness preserved)
//!
//! Two integration points tighten the exact searches without touching
//! their argmin bits:
//!
//! 1. **Scout-point priming** (`netopt::run_points_gated`, enabled by
//!    [`NetOptConfig::prime`](crate::netopt::NetOptConfig)): the
//!    heuristically best feasible candidate architecture
//!    ([`scout_candidates`]) is evaluated *first*, through the identical
//!    official point evaluator. Its completed total is a real enumerated
//!    result, so the shared incumbent (scalar mode) or the dominance
//!    archive (frontier mode) starts from an admissible bound instead of
//!    `+inf` — every later point prunes harder. Because the scout is
//!    just an evaluation-order change of the same candidate set, the
//!    winner (and the exact frontier) is bit-identical by the existing
//!    pruning contracts; no certification or rerun is ever needed.
//! 2. **Seed-and-rerun priming** ([`optimize_layer_primed`]): the
//!    heuristic energy seeds the layer incumbent; a clipped outcome
//!    (nothing found, or a result above the seed — possible when the
//!    heuristic table lies outside the capped enumeration) falls back to
//!    the unseeded search, the same fallback idiom `netopt` uses for its
//!    cross-architecture seeds. The returned winner is bit-identical to
//!    [`optimize_layer`](crate::search::optimize_layer).
//!
//! The serving fast path (`RemapPolicy::deadline`,
//! `coordinator::remap`) publishes [`heuristic_plan`]'s pick immediately
//! on drift and hot-swaps the exact plan in when the deferred
//! branch-and-bound finishes. `fastmap::tests` property-checks validity
//! and priming bit-identity on random (shape, arch) draws;
//! `benches/perf_fastmap.rs` gates the energy gap and the speedup in CI.

use std::collections::HashMap;

use crate::arch::{Arch, LevelKind};
use crate::dataflow::Dataflow;
use crate::energy::CostModel;
use crate::engine::{DivisorCache, Engine, EvalStats, Staged};
use crate::loopnest::{Blocking, Mapping, Shape, ALL_DIMS, ALL_TENSORS, NDIMS};
use crate::netopt::LayerKey;
use crate::nn::Network;
use crate::search::{
    divisor_replication, optimize_layer_seeded, order_combos, HierarchyResult, LayerOpt,
    NetworkOpt, SearchOpts,
};

/// Order combos the heuristic scores per table — the structured
/// stationary subset (uniform inner stationarity × varied outermost
/// level). Kept small: the whole heuristic must stay in microseconds.
const HEUR_ORDER_CAP: usize = 9;

/// Dimension indices in descending reuse weight: the summed sizes of the
/// tensors a dimension is *irrelevant* to ([`Tensor::relevant`]). Moving
/// an irrelevant dimension's factor into an inner level multiplies the
/// reuse of those tensors at that level without growing their tiles, so
/// high-weight dimensions are blocked first. Stable sort keeps
/// [`ALL_DIMS`] order on ties.
fn reuse_priority(shape: &Shape) -> [usize; NDIMS] {
    let w: Vec<u64> = ALL_DIMS
        .iter()
        .map(|&d| {
            ALL_TENSORS
                .iter()
                .filter(|t| !t.relevant(d))
                .map(|&t| shape.tensor_elems(t))
                .sum()
        })
        .collect();
    let mut idx: Vec<usize> = (0..NDIMS).collect();
    idx.sort_by(|&a, &b| w[b].cmp(&w[a]));
    idx.try_into().expect("NDIMS indices")
}

/// The plain canonical priority — a second greedy variant; the two often
/// produce different tables and the engine picks the better one.
fn canonical_priority() -> [usize; NDIMS] {
    let mut idx = [0usize; NDIMS];
    for (i, v) in idx.iter_mut().enumerate() {
        *v = i;
    }
    idx
}

/// Allocation-free mirror of [`crate::engine::Footprints`] `compute` +
/// `fit`: cumulative per-level factor products, spatial factors folded
/// in at and above `spatial_at`, halo'd input tiles clamped to the layer
/// extent, double-buffered capacity per on-chip level. Must stay
/// bit-identical to the engine's stage-2 check — the greedy construction
/// relies on it so its output always passes the real pipeline.
fn fits(
    table: &[[u64; NDIMS]],
    shape: &Shape,
    spatial: &[u64; NDIMS],
    spatial_at: usize,
    arch: &Arch,
) -> bool {
    let stride = shape.stride as u64;
    let (in_x, in_y) = (shape.input_x(), shape.input_y());
    let mut cum = [1u64; NDIMS];
    for (i, level) in table.iter().enumerate() {
        for (d, c) in cum.iter_mut().enumerate() {
            *c *= level[d];
        }
        if arch.levels[i].kind == LevelKind::Dram {
            continue;
        }
        let ws = |d: usize| -> u64 {
            if i >= spatial_at {
                cum[d] * spatial[d]
            } else {
                cum[d]
            }
        };
        let (b, k, c, x, y, fx, fy) = (ws(0), ws(1), ws(2), ws(3), ws(4), ws(5), ws(6));
        let ix = ((x - 1) * stride + fx).min(in_x);
        let iy = ((y - 1) * stride + fy).min(in_y);
        let need = (b * c * ix * iy + k * c * fx * fy + b * k * x * y) * 2;
        if need > arch.level_words(i) {
            return false;
        }
    }
    true
}

/// One greedy blocking table: all residues start at DRAM (outermost
/// level); for each on-chip level, innermost first, keep moving divisors
/// of the DRAM residues down while the capacity check passes —
/// `largest_first` grabs the biggest fitting divisor per move (maximal
/// filling), otherwise the smallest `> 1` (balanced growth). Returns
/// `None` exactly when the base table itself does not fit: footprints
/// are monotone in the cumulative factors, so nothing else can fit
/// either.
fn greedy_table(
    shape: &Shape,
    arch: &Arch,
    spatial: &[u64; NDIMS],
    spatial_at: usize,
    priority: &[usize; NDIMS],
    largest_first: bool,
    cache: &mut DivisorCache,
) -> Option<Vec<[u64; NDIMS]>> {
    let nlv = arch.num_levels();
    let mut table = vec![[1u64; NDIMS]; nlv];
    for d in 0..NDIMS {
        table[nlv - 1][d] = shape.bounds[d] / spatial[d];
    }
    if !fits(&table, shape, spatial, spatial_at, arch) {
        return None;
    }
    for lvl in 0..nlv - 1 {
        loop {
            let mut moved = false;
            for &d in priority {
                let residue = table[nlv - 1][d];
                if residue <= 1 {
                    continue;
                }
                let divs = cache.divisors(residue);
                // candidate factors, best-first for the chosen style; in
                // balanced mode only the smallest prime step is tried
                // (its multiples can only need more capacity)
                let attempts: Vec<u64> = if largest_first {
                    divs.iter().rev().copied().filter(|&f| f > 1).collect()
                } else {
                    divs.iter().copied().find(|&f| f > 1).into_iter().collect()
                };
                for f in attempts {
                    table[lvl][d] *= f;
                    table[nlv - 1][d] /= f;
                    if fits(&table, shape, spatial, spatial_at, arch) {
                        moved = true;
                        break;
                    }
                    table[lvl][d] /= f;
                    table[nlv - 1][d] *= f;
                }
            }
            if !moved {
                break;
            }
        }
    }
    Some(table)
}

/// The heuristic mapping of one layer on one architecture: greedy tables
/// (two priorities × two growth styles, deduplicated) scored over the
/// structured order set through the normal staged engine; the best point
/// is materialized with the engine's full stage-4 evaluation. Runs in
/// microseconds — at most four tables × [`HEUR_ORDER_CAP`] bounded
/// evaluations, with a running local bound pruning most of them.
///
/// Returns `None` exactly when nothing fits this architecture (the
/// all-ones base tile already busts a level), which is precisely when
/// the exact search returns `None` too.
pub fn heuristic_layer(
    shape: &Shape,
    arch: &Arch,
    df: &Dataflow,
    cost: &dyn CostModel,
    cache: &mut DivisorCache,
) -> Option<LayerOpt> {
    let smap = divisor_replication(shape, df, &arch.array);
    let spatial = smap.factors();
    let spatial_at = arch.rf_levels();
    let mut tables: Vec<Vec<[u64; NDIMS]>> = Vec::new();
    for priority in [reuse_priority(shape), canonical_priority()] {
        for largest_first in [true, false] {
            if let Some(t) = greedy_table(
                shape,
                arch,
                &spatial,
                spatial_at,
                &priority,
                largest_first,
                cache,
            ) {
                if !tables.contains(&t) {
                    tables.push(t);
                }
            }
        }
    }
    if tables.is_empty() {
        return None;
    }
    let combos = order_combos(arch.num_levels(), HEUR_ORDER_CAP);
    let engine = Engine::new(arch, cost);
    let ctx = engine.context(shape, &smap);
    let stats = EvalStats::default();
    let evaluated = tables.len() * combos.len();
    let mut best: Option<(f64, usize, usize)> = None; // (energy, table, combo)
    for (ti, table) in tables.iter().enumerate() {
        let mut m = Mapping {
            shape: *shape,
            blocking: Blocking {
                factors: table.clone(),
            },
            orders: combos[0].clone(),
            spatial,
            spatial_at,
        };
        let Ok(fp) = engine.footprints(&m, &stats) else {
            continue;
        };
        for (ci, orders) in combos.iter().enumerate() {
            m.orders.clone_from(orders);
            let bound = best.map(|(e, _, _)| e).unwrap_or(f64::INFINITY);
            if let Staged::Energy(e) = engine.energy_bounded(&m, &smap, &ctx, &fp, bound, &stats) {
                if best.map(|(b, _, _)| e < b).unwrap_or(true) {
                    best = Some((e, ti, ci));
                }
            }
        }
    }
    let (energy, ti, ci) = best?;
    let mapping = Mapping {
        shape: *shape,
        blocking: Blocking {
            factors: tables[ti].clone(),
        },
        orders: combos[ci].clone(),
        spatial,
        spatial_at,
    };
    // stage 4: materialize the pick through the official evaluator
    let result = engine.evaluate(&mapping, &smap).ok()?;
    debug_assert_eq!(result.energy_pj, energy);
    Some(LayerOpt {
        mapping,
        smap,
        result,
        evaluated,
        stats: stats.snapshot(),
    })
}

/// Heuristic mapping of a whole network on one architecture — the same
/// shape-deduplicated, mix-weighted accumulation as the exact
/// co-optimizer's point evaluator (`1.0 × x == x`, so unweighted totals
/// keep exact bits and u64 MAC sums), which makes the heuristic total
/// directly comparable to [`co_optimize`](crate::netopt::co_optimize)
/// results on the same candidates.
pub fn heuristic_network(
    net: &Network,
    arch: &Arch,
    df: &Dataflow,
    cost: &dyn CostModel,
    weights: Option<&[f64]>,
    cache: &mut DivisorCache,
) -> NetworkOpt {
    if let Some(w) = weights {
        assert_eq!(
            w.len(),
            net.layers.len(),
            "layer_weights length must match the network depth"
        );
    }
    let weighted = weights.is_some();
    let mut shape_results: HashMap<LayerKey, Option<LayerOpt>> = HashMap::new();
    let mut per_layer: Vec<Option<LayerOpt>> = Vec::with_capacity(net.layers.len());
    let mut total_e = 0.0;
    let mut total_c = 0.0;
    let mut total_m = 0u64;
    let mut total_m_f = 0.0f64;
    let mut unmapped_layers: Vec<usize> = Vec::new();
    for (li, l) in net.layers.iter().enumerate() {
        let key: LayerKey = (l.shape.bounds, l.shape.stride);
        let w = weights.map(|w| w[li]).unwrap_or(1.0);
        let entry = shape_results
            .entry(key)
            .or_insert_with(|| heuristic_layer(&l.shape, arch, df, cost, cache))
            .clone();
        match entry {
            Some(lo) => {
                total_e += w * lo.result.energy_pj;
                total_c += w * lo.result.cycles;
                if weighted {
                    total_m_f += w * lo.result.macs as f64;
                } else {
                    total_m += lo.result.macs;
                }
                per_layer.push(Some(lo));
            }
            None => {
                unmapped_layers.push(li);
                per_layer.push(None);
            }
        }
    }
    NetworkOpt {
        per_layer,
        total_energy_pj: total_e,
        total_cycles: total_c,
        total_macs: if weighted {
            total_m_f.round() as u64
        } else {
            total_m
        },
        unmapped: unmapped_layers.len(),
        unmapped_layers,
    }
}

/// The remap fast path: heuristically map the (mix-weighted) network on
/// every candidate and return the lowest-energy fully-mapped point —
/// restricted to points whose weighted heuristic cycles fit
/// `latency_budget` when one is set. Ties break toward the earlier
/// candidate (strict improvement), mirroring the exact ranking's
/// enumeration-order tie-break. Microseconds per candidate; the exact
/// search later replaces whatever this picks.
pub fn heuristic_plan(
    net: &Network,
    arches: &[Arch],
    df: &Dataflow,
    cost: &dyn CostModel,
    weights: Option<&[f64]>,
    latency_budget: Option<f64>,
) -> Option<HierarchyResult> {
    let mut cache = DivisorCache::new();
    let mut best: Option<HierarchyResult> = None;
    for arch in arches {
        let opt = heuristic_network(net, arch, df, cost, weights, &mut cache);
        if opt.unmapped > 0 {
            continue;
        }
        if let Some(budget) = latency_budget {
            if opt.total_cycles > budget {
                continue;
            }
        }
        if best
            .as_ref()
            .map(|b| opt.total_energy_pj < b.opt.total_energy_pj)
            .unwrap_or(true)
        {
            best = Some(HierarchyResult {
                arch: arch.clone(),
                opt,
            });
        }
    }
    best
}

/// Pick the scout: the position (into `cands`) of the heuristically best
/// feasible candidate, preferring points that pass the `min_tops`
/// estimate and falling back to any fully-mapped point. The caller
/// evaluates the scout first through the official point evaluator, so
/// the network incumbent / dominance archive starts from an admissible
/// completed total — any pick is sound (it is only an evaluation-order
/// choice), a good pick prunes the rest of the sweep hardest.
pub(crate) fn scout_candidates(
    net: &Network,
    cands: &[(usize, Arch)],
    df: &Dataflow,
    cost: &dyn CostModel,
    weights: Option<&[f64]>,
    min_tops: Option<f64>,
    clock_ghz: f64,
) -> Option<usize> {
    let mut cache = DivisorCache::new();
    let mut best_ok: Option<(usize, f64)> = None; // passes the tops estimate
    let mut best_any: Option<(usize, f64)> = None; // merely fully mapped
    for (pos, (_, arch)) in cands.iter().enumerate() {
        let opt = heuristic_network(net, arch, df, cost, weights, &mut cache);
        if opt.unmapped > 0 {
            continue;
        }
        let e = opt.total_energy_pj;
        if best_any.map(|(_, b)| e < b).unwrap_or(true) {
            best_any = Some((pos, e));
        }
        let tops_ok = min_tops.map(|mt| opt.tops(clock_ghz) >= mt).unwrap_or(true);
        if tops_ok && best_ok.map(|(_, b)| e < b).unwrap_or(true) {
            best_ok = Some((pos, e));
        }
    }
    best_ok.or(best_any).map(|(pos, _)| pos)
}

/// [`optimize_layer`](crate::search::optimize_layer) primed by the
/// heuristic: the heuristic energy seeds the layer incumbent so pruning
/// is tight from the very first candidate. Exactness by the standard
/// seed-and-rerun idiom: a clipped outcome (nothing found, or a result
/// above the seed — possible when the heuristic's table lies outside the
/// capped enumeration) reruns unseeded, so the returned winner is
/// bit-identical to the unprimed search (property-tested in
/// `fastmap::tests`).
pub fn optimize_layer_primed(
    shape: &Shape,
    arch: &Arch,
    df: &Dataflow,
    cost: &dyn CostModel,
    opts: &SearchOpts,
    threads: usize,
) -> Option<LayerOpt> {
    let mut cache = DivisorCache::new();
    let seed = heuristic_layer(shape, arch, df, cost, &mut cache)
        .map(|lo| lo.result.energy_pj)
        .unwrap_or(f64::INFINITY);
    let (win, _) = optimize_layer_seeded(shape, arch, df, cost, opts, threads, seed, &mut cache);
    let clipped = match &win {
        Some(l) => l.result.energy_pj > seed,
        None => true,
    };
    if seed.is_finite() && clipped {
        let (win2, _) =
            optimize_layer_seeded(shape, arch, df, cost, opts, threads, f64::INFINITY, &mut cache);
        return win2;
    }
    win
}

#[cfg(test)]
mod tests;
