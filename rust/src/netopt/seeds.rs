//! The cross-run `(shape, stride) → best-known energy` seeds table.
//!
//! One representation shared by everything that carries layer-energy
//! hints between searches: shard checkpoints persist it (so a merge —
//! or a future resume — sees the final per-shape bounds), and the
//! serving-time remapper ([`crate::coordinator::remap`]) feeds it back
//! into [`co_optimize_arches_seeded`](super::co_optimize_arches_seeded)
//! to warm-start on-line re-optimizations from everything earlier plans
//! learned.
//!
//! Seeds are *hints*, never trusted results: a seeded layer search whose
//! outcome is clipped by the borrowed bound is rerun against the
//! admissible network bound alone (see the parent module's seeding
//! fallback), so an arbitrary — even adversarial — table can only prune
//! work, never change the argmin. `netopt::tests` asserts this under the
//! randomized property harness.
//!
//! Entries are kept sorted by key, so serialization is deterministic and
//! the pairwise [`merge`](SeedTable::merge) (minimum on shared keys) is
//! a linear sorted-merge — associative and commutative, which the shard
//! checkpoint merge relies on.

use anyhow::Result;

use crate::loopnest::NDIMS;
use crate::util::json::Json;

/// Layer-shape dedup key: identical `(bounds, stride)` layers share one
/// search per architecture point, one seeds-table entry across all of
/// them.
pub type LayerKey = ([u64; NDIMS], u32);

/// Best-known per-layer-shape energies, sorted by key.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SeedTable {
    entries: Vec<(LayerKey, f64)>,
}

impl SeedTable {
    /// An empty table.
    pub fn new() -> SeedTable {
        SeedTable::default()
    }

    /// Build from arbitrary entries: sorts by key and keeps the minimum
    /// energy of duplicate keys.
    pub fn from_entries(mut entries: Vec<(LayerKey, f64)>) -> SeedTable {
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        let mut out: Vec<(LayerKey, f64)> = Vec::with_capacity(entries.len());
        for (k, e) in entries {
            match out.last_mut() {
                Some((lk, le)) if *lk == k => *le = le.min(e),
                _ => out.push((k, e)),
            }
        }
        SeedTable { entries: out }
    }

    /// Best-known energy for a shape, if any.
    pub fn get(&self, key: &LayerKey) -> Option<f64> {
        self.entries
            .binary_search_by(|(k, _)| k.cmp(key))
            .ok()
            .map(|i| self.entries[i].1)
    }

    /// Record an observed energy, keeping the per-key minimum.
    pub fn observe(&mut self, key: LayerKey, energy_pj: f64) {
        match self.entries.binary_search_by(|(k, _)| k.cmp(&key)) {
            Ok(i) => self.entries[i].1 = self.entries[i].1.min(energy_pj),
            Err(i) => self.entries.insert(i, (key, energy_pj)),
        }
    }

    /// Min-merge another table into this one (sorted linear merge,
    /// minimum on shared keys). Associative, commutative, and
    /// **idempotent** (`t.merge(&t) == t`) — the third property is what
    /// lets the checkpoint merge fold duplicate shard coverage (a
    /// re-split straggler finishing after its replacement sub-shards)
    /// without inventing energies no run observed.
    pub fn merge(&mut self, other: &SeedTable) {
        let a = std::mem::take(&mut self.entries);
        let b = &other.entries;
        let mut out: Vec<(LayerKey, f64)> = Vec::with_capacity(a.len() + b.len());
        let (mut ia, mut ib) = (0usize, 0usize);
        while ia < a.len() || ib < b.len() {
            let pick_a = match (a.get(ia), b.get(ib)) {
                (Some(x), Some(y)) => match x.0.cmp(&y.0) {
                    std::cmp::Ordering::Less => true,
                    std::cmp::Ordering::Greater => false,
                    std::cmp::Ordering::Equal => {
                        out.push((x.0, x.1.min(y.1)));
                        ia += 1;
                        ib += 1;
                        continue;
                    }
                },
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (None, None) => unreachable!(),
            };
            if pick_a {
                out.push(a[ia]);
                ia += 1;
            } else {
                out.push(b[ib]);
                ib += 1;
            }
        }
        self.entries = out;
    }

    /// Number of distinct shapes.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no shape has been observed.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The sorted `(key, energy)` entries.
    pub fn entries(&self) -> &[(LayerKey, f64)] {
        &self.entries
    }

    /// Iterate the sorted entries.
    pub fn iter(&self) -> std::slice::Iter<'_, (LayerKey, f64)> {
        self.entries.iter()
    }

    /// Serialize as the checkpoint-v1 seeds array
    /// (`[{"bounds": [...], "stride": n, "energy_pj": x}, ...]`).
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.entries
                .iter()
                .map(|((bounds, stride), e)| {
                    Json::Obj(vec![
                        (
                            "bounds".into(),
                            Json::Arr(bounds.iter().map(|&b| Json::int(b)).collect()),
                        ),
                        ("stride".into(), Json::int(*stride as u64)),
                        ("energy_pj".into(), Json::num(*e)),
                    ])
                })
                .collect(),
        )
    }

    /// Parse the checkpoint-v1 seeds array.
    pub fn from_json(v: &Json) -> Result<SeedTable> {
        let mut entries = Vec::new();
        for s in v.as_arr()? {
            let mut bounds = [0u64; NDIMS];
            let arr = s.field("bounds")?.as_arr()?;
            if arr.len() != NDIMS {
                anyhow::bail!("seed bounds need {NDIMS} ints, got {}", arr.len());
            }
            for (i, b) in arr.iter().enumerate() {
                bounds[i] = b.as_u64()?;
            }
            entries.push((
                (bounds, s.field("stride")?.as_u64()? as u32),
                s.field("energy_pj")?.as_f64()?,
            ));
        }
        Ok(SeedTable::from_entries(entries))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(d0: u64, stride: u32) -> LayerKey {
        let mut bounds = [1u64; NDIMS];
        bounds[0] = d0;
        (bounds, stride)
    }

    #[test]
    fn from_entries_sorts_and_keeps_minimum() {
        let t = SeedTable::from_entries(vec![
            (key(3, 1), 30.0),
            (key(1, 1), 10.0),
            (key(3, 1), 25.0),
        ]);
        assert_eq!(t.len(), 2);
        assert_eq!(t.get(&key(1, 1)), Some(10.0));
        assert_eq!(t.get(&key(3, 1)), Some(25.0));
        assert_eq!(t.get(&key(2, 1)), None);
    }

    #[test]
    fn observe_keeps_minimum() {
        let mut t = SeedTable::new();
        t.observe(key(5, 1), 50.0);
        t.observe(key(5, 1), 40.0);
        t.observe(key(5, 1), 60.0);
        t.observe(key(2, 2), 7.0);
        assert_eq!(t.get(&key(5, 1)), Some(40.0));
        assert_eq!(t.get(&key(2, 2)), Some(7.0));
        // entries stay key-sorted
        let keys: Vec<LayerKey> = t.iter().map(|(k, _)| *k).collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
    }

    #[test]
    fn merge_is_min_per_key_and_commutative() {
        let a = SeedTable::from_entries(vec![(key(1, 1), 10.0), (key(2, 1), 5.0)]);
        let b = SeedTable::from_entries(vec![(key(2, 1), 3.0), (key(4, 1), 8.0)]);
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba);
        assert_eq!(ab.len(), 3);
        assert_eq!(ab.get(&key(2, 1)), Some(3.0));
        assert_eq!(ab.get(&key(1, 1)), Some(10.0));
        assert_eq!(ab.get(&key(4, 1)), Some(8.0));
    }

    #[test]
    fn merge_is_idempotent() {
        // Duplicate-coverage checkpoint dedup folds a checkpoint's seeds
        // into a merge that already contains them; self-merge must be a
        // no-op for that to be sound.
        let t = SeedTable::from_entries(vec![
            (key(1, 1), 10.0),
            (key(2, 1), 5.0),
            (key(4, 2), 0.1 + 0.2),
        ]);
        let mut m = t.clone();
        m.merge(&t);
        assert_eq!(m, t);
        for ((_, a), (_, b)) in m.iter().zip(t.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn json_round_trip_is_lossless() {
        let t = SeedTable::from_entries(vec![
            (key(7, 2), 0.1 + 0.2), // a value with awkward f64 bits
            (key(1, 1), f64::from_bits(0x3FF5_5555_5555_5555)),
        ]);
        let mut text = String::new();
        t.to_json().write(&mut text);
        let back = SeedTable::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(t, back);
        for ((_, a), (_, b)) in t.iter().zip(back.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn empty_table_basics() {
        let t = SeedTable::new();
        assert!(t.is_empty());
        assert_eq!(t.len(), 0);
        let mut m = t.clone();
        m.merge(&SeedTable::from_entries(vec![(key(1, 1), 1.0)]));
        assert_eq!(m.len(), 1);
    }
}
