//! Network-level resource co-optimization — the paper's headline §6.3
//! result (up to 4.2× CNN / 1.6× LSTM / 1.8× MLP energy at constant
//! throughput comes from *resource allocation*, not per-layer mapping).
//!
//! The subsystem has four parts:
//!
//! 1. **[`DesignSpace`]** — enumerates architecture points (RF / RF2 /
//!    GBUF sizes, array shapes, bus styles) under an optional on-chip
//!    capacity budget and the Observation-2 aggregate size-ratio rule
//!    ([`OBS2_RATIO_MIN`]..[`OBS2_RATIO_MAX`], widenable through
//!    documented knobs), replacing the grid that used to be hardcoded in
//!    `search_hierarchy`.
//! 2. **Cross-architecture branch-and-bound** ([`co_optimize`]) — all
//!    architecture points share one network-level
//!    [`Incumbent`](crate::engine::Incumbent). A point is abandoned as
//!    soon as its partial per-layer energy sum plus the remaining
//!    layers' compulsory-DRAM floors (the same floor formula as
//!    `EvalCtx::floor_pj` — MAC energy plus full weight and output
//!    top-level traffic, an admissible lower bound) exceeds the best
//!    completed network. Each surviving layer search additionally seeds
//!    its layer-level incumbent from the best-known architecture's
//!    same-layer result; because that borrowed seed is *not* admissible
//!    at the network level, a search whose result does not beat the seed
//!    is rerun against the admissible network bound alone, which
//!    restores exactness.
//! 3. **Chunked parallel evaluation** — architecture points are split
//!    into contiguous chunks over the safe
//!    [`parallel_map`](crate::search::parallel_map); the per-layer-shape
//!    dedup profile is computed once for the whole run and each chunk
//!    shares one [`DivisorCache`] across all of its points.
//! 4. **Iso-throughput mode** — [`NetOptConfig::min_tops`] excludes
//!    points below a throughput floor (the paper's constant-throughput
//!    comparison), and [`NetOptStats`] rolls up arch-point and engine
//!    counters for the `search-stats` report.
//! 5. **Multi-process sharding** (CLI `co-opt --shard I/N` +
//!    `co-opt-merge`) — [`DesignSpace::shard`] deterministically
//!    interleaves the grid across worker processes; each writes a
//!    [`ShardCheckpoint`] (winner, incumbent bound, seeds table, stats)
//!    as JSON, and [`merge_checkpoints`] combines them associatively
//!    into the bit-identical single-process winner.
//! 6. **Warm starts and mix weights** (serving-time remapping) — the
//!    best-known per-shape energies live in a [`SeedTable`] shared by
//!    the shard checkpoints and the on-line remapper
//!    (`coordinator::remap`): [`co_optimize_arches_seeded`] pre-loads a
//!    run's seeds from a table learned by earlier runs (hints only —
//!    the rerun fallback keeps the argmin exact), and
//!    [`NetOptConfig::layer_weights`] weights each layer's energy,
//!    cycles and floors by its serving-window frequency instead of the
//!    uniform layer sum, so the optimum tracks the live request mix.
//! 7. **Scout priming** ([`NetOptConfig::prime`]) — before the parallel
//!    sweep, the microsecond heuristic mapper ([`crate::fastmap`]) ranks
//!    the candidates and the heuristically best feasible point is
//!    evaluated *first*, synchronously, through the identical official
//!    point evaluator. Its completed total seeds the shared incumbent
//!    (or the frontier archive) from an admissible bound, so every
//!    later point prunes as hard as possible. This is purely an
//!    evaluation-order change over the same candidate set under the
//!    same admissible bounds, so winners and frontiers keep their exact
//!    bits; unlike the per-shape warm seeds it never needs a rerun.
//!    Off by default (bit-compatibility for checkpointed shard runs);
//!    the CLI turns it on.
//!
//! ## Winner-identity contract
//!
//! With `NetOptConfig::prune == BranchAndBound` the returned best point
//! (architecture *and* per-layer mappings, bit-for-bit) is identical to
//! the network-level exhaustive sweep, by the same argument as the
//! engine's layer-level pruning contract: the floors are admissible
//! (weights and outputs must each cross the top boundary at least once
//! in full), the per-layer bound only ever discards candidates that
//! cannot be part of a network beating the incumbent, and the seed-rerun
//! fallback removes the one inadmissible shortcut. Ties are broken by
//! enumeration order in both modes (stable sort over a shared
//! accumulation code path). `netopt::tests` asserts this equivalence on
//! small spaces; `benches/perf_netopt.rs` gates it in CI together with a
//! strict reduction in fully evaluated points.
//!
//! `search::optimize_network` and `search::search_hierarchy` are thin
//! compatibility shims over [`evaluate_network`] and [`co_optimize`].
//!
//! ## Vector bounds (Pareto mode)
//!
//! The multi-objective frontier subsystem (`crate::pareto`) runs on the
//! same point evaluator through the [`FrontierGate`] hook: instead of the
//! scalar incumbent, a partially evaluated point is abandoned when its
//! admissible `(energy, cycles)` lower-bound vector — the spent prefix
//! plus the compulsory energy floors and
//! [`cycle_floor`](crate::engine::cycle_floor)s of the remaining layers —
//! is strictly dominated by an already-completed point in the shared
//! dominance archive. Layer searches keep the cross-architecture seeds
//! as rerun-corrected hints but get **no scalar energy bound** (a
//! high-energy point may still be frontier-optimal in cycles), so every
//! surviving point's totals are bit-identical to the exhaustive
//! evaluation and the exact 2-D frontier is recovered.

mod seeds;
pub(crate) mod shard;
mod space;
mod stats;

pub use seeds::{LayerKey, SeedTable};
pub use shard::{
    co_optimize_shard, co_optimize_shard_with, co_optimize_sharded, merge_all, merge_checkpoints,
    ShardCheckpoint, ShardRun, CHECKPOINT_FORMAT,
};
pub use space::{DesignSpace, ShardEnumeration, SpaceEnumeration, OBS2_RATIO_MAX, OBS2_RATIO_MIN};
pub use stats::NetOptStats;

use std::collections::HashMap;
use std::sync::Mutex;

use crate::arch::Arch;
use crate::dataflow::Dataflow;
use crate::energy::CostModel;
use crate::engine::{DivisorCache, EvalSnapshot, Incumbent, PruneMode, PRUNE_SLACK};
use crate::loopnest::{Shape, Tensor};
use crate::nn::Network;
use crate::search::{
    optimize_layer_seeded, parallel_map, HierarchyResult, LayerOpt, NetworkOpt, SearchOpts,
};
use crate::telemetry;
use crate::util::json::Json;

/// Configuration of one [`co_optimize`] run.
#[derive(Debug, Clone)]
pub struct NetOptConfig {
    /// The fixed dataflow (Observation 1: `C|K` is near-optimal across
    /// hierarchies, so the co-optimizer does not sweep it).
    pub df: Dataflow,
    /// Per-layer search options. `opts.prune` controls the *layer-level*
    /// candidate pruning, independent of the network-level mode below.
    pub opts: SearchOpts,
    /// Worker threads: architecture points are sharded across them; any
    /// leftover parallelism goes to the per-layer searches.
    pub threads: usize,
    /// Network-level mode: branch-and-bound (default) abandons
    /// architecture points against the shared incumbent; exhaustive
    /// fully evaluates every point (the `search_hierarchy` shim's
    /// behavior, needed when the caller wants the whole ranking).
    pub prune: PruneMode,
    /// Iso-throughput constraint: fully evaluated points below this
    /// many TOPS (at [`clock_ghz`](Self::clock_ghz)) are excluded from
    /// the ranking and never set the incumbent.
    pub min_tops: Option<f64>,
    /// Clock used to convert cycles to TOPS for `min_tops`.
    pub clock_ghz: f64,
    /// Mix weights, one per network layer (finite, `> 0`): layer `i`
    /// contributes `w[i] ×` its energy and cycles to the network totals,
    /// and its compulsory floor scales the same way, so the optimizer
    /// minimizes the serving-mix expectation instead of the uniform
    /// layer sum. `None` is the uniform case and is **bit-identical** to
    /// the pre-weights behavior (all weights `1.0`).
    pub layer_weights: Option<Vec<f64>>,
    /// Scout priming: evaluate the heuristically best candidate
    /// ([`crate::fastmap::scout_candidates`]) first so the network-level
    /// incumbent / frontier archive starts from an admissible completed
    /// total. Winners and frontiers are bit-identical either way (it is
    /// only an evaluation-order change); priming strictly reduces the
    /// bound-side work on any space where the scout lands near the
    /// optimum. Ignored when network-level pruning is off (exhaustive
    /// mode ranks every point anyway). Default `false`.
    pub prime: bool,
}

impl NetOptConfig {
    /// Default configuration: `C|K` dataflow, network-level
    /// branch-and-bound, no throughput constraint, 1 GHz clock.
    pub fn new(opts: SearchOpts, threads: usize) -> Self {
        NetOptConfig {
            df: Dataflow::parse("C|K").unwrap(),
            opts,
            threads,
            prune: PruneMode::BranchAndBound,
            min_tops: None,
            clock_ghz: 1.0,
            layer_weights: None,
            prime: false,
        }
    }

    /// Like [`new`](Self::new) but with network-level pruning disabled,
    /// so every architecture point is fully evaluated and ranked.
    pub fn exhaustive(opts: SearchOpts, threads: usize) -> Self {
        NetOptConfig {
            prune: PruneMode::Exhaustive,
            ..Self::new(opts, threads)
        }
    }

    /// Same configuration with an iso-throughput floor.
    pub fn with_min_tops(mut self, min_tops: f64) -> Self {
        self.min_tops = Some(min_tops);
        self
    }

    /// Same configuration with per-layer mix weights (one per network
    /// layer, finite and `> 0` — validated at run start).
    pub fn with_layer_weights(mut self, weights: Vec<f64>) -> Self {
        self.layer_weights = Some(weights);
        self
    }

    /// Same configuration with scout priming switched on or off (see
    /// [`prime`](Self::prime)).
    pub fn with_prime(mut self, prime: bool) -> Self {
        self.prime = prime;
        self
    }
}

/// The outcome of [`co_optimize`].
#[derive(Debug, Clone)]
pub struct CoOptResult {
    /// Completed (non-abandoned, throughput-passing) architecture
    /// points: fully mapped points first, each group sorted by ascending
    /// network energy, ties in enumeration order. Under branch-and-bound
    /// this omits the abandoned points, and the *first* element is the
    /// identical, exact winner the exhaustive mode finds; later entries
    /// are upper bounds — their layer searches ran under the network
    /// bound, so a non-winning point's energies may exceed its true
    /// optima. Use the exhaustive mode (the `search_hierarchy` shim)
    /// when the whole ranking must be exact.
    pub ranked: Vec<HierarchyResult>,
    /// Arch-point and engine counter roll-up.
    pub stats: NetOptStats,
    /// Final best-known per-layer-shape energies of the run (warm seeds
    /// min-merged with what the run observed) — feed this back into
    /// [`co_optimize_arches_seeded`] to warm-start the next run.
    pub seeds: SeedTable,
}

impl CoOptResult {
    /// The winning fully-mapped point, if any architecture mapped every
    /// layer (and passed the throughput constraint).
    pub fn best(&self) -> Option<&HierarchyResult> {
        self.ranked.first().filter(|r| r.opt.unmapped == 0)
    }
}

/// Network-level bound consulted between layers of a point evaluation —
/// the generalization of the scalar [`Incumbent`] that lets the Pareto
/// subsystem (`crate::pareto`) plug its dominance archive into
/// [`run_points_gated`]'s machinery. One value is shared by every worker
/// chunk of a run, hence the `Sync` bound.
pub(crate) trait FrontierGate: Sync {
    /// Is the admissible `(energy, cycles)` lower-bound vector of a
    /// partially evaluated point already strictly dominated (beyond the
    /// pruning slack, in both coordinates) by an archived completed
    /// point? `true` abandons the point: its final totals can only be
    /// componentwise worse than the bound, so it can neither join the
    /// frontier nor displace a tie.
    fn dominated(&self, energy_lb_pj: f64, cycles_lb: f64) -> bool;

    /// A fully mapped, throughput-passing point completed with these
    /// totals. `index` is the global candidate index — the archive's
    /// deterministic tie-break key.
    fn observe(&self, index: usize, energy_pj: f64, cycles: f64);
}

/// How one run treats the network-level bound.
enum NetMode<'a> {
    /// No network-level pruning (exhaustive ranking, single-architecture
    /// evaluation).
    Off,
    /// Scalar energy branch-and-bound against the shared incumbent.
    Scalar(&'a Incumbent),
    /// Vector `(energy, cycles)` dominance pruning against a shared
    /// frontier archive. Layer searches still use the cross-architecture
    /// seeds as rerun-corrected hints, but no scalar energy bound.
    Frontier(&'a dyn FrontierGate),
}

/// One layer of the shared network profile.
struct ProfLayer {
    shape: Shape,
    key: LayerKey,
    /// Mix weight of this layer (`1.0` when no weights were given).
    weight: f64,
    /// Summed weight of this shape at this index or later (`>= weight`);
    /// tightens the per-occurrence bound for repeated layers (LSTM gate
    /// banks, VGG's repeated convs) and generalizes the old
    /// occurrence-count form to fractional mix weights.
    remaining_weight: f64,
}

/// Shape-dedup profile of the network, computed once and shared across
/// every architecture point of a run.
struct NetProfile {
    layers: Vec<ProfLayer>,
    /// Whether non-uniform weights are in play (selects the f64 MAC
    /// accumulation; the unweighted path keeps exact u64 totals).
    weighted: bool,
}

impl NetProfile {
    fn new(net: &Network, weights: Option<&[f64]>) -> Self {
        if let Some(w) = weights {
            assert_eq!(
                w.len(),
                net.layers.len(),
                "layer_weights length must match the network depth"
            );
            assert!(
                w.iter().all(|x| x.is_finite() && *x > 0.0),
                "layer weights must be finite and positive"
            );
        }
        let mut layers: Vec<ProfLayer> = net
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| ProfLayer {
                shape: l.shape,
                key: (l.shape.bounds, l.shape.stride),
                weight: weights.map(|w| w[i]).unwrap_or(1.0),
                remaining_weight: 0.0,
            })
            .collect();
        let mut seen: HashMap<LayerKey, f64> = HashMap::new();
        for pl in layers.iter_mut().rev() {
            let c = seen.entry(pl.key).or_insert(0.0);
            *c += pl.weight;
            pl.remaining_weight = *c;
        }
        NetProfile {
            layers,
            weighted: weights.is_some(),
        }
    }

    /// Per-layer compulsory energy floors (unweighted, per single
    /// occurrence) and the *weighted* suffix sums (`suffix[i]` = weighted
    /// floors of layers `i..`; `suffix[len]` = 0). The floor is
    /// `EvalCtx::floor_pj`'s formula: MAC energy plus full weight and
    /// output traffic across the top (DRAM) boundary — a rigorous lower
    /// bound on any mapping's energy (the input floor is deliberately
    /// omitted, exactly as in the engine). With uniform weights the
    /// suffix is bit-identical to the unweighted sum (`1.0 × x == x`).
    fn floors(&self, arch: &Arch, cost: &dyn CostModel) -> (Vec<f64>, Vec<f64>) {
        let top = cost.level_access(arch, arch.num_levels() - 1);
        let n = self.layers.len();
        let mut per = Vec::with_capacity(n);
        for pl in &self.layers {
            let mac_energy = pl.shape.macs() as f64 * cost.mac();
            let w_floor = pl.shape.tensor_elems(Tensor::Weight) as f64 * top;
            let o_floor = pl.shape.tensor_elems(Tensor::Output) as f64 * top;
            per.push(mac_energy + w_floor + o_floor);
        }
        let mut suffix = vec![0.0; n + 1];
        for i in (0..n).rev() {
            suffix[i] = self.layers[i].weight * per[i] + suffix[i + 1];
        }
        (per, suffix)
    }

    /// The cycles half of the Pareto mode's vector bound, mirroring
    /// [`floors`](Self::floors)' suffix: `suffix[i]` is the weighted sum
    /// of the admissible per-layer cycle floors
    /// ([`crate::engine::cycle_floor`] — MACs at full-array utilization
    /// vs compulsory DRAM traffic at full bandwidth, whichever binds)
    /// over layers `i..`; `suffix[len]` = 0.
    fn cycle_floors(&self, arch: &Arch) -> Vec<f64> {
        let n = self.layers.len();
        let mut suffix = vec![0.0; n + 1];
        for i in (0..n).rev() {
            let floor = crate::engine::cycle_floor(&self.layers[i].shape, arch);
            suffix[i] = self.layers[i].weight * floor + suffix[i + 1];
        }
        suffix
    }
}

/// How one architecture point ended.
enum PointEval {
    /// Every layer evaluated (possibly with unmapped layers, which make
    /// the point infeasible). `passes_tops` is the `min_tops` gate,
    /// computed once here so incumbent admission and ranking admission
    /// can never disagree.
    Complete { opt: NetworkOpt, passes_tops: bool },
    /// Abandoned by the network-level bound (or a bounded layer search
    /// that came back empty): this point cannot beat the incumbent.
    Pruned,
}

/// Per-point evaluation report.
struct PointReport {
    eval: PointEval,
    engine: EvalSnapshot,
    searches: usize,
    reruns: usize,
}

/// Everything shared by the worker shards of one run.
struct NetRun<'a> {
    profile: &'a NetProfile,
    df: &'a Dataflow,
    cost: &'a dyn CostModel,
    opts: &'a SearchOpts,
    /// Threads handed to each per-layer search.
    threads: usize,
    /// Network-level bound mode (off / scalar incumbent / frontier).
    mode: NetMode<'a>,
    min_tops: Option<f64>,
    clock_ghz: f64,
    /// Best-known per-layer-shape energies (from completed feasible
    /// points), used to seed layer searches on other architectures.
    seeds: &'a Mutex<HashMap<LayerKey, f64>>,
    /// Telemetry parent for per-point spans: worker threads have empty
    /// span stacks, so their point spans attach under the sweep's root
    /// span explicitly (0 = no sweep span; telemetry never steers).
    trace_parent: u64,
}

impl NetRun<'_> {
    fn evaluate_point(&self, idx: usize, arch: &Arch, cache: &mut DivisorCache) -> PointReport {
        let _pspan = telemetry::span_under("search", "point", self.trace_parent, || {
            vec![
                ("idx".into(), Json::int(idx as u64)),
                ("arch".into(), Json::str(&arch.name)),
            ]
        });
        let (floor_l, suffix) = self.profile.floors(arch, self.cost);
        // The cycles suffix is only consulted by the vector bound.
        let cycle_suffix = match self.mode {
            NetMode::Frontier(_) => Some(self.profile.cycle_floors(arch)),
            _ => None,
        };
        let layer_bnb = self.opts.prune == PruneMode::BranchAndBound;
        let use_seeds = layer_bnb && !matches!(self.mode, NetMode::Off);
        let nlayers = self.profile.layers.len();
        let mut shape_results: HashMap<LayerKey, Option<LayerOpt>> = HashMap::new();
        let mut per_layer: Vec<Option<LayerOpt>> = Vec::with_capacity(nlayers);
        let mut total_e = 0.0;
        let mut total_c = 0.0;
        let mut total_m = 0u64;
        let mut total_m_f = 0.0f64; // weighted-mode MAC accumulator
        let mut unmapped_layers: Vec<usize> = Vec::new();
        let mut engine = EvalSnapshot::default();
        let mut searches = 0usize;
        let mut reruns = 0usize;

        for (li, pl) in self.profile.layers.iter().enumerate() {
            let inc = match self.mode {
                NetMode::Scalar(inc) => inc.get(),
                _ => f64::INFINITY,
            };
            // Admissible abandon check: even if every remaining layer
            // only paid its compulsory floor, the point cannot beat the
            // incumbent.
            if total_e + suffix[li] > inc * (1.0 + PRUNE_SLACK) {
                telemetry::counter("search", "points_pruned", 1);
                return PointReport {
                    eval: PointEval::Pruned,
                    engine,
                    searches,
                    reruns,
                };
            }
            // Vector abandon check: the point's admissible lower-bound
            // vector — spent prefix plus the remaining layers' energy and
            // cycle floors — is strictly dominated by a completed point.
            if let (NetMode::Frontier(gate), Some(cyc)) = (&self.mode, &cycle_suffix) {
                if gate.dominated(total_e + suffix[li], total_c + cyc[li]) {
                    telemetry::counter("search", "points_pruned", 1);
                    return PointReport {
                        eval: PointEval::Pruned,
                        engine,
                        searches,
                        reruns,
                    };
                }
            }
            // Admissible per-occurrence bound for this layer's search:
            // the incumbent minus what is already spent and the floors
            // of the *other* remaining layers, split across the
            // remaining (mix-weighted) occurrences of this same shape.
            // With uniform weights this is bit-identical to the old
            // occurrence-count form.
            let rem_w = pl.remaining_weight;
            let net_bound = if inc.is_finite() {
                (inc - total_e - suffix[li + 1] + (rem_w - pl.weight) * floor_l[li]) / rem_w
            } else {
                f64::INFINITY
            };
            let cached = shape_results.get(&pl.key).cloned();
            let entry = match cached {
                Some(e) => e,
                None => {
                    let seed = if use_seeds {
                        let m = self.seeds.lock().expect("netopt seeds lock");
                        m.get(&pl.key).copied().unwrap_or(f64::INFINITY)
                    } else {
                        f64::INFINITY
                    };
                    let bound0 = if layer_bnb {
                        net_bound.min(seed)
                    } else {
                        f64::INFINITY
                    };
                    searches += 1;
                    let lspan = telemetry::span_with("engine", "layer_search", || {
                        vec![("layer".into(), Json::int(li as u64))]
                    });
                    let (mut lo, snap) = optimize_layer_seeded(
                        &pl.shape,
                        arch,
                        self.df,
                        self.cost,
                        self.opts,
                        self.threads,
                        bound0,
                        cache,
                    );
                    drop(lspan);
                    engine.absorb(&snap);
                    // The borrowed cross-architecture seed is not
                    // admissible at the network level: if it was the
                    // binding constraint and no candidate beat it, the
                    // result may be clipped — rerun against the
                    // admissible network bound alone.
                    let clipped = match lo {
                        Some(ref l) => l.result.energy_pj > seed,
                        None => true,
                    };
                    if layer_bnb && seed < net_bound && clipped {
                        reruns += 1;
                        let rspan = telemetry::span_with("engine", "layer_search", || {
                            vec![
                                ("layer".into(), Json::int(li as u64)),
                                ("rerun".into(), Json::Bool(true)),
                            ]
                        });
                        let (lo2, snap2) = optimize_layer_seeded(
                            &pl.shape,
                            arch,
                            self.df,
                            self.cost,
                            self.opts,
                            self.threads,
                            net_bound,
                            cache,
                        );
                        drop(rspan);
                        engine.absorb(&snap2);
                        lo = lo2;
                    }
                    if lo.is_none() && layer_bnb && net_bound.is_finite() {
                        // Unmappable or fully pruned under an admissible
                        // bound — either way the point cannot win.
                        telemetry::counter("search", "points_pruned", 1);
                        return PointReport {
                            eval: PointEval::Pruned,
                            engine,
                            searches,
                            reruns,
                        };
                    }
                    shape_results.insert(pl.key, lo.clone());
                    lo
                }
            };
            match entry {
                Some(lo) => {
                    // `1.0 × x == x` exactly, so the uniform case keeps
                    // the pre-weights bits.
                    total_e += pl.weight * lo.result.energy_pj;
                    total_c += pl.weight * lo.result.cycles;
                    if self.profile.weighted {
                        total_m_f += pl.weight * lo.result.macs as f64;
                    } else {
                        total_m += lo.result.macs;
                    }
                    per_layer.push(Some(lo));
                }
                None => {
                    unmapped_layers.push(li);
                    per_layer.push(None);
                }
            }
        }

        let opt = NetworkOpt {
            per_layer,
            total_energy_pj: total_e,
            total_cycles: total_c,
            total_macs: if self.profile.weighted {
                total_m_f.round() as u64
            } else {
                total_m
            },
            unmapped: unmapped_layers.len(),
            unmapped_layers,
        };
        let meets_tops = match self.min_tops {
            Some(mt) => opt.tops(self.clock_ghz) >= mt,
            None => true,
        };
        let feasible = opt.unmapped == 0 && meets_tops;
        if feasible && !matches!(self.mode, NetMode::Off) {
            match &self.mode {
                NetMode::Scalar(inc) => {
                    // The pre-observe load is telemetry-only: `observe`
                    // still makes the real CAS decision, so the bound's
                    // bits are unchanged with tracing on. Racy reads can
                    // only under-report tightenings, never misreport one.
                    let before = inc.get();
                    inc.observe(opt.total_energy_pj);
                    if opt.total_energy_pj < before {
                        telemetry::event("search", "bound_tighten", || {
                            vec![
                                ("idx".into(), Json::int(idx as u64)),
                                ("from_pj".into(), Json::num(before)),
                                ("to_pj".into(), Json::num(opt.total_energy_pj)),
                            ]
                        });
                    }
                }
                NetMode::Frontier(gate) => {
                    gate.observe(idx, opt.total_energy_pj, opt.total_cycles)
                }
                NetMode::Off => unreachable!(),
            }
            let mut m = self.seeds.lock().expect("netopt seeds lock");
            for (k, v) in &shape_results {
                if let Some(lo) = v {
                    let e = m.entry(*k).or_insert(f64::INFINITY);
                    if lo.result.energy_pj < *e {
                        *e = lo.result.energy_pj;
                    }
                }
            }
        }
        if telemetry::enabled() {
            // Live per-stage engine counters, emitted from the worker
            // thread as each point completes (pruned points' residual
            // counts are folded into the end-of-run gauges).
            telemetry::counter("engine", "stage2", engine.stage2);
            telemetry::counter("engine", "fit_rejected", engine.fit_rejected);
            telemetry::counter("engine", "stage3", engine.stage3);
            telemetry::counter("engine", "stage3_pruned", engine.pruned);
            telemetry::counter("engine", "full", engine.full);
            telemetry::counter("search", "points_evaluated_full", 1);
        }
        PointReport {
            eval: PointEval::Complete {
                opt,
                passes_tops: meets_tops,
            },
            engine,
            searches,
            reruns,
        }
    }
}

/// Evaluate one network on one architecture — shape-deduplicated
/// per-layer searches, unmapped-layer tracking, no cross-architecture
/// bound. The backend of the `search::optimize_network` shim.
pub fn evaluate_network(
    net: &Network,
    arch: &Arch,
    df: &Dataflow,
    cost: &dyn CostModel,
    opts: &SearchOpts,
    threads: usize,
) -> NetworkOpt {
    let profile = NetProfile::new(net, None);
    let seeds: Mutex<HashMap<LayerKey, f64>> = Mutex::new(HashMap::new());
    let run = NetRun {
        profile: &profile,
        df,
        cost,
        opts,
        threads,
        mode: NetMode::Off,
        min_tops: None,
        clock_ghz: 1.0,
        seeds: &seeds,
        trace_parent: 0,
    };
    let mut cache = DivisorCache::new();
    match run.evaluate_point(0, arch, &mut cache).eval {
        PointEval::Complete { opt, .. } => opt,
        PointEval::Pruned => unreachable!("no network bound when the mode is Off"),
    }
}

/// Output of [`run_points`]: the evaluator's view of one candidate set,
/// before the caller layers on the space-generation counters. The shard
/// path serializes `incumbent_pj` and `seeds` into its checkpoint so a
/// future resume (or the merge report) can see the final bounds.
pub(crate) struct RunOutput {
    /// Completed, throughput-passing points tagged with their **global**
    /// candidate index, sorted fully-mapped-first, then ascending energy,
    /// ties by ascending index (== enumeration order).
    pub ranked: Vec<(usize, HierarchyResult)>,
    /// Evaluation counters: `candidates`, `pruned`, `evaluated_full`,
    /// `infeasible`, `throughput_filtered`, layer-search and engine
    /// roll-ups. The three space counters (`generated`,
    /// `budget_filtered`, `ratio_filtered`) are left zero for the caller.
    pub stats: NetOptStats,
    /// Final network-level incumbent bound (+inf when nothing completed
    /// or network-level pruning was off).
    pub incumbent_pj: f64,
    /// Final best-known per-layer-shape energies (any warm seeds
    /// min-merged with what the run observed).
    pub seeds: SeedTable,
}

/// The contract-critical total order over completed points: fully mapped
/// first, then ascending energy, ties by ascending **global** candidate
/// index (== enumeration order). The single source of truth shared by
/// [`run_points`] and the sharded union re-sort — the sharded /
/// single-process winner-identity contract requires the two to stay
/// bit-identical forever.
pub(crate) fn rank_order(
    (ia, a): &(usize, HierarchyResult),
    (ib, b): &(usize, HierarchyResult),
) -> std::cmp::Ordering {
    let feasibility = a.opt.unmapped.cmp(&b.opt.unmapped);
    let energy = a.opt.total_energy_pj.partial_cmp(&b.opt.total_energy_pj);
    feasibility.then(energy.unwrap()).then(ia.cmp(ib))
}

/// Evaluate an explicit, index-tagged candidate list (ascending indices)
/// under one shared network incumbent — the core of [`co_optimize`],
/// [`co_optimize_arches`], and the per-shard runner
/// ([`co_optimize_shard`]). Work is split into contiguous chunks over
/// [`parallel_map`]; each chunk shares one divisor cache across all of
/// its architecture points. `warm` pre-loads the cross-architecture
/// seeds table (hints only — the rerun fallback keeps the winner exact;
/// see [`co_optimize_arches_seeded`]).
pub(crate) fn run_points(
    net: &Network,
    cands: Vec<(usize, Arch)>,
    cost: &dyn CostModel,
    cfg: &NetOptConfig,
    warm: Option<&SeedTable>,
) -> RunOutput {
    run_points_gated(net, cands, cost, cfg, warm, None, None)
}

/// [`run_points`] with an optional [`FrontierGate`] and an optional
/// externally shared [`Incumbent`]. When `gate` is given, the
/// network-level bound is the gate's dominance archive (`cfg.prune` is
/// ignored — the gate *is* the pruning mode) and every completed
/// feasible point is reported to it; the `crate::pareto` entry points
/// are the only gated callers. When `shared` is given (scalar mode
/// only — callers pass at most one of `gate`/`shared`), the run prunes
/// against and reports completions to the caller's incumbent instead of
/// a run-local one, which is how the orchestrator's streamed workers
/// fold foreign bounds into a live sweep (`netopt::co_optimize_shard_with`
/// documents the admissibility argument). Otherwise `cfg.prune` selects
/// the scalar incumbent or exhaustive evaluation as before.
pub(crate) fn run_points_gated(
    net: &Network,
    cands: Vec<(usize, Arch)>,
    cost: &dyn CostModel,
    cfg: &NetOptConfig,
    warm: Option<&SeedTable>,
    gate: Option<&dyn FrontierGate>,
    shared: Option<&Incumbent>,
) -> RunOutput {
    let n = cands.len();
    let mut stats = NetOptStats {
        candidates: n,
        ..Default::default()
    };
    if n == 0 {
        return RunOutput {
            ranked: Vec::new(),
            stats,
            incumbent_pj: f64::INFINITY,
            seeds: warm.cloned().unwrap_or_default(),
        };
    }
    let profile = NetProfile::new(net, cfg.layer_weights.as_deref());
    let local_incumbent = Incumbent::new();
    let incumbent = shared.unwrap_or(&local_incumbent);
    let seed_map: HashMap<LayerKey, f64> = warm
        .map(|t| t.iter().copied().collect())
        .unwrap_or_default();
    let seeds: Mutex<HashMap<LayerKey, f64>> = Mutex::new(seed_map);
    let nchunks = cfg.threads.max(1).min(n);
    let mode = match gate {
        Some(g) => NetMode::Frontier(g),
        None if cfg.prune == PruneMode::BranchAndBound => NetMode::Scalar(incumbent),
        None => NetMode::Off,
    };
    let sweep_span = telemetry::span_with("search", "run_points", || {
        vec![
            ("candidates".into(), Json::int(n as u64)),
            ("network".into(), Json::str(&net.name)),
        ]
    });
    let run = NetRun {
        profile: &profile,
        df: &cfg.df,
        cost,
        opts: &cfg.opts,
        threads: (cfg.threads / nchunks).max(1),
        mode,
        min_tops: cfg.min_tops,
        clock_ghz: cfg.clock_ghz,
        seeds: &seeds,
        trace_parent: sweep_span.id(),
    };

    // Scout priming: evaluate the heuristically best feasible candidate
    // first, synchronously, through the identical official evaluator, so
    // the shared incumbent / dominance archive starts from an admissible
    // completed total instead of +inf. A pure evaluation-order change
    // over the same candidate set under the same admissible bounds —
    // winners and frontiers keep their exact bits (property-tested in
    // `fastmap::tests`). With `prime` off (the default) the chunking
    // below is bit-identical to the unprimed code path.
    let primed = cfg.prime && (gate.is_some() || cfg.prune == PruneMode::BranchAndBound);
    let scout: Option<usize> = if primed {
        crate::fastmap::scout_candidates(
            net,
            &cands,
            &cfg.df,
            cost,
            cfg.layer_weights.as_deref(),
            cfg.min_tops,
            cfg.clock_ghz,
        )
    } else {
        None
    };
    let mut reports: Vec<(usize, PointReport)> = Vec::new();
    if let Some(pos) = scout {
        let (i, arch) = &cands[pos];
        telemetry::event("search", "prime", || {
            vec![
                ("idx".into(), Json::int(*i as u64)),
                ("arch".into(), Json::str(&arch.name)),
            ]
        });
        let mut cache = DivisorCache::new();
        reports.push((*i, run.evaluate_point(*i, arch, &mut cache)));
    }
    let sweep: Vec<(usize, Arch)> = cands
        .iter()
        .enumerate()
        .filter(|(pos, _)| Some(*pos) != scout)
        .map(|(_, c)| c.clone())
        .collect();
    if !sweep.is_empty() {
        let nch = nchunks.min(sweep.len());
        let chunk = sweep.len().div_ceil(nch);
        let chunks: Vec<Vec<(usize, Arch)>> = sweep.chunks(chunk).map(|c| c.to_vec()).collect();
        reports.extend(
            parallel_map(chunks, nch, |chunk| {
                let mut cache = DivisorCache::new();
                chunk
                    .iter()
                    .map(|(i, arch)| (*i, run.evaluate_point(*i, arch, &mut cache)))
                    .collect::<Vec<_>>()
            })
            .into_iter()
            .flatten(),
        );
    }

    let arch_by_idx: HashMap<usize, &Arch> = cands.iter().map(|(i, a)| (*i, a)).collect();
    let mut ranked: Vec<(usize, HierarchyResult)> = Vec::new();
    for (idx, report) in reports {
        stats.engine.absorb(&report.engine);
        stats.layer_searches += report.searches;
        stats.layer_reruns += report.reruns;
        match report.eval {
            PointEval::Pruned => stats.pruned += 1,
            PointEval::Complete { opt, passes_tops } => {
                stats.evaluated_full += 1;
                if opt.unmapped > 0 {
                    stats.infeasible += 1;
                }
                if !passes_tops {
                    stats.throughput_filtered += 1;
                    continue;
                }
                ranked.push((
                    idx,
                    HierarchyResult {
                        arch: arch_by_idx[&idx].clone(),
                        opt,
                    },
                ));
            }
        }
    }
    // The exhaustive/B&B and the sharded/single-process winner-identity
    // contracts both rely on `rank_order` being reconstructible from any
    // subset of points.
    ranked.sort_by(rank_order);
    if telemetry::enabled() {
        // End-of-run roll-ups: totals including pruned points' residual
        // engine work, which the live per-point counters elide.
        telemetry::gauge("engine", "stage2_total", stats.engine.stage2 as f64);
        telemetry::gauge("engine", "fit_rejected_total", stats.engine.fit_rejected as f64);
        telemetry::gauge("engine", "stage3_total", stats.engine.stage3 as f64);
        telemetry::gauge("engine", "stage3_pruned_total", stats.engine.pruned as f64);
        telemetry::gauge("engine", "full_total", stats.engine.full as f64);
        telemetry::gauge("search", "points_evaluated_full", stats.evaluated_full as f64);
        telemetry::gauge("search", "points_pruned", stats.pruned as f64);
        telemetry::gauge("search", "incumbent_pj", incumbent.get());
    }
    drop(sweep_span);
    let seeds = seeds.into_inner().expect("netopt seeds lock");
    RunOutput {
        ranked,
        stats,
        incumbent_pj: incumbent.get(),
        seeds: SeedTable::from_entries(seeds.into_iter().collect()),
    }
}

/// Co-optimize a network across a whole architecture design space: run
/// the per-layer optimizer on every (surviving) architecture point,
/// sharing a network-level incumbent, layer-shape dedup, and per-chunk
/// divisor caches. See the module docs for the bound construction and
/// the winner-identity contract.
pub fn co_optimize(
    net: &Network,
    space: &DesignSpace,
    cost: &dyn CostModel,
    cfg: &NetOptConfig,
) -> CoOptResult {
    let enumeration = space.enumerate();
    let cands: Vec<(usize, Arch)> = enumeration.candidates.into_iter().enumerate().collect();
    let mut out = run_points(net, cands, cost, cfg, None);
    out.stats.generated = enumeration.generated;
    out.stats.budget_filtered = enumeration.budget_filtered;
    out.stats.ratio_filtered = enumeration.ratio_filtered;
    CoOptResult {
        ranked: out.ranked.into_iter().map(|(_, r)| r).collect(),
        stats: out.stats,
        seeds: out.seeds,
    }
}

/// [`co_optimize`] over an explicit architecture list instead of a
/// generated [`DesignSpace`] — the entry point for callers whose points
/// are not grid-expressible (multi-SRAM hierarchies like the TPU-like
/// baseline, serving-time remapping candidates). The list is the whole
/// "space": `generated == candidates == arches.len()`, no filters.
pub fn co_optimize_arches(
    net: &Network,
    arches: &[Arch],
    cost: &dyn CostModel,
    cfg: &NetOptConfig,
) -> CoOptResult {
    let cands: Vec<(usize, Arch)> = arches.iter().cloned().enumerate().collect();
    let mut out = run_points(net, cands, cost, cfg, None);
    out.stats.generated = arches.len();
    CoOptResult {
        ranked: out.ranked.into_iter().map(|(_, r)| r).collect(),
        stats: out.stats,
        seeds: out.seeds,
    }
}

/// [`co_optimize_arches`] warm-started from a [`SeedTable`] — the
/// serving-time remapping entry point (`coordinator::remap`). The table
/// pre-loads the run's cross-architecture per-shape seeds, so layer
/// searches start bounded by everything earlier runs learned.
///
/// **Exactness contract:** seeds are hints, never trusted results. A
/// borrowed seed is not admissible at the network level, so any layer
/// search whose outcome it clips is rerun against the admissible network
/// bound alone (the same fallback the in-run seeding uses). Therefore an
/// *arbitrary* table — stale, from another mix, even adversarial —
/// returns the identical winner (architecture, energy bits, per-layer
/// mappings) as the cold [`co_optimize_arches`] run, with at most as
/// many fully evaluated architecture points. Asserted by the randomized
/// property test in `netopt::tests`.
pub fn co_optimize_arches_seeded(
    net: &Network,
    arches: &[Arch],
    cost: &dyn CostModel,
    cfg: &NetOptConfig,
    warm: &SeedTable,
) -> CoOptResult {
    let cands: Vec<(usize, Arch)> = arches.iter().cloned().enumerate().collect();
    let mut out = run_points(net, cands, cost, cfg, Some(warm));
    out.stats.generated = arches.len();
    CoOptResult {
        ranked: out.ranked.into_iter().map(|(_, r)| r).collect(),
        stats: out.stats,
        seeds: out.seeds,
    }
}

#[cfg(test)]
mod tests;
