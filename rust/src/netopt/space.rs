//! Architecture design-space generation for the network-level resource
//! co-optimizer: the RF / RF2 / GBUF / array / bus grid, an optional
//! on-chip capacity budget, and the paper's Observation-2 inter-level
//! size-ratio rule.

use crate::arch::{Arch, ArrayBus, ArrayShape, MemLevel};

/// Observation 2 (§6.3): each on-chip storage level should be roughly
/// 4×–16× larger than the level below it **in aggregate** (register
/// levels are per-PE, so their aggregate size is `size × PEs`). These
/// constants are the paper's bounds; widen them only through the
/// documented [`DesignSpace::ratio_min`] / [`DesignSpace::ratio_max`]
/// knobs.
pub const OBS2_RATIO_MIN: f64 = 4.0;
/// Upper bound of the Observation-2 ratio rule (see [`OBS2_RATIO_MIN`]).
pub const OBS2_RATIO_MAX: f64 = 16.0;

/// The architecture grid the co-optimizer sweeps: memory sizes, array
/// shapes, and bus styles, filtered by an optional on-chip capacity
/// budget and the Observation-2 ratio rule.
#[derive(Debug, Clone)]
pub struct DesignSpace {
    /// First-level (per-PE) register file sizes, bytes.
    pub rf1_sizes: Vec<u64>,
    /// Second-level RF sizes as multiples of the first level (Observation
    /// 2 applied between the two register levels). Empty disables
    /// two-level points; single-level points are always generated.
    pub rf2_ratios: Vec<u64>,
    /// Cap on the second-level RF size, bytes (larger points skipped).
    pub rf2_max_bytes: u64,
    /// Shared buffer sizes, bytes.
    pub gbuf_sizes: Vec<u64>,
    /// PE array shapes to sweep.
    pub arrays: Vec<ArrayShape>,
    /// Interconnect styles to sweep.
    pub buses: Vec<ArrayBus>,
    /// Word size in bytes.
    pub word_bytes: u32,
    /// DRAM bandwidth, bytes per cycle.
    pub dram_bw_bytes_per_cycle: f64,
    /// Optional on-chip capacity budget: points whose
    /// [`Arch::onchip_bytes`] exceeds it are dropped (counted in
    /// [`SpaceEnumeration::budget_filtered`]).
    pub max_onchip_bytes: Option<u64>,
    /// Lower bound of the aggregate inter-level size-ratio filter.
    /// Defaults to [`OBS2_RATIO_MIN`]; lowering it is a deliberate,
    /// documented widening of the paper's rule (e.g. for equivalence
    /// tests that want the unfiltered grid).
    pub ratio_min: f64,
    /// Upper bound of the ratio filter; defaults to [`OBS2_RATIO_MAX`].
    pub ratio_max: f64,
}

/// The outcome of [`DesignSpace::enumerate`]: surviving candidates plus
/// the filter counts the `search-stats` report and [`super::NetOptStats`]
/// surface.
#[derive(Debug, Clone)]
pub struct SpaceEnumeration {
    /// Candidates that passed every filter, in deterministic grid order.
    pub candidates: Vec<Arch>,
    /// Raw grid points before filtering.
    pub generated: usize,
    /// Points dropped by the capacity budget.
    pub budget_filtered: usize,
    /// Points dropped by the Observation-2 ratio rule.
    pub ratio_filtered: usize,
}

/// One shard of the design space ([`DesignSpace::shard`]): the candidates
/// assigned to shard `index` of `nshards`, each tagged with its **global
/// raw-grid index** (the cross-shard tie-break key for the merge step),
/// plus this shard's share of the filter counts. Because assignment is
/// per-raw-point, the counts satisfy the same partition identity as the
/// whole space (`generated == budget_filtered + ratio_filtered +
/// candidates.len()`) shard-by-shard, and summing any disjoint set of
/// shards reproduces the corresponding [`SpaceEnumeration`] counts
/// exactly — the associativity the checkpoint merge relies on.
#[derive(Debug, Clone)]
pub struct ShardEnumeration {
    /// `(global raw-grid index, arch)` pairs, ascending by index.
    pub candidates: Vec<(usize, Arch)>,
    /// Raw grid points assigned to this shard.
    pub generated: usize,
    /// Assigned points dropped by the capacity budget.
    pub budget_filtered: usize,
    /// Assigned points dropped by the Observation-2 ratio rule.
    pub ratio_filtered: usize,
}

impl DesignSpace {
    /// The §6.3 auto-optimizer's default grid on a fixed PE array: the
    /// paper's RF sizes, 4/8/16× second-level RF steps, the three mobile
    /// buffer sizes, a systolic bus, and the strict Observation-2 filter.
    /// (This replaces the old `search_hierarchy` hardcoded grid, whose
    /// ratio loop only ever ran at 8× and whose filter accepted
    /// 0.25–64×.)
    pub fn paper_default(array: ArrayShape) -> Self {
        DesignSpace {
            rf1_sizes: vec![16, 32, 64, 128, 512],
            rf2_ratios: vec![4, 8, 16],
            rf2_max_bytes: 1024,
            gbuf_sizes: vec![64 << 10, 128 << 10, 256 << 10],
            arrays: vec![array],
            buses: vec![ArrayBus::Systolic],
            word_bytes: 2,
            dram_bw_bytes_per_cycle: 16.0,
            max_onchip_bytes: None,
            ratio_min: OBS2_RATIO_MIN,
            ratio_max: OBS2_RATIO_MAX,
        }
    }

    /// The richer default grid behind `co-opt`/`pareto` `--space full`:
    /// [`paper_default`](Self::paper_default) widened with the
    /// generator's array-shape and bus-style axes (8×8 / 16×16 / 32×32
    /// PE arrays — plus the requested array when it is none of those —
    /// and both interconnect styles). `paper_default` itself is
    /// untouched, so the paper-parity sweeps stay bit-identical.
    pub fn full(array: ArrayShape) -> Self {
        let mut s = Self::paper_default(array);
        s.arrays = vec![
            ArrayShape { rows: 8, cols: 8 },
            ArrayShape { rows: 16, cols: 16 },
            ArrayShape { rows: 32, cols: 32 },
        ];
        if !s.arrays.contains(&array) {
            s.arrays.push(array);
        }
        s.buses = vec![ArrayBus::Systolic, ArrayBus::Broadcast];
        s
    }

    /// Does `arch` satisfy this space's aggregate inter-level size-ratio
    /// rule (Observation 2, possibly widened)?
    pub fn obs2_ok(&self, arch: &Arch) -> bool {
        arch.onchip_level_bytes().windows(2).all(|w| {
            let r = w[1] as f64 / w[0] as f64;
            r >= self.ratio_min && r <= self.ratio_max
        })
    }

    /// The raw grid in deterministic enumeration order, before any
    /// filtering. Shared by [`enumerate`](Self::enumerate) (the whole
    /// space) and [`shard`](Self::shard) (one interleaved slice), so a
    /// point's raw-grid index is identical however the space is consumed.
    fn raw_grid(&self) -> Vec<Arch> {
        let mut raw: Vec<Arch> = Vec::new();
        for &array in &self.arrays {
            for &bus in &self.buses {
                for &rf in &self.rf1_sizes {
                    for &gbuf in &self.gbuf_sizes {
                        raw.push(self.point(array, bus, &[rf], gbuf));
                        for &ratio in &self.rf2_ratios {
                            let rf2 = rf * ratio;
                            if rf2 > self.rf2_max_bytes {
                                continue;
                            }
                            raw.push(self.point(array, bus, &[rf, rf2], gbuf));
                        }
                    }
                }
            }
        }
        raw
    }

    /// Enumerate the grid and apply the budget and ratio filters,
    /// reporting how many points each filter removed.
    pub fn enumerate(&self) -> SpaceEnumeration {
        let mut raw = self.raw_grid();
        let generated = raw.len();
        if let Some(budget) = self.max_onchip_bytes {
            raw.retain(|a| a.onchip_bytes() <= budget);
        }
        let budget_filtered = generated - raw.len();
        raw.retain(|a| self.obs2_ok(a));
        let ratio_filtered = generated - budget_filtered - raw.len();
        SpaceEnumeration {
            candidates: raw,
            generated,
            budget_filtered,
            ratio_filtered,
        }
    }

    /// Deterministic shard `index` of `nshards`: raw grid point `i` is
    /// assigned to shard `i % nshards` (stable interleaving — neighboring
    /// grid points have similar search cost, so round-robin balances the
    /// shard loads far better than contiguous ranges), then the budget and
    /// ratio filters run on the assigned subset. The union of all
    /// `nshards` shards is exactly [`enumerate`](Self::enumerate), with
    /// candidates tagged by their global raw-grid index.
    pub fn shard(&self, index: usize, nshards: usize) -> ShardEnumeration {
        assert!(nshards >= 1, "need at least one shard");
        assert!(index < nshards, "shard index {index} out of 0..{nshards}");
        let mut generated = 0usize;
        let mut budget_filtered = 0usize;
        let mut ratio_filtered = 0usize;
        let mut candidates = Vec::new();
        for (i, a) in self.raw_grid().into_iter().enumerate() {
            if i % nshards != index {
                continue;
            }
            generated += 1;
            if self
                .max_onchip_bytes
                .map(|budget| a.onchip_bytes() > budget)
                .unwrap_or(false)
            {
                budget_filtered += 1;
            } else if !self.obs2_ok(&a) {
                ratio_filtered += 1;
            } else {
                candidates.push((i, a));
            }
        }
        ShardEnumeration {
            candidates,
            generated,
            budget_filtered,
            ratio_filtered,
        }
    }

    /// Build one architecture point. `rfs` is one or two register levels,
    /// innermost first.
    fn point(&self, array: ArrayShape, bus: ArrayBus, rfs: &[u64], gbuf: u64) -> Arch {
        let mut name = match rfs {
            [rf] => format!("rf{rf}-sram{}", gbuf >> 10),
            [rf, rf2] => format!("rf{rf}+{rf2}-sram{}", gbuf >> 10),
            _ => unreachable!("one or two RF levels"),
        };
        if self.arrays.len() > 1 {
            name.push_str(&format!("-{}x{}", array.rows, array.cols));
        }
        if self.buses.len() > 1 && bus == ArrayBus::Broadcast {
            name.push_str("-bcast");
        }
        let mut levels = Vec::with_capacity(rfs.len() + 2);
        match rfs {
            [rf] => levels.push(MemLevel::reg("RF", *rf)),
            [rf, rf2] => {
                levels.push(MemLevel::reg("RF1", *rf));
                levels.push(MemLevel::reg("RF2", *rf2));
            }
            _ => unreachable!(),
        }
        levels.push(MemLevel::sram("GBUF", gbuf));
        levels.push(MemLevel::dram());
        Arch {
            name,
            levels,
            array,
            bus,
            word_bytes: self.word_bytes,
            dram_bw_bytes_per_cycle: self.dram_bw_bytes_per_cycle,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_counts_add_up() {
        let space = DesignSpace::paper_default(ArrayShape { rows: 16, cols: 16 });
        let e = space.enumerate();
        // 1 bus x 5 RF sizes x 3 buffers x (1 single + 3 ratios), minus
        // the points whose rf2 overflows 1024 B (rf128x16, all of rf512)
        assert_eq!(e.generated, 5 * 3 * 4 - 3 * 4);
        assert_eq!(e.budget_filtered, 0);
        assert_eq!(
            e.generated,
            e.budget_filtered + e.ratio_filtered + e.candidates.len()
        );
        assert!(!e.candidates.is_empty());
        for a in &e.candidates {
            a.validate().unwrap_or_else(|m| panic!("{}: {m}", a.name));
            assert!(space.obs2_ok(a), "{} violates the ratio rule", a.name);
        }
        // the paper's optimized mobile configuration survives the strict
        // filter (16 B + 128 B RF, 256 KB buffer on 16x16 PEs)
        assert!(
            e.candidates.iter().any(|a| a.name == "rf16+128-sram256"),
            "expected the paper's winner in the space"
        );
    }

    #[test]
    fn strict_filter_rejects_what_widened_accepts() {
        let array = ArrayShape { rows: 16, cols: 16 };
        let strict = DesignSpace::paper_default(array);
        let mut wide = DesignSpace::paper_default(array);
        wide.ratio_min = 0.25;
        wide.ratio_max = 64.0;
        let ns = strict.enumerate();
        let nw = wide.enumerate();
        assert!(ns.candidates.len() < nw.candidates.len());
        assert_eq!(nw.ratio_filtered, 0, "64x window keeps the whole grid");
    }

    #[test]
    fn capacity_budget_filters_points() {
        let array = ArrayShape { rows: 16, cols: 16 };
        let mut space = DesignSpace::paper_default(array);
        space.ratio_min = 0.0;
        space.ratio_max = f64::INFINITY;
        let all = space.enumerate();
        // 100 KB keeps the 64 KB buffer points with small RFs only
        space.max_onchip_bytes = Some(100 << 10);
        let capped = space.enumerate();
        assert!(capped.budget_filtered > 0);
        assert!(capped.candidates.len() < all.candidates.len());
        for a in &capped.candidates {
            assert!(a.onchip_bytes() <= 100 << 10, "{} over budget", a.name);
        }
    }

    #[test]
    fn shards_partition_the_enumeration() {
        let mut space = DesignSpace::paper_default(ArrayShape { rows: 16, cols: 16 });
        space.max_onchip_bytes = Some(300 << 10); // exercise all three outcomes
        let whole = space.enumerate();
        for nshards in [1usize, 2, 3, 5, 7, whole.generated + 3] {
            let shards: Vec<ShardEnumeration> =
                (0..nshards).map(|i| space.shard(i, nshards)).collect();
            // counts sum to the whole space, shard by shard
            assert_eq!(
                shards.iter().map(|s| s.generated).sum::<usize>(),
                whole.generated
            );
            assert_eq!(
                shards.iter().map(|s| s.budget_filtered).sum::<usize>(),
                whole.budget_filtered
            );
            assert_eq!(
                shards.iter().map(|s| s.ratio_filtered).sum::<usize>(),
                whole.ratio_filtered
            );
            // per-shard partition identity (the stats invariant)
            for s in &shards {
                assert_eq!(
                    s.generated,
                    s.budget_filtered + s.ratio_filtered + s.candidates.len()
                );
            }
            // interleaving balances assignment to within one point
            let lo = shards.iter().map(|s| s.generated).min().unwrap();
            let hi = shards.iter().map(|s| s.generated).max().unwrap();
            assert!(hi - lo <= 1, "unbalanced raw assignment ({lo}..{hi})");
            // union of candidates == whole enumeration, in global order
            let mut merged: Vec<(usize, &Arch)> = shards
                .iter()
                .flat_map(|s| s.candidates.iter().map(|(i, a)| (*i, a)))
                .collect();
            merged.sort_by_key(|(i, _)| *i);
            assert_eq!(merged.len(), whole.candidates.len());
            for ((_, a), b) in merged.iter().zip(whole.candidates.iter()) {
                assert_eq!(**a, *b, "shard union diverges from enumerate()");
            }
        }
    }

    #[test]
    #[should_panic(expected = "out of 0..")]
    fn shard_index_out_of_range_panics() {
        DesignSpace::paper_default(ArrayShape { rows: 8, cols: 8 }).shard(3, 3);
    }

    #[test]
    fn full_space_widens_paper_default_without_touching_it() {
        let array = ArrayShape { rows: 16, cols: 16 };
        let paper = DesignSpace::paper_default(array);
        let full = DesignSpace::full(array);
        // the paper grid is a strict slice of the full grid's axes
        assert_eq!(full.rf1_sizes, paper.rf1_sizes);
        assert_eq!(full.gbuf_sizes, paper.gbuf_sizes);
        assert_eq!(full.arrays.len(), 3);
        assert!(full.arrays.contains(&array));
        assert_eq!(full.buses, vec![ArrayBus::Systolic, ArrayBus::Broadcast]);
        let ep = paper.enumerate();
        let ef = full.enumerate();
        assert_eq!(
            ef.generated,
            ep.generated * full.arrays.len() * full.buses.len(),
            "full grid must be the paper grid times the new axes"
        );
        assert!(ef.candidates.len() > ep.candidates.len());
        assert_eq!(
            ef.generated,
            ef.budget_filtered + ef.ratio_filtered + ef.candidates.len()
        );
        // every generated point validates and names stay unique
        let names: std::collections::HashSet<&str> =
            ef.candidates.iter().map(|a| a.name.as_str()).collect();
        assert_eq!(names.len(), ef.candidates.len(), "names must be unique");
        for a in &ef.candidates {
            a.validate().unwrap_or_else(|m| panic!("{}: {m}", a.name));
        }
        // an off-grid array is appended, not dropped
        let odd = ArrayShape { rows: 12, cols: 24 };
        let widened = DesignSpace::full(odd);
        assert!(widened.arrays.contains(&odd));
        assert_eq!(widened.arrays.len(), 4);
    }

    #[test]
    fn multi_array_and_bus_names_disambiguate() {
        let mut space = DesignSpace::paper_default(ArrayShape { rows: 8, cols: 8 });
        space.arrays = vec![
            ArrayShape { rows: 8, cols: 8 },
            ArrayShape { rows: 16, cols: 16 },
        ];
        space.buses = vec![ArrayBus::Systolic, ArrayBus::Broadcast];
        space.ratio_min = 0.0;
        space.ratio_max = f64::INFINITY;
        let e = space.enumerate();
        let names: std::collections::HashSet<&str> =
            e.candidates.iter().map(|a| a.name.as_str()).collect();
        assert_eq!(names.len(), e.candidates.len(), "names must be unique");
        assert!(names.iter().any(|n| n.ends_with("-bcast")));
        assert!(names.iter().any(|n| n.contains("-16x16")));
    }
}
