//! Netopt tests: the cross-architecture branch-and-bound returns the
//! *identical* best (architecture, per-layer mappings) as the exhaustive
//! sweep on small design spaces × {alexnet subset, lstm-m, mlp-m},
//! mirroring the layer-level equivalence tests in `engine::tests` — plus
//! floor admissibility and the iso-throughput constraint.

use super::*;
use crate::arch::ArrayShape;
use crate::energy::Table3;
use crate::nn::network;

/// A compact grid with the ratio filter deliberately widened (documented
/// knob), so the equivalence claim exercises the search, not the filter:
/// the deliberately-bad rf512 points stay in play and must be pruned by
/// the bound, never mis-ranked.
fn small_space() -> DesignSpace {
    let mut s = DesignSpace::paper_default(ArrayShape { rows: 8, cols: 8 });
    s.rf1_sizes = vec![16, 64, 512];
    s.rf2_ratios = vec![8];
    s.gbuf_sizes = vec![64 << 10, 256 << 10];
    s.ratio_min = 0.25;
    s.ratio_max = 64.0;
    s
}

fn small_opts() -> SearchOpts {
    let mut o = SearchOpts::capped(150, 4);
    o.max_order_combos = 9;
    o
}

fn workloads() -> Vec<Network> {
    vec![
        network("alexnet", 1).unwrap().head(3),
        network("lstm-m", 1).unwrap(),
        network("mlp-m", 16).unwrap(),
    ]
}

#[test]
fn bnb_matches_exhaustive_on_small_spaces() {
    let space = small_space();
    for net in workloads() {
        for threads in [1usize, 3] {
            let ex = co_optimize(
                &net,
                &space,
                &Table3,
                &NetOptConfig::exhaustive(small_opts(), threads),
            );
            let bb = co_optimize(
                &net,
                &space,
                &Table3,
                &NetOptConfig::new(small_opts(), threads),
            );
            let (Some(we), Some(wb)) = (ex.best(), bb.best()) else {
                panic!("{}: no feasible winner (t={threads})", net.name);
            };
            assert_eq!(
                we.arch.name, wb.arch.name,
                "{}: winner arch differs (t={threads})",
                net.name
            );
            assert_eq!(
                we.opt.total_energy_pj, wb.opt.total_energy_pj,
                "{}: winner energy differs (t={threads})",
                net.name
            );
            assert_eq!(we.opt.unmapped, 0);
            assert_eq!(wb.opt.unmapped, 0);
            assert_eq!(we.opt.per_layer.len(), wb.opt.per_layer.len());
            for (a, b) in we.opt.per_layer.iter().zip(wb.opt.per_layer.iter()) {
                let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
                assert_eq!(a.mapping, b.mapping, "{}: winner mapping differs", net.name);
                assert_eq!(a.smap, b.smap, "{}: winner spatial map differs", net.name);
                assert_eq!(a.result.energy_pj, b.result.energy_pj);
            }
            // exhaustive mode fully evaluates the whole space...
            assert_eq!(ex.stats.evaluated_full, ex.stats.candidates);
            assert_eq!(ex.stats.pruned, 0);
            // ...and branch-and-bound accounts for every candidate
            assert_eq!(
                bb.stats.pruned + bb.stats.evaluated_full,
                bb.stats.candidates
            );
            assert!(bb.stats.evaluated_full <= ex.stats.evaluated_full);
        }
    }
}

#[test]
fn bnb_prunes_architecture_points() {
    // Deterministic single-thread run. The MLP's DRAM-dominated floors
    // make the network bound strong, so the oversized-RF points must be
    // abandoned before completing every layer.
    let net = network("mlp-m", 16).unwrap();
    let bb = co_optimize(
        &net,
        &small_space(),
        &Table3,
        &NetOptConfig::new(small_opts(), 1),
    );
    assert!(
        bb.stats.pruned > 0,
        "expected network-level pruning, got {}",
        bb.stats
    );
    assert!(bb.stats.evaluated_full < bb.stats.candidates);
}

#[test]
fn network_floor_lower_bounds_every_point() {
    let space = small_space();
    for net in workloads() {
        let profile = NetProfile::new(&net, None);
        let ex = co_optimize(
            &net,
            &space,
            &Table3,
            &NetOptConfig::exhaustive(small_opts(), 2),
        );
        assert!(!ex.ranked.is_empty());
        for r in &ex.ranked {
            if r.opt.unmapped > 0 {
                continue;
            }
            let (_, suffix) = profile.floors(&r.arch, &Table3);
            assert!(
                suffix[0] <= r.opt.total_energy_pj * (1.0 + PRUNE_SLACK),
                "{} on {}: floor {} above total {}",
                net.name,
                r.arch.name,
                suffix[0],
                r.opt.total_energy_pj
            );
        }
    }
}

#[test]
fn min_tops_constraint_filters_and_preserves_winner() {
    let net = network("mlp-m", 16).unwrap();
    let space = small_space();
    let plain = co_optimize(
        &net,
        &space,
        &Table3,
        &NetOptConfig::exhaustive(small_opts(), 2),
    );
    let winner = plain.best().expect("feasible winner").arch.name.clone();

    // a floor below every point changes nothing
    let tiny = co_optimize(
        &net,
        &space,
        &Table3,
        &NetOptConfig::exhaustive(small_opts(), 2).with_min_tops(1e-12),
    );
    assert_eq!(tiny.best().expect("still feasible").arch.name, winner);
    assert_eq!(tiny.stats.throughput_filtered, 0);

    // a floor above every point empties the ranking
    let huge = co_optimize(
        &net,
        &space,
        &Table3,
        &NetOptConfig::exhaustive(small_opts(), 2).with_min_tops(1e12),
    );
    assert!(huge.ranked.is_empty());
    assert_eq!(huge.stats.throughput_filtered, huge.stats.evaluated_full);
    assert!(huge.stats.throughput_filtered > 0);

    // iso-throughput at the best achieved TOPS keeps only points that
    // actually meet it (branch-and-bound mode)
    let best_tops = plain
        .ranked
        .iter()
        .map(|r| r.opt.tops(1.0))
        .fold(f64::NEG_INFINITY, f64::max);
    let constrained = co_optimize(
        &net,
        &space,
        &Table3,
        &NetOptConfig::new(small_opts(), 2).with_min_tops(best_tops),
    );
    assert!(!constrained.ranked.is_empty());
    for r in &constrained.ranked {
        assert!(r.opt.tops(1.0) >= best_tops);
    }
}

#[test]
fn search_hierarchy_shim_matches_co_optimize() {
    let net = network("mlp-m", 16).unwrap();
    let opts = small_opts();
    let array = ArrayShape { rows: 8, cols: 8 };
    let shim = crate::search::search_hierarchy(&net, array, &Table3, &opts, 2);
    let direct = co_optimize(
        &net,
        &DesignSpace::paper_default(array),
        &Table3,
        &NetOptConfig::exhaustive(opts, 2),
    );
    assert_eq!(shim.len(), direct.ranked.len());
    for (a, b) in shim.iter().zip(direct.ranked.iter()) {
        assert_eq!(a.arch.name, b.arch.name);
        assert_eq!(a.opt.total_energy_pj, b.opt.total_energy_pj);
        assert_eq!(a.opt.unmapped, b.opt.unmapped);
    }
}

/// Assert two winners are bit-identical on the contract surface:
/// architecture, network totals, and every per-layer (mapping, smap,
/// model result). Search *counters* (`LayerOpt::evaluated`/`stats`) are
/// deliberately excluded — pruning histories legitimately differ across
/// sharding and thread layouts; the optimum must not.
fn assert_winner_payload_eq(tag: &str, wa: &HierarchyResult, wb: &HierarchyResult) {
    assert_eq!(wa.arch, wb.arch, "{tag}: winner arch differs");
    assert_eq!(
        wa.opt.total_energy_pj.to_bits(),
        wb.opt.total_energy_pj.to_bits(),
        "{tag}: winner energy bits differ"
    );
    assert_eq!(
        wa.opt.total_cycles.to_bits(),
        wb.opt.total_cycles.to_bits(),
        "{tag}: winner cycle bits differ"
    );
    assert_eq!(wa.opt.total_macs, wb.opt.total_macs, "{tag}: macs differ");
    assert_eq!(wa.opt.unmapped, 0, "{tag}: winner must be fully mapped");
    assert_eq!(wb.opt.unmapped, 0, "{tag}: winner must be fully mapped");
    assert_eq!(wa.opt.per_layer.len(), wb.opt.per_layer.len());
    for (x, y) in wa.opt.per_layer.iter().zip(wb.opt.per_layer.iter()) {
        let (x, y) = (x.as_ref().unwrap(), y.as_ref().unwrap());
        assert_eq!(x.mapping, y.mapping, "{tag}: winner mapping differs");
        assert_eq!(x.smap, y.smap, "{tag}: winner spatial map differs");
        assert_eq!(x.result, y.result, "{tag}: winner model result differs");
    }
}

/// Assert two results agree on the winner bit-for-bit.
fn assert_same_winner(tag: &str, a: &CoOptResult, b: &CoOptResult) {
    let (Some(wa), Some(wb)) = (a.best(), b.best()) else {
        panic!("{tag}: missing winner");
    };
    assert_winner_payload_eq(tag, wa, wb);
}

#[test]
fn sharded_matches_single_process() {
    let space = small_space();
    for net in workloads() {
        let single = co_optimize(&net, &space, &Table3, &NetOptConfig::new(small_opts(), 2));
        for nshards in [1usize, 2, 3, 5] {
            let sharded = co_optimize_sharded(
                &net,
                &space,
                &Table3,
                &NetOptConfig::new(small_opts(), 2),
                nshards,
            );
            assert_same_winner(&format!("{} n={nshards}", net.name), &single, &sharded);
            // every candidate is accounted for across the shards
            assert!(sharded.stats.invariants_hold(), "{}", sharded.stats);
            assert_eq!(sharded.stats.generated, single.stats.generated);
            assert_eq!(sharded.stats.candidates, single.stats.candidates);
        }
    }
}

#[test]
fn sharded_exhaustive_reproduces_full_ranking() {
    // Exhaustive mode has no cross-point state at all, so the sharded
    // union must equal the single-process ranking point for point.
    let net = network("mlp-m", 16).unwrap();
    let space = small_space();
    let single = co_optimize(
        &net,
        &space,
        &Table3,
        &NetOptConfig::exhaustive(small_opts(), 2),
    );
    let sharded = co_optimize_sharded(
        &net,
        &space,
        &Table3,
        &NetOptConfig::exhaustive(small_opts(), 2),
        3,
    );
    assert_eq!(single.ranked.len(), sharded.ranked.len());
    for (a, b) in single.ranked.iter().zip(sharded.ranked.iter()) {
        assert_eq!(a.arch, b.arch);
        assert_eq!(
            a.opt.total_energy_pj.to_bits(),
            b.opt.total_energy_pj.to_bits()
        );
    }
}

#[test]
fn checkpoint_json_roundtrip_is_lossless() {
    let net = network("mlp-m", 16).unwrap();
    let space = small_space();
    for (index, nshards) in [(0usize, 2usize), (1, 2), (2, 7)] {
        let run = co_optimize_shard(
            &net,
            &space,
            &Table3,
            &NetOptConfig::new(small_opts(), 1),
            index,
            nshards,
        );
        let text = run.checkpoint.to_json();
        let back = ShardCheckpoint::from_json(&text)
            .unwrap_or_else(|e| panic!("shard {index}/{nshards}: {e}\n{text}"));
        assert_eq!(run.checkpoint, back, "shard {index}/{nshards} round-trip");
        // and the serialized form is stable (write → parse → write)
        assert_eq!(text, back.to_json());
    }
}

#[test]
fn checkpoint_merge_is_associative_and_order_free() {
    let net = network("mlp-m", 16).unwrap();
    let space = small_space();
    let cfg = NetOptConfig::new(small_opts(), 1);
    let ckpts: Vec<ShardCheckpoint> = (0..3)
        .map(|i| co_optimize_shard(&net, &space, &Table3, &cfg, i, 3).checkpoint)
        .collect();
    let left = merge_checkpoints(&merge_checkpoints(&ckpts[0], &ckpts[1]).unwrap(), &ckpts[2])
        .unwrap();
    let right = merge_checkpoints(&ckpts[0], &merge_checkpoints(&ckpts[1], &ckpts[2]).unwrap())
        .unwrap();
    let rev = merge_all(&[ckpts[2].clone(), ckpts[0].clone(), ckpts[1].clone()]).unwrap();
    assert_eq!(left, right, "merge must be associative");
    assert_eq!(left, rev, "merge must be order-free");
    assert_eq!(left.shards, vec![0, 1, 2]);
    assert!(left.stats.invariants_hold(), "{}", left.stats);
    // the merged winner is the single-process winner, bit for bit
    let single = co_optimize(&net, &space, &Table3, &cfg);
    let sw = single.best().unwrap();
    let mw = left.winner_result().expect("merged winner");
    assert_winner_payload_eq("merged", sw, mw);
}

#[test]
fn checkpoint_merge_rejects_mismatches() {
    let net = network("mlp-m", 16).unwrap();
    let space = small_space();
    let cfg = NetOptConfig::new(small_opts(), 1);
    let c0 = co_optimize_shard(&net, &space, &Table3, &cfg, 0, 2).checkpoint;
    let c1 = co_optimize_shard(&net, &space, &Table3, &cfg, 1, 2).checkpoint;
    // duplicate coverage deduplicates (identity-checked), it is no longer
    // an error: a raced straggler finishing after its replacements must
    // merge cleanly
    assert_eq!(merge_checkpoints(&c0, &c0).unwrap(), c0);
    // partially overlapping coverage is still an error: shard 0/2 covers
    // residues {0,2,4} of the lcm-6 refinement, shard 1/3 covers {1,4} —
    // they share grid index 4 without either containing the other
    let c_other_n = co_optimize_shard(&net, &space, &Table3, &cfg, 1, 3).checkpoint;
    let err = merge_checkpoints(&c0, &c_other_n).unwrap_err().to_string();
    assert!(err.contains("partially overlapping"), "got: {err}");
    // different network
    let other = network("lstm-m", 1).unwrap();
    let c_other_net = co_optimize_shard(&other, &space, &Table3, &cfg, 1, 2).checkpoint;
    assert!(merge_checkpoints(&c0, &c_other_net).is_err());
    // sane pair still merges
    assert!(merge_checkpoints(&c0, &c1).is_ok());
}

#[test]
fn subshard_split_recovers_parent_grid_exactly() {
    // Work stealing re-splits shard (i, n) into (i + j*n, n*m) for
    // j in 0..m; the union of the sub-shards' candidate grid indices
    // must be exactly the parent's, in the same global order.
    let space = small_space();
    for (i, n) in [(0usize, 2usize), (1, 2), (2, 3)] {
        let parent = space.shard(i, n);
        for m in [2usize, 3] {
            let mut union: Vec<(usize, String)> = (0..m)
                .flat_map(|j| {
                    space
                        .shard(i + j * n, n * m)
                        .candidates
                        .into_iter()
                        .map(|(g, a)| (g, a.name))
                })
                .collect();
            union.sort_by_key(|(g, _)| *g);
            let want: Vec<(usize, String)> = parent
                .candidates
                .iter()
                .map(|(g, a)| (*g, a.name.clone()))
                .collect();
            assert_eq!(union, want, "shard ({i},{n}) split by {m}");
        }
    }
}

#[test]
fn mixed_granularity_merge_is_bit_identical_to_parent_merge() {
    // A stolen shard's sub-checkpoints must merge to exactly what the
    // parent checkpoint would have contributed — over any interleaving,
    // and idempotently under duplicate coverage (a straggler finishing
    // after its replacements).
    let net = network("mlp-m", 16).unwrap();
    let space = small_space();
    let cfg = NetOptConfig::new(small_opts(), 1);
    let c0 = co_optimize_shard(&net, &space, &Table3, &cfg, 0, 2).checkpoint;
    let c1 = co_optimize_shard(&net, &space, &Table3, &cfg, 1, 2).checkpoint;
    // sub-shards of shard (1,2): (1,4) and (3,4)
    let s1 = co_optimize_shard(&net, &space, &Table3, &cfg, 1, 4).checkpoint;
    let s3 = co_optimize_shard(&net, &space, &Table3, &cfg, 3, 4).checkpoint;

    let whole = merge_checkpoints(&c0, &c1).unwrap();
    let via_subs =
        merge_all(&[c0.clone(), s1.clone(), s3.clone()]).expect("mixed-granularity merge");
    let interleaved =
        merge_all(&[s3.clone(), c0.clone(), s1.clone()]).expect("interleaved merge");
    // winner, incumbent, seeds, and coverage all bit-identical to the
    // parent merge; stats differ only in partition granularity, so
    // compare the winner payloads and scalar fields rather than `==`
    // on the whole struct (nshards legitimately differs: 2 vs 4).
    for merged in [&via_subs, &interleaved] {
        assert_eq!(merged.nshards, 4);
        assert_eq!(merged.shards, vec![0, 1, 2, 3]);
        let (wi, wr) = merged.winner.as_ref().expect("winner");
        let (pi, pr) = whole.winner.as_ref().expect("winner");
        assert_eq!(wi, pi, "winner grid index differs");
        assert_eq!(
            wr.opt.total_energy_pj.to_bits(),
            pr.opt.total_energy_pj.to_bits(),
            "winner energy bits differ"
        );
        assert_eq!(wr.opt.total_cycles.to_bits(), pr.opt.total_cycles.to_bits());
        assert_eq!(wr.arch, pr.arch);
        assert_eq!(merged.incumbent_pj.to_bits(), whole.incumbent_pj.to_bits());
        // seeds are deliberately NOT compared across partitions: they
        // record energies observed along the pruning history, and a
        // sub-shard may complete a point its parent shard pruned (its
        // own incumbent warms up later) — hints, not results
        assert!(merged.stats.invariants_hold(), "{}", merged.stats);
        assert_eq!(merged.stats.generated, whole.stats.generated);
        assert_eq!(merged.stats.candidates, whole.stats.candidates);
    }
    // duplicate coverage on top (straggler c1 finished anyway): same
    // result, no double-counted stats
    let with_dup = merge_all(&[c0.clone(), c1.clone(), s1.clone(), s3.clone()])
        .expect("duplicate-coverage merge");
    assert_eq!(with_dup.winner, via_subs.winner);
    assert_eq!(with_dup.stats.generated, whole.stats.generated);
    assert_eq!(with_dup.stats.candidates, whole.stats.candidates);
}

#[test]
fn duplicate_coverage_identity_violation_is_detected() {
    // Two checkpoints claiming the same coverage but disagreeing on the
    // winner payload means a worker ran a different configuration — the
    // merge must refuse rather than silently pick one.
    let net = network("mlp-m", 16).unwrap();
    let space = small_space();
    let cfg = NetOptConfig::new(small_opts(), 1);
    let c = co_optimize_shard(&net, &space, &Table3, &cfg, 0, 2).checkpoint;
    let mut tampered = c.clone();
    let (_, w) = tampered.winner.as_mut().expect("winner");
    w.opt.total_energy_pj *= 1.5;
    let err = merge_checkpoints(&c, &tampered).unwrap_err().to_string();
    assert!(err.contains("identity check failed"), "got: {err}");
}

#[test]
fn co_optimize_arches_matches_evaluate_network() {
    let net = network("mlp-m", 16).unwrap();
    let arches = [crate::arch::eyeriss_like(), crate::arch::tpu_like()];
    let res = co_optimize_arches(
        &net,
        &arches,
        &Table3,
        &NetOptConfig::exhaustive(small_opts(), 2),
    );
    assert_eq!(res.stats.generated, 2);
    assert_eq!(res.stats.candidates, 2);
    assert_eq!(res.stats.evaluated_full, 2);
    for r in &res.ranked {
        let direct = evaluate_network(
            &net,
            &r.arch,
            &NetOptConfig::new(small_opts(), 2).df,
            &Table3,
            &small_opts(),
            2,
        );
        assert_eq!(
            r.opt.total_energy_pj.to_bits(),
            direct.total_energy_pj.to_bits(),
            "{}: arch-list path diverges from evaluate_network",
            r.arch.name
        );
    }
}

#[test]
fn seeded_warm_start_preserves_winner() {
    // The seeded-vs-cold property: co_optimize_arches warm-started from
    // an ARBITRARY (randomized) SeedTable returns the identical winner —
    // seeds may only prune, never change the argmin (the rerun fallback
    // restores exactness) — with at most as many fully evaluated points.
    use crate::loopnest::NDIMS;
    use crate::util::prop::for_cases;

    let net = network("mlp-m", 16).unwrap();
    let arches = [
        crate::arch::eyeriss_like(),
        crate::arch::no_local_reuse(),
        crate::arch::small_rf(),
    ];
    let cfg = NetOptConfig::new(small_opts(), 1);
    let cold = co_optimize_arches(&net, &arches, &Table3, &cfg);
    let cw = cold.best().expect("cold winner").clone();
    let layer_e: Vec<(LayerKey, f64)> = cw
        .opt
        .per_layer
        .iter()
        .zip(net.layers.iter())
        .map(|(lo, l)| {
            (
                (l.shape.bounds, l.shape.stride),
                lo.as_ref().unwrap().result.energy_pj,
            )
        })
        .collect();

    for_cases(0x5EED, 8, |rng| {
        let mut entries: Vec<(LayerKey, f64)> = Vec::new();
        for (k, e) in &layer_e {
            match rng.below(4) {
                0 => {} // shape absent from the table
                1 => entries.push((*k, e * 1e-6)), // absurdly low: forces reruns
                2 => entries.push((*k, e * (0.5 + rng.below(150) as f64 / 100.0))),
                _ => entries.push((*k, e * 1e6)), // uselessly loose
            }
        }
        // a key no layer has — must be ignored entirely
        let mut bogus = [1u64; NDIMS];
        bogus[0] = 100_000 + rng.below(1000);
        entries.push(((bogus, 1), 1.0 + rng.below(1000) as f64));
        let warm = SeedTable::from_entries(entries);

        let seeded = co_optimize_arches_seeded(&net, &arches, &Table3, &cfg, &warm);
        let sw = seeded.best().expect("seeded winner");
        assert_winner_payload_eq("seeded-vs-cold", &cw, sw);
        assert!(
            seeded.stats.evaluated_full <= cold.stats.evaluated_full,
            "seeds must never add full evaluations: {} > {}",
            seeded.stats.evaluated_full,
            cold.stats.evaluated_full
        );
        assert!(seeded.stats.invariants_hold(), "{}", seeded.stats);
        // the run's output table absorbed the winner's energies, so the
        // next warm start can only be tighter
        assert!(!seeded.seeds.is_empty());
    });
}

#[test]
fn uniform_weights_are_bit_identical_to_unweighted() {
    let net = network("mlp-m", 16).unwrap();
    let arches = [crate::arch::eyeriss_like(), crate::arch::small_rf()];
    let base = NetOptConfig::new(small_opts(), 1);
    let uni = base.clone().with_layer_weights(vec![1.0; net.layers.len()]);
    let a = co_optimize_arches(&net, &arches, &Table3, &base);
    let b = co_optimize_arches(&net, &arches, &Table3, &uni);
    assert_eq!(a.ranked.len(), b.ranked.len());
    for (x, y) in a.ranked.iter().zip(b.ranked.iter()) {
        assert_eq!(x.arch, y.arch);
        assert_eq!(
            x.opt.total_energy_pj.to_bits(),
            y.opt.total_energy_pj.to_bits(),
            "uniform weights changed energy bits on {}",
            x.arch.name
        );
        assert_eq!(x.opt.total_cycles.to_bits(), y.opt.total_cycles.to_bits());
        assert_eq!(x.opt.total_macs, y.opt.total_macs);
    }
    assert_eq!(a.stats, b.stats, "uniform weights changed the counters");
}

#[test]
fn mix_weights_scale_objective_and_preserve_per_layer_sum() {
    let net = network("mlp-m", 16).unwrap();
    let arches = [crate::arch::eyeriss_like(), crate::arch::small_rf()];
    let base = NetOptConfig::new(small_opts(), 1);
    let plain = co_optimize_arches(&net, &arches, &Table3, &base);
    let pw = plain.best().expect("plain winner");

    // uniform scaling: same winner, ~scaled totals
    let scaled_cfg = base.clone().with_layer_weights(vec![3.0; net.layers.len()]);
    let scaled = co_optimize_arches(&net, &arches, &Table3, &scaled_cfg);
    let sw = scaled.best().expect("scaled winner");
    assert_eq!(pw.arch.name, sw.arch.name, "uniform scaling moved the winner");
    let rel = (sw.opt.total_energy_pj - 3.0 * pw.opt.total_energy_pj).abs()
        / (3.0 * pw.opt.total_energy_pj);
    assert!(rel < 1e-9, "scaled energy off by {rel}");

    // skewed weights: the reported total is exactly the weighted
    // per-layer sum (accumulated in layer order)
    let weights: Vec<f64> = (0..net.layers.len()).map(|i| 1.0 + i as f64 * 4.0).collect();
    let skew_cfg = base.with_layer_weights(weights.clone());
    let skew = co_optimize_arches(&net, &arches, &Table3, &skew_cfg);
    let kw = skew.best().expect("skewed winner");
    let mut want = 0.0f64;
    for (w, lo) in weights.iter().zip(kw.opt.per_layer.iter()) {
        want += w * lo.as_ref().unwrap().result.energy_pj;
    }
    assert_eq!(
        kw.opt.total_energy_pj.to_bits(),
        want.to_bits(),
        "weighted total is not the weighted per-layer sum"
    );
}

#[test]
fn empty_space_returns_no_points() {
    let mut space = small_space();
    space.rf1_sizes.clear();
    let res = co_optimize(
        &network("mlp-m", 16).unwrap(),
        &space,
        &Table3,
        &NetOptConfig::new(small_opts(), 2),
    );
    assert!(res.ranked.is_empty());
    assert!(res.best().is_none());
    assert_eq!(res.stats.generated, 0);
}
