//! Netopt tests: the cross-architecture branch-and-bound returns the
//! *identical* best (architecture, per-layer mappings) as the exhaustive
//! sweep on small design spaces × {alexnet subset, lstm-m, mlp-m},
//! mirroring the layer-level equivalence tests in `engine::tests` — plus
//! floor admissibility and the iso-throughput constraint.

use super::*;
use crate::arch::ArrayShape;
use crate::energy::Table3;
use crate::nn::network;

/// A compact grid with the ratio filter deliberately widened (documented
/// knob), so the equivalence claim exercises the search, not the filter:
/// the deliberately-bad rf512 points stay in play and must be pruned by
/// the bound, never mis-ranked.
fn small_space() -> DesignSpace {
    let mut s = DesignSpace::paper_default(ArrayShape { rows: 8, cols: 8 });
    s.rf1_sizes = vec![16, 64, 512];
    s.rf2_ratios = vec![8];
    s.gbuf_sizes = vec![64 << 10, 256 << 10];
    s.ratio_min = 0.25;
    s.ratio_max = 64.0;
    s
}

fn small_opts() -> SearchOpts {
    let mut o = SearchOpts::capped(150, 4);
    o.max_order_combos = 9;
    o
}

fn workloads() -> Vec<Network> {
    vec![
        network("alexnet", 1).unwrap().head(3),
        network("lstm-m", 1).unwrap(),
        network("mlp-m", 16).unwrap(),
    ]
}

#[test]
fn bnb_matches_exhaustive_on_small_spaces() {
    let space = small_space();
    for net in workloads() {
        for threads in [1usize, 3] {
            let ex = co_optimize(
                &net,
                &space,
                &Table3,
                &NetOptConfig::exhaustive(small_opts(), threads),
            );
            let bb = co_optimize(
                &net,
                &space,
                &Table3,
                &NetOptConfig::new(small_opts(), threads),
            );
            let (Some(we), Some(wb)) = (ex.best(), bb.best()) else {
                panic!("{}: no feasible winner (t={threads})", net.name);
            };
            assert_eq!(
                we.arch.name, wb.arch.name,
                "{}: winner arch differs (t={threads})",
                net.name
            );
            assert_eq!(
                we.opt.total_energy_pj, wb.opt.total_energy_pj,
                "{}: winner energy differs (t={threads})",
                net.name
            );
            assert_eq!(we.opt.unmapped, 0);
            assert_eq!(wb.opt.unmapped, 0);
            assert_eq!(we.opt.per_layer.len(), wb.opt.per_layer.len());
            for (a, b) in we.opt.per_layer.iter().zip(wb.opt.per_layer.iter()) {
                let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
                assert_eq!(a.mapping, b.mapping, "{}: winner mapping differs", net.name);
                assert_eq!(a.smap, b.smap, "{}: winner spatial map differs", net.name);
                assert_eq!(a.result.energy_pj, b.result.energy_pj);
            }
            // exhaustive mode fully evaluates the whole space...
            assert_eq!(ex.stats.evaluated_full, ex.stats.candidates);
            assert_eq!(ex.stats.pruned, 0);
            // ...and branch-and-bound accounts for every candidate
            assert_eq!(
                bb.stats.pruned + bb.stats.evaluated_full,
                bb.stats.candidates
            );
            assert!(bb.stats.evaluated_full <= ex.stats.evaluated_full);
        }
    }
}

#[test]
fn bnb_prunes_architecture_points() {
    // Deterministic single-thread run. The MLP's DRAM-dominated floors
    // make the network bound strong, so the oversized-RF points must be
    // abandoned before completing every layer.
    let net = network("mlp-m", 16).unwrap();
    let bb = co_optimize(
        &net,
        &small_space(),
        &Table3,
        &NetOptConfig::new(small_opts(), 1),
    );
    assert!(
        bb.stats.pruned > 0,
        "expected network-level pruning, got {}",
        bb.stats
    );
    assert!(bb.stats.evaluated_full < bb.stats.candidates);
}

#[test]
fn network_floor_lower_bounds_every_point() {
    let space = small_space();
    for net in workloads() {
        let profile = NetProfile::new(&net);
        let ex = co_optimize(
            &net,
            &space,
            &Table3,
            &NetOptConfig::exhaustive(small_opts(), 2),
        );
        assert!(!ex.ranked.is_empty());
        for r in &ex.ranked {
            if r.opt.unmapped > 0 {
                continue;
            }
            let (_, suffix) = profile.floors(&r.arch, &Table3);
            assert!(
                suffix[0] <= r.opt.total_energy_pj * (1.0 + PRUNE_SLACK),
                "{} on {}: floor {} above total {}",
                net.name,
                r.arch.name,
                suffix[0],
                r.opt.total_energy_pj
            );
        }
    }
}

#[test]
fn min_tops_constraint_filters_and_preserves_winner() {
    let net = network("mlp-m", 16).unwrap();
    let space = small_space();
    let plain = co_optimize(
        &net,
        &space,
        &Table3,
        &NetOptConfig::exhaustive(small_opts(), 2),
    );
    let winner = plain.best().expect("feasible winner").arch.name.clone();

    // a floor below every point changes nothing
    let tiny = co_optimize(
        &net,
        &space,
        &Table3,
        &NetOptConfig::exhaustive(small_opts(), 2).with_min_tops(1e-12),
    );
    assert_eq!(tiny.best().expect("still feasible").arch.name, winner);
    assert_eq!(tiny.stats.throughput_filtered, 0);

    // a floor above every point empties the ranking
    let huge = co_optimize(
        &net,
        &space,
        &Table3,
        &NetOptConfig::exhaustive(small_opts(), 2).with_min_tops(1e12),
    );
    assert!(huge.ranked.is_empty());
    assert_eq!(huge.stats.throughput_filtered, huge.stats.evaluated_full);
    assert!(huge.stats.throughput_filtered > 0);

    // iso-throughput at the best achieved TOPS keeps only points that
    // actually meet it (branch-and-bound mode)
    let best_tops = plain
        .ranked
        .iter()
        .map(|r| r.opt.tops(1.0))
        .fold(f64::NEG_INFINITY, f64::max);
    let constrained = co_optimize(
        &net,
        &space,
        &Table3,
        &NetOptConfig::new(small_opts(), 2).with_min_tops(best_tops),
    );
    assert!(!constrained.ranked.is_empty());
    for r in &constrained.ranked {
        assert!(r.opt.tops(1.0) >= best_tops);
    }
}

#[test]
fn search_hierarchy_shim_matches_co_optimize() {
    let net = network("mlp-m", 16).unwrap();
    let opts = small_opts();
    let array = ArrayShape { rows: 8, cols: 8 };
    let shim = crate::search::search_hierarchy(&net, array, &Table3, &opts, 2);
    let direct = co_optimize(
        &net,
        &DesignSpace::paper_default(array),
        &Table3,
        &NetOptConfig::exhaustive(opts, 2),
    );
    assert_eq!(shim.len(), direct.ranked.len());
    for (a, b) in shim.iter().zip(direct.ranked.iter()) {
        assert_eq!(a.arch.name, b.arch.name);
        assert_eq!(a.opt.total_energy_pj, b.opt.total_energy_pj);
        assert_eq!(a.opt.unmapped, b.opt.unmapped);
    }
}

#[test]
fn empty_space_returns_no_points() {
    let mut space = small_space();
    space.rf1_sizes.clear();
    let res = co_optimize(
        &network("mlp-m", 16).unwrap(),
        &space,
        &Table3,
        &NetOptConfig::new(small_opts(), 2),
    );
    assert!(res.ranked.is_empty());
    assert!(res.best().is_none());
    assert_eq!(res.stats.generated, 0);
}
