//! Counters for the network-level co-optimizer: how many architecture
//! points the design space generated, how many each filter removed, how
//! many the cross-architecture branch-and-bound abandoned, and the
//! aggregated per-layer engine counters.

use crate::engine::EvalSnapshot;

/// Roll-up of one [`super::co_optimize`] run. `generated ==
/// budget_filtered + ratio_filtered + candidates` and `candidates ==
/// pruned + evaluated_full` always hold.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct NetOptStats {
    /// Raw design-space grid points.
    pub generated: usize,
    /// Points dropped by the on-chip capacity budget.
    pub budget_filtered: usize,
    /// Points dropped by the Observation-2 ratio rule.
    pub ratio_filtered: usize,
    /// Points that entered evaluation.
    pub candidates: usize,
    /// Points abandoned by the network-level bound before completing all
    /// layers (branch-and-bound only; includes points whose bounded layer
    /// search came back empty).
    pub pruned: usize,
    /// Points evaluated through every layer.
    pub evaluated_full: usize,
    /// Fully evaluated points with at least one unmappable layer (their
    /// totals under-report; they never win).
    pub infeasible: usize,
    /// Fully evaluated points excluded by the `min_tops` constraint.
    pub throughput_filtered: usize,
    /// Per-layer searches actually run (shape-deduplicated).
    pub layer_searches: usize,
    /// Seeded layer searches that had to rerun because the borrowed
    /// cross-architecture seed clipped the result.
    pub layer_reruns: usize,
    /// Aggregated staged-engine counters across every layer search.
    pub engine: EvalSnapshot,
}

impl NetOptStats {
    /// Field-wise accumulation of another run's counters — the roll-up
    /// used when merging shard checkpoints. Addition is associative and
    /// commutative per field, so any merge order yields identical totals,
    /// and both [`invariants_hold`](Self::invariants_hold) identities are
    /// preserved (each is a sum equation, stable under summation).
    pub fn merge(&mut self, other: &NetOptStats) {
        self.generated += other.generated;
        self.budget_filtered += other.budget_filtered;
        self.ratio_filtered += other.ratio_filtered;
        self.candidates += other.candidates;
        self.pruned += other.pruned;
        self.evaluated_full += other.evaluated_full;
        self.infeasible += other.infeasible;
        self.throughput_filtered += other.throughput_filtered;
        self.layer_searches += other.layer_searches;
        self.layer_reruns += other.layer_reruns;
        self.engine.absorb(&other.engine);
    }

    /// The two structural identities every (shard or merged) stats value
    /// must satisfy: the space filters partition the grid
    /// (`generated == budget_filtered + ratio_filtered + candidates`) and
    /// the evaluator accounts for every candidate
    /// (`candidates == pruned + evaluated_full`).
    pub fn invariants_hold(&self) -> bool {
        self.generated == self.budget_filtered + self.ratio_filtered + self.candidates
            && self.candidates == self.pruned + self.evaluated_full
    }
}

impl std::fmt::Display for NetOptStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "arch points: {} generated, {} budget-filtered, {} ratio-filtered, \
             {} candidates, {} pruned, {} fully evaluated ({} infeasible, \
             {} below min-tops); {} layer searches ({} seed reruns); engine: {}",
            self.generated,
            self.budget_filtered,
            self.ratio_filtered,
            self.candidates,
            self.pruned,
            self.evaluated_full,
            self.infeasible,
            self.throughput_filtered,
            self.layer_searches,
            self.layer_reruns,
            self.engine
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_cases;
    use crate::util::XorShift;

    /// A random stats value that satisfies both structural invariants by
    /// construction (counts partitioned bottom-up).
    fn random_stats(rng: &mut XorShift) -> NetOptStats {
        let pruned = rng.below(50) as usize;
        let evaluated_full = rng.below(50) as usize;
        let candidates = pruned + evaluated_full;
        let budget_filtered = rng.below(20) as usize;
        let ratio_filtered = rng.below(20) as usize;
        NetOptStats {
            generated: budget_filtered + ratio_filtered + candidates,
            budget_filtered,
            ratio_filtered,
            candidates,
            pruned,
            evaluated_full,
            infeasible: rng.below(1 + evaluated_full as u64) as usize,
            throughput_filtered: rng.below(1 + evaluated_full as u64) as usize,
            layer_searches: rng.below(1000) as usize,
            layer_reruns: rng.below(100) as usize,
            engine: EvalSnapshot {
                stage2: rng.below(10_000),
                fit_rejected: rng.below(100),
                stage3: rng.below(100_000),
                pruned: rng.below(50_000),
                full: rng.below(10_000),
            },
        }
    }

    fn merged(a: &NetOptStats, b: &NetOptStats) -> NetOptStats {
        let mut out = a.clone();
        out.merge(b);
        out
    }

    #[test]
    fn display_mentions_counts() {
        let s = NetOptStats {
            generated: 10,
            candidates: 7,
            pruned: 4,
            evaluated_full: 3,
            ..Default::default()
        };
        let text = format!("{s}");
        assert!(text.contains("10 generated"));
        assert!(text.contains("4 pruned"));
        assert!(text.contains("3 fully evaluated"));
    }

    #[test]
    fn merge_preserves_invariants() {
        for_cases(0x57A7, 200, |rng| {
            let a = random_stats(rng);
            let b = random_stats(rng);
            assert!(a.invariants_hold() && b.invariants_hold());
            let m = merged(&a, &b);
            assert!(m.invariants_hold(), "merge broke invariants: {m}");
            assert_eq!(m.generated, a.generated + b.generated);
            assert_eq!(m.engine.full, a.engine.full + b.engine.full);
        });
    }

    #[test]
    fn merge_is_commutative() {
        for_cases(0xC0117, 200, |rng| {
            let a = random_stats(rng);
            let b = random_stats(rng);
            assert_eq!(merged(&a, &b), merged(&b, &a));
        });
    }

    #[test]
    fn merge_is_associative() {
        for_cases(0xA550C, 200, |rng| {
            let a = random_stats(rng);
            let b = random_stats(rng);
            let c = random_stats(rng);
            assert_eq!(merged(&merged(&a, &b), &c), merged(&a, &merged(&b, &c)));
        });
    }

    #[test]
    fn merge_identity_is_default() {
        for_cases(0x1D, 50, |rng| {
            let a = random_stats(rng);
            assert_eq!(merged(&a, &NetOptStats::default()), a);
            assert_eq!(merged(&NetOptStats::default(), &a), a);
        });
    }
}
