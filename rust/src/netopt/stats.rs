//! Counters for the network-level co-optimizer: how many architecture
//! points the design space generated, how many each filter removed, how
//! many the cross-architecture branch-and-bound abandoned, and the
//! aggregated per-layer engine counters.

use crate::engine::EvalSnapshot;

/// Roll-up of one [`super::co_optimize`] run. `generated ==
/// budget_filtered + ratio_filtered + candidates` and `candidates ==
/// pruned + evaluated_full` always hold.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct NetOptStats {
    /// Raw design-space grid points.
    pub generated: usize,
    /// Points dropped by the on-chip capacity budget.
    pub budget_filtered: usize,
    /// Points dropped by the Observation-2 ratio rule.
    pub ratio_filtered: usize,
    /// Points that entered evaluation.
    pub candidates: usize,
    /// Points abandoned by the network-level bound before completing all
    /// layers (branch-and-bound only; includes points whose bounded layer
    /// search came back empty).
    pub pruned: usize,
    /// Points evaluated through every layer.
    pub evaluated_full: usize,
    /// Fully evaluated points with at least one unmappable layer (their
    /// totals under-report; they never win).
    pub infeasible: usize,
    /// Fully evaluated points excluded by the `min_tops` constraint.
    pub throughput_filtered: usize,
    /// Per-layer searches actually run (shape-deduplicated).
    pub layer_searches: usize,
    /// Seeded layer searches that had to rerun because the borrowed
    /// cross-architecture seed clipped the result.
    pub layer_reruns: usize,
    /// Aggregated staged-engine counters across every layer search.
    pub engine: EvalSnapshot,
}

impl std::fmt::Display for NetOptStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "arch points: {} generated, {} budget-filtered, {} ratio-filtered, \
             {} candidates, {} pruned, {} fully evaluated ({} infeasible, \
             {} below min-tops); {} layer searches ({} seed reruns); engine: {}",
            self.generated,
            self.budget_filtered,
            self.ratio_filtered,
            self.candidates,
            self.pruned,
            self.evaluated_full,
            self.infeasible,
            self.throughput_filtered,
            self.layer_searches,
            self.layer_reruns,
            self.engine
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_mentions_counts() {
        let s = NetOptStats {
            generated: 10,
            candidates: 7,
            pruned: 4,
            evaluated_full: 3,
            ..Default::default()
        };
        let text = format!("{s}");
        assert!(text.contains("10 generated"));
        assert!(text.contains("4 pruned"));
        assert!(text.contains("3 fully evaluated"));
    }
}
