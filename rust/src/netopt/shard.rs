//! Sharded, multi-process design-space sweeps with mergeable incumbents.
//!
//! The §6.3 resource sweep is embarrassingly partitionable: architecture
//! points are independent except for the *shared incumbent* (which only
//! makes branch-and-bound faster, never changes the winner) and the
//! *seeds table* (which is rerun-corrected, never trusted). So a sweep
//! can be split across OS processes with no coordination at all:
//!
//! 1. **Partition** — [`DesignSpace::shard`] assigns raw grid point `i`
//!    to shard `i % nshards` (stable interleaving, balanced loads).
//! 2. **Run** — each worker process runs [`co_optimize_shard`] over its
//!    slice and writes a [`ShardCheckpoint`] as JSON (CLI:
//!    `co-opt --shard I/N --checkpoint PATH`).
//! 3. **Merge** — [`merge_checkpoints`] combines checkpoints pairwise
//!    (CLI: `co-opt-merge`): stats add field-wise, incumbents and seeds
//!    take minima, and the winner is the minimum by
//!    `(energy, global index)`. Every operation is associative and
//!    commutative, so any merge tree over any shard grouping produces
//!    the identical result.
//!
//! ## Shard composition and duplicate coverage
//!
//! `shard(i, n)` covers the raw-grid residue class `{g : g % n == i}`,
//! and classes **compose**: re-splitting shard `i/n` into `m` sub-shards
//! yields exactly the classes `(i + j*n)/(n*m)` for `j < m`, whose union
//! is the parent class. The merge layer exploits this for the
//! orchestrator's work stealing (`crate::orchestrator`): two checkpoints
//! are normalized to the lcm of their shard counts and compared as
//! raw-grid coverage there ([`merge_coverage`]). Disjoint coverage
//! merges exactly as before; *nested* coverage — a re-split straggler
//! finishing after its replacement sub-shards, or a speculative
//! duplicate — deduplicates under an identity check (completed totals
//! are deterministic per grid index, so duplicate runs must agree on any
//! shared winner index bit-for-bit; the duplicate's stats are dropped so
//! no grid point is double-counted); *partially* overlapping coverage,
//! which no shard()/re-split tree can produce, stays an error.
//!
//! ## Winner-identity contract (cross-process)
//!
//! Within one shard, the branch-and-bound winner equals the shard's
//! exhaustive winner — the per-shard incumbent only ever discards points
//! that cannot beat it, and the borrowed cross-architecture seeds are
//! inadmissible *only* until the existing rerun fallback fires (see the
//! parent module's docs), which restores exactness shard-locally.
//! The global winner is then the minimum over exact shard winners, with
//! ties broken by the global raw-grid index — the same total order the
//! single-process sort uses. Checkpoint JSON writes every float with
//! Rust's shortest round-trip formatting ([`crate::util::json`]), so the
//! merged winner is **bit-for-bit** identical to the single-process
//! [`co_optimize`](super::co_optimize) winner: architecture, energy
//! bits, and per-layer mappings. `netopt::tests` asserts this in-process
//! and `benches/perf_shard.rs` asserts it across real OS processes.
//!
//! ## Checkpoint JSON format (v1)
//!
//! ```json
//! {
//!   "format": "interstellar-shard-checkpoint-v1",
//!   "network": "mlp-m", "batch": 16,
//!   "nshards": 3, "shards": [0],
//!   "incumbent_pj": 1234.5,            // null == +inf (nothing completed)
//!   "stats": { ...NetOptStats fields..., "engine": {...} },
//!   "seeds": [ {"bounds": [7 ints], "stride": 1, "energy_pj": 12.5}, ... ],
//!   "winner": null | {
//!     "index": 17,                     // global raw-grid index
//!     "arch": { "name", "levels": [{"name","kind","size_bytes"}...],
//!               "array": {"rows","cols"}, "bus", "word_bytes",
//!               "dram_bw_bytes_per_cycle" },
//!     "opt": { "total_energy_pj", "total_cycles", "total_macs",
//!              "unmapped", "unmapped_layers": [...],
//!              "per_layer": [ null | {
//!                 "mapping": { "shape": {"bounds","stride"},
//!                              "blocking": [[7 ints]...],
//!                              "orders": [["FX","FY",...]...],
//!                              "spatial": [7 ints], "spatial_at": 1 },
//!                 "smap": { "u": [["K", 4]...], "v": [...] },
//!                 "evaluated": 600, "stats": {engine counters},
//!                 "result": { "levels": [{"reads":[3],"writes":[3]}...],
//!                             "fabric_words":[3], "fabric_hops", "macs",
//!                             "active_pes", "energy_by_level":[...],
//!                             "fabric_energy", "mac_energy", "energy_pj",
//!                             "cycles", "utilization" } } ] } }
//! }
//! ```
//!
//! The format is documented in `ARCHITECTURE.md`; bump
//! [`CHECKPOINT_FORMAT`] on any incompatible change.

use anyhow::{anyhow, bail, Result};

use crate::arch::{Arch, ArrayBus, ArrayShape, LevelKind, MemLevel};
use crate::dataflow::SpatialMap;
use crate::energy::CostModel;
use crate::engine::EvalSnapshot;
use crate::loopnest::{Blocking, Dim, LevelOrder, Mapping, Shape, NDIMS};
use crate::nn::Network;
use crate::search::{HierarchyResult, LayerOpt, NetworkOpt};
use crate::util::json::Json;
use crate::xmodel::{LevelCounts, ModelResult};

use crate::engine::Incumbent;

use super::{run_points_gated, CoOptResult, DesignSpace, NetOptConfig, NetOptStats, SeedTable};

/// Checkpoint schema identifier; readers reject anything else.
pub const CHECKPOINT_FORMAT: &str = "interstellar-shard-checkpoint-v1";

// ---- Residue-class shard coverage ------------------------------------

/// Cap on the normalized shard granularity a merge may expand coverage
/// to — guards the lcm expansion against pathological co-prime shard
/// counts. Orchestrator re-splits multiply granularity by small factors,
/// so real merge chains sit far below this.
pub(crate) const MAX_MERGE_GRANULARITY: usize = 1 << 20;

pub(crate) fn gcd(mut a: usize, mut b: usize) -> usize {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

/// Expand residue classes `{s (mod from)}` to the finer granularity `to`
/// (a multiple of `from`): each class becomes `to / from` classes.
/// Sorted output.
pub(crate) fn expand_classes(shards: &[usize], from: usize, to: usize) -> Vec<usize> {
    debug_assert!(from >= 1 && to % from == 0);
    let mut out: Vec<usize> = shards
        .iter()
        .flat_map(|&s| (0..to / from).map(move |t| s + t * from))
        .collect();
    out.sort_unstable();
    out
}

/// How two checkpoints' raw-grid coverages relate at their common
/// granularity (see [`merge_coverage`]).
pub(crate) enum CoverageRelation {
    /// No raw-grid index in common — the ordinary additive merge.
    Disjoint,
    /// `b`'s coverage is contained in `a`'s (or equal): `b` is a
    /// duplicate — dedup, keep `a`'s stats.
    AContainsB,
    /// `a`'s coverage is strictly contained in `b`'s: `a` is the
    /// duplicate — dedup, keep `b`'s stats.
    BContainsA,
}

/// Normalized union of two shard coverages: the lcm granularity, the
/// sorted union of both coverages expanded to it, and how they relate.
pub(crate) struct CoverageMerge {
    /// lcm of the two shard counts.
    pub nshards: usize,
    /// Sorted, deduplicated union at `nshards` granularity.
    pub shards: Vec<usize>,
    /// Disjoint, or which side contains the other.
    pub relation: CoverageRelation,
}

/// Relate two shard coverages, possibly at different granularities, by
/// expanding both to the lcm of their shard counts. Errors on partial
/// overlap (ambiguous double-counting — neither a disjoint merge nor a
/// contained duplicate; no shard()/re-split tree produces it) and on an
/// lcm above [`MAX_MERGE_GRANULARITY`].
pub(crate) fn merge_coverage(
    a_shards: &[usize],
    a_n: usize,
    b_shards: &[usize],
    b_n: usize,
) -> Result<CoverageMerge> {
    if a_n == 0 || b_n == 0 {
        bail!("shard count must be at least 1");
    }
    let l = (a_n / gcd(a_n, b_n))
        .checked_mul(b_n)
        .filter(|&l| l <= MAX_MERGE_GRANULARITY)
        .ok_or_else(|| {
            anyhow!("merged shard granularity lcm({a_n}, {b_n}) exceeds {MAX_MERGE_GRANULARITY}")
        })?;
    let ea = expand_classes(a_shards, a_n, l);
    let eb = expand_classes(b_shards, b_n, l);
    let in_a: std::collections::HashSet<usize> = ea.iter().copied().collect();
    let common = eb.iter().filter(|s| in_a.contains(s)).count();
    let relation = if common == 0 {
        CoverageRelation::Disjoint
    } else if common == eb.len() {
        CoverageRelation::AContainsB
    } else if common == ea.len() {
        CoverageRelation::BContainsA
    } else {
        bail!(
            "partially overlapping shard coverage: {:?}/{} vs {:?}/{}",
            a_shards,
            a_n,
            b_shards,
            b_n
        );
    };
    let mut shards = ea;
    shards.extend(eb);
    shards.sort_unstable();
    shards.dedup();
    Ok(CoverageMerge {
        nshards: l,
        shards,
        relation,
    })
}

/// Everything one worker (or a merge of workers) knows about its slice of
/// a [`co_optimize`](super::co_optimize) run: the exact winner of the
/// covered shards, the final incumbent bound, the best-known per-shape
/// seed energies, and the stats roll-up. Serializable as JSON
/// ([`to_json`](Self::to_json) / [`from_json`](Self::from_json)) and
/// mergeable associatively ([`merge_checkpoints`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ShardCheckpoint {
    /// Network name the run was over (merge identity guard).
    pub network: String,
    /// Batch size of the run (merge identity guard).
    pub batch: u64,
    /// Total shard count of the partition this checkpoint belongs to.
    pub nshards: usize,
    /// Shard indices covered (sorted; one entry per worker checkpoint,
    /// the union after merging — possibly re-expressed at a finer
    /// granularity when checkpoints with different shard counts merge).
    /// Duplicate coverage deduplicates under an identity check; partial
    /// overlap is an error (see the module docs).
    pub shards: Vec<usize>,
    /// Stats over the covered shards (space counters included, so the
    /// full merge reproduces the single-process counters' identities).
    pub stats: NetOptStats,
    /// Final network-level incumbent bound (+inf when nothing completed).
    pub incumbent_pj: f64,
    /// Best-known `(shape, stride) → energy` seeds.
    pub seeds: SeedTable,
    /// The covered shards' exact winner and its global raw-grid index
    /// (`None` when no fully-mapped, throughput-passing point exists).
    pub winner: Option<(usize, HierarchyResult)>,
}

/// [`co_optimize_shard`]'s full in-process return: the serializable
/// checkpoint plus the shard's complete ranked list (which the
/// in-process [`co_optimize_sharded`] merges so exhaustive callers keep
/// per-point energies; worker *processes* persist only the checkpoint).
#[derive(Debug, Clone)]
pub struct ShardRun {
    /// The mergeable, serializable summary.
    pub checkpoint: ShardCheckpoint,
    /// All completed points of this shard, `(global index, result)`,
    /// in the run's ranked order.
    pub ranked: Vec<(usize, HierarchyResult)>,
}

/// Run shard `index` of `nshards` of a co-optimization — the worker body
/// behind `co-opt --shard I/N`. Identical configuration across workers
/// (network, space, cost, cfg) is the caller's contract; the merge step
/// re-checks the cheap identity fields.
pub fn co_optimize_shard(
    net: &Network,
    space: &DesignSpace,
    cost: &dyn CostModel,
    cfg: &NetOptConfig,
    index: usize,
    nshards: usize,
) -> ShardRun {
    co_optimize_shard_impl(net, space, cost, cfg, index, nshards, None)
}

/// [`co_optimize_shard`] sharing an externally owned [`Incumbent`] — the
/// orchestrator's live bound-streaming hook (`crate::orchestrator`).
/// Values folded into `shared` before or during the run are energies of
/// *completed* points elsewhere in the same global sweep, i.e. admissible
/// network-level bounds: pruning against them discards only points that
/// cannot beat (or index-tie) the global winner, by exactly the
/// [`NetOptConfig::prime`] argument. The merged global winner keeps its
/// bits; the only effect is more pruning in this shard.
pub fn co_optimize_shard_with(
    net: &Network,
    space: &DesignSpace,
    cost: &dyn CostModel,
    cfg: &NetOptConfig,
    index: usize,
    nshards: usize,
    shared: &Incumbent,
) -> ShardRun {
    co_optimize_shard_impl(net, space, cost, cfg, index, nshards, Some(shared))
}

fn co_optimize_shard_impl(
    net: &Network,
    space: &DesignSpace,
    cost: &dyn CostModel,
    cfg: &NetOptConfig,
    index: usize,
    nshards: usize,
    shared: Option<&Incumbent>,
) -> ShardRun {
    let se = space.shard(index, nshards);
    let mut out = run_points_gated(net, se.candidates, cost, cfg, None, None, shared);
    out.stats.generated = se.generated;
    out.stats.budget_filtered = se.budget_filtered;
    out.stats.ratio_filtered = se.ratio_filtered;
    let winner = out
        .ranked
        .first()
        .filter(|(_, r)| r.opt.unmapped == 0)
        .cloned();
    ShardRun {
        checkpoint: ShardCheckpoint {
            network: net.name.clone(),
            batch: net.batch,
            nshards,
            shards: vec![index],
            stats: out.stats,
            incumbent_pj: out.incumbent_pj,
            seeds: out.seeds,
            winner,
        },
        ranked: out.ranked,
    }
}

/// Combine two checkpoints of the same run: incumbent and per-key seeds
/// take minima, the winner is the minimum by `(energy, global index)`,
/// and stats add when the coverages are disjoint. Checkpoints at
/// different shard granularities merge through [`merge_coverage`]:
/// nested (duplicate) coverage deduplicates — the duplicate side's stats
/// are dropped so no grid point double-counts, after an identity check
/// that any shared winner index carries bit-equal totals (completed
/// totals are deterministic per grid index, whatever bounds were
/// streamed in). Errors on mismatched run identity, partially
/// overlapping coverage, or a failed identity check.
pub fn merge_checkpoints(a: &ShardCheckpoint, b: &ShardCheckpoint) -> Result<ShardCheckpoint> {
    if a.network != b.network || a.batch != b.batch {
        bail!(
            "checkpoint mismatch: {}@{} vs {}@{}",
            a.network,
            a.batch,
            b.network,
            b.batch
        );
    }
    let cov = merge_coverage(&a.shards, a.nshards, &b.shards, b.nshards)?;

    // Identity check for duplicate coverage: two runs that both visited
    // a grid index must agree on its totals bit-for-bit. (Under disjoint
    // coverage equal winner indices are impossible, so the check only
    // ever fires on duplicates.)
    if let (Some(wa), Some(wb)) = (&a.winner, &b.winner) {
        if wa.0 == wb.0
            && (wa.1.opt.total_energy_pj.to_bits() != wb.1.opt.total_energy_pj.to_bits()
                || wa.1.opt.total_cycles.to_bits() != wb.1.opt.total_cycles.to_bits())
        {
            bail!(
                "duplicate-coverage identity check failed: winners disagree at grid index {} \
                 ({} pJ vs {} pJ)",
                wa.0,
                wa.1.opt.total_energy_pj,
                wb.1.opt.total_energy_pj
            );
        }
    }

    // Stats: disjoint coverage adds; duplicate coverage keeps the
    // covering side's counters. (Which duplicate "pays" when coverages
    // are equal is a merge-order detail of the telemetry — winner,
    // incumbent, seeds and coverage are all order-independent minima or
    // unions.)
    let stats = match cov.relation {
        CoverageRelation::Disjoint => {
            let mut s = a.stats.clone();
            s.merge(&b.stats);
            s
        }
        CoverageRelation::AContainsB => a.stats.clone(),
        CoverageRelation::BContainsA => b.stats.clone(),
    };

    // key-sorted min-merge, now owned by the shared SeedTable type
    // (idempotent per key, so duplicate coverage folds safely)
    let mut seeds = a.seeds.clone();
    seeds.merge(&b.seeds);

    let winner = match (&a.winner, &b.winner) {
        (None, w) | (w, None) => w.clone(),
        (Some(wa), Some(wb)) => {
            let a_wins = (wa.1.opt.total_energy_pj, wa.0) <= (wb.1.opt.total_energy_pj, wb.0);
            Some(if a_wins { wa.clone() } else { wb.clone() })
        }
    };

    Ok(ShardCheckpoint {
        network: a.network.clone(),
        batch: a.batch,
        nshards: cov.nshards,
        shards: cov.shards,
        stats,
        incumbent_pj: a.incumbent_pj.min(b.incumbent_pj),
        seeds,
        winner,
    })
}

/// Merge a whole set of checkpoints. Same-granularity disjoint sets
/// merge identically in any order (every per-field operation is
/// associative and commutative). Mixed-granularity sets — re-split
/// stolen shards, speculative duplicates — are folded coarsest-first
/// (ascending shard count, then lowest shard index), so a duplicate
/// checkpoint always meets an accumulated coverage that contains it and
/// deduplicates, instead of tripping the partial-overlap error an
/// unlucky fold order could produce. Errors on an empty set.
pub fn merge_all(ckpts: &[ShardCheckpoint]) -> Result<ShardCheckpoint> {
    if ckpts.is_empty() {
        bail!("no checkpoints to merge");
    }
    let mut order: Vec<&ShardCheckpoint> = ckpts.iter().collect();
    order.sort_by_key(|c| (c.nshards, c.shards.first().copied().unwrap_or(0)));
    let mut acc = order[0].clone();
    for c in &order[1..] {
        acc = merge_checkpoints(&acc, c)?;
    }
    Ok(acc)
}

/// In-process sharded co-optimization: run every shard (sequentially —
/// each shard parallelizes internally over `cfg.threads`; incumbents are
/// deliberately **not** shared across shards, exactly mirroring the
/// process-isolated deployment), merge the checkpoints, and return a
/// [`CoOptResult`] whose ranked list is the union of all shards in the
/// global total order. With `nshards == 1` this is `co_optimize` with
/// shard bookkeeping.
pub fn co_optimize_sharded(
    net: &Network,
    space: &DesignSpace,
    cost: &dyn CostModel,
    cfg: &NetOptConfig,
    nshards: usize,
) -> CoOptResult {
    assert!(nshards >= 1, "need at least one shard");
    let mut merged: Option<ShardCheckpoint> = None;
    let mut ranked: Vec<(usize, HierarchyResult)> = Vec::new();
    for i in 0..nshards {
        let run = co_optimize_shard(net, space, cost, cfg, i, nshards);
        ranked.extend(run.ranked);
        merged = Some(match merged {
            None => run.checkpoint,
            Some(m) => merge_checkpoints(&m, &run.checkpoint)
                .expect("same-run shard checkpoints must merge"),
        });
    }
    let merged = merged.expect("nshards >= 1");
    ranked.sort_by(super::rank_order);
    CoOptResult {
        ranked: ranked.into_iter().map(|(_, r)| r).collect(),
        stats: merged.stats,
        seeds: merged.seeds,
    }
}

impl ShardCheckpoint {
    /// The winner's result, if any shard found a feasible point.
    pub fn winner_result(&self) -> Option<&HierarchyResult> {
        self.winner.as_ref().map(|(_, r)| r)
    }

    /// Serialize to the v1 checkpoint JSON (see the module docs).
    pub fn to_json(&self) -> String {
        let winner = match &self.winner {
            None => Json::Null,
            Some((idx, r)) => Json::Obj(vec![
                ("index".into(), Json::int(*idx as u64)),
                ("arch".into(), arch_to_json(&r.arch)),
                ("opt".into(), opt_to_json(&r.opt)),
            ]),
        };
        Json::Obj(vec![
            ("format".into(), Json::str(CHECKPOINT_FORMAT)),
            ("network".into(), Json::str(&self.network)),
            ("batch".into(), Json::int(self.batch)),
            ("nshards".into(), Json::int(self.nshards as u64)),
            (
                "shards".into(),
                Json::Arr(self.shards.iter().map(|s| Json::int(*s as u64)).collect()),
            ),
            ("incumbent_pj".into(), Json::num(self.incumbent_pj)),
            ("stats".into(), stats_to_json(&self.stats)),
            ("seeds".into(), self.seeds.to_json()),
            ("winner".into(), winner),
        ])
        .to_string()
    }

    /// Parse a v1 checkpoint JSON document.
    pub fn from_json(text: &str) -> Result<ShardCheckpoint> {
        let v = Json::parse(text).map_err(|e| e.context("checkpoint is not valid JSON"))?;
        let format = v.field("format")?.as_str()?;
        if format != CHECKPOINT_FORMAT {
            bail!("unknown checkpoint format `{format}` (want `{CHECKPOINT_FORMAT}`)");
        }
        let seeds = SeedTable::from_json(v.field("seeds")?)?;
        let winner = match v.field("winner")? {
            Json::Null => None,
            w => Some((
                w.field("index")?.as_usize()?,
                HierarchyResult {
                    arch: arch_from_json(w.field("arch")?)?,
                    opt: opt_from_json(w.field("opt")?)?,
                },
            )),
        };
        let mut shards = Vec::new();
        for s in v.field("shards")?.as_arr()? {
            shards.push(s.as_usize()?);
        }
        Ok(ShardCheckpoint {
            network: v.field("network")?.as_str()?.to_string(),
            batch: v.field("batch")?.as_u64()?,
            nshards: v.field("nshards")?.as_usize()?,
            shards,
            stats: stats_from_json(v.field("stats")?)?,
            incumbent_pj: v.field("incumbent_pj")?.as_f64()?,
            seeds,
            winner,
        })
    }
}

// ---- JSON codecs for the winner payload ------------------------------

fn u64_arr(xs: &[u64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::int(x)).collect())
}

fn f64_arr(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::num(x)).collect())
}

fn u64s(v: &Json) -> Result<Vec<u64>> {
    v.as_arr()?.iter().map(|x| x.as_u64()).collect()
}

fn f64s(v: &Json) -> Result<Vec<f64>> {
    v.as_arr()?.iter().map(|x| x.as_f64()).collect()
}

fn u64_fixed<const N: usize>(v: &Json) -> Result<[u64; N]> {
    u64s(v)?
        .try_into()
        .map_err(|xs: Vec<u64>| anyhow!("expected {N} ints, got {}", xs.len()))
}

fn f64_fixed<const N: usize>(v: &Json) -> Result<[f64; N]> {
    f64s(v)?
        .try_into()
        .map_err(|xs: Vec<f64>| anyhow!("expected {N} numbers, got {}", xs.len()))
}

pub(crate) fn arch_to_json(a: &Arch) -> Json {
    let levels = a
        .levels
        .iter()
        .map(|l| {
            let kind = match l.kind {
                LevelKind::Reg => "reg",
                LevelKind::Sram => "sram",
                LevelKind::Dram => "dram",
            };
            let mut m = vec![
                ("name".into(), Json::str(&l.name)),
                ("kind".into(), Json::str(kind)),
            ];
            // DRAM capacity is the u64::MAX sentinel — implied by kind
            if l.kind != LevelKind::Dram {
                m.push(("size_bytes".into(), Json::int(l.size_bytes)));
            }
            Json::Obj(m)
        })
        .collect();
    Json::Obj(vec![
        ("name".into(), Json::str(&a.name)),
        ("levels".into(), Json::Arr(levels)),
        (
            "array".into(),
            Json::Obj(vec![
                ("rows".into(), Json::int(a.array.rows as u64)),
                ("cols".into(), Json::int(a.array.cols as u64)),
            ]),
        ),
        (
            "bus".into(),
            Json::str(match a.bus {
                ArrayBus::Systolic => "systolic",
                ArrayBus::Broadcast => "broadcast",
            }),
        ),
        ("word_bytes".into(), Json::int(a.word_bytes as u64)),
        (
            "dram_bw_bytes_per_cycle".into(),
            Json::num(a.dram_bw_bytes_per_cycle),
        ),
    ])
}

pub(crate) fn arch_from_json(v: &Json) -> Result<Arch> {
    let mut levels = Vec::new();
    for l in v.field("levels")?.as_arr()? {
        let name = l.field("name")?.as_str()?;
        levels.push(match l.field("kind")?.as_str()? {
            "reg" => MemLevel::reg(name, l.field("size_bytes")?.as_u64()?),
            "sram" => MemLevel::sram(name, l.field("size_bytes")?.as_u64()?),
            "dram" => MemLevel::dram(),
            other => bail!("unknown level kind `{other}`"),
        });
    }
    let array = v.field("array")?;
    Ok(Arch {
        name: v.field("name")?.as_str()?.to_string(),
        levels,
        array: ArrayShape {
            rows: array.field("rows")?.as_u64()? as u32,
            cols: array.field("cols")?.as_u64()? as u32,
        },
        bus: match v.field("bus")?.as_str()? {
            "systolic" => ArrayBus::Systolic,
            "broadcast" => ArrayBus::Broadcast,
            other => bail!("unknown bus `{other}`"),
        },
        word_bytes: v.field("word_bytes")?.as_u64()? as u32,
        dram_bw_bytes_per_cycle: v.field("dram_bw_bytes_per_cycle")?.as_f64()?,
    })
}

fn shape_to_json(s: &Shape) -> Json {
    Json::Obj(vec![
        ("bounds".into(), u64_arr(&s.bounds)),
        ("stride".into(), Json::int(s.stride as u64)),
    ])
}

fn shape_from_json(v: &Json) -> Result<Shape> {
    Ok(Shape {
        bounds: u64_fixed::<NDIMS>(v.field("bounds")?)?,
        stride: v.field("stride")?.as_u64()? as u32,
    })
}

fn order_to_json(o: &LevelOrder) -> Json {
    Json::Arr(o.0.iter().map(|d| Json::str(d.name())).collect())
}

fn order_from_json(v: &Json) -> Result<LevelOrder> {
    let names = v.as_arr()?;
    if names.len() != NDIMS {
        bail!("level order needs {NDIMS} dims");
    }
    let mut dims = [Dim::B; NDIMS];
    for (i, n) in names.iter().enumerate() {
        let n = n.as_str()?;
        dims[i] = Dim::parse(n).ok_or_else(|| anyhow!("unknown dim `{n}`"))?;
    }
    let o = LevelOrder(dims);
    if !o.is_valid() {
        bail!("level order is not a permutation");
    }
    Ok(o)
}

fn mapping_to_json(m: &Mapping) -> Json {
    Json::Obj(vec![
        ("shape".into(), shape_to_json(&m.shape)),
        (
            "blocking".into(),
            Json::Arr(m.blocking.factors.iter().map(|f| u64_arr(f.as_slice())).collect()),
        ),
        (
            "orders".into(),
            Json::Arr(m.orders.iter().map(order_to_json).collect()),
        ),
        ("spatial".into(), u64_arr(&m.spatial)),
        ("spatial_at".into(), Json::int(m.spatial_at as u64)),
    ])
}

fn mapping_from_json(v: &Json) -> Result<Mapping> {
    let mut factors = Vec::new();
    for f in v.field("blocking")?.as_arr()? {
        factors.push(u64_fixed::<NDIMS>(f)?);
    }
    let mut orders = Vec::new();
    for o in v.field("orders")?.as_arr()? {
        orders.push(order_from_json(o)?);
    }
    let m = Mapping {
        shape: shape_from_json(v.field("shape")?)?,
        blocking: Blocking { factors },
        orders,
        spatial: u64_fixed::<NDIMS>(v.field("spatial")?)?,
        spatial_at: v.field("spatial_at")?.as_usize()?,
    };
    m.validate().map_err(|e| anyhow!("invalid mapping: {e}"))?;
    Ok(m)
}

fn smap_axis_to_json(axis: &[(Dim, u64)]) -> Json {
    Json::Arr(
        axis.iter()
            .map(|(d, e)| Json::Arr(vec![Json::str(d.name()), Json::int(*e)]))
            .collect(),
    )
}

fn smap_axis_from_json(v: &Json) -> Result<Vec<(Dim, u64)>> {
    let mut out = Vec::new();
    for pair in v.as_arr()? {
        let pair = pair.as_arr()?;
        if pair.len() != 2 {
            bail!("spatial-map entry must be [dim, extent]");
        }
        let n = pair[0].as_str()?;
        out.push((
            Dim::parse(n).ok_or_else(|| anyhow!("unknown dim `{n}`"))?,
            pair[1].as_u64()?,
        ));
    }
    Ok(out)
}

fn smap_to_json(s: &SpatialMap) -> Json {
    Json::Obj(vec![
        ("u".into(), smap_axis_to_json(&s.u)),
        ("v".into(), smap_axis_to_json(&s.v)),
    ])
}

fn smap_from_json(v: &Json) -> Result<SpatialMap> {
    Ok(SpatialMap {
        u: smap_axis_from_json(v.field("u")?)?,
        v: smap_axis_from_json(v.field("v")?)?,
    })
}

fn snapshot_to_json(s: &EvalSnapshot) -> Json {
    Json::Obj(vec![
        ("stage2".into(), Json::int(s.stage2)),
        ("fit_rejected".into(), Json::int(s.fit_rejected)),
        ("stage3".into(), Json::int(s.stage3)),
        ("pruned".into(), Json::int(s.pruned)),
        ("full".into(), Json::int(s.full)),
    ])
}

fn snapshot_from_json(v: &Json) -> Result<EvalSnapshot> {
    Ok(EvalSnapshot {
        stage2: v.field("stage2")?.as_u64()?,
        fit_rejected: v.field("fit_rejected")?.as_u64()?,
        stage3: v.field("stage3")?.as_u64()?,
        pruned: v.field("pruned")?.as_u64()?,
        full: v.field("full")?.as_u64()?,
    })
}

fn result_to_json(r: &ModelResult) -> Json {
    let levels = r
        .levels
        .iter()
        .map(|l| {
            Json::Obj(vec![
                ("reads".into(), f64_arr(&l.reads)),
                ("writes".into(), f64_arr(&l.writes)),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("levels".into(), Json::Arr(levels)),
        ("fabric_words".into(), f64_arr(&r.fabric_words)),
        ("fabric_hops".into(), Json::num(r.fabric_hops)),
        ("macs".into(), Json::int(r.macs)),
        ("active_pes".into(), Json::int(r.active_pes)),
        ("energy_by_level".into(), f64_arr(&r.energy_by_level)),
        ("fabric_energy".into(), Json::num(r.fabric_energy)),
        ("mac_energy".into(), Json::num(r.mac_energy)),
        ("energy_pj".into(), Json::num(r.energy_pj)),
        ("cycles".into(), Json::num(r.cycles)),
        ("utilization".into(), Json::num(r.utilization)),
    ])
}

fn result_from_json(v: &Json) -> Result<ModelResult> {
    let mut levels = Vec::new();
    for l in v.field("levels")?.as_arr()? {
        levels.push(LevelCounts {
            reads: f64_fixed::<3>(l.field("reads")?)?,
            writes: f64_fixed::<3>(l.field("writes")?)?,
        });
    }
    Ok(ModelResult {
        levels,
        fabric_words: f64_fixed::<3>(v.field("fabric_words")?)?,
        fabric_hops: v.field("fabric_hops")?.as_f64()?,
        macs: v.field("macs")?.as_u64()?,
        active_pes: v.field("active_pes")?.as_u64()?,
        energy_by_level: f64s(v.field("energy_by_level")?)?,
        fabric_energy: v.field("fabric_energy")?.as_f64()?,
        mac_energy: v.field("mac_energy")?.as_f64()?,
        energy_pj: v.field("energy_pj")?.as_f64()?,
        cycles: v.field("cycles")?.as_f64()?,
        utilization: v.field("utilization")?.as_f64()?,
    })
}

fn layer_opt_to_json(lo: &LayerOpt) -> Json {
    Json::Obj(vec![
        ("mapping".into(), mapping_to_json(&lo.mapping)),
        ("smap".into(), smap_to_json(&lo.smap)),
        ("result".into(), result_to_json(&lo.result)),
        ("evaluated".into(), Json::int(lo.evaluated as u64)),
        ("stats".into(), snapshot_to_json(&lo.stats)),
    ])
}

fn layer_opt_from_json(v: &Json) -> Result<LayerOpt> {
    Ok(LayerOpt {
        mapping: mapping_from_json(v.field("mapping")?)?,
        smap: smap_from_json(v.field("smap")?)?,
        result: result_from_json(v.field("result")?)?,
        evaluated: v.field("evaluated")?.as_usize()?,
        stats: snapshot_from_json(v.field("stats")?)?,
    })
}

pub(crate) fn opt_to_json(o: &NetworkOpt) -> Json {
    let per_layer = o
        .per_layer
        .iter()
        .map(|l| match l {
            Some(lo) => layer_opt_to_json(lo),
            None => Json::Null,
        })
        .collect();
    Json::Obj(vec![
        ("total_energy_pj".into(), Json::num(o.total_energy_pj)),
        ("total_cycles".into(), Json::num(o.total_cycles)),
        ("total_macs".into(), Json::int(o.total_macs)),
        ("unmapped".into(), Json::int(o.unmapped as u64)),
        (
            "unmapped_layers".into(),
            Json::Arr(o.unmapped_layers.iter().map(|&i| Json::int(i as u64)).collect()),
        ),
        ("per_layer".into(), Json::Arr(per_layer)),
    ])
}

pub(crate) fn opt_from_json(v: &Json) -> Result<NetworkOpt> {
    let mut per_layer = Vec::new();
    for l in v.field("per_layer")?.as_arr()? {
        per_layer.push(match l {
            Json::Null => None,
            lo => Some(layer_opt_from_json(lo)?),
        });
    }
    let mut unmapped_layers = Vec::new();
    for i in v.field("unmapped_layers")?.as_arr()? {
        unmapped_layers.push(i.as_usize()?);
    }
    Ok(NetworkOpt {
        per_layer,
        total_energy_pj: v.field("total_energy_pj")?.as_f64()?,
        total_cycles: v.field("total_cycles")?.as_f64()?,
        total_macs: v.field("total_macs")?.as_u64()?,
        unmapped: v.field("unmapped")?.as_usize()?,
        unmapped_layers,
    })
}

pub(crate) fn stats_to_json(s: &NetOptStats) -> Json {
    Json::Obj(vec![
        ("generated".into(), Json::int(s.generated as u64)),
        ("budget_filtered".into(), Json::int(s.budget_filtered as u64)),
        ("ratio_filtered".into(), Json::int(s.ratio_filtered as u64)),
        ("candidates".into(), Json::int(s.candidates as u64)),
        ("pruned".into(), Json::int(s.pruned as u64)),
        ("evaluated_full".into(), Json::int(s.evaluated_full as u64)),
        ("infeasible".into(), Json::int(s.infeasible as u64)),
        (
            "throughput_filtered".into(),
            Json::int(s.throughput_filtered as u64),
        ),
        ("layer_searches".into(), Json::int(s.layer_searches as u64)),
        ("layer_reruns".into(), Json::int(s.layer_reruns as u64)),
        ("engine".into(), snapshot_to_json(&s.engine)),
    ])
}

pub(crate) fn stats_from_json(v: &Json) -> Result<NetOptStats> {
    Ok(NetOptStats {
        generated: v.field("generated")?.as_usize()?,
        budget_filtered: v.field("budget_filtered")?.as_usize()?,
        ratio_filtered: v.field("ratio_filtered")?.as_usize()?,
        candidates: v.field("candidates")?.as_usize()?,
        pruned: v.field("pruned")?.as_usize()?,
        evaluated_full: v.field("evaluated_full")?.as_usize()?,
        infeasible: v.field("infeasible")?.as_usize()?,
        throughput_filtered: v.field("throughput_filtered")?.as_usize()?,
        layer_searches: v.field("layer_searches")?.as_usize()?,
        layer_reruns: v.field("layer_reruns")?.as_usize()?,
        engine: snapshot_from_json(v.field("engine")?)?,
    })
}
