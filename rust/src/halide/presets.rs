//! Re-creations of prior-work accelerator schedules (§4.2 / Fig 6):
//! Eyeriss row-stationary, TPU `C|K`, ShiDianNao output-stationary,
//! DianNao reduction tree, NVDLA-like. Each returns a [`Schedule`] that
//! lowers against the matching 3-level architecture.

use super::schedule::{Axis, Schedule};
use crate::loopnest::{Dim, Shape};
use crate::util::divisors;

/// Largest divisor of `n` that is `<= cap`.
fn dv(n: u64, cap: u64) -> u64 {
    divisors(n).into_iter().filter(|&d| d <= cap).max().unwrap_or(1)
}

/// Shared builder: split each dim into (outer, mid, rf) pieces plus
/// spatial extents, order the nest, attach the RF and GBUF buffers.
///
/// `rf` and `mid` list (dim, extent) innermost-first; any dim's leftover
/// iterates at the DRAM level. `unroll_u`/`unroll_v` extents must divide
/// the bound alongside the temporal pieces (the caller passes divisors).
#[allow(clippy::too_many_arguments)]
fn build(
    name: &str,
    shape: Shape,
    rf: &[(Dim, u64)],
    mid: &[(Dim, u64)],
    unroll_u: &[(Dim, u64)],
    unroll_v: &[(Dim, u64)],
    systolic: bool,
) -> Schedule {
    let mut s = Schedule::new(name, shape);
    let f = |list: &[(Dim, u64)], d: Dim| -> u64 {
        list.iter().find(|(x, _)| *x == d).map(|(_, e)| *e).unwrap_or(1)
    };

    let mut rf_ids = Vec::new();
    let mut sp_u = Vec::new();
    let mut sp_v = Vec::new();
    let mut mid_ids = Vec::new();
    let mut outer_ids = Vec::new();

    for d in [Dim::FX, Dim::FY, Dim::X, Dim::Y, Dim::C, Dim::K, Dim::B] {
        let (rf_e, u_e, v_e, mid_e) = (f(rf, d), f(unroll_u, d), f(unroll_v, d), f(mid, d));
        let bound = shape.bound(d);
        debug_assert_eq!(
            bound % (rf_e * u_e * v_e * mid_e),
            0,
            "{d}: {bound} not divisible by pieces"
        );
        let mut outer = s.loop_of(d);
        // split chain: peel rf, then spatial, then mid; leftover = outer
        if rf_e * u_e * v_e * mid_e > 1 {
            let (o, rest) = s.split(outer, rf_e * u_e * v_e * mid_e);
            outer = o;
            let (rest2, rf_id) = s.split(rest, rf_e);
            rf_ids.push(rf_id);
            let (rest3, u_id) = s.split(rest2, u_e);
            if u_e > 1 {
                s.unroll(u_id, Axis::U);
                sp_u.push(u_id);
            } else {
                mid_ids.push(u_id); // unit piece rides in the mid segment
            }
            let (rest4, v_id) = s.split(rest3, v_e);
            if v_e > 1 {
                s.unroll(v_id, Axis::V);
                sp_v.push(v_id);
            } else {
                mid_ids.push(v_id);
            }
            // rest4 extent == mid_e
            mid_ids.push(rest4);
        }
        outer_ids.push(outer);
    }

    // order innermost-first: rf pieces (caller's order first), then
    // spatial, then mid, then outer.
    let mut order: Vec<super::schedule::LoopId> = Vec::new();
    for (d, _) in rf {
        if let Some(id) = rf_ids.iter().find(|id| s.dim(**id) == *d) {
            order.push(*id);
        }
    }
    for id in &rf_ids {
        if !order.contains(id) {
            order.push(*id);
        }
    }
    let rf_count = order.len();
    for id in sp_u.iter().chain(sp_v.iter()) {
        order.push(*id);
    }
    for (d, _) in mid {
        if let Some(id) = mid_ids
            .iter()
            .find(|id| s.dim(**id) == *d && s.extent(**id) > 1 && !order.contains(id))
        {
            order.push(*id);
        }
    }
    for id in &mid_ids {
        if !order.contains(id) {
            order.push(*id);
        }
    }
    for id in &outer_ids {
        order.push(*id);
    }
    s.reorder(&order);

    // buffers: RF attaches at the first loop outside the RF segment,
    // GBUF at the first outer loop.
    let rf_attach = order[rf_count];
    let gbuf_attach = order[order.len() - outer_ids.len()];
    s.buffer_at("rf", rf_attach);
    s.buffer_at("gbuf", gbuf_attach);

    if systolic {
        s.set_systolic();
    }
    s
}

/// Eyeriss row-stationary (`FY | Y`): filter rows move horizontally,
/// output rows accumulate vertically (Fig 6a).
pub fn eyeriss_rs(shape: Shape, rows: u64, cols: u64) -> Schedule {
    let fy = dv(shape.bound(Dim::FY), rows);
    let y = dv(shape.bound(Dim::Y), cols);
    let c0 = dv(shape.bound(Dim::C), 2);
    let x0 = dv(shape.bound(Dim::X), 2);
    let k_mid = dv(shape.bound(Dim::K), 16);
    let c_mid = dv(shape.bound(Dim::C) / c0, 8);
    build(
        "eyeriss_rs",
        shape,
        &[(Dim::FX, shape.bound(Dim::FX)), (Dim::X, x0), (Dim::C, c0)],
        &[(Dim::K, k_mid), (Dim::C, c_mid), (Dim::X, dv(shape.bound(Dim::X) / x0, 4))],
        &[(Dim::FY, fy)],
        &[(Dim::Y, y)],
        true,
    )
}

/// TPU-style `C | K` systolic matmul (Fig 6b): input channels stream
/// vertically, output channels accumulate horizontally.
pub fn tpu_ck(shape: Shape, rows: u64, cols: u64) -> Schedule {
    let c = dv(shape.bound(Dim::C), rows);
    let k = dv(shape.bound(Dim::K), cols);
    let x0 = dv(shape.bound(Dim::X), 2);
    build(
        "tpu_ck",
        shape,
        &[
            (Dim::FX, shape.bound(Dim::FX)),
            (Dim::FY, shape.bound(Dim::FY)),
            (Dim::X, x0),
        ],
        &[
            (Dim::X, dv(shape.bound(Dim::X) / x0, 8)),
            (Dim::Y, dv(shape.bound(Dim::Y), 8)),
            (Dim::K, dv(shape.bound(Dim::K) / k, 4)),
        ],
        &[(Dim::C, c)],
        &[(Dim::K, k)],
        true,
    )
}

/// ShiDianNao output-stationary (`X | Y`): each PE owns an output pixel.
pub fn shidiannao_os(shape: Shape, rows: u64, cols: u64) -> Schedule {
    let x = dv(shape.bound(Dim::X), rows);
    let y = dv(shape.bound(Dim::Y), cols);
    build(
        "shidiannao_os",
        shape,
        &[
            (Dim::FX, shape.bound(Dim::FX)),
            (Dim::FY, shape.bound(Dim::FY)),
            (Dim::C, dv(shape.bound(Dim::C), 2)),
        ],
        &[
            (Dim::C, dv(shape.bound(Dim::C) / dv(shape.bound(Dim::C), 2), 8)),
            (Dim::K, dv(shape.bound(Dim::K), 8)),
        ],
        &[(Dim::X, x)],
        &[(Dim::Y, y)],
        true,
    )
}

/// DianNao-style 1D reduction tree over input channels (Fig 6c):
/// broadcast bus, no inter-PE forwarding.
pub fn diannao_tree(shape: Shape, rows: u64) -> Schedule {
    let c = dv(shape.bound(Dim::C), rows);
    build(
        "diannao_tree",
        shape,
        &[
            (Dim::FX, shape.bound(Dim::FX)),
            (Dim::FY, shape.bound(Dim::FY)),
        ],
        &[
            (Dim::K, dv(shape.bound(Dim::K), 16)),
            (Dim::X, dv(shape.bound(Dim::X), 4)),
        ],
        &[(Dim::C, c)],
        &[],
        false,
    )
}

/// NVDLA-like `C | K` with a broadcast data bus.
pub fn nvdla_like(shape: Shape, rows: u64, cols: u64) -> Schedule {
    let c = dv(shape.bound(Dim::C), rows);
    let k = dv(shape.bound(Dim::K), cols);
    build(
        "nvdla_like",
        shape,
        &[
            (Dim::FX, shape.bound(Dim::FX)),
            (Dim::FY, shape.bound(Dim::FY)),
        ],
        &[
            (Dim::X, dv(shape.bound(Dim::X), 8)),
            (Dim::Y, dv(shape.bound(Dim::Y), 8)),
        ],
        &[(Dim::C, c)],
        &[(Dim::K, k)],
        false,
    )
}
