//! Lowering a [`Schedule`] onto the loop-nest IR.

use super::schedule::{Axis, Schedule};
use crate::arch::{Arch, ArrayBus};
use crate::dataflow::SpatialMap;
use crate::loopnest::{Blocking, Dim, LevelOrder, Mapping, ALL_DIMS, NDIMS};

/// Lowering failures.
#[derive(Debug, Clone, PartialEq)]
pub enum LowerError {
    /// Number of buffer groups must be `arch levels - 1`.
    WrongBufferCount {
        /// Buffer groups declared.
        got: usize,
        /// Groups required by the architecture.
        want: usize,
    },
    /// Buffer attach points must nest strictly outward.
    BuffersNotNested,
    /// Spatial extents exceed the array axis.
    ArrayOverflow {
        /// Axis name ("U" or "V").
        axis: &'static str,
        /// Product of unrolled extents.
        extent: u64,
        /// Physical axis size.
        size: u64,
    },
    /// The schedule requests systolic forwarding but the architecture has
    /// a broadcast bus (or vice versa).
    BusMismatch,
}

impl std::fmt::Display for LowerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LowerError::WrongBufferCount { got, want } => {
                write!(f, "schedule declares {got} buffer groups, arch needs {want}")
            }
            LowerError::BuffersNotNested => write!(f, "buffer attach points must nest"),
            LowerError::ArrayOverflow { axis, extent, size } => {
                write!(f, "axis {axis}: unrolled extent {extent} > array size {size}")
            }
            LowerError::BusMismatch => write!(f, "systolic/broadcast mismatch with arch"),
        }
    }
}

impl std::error::Error for LowerError {}

impl Schedule {
    /// `accelerate`: lower the schedule for a target architecture into
    /// the `(Mapping, SpatialMap)` pair consumed by the model, the
    /// simulator, and the search.
    pub fn lower(&self, arch: &Arch) -> Result<(Mapping, SpatialMap), LowerError> {
        let nlv = arch.num_levels();

        // group buffers by attach loop, positions sorted innermost-first
        let mut attach_positions: Vec<usize> = self
            .buffers
            .iter()
            .map(|b| self.pos(b.at))
            .collect();
        attach_positions.sort_unstable();
        attach_positions.dedup();
        if attach_positions.len() != nlv - 1 {
            return Err(LowerError::WrongBufferCount {
                got: attach_positions.len(),
                want: nlv - 1,
            });
        }

        if self.systolic != (arch.bus == ArrayBus::Systolic) {
            return Err(LowerError::BusMismatch);
        }

        // spatial map from unrolled pieces (push order = proximity order)
        let mut smap = SpatialMap::scalar();
        for &id in self.order.iter() {
            let p = &self.pieces[id.0];
            match p.unrolled {
                Some(Axis::U) => smap.u.push((p.dim, p.extent)),
                Some(Axis::V) => smap.v.push((p.dim, p.extent)),
                None => {}
            }
        }
        let (eu, ev) = (smap.axis_extent(true), smap.axis_extent(false));
        if eu > arch.array.rows as u64 {
            return Err(LowerError::ArrayOverflow {
                axis: "U",
                extent: eu,
                size: arch.array.rows as u64,
            });
        }
        if ev > arch.array.cols as u64 {
            return Err(LowerError::ArrayOverflow {
                axis: "V",
                extent: ev,
                size: arch.array.cols as u64,
            });
        }

        // assign temporal pieces to levels by their position relative to
        // the attach points: inside attach[0] -> level 0, between
        // attach[i-1] and attach[i] -> level i, outside the last -> DRAM
        let mut blocking = Blocking::ones(nlv);
        let mut level_dims: Vec<Vec<Dim>> = vec![Vec::new(); nlv]; // innermost-first per level
        for (pos, &id) in self.order.iter().enumerate() {
            let p = &self.pieces[id.0];
            if p.unrolled.is_some() {
                continue;
            }
            let level = attach_positions
                .iter()
                .position(|&a| pos < a)
                .unwrap_or(nlv - 1);
            let cur = blocking.factor(level, p.dim);
            blocking.set(level, p.dim, cur * p.extent);
            if !level_dims[level].contains(&p.dim) {
                level_dims[level].push(p.dim);
            }
        }

        // per-level orders: listed dims innermost-first, then the rest
        let orders: Vec<LevelOrder> = level_dims
            .iter()
            .map(|dims| {
                let mut o: Vec<Dim> = dims.clone();
                for d in ALL_DIMS {
                    if !o.contains(&d) {
                        o.push(d);
                    }
                }
                let mut arr = [Dim::B; NDIMS];
                arr.copy_from_slice(&o);
                LevelOrder(arr)
            })
            .collect();

        let mapping = Mapping {
            shape: self.shape,
            blocking,
            orders,
            spatial: smap.factors(),
            spatial_at: arch.rf_levels(),
        };
        Ok((mapping, smap))
    }
}
