//! The Halide-style scheduling language (§4): `split`, `reorder`,
//! `in_` + `compute_at`, `unroll`, `systolic`, `accelerate` — and its
//! lowering onto the loop-nest IR ([`crate::loopnest::Mapping`]).
//!
//! The paper's key claim is that these primitives are sufficient to
//! express every dense DNN accelerator. Here a [`Schedule`] is built by
//! applying primitives to the seven-loop CONV algorithm; `lower()`
//! produces the `(Mapping, SpatialMap)` pair the analytical model, the
//! simulator, and the hardware backend all consume, and
//! [`print_ir`](printer::print_ir) renders the Listing-2-style
//! intermediate representation.
//!
//! Lowering contract: an architecture with `L` storage levels needs
//! `L - 1` buffer groups (`in_` + `compute_at`), one per on-chip level,
//! innermost (RF) first; loops inside the innermost attach point become
//! level-0 (RF) factors, loops between attach points `i-1` and `i`
//! become level-`i` factors, loops outside the outermost attach point
//! become DRAM-level factors. `unroll`ed loops leave the temporal nest
//! and become the spatial map.

mod lower;
mod presets;
mod printer;
mod schedule;

pub use lower::LowerError;
pub use presets::{diannao_tree, eyeriss_rs, nvdla_like, shidiannao_os, tpu_ck};
pub use printer::print_ir;
pub use schedule::{Axis, LoopId, Schedule};

#[cfg(test)]
mod tests;
