//! Listing-2-style IR pretty-printer: the lowered loop nest with buffer
//! allocations, unrolled loops, and the MAC statement.

use super::schedule::Schedule;
use crate::loopnest::Dim;

/// Render the schedule as the intermediate representation the paper's
/// Listing 2 shows: nested `for` loops (outermost first), `alloc`/copy
/// lines at each buffer attach point, `unrolled_for` for spatial loops,
/// and the innermost compute statement.
pub fn print_ir(s: &Schedule) -> String {
    let mut out = String::new();
    let mut indent = 0usize;

    // count suffix occurrences per dim to name pieces xo/xi/x2...
    let mut seen: std::collections::HashMap<Dim, usize> = std::collections::HashMap::new();
    let mut names: Vec<String> = vec![String::new(); s.pieces.len()];
    // order outermost-first for naming: outer pieces get "o", inner "i"
    for &id in s.order.iter().rev() {
        let d = s.pieces[id.0].dim;
        let n = seen.entry(d).or_insert(0);
        let total_pieces = s
            .pieces
            .iter()
            .filter(|p| p.dim == d)
            .count();
        let base = d.name().to_lowercase();
        names[id.0] = if total_pieces == 1 {
            base
        } else if *n == 0 {
            format!("{base}o")
        } else if *n == total_pieces - 1 {
            format!("{base}i")
        } else {
            format!("{base}{n}")
        };
        *n += 1;
    }

    let pad = |n: usize| "  ".repeat(n);

    // walk outermost -> innermost, emitting buffers attached at each loop
    for (rev_idx, &id) in s.order.iter().rev().enumerate() {
        let pos = s.order.len() - 1 - rev_idx;
        let p = &s.pieces[id.0];
        let kw = if p.unrolled.is_some() {
            "unrolled_for"
        } else {
            "for"
        };
        out.push_str(&format!(
            "{}{} ({}, 0, {})\n",
            pad(indent),
            kw,
            names[id.0],
            p.extent
        ));
        indent += 1;
        // buffers attached at this loop are allocated just inside it
        for b in &s.buffers {
            if s.pos(b.at) == pos {
                out.push_str(&format!("{}alloc {}[...]\n", pad(indent), b.name));
                out.push_str(&format!("{}{}[...] = <parent>[...]\n", pad(indent), b.name));
            }
        }
    }
    out.push_str(&format!(
        "{}{}(x, y, k) += ibuf(x + r.x, y + r.y, r.z) * wbuf(r.x, r.y, r.z, k)\n",
        pad(indent),
        s.name
    ));
    out
}
