//! Schedule DSL tests: primitives, lowering, IR printing, presets.

use super::*;
use crate::arch::{eyeriss_like, no_local_reuse};
use crate::energy::Table3;
use crate::loopnest::{Dim, Shape};
use crate::xmodel::evaluate;

fn listing1_shape() -> Shape {
    // The paper's running example: 16x16x64 output from 3x5x5 filters
    Shape::new(1, 64, 3, 16, 16, 5, 5, 1)
}

/// Build the paper's Listing 1 schedule: split x and y by 8, buffers at
/// xo, unroll xi on a 4-PE systolic row.
fn listing1() -> Schedule {
    let mut s = Schedule::new("output", listing1_shape());
    let (_xo, xi) = s.split_dim(Dim::X, 8);
    let (_yo, _yi) = s.split_dim(Dim::Y, 8);
    let (_xii_o, xii) = s.split(xi, 4); // the 4-wide systolic piece
    s.unroll(xii, Axis::U);
    s.set_systolic();
    s
}

#[test]
fn split_preserves_product() {
    let mut s = Schedule::new("f", listing1_shape());
    let (xo, xi) = s.split_dim(Dim::X, 8);
    assert_eq!(s.extent(xo), 2);
    assert_eq!(s.extent(xi), 8);
    assert_eq!(s.dim(xo), Dim::X);
    assert_eq!(s.dim(xi), Dim::X);
    assert_eq!(s.num_loops(), 8);
    // inner piece sits directly inside the outer
    assert_eq!(s.pos(xi) + 1, s.pos(xo));
}

#[test]
#[should_panic(expected = "must divide")]
fn split_requires_divisibility() {
    let mut s = Schedule::new("f", listing1_shape());
    s.split_dim(Dim::X, 7);
}

#[test]
fn reorder_rejects_duplicates() {
    let mut s = Schedule::new("f", listing1_shape());
    let mut order: Vec<LoopId> = (0..s.num_loops()).map(LoopId).collect();
    order[1] = order[0];
    let r = std::panic::catch_unwind(move || s.reorder(&order));
    assert!(r.is_err());
}

#[test]
fn listing1_lowers_to_valid_mapping() {
    let mut s = listing1();
    // RF buffer inside everything; GBUF at xo (per Listing 1)
    let order: Vec<LoopId> = s.order_snapshot();
    let rf_attach = order[0]; // attach at innermost loop: RF = operands only
    s.buffer_at("rf", rf_attach);
    let xo = s.loop_of(Dim::X);
    s.buffer_at("ibuf", xo);
    let (m, smap) = s.lower(&eyeriss_like()).unwrap();
    m.validate().unwrap();
    assert_eq!(smap.axis_extent(true), 4);
    assert_eq!(m.pe_count(), 4);
}

#[test]
fn lowering_counts_buffer_groups() {
    let s = listing1(); // no buffers declared
    match s.lower(&eyeriss_like()) {
        Err(LowerError::WrongBufferCount { got: 0, want: 2 }) => {}
        other => panic!("{other:?}"),
    }
}

#[test]
fn lowering_rejects_array_overflow() {
    let shape = Shape::new(1, 64, 3, 16, 16, 5, 5, 1);
    let mut s = Schedule::new("f", shape);
    let k = s.loop_of(Dim::K);
    s.unroll(k, Axis::U); // 64 > 16 rows
    s.set_systolic();
    let order = s.order_snapshot();
    s.buffer_at("rf", order[0]);
    s.buffer_at("gbuf", order[3]);
    match s.lower(&eyeriss_like()) {
        Err(LowerError::ArrayOverflow { axis: "U", extent: 64, .. }) => {}
        other => panic!("{other:?}"),
    }
}

#[test]
fn lowering_rejects_bus_mismatch() {
    let mut s = listing1();
    let order = s.order_snapshot();
    s.buffer_at("rf", order[0]);
    s.buffer_at("gbuf", order[4]);
    match s.lower(&no_local_reuse()) {
        Err(LowerError::BusMismatch) => {}
        other => panic!("{other:?}"),
    }
}

#[test]
fn ir_printer_emits_listing2_structure() {
    let mut s = listing1();
    let order = s.order_snapshot();
    s.buffer_at("rf", order[0]);
    let xo = s.loop_of(Dim::X);
    s.buffer_at("ibuf", xo);
    s.buffer_at("wbuf", xo);
    let ir = print_ir(&s);
    assert!(ir.contains("alloc ibuf"), "{ir}");
    assert!(ir.contains("alloc wbuf"), "{ir}");
    assert!(ir.contains("unrolled_for"), "{ir}");
    assert!(ir.contains("output(x, y, k) +="), "{ir}");
    // loops print outermost-first; the b loop (extent 1) exists
    let first_for = ir.lines().next().unwrap();
    assert!(first_for.starts_with("for ("), "{first_for}");
}

#[test]
fn presets_lower_and_evaluate_on_alexnet_conv3() {
    let conv3 = Shape::new(4, 384, 256, 13, 13, 3, 3, 1);
    let arch = eyeriss_like();
    let bcast = no_local_reuse();
    let cases: Vec<(Schedule, &crate::arch::Arch)> = vec![
        (eyeriss_rs(conv3, 16, 16), &arch),
        (tpu_ck(conv3, 16, 16), &arch),
        (shidiannao_os(conv3, 16, 16), &arch),
        (diannao_tree(conv3, 16), &bcast),
        (nvdla_like(conv3, 16, 16), &bcast),
    ];
    for (s, a) in cases {
        let name = s.name.clone();
        let (m, smap) = s
            .lower(a)
            .unwrap_or_else(|e| panic!("{name}: lower failed: {e}"));
        m.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        let r = evaluate(&m, &smap, a, &Table3)
            .unwrap_or_else(|e| panic!("{name}: eval failed: {e}"));
        assert!(r.energy_pj > 0.0, "{name}");
        assert!(r.active_pes > 1, "{name} uses the array");
        // every preset keeps most references on-chip: DRAM fraction < 80%
        let dram_frac = r.energy_by_level.last().unwrap() / r.energy_pj;
        assert!(dram_frac < 0.95, "{name}: DRAM fraction {dram_frac}");
    }
}

#[test]
fn preset_schedules_match_their_dataflow_labels() {
    let conv3 = Shape::new(4, 384, 256, 13, 13, 3, 3, 1);
    let (_, smap) = tpu_ck(conv3, 16, 16).lower(&eyeriss_like()).unwrap();
    assert_eq!(smap.label().to_string(), "C|K");
    let (_, smap) = eyeriss_rs(conv3, 16, 16).lower(&eyeriss_like()).unwrap();
    assert_eq!(smap.label().to_string(), "FY|Y");
    let (_, smap) = shidiannao_os(conv3, 16, 16).lower(&eyeriss_like()).unwrap();
    assert_eq!(smap.label().to_string(), "X|Y");
}

#[test]
fn lowered_schedule_agrees_with_simulator() {
    // the DSL path and the direct-mapping path must produce identical
    // access counts on a small layer
    let shape = Shape::new(2, 8, 4, 8, 8, 3, 3, 1);
    let (m, smap) = tpu_ck(shape, 4, 4).lower(&eyeriss_like()).unwrap();
    let model = evaluate(&m, &smap, &eyeriss_like(), &Table3).unwrap();
    let sim =
        crate::sim::simulate(&m, &smap, &eyeriss_like(), &Table3, 100_000_000).unwrap();
    assert!((model.energy_pj - sim.energy_pj).abs() < 1e-9 * model.energy_pj);
}

#[test]
fn functional_equivalence_of_preset_schedule() {
    let shape = Shape::new(1, 4, 4, 6, 6, 3, 3, 1);
    let (m, _) = shidiannao_os(shape, 3, 3).lower(&eyeriss_like()).unwrap();
    let data = crate::sim::ConvData::random(shape, 42);
    assert_eq!(
        crate::sim::functional_conv(&m, &data),
        crate::sim::reference_conv(&data)
    );
}

#[test]
fn printer_names_split_pieces() {
    let mut s = Schedule::new("f", listing1_shape());
    let (_xo, xi) = s.split_dim(Dim::X, 8);
    let _ = xi;
    let ir = print_ir(&s);
    assert!(ir.contains("for (xo, 0, 2)"), "{ir}");
    assert!(ir.contains("for (xi, 0, 8)"), "{ir}");
}

#[test]
fn loop_of_returns_outermost_piece() {
    let mut s = Schedule::new("f", listing1_shape());
    let (xo, _xi) = s.split_dim(Dim::X, 8);
    assert_eq!(s.loop_of(Dim::X), xo);
    let (xoo, _xoi) = s.split_dim(Dim::X, 2);
    assert_eq!(s.loop_of(Dim::X), xoo);
    assert_eq!(xoo, xo); // split keeps the outer identity
}

#[test]
fn diannao_tree_is_broadcast_reduction() {
    let shape = Shape::new(2, 16, 16, 6, 6, 3, 3, 1);
    let sched = diannao_tree(shape, 16);
    let (m, smap) = sched.lower(&no_local_reuse()).unwrap();
    // C unrolled on the tree
    assert!(smap.extent(Dim::C) > 1);
    assert!(smap.v.is_empty() || smap.axis_extent(false) == 1);
    m.validate().unwrap();
}

#[test]
fn presets_respect_arbitrary_array_sizes() {
    let conv3 = Shape::new(2, 384, 256, 13, 13, 3, 3, 1);
    for (rows, cols) in [(4, 4), (8, 8), (32, 32)] {
        let (m, smap) = tpu_ck(conv3, rows, cols)
            .lower(&{
                let mut a = eyeriss_like();
                a.array = crate::arch::ArrayShape {
                    rows: rows as u32,
                    cols: cols as u32,
                };
                a
            })
            .unwrap();
        m.validate().unwrap();
        assert!(smap.axis_extent(true) <= rows);
        assert!(smap.axis_extent(false) <= cols);
    }
}
