//! The schedule builder: primitives applied to the CONV algorithm.

use crate::loopnest::{Dim, Shape};

/// Handle to one loop piece created by the algorithm or by `split`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LoopId(pub(crate) usize);

/// Physical array axis for `unroll`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Axis {
    /// Vertical (`U` of `U | V`).
    U,
    /// Horizontal (`V`).
    V,
}

/// One loop piece: a dim and its extent. Pieces of the same dim nest
/// multiplicatively (their extents multiply back to the dim's bound).
#[derive(Debug, Clone, Copy)]
pub(crate) struct LoopPiece {
    pub dim: Dim,
    pub extent: u64,
    /// Spatially unrolled (and on which axis, in push order).
    pub unrolled: Option<Axis>,
}

/// A buffer declared with `in_` + `compute_at`.
#[derive(Debug, Clone)]
pub(crate) struct Buffer {
    pub name: String,
    /// The loop the buffer hangs at (refilled per iteration of it).
    pub at: LoopId,
}

/// A schedule under construction for the CONV algorithm of one layer.
///
/// Mirrors the paper's Table 2: `split`/`reorder` (loop blocking),
/// `in_`+`compute_at` (memory levels), `unroll`+`systolic` (dataflow),
/// `accelerate` (finalize → lower).
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Algorithm (function) name, used by the IR printer.
    pub name: String,
    /// The layer being scheduled.
    pub shape: Shape,
    pub(crate) pieces: Vec<LoopPiece>,
    /// Nest order, **innermost first** (like Halide's `reorder` argument
    /// order).
    pub(crate) order: Vec<LoopId>,
    pub(crate) buffers: Vec<Buffer>,
    pub(crate) systolic: bool,
}

impl Schedule {
    /// Start from the pure algorithm: one loop per dim in Algorithm 1's
    /// order (`fx` innermost ... `b` outermost).
    pub fn new(name: &str, shape: Shape) -> Self {
        let dims_inner_first = [Dim::FX, Dim::FY, Dim::X, Dim::Y, Dim::C, Dim::K, Dim::B];
        let pieces: Vec<LoopPiece> = dims_inner_first
            .iter()
            .map(|&dim| LoopPiece {
                dim,
                extent: shape.bound(dim),
                unrolled: None,
            })
            .collect();
        let order = (0..pieces.len()).map(LoopId).collect();
        Schedule {
            name: name.to_string(),
            shape,
            pieces,
            order,
            buffers: Vec::new(),
            systolic: false,
        }
    }

    /// The current (outermost) piece of a dim — the piece `split` splits.
    pub fn loop_of(&self, d: Dim) -> LoopId {
        // outermost piece of the dim = last in order with that dim
        *self
            .order
            .iter()
            .rev()
            .find(|id| self.pieces[id.0].dim == d)
            .expect("dim always has a piece")
    }

    /// `split(x, xo, xi, f)`: split a loop into an outer piece of
    /// `extent/f` (keeps the identity of `id`) and a new inner piece of
    /// extent `f` placed directly inside it. Returns `(outer, inner)`.
    /// The factor must divide the current extent.
    pub fn split(&mut self, id: LoopId, factor: u64) -> (LoopId, LoopId) {
        let extent = self.pieces[id.0].extent;
        assert!(
            factor >= 1 && extent % factor == 0,
            "split factor {factor} must divide extent {extent}"
        );
        let dim = self.pieces[id.0].dim;
        self.pieces[id.0].extent = extent / factor;
        let inner = LoopId(self.pieces.len());
        self.pieces.push(LoopPiece {
            dim,
            extent: factor,
            unrolled: None,
        });
        let pos = self.pos(id);
        self.order.insert(pos, inner); // directly inside the outer piece
        (id, inner)
    }

    /// Convenience: split the outermost piece of dim `d`.
    pub fn split_dim(&mut self, d: Dim, factor: u64) -> (LoopId, LoopId) {
        self.split(self.loop_of(d), factor)
    }

    /// `reorder(...)`: set the nest order, **innermost first**. Every
    /// current loop piece must appear exactly once.
    pub fn reorder(&mut self, order: &[LoopId]) {
        assert_eq!(order.len(), self.pieces.len(), "reorder must list every loop");
        let mut seen = vec![false; self.pieces.len()];
        for id in order {
            assert!(!seen[id.0], "duplicate loop in reorder");
            seen[id.0] = true;
        }
        self.order = order.to_vec();
    }

    /// `in_(tensor, buf) ... compute_at(buf, at)`: declare a staging
    /// buffer refilled per iteration of `at`. Buffers attached at the
    /// same loop form one memory level; levels must be declared for every
    /// on-chip level of the target architecture.
    pub fn buffer_at(&mut self, name: &str, at: LoopId) {
        self.buffers.push(Buffer {
            name: name.to_string(),
            at,
        });
    }

    /// `unroll`: spatially unroll a loop piece onto a physical axis.
    pub fn unroll(&mut self, id: LoopId, axis: Axis) {
        self.pieces[id.0].unrolled = Some(axis);
    }

    /// `systolic`: inter-PE forwarding (Fig 5a). Without it the array is
    /// a broadcast/reduction-tree structure (Fig 5b).
    pub fn set_systolic(&mut self) {
        self.systolic = true;
    }

    /// Position of a piece in the order (0 = innermost).
    pub fn pos(&self, id: LoopId) -> usize {
        self.order.iter().position(|x| *x == id).expect("loop in order")
    }

    /// Extent of a piece.
    pub fn extent(&self, id: LoopId) -> u64 {
        self.pieces[id.0].extent
    }

    /// Dim of a piece.
    pub fn dim(&self, id: LoopId) -> Dim {
        self.pieces[id.0].dim
    }

    /// Number of loop pieces.
    pub fn num_loops(&self) -> usize {
        self.pieces.len()
    }

    /// The current nest order (innermost first).
    pub fn order_snapshot(&self) -> Vec<LoopId> {
        self.order.clone()
    }
}
