//! Replication (§3.2, Fig 2): packing multiple loops onto one physical
//! array axis to raise utilization, and the utilization model itself.

use super::taxonomy::{Dataflow, SpatialMap};
use crate::arch::ArrayShape;
use crate::loopnest::{Dim, Shape, ALL_DIMS};
use crate::util::ceil_div;

/// PE-array utilization of a concrete spatial map on `array` for `shape`:
/// useful work over occupied capacity, accounting for ceil fragmentation
/// on every unrolled loop (Fig 2's 3/16 vs 15/16).
pub fn utilization(shape: &Shape, map: &SpatialMap, array: &ArrayShape) -> f64 {
    let mut work: f64 = 1.0;
    let mut capacity: f64 = 1.0;
    for (d, e) in map.u.iter().chain(map.v.iter()) {
        let bound = shape.bound(*d);
        let passes = ceil_div(bound, *e);
        work *= bound as f64;
        capacity *= (passes * e) as f64;
    }
    // idle PEs on each axis also count as occupied capacity
    let used_u = map.axis_extent(true);
    let used_v = map.axis_extent(false);
    if used_u > array.rows as u64 || used_v > array.cols as u64 {
        return 0.0; // does not fit
    }
    capacity *= array.rows as f64 / used_u as f64;
    capacity *= array.cols as f64 / used_v as f64;
    work / capacity
}

/// The no-replication spatial map for a dataflow label: each axis unrolls
/// its single primary loop with extent `min(bound, axis size)` (the best
/// single-loop extent is the full axis, or the bound when smaller).
pub fn single_loop_map(shape: &Shape, df: &Dataflow, array: &ArrayShape) -> SpatialMap {
    let mk = |dims: &[Dim], size: u64| -> Vec<(Dim, u64)> {
        dims.first()
            .map(|&d| vec![(d, best_single_extent(shape.bound(d), size))])
            .into_iter()
            .flatten()
            .collect()
    };
    SpatialMap {
        u: mk(&df.u, array.rows as u64),
        v: mk(&df.v, array.cols as u64),
    }
}

/// Best extent for unrolling a single loop of `bound` onto an axis of
/// `size` PEs: maximizes `bound / (ceil(bound/e) * e)` with `e <= size`,
/// breaking ties toward larger `e` (more parallelism).
fn best_single_extent(bound: u64, size: u64) -> u64 {
    let mut best_e = 1;
    let mut best_score = 0.0;
    for e in 1..=size.min(bound.max(1)) {
        let score = bound as f64 / ((ceil_div(bound, e) * e) as f64);
        let better = score > best_score + 1e-12
            || ((score - best_score).abs() <= 1e-12 && e > best_e);
        if better {
            best_e = e;
            best_score = score;
        }
    }
    best_e
}

/// Greedily pack extra loops onto one axis of `map` while utilization
/// improves. Mutates `map` and `used`.
fn greedy_fill(
    shape: &Shape,
    map: &mut SpatialMap,
    used: &mut Vec<Dim>,
    array: &ArrayShape,
    vertical: bool,
) {
    let axis_size = if vertical { array.rows } else { array.cols } as u64;
    loop {
        let occupied = map.axis_extent(vertical);
        let room = axis_size / occupied.max(1);
        if room < 2 {
            break;
        }
        let mut best: Option<(Dim, u64, f64)> = None;
        let current = utilization(shape, map, array);
        for d in ALL_DIMS {
            if used.contains(&d) || shape.bound(d) == 1 {
                continue;
            }
            for e in 2..=room.min(shape.bound(d)) {
                let mut cand = map.clone();
                if vertical {
                    cand.u.push((d, e));
                } else {
                    cand.v.push((d, e));
                }
                let u = utilization(shape, &cand, array);
                if u > current + 1e-12 && best.map(|(_, _, bu)| u > bu + 1e-12).unwrap_or(true) {
                    best = Some((d, e, u));
                }
            }
        }
        match best {
            Some((d, e, _)) => {
                if vertical {
                    map.u.push((d, e));
                } else {
                    map.v.push((d, e));
                }
                used.push(d);
            }
            None => break,
        }
    }
}

/// Replication search: pack multiple loops onto each axis to maximize
/// utilization — the paper's Fig 2 move (C=3 alone → 3/16; C=3 × X=5 →
/// 15/16). The primary loop keeps its axis but its extent is searched
/// too: `FY|Y` with Y=13 on 16 columns does better as Y=2 × K=8 than as
/// Y=13 alone.
pub fn best_replication(shape: &Shape, df: &Dataflow, array: &ArrayShape) -> SpatialMap {
    let mut map = single_loop_map(shape, df, array);
    let mut used: Vec<Dim> = df.dims();

    for vertical in [true, false] {
        let axis_size = if vertical { array.rows } else { array.cols } as u64;
        let primary = if vertical { df.u.first() } else { df.v.first() };
        let Some(&primary) = primary else { continue };
        let mut best: Option<(SpatialMap, Vec<Dim>, f64)> = None;
        for e_p in 1..=axis_size.min(shape.bound(primary)) {
            let mut cand = map.clone();
            let axis = if vertical { &mut cand.u } else { &mut cand.v };
            axis.clear();
            axis.push((primary, e_p));
            let mut cand_used = used.clone();
            greedy_fill(shape, &mut cand, &mut cand_used, array, vertical);
            let u = utilization(shape, &cand, array);
            if best.as_ref().map(|(_, _, bu)| u > bu + 1e-12).unwrap_or(true) {
                best = Some((cand, cand_used, u));
            }
        }
        if let Some((cand, cand_used, _)) = best {
            map = cand;
            used = cand_used;
        }
    }
    map
}
