//! Tests for the dataflow taxonomy, replication, and utilization model.

use super::*;
use crate::arch::ArrayShape;
use crate::loopnest::{Dim, Shape, Tensor};
use crate::util::prop;

fn conv3() -> Shape {
    Shape::new(16, 384, 256, 13, 13, 3, 3, 1)
}

#[test]
fn parse_and_display_roundtrip() {
    for s in ["C|K", "FY|Y", "X|Y", "CK|X", "C|KX", "X", "FX|FY"] {
        let df = Dataflow::parse(s).unwrap_or_else(|| panic!("parse {s}"));
        assert_eq!(df.to_string(), s, "roundtrip {s}");
    }
}

#[test]
fn parse_rejects_garbage() {
    assert!(Dataflow::parse("").is_none());
    assert!(Dataflow::parse("Q|K").is_none());
    assert!(Dataflow::parse("C|C").is_none()); // duplicate dim
    assert!(Dataflow::parse("C|K|X").is_none()); // three axes
}

#[test]
fn parse_multiletter_dims() {
    let df = Dataflow::parse("FXFY|C").unwrap();
    assert_eq!(df.u, vec![Dim::FX, Dim::FY]);
    assert_eq!(df.v, vec![Dim::C]);
}

#[test]
fn enumeration_count_matches_paper() {
    // CONV layer with all 7 dims > 1: (7 choose 2) = 21 (§3.2)
    let s = Shape::new(2, 4, 4, 5, 5, 3, 3, 1);
    assert_eq!(enumerate_dataflows(&s).len(), 21);
    // FC layer: only B, K, C: (3 choose 2) = 3
    let fc = Shape::new(16, 100, 200, 1, 1, 1, 1, 1);
    assert_eq!(enumerate_dataflows(&fc).len(), 3);
}

#[test]
fn named_dataflows_table1() {
    let named = named_dataflows();
    assert_eq!(named.len(), 4);
    assert_eq!(named[0].1, Dataflow::two_d(Dim::X, Dim::Y));
    assert_eq!(named[3].1, Dataflow::two_d(Dim::C, Dim::K));
}

#[test]
fn figure2_utilization_example() {
    // Fig 2: C=3 unrolled on 16 rows -> 3/16; adding X=5 -> 15/16.
    // (1D array: cols = 1)
    let shape = Shape::new(1, 64, 3, 55, 55, 3, 3, 1);
    let arr = ArrayShape { rows: 16, cols: 1 };
    let alone = SpatialMap {
        u: vec![(Dim::C, 3)],
        v: vec![],
    };
    assert!((utilization(&shape, &alone, &arr) - 3.0 / 16.0).abs() < 1e-9);
    let replicated = SpatialMap {
        u: vec![(Dim::C, 3), (Dim::X, 5)],
        v: vec![],
    };
    assert!((utilization(&shape, &replicated, &arr) - 15.0 / 16.0).abs() < 1e-9);
}

#[test]
fn utilization_with_fragmentation() {
    // X=13 on extent 5: ceil(13/5)=3 passes, work 13, capacity 15
    let shape = Shape::new(1, 1, 1, 13, 1, 1, 1, 1);
    let arr = ArrayShape { rows: 5, cols: 1 };
    let m = SpatialMap {
        u: vec![(Dim::X, 5)],
        v: vec![],
    };
    assert!((utilization(&shape, &m, &arr) - 13.0 / 15.0).abs() < 1e-9);
}

#[test]
fn utilization_overflow_is_zero() {
    let shape = conv3();
    let arr = ArrayShape { rows: 4, cols: 4 };
    let m = SpatialMap {
        u: vec![(Dim::K, 8)],
        v: vec![],
    };
    assert_eq!(utilization(&shape, &m, &arr), 0.0);
}

#[test]
fn replication_improves_utilization_on_conv3() {
    // FY|Y on 16x16: FY=3, Y=13 -> low; replication should lift it
    let shape = conv3();
    let arr = ArrayShape { rows: 16, cols: 16 };
    let df = Dataflow::parse("FY|Y").unwrap();
    let plain = single_loop_map(&shape, &df, &arr);
    let repl = best_replication(&shape, &df, &arr);
    let u0 = utilization(&shape, &plain, &arr);
    let u1 = utilization(&shape, &repl, &arr);
    assert!(u0 < 0.7, "plain FY|Y should underutilize, got {u0}");
    assert!(u1 > 0.85, "replication should fix it, got {u1}");
    assert!(u1 >= u0);
}

#[test]
fn ck_dataflow_fills_large_channel_dims() {
    // C|K with C=256, K=384 divides 16x16 exactly -> utilization 1.0
    let shape = conv3();
    let arr = ArrayShape { rows: 16, cols: 16 };
    let df = Dataflow::parse("C|K").unwrap();
    let m = single_loop_map(&shape, &df, &arr);
    assert!((utilization(&shape, &m, &arr) - 1.0).abs() < 1e-9);
}

#[test]
fn spatial_map_factors_and_unique() {
    let m = SpatialMap {
        u: vec![(Dim::C, 4)],
        v: vec![(Dim::K, 8)],
    };
    assert_eq!(m.pes_used(), 32);
    assert_eq!(m.extent(Dim::C), 4);
    assert_eq!(m.extent(Dim::B), 1);
    // W relevant to both C and K -> 32 unique slices
    assert_eq!(m.unique_factor(Tensor::Weight), 32);
    // I irrelevant to K -> 4 unique slices (multicast along K)
    assert_eq!(m.unique_factor(Tensor::Input), 4);
    // O irrelevant to C -> 8 unique, spatial reduction = 4
    assert_eq!(m.unique_factor(Tensor::Output), 8);
    assert_eq!(m.spatial_reduction(), 4);
}

#[test]
fn share_hops_fig3_groups() {
    // Fig 3: CK on a 1D array of 8 (C inner 4, K outer 2).
    // Outputs (K-relevant, C-irrelevant): shared across C (inner, step 1)
    // -> ~1 hop. Inputs (C-relevant, K-irrelevant): shared across K groups
    // (step = group size 4) -> ~4x the output distance.
    let m = SpatialMap {
        u: vec![(Dim::C, 4), (Dim::K, 2)],
        v: vec![],
    };
    let o_hops = m.share_hops(Tensor::Output);
    let i_hops = m.share_hops(Tensor::Input);
    assert!(o_hops > 0.0 && o_hops <= 1.0, "{o_hops}");
    assert!(
        (i_hops / o_hops - 4.0 / 1.5).abs() < 0.3 || i_hops / o_hops >= 2.0,
        "inter-group {i_hops} should cost several x intra-group {o_hops}"
    );
    // W relevant to both: private per PE, no sharing hops
    assert_eq!(m.share_hops(Tensor::Weight), 0.0);
}

#[test]
fn label_strips_unit_extents() {
    let m = SpatialMap {
        u: vec![(Dim::C, 4), (Dim::X, 1)],
        v: vec![(Dim::K, 8)],
    };
    assert_eq!(m.label().to_string(), "C|K");
}

#[test]
fn prop_replication_never_hurts_and_fits() {
    prop::for_cases(0xdf10, 120, |rng| {
        let shape = Shape::new(
            rng.range(1, 8),
            rng.range(1, 64),
            rng.range(1, 64),
            rng.range(1, 28),
            rng.range(1, 28),
            rng.range(1, 5),
            rng.range(1, 5),
            1,
        );
        let arr = ArrayShape {
            rows: *rng.choose(&[4, 8, 16]),
            cols: *rng.choose(&[1, 4, 16]),
        };
        let flows = enumerate_dataflows(&shape);
        if flows.is_empty() {
            return;
        }
        let df = rng.choose(&flows).clone();
        let plain = single_loop_map(&shape, &df, &arr);
        let repl = best_replication(&shape, &df, &arr);
        let u0 = utilization(&shape, &plain, &arr);
        let u1 = utilization(&shape, &repl, &arr);
        assert!(u1 + 1e-9 >= u0, "replication reduced utilization: {u0} -> {u1}");
        assert!(u1 <= 1.0 + 1e-9);
        assert!(repl.axis_extent(true) <= arr.rows as u64);
        assert!(repl.axis_extent(false) <= arr.cols as u64);
    });
}

#[test]
fn single_loop_map_degenerate_axis() {
    // 1D dataflow leaves the v axis empty
    let shape = Shape::new(1, 16, 16, 4, 4, 3, 3, 1);
    let arr = ArrayShape { rows: 8, cols: 1 };
    let df = Dataflow::one_d(Dim::C);
    let m = single_loop_map(&shape, &df, &arr);
    assert!(m.v.is_empty());
    assert_eq!(m.axis_extent(false), 1);
    assert_eq!(m.extent(Dim::C), 8);
}

#[test]
fn scalar_map_is_one_pe() {
    let m = SpatialMap::scalar();
    assert_eq!(m.pes_used(), 1);
    assert_eq!(m.unique_factor(Tensor::Weight), 1);
    assert_eq!(m.spatial_reduction(), 1);
    assert_eq!(m.share_hops(Tensor::Input), 0.0);
}

#[test]
fn spatial_reduction_counts_all_reduction_dims() {
    let m = SpatialMap {
        u: vec![(Dim::C, 4), (Dim::FX, 3)],
        v: vec![(Dim::FY, 3)],
    };
    assert_eq!(m.spatial_reduction(), 36);
    // outputs irrelevant to all three -> fully merged
    assert_eq!(m.unique_factor(Tensor::Output), 1);
}

#[test]
fn best_single_extent_prefers_exact_fill() {
    // bound 384 on 16 rows: extent 16 divides -> utilization 1.0
    let shape = Shape::new(1, 384, 1, 1, 1, 1, 1, 1);
    let arr = ArrayShape { rows: 16, cols: 1 };
    let m = single_loop_map(&shape, &Dataflow::one_d(Dim::K), &arr);
    assert_eq!(m.extent(Dim::K), 16);
    assert!((utilization(&shape, &m, &arr) - 1.0).abs() < 1e-12);
}

#[test]
fn prop_unique_factor_divides_pes() {
    prop::for_cases(0x0d1f, 100, |rng| {
        let shape = Shape::new(
            rng.range(1, 4),
            rng.range(2, 32),
            rng.range(2, 32),
            rng.range(2, 14),
            rng.range(2, 14),
            rng.range(1, 4),
            rng.range(1, 4),
            1,
        );
        let arr = ArrayShape { rows: 16, cols: 16 };
        let flows = enumerate_dataflows(&shape);
        let df = rng.choose(&flows).clone();
        let m = best_replication(&shape, &df, &arr);
        for t in crate::loopnest::ALL_TENSORS {
            assert_eq!(
                m.pes_used() % m.unique_factor(t),
                0,
                "{t}: unique must divide PEs for {m}"
            );
        }
    });
}
