//! The `U | V` dataflow taxonomy (§3.2): which loops are spatially
//! unrolled on each physical array axis, with replication (multiple loops
//! per axis) and the communication-distance model of Fig 3.

mod replication;
mod taxonomy;

pub use replication::{best_replication, single_loop_map, utilization};
pub use taxonomy::{enumerate_dataflows, named_dataflows, Dataflow, SpatialMap};

#[cfg(test)]
mod tests;
