//! Dataflow labels (`C|K`, `FY|Y`, `CK|X`, ...) and concrete spatial maps.

use crate::loopnest::{Dim, Tensor, ALL_DIMS, NDIMS, Shape};

/// A dataflow *label*: the loops unrolled on the vertical (`u`) and
/// horizontal (`v`) array axes, ordered by communication proximity —
/// the leftmost loop of an axis maps to nearest-neighbor PEs (Fig 3).
///
/// A 1D dataflow has an empty `v`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Dataflow {
    /// Vertical-axis loops, nearest-neighbor first.
    pub u: Vec<Dim>,
    /// Horizontal-axis loops, nearest-neighbor first.
    pub v: Vec<Dim>,
}

impl Dataflow {
    /// Single-loop-per-axis 2D dataflow.
    pub fn two_d(u: Dim, v: Dim) -> Self {
        Dataflow {
            u: vec![u],
            v: vec![v],
        }
    }

    /// 1D dataflow.
    pub fn one_d(u: Dim) -> Self {
        Dataflow {
            u: vec![u],
            v: vec![],
        }
    }

    /// Parse `"C|K"`, `"CK|X"`, `"FY|Y"`, `"X"` (case-insensitive;
    /// multi-letter dims FX/FY are recognized greedily).
    pub fn parse(s: &str) -> Option<Dataflow> {
        let mut parts = s.split('|');
        let u = parse_axis(parts.next()?.trim())?;
        let v = match parts.next() {
            Some(p) => parse_axis(p.trim())?,
            None => vec![],
        };
        if parts.next().is_some() || u.is_empty() {
            return None;
        }
        // no dim may appear twice
        let mut seen = [false; NDIMS];
        for d in u.iter().chain(v.iter()) {
            if seen[d.idx()] {
                return None;
            }
            seen[d.idx()] = true;
        }
        Some(Dataflow { u, v })
    }

    /// All dims used on either axis.
    pub fn dims(&self) -> Vec<Dim> {
        self.u.iter().chain(self.v.iter()).copied().collect()
    }
}

fn parse_axis(s: &str) -> Option<Vec<Dim>> {
    let up = s.to_ascii_uppercase();
    let bytes = up.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'F' && i + 1 < bytes.len() {
            out.push(Dim::parse(&up[i..i + 2])?);
            i += 2;
        } else {
            out.push(Dim::parse(&up[i..i + 1])?);
            i += 1;
        }
    }
    Some(out)
}

impl std::fmt::Display for Dataflow {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let axis = |dims: &[Dim]| dims.iter().map(|d| d.name()).collect::<String>();
        if self.v.is_empty() {
            write!(f, "{}", axis(&self.u))
        } else {
            write!(f, "{}|{}", axis(&self.u), axis(&self.v))
        }
    }
}

/// A concrete spatial mapping: each unrolled loop with its extent.
/// Extents on one axis multiply to at most the axis size.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpatialMap {
    /// Vertical axis: (dim, extent), nearest-neighbor first.
    pub u: Vec<(Dim, u64)>,
    /// Horizontal axis.
    pub v: Vec<(Dim, u64)>,
}

impl SpatialMap {
    /// No unrolling (1 PE).
    pub fn scalar() -> Self {
        SpatialMap { u: vec![], v: vec![] }
    }

    /// Total PEs occupied.
    pub fn pes_used(&self) -> u64 {
        self.axis_extent(true) * self.axis_extent(false)
    }

    /// Product of extents on one axis (`vertical = true` for `u`).
    pub fn axis_extent(&self, vertical: bool) -> u64 {
        let axis = if vertical { &self.u } else { &self.v };
        axis.iter().map(|(_, e)| e).product()
    }

    /// Spatial factor per dim as a canonical `[u64; NDIMS]` array
    /// (for [`crate::loopnest::Mapping::spatial`]).
    pub fn factors(&self) -> [u64; NDIMS] {
        let mut f = [1u64; NDIMS];
        for (d, e) in self.u.iter().chain(self.v.iter()) {
            f[d.idx()] *= e;
        }
        f
    }

    /// Extent of a dim (1 when not unrolled).
    pub fn extent(&self, d: Dim) -> u64 {
        self.factors()[d.idx()]
    }

    /// Product of extents of dims *relevant* to tensor `t` — the number
    /// of distinct tile slices of `t` across the array (multicast width is
    /// `pes_used / unique_factor`).
    pub fn unique_factor(&self, t: Tensor) -> u64 {
        self.u
            .iter()
            .chain(self.v.iter())
            .filter(|(d, _)| t.relevant(*d))
            .map(|(_, e)| e)
            .product()
    }

    /// Product of extents of *reduction* dims — the number of partial
    /// sums per output element produced across the array.
    pub fn spatial_reduction(&self) -> u64 {
        self.u
            .iter()
            .chain(self.v.iter())
            .filter(|(d, _)| d.is_reduction())
            .map(|(_, e)| e)
            .product()
    }

    /// The dataflow label of this map (dims with extent > 1).
    pub fn label(&self) -> Dataflow {
        Dataflow {
            u: self.u.iter().filter(|(_, e)| *e > 1).map(|(d, _)| *d).collect(),
            v: self.v.iter().filter(|(_, e)| *e > 1).map(|(d, _)| *d).collect(),
        }
    }

    /// Average hop distance for one word of tensor `t` delivered into the
    /// array, under systolic forwarding (Fig 3 model): data shared along a
    /// `t`-irrelevant unrolled loop is forwarded between the PEs that
    /// share it; the forwarding step spans the extents of the loops mapped
    /// *nearer* (to the left) on the same axis.
    ///
    /// Returns ~0 for data fully private per PE (no sharing → delivered
    /// once, charged at the buffer) and grows with replication-group size
    /// for inter-group sharing.
    pub fn share_hops(&self, t: Tensor) -> f64 {
        let mut hops = 0.0;
        for axis in [&self.u, &self.v] {
            let mut inner: u64 = 1;
            for (d, e) in axis.iter() {
                if *e > 1 && !t.relevant(*d) {
                    // one word visits `e` positions spaced `inner` apart
                    hops += (inner as f64) * ((*e - 1) as f64) / (*e as f64);
                }
                inner *= *e;
            }
        }
        hops
    }
}

impl std::fmt::Display for SpatialMap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let axis = |dims: &[(Dim, u64)]| {
            dims.iter()
                .map(|(d, e)| format!("{}{}", d.name(), e))
                .collect::<Vec<_>>()
                .join("·")
        };
        write!(f, "[{} | {}]", axis(&self.u), axis(&self.v))
    }
}

/// Enumerate dataflow labels for a layer: all 1D choices plus all
/// unordered 2D pairs over dims with bound > 1 (the paper's
/// `(L choose 2)` count; `U|V` and `V|U` are symmetric on square arrays).
pub fn enumerate_dataflows(shape: &Shape) -> Vec<Dataflow> {
    let dims: Vec<Dim> = ALL_DIMS
        .into_iter()
        .filter(|d| shape.bound(*d) > 1)
        .collect();
    let mut out = Vec::new();
    for (i, &u) in dims.iter().enumerate() {
        for &v in dims.iter().skip(i + 1) {
            out.push(Dataflow::two_d(u, v));
        }
    }
    if out.is_empty() {
        // degenerate single-dim layers: 1D flows
        for &u in &dims {
            out.push(Dataflow::one_d(u));
        }
    }
    out
}

/// The named dataflows of Table 1, for reports.
pub fn named_dataflows() -> Vec<(&'static str, Dataflow)> {
    vec![
        ("output-stationary (X|Y)", Dataflow::two_d(Dim::X, Dim::Y)),
        ("weight-stationary (FX|FY)", Dataflow::two_d(Dim::FX, Dim::FY)),
        ("row-stationary (FY|Y)", Dataflow::two_d(Dim::FY, Dim::Y)),
        ("weight-stationary (C|K)", Dataflow::two_d(Dim::C, Dim::K)),
    ]
}
