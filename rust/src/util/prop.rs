//! Property-testing helper (proptest is not in the offline vendor set).
//!
//! [`for_cases`] runs a closure over `n` deterministic random cases and, on
//! panic, reports the failing case index and seed so the exact case can be
//! replayed with `replay`.

use super::rng::XorShift;

/// Run `f` for `n` cases with independent deterministic sub-seeds derived
/// from `seed`. Panics with the failing case's replay seed on failure.
pub fn for_cases<F: FnMut(&mut XorShift)>(seed: u64, n: u64, mut f: F) {
    for case in 0..n {
        let sub = seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case + 1);
        let mut rng = XorShift::new(sub);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property failed at case {case}/{n} (replay seed {sub:#x}): {msg}"
            );
        }
    }
}

/// Replay a single case by its sub-seed (printed by [`for_cases`] on
/// failure).
pub fn replay<F: FnMut(&mut XorShift)>(sub_seed: u64, mut f: F) {
    let mut rng = XorShift::new(sub_seed);
    f(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_when_property_holds() {
        for_cases(1, 50, |rng| {
            let a = rng.below(100);
            let b = rng.below(100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn reports_failing_case() {
        let r = std::panic::catch_unwind(|| {
            for_cases(2, 50, |rng| {
                let v = rng.below(10);
                assert!(v < 9, "v was {v}");
            });
        });
        let msg = match r {
            Err(e) => e
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default(),
            Ok(()) => panic!("expected failure"),
        };
        assert!(msg.contains("replay seed"), "{msg}");
    }

    #[test]
    fn replay_is_deterministic() {
        let mut first = None;
        replay(0xdead, |rng| {
            first = Some(rng.next_u64());
        });
        let mut second = None;
        replay(0xdead, |rng| {
            second = Some(rng.next_u64());
        });
        assert_eq!(first, second);
    }
}
