//! Tiny criterion-like timing harness (criterion is not in the offline
//! vendor set). Benches are `harness = false` binaries that call
//! [`Bencher::bench`] and print a stable, greppable report line.

use std::time::Instant;

/// Timing result for one benchmark case.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Case name.
    pub name: String,
    /// Iterations actually run.
    pub iters: u64,
    /// Mean wall time per iteration, nanoseconds.
    pub mean_ns: f64,
    /// Min / max per-batch estimates, nanoseconds per iter.
    pub min_ns: f64,
    /// Max per-batch estimate, ns/iter.
    pub max_ns: f64,
}

impl Measurement {
    /// Report line: `bench <name> ... mean 12.3 us/iter`.
    pub fn report(&self) -> String {
        format!(
            "bench {:<48} {:>10}/iter  (min {}, max {}, n={})",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.min_ns),
            fmt_ns(self.max_ns),
            self.iters
        )
    }
}

/// Format nanoseconds with an appropriate unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} us", ns / 1e3)
    } else {
        format!("{:.1} ns", ns)
    }
}

/// The harness: runs each case for ~`target_ms` of wall time (after a
/// warmup batch) split over several batches, and prints a report line.
pub struct Bencher {
    target_ms: u64,
    results: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new(300)
    }
}

impl Bencher {
    /// Create with a wall-time budget per case, in milliseconds.
    pub fn new(target_ms: u64) -> Self {
        Self {
            target_ms,
            results: Vec::new(),
        }
    }

    /// Benchmark a closure; returns the measurement (also stored + printed).
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> Measurement {
        // Warmup + calibration: time a single run.
        let t0 = Instant::now();
        f();
        let single_ns = t0.elapsed().as_nanos().max(1) as f64;

        let budget_ns = (self.target_ms as f64) * 1e6;
        let batches = 5u64;
        let iters_per_batch = ((budget_ns / single_ns / batches as f64).floor() as u64).max(1);

        let mut per_iter = Vec::with_capacity(batches as usize);
        for _ in 0..batches {
            let t = Instant::now();
            for _ in 0..iters_per_batch {
                f();
            }
            per_iter.push(t.elapsed().as_nanos() as f64 / iters_per_batch as f64);
        }
        let m = Measurement {
            name: name.to_string(),
            iters: batches * iters_per_batch,
            mean_ns: per_iter.iter().sum::<f64>() / per_iter.len() as f64,
            min_ns: per_iter.iter().cloned().fold(f64::INFINITY, f64::min),
            max_ns: per_iter.iter().cloned().fold(0.0, f64::max),
        };
        println!("{}", m.report());
        self.results.push(m.clone());
        m
    }

    /// All measurements so far.
    pub fn results(&self) -> &[Measurement] {
        &self.results
    }
}

/// Prevent the optimizer from discarding a value (ptr read trick, no
/// dependencies on std::hint::black_box stability semantics needed —
/// it exists on this toolchain, so just wrap it).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Validate one `BENCH_*.json` document against the documented
/// perf-trajectory schema (ARCHITECTURE.md, "CI tiers and the perf
/// trajectory"): a single **flat** JSON object with a required non-empty
/// `"bench"` string naming the emitter; every other field a scalar
/// (string, bool, or finite number). The `bench_schema` CI gate runs
/// this over every emitted file; it lives in the library so the schema
/// rules themselves are unit-tested by tier-1.
pub fn validate_bench_json(text: &str) -> Result<(), String> {
    use super::json::Json;
    let v = Json::parse(text).map_err(|e| e.to_string())?;
    let members = v
        .as_obj()
        .map_err(|_| "top level must be a JSON object".to_string())?;
    let bench = v
        .get("bench")
        .ok_or_else(|| "missing required `bench` field".to_string())?
        .as_str()
        .map_err(|_| "`bench` must be a string".to_string())?;
    if bench.is_empty() {
        return Err("`bench` must be non-empty".to_string());
    }
    for (key, value) in members {
        match value {
            Json::Str(_) | Json::Bool(_) => {}
            Json::Num(x) if x.is_finite() => {}
            other => {
                return Err(format!(
                    "field `{key}` must be a scalar (string, bool, finite number), got {other:?}"
                ))
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut b = Bencher::new(5);
        let m = b.bench("noop", || {
            black_box(1 + 1);
        });
        assert!(m.iters >= 5);
        assert!(m.mean_ns >= 0.0);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("us"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2_000_000_000.0).ends_with(" s"));
    }

    #[test]
    fn bench_schema_accepts_flat_scalar_objects() {
        validate_bench_json(r#"{"bench":"perf_x","n":3,"winner":"rf16","ok":true}"#).unwrap();
    }

    #[test]
    fn bench_schema_rejects_bad_documents() {
        assert!(validate_bench_json("[]").is_err());
        assert!(validate_bench_json(r#"{"n":3}"#).is_err(), "missing bench");
        assert!(validate_bench_json(r#"{"bench":""}"#).is_err(), "empty bench");
        assert!(validate_bench_json(r#"{"bench":"x","nested":{"a":1}}"#).is_err());
        assert!(validate_bench_json(r#"{"bench":"x","xs":[1]}"#).is_err(), "array");
        assert!(
            validate_bench_json(r#"{"bench":"x","inf":null}"#).is_err(),
            "non-finite / null"
        );
        assert!(validate_bench_json("{\"bench\":\"x\"").is_err(), "truncated");
    }
}
