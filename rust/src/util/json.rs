//! Minimal JSON tree, writer, and recursive-descent parser (serde is not
//! in the offline vendor set). Built for the shard-checkpoint files and
//! the `BENCH_*.json` schema gate, where the load-bearing property is
//! **exact f64 round-tripping**: numbers are written with Rust's shortest
//! round-trip `Display` for `f64` and parsed with `str::parse::<f64>`,
//! so `write → parse` reproduces the original bits (the cross-process
//! shard-merge winner-identity contract depends on this).
//!
//! Deliberately small: no streaming, no borrowed values, objects keep
//! insertion order (writers emit deterministic files; `git diff`-able
//! checkpoints matter more than lookup speed at these sizes).

use anyhow::{anyhow, bail, Result};

/// A parsed or under-construction JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null` — also how non-finite floats are written (JSON has no
    /// Infinity/NaN literals).
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number. Integers up to 2^53 round-trip exactly through the
    /// f64 payload; [`Json::int`] guards the writer side.
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object, insertion-ordered (duplicate keys are not merged).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Integer constructor with an exactness guard: values above 2^53
    /// would silently lose bits in the f64 payload, so refuse them loudly
    /// (nothing in this codebase emits such counts).
    pub fn int(v: u64) -> Json {
        assert!(v <= (1u64 << 53), "u64 {v} exceeds exact f64 range");
        Json::Num(v as f64)
    }

    /// Float constructor; non-finite values become [`Json::Null`].
    pub fn num(v: f64) -> Json {
        if v.is_finite() {
            Json::Num(v)
        } else {
            Json::Null
        }
    }

    /// String constructor.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Object member by key (first occurrence).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Object member by key, or an error naming the missing field.
    pub fn field(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing field `{key}`"))
    }

    /// The f64 payload of a number; `Null` reads as +infinity (the
    /// writer's encoding for non-finite values — see [`Json::num`]).
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(v) => Ok(*v),
            Json::Null => Ok(f64::INFINITY),
            other => bail!("expected number, got {other:?}"),
        }
    }

    /// A number as an exact unsigned integer.
    pub fn as_u64(&self) -> Result<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 && *v <= (1u64 << 53) as f64 => {
                Ok(*v as u64)
            }
            other => bail!("expected non-negative integer, got {other:?}"),
        }
    }

    /// A number as a usize.
    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    /// String payload.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    /// Array payload.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => bail!("expected array, got {other:?}"),
        }
    }

    /// Object payload.
    pub fn as_obj(&self) -> Result<&[(String, Json)]> {
        match self {
            Json::Obj(m) => Ok(m),
            other => bail!("expected object, got {other:?}"),
        }
    }

    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing characters at byte {pos}");
        }
        Ok(v)
    }

    /// Serialize without whitespace (stable, diff-friendly key order —
    /// whatever order the object was built in).
    pub fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    // Rust's Display for f64 is the shortest string that
                    // parses back to the identical bits.
                    out.push_str(&format!("{v}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_str(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(members) => {
                out.push('{');
                for (i, (k, v)) in members.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, lit: &str) -> Result<()> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(())
    } else {
        bail!("expected `{lit}` at byte {pos}")
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => bail!("unexpected end of input"),
        Some(b'n') => {
            expect(b, pos, "null")?;
            Ok(Json::Null)
        }
        Some(b't') => {
            expect(b, pos, "true")?;
            Ok(Json::Bool(true))
        }
        Some(b'f') => {
            expect(b, pos, "false")?;
            Ok(Json::Bool(false))
        }
        Some(b'"') => Ok(Json::Str(parse_string(b, pos)?)),
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => bail!("expected `,` or `]` at byte {pos}"),
                }
            }
        }
        Some(b'{') => {
            *pos += 1;
            let mut members = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(members));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                expect(b, pos, ":")?;
                members.push((key, parse_value(b, pos)?));
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(members));
                    }
                    _ => bail!("expected `,` or `}}` at byte {pos}"),
                }
            }
        }
        Some(_) => parse_number(b, pos),
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    if b.get(*pos) != Some(&b'"') {
        bail!("expected string at byte {pos}");
    }
    *pos += 1;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => bail!("unterminated string"),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                        let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                        out.push(
                            char::from_u32(code)
                                .ok_or_else(|| anyhow!("invalid \\u escape {code:#x}"))?,
                        );
                        *pos += 4;
                    }
                    other => bail!("bad escape {other:?}"),
                }
                *pos += 1;
            }
            Some(_) => {
                // consume one UTF-8 scalar (multi-byte sequences are
                // copied verbatim)
                let start = *pos;
                *pos += 1;
                while *pos < b.len() && (b[*pos] & 0xC0) == 0x80 {
                    *pos += 1;
                }
                out.push_str(std::str::from_utf8(&b[start..*pos])?);
            }
        }
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    if start == *pos {
        bail!("expected value at byte {start}");
    }
    let text = std::str::from_utf8(&b[start..*pos])?;
    Ok(Json::Num(text.parse::<f64>().map_err(|e| {
        anyhow!("bad number `{text}` at byte {start}: {e}")
    })?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_structure() {
        let v = Json::Obj(vec![
            ("name".into(), Json::str("rf16+128-sram256")),
            ("n".into(), Json::int(42)),
            ("e".into(), Json::num(1.25e-3)),
            ("inf".into(), Json::num(f64::INFINITY)),
            ("ok".into(), Json::Bool(true)),
            (
                "xs".into(),
                Json::Arr(vec![Json::int(1), Json::Null, Json::str("a\"b\\c\nd")]),
            ),
        ]);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        // Null-encoded infinity parses back as Null; everything else is
        // structurally identical
        assert_eq!(back.get("name").unwrap().as_str().unwrap(), "rf16+128-sram256");
        assert_eq!(back.get("n").unwrap().as_u64().unwrap(), 42);
        assert_eq!(back.get("e").unwrap().as_f64().unwrap(), 1.25e-3);
        assert_eq!(back.get("inf").unwrap().as_f64().unwrap(), f64::INFINITY);
        assert_eq!(back.get("xs").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            back.get("xs").unwrap().as_arr().unwrap()[2]
                .as_str()
                .unwrap(),
            "a\"b\\c\nd"
        );
    }

    #[test]
    fn f64_bits_roundtrip_exactly() {
        // awkward values: shortest-Display must reparse to identical bits
        let cases = [
            0.1,
            1.0 / 3.0,
            6.02214076e23,
            f64::MIN_POSITIVE,
            1.7976931348623157e308,
            123456789.123456789,
            2f64.powi(53) - 1.0,
        ];
        for v in cases {
            let text = Json::num(v).to_string();
            let back = Json::parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(v.to_bits(), back.to_bits(), "{v} -> {text} -> {back}");
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn parse_accepts_whitespace_and_nesting() {
        let v = Json::parse(" { \"a\" : [ 1 , { \"b\" : null } ] } ").unwrap();
        assert_eq!(v.field("a").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn unicode_strings_roundtrip() {
        let v = Json::Str("héllo ☃ \u{1F600}".into());
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
        // \u escapes parse too
        assert_eq!(
            Json::parse("\"\\u2603\"").unwrap().as_str().unwrap(),
            "☃"
        );
    }
}
