//! Deterministic xorshift64* PRNG — the project's only randomness source.
//!
//! Used by property tests (see [`crate::util::prop`]), the simulator's
//! functional mode (random test tensors), and randomized search seeds.
//! Deterministic by construction so every test failure reproduces.

/// xorshift64* generator (Vigna 2016). Not cryptographic; plenty for
/// test-case generation and sampling.
#[derive(Clone, Debug)]
pub struct XorShift {
    state: u64,
}

impl XorShift {
    /// Create a generator from a seed. A zero seed is remapped (xorshift
    /// has an all-zero fixed point).
    pub fn new(seed: u64) -> Self {
        Self {
            state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Rejection-free modulo is fine at our scale (bias < 2^-40 for n < 2^24).
        self.next_u64() % n
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f32 in `[-1, 1)` — test tensor values.
    pub fn unit_f32(&mut self) -> f32 {
        let v = (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32;
        v * 2.0 - 1.0
    }

    /// Split off an independent child stream, advancing this generator
    /// by one step. The child is seeded from the parent's next output;
    /// xorshift64* outputs are a bijection of the never-repeating state
    /// sequence, so successive children of one parent have pairwise
    /// distinct (and never-zero) seeds — the collision-free way to
    /// derive per-item sub-seeds (e.g. per-request input seeds), unlike
    /// `seed ^ f(i)` mixing, which aliases across related parent seeds.
    pub fn split(&mut self) -> XorShift {
        XorShift::new(self.next_u64())
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Vector of random f32 test values.
    pub fn f32_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.unit_f32()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = XorShift::new(42);
        let mut b = XorShift::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = XorShift::new(1);
        let mut b = XorShift::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = XorShift::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = XorShift::new(7);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn unit_f32_bounds() {
        let mut r = XorShift::new(9);
        for _ in 0..1000 {
            let v = r.unit_f32();
            assert!((-1.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = XorShift::new(11);
        let mut v: Vec<u32> = (0..20).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort();
        assert_eq!(s, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn zero_seed_ok() {
        let mut r = XorShift::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn split_is_deterministic_and_advances_parent() {
        let mut a = XorShift::new(42);
        let mut b = XorShift::new(42);
        let mut ca = a.split();
        let mut cb = b.split();
        assert_eq!(ca.next_u64(), cb.next_u64(), "same parent, same child");
        // the parent advanced, so the next child is a different stream
        let mut ca2 = a.split();
        assert_ne!(ca.next_u64(), ca2.next_u64());
        assert_eq!(a.next_u64(), b.next_u64(), "parents stay in lockstep");
    }

    #[test]
    fn split_children_have_distinct_first_outputs() {
        // bijectivity of the xorshift64* output function makes child
        // first-outputs pairwise distinct for one parent
        let mut r = XorShift::new(1);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..4096 {
            assert!(seen.insert(r.split().next_u64()), "child stream collision");
        }
    }
}
