//! Dependency-free utilities: deterministic PRNG, stats, table formatting,
//! CLI argument parsing, and a tiny property-testing helper.
//!
//! The offline vendor set only contains the `xla` crate closure, so the
//! usual suspects (rand, clap, serde, proptest, criterion) are hand-rolled
//! here at the small scale this project needs.

pub mod args;
pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

pub use args::Args;
pub use rng::XorShift;

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// All divisors of `n`, ascending. `n` must be >= 1.
pub fn divisors(n: u64) -> Vec<u64> {
    debug_assert!(n >= 1);
    let mut small = Vec::new();
    let mut large = Vec::new();
    let mut d = 1;
    while d * d <= n {
        if n % d == 0 {
            small.push(d);
            if d != n / d {
                large.push(n / d);
            }
        }
        d += 1;
    }
    large.reverse();
    small.extend(large);
    small
}

/// Human-readable byte size ("64 B", "128 KB", "28 MB").
pub fn fmt_bytes(b: u64) -> String {
    if b >= 1 << 20 && b % (1 << 20) == 0 {
        format!("{} MB", b >> 20)
    } else if b >= 1 << 10 && b % (1 << 10) == 0 {
        format!("{} KB", b >> 10)
    } else {
        format!("{} B", b)
    }
}

/// Format a float with engineering-style precision for reports.
pub fn fmt_sig(v: f64) -> String {
    if v == 0.0 {
        return "0".to_string();
    }
    let a = v.abs();
    if a >= 1e9 {
        format!("{:.3}e9", v / 1e9)
    } else if a >= 1e6 {
        format!("{:.3}e6", v / 1e6)
    } else if a >= 100.0 {
        format!("{:.1}", v)
    } else if a >= 1.0 {
        format!("{:.3}", v)
    } else {
        format!("{:.5}", v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(1, 1), 1);
        assert_eq!(ceil_div(0, 5), 0);
    }

    #[test]
    fn divisors_of_12() {
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
    }

    #[test]
    fn divisors_of_1_and_prime() {
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(13), vec![1, 13]);
    }

    #[test]
    fn divisors_perfect_square() {
        assert_eq!(divisors(36), vec![1, 2, 3, 4, 6, 9, 12, 18, 36]);
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(64), "64 B");
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(128 << 10), "128 KB");
        assert_eq!(fmt_bytes(28 << 20), "28 MB");
    }

    #[test]
    fn divisors_product_pairing() {
        // every divisor d pairs with n/d
        let n = 360;
        let ds = divisors(n);
        for &d in &ds {
            assert_eq!(n % d, 0);
            assert!(ds.contains(&(n / d)));
        }
    }
}
