//! Plain-text / markdown / CSV table rendering for reports and benches.
//!
//! Every figure-regeneration bench prints its rows through this so the
//! output can be diffed, written to the `report --all` artifact set
//! (REPRODUCING.md), or post-processed.

/// A simple column-aligned table builder.
#[derive(Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row. Rows shorter than the header are right-padded.
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let mut r: Vec<String> = cells.into_iter().map(Into::into).collect();
        while r.len() < self.header.len() {
            r.push(String::new());
        }
        self.rows.push(r);
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when there are no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                if i < w.len() {
                    w[i] = w[i].max(c.len());
                } else {
                    w.push(c.len());
                }
            }
        }
        w
    }

    /// Render as aligned plain text.
    pub fn to_text(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = w.get(i).copied().unwrap_or(0)))
                .collect::<Vec<_>>()
                .join("  ")
                .trim_end()
                .to_string()
        };
        out.push_str(&fmt_row(&self.header, &w));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * w.len().saturating_sub(1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &w));
            out.push('\n');
        }
        out
    }

    /// Render as a GitHub-flavored markdown table.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            self.header.iter().map(|_| "---|").collect::<String>()
        ));
        for r in &self.rows {
            out.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        out
    }

    /// Render as CSV (naive quoting: commas in cells are replaced by ';').
    pub fn to_csv(&self) -> String {
        let clean = |s: &String| s.replace(',', ";");
        let mut out = String::new();
        out.push_str(&self.header.iter().map(clean).collect::<Vec<_>>().join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(clean).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new(vec!["name", "value"]);
        t.row(vec!["alpha", "1"]);
        t.row(vec!["b", "22"]);
        t
    }

    #[test]
    fn text_alignment() {
        let txt = sample().to_text();
        let lines: Vec<&str> = txt.lines().collect();
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].starts_with("alpha"));
        // columns aligned: "value" column starts at same offset
        let col = lines[0].find("value").unwrap();
        assert_eq!(&lines[2][col..col + 1], "1");
    }

    #[test]
    fn markdown_shape() {
        let md = sample().to_markdown();
        assert!(md.starts_with("| name | value |"));
        assert_eq!(md.lines().count(), 4);
    }

    #[test]
    fn csv_shape() {
        let csv = sample().to_csv();
        assert_eq!(csv.lines().next().unwrap(), "name,value");
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    fn short_rows_padded() {
        let mut t = Table::new(vec!["a", "b", "c"]);
        t.row(vec!["1"]);
        assert_eq!(t.rows[0].len(), 3);
    }

    #[test]
    fn len_and_empty() {
        assert!(Table::new(vec!["x"]).is_empty());
        assert_eq!(sample().len(), 2);
    }
}
