//! Small statistics helpers for benches and sweep reports.

/// Mean of a slice. Empty slices return 0.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Minimum (0 for empty).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min).min(f64::INFINITY)
}

/// Maximum (0 for empty).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// p-th percentile (0..=100) by nearest-rank on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Geometric mean (all inputs must be > 0).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Fraction of values within `factor` of the minimum — the paper's
/// "only 30% of blocking schemes fall within 1.25x of the minimum" metric
/// (Fig 10).
pub fn frac_within_of_min(xs: &[f64], factor: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let n = xs.iter().filter(|&&x| x <= lo * factor).count();
    n as f64 / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn stddev_basic() {
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
        assert_eq!(stddev(&[5.0]), 0.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 50.0), 3.0);
        assert_eq!(percentile(&v, 100.0), 5.0);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn frac_within() {
        let v = [1.0, 1.2, 1.3, 2.0];
        assert!((frac_within_of_min(&v, 1.25) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn min_max() {
        let v = [3.0, 1.0, 2.0];
        assert_eq!(min(&v), 1.0);
        assert_eq!(max(&v), 3.0);
    }
}
