//! Minimal CLI argument parser (no clap in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::HashMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments in order.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options (last occurrence wins).
    pub options: HashMap<String, String>,
    /// Bare `--flag`s.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Option value as string.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Option value parsed, with default.
    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Option value parsed, with default.
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Option value parsed, with default.
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// String option with default.
    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// True when `--flag` was passed.
    pub fn has_flag(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["optimize", "--net", "alexnet", "--batch=16"]);
        assert_eq!(a.positional, vec!["optimize"]);
        assert_eq!(a.get("net"), Some("alexnet"));
        assert_eq!(a.get_u64("batch", 1), 16);
    }

    #[test]
    fn flags() {
        // a bare --flag followed by a non-flag token consumes it as a
        // value (clap-like greedy options); put flags last or use `=`
        let a = parse(&["cmd", "--dry-run", "--verbose"]);
        assert!(a.has_flag("verbose"));
        assert!(a.has_flag("dry-run"));
        assert_eq!(a.positional, vec!["cmd"]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--a", "--b", "v"]);
        assert!(a.has_flag("a"));
        assert_eq!(a.get("b"), Some("v"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_u64("missing", 7), 7);
        assert_eq!(a.get_str("missing", "x"), "x");
        assert_eq!(a.get_f64("missing", 0.5), 0.5);
    }

    #[test]
    fn last_occurrence_wins() {
        let a = parse(&["--k", "1", "--k=2"]);
        assert_eq!(a.get("k"), Some("2"));
    }
}
