//! Mergeable log-bucketed latency histogram.
//!
//! The serving fleet needs percentiles that (a) merge across workers and
//! processes without shipping every sample, (b) stay bounded in memory at
//! fleet scale, and (c) are **deterministic regardless of merge order** —
//! the fleet digest contract extends to every reported statistic. A
//! float-summing reservoir fails (c): f64 addition is not associative, so
//! two merge orders can disagree in the last bit. This histogram stores
//! only integer counts keyed by bucket index, so merging is exact integer
//! addition — associative, commutative, and thread-count-independent —
//! and every derived statistic (mean, quantiles) is a pure function of
//! the final counts.
//!
//! Bucketing is log-spaced and computed **directly from the IEEE-754
//! bits** (no `log2` call, so no libm rounding hazards): the bucket index
//! of a positive finite `v` is its exponent and top [`SUB_BITS`] mantissa
//! bits, i.e. the top 16 bits of `v.to_bits()` minus a bias. That gives
//! 2^[`SUB_BITS`] = 32 sub-buckets per octave — at most ~3.2% relative
//! width — and makes [`bucket_index`] / [`bucket_value`] exact inverses:
//! `bucket_index(bucket_value(i)) == i` for every representable bucket.
//! Quantiles use the same nearest-rank rule as [`crate::util::stats::
//! percentile`], so on fixtures whose samples are exact bucket
//! representatives the histogram reproduces the sorted-`Vec` percentile
//! bit for bit (the `ServeStats` replacement contract).

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::util::json::Json;

/// Mantissa bits per bucket index: 2^5 = 32 sub-buckets per octave.
pub const SUB_BITS: u32 = 5;
/// Bias aligning bucket 0 with `v = 1.0` (exponent 0, first sub-bucket).
const IDX_BIAS: i32 = 1023 << SUB_BITS;

/// Bucket index of a positive finite value: the top `11 + SUB_BITS` bits
/// of its IEEE-754 representation, re-biased so 1.0 lands in bucket 0.
/// Monotone in `v` (larger values never map to smaller buckets).
pub fn bucket_index(v: f64) -> i32 {
    debug_assert!(v.is_finite() && v > 0.0, "bucket_index wants positive finite, got {v}");
    ((v.to_bits() >> (52 - SUB_BITS)) as i32) - IDX_BIAS
}

/// The bucket's representative value: its exact lower bound,
/// reconstructed from the same bit layout, so
/// `bucket_index(bucket_value(i)) == i` holds exactly.
pub fn bucket_value(idx: i32) -> f64 {
    f64::from_bits(((idx + IDX_BIAS) as u64) << (52 - SUB_BITS))
}

/// Log-bucketed histogram of non-negative samples. Non-positive and
/// non-finite samples are counted in a dedicated `zeros` bucket (they
/// sort below every positive bucket). Derives `Eq`: two histograms are
/// equal iff they hold identical counts — the property the merge laws
/// are stated over.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LogHistogram {
    zeros: u64,
    buckets: BTreeMap<i32, u64>,
}

impl LogHistogram {
    /// Empty histogram.
    pub fn new() -> LogHistogram {
        LogHistogram::default()
    }

    /// Record one sample.
    pub fn record(&mut self, v: f64) {
        self.record_n(v, 1);
    }

    /// Record `n` identical samples.
    pub fn record_n(&mut self, v: f64, n: u64) {
        if n == 0 {
            return;
        }
        if v.is_finite() && v > 0.0 {
            *self.buckets.entry(bucket_index(v)).or_insert(0) += n;
        } else {
            self.zeros += n;
        }
    }

    /// Total recorded samples.
    pub fn count(&self) -> u64 {
        self.zeros + self.buckets.values().sum::<u64>()
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.zeros == 0 && self.buckets.is_empty()
    }

    /// Samples in the zero bucket (non-positive or non-finite).
    pub fn zeros(&self) -> u64 {
        self.zeros
    }

    /// Fold another histogram into this one — exact integer addition per
    /// bucket, so merging is associative, commutative, and independent of
    /// how samples were sharded across threads or processes.
    pub fn merge(&mut self, other: &LogHistogram) {
        self.zeros += other.zeros;
        for (&idx, &n) in &other.buckets {
            *self.buckets.entry(idx).or_insert(0) += n;
        }
    }

    /// Mean over bucket representatives (zero-bucket samples count as 0).
    /// A pure function of the counts, so it is merge-order independent.
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let sum: f64 = self
            .buckets
            .iter()
            .map(|(&idx, &c)| bucket_value(idx) * c as f64)
            .sum();
        sum / n as f64
    }

    /// p-th quantile (0..=100) by the same nearest-rank rule as
    /// [`crate::util::stats::percentile`]: rank =
    /// `round(p/100 * (n-1))`, then walk buckets in ascending order and
    /// return the representative of the bucket holding that rank.
    pub fn quantile(&self, p: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let rank = (((p / 100.0) * (n as f64 - 1.0)).round() as u64).min(n - 1);
        if rank < self.zeros {
            return 0.0;
        }
        let mut seen = self.zeros;
        for (&idx, &c) in &self.buckets {
            seen += c;
            if rank < seen {
                return bucket_value(idx);
            }
        }
        // unreachable when counts are consistent; fall back to the top
        // bucket so a logic slip degrades instead of panicking
        self.buckets
            .keys()
            .next_back()
            .map(|&i| bucket_value(i))
            .unwrap_or(0.0)
    }

    /// Iterate `(bucket index, count)` in ascending bucket order.
    pub fn iter(&self) -> impl Iterator<Item = (i32, u64)> + '_ {
        self.buckets.iter().map(|(&i, &c)| (i, c))
    }

    /// JSON form (schema v1): `{"v":1,"zeros":Z,"buckets":[[idx,n],..]}`.
    /// Bucket order is ascending, so the encoding is deterministic.
    pub fn to_json(&self) -> Json {
        let buckets = self
            .buckets
            .iter()
            .map(|(&idx, &n)| Json::Arr(vec![Json::Num(idx as f64), Json::int(n)]))
            .collect();
        Json::Obj(vec![
            ("v".into(), Json::int(1)),
            ("zeros".into(), Json::int(self.zeros)),
            ("buckets".into(), Json::Arr(buckets)),
        ])
    }

    /// Parse the [`LogHistogram::to_json`] form.
    pub fn from_json(j: &Json) -> Result<LogHistogram> {
        ensure!(
            j.field("v")?.as_u64()? == 1,
            "unsupported histogram schema version"
        );
        let zeros = j.field("zeros")?.as_u64()?;
        let mut buckets = BTreeMap::new();
        for pair in j.field("buckets")?.as_arr()? {
            let pair = pair.as_arr()?;
            ensure!(pair.len() == 2, "histogram bucket wants [idx, count]");
            let idx = pair[0].as_f64()?;
            ensure!(
                idx.fract() == 0.0 && idx.abs() <= 66_000.0,
                "bad histogram bucket index {idx}"
            );
            let n = pair[1].as_u64()?;
            if n > 0 {
                *buckets.entry(idx as i32).or_insert(0) += n;
            }
        }
        Ok(LogHistogram { zeros, buckets })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn bucket_index_and_value_are_exact_inverses() {
        for idx in [-320, -33, -1, 0, 1, 5, 16, 31, 32, 100, 640] {
            let v = bucket_value(idx);
            assert!(v > 0.0, "bucket {idx} representative not positive");
            assert_eq!(bucket_index(v), idx, "round-trip failed for {idx}");
        }
    }

    #[test]
    fn bucket_width_is_bounded() {
        // adjacent representatives differ by at most a factor 1 + 2^-5
        for idx in [-320, -1, 0, 31, 32, 640] {
            let lo = bucket_value(idx);
            let hi = bucket_value(idx + 1);
            assert!(hi > lo);
            assert!(hi / lo <= 1.0 + 1.0 / 32.0 + 1e-12, "{idx}: {lo}..{hi}");
        }
    }

    #[test]
    fn quantiles_match_sorted_percentile_on_representative_fixtures() {
        // the ServeStats replacement contract: on samples that are exact
        // bucket representatives, the histogram reproduces the sorted-Vec
        // nearest-rank percentile bit for bit
        let samples = [0.25, 1.5, 0.75, 12.0, 3.0, 0.25, 96.0, 1.5];
        for v in samples {
            assert_eq!(bucket_value(bucket_index(v)), v, "{v} is not a representative");
        }
        let mut h = LogHistogram::new();
        for v in samples {
            h.record(v);
        }
        for p in [0.0, 10.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
            let exact = stats::percentile(&samples, p);
            assert_eq!(h.quantile(p).to_bits(), exact.to_bits(), "p{p}");
        }
        let exact_mean = samples.iter().sum::<f64>() / samples.len() as f64;
        assert!((h.mean() - exact_mean).abs() < 1e-12);
    }

    #[test]
    fn zero_and_nonfinite_samples_land_in_the_zero_bucket() {
        let mut h = LogHistogram::new();
        h.record(0.0);
        h.record(-1.0);
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        h.record(2.0);
        assert_eq!(h.zeros(), 4);
        assert_eq!(h.count(), 5);
        assert_eq!(h.quantile(0.0), 0.0);
        assert_eq!(h.quantile(100.0), 2.0);
    }

    #[test]
    fn merge_is_exact_integer_addition() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut whole = LogHistogram::new();
        for (i, v) in [0.3, 1.7, 2.9, 0.0, 55.0, 1.7].iter().enumerate() {
            whole.record(*v);
            if i % 2 == 0 {
                a.record(*v);
            } else {
                b.record(*v);
            }
        }
        a.merge(&b);
        assert_eq!(a, whole);
        assert_eq!(a.count(), 6);
    }

    #[test]
    fn json_round_trips() {
        let mut h = LogHistogram::new();
        for v in [0.25, 1.5, 0.0, 3.25e-3, 8192.0] {
            h.record(v);
        }
        let back = LogHistogram::from_json(&h.to_json()).unwrap();
        assert_eq!(h, back);
        assert!(LogHistogram::from_json(&Json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn empty_histogram_is_all_zeros() {
        let h = LogHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(50.0), 0.0);
    }
}
