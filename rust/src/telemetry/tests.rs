//! Property tests for the telemetry layer: histogram merge laws and
//! bucket determinism, trace framing torn-tail recovery, and the
//! cross-process merge order — all under `util::prop::for_cases`.

use super::hist::{bucket_value, LogHistogram};
use super::report::{check_trace, render};
use super::{parse_trace, read_trace, Recorder, TraceRecord};
use crate::util::json::Json;
use crate::util::prop::for_cases;
use crate::util::rng::XorShift;
use crate::util::stats;

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "interstellar-telemetry-{}-{name}",
        std::process::id()
    ))
}

/// Mostly positive latency-like samples across many octaves, with an
/// occasional zero / negative / non-finite to exercise the zero bucket.
fn sample(rng: &mut XorShift) -> f64 {
    match rng.below(12) {
        0 => 0.0,
        1 => -1.0,
        2 => f64::NAN,
        _ => (rng.below(1_000_000) as f64 + 1.0) / 997.0,
    }
}

#[test]
fn hist_merge_is_associative_and_commutative() {
    for_cases(0x7e1e_0001, 60, |rng| {
        let mut parts = Vec::new();
        for _ in 0..3 {
            let mut h = LogHistogram::new();
            for _ in 0..rng.below(40) {
                h.record(sample(rng));
            }
            parts.push(h);
        }
        let (a, b, c) = (&parts[0], &parts[1], &parts[2]);
        let mut left = a.clone(); // (a + b) + c
        left.merge(b);
        left.merge(c);
        let mut bc = b.clone(); // a + (b + c)
        bc.merge(c);
        let mut right = a.clone();
        right.merge(&bc);
        assert_eq!(left, right, "merge not associative");
        let mut ab = a.clone();
        ab.merge(b);
        let mut ba = b.clone();
        ba.merge(a);
        assert_eq!(ab, ba, "merge not commutative");
        // equal histograms encode to identical JSON
        assert_eq!(left.to_json().to_string(), right.to_json().to_string());
    });
}

#[test]
fn hist_quantiles_are_monotone_in_p() {
    for_cases(0x7e1e_0002, 60, |rng| {
        let mut h = LogHistogram::new();
        for _ in 0..rng.below(200) + 1 {
            h.record(sample(rng));
        }
        let mut ps: Vec<f64> = (0..8).map(|_| rng.below(1001) as f64 / 10.0).collect();
        ps.sort_by(|x, y| x.partial_cmp(y).unwrap());
        for w in ps.windows(2) {
            assert!(
                h.quantile(w[0]) <= h.quantile(w[1]),
                "quantile not monotone: p{} -> {}, p{} -> {}",
                w[0],
                h.quantile(w[0]),
                w[1],
                h.quantile(w[1])
            );
        }
    });
}

#[test]
fn hist_quantiles_match_sorted_percentile_on_representatives() {
    // on multisets of exact bucket representatives the histogram must
    // reproduce the sorted-Vec nearest-rank percentile bit for bit —
    // the ServeStats replacement contract, as a property
    for_cases(0x7e1e_0003, 40, |rng| {
        let mut values = Vec::new();
        let mut h = LogHistogram::new();
        for _ in 0..rng.below(120) + 1 {
            let idx = rng.range(0, 400) as i32 - 200;
            let v = bucket_value(idx);
            values.push(v);
            h.record(v);
        }
        for p in [0.0, 25.0, 50.0, 90.0, 95.0, 99.0, 99.9, 100.0] {
            let exact = stats::percentile(&values, p);
            assert_eq!(h.quantile(p).to_bits(), exact.to_bits(), "p{p} diverged");
        }
    });
}

#[test]
fn hist_is_deterministic_across_thread_counts_and_merge_order() {
    for_cases(0x7e1e_0004, 12, |rng| {
        let values: Vec<f64> = (0..rng.below(300) + 16).map(|_| sample(rng)).collect();
        let mut single = LogHistogram::new();
        for &v in &values {
            single.record(v);
        }
        for nthreads in [2usize, 3, 5] {
            let mut shards: Vec<LogHistogram> = std::thread::scope(|scope| {
                let mut handles = Vec::new();
                for t in 0..nthreads {
                    let vals = &values;
                    handles.push(scope.spawn(move || {
                        let mut h = LogHistogram::new();
                        for (i, &v) in vals.iter().enumerate() {
                            if i % nthreads == t {
                                h.record(v);
                            }
                        }
                        h
                    }));
                }
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            let mut fwd = LogHistogram::new();
            for s in &shards {
                fwd.merge(s);
            }
            shards.reverse();
            let mut rev = LogHistogram::new();
            for s in &shards {
                rev.merge(s);
            }
            assert_eq!(fwd, single, "{nthreads}-way shard + merge diverged");
            assert_eq!(rev, single, "reverse merge order diverged");
        }
    });
}

#[test]
fn trace_framing_recovers_from_torn_tails() {
    for_cases(0x7e1e_0005, 30, |rng| {
        let mut text = String::new();
        let mut want = 0usize;
        let mut want_skipped = 0usize;
        for i in 0..rng.below(12) + 1 {
            text.push_str(&format!(
                "\n{{\"v\":1,\"k\":\"g\",\"w\":7,\"s\":{i},\"e\":1000,\"t\":{},\
                 \"plane\":\"engine\",\"name\":\"x\",\"val\":1}}\n",
                i * 10
            ));
            want += 1;
        }
        if rng.below(2) == 0 {
            // a foreign non-JSON line is skipped, never fatal
            text.push_str("not json at all\n");
            want_skipped += 1;
        }
        // a record torn mid-write (killed appender): cut strictly inside
        // the body so the remainder can never parse as complete JSON
        let full = String::from(
            "\n{\"v\":1,\"k\":\"g\",\"w\":7,\"s\":99,\"e\":1000,\"t\":999,\
             \"plane\":\"engine\",\"name\":\"x\",\"val\":2}\n",
        );
        let cut = rng.range(2, full.len() as u64 - 2) as usize;
        text.push_str(&full[..cut]);
        let (records, skipped) = parse_trace(&text);
        assert_eq!(records.len(), want, "whole records lost to the torn tail");
        assert_eq!(skipped, want_skipped + 1, "torn tail not counted");
        // in-worker order follows the monotonic timebase
        let ts: Vec<u64> = records.iter().map(|r| r.abs_ns).collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]));
    });
}

#[test]
fn trace_merge_order_is_total_and_file_order_independent() {
    for_cases(0x7e1e_0006, 30, |rng| {
        let n = rng.below(40) + 2;
        let mut lines = Vec::new();
        for s in 0..n {
            let w = rng.below(4);
            let e = 1_000_000 + rng.below(1000);
            let t = rng.below(100_000);
            lines.push(format!(
                "{{\"v\":1,\"k\":\"ev\",\"w\":{w},\"s\":{s},\"e\":{e},\"t\":{t},\
                 \"plane\":\"fleet\",\"name\":\"n\",\"attrs\":{{}}}}"
            ));
        }
        let mut shuffled = lines.clone();
        rng.shuffle(&mut shuffled);
        let (a, _) = parse_trace(&lines.join("\n"));
        let (b, _) = parse_trace(&shuffled.join("\n"));
        let key = |r: &TraceRecord| (r.abs_ns, r.worker, r.seq);
        let ka: Vec<_> = a.iter().map(key).collect();
        let kb: Vec<_> = b.iter().map(key).collect();
        assert_eq!(ka, kb, "merge order depends on file order");
        assert!(ka.windows(2).all(|w| w[0] <= w[1]), "not sorted");
    });
}

#[test]
fn recorder_emits_framed_schema_valid_records() {
    let path = tmp("recorder.jsonl");
    let _ = std::fs::remove_file(&path);
    let rec = Recorder::new(&path, 42);
    rec.emit(
        "b",
        vec![
            ("id".into(), Json::int(1)),
            ("par".into(), Json::int(0)),
            ("plane".into(), Json::str("engine")),
            ("name".into(), Json::str("layer_search")),
        ],
    );
    rec.emit(
        "c",
        vec![
            ("plane".into(), Json::str("engine")),
            ("name".into(), Json::str("stage3")),
            ("val".into(), Json::int(5)),
        ],
    );
    rec.emit(
        "e",
        vec![
            ("id".into(), Json::int(1)),
            ("ns".into(), Json::int(1234)),
        ],
    );
    rec.flush().unwrap();
    // a torn tail from a killed writer must not break later records
    {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(&path)
            .unwrap();
        f.write_all(b"\n{\"v\":1,\"k\":\"g\",\"w\":42,\"s\":9").unwrap();
    }
    rec.emit(
        "g",
        vec![
            ("plane".into(), Json::str("engine")),
            ("name".into(), Json::str("pruned")),
            ("val".into(), Json::num(0.5)),
        ],
    );
    rec.flush().unwrap();
    let (records, skipped) = read_trace(&path).unwrap();
    assert_eq!(skipped, 1, "torn tail not skipped");
    assert_eq!(records.len(), 4);
    assert!(records.iter().all(|r| r.worker == 42));
    let seqs: Vec<u64> = records.iter().map(|r| r.seq).collect();
    assert_eq!(seqs, vec![0, 1, 2, 3], "per-process seq not monotone");
    let summary = check_trace(&records, skipped);
    assert!(summary.violations.is_empty(), "{:?}", summary.violations);
    assert_eq!(summary.spans, 1);
    assert_eq!(summary.planes, vec!["engine".to_string()]);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn check_flags_orphaned_spans_and_unknown_parents() {
    let text = concat!(
        "{\"v\":1,\"k\":\"b\",\"w\":1,\"s\":0,\"e\":10,\"t\":5,",
        "\"id\":1,\"par\":0,\"plane\":\"search\",\"name\":\"point\"}\n",
        "{\"v\":1,\"k\":\"b\",\"w\":1,\"s\":1,\"e\":10,\"t\":6,",
        "\"id\":2,\"par\":9,\"plane\":\"engine\",\"name\":\"layer\"}\n",
        "{\"v\":1,\"k\":\"e\",\"w\":1,\"s\":2,\"e\":10,\"t\":7,\"id\":2,\"ns\":100}\n",
        "{\"v\":1,\"k\":\"e\",\"w\":1,\"s\":3,\"e\":10,\"t\":8,\"id\":5,\"ns\":100}\n",
    );
    let (records, skipped) = parse_trace(text);
    assert_eq!(skipped, 0);
    let summary = check_trace(&records, skipped);
    // three problems: span 1 never ends, span 2's parent never began,
    // end for id 5 has no begin
    assert_eq!(summary.violations.len(), 3, "{:?}", summary.violations);
}

#[test]
fn render_covers_every_section_for_a_multi_worker_trace() {
    let path = tmp("render.jsonl");
    let _ = std::fs::remove_file(&path);
    // "process" one: an orchestrator controller with a task span
    let ctl = Recorder::new(&path, 1);
    ctl.emit(
        "b",
        vec![
            ("id".into(), Json::int(1)),
            ("par".into(), Json::int(0)),
            ("plane".into(), Json::str("orchestrator")),
            ("name".into(), Json::str("task")),
            (
                "attrs".into(),
                Json::Obj(vec![
                    ("shard".into(), Json::str("0/2")),
                    ("attempt".into(), Json::int(2)),
                ]),
            ),
        ],
    );
    ctl.emit(
        "e",
        vec![
            ("id".into(), Json::int(1)),
            ("ns".into(), Json::int(5_000_000)),
            (
                "attrs".into(),
                Json::Obj(vec![("outcome".into(), Json::str("done"))]),
            ),
        ],
    );
    ctl.flush().unwrap();
    // "process" two: a fleet worker publishing its latency histogram
    let mut h = LogHistogram::new();
    for v in [0.25, 1.5, 0.75, 1.5] {
        h.record(v);
    }
    let w = Recorder::new(&path, 2);
    w.emit(
        "ev",
        vec![
            ("plane".into(), Json::str("fleet")),
            ("name".into(), Json::str("latency_hist")),
            ("attrs".into(), Json::Obj(vec![("hist".into(), h.to_json())])),
        ],
    );
    w.flush().unwrap();
    let (records, skipped) = read_trace(&path).unwrap();
    let text = render(&records, skipped);
    for section in [
        "profile tree",
        "per-worker utilization",
        "stragglers",
        "per-shard tasks",
        "serving latency",
        "orchestrator:task",
        "shard=0/2",
    ] {
        assert!(text.contains(section), "missing `{section}` in:\n{text}");
    }
    let _ = std::fs::remove_file(&path);
}
