//! `trace-report`: explain a `trace.jsonl` — a self-time profile tree
//! ("where did the wall-clock go"), per-worker utilization and
//! straggler tables, a per-shard task table, and the merged serving
//! latency-histogram view — plus the `--check` validator the CI full
//! tier gates on: schema-valid records, zero orphaned spans (every
//! begin ended, every end begun, every parent known), and required
//! plane coverage.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::path::Path;

use anyhow::Result;

use super::hist::{bucket_value, LogHistogram, SUB_BITS};
use super::{read_trace, TraceRecord};
use crate::util::json::Json;

/// One reconstructed span (begin matched to end when present).
#[derive(Clone, Debug)]
pub struct Span {
    /// Worker id of the emitting process.
    pub worker: u64,
    /// Per-process span id.
    pub id: u64,
    /// Parent span id within the same worker (0 = root).
    pub parent: u64,
    /// Instrumented plane (`engine`/`search`/`orchestrator`/`fleet`).
    pub plane: String,
    /// Span name within the plane.
    pub name: String,
    /// Begin-record attributes.
    pub attrs: Option<Json>,
    /// End-record attributes (e.g. a task outcome).
    pub end_attrs: Option<Json>,
    /// Measured wall nanoseconds; `None` for an orphaned begin.
    pub ns: Option<u64>,
}

impl Span {
    fn label(&self) -> String {
        format!("{}:{}", self.plane, self.name)
    }

    fn attr_str(&self, key: &str) -> Option<String> {
        for side in [&self.attrs, &self.end_attrs] {
            if let Some(v) = side.as_ref().and_then(|a| a.get(key)) {
                if let Ok(s) = v.as_str() {
                    return Some(s.to_string());
                }
                return Some(v.to_string());
            }
        }
        None
    }

    fn attr_u64(&self, key: &str) -> Option<u64> {
        for side in [&self.attrs, &self.end_attrs] {
            if let Some(n) = side.as_ref().and_then(|a| a.get(key)).and_then(|v| v.as_u64().ok())
            {
                return Some(n);
            }
        }
        None
    }
}

/// What `--check` computed over one trace.
#[derive(Debug)]
pub struct CheckSummary {
    /// Schema-valid records.
    pub records: usize,
    /// Skipped lines (torn tails / foreign records).
    pub skipped: usize,
    /// Distinct worker ids.
    pub workers: usize,
    /// Reconstructed spans (matched or orphaned).
    pub spans: usize,
    /// Counter + gauge + event records.
    pub points: usize,
    /// Planes seen across all records, sorted.
    pub planes: Vec<String>,
    /// Violations: orphaned spans, malformed records, unknown kinds.
    pub violations: Vec<String>,
}

/// Reconstruct spans from time-ordered records, reporting violations
/// into `violations` when provided.
fn collect_spans(
    records: &[TraceRecord],
    mut violations: Option<&mut Vec<String>>,
) -> Vec<Span> {
    let mut spans: Vec<Span> = Vec::new();
    let mut open: HashMap<(u64, u64), usize> = HashMap::new();
    let mut note = |violations: &mut Option<&mut Vec<String>>, msg: String| {
        if let Some(v) = violations.as_deref_mut() {
            v.push(msg);
        }
    };
    for r in records {
        match r.kind.as_str() {
            "b" => {
                let id = r.json.get("id").and_then(|v| v.as_u64().ok());
                let parent = r.json.get("par").and_then(|v| v.as_u64().ok());
                let plane = r.json.get("plane").and_then(|v| v.as_str().ok());
                let name = r.json.get("name").and_then(|v| v.as_str().ok());
                let (Some(id), Some(parent), Some(plane), Some(name)) =
                    (id, parent, plane, name)
                else {
                    note(
                        &mut violations,
                        format!("worker {} seq {}: malformed span begin", r.worker, r.seq),
                    );
                    continue;
                };
                if id == 0 {
                    note(
                        &mut violations,
                        format!("worker {} seq {}: span id 0 is reserved", r.worker, r.seq),
                    );
                    continue;
                }
                if parent != 0 && !open.contains_key(&(r.worker, parent)) {
                    note(
                        &mut violations,
                        format!(
                            "worker {} span {id} ({plane}:{name}): parent {parent} never began",
                            r.worker
                        ),
                    );
                }
                if open.insert((r.worker, id), spans.len()).is_some() {
                    note(
                        &mut violations,
                        format!("worker {} span {id}: duplicate begin", r.worker),
                    );
                }
                spans.push(Span {
                    worker: r.worker,
                    id,
                    parent,
                    plane: plane.to_string(),
                    name: name.to_string(),
                    attrs: r.json.get("attrs").cloned(),
                    end_attrs: None,
                    ns: None,
                });
            }
            "e" => {
                let id = r.json.get("id").and_then(|v| v.as_u64().ok());
                let ns = r.json.get("ns").and_then(|v| v.as_u64().ok());
                let (Some(id), Some(ns)) = (id, ns) else {
                    note(
                        &mut violations,
                        format!("worker {} seq {}: malformed span end", r.worker, r.seq),
                    );
                    continue;
                };
                match open.get(&(r.worker, id)) {
                    Some(&i) if spans[i].ns.is_none() => {
                        spans[i].ns = Some(ns);
                        spans[i].end_attrs = r.json.get("attrs").cloned();
                    }
                    Some(_) => note(
                        &mut violations,
                        format!("worker {} span {id}: ended twice", r.worker),
                    ),
                    None => note(
                        &mut violations,
                        format!("worker {} span {id}: end without begin", r.worker),
                    ),
                }
            }
            "c" | "g" => {
                let ok = r.json.get("plane").and_then(|v| v.as_str().ok()).is_some()
                    && r.json.get("name").and_then(|v| v.as_str().ok()).is_some()
                    && r.json.get("val").is_some();
                if !ok {
                    note(
                        &mut violations,
                        format!("worker {} seq {}: malformed {} record", r.worker, r.seq, r.kind),
                    );
                }
            }
            "ev" => {
                let ok = r.json.get("plane").and_then(|v| v.as_str().ok()).is_some()
                    && r.json.get("name").and_then(|v| v.as_str().ok()).is_some();
                if !ok {
                    note(
                        &mut violations,
                        format!("worker {} seq {}: malformed event record", r.worker, r.seq),
                    );
                }
            }
            "meta" => {}
            other => note(
                &mut violations,
                format!("worker {} seq {}: unknown record kind `{other}`", r.worker, r.seq),
            ),
        }
    }
    for s in &spans {
        if s.ns.is_none() {
            note(
                &mut violations,
                format!(
                    "worker {} span {} ({}): never ended",
                    s.worker,
                    s.id,
                    s.label()
                ),
            );
        }
    }
    spans
}

/// Planes named by any record (spans, counters, gauges, events).
fn planes_of(records: &[TraceRecord]) -> Vec<String> {
    let mut planes: BTreeSet<String> = BTreeSet::new();
    for r in records {
        if let Some(p) = r.json.get("plane").and_then(|v| v.as_str().ok()) {
            planes.insert(p.to_string());
        }
    }
    planes.into_iter().collect()
}

/// Validate a parsed trace: schema-valid records and zero orphaned
/// spans. Violations are collected, not bailed on, so one run reports
/// every problem.
pub fn check_trace(records: &[TraceRecord], skipped: usize) -> CheckSummary {
    let mut violations = Vec::new();
    let spans = collect_spans(records, Some(&mut violations));
    let workers: BTreeSet<u64> = records.iter().map(|r| r.worker).collect();
    let points = records
        .iter()
        .filter(|r| matches!(r.kind.as_str(), "c" | "g" | "ev"))
        .count();
    CheckSummary {
        records: records.len(),
        skipped,
        workers: workers.len(),
        spans: spans.len(),
        points,
        planes: planes_of(records),
        violations,
    }
}

/// Read, parse, and [`check_trace`] a trace file.
pub fn check_path(path: &Path) -> Result<CheckSummary> {
    let (records, skipped) = read_trace(path)?;
    Ok(check_trace(&records, skipped))
}

fn fmt_ms(ns: u64) -> String {
    format!("{:.2}", ns as f64 / 1e6)
}

/// The self-time profile tree: spans aggregated by their parent-chain
/// label path, with total, self (total minus children), and the
/// self-time share of all root wall-clock.
pub fn profile_tree(records: &[TraceRecord]) -> String {
    let spans = collect_spans(records, None);
    let mut index: HashMap<(u64, u64), usize> = HashMap::new();
    for (i, s) in spans.iter().enumerate() {
        index.insert((s.worker, s.id), i);
    }
    let path_of = |i: usize| -> String {
        let mut parts = vec![spans[i].label()];
        let mut cur = i;
        let mut depth = 0;
        while spans[cur].parent != 0 && depth < 64 {
            match index.get(&(spans[cur].worker, spans[cur].parent)) {
                Some(&p) => {
                    parts.push(spans[p].label());
                    cur = p;
                }
                None => break,
            }
            depth += 1;
        }
        parts.reverse();
        parts.join(" > ")
    };
    #[derive(Default)]
    struct Agg {
        count: u64,
        total_ns: u64,
        child_ns: u64,
    }
    let mut aggs: BTreeMap<String, Agg> = BTreeMap::new();
    for (i, s) in spans.iter().enumerate() {
        let Some(ns) = s.ns else { continue };
        let path = path_of(i);
        if s.parent != 0 && index.contains_key(&(s.worker, s.parent)) {
            let parent_path = path
                .rsplit_once(" > ")
                .map(|(head, _)| head.to_string())
                .unwrap_or_default();
            if !parent_path.is_empty() {
                aggs.entry(parent_path).or_default().child_ns += ns;
            }
        }
        let a = aggs.entry(path).or_default();
        a.count += 1;
        a.total_ns += ns;
    }
    let grand: u64 = aggs
        .iter()
        .filter(|(path, _)| !path.contains(" > "))
        .map(|(_, a)| a.total_ns)
        .sum();
    let mut out = String::new();
    out.push_str("== profile tree (self-time) ==\n");
    out.push_str(&format!(
        "{:<52} {:>7} {:>12} {:>12} {:>7}\n",
        "span", "count", "total ms", "self ms", "self%"
    ));
    for (path, a) in &aggs {
        let depth = path.matches(" > ").count();
        let name = path.rsplit(" > ").next().unwrap_or(path);
        let self_ns = a.total_ns.saturating_sub(a.child_ns);
        let pct = if grand > 0 {
            100.0 * self_ns as f64 / grand as f64
        } else {
            0.0
        };
        out.push_str(&format!(
            "{:<52} {:>7} {:>12} {:>12} {:>6.1}%\n",
            format!("{}{}", "  ".repeat(depth), name),
            a.count,
            fmt_ms(a.total_ns),
            fmt_ms(self_ns),
            pct
        ));
    }
    if aggs.is_empty() {
        out.push_str("(no completed spans)\n");
    }
    out
}

/// Per-worker utilization: root-span busy time against the worker's
/// active window (first to last record). Thread-parallel workers can
/// exceed 100% — that is the parallelism showing, not an error.
pub fn utilization_table(records: &[TraceRecord]) -> String {
    let spans = collect_spans(records, None);
    #[derive(Default)]
    struct W {
        records: u64,
        spans: u64,
        busy_ns: u64,
        first: u64,
        last: u64,
    }
    let mut workers: BTreeMap<u64, W> = BTreeMap::new();
    for r in records {
        let w = workers.entry(r.worker).or_default();
        if w.records == 0 {
            w.first = r.abs_ns;
        }
        w.records += 1;
        w.last = w.last.max(r.abs_ns);
    }
    for s in &spans {
        let w = workers.entry(s.worker).or_default();
        w.spans += 1;
        if s.parent == 0 {
            w.busy_ns += s.ns.unwrap_or(0);
        }
    }
    let mut out = String::new();
    out.push_str("== per-worker utilization ==\n");
    out.push_str(&format!(
        "{:>10} {:>9} {:>7} {:>12} {:>12} {:>7}\n",
        "worker", "records", "spans", "busy ms", "window ms", "util%"
    ));
    for (id, w) in &workers {
        let window = w.last.saturating_sub(w.first);
        let util = if window > 0 {
            100.0 * w.busy_ns as f64 / window as f64
        } else {
            0.0
        };
        out.push_str(&format!(
            "{:>10} {:>9} {:>7} {:>12} {:>12} {:>6.1}%\n",
            id,
            w.records,
            w.spans,
            fmt_ms(w.busy_ns),
            fmt_ms(window),
            util
        ));
    }
    out
}

/// The longest completed spans — where to look first for a straggler.
/// Orchestrator task spans carry shard/attempt/outcome attributes.
pub fn straggler_table(records: &[TraceRecord], top: usize) -> String {
    let mut spans = collect_spans(records, None);
    spans.retain(|s| s.ns.is_some());
    spans.sort_by_key(|s| std::cmp::Reverse(s.ns.unwrap_or(0)));
    let mut out = String::new();
    out.push_str("== stragglers (longest spans) ==\n");
    out.push_str(&format!(
        "{:<28} {:>10} {:>12}  {}\n",
        "span", "worker", "wall ms", "detail"
    ));
    for s in spans.iter().take(top) {
        let mut detail = Vec::new();
        for key in ["shard", "seq", "attempt", "outcome", "arch", "batch"] {
            if let Some(v) = s.attr_str(key) {
                detail.push(format!("{key}={v}"));
            }
        }
        out.push_str(&format!(
            "{:<28} {:>10} {:>12}  {}\n",
            s.label(),
            s.worker,
            fmt_ms(s.ns.unwrap_or(0)),
            detail.join(" ")
        ));
    }
    if spans.is_empty() {
        out.push_str("(no completed spans)\n");
    }
    out
}

/// Orchestrator task spans grouped by shard class: task count, highest
/// attempt, total wall, and outcomes — the per-shard view of a sweep.
pub fn shard_table(records: &[TraceRecord]) -> String {
    let spans = collect_spans(records, None);
    #[derive(Default)]
    struct Sh {
        tasks: u64,
        max_attempt: u64,
        total_ns: u64,
        outcomes: BTreeMap<String, u64>,
    }
    let mut shards: BTreeMap<String, Sh> = BTreeMap::new();
    for s in &spans {
        if !(s.plane == "orchestrator" && s.name == "task") {
            continue;
        }
        let key = s.attr_str("shard").unwrap_or_else(|| "?".into());
        let sh = shards.entry(key).or_default();
        sh.tasks += 1;
        sh.max_attempt = sh.max_attempt.max(s.attr_u64("attempt").unwrap_or(1));
        sh.total_ns += s.ns.unwrap_or(0);
        let outcome = s.attr_str("outcome").unwrap_or_else(|| "open".into());
        *sh.outcomes.entry(outcome).or_insert(0) += 1;
    }
    if shards.is_empty() {
        return String::new();
    }
    let mut out = String::new();
    out.push_str("== per-shard tasks ==\n");
    out.push_str(&format!(
        "{:<12} {:>6} {:>9} {:>12}  {}\n",
        "shard", "tasks", "attempts", "total ms", "outcomes"
    ));
    for (shard, sh) in &shards {
        let outcomes = sh
            .outcomes
            .iter()
            .map(|(k, v)| format!("{k}x{v}"))
            .collect::<Vec<_>>()
            .join(" ");
        out.push_str(&format!(
            "{:<12} {:>6} {:>9} {:>12}  {}\n",
            shard,
            sh.tasks,
            sh.max_attempt,
            fmt_ms(sh.total_ns),
            outcomes
        ));
    }
    out
}

/// Merge every `latency_hist` event in the trace into one histogram —
/// the cross-worker serving latency distribution.
pub fn merged_latency_hist(records: &[TraceRecord]) -> LogHistogram {
    let mut merged = LogHistogram::new();
    for r in records {
        if r.kind != "ev" {
            continue;
        }
        let name = r.json.get("name").and_then(|v| v.as_str().ok());
        if name != Some("latency_hist") {
            continue;
        }
        if let Some(h) = r
            .json
            .get("attrs")
            .and_then(|a| a.get("hist"))
            .and_then(|h| LogHistogram::from_json(h).ok())
        {
            merged.merge(&h);
        }
    }
    merged
}

/// The serving latency view: merged-histogram quantiles plus a
/// per-octave bar chart (buckets coalesced to powers of two).
pub fn latency_view(records: &[TraceRecord]) -> String {
    let h = merged_latency_hist(records);
    if h.is_empty() {
        return String::new();
    }
    let mut out = String::new();
    out.push_str("== serving latency (merged histogram, ms) ==\n");
    out.push_str(&format!(
        "count {}  mean {:.3}  p50 {:.3}  p95 {:.3}  p99 {:.3}  p99.9 {:.3}\n",
        h.count(),
        h.mean(),
        h.quantile(50.0),
        h.quantile(95.0),
        h.quantile(99.0),
        h.quantile(99.9)
    ));
    let mut octaves: BTreeMap<i32, u64> = BTreeMap::new();
    if h.zeros() > 0 {
        octaves.insert(i32::MIN, h.zeros());
    }
    for (idx, n) in h.iter() {
        *octaves.entry(idx >> SUB_BITS).or_insert(0) += n;
    }
    let peak = octaves.values().copied().max().unwrap_or(1).max(1);
    for (oct, n) in &octaves {
        let label = if *oct == i32::MIN {
            "<=0".to_string()
        } else {
            format!("{:.4}", bucket_value(oct << SUB_BITS))
        };
        let bar = "#".repeat(((n * 40).div_ceil(peak)) as usize);
        out.push_str(&format!("{label:>12} {n:>8} {bar}\n"));
    }
    out
}

/// The full human report: summary line, profile tree, utilization,
/// stragglers, shard table, latency view.
pub fn render(records: &[TraceRecord], skipped: usize) -> String {
    let summary = check_trace(records, skipped);
    let mut out = String::new();
    out.push_str(&format!(
        "trace: {} records ({} skipped line(s)), {} worker(s), {} span(s), \
         {} counter/gauge/event(s), planes [{}]\n\n",
        summary.records,
        summary.skipped,
        summary.workers,
        summary.spans,
        summary.points,
        summary.planes.join(", ")
    ));
    out.push_str(&profile_tree(records));
    out.push('\n');
    out.push_str(&utilization_table(records));
    out.push('\n');
    out.push_str(&straggler_table(records, 8));
    let shards = shard_table(records);
    if !shards.is_empty() {
        out.push('\n');
        out.push_str(&shards);
    }
    let latency = latency_view(records);
    if !latency.is_empty() {
        out.push('\n');
        out.push_str(&latency);
    }
    if !summary.violations.is_empty() {
        out.push_str(&format!(
            "\n{} violation(s) — run with --check for the gate:\n",
            summary.violations.len()
        ));
        for v in &summary.violations {
            out.push_str(&format!("  {v}\n"));
        }
    }
    out
}
