//! Unified telemetry: spans, counters, gauges, and events into an
//! append-only `trace.jsonl` — zero-cost when disabled (the default).
//!
//! ## Design
//!
//! A process-global [`Recorder`] (installed once, from
//! [`init_from_env`] in `main`) emits schema-v1 records using the
//! orchestrator's torn-write-safe `\n{json}\n` framing, buffered and
//! flushed with one `O_APPEND` `write_all` so concurrent fleet /
//! orchestrator worker processes can share a single trace file. Every
//! record is stamped with:
//!
//! - `v` — schema version (1)
//! - `k` — kind: `meta`, `b` (span begin), `e` (span end), `c`
//!   (counter delta), `g` (gauge), `ev` (event)
//! - `w` — worker id (defaults to the process id, override via
//!   `INTERSTELLAR_TRACE_WORKER`)
//! - `s` — per-process monotone sequence number
//! - `e` — wall-clock **microseconds** since the unix epoch at recorder
//!   init (microseconds keep the value inside `Json::int`'s exact-f64
//!   range; nanoseconds would not fit)
//! - `t` — monotonic nanoseconds since recorder init
//!
//! `e*1000 + t` is a per-record absolute-nanosecond timestamp, so traces
//! from many processes merge into one global order: sort by
//! `(abs_ns, worker, seq)` (see [`parse_trace`]). Span ids are
//! per-process, so `(worker, id)` is globally unique; parent links are
//! kept per thread via a thread-local span stack ([`span`] /
//! [`span_with`]) or set explicitly for spans that outlive a scope
//! ([`begin`] → [`ManualSpan`], used for orchestrator task lifecycles).
//!
//! ## Telemetry observes, never steers
//!
//! Nothing in this module feeds back into search, scheduling, or
//! serving decisions: recording a span or counter can allocate and take
//! a mutex, but it cannot change any computed value. Every bit-identity
//! pin (search winners, Pareto frontiers, fleet digest) holds with
//! tracing on — `perf_telemetry` gates this, plus a ≤5% wall-clock
//! overhead bound on the `perf_search` workload. When disabled, every
//! entry point is one relaxed atomic load and an early return.

pub mod hist;
pub mod report;
#[cfg(test)]
mod tests;

use std::cell::RefCell;
use std::fs::OpenOptions;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Trace record schema version.
pub const SCHEMA_VERSION: u64 = 1;
/// Env var naming the trace file; absence (or empty) disables telemetry.
pub const TRACE_ENV: &str = "INTERSTELLAR_TRACE";
/// Env var overriding the per-record worker id (defaults to the pid).
pub const WORKER_ENV: &str = "INTERSTELLAR_TRACE_WORKER";
/// Buffered bytes that trigger an implicit flush.
const FLUSH_BYTES: usize = 64 * 1024;

static ENABLED: AtomicBool = AtomicBool::new(false);
static RECORDER: OnceLock<Recorder> = OnceLock::new();

thread_local! {
    static SPAN_STACK: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
}

/// True when a recorder is installed — one relaxed atomic load. Guard
/// any non-trivial attribute construction on this (the span/event APIs
/// already take attribute closures, evaluated only when enabled).
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// The buffered trace writer. Normally used through the process-global
/// API below; constructible directly so tests can exercise emission and
/// framing without touching global state.
pub struct Recorder {
    path: PathBuf,
    worker: u64,
    epoch_us: u64,
    base: Instant,
    seq: AtomicU64,
    next_span: AtomicU64,
    buf: Mutex<String>,
}

impl Recorder {
    /// New recorder appending to `path`, stamping `worker` on every
    /// record. The epoch (wall clock) and timebase (monotonic clock)
    /// are captured here.
    pub fn new(path: impl Into<PathBuf>, worker: u64) -> Recorder {
        let epoch_us = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(0);
        Recorder {
            path: path.into(),
            worker,
            epoch_us,
            base: Instant::now(),
            seq: AtomicU64::new(0),
            next_span: AtomicU64::new(0),
            buf: Mutex::new(String::new()),
        }
    }

    fn t_ns(&self) -> u64 {
        self.base.elapsed().as_nanos() as u64
    }

    /// Append one framed record (common stamps + `fields`) to the
    /// buffer; flushes when the buffer passes [`FLUSH_BYTES`].
    pub fn emit(&self, kind: &str, fields: Vec<(String, Json)>) {
        let mut members = Vec::with_capacity(fields.len() + 6);
        members.push(("v".into(), Json::int(SCHEMA_VERSION)));
        members.push(("k".into(), Json::str(kind)));
        members.push(("w".into(), Json::int(self.worker)));
        members.push((
            "s".into(),
            Json::int(self.seq.fetch_add(1, Ordering::Relaxed)),
        ));
        members.push(("e".into(), Json::int(self.epoch_us)));
        members.push(("t".into(), Json::int(self.t_ns())));
        members.extend(fields);
        let record = Json::Obj(members);
        let mut line = String::with_capacity(160);
        line.push('\n');
        record.write(&mut line);
        line.push('\n');
        let flush_now = {
            let mut buf = self.buf.lock().unwrap();
            buf.push_str(&line);
            buf.len() >= FLUSH_BYTES
        };
        if flush_now {
            // best-effort: a full disk must not take the workload down
            let _ = self.flush();
        }
    }

    /// Write all buffered records with one `O_APPEND` `write_all` —
    /// records from concurrent processes interleave only at frame
    /// boundaries, and a torn tail loses at most the torn record.
    pub fn flush(&self) -> Result<()> {
        let pending = {
            let mut buf = self.buf.lock().unwrap();
            if buf.is_empty() {
                return Ok(());
            }
            std::mem::take(&mut *buf)
        };
        let mut f = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .with_context(|| format!("open trace log {}", self.path.display()))?;
        f.write_all(pending.as_bytes())
            .with_context(|| format!("append trace records to {}", self.path.display()))?;
        Ok(())
    }
}

/// Install the process-global recorder. Fails if one is already
/// installed (the recorder captures the timebase, so it is
/// once-per-process by construction).
pub fn init(path: impl Into<PathBuf>, worker: u64) -> Result<()> {
    let rec = Recorder::new(path, worker);
    rec.emit(
        "meta",
        vec![
            ("pid".into(), Json::int(std::process::id() as u64)),
            (
                "argv".into(),
                Json::Arr(std::env::args().map(Json::str).collect()),
            ),
        ],
    );
    RECORDER
        .set(rec)
        .map_err(|_| anyhow::anyhow!("telemetry recorder already installed"))?;
    ENABLED.store(true, Ordering::Relaxed);
    Ok(())
}

/// Install a recorder from `INTERSTELLAR_TRACE` (the trace path) and
/// `INTERSTELLAR_TRACE_WORKER` (worker id, default: pid). Called from
/// `main` before the CLI dispatch, so every spawned worker process
/// (which inherits the environment) self-initializes against the same
/// trace file with a distinct worker id. No env var → `Disabled`
/// stays the default and this is a no-op.
pub fn init_from_env() {
    let Ok(path) = std::env::var(TRACE_ENV) else {
        return;
    };
    if path.is_empty() {
        return;
    }
    let worker = std::env::var(WORKER_ENV)
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(std::process::id() as u64)
        & ((1u64 << 53) - 1);
    if let Err(e) = init(path, worker) {
        eprintln!("telemetry: disabled ({e})");
    }
}

/// Flush the global recorder's buffer (no-op when disabled). `main`
/// calls this after the CLI returns so process exit never strands
/// buffered records.
pub fn flush() {
    if let Some(rec) = RECORDER.get() {
        if let Err(e) = rec.flush() {
            eprintln!("telemetry: flush failed ({e})");
        }
    }
}

#[inline]
fn recorder() -> Option<&'static Recorder> {
    if enabled() {
        RECORDER.get()
    } else {
        None
    }
}

/// RAII span tied to the current thread's span stack: `begin` on
/// creation, `end` (with measured wall-ns) on drop. When telemetry is
/// disabled this is an inert zero-sized-state guard.
pub struct SpanGuard {
    id: u64,
    start: Option<Instant>,
}

impl SpanGuard {
    /// The span's id (0 when telemetry is disabled). Pass to
    /// [`span_under`] so spans opened on *other* threads (e.g. a
    /// parallel sweep's workers) attach under this span instead of
    /// becoming extra roots.
    pub fn id(&self) -> u64 {
        self.id
    }
}

/// Open a span with no attributes. See [`span_with`].
pub fn span(plane: &str, name: &str) -> SpanGuard {
    span_with(plane, name, Vec::new)
}

/// Open a span on the current thread's stack: the innermost open span
/// becomes the parent. `attrs` is only evaluated when telemetry is
/// enabled, so call sites stay zero-cost when disabled.
pub fn span_with(
    plane: &str,
    name: &str,
    attrs: impl FnOnce() -> Vec<(String, Json)>,
) -> SpanGuard {
    span_under(plane, name, 0, attrs)
}

/// Like [`span_with`], but when the current thread has no open span the
/// parent falls back to `parent` instead of the root. Worker threads in
/// a parallel sweep use this to hang their spans under the sweep's root
/// span, which lives on the dispatching thread's stack.
pub fn span_under(
    plane: &str,
    name: &str,
    parent: u64,
    attrs: impl FnOnce() -> Vec<(String, Json)>,
) -> SpanGuard {
    let Some(rec) = recorder() else {
        return SpanGuard { id: 0, start: None };
    };
    let id = rec.next_span.fetch_add(1, Ordering::Relaxed) + 1;
    let parent = SPAN_STACK.with(|s| {
        let mut s = s.borrow_mut();
        let p = s.last().copied().unwrap_or(parent);
        s.push(id);
        p
    });
    let mut fields = vec![
        ("id".into(), Json::int(id)),
        ("par".into(), Json::int(parent)),
        ("plane".into(), Json::str(plane)),
        ("name".into(), Json::str(name)),
    ];
    let a = attrs();
    if !a.is_empty() {
        fields.push(("attrs".into(), Json::Obj(a)));
    }
    rec.emit("b", fields);
    SpanGuard {
        id,
        start: Some(Instant::now()),
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(start) = self.start else {
            return;
        };
        SPAN_STACK.with(|s| {
            s.borrow_mut().pop();
        });
        if let Some(rec) = RECORDER.get() {
            rec.emit(
                "e",
                vec![
                    ("id".into(), Json::int(self.id)),
                    ("ns".into(), Json::int(start.elapsed().as_nanos() as u64)),
                ],
            );
        }
    }
}

/// A span that outlives a lexical scope (e.g. an orchestrator task:
/// begun at dispatch, ended at reap, with other spans interleaved).
/// Not tied to the thread-local stack — its parent is the root. Ends
/// with outcome attributes via [`ManualSpan::end_with`], or plainly on
/// drop, so a cancelled task can never strand an open span.
pub struct ManualSpan {
    id: u64,
    start: Option<Instant>,
}

/// Open a manual (stack-free, root-parented) span. `attrs` is only
/// evaluated when telemetry is enabled.
pub fn begin(plane: &str, name: &str, attrs: impl FnOnce() -> Vec<(String, Json)>) -> ManualSpan {
    begin_under(plane, name, 0, attrs)
}

/// [`begin`] with an explicit parent span id (0 = root) — e.g. the
/// orchestrator parents every task span under its run span.
pub fn begin_under(
    plane: &str,
    name: &str,
    parent: u64,
    attrs: impl FnOnce() -> Vec<(String, Json)>,
) -> ManualSpan {
    let Some(rec) = recorder() else {
        return ManualSpan { id: 0, start: None };
    };
    let id = rec.next_span.fetch_add(1, Ordering::Relaxed) + 1;
    let mut fields = vec![
        ("id".into(), Json::int(id)),
        ("par".into(), Json::int(parent)),
        ("plane".into(), Json::str(plane)),
        ("name".into(), Json::str(name)),
    ];
    let a = attrs();
    if !a.is_empty() {
        fields.push(("attrs".into(), Json::Obj(a)));
    }
    rec.emit("b", fields);
    ManualSpan {
        id,
        start: Some(Instant::now()),
    }
}

impl ManualSpan {
    /// The span's id (0 when telemetry is disabled).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// End the span, attaching outcome attributes to the end record.
    pub fn end_with(mut self, attrs: impl FnOnce() -> Vec<(String, Json)>) {
        let a = if self.start.is_some() { attrs() } else { Vec::new() };
        self.finish(a);
    }

    fn finish(&mut self, attrs: Vec<(String, Json)>) {
        let Some(start) = self.start.take() else {
            return;
        };
        if let Some(rec) = RECORDER.get() {
            let mut fields = vec![
                ("id".into(), Json::int(self.id)),
                ("ns".into(), Json::int(start.elapsed().as_nanos() as u64)),
            ];
            if !attrs.is_empty() {
                fields.push(("attrs".into(), Json::Obj(attrs)));
            }
            rec.emit("e", fields);
        }
    }
}

impl Drop for ManualSpan {
    fn drop(&mut self) {
        self.finish(Vec::new());
    }
}

/// Record a monotone counter increment (`delta` of the named counter).
/// Zero deltas are elided.
pub fn counter(plane: &str, name: &str, delta: u64) {
    if delta == 0 {
        return;
    }
    let Some(rec) = recorder() else {
        return;
    };
    rec.emit(
        "c",
        vec![
            ("plane".into(), Json::str(plane)),
            ("name".into(), Json::str(name)),
            ("val".into(), Json::int(delta)),
        ],
    );
}

/// Record an instantaneous gauge sample.
pub fn gauge(plane: &str, name: &str, value: f64) {
    let Some(rec) = recorder() else {
        return;
    };
    rec.emit(
        "g",
        vec![
            ("plane".into(), Json::str(plane)),
            ("name".into(), Json::str(name)),
            ("val".into(), Json::num(value)),
        ],
    );
}

/// Record a point event with attributes (evaluated only when enabled).
pub fn event(plane: &str, name: &str, attrs: impl FnOnce() -> Vec<(String, Json)>) {
    let Some(rec) = recorder() else {
        return;
    };
    rec.emit(
        "ev",
        vec![
            ("plane".into(), Json::str(plane)),
            ("name".into(), Json::str(name)),
            ("attrs".into(), Json::Obj(attrs())),
        ],
    );
}

/// One parsed trace record with its merge keys extracted.
#[derive(Clone, Debug)]
pub struct TraceRecord {
    /// The full record.
    pub json: Json,
    /// Record kind (`meta`/`b`/`e`/`c`/`g`/`ev`).
    pub kind: String,
    /// Worker id stamp.
    pub worker: u64,
    /// Per-process sequence number.
    pub seq: u64,
    /// Absolute nanoseconds: `epoch_us * 1000 + t_ns`.
    pub abs_ns: u64,
}

/// Parse a trace file's text into records sorted by the cross-process
/// merge order `(abs_ns, worker, seq)` — the monotonic timebase plus
/// worker id makes the order total and deterministic. Returns the
/// records and the count of skipped lines (torn tails from interrupted
/// appends, or records missing the v1 stamps).
pub fn parse_trace(text: &str) -> (Vec<TraceRecord>, usize) {
    let mut records = Vec::new();
    let mut skipped = 0usize;
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let Ok(json) = Json::parse(line) else {
            skipped += 1;
            continue;
        };
        let stamps = (
            json.get("v").and_then(|v| v.as_u64().ok()),
            json.get("k").and_then(|v| v.as_str().ok().map(String::from)),
            json.get("w").and_then(|v| v.as_u64().ok()),
            json.get("s").and_then(|v| v.as_u64().ok()),
            json.get("e").and_then(|v| v.as_u64().ok()),
            json.get("t").and_then(|v| v.as_u64().ok()),
        );
        let (Some(v), Some(kind), Some(worker), Some(seq), Some(epoch_us), Some(t_ns)) = stamps
        else {
            skipped += 1;
            continue;
        };
        if v != SCHEMA_VERSION {
            skipped += 1;
            continue;
        }
        records.push(TraceRecord {
            json,
            kind,
            worker,
            seq,
            abs_ns: epoch_us.saturating_mul(1000).saturating_add(t_ns),
        });
    }
    records.sort_by_key(|r| (r.abs_ns, r.worker, r.seq));
    (records, skipped)
}

/// Read and [`parse_trace`] a trace file.
pub fn read_trace(path: &Path) -> Result<(Vec<TraceRecord>, usize)> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    Ok(parse_trace(&text))
}
