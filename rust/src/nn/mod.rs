//! Layers and benchmark networks (the paper's §6.3 workload set).

mod layer;
mod networks;

pub use layer::{Layer, LayerKind};
pub use networks::{all_benchmarks, network, network_names, Network};

#[cfg(test)]
mod tests;
