//! Tests for layer shapes and benchmark network tables.

use super::*;
use crate::loopnest::{Dim, Tensor};

#[test]
fn alexnet_conv3_dims() {
    let net = network("alexnet", 16).unwrap();
    let conv3 = net.layers.iter().find(|l| l.name == "CONV3").unwrap();
    assert_eq!(conv3.shape.bound(Dim::K), 384);
    assert_eq!(conv3.shape.bound(Dim::C), 256);
    assert_eq!(conv3.shape.bound(Dim::X), 13);
    assert_eq!(conv3.shape.bound(Dim::FX), 3);
    assert_eq!(conv3.shape.bound(Dim::B), 16);
    // per-image MACs ~ 149.5M
    assert_eq!(conv3.macs() / 16, 384 * 256 * 13 * 13 * 9);
}

#[test]
fn alexnet_macs_order_of_magnitude() {
    // ~666M conv MACs + ~58.6M FC MACs per image
    let net = network("alexnet", 1).unwrap();
    let macs = net.macs();
    assert!(macs > 600_000_000 && macs < 800_000_000, "{macs}");
}

#[test]
fn vgg16_macs_order_of_magnitude() {
    // ~15.3G conv MACs + ~123M FC per image
    let net = network("vgg16", 1).unwrap();
    let macs = net.macs();
    assert!(
        macs > 15_000_000_000 && macs < 16_000_000_000,
        "{macs}"
    );
}

#[test]
fn googlenet_4c3r_layer() {
    let net = network("googlenet", 16).unwrap();
    let l = net.layers.iter().find(|l| l.name == "4C3R").unwrap();
    assert_eq!(l.kind, LayerKind::Pointwise);
    assert_eq!(l.shape.bound(Dim::C), 512);
    assert_eq!(l.shape.bound(Dim::K), 128);
    assert_eq!(l.shape.bound(Dim::X), 14);
    assert_eq!(l.shape.bound(Dim::FX), 1);
}

#[test]
fn googlenet_layer_count() {
    // 3 stem + 9 modules x 6 + 1 FC = 58
    let net = network("googlenet", 1).unwrap();
    assert_eq!(net.layers.len(), 58);
    // ~1.58G MACs per image (inception v1, incl. pointwise pool projections)
    let macs = net.macs();
    assert!(macs > 1_300_000_000 && macs < 1_800_000_000, "{macs}");
}

#[test]
fn mobilenet_structure() {
    let net = network("mobilenet", 1).unwrap();
    // 1 stem + 13 x (dw + pw) + 1 fc = 28
    assert_eq!(net.layers.len(), 28);
    let dw1 = net.layers.iter().find(|l| l.name == "DW1").unwrap();
    assert_eq!(dw1.kind, LayerKind::Depthwise);
    assert_eq!(dw1.shape.bound(Dim::C), 1);
    assert_eq!(dw1.shape.bound(Dim::K), 32);
    // ~569M MACs per image
    let macs = net.macs();
    assert!(macs > 500_000_000 && macs < 650_000_000, "{macs}");
}

#[test]
fn depthwise_input_elems_ride_on_k() {
    let l = Layer::depthwise("DW", 1, 32, 10, 10, 3, 1);
    // input = 32 channels of 12x12, even though nest C = 1
    assert_eq!(l.tensor_elems(Tensor::Input), 32 * 12 * 12);
    assert_eq!(l.tensor_elems(Tensor::Weight), 32 * 9);
}

#[test]
fn fc_layers_are_degenerate() {
    let net = network("mlp-m", 128).unwrap();
    assert_eq!(net.layers.len(), 3);
    for l in &net.layers {
        assert!(l.is_fc_family());
        assert_eq!(l.shape.bound(Dim::X), 1);
        assert_eq!(l.shape.bound(Dim::FX), 1);
        assert_eq!(l.shape.bound(Dim::B), 128);
    }
    assert_eq!(net.layers[0].shape.bound(Dim::C), 784);
    assert_eq!(net.layers[0].shape.bound(Dim::K), 500);
}

#[test]
fn lstm_gate_shapes() {
    let net = network("lstm-m", 1).unwrap();
    assert_eq!(net.layers.len(), 8); // 4 layers x 2 gate banks
    for l in &net.layers {
        assert_eq!(l.shape.bound(Dim::K), 2000); // 4 x 500
        assert_eq!(l.shape.bound(Dim::C), 500);
    }
    let large = network("lstm-l", 1).unwrap();
    assert_eq!(large.layers[0].shape.bound(Dim::K), 4000);
}

#[test]
fn all_benchmarks_present_with_paper_batches() {
    let nets = all_benchmarks();
    assert_eq!(nets.len(), 9);
    let get = |n: &str| nets.iter().find(|x| x.name == n).unwrap().batch;
    assert_eq!(get("alexnet"), 16);
    assert_eq!(get("vgg16"), 16);
    assert_eq!(get("lstm-m"), 1);
    assert_eq!(get("rhn"), 1);
    assert_eq!(get("mlp-l"), 128);
}

#[test]
fn unknown_network_is_none() {
    assert!(network("resnet-9000", 1).is_none());
}

#[test]
fn head_truncates_and_clamps() {
    let net = network("alexnet", 4).unwrap();
    let sub = net.head(3);
    assert_eq!(sub.layers.len(), 3);
    assert_eq!(sub.layers[0].name, net.layers[0].name);
    assert_eq!(sub.batch, net.batch);
    assert!(sub.name.contains("alexnet"));
    // n beyond the depth keeps everything
    assert_eq!(net.head(1000).layers.len(), net.layers.len());
}

#[test]
fn dedup_shapes_keeps_first_occurrences() {
    let net = network("lstm-m", 1).unwrap(); // 8 identical gate banks
    let unique = net.dedup_shapes();
    assert_eq!(unique.layers.len(), 1);
    assert_eq!(unique.layers[0].name, net.layers[0].name);
    assert_eq!(unique.name, net.name);
    // a mixed-shape net keeps every distinct shape in order
    let mlp = network("mlp-m", 128).unwrap();
    assert_eq!(mlp.dedup_shapes().layers.len(), mlp.layers.len());
}

#[test]
fn batch_scales_macs_linearly() {
    let m1 = network("alexnet", 1).unwrap().macs();
    let m16 = network("alexnet", 16).unwrap().macs();
    assert_eq!(m16, 16 * m1);
}
