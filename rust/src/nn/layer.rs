//! A single DNN layer as an instance of the seven-loop nest.

use crate::loopnest::{Shape, Tensor};

/// Layer kind — determines which loop bounds degenerate to 1 and how the
/// layer maps onto the Pallas kernels at the compute layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// Standard convolution.
    Conv,
    /// 1×1 convolution (channel reduction / expansion).
    Pointwise,
    /// Depthwise convolution: one filter per channel. Expressed in the
    /// seven-loop nest with `C = 1` and `K =` channel count (each output
    /// channel reads its own single input channel); the input-channel
    /// dimension rides on `K`, so input size uses `K` instead of `C`.
    Depthwise,
    /// Fully connected: only B, K, C loops.
    FullyConnected,
    /// One gate-bank matmul of an LSTM cell (timestep-batched FC).
    LstmGate,
}

/// One layer: a name, a kind, and the seven loop bounds.
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    /// Display name, e.g. `"CONV3"`.
    pub name: String,
    /// Kind (see [`LayerKind`]).
    pub kind: LayerKind,
    /// The loop-nest shape.
    pub shape: Shape,
}

impl Layer {
    /// Standard conv layer. `x`/`y` are *output* spatial sizes.
    #[allow(clippy::too_many_arguments)]
    pub fn conv(name: &str, b: u64, k: u64, c: u64, x: u64, y: u64, f: u64, stride: u32) -> Self {
        Layer {
            name: name.to_string(),
            kind: if f == 1 { LayerKind::Pointwise } else { LayerKind::Conv },
            shape: Shape::new(b, k, c, x, y, f, f, stride),
        }
    }

    /// Depthwise conv layer over `ch` channels (MobileNet).
    pub fn depthwise(name: &str, b: u64, ch: u64, x: u64, y: u64, f: u64, stride: u32) -> Self {
        Layer {
            name: name.to_string(),
            kind: LayerKind::Depthwise,
            shape: Shape::new(b, ch, 1, x, y, f, f, stride),
        }
    }

    /// Fully-connected layer: `c` inputs, `k` outputs, batch `b`.
    pub fn fc(name: &str, b: u64, k: u64, c: u64) -> Self {
        Layer {
            name: name.to_string(),
            kind: LayerKind::FullyConnected,
            shape: Shape::new(b, k, c, 1, 1, 1, 1, 1),
        }
    }

    /// One LSTM gate bank: `[b, e] @ [e, 4h]` (input) or `[b, h] @ [h, 4h]`
    /// (hidden) — both matmuls per cell are emitted as separate layers.
    pub fn lstm_gate(name: &str, b: u64, in_dim: u64, h: u64) -> Self {
        Layer {
            name: name.to_string(),
            kind: LayerKind::LstmGate,
            shape: Shape::new(b, 4 * h, in_dim, 1, 1, 1, 1, 1),
        }
    }

    /// MACs for this layer.
    pub fn macs(&self) -> u64 {
        self.shape.macs()
    }

    /// Total elements of one tensor (depthwise adjusts I to ride on K).
    pub fn tensor_elems(&self, t: Tensor) -> u64 {
        match (self.kind, t) {
            (LayerKind::Depthwise, Tensor::Input) => {
                // input channels == output channels (K); C is 1 in the nest
                self.shape.tensor_elems(Tensor::Input) * self.shape.bounds[1]
            }
            _ => self.shape.tensor_elems(t),
        }
    }

    /// True when the layer has meaningful weight reuse only through
    /// batching (FC-family) — the paper's "limited reuse" class.
    pub fn is_fc_family(&self) -> bool {
        matches!(self.kind, LayerKind::FullyConnected | LayerKind::LstmGate)
    }
}
