//! The paper's benchmark networks (§6.3): four CNNs, three recurrent
//! networks, two MLPs.
//!
//! Layer tables are encoded from the original papers. Notes:
//! - AlexNet's grouped CONV2/4/5 use the per-group input-channel counts
//!   (C = 48/192/192), matching the original network's MAC count (~724M
//!   per image) and the convention of Eyeriss and the Interstellar repo.
//! - LSTM-M / LSTM-L are one four-layer seq2seq timestep (Sutskever et
//!   al.) with embedding sizes 500 / 1000: two gate-bank matmuls per
//!   layer. RHN is the depth-10 Recurrent Highway Network (hidden 830).
//! - MLPs follow PRIME's topologies at batch 128.

use super::layer::Layer;

/// A named network: an ordered list of layers.
#[derive(Debug, Clone)]
pub struct Network {
    /// Display name ("alexnet", ...).
    pub name: String,
    /// Layers in execution order.
    pub layers: Vec<Layer>,
    /// The batch size the layers were instantiated with.
    pub batch: u64,
}

impl Network {
    /// Total MACs over all layers.
    pub fn macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// The first `n` layers (all of them when `n` exceeds the depth) —
    /// e.g. the AlexNet conv subset `netopt`'s equivalence tests sweep.
    pub fn head(&self, n: usize) -> Network {
        let n = n.min(self.layers.len());
        Network {
            name: format!("{}[..{n}]", self.name),
            layers: self.layers[..n].to_vec(),
            batch: self.batch,
        }
    }

    /// One layer per distinct `(bounds, stride)` shape, first-occurrence
    /// order. Bounds sweep time on very deep networks while keeping
    /// per-layer energies representative (repeated shapes share one
    /// search result anyway).
    pub fn dedup_shapes(&self) -> Network {
        let mut seen = std::collections::HashSet::new();
        Network {
            name: self.name.clone(),
            layers: self
                .layers
                .iter()
                .filter(|l| seen.insert((l.shape.bounds, l.shape.stride)))
                .cloned()
                .collect(),
            batch: self.batch,
        }
    }
}

/// Names of all nine benchmarks, in the paper's Figure 14 order.
pub fn network_names() -> Vec<&'static str> {
    vec![
        "alexnet",
        "vgg16",
        "googlenet",
        "mobilenet",
        "lstm-m",
        "lstm-l",
        "rhn",
        "mlp-m",
        "mlp-l",
    ]
}

/// Build a benchmark network by name with the given batch size.
/// Recognized names are those in [`network_names`].
pub fn network(name: &str, batch: u64) -> Option<Network> {
    let b = batch;
    let layers = match name {
        "alexnet" => alexnet(b),
        "vgg16" => vgg16(b),
        "googlenet" => googlenet(b),
        "mobilenet" => mobilenet(b),
        "lstm-m" => lstm(b, 500),
        "lstm-l" => lstm(b, 1000),
        "rhn" => rhn(b, 830, 10),
        "mlp-m" => mlp(b, &[784, 500, 250, 10]),
        "mlp-l" => mlp(b, &[784, 1500, 1000, 500, 10]),
        _ => return None,
    };
    Some(Network {
        name: name.to_string(),
        layers,
        batch,
    })
}

/// All nine benchmarks at the paper's default batch sizes
/// (CNNs 16, LSTMs/RHN 1, MLPs 128).
pub fn all_benchmarks() -> Vec<Network> {
    network_names()
        .into_iter()
        .map(|n| {
            let batch = if n.starts_with("lstm") || n == "rhn" {
                1
            } else if n.starts_with("mlp") {
                128
            } else {
                16
            };
            network(n, batch).unwrap()
        })
        .collect()
}

fn alexnet(b: u64) -> Vec<Layer> {
    vec![
        Layer::conv("CONV1", b, 96, 3, 55, 55, 11, 4),
        Layer::conv("CONV2", b, 256, 48, 27, 27, 5, 1),
        Layer::conv("CONV3", b, 384, 256, 13, 13, 3, 1),
        Layer::conv("CONV4", b, 384, 192, 13, 13, 3, 1),
        Layer::conv("CONV5", b, 256, 192, 13, 13, 3, 1),
        Layer::fc("FC6", b, 4096, 9216),
        Layer::fc("FC7", b, 4096, 4096),
        Layer::fc("FC8", b, 1000, 4096),
    ]
}

fn vgg16(b: u64) -> Vec<Layer> {
    let mut v = vec![
        Layer::conv("CONV1_1", b, 64, 3, 224, 224, 3, 1),
        Layer::conv("CONV1_2", b, 64, 64, 224, 224, 3, 1),
        Layer::conv("CONV2_1", b, 128, 64, 112, 112, 3, 1),
        Layer::conv("CONV2_2", b, 128, 128, 112, 112, 3, 1),
        Layer::conv("CONV3_1", b, 256, 128, 56, 56, 3, 1),
        Layer::conv("CONV3_2", b, 256, 256, 56, 56, 3, 1),
        Layer::conv("CONV3_3", b, 256, 256, 56, 56, 3, 1),
        Layer::conv("CONV4_1", b, 512, 256, 28, 28, 3, 1),
        Layer::conv("CONV4_2", b, 512, 512, 28, 28, 3, 1),
        Layer::conv("CONV4_3", b, 512, 512, 28, 28, 3, 1),
        Layer::conv("CONV5_1", b, 512, 512, 14, 14, 3, 1),
        Layer::conv("CONV5_2", b, 512, 512, 14, 14, 3, 1),
        Layer::conv("CONV5_3", b, 512, 512, 14, 14, 3, 1),
    ];
    v.push(Layer::fc("FC6", b, 4096, 25088));
    v.push(Layer::fc("FC7", b, 4096, 4096));
    v.push(Layer::fc("FC8", b, 1000, 4096));
    v
}

/// Inception v1 module: (name, spatial, c_in, n1x1, n3x3r, n3x3, n5x5r, n5x5, pool_proj).
const INCEPTION: [(&str, u64, u64, u64, u64, u64, u64, u64, u64); 9] = [
    ("3A", 28, 192, 64, 96, 128, 16, 32, 32),
    ("3B", 28, 256, 128, 128, 192, 32, 96, 64),
    ("4A", 14, 480, 192, 96, 208, 16, 48, 64),
    ("4B", 14, 512, 160, 112, 224, 24, 64, 64),
    ("4C", 14, 512, 128, 128, 256, 24, 64, 64),
    ("4D", 14, 512, 112, 144, 288, 32, 64, 64),
    ("4E", 14, 528, 256, 160, 320, 32, 128, 128),
    ("5A", 7, 832, 256, 160, 320, 32, 128, 128),
    ("5B", 7, 832, 384, 192, 384, 48, 128, 128),
];

fn googlenet(b: u64) -> Vec<Layer> {
    let mut v = vec![
        Layer::conv("CONV1", b, 64, 3, 112, 112, 7, 2),
        Layer::conv("CONV2R", b, 64, 64, 56, 56, 1, 1),
        Layer::conv("CONV2", b, 192, 64, 56, 56, 3, 1),
    ];
    for (name, s, cin, n1, n3r, n3, n5r, n5, pp) in INCEPTION {
        v.push(Layer::conv(&format!("{name}1"), b, n1, cin, s, s, 1, 1));
        v.push(Layer::conv(&format!("{name}3R"), b, n3r, cin, s, s, 1, 1));
        v.push(Layer::conv(&format!("{name}3"), b, n3, n3r, s, s, 3, 1));
        v.push(Layer::conv(&format!("{name}5R"), b, n5r, cin, s, s, 1, 1));
        v.push(Layer::conv(&format!("{name}5"), b, n5, n5r, s, s, 5, 1));
        v.push(Layer::conv(&format!("{name}PP"), b, pp, cin, s, s, 1, 1));
    }
    v.push(Layer::fc("FC", b, 1000, 1024));
    v
}

fn mobilenet(b: u64) -> Vec<Layer> {
    // (channels_in, channels_out, output_spatial, dw_stride)
    const BLOCKS: [(u64, u64, u64, u32); 13] = [
        (32, 64, 112, 1),
        (64, 128, 56, 2),
        (128, 128, 56, 1),
        (128, 256, 28, 2),
        (256, 256, 28, 1),
        (256, 512, 14, 2),
        (512, 512, 14, 1),
        (512, 512, 14, 1),
        (512, 512, 14, 1),
        (512, 512, 14, 1),
        (512, 512, 14, 1),
        (512, 1024, 7, 2),
        (1024, 1024, 7, 1),
    ];
    let mut v = vec![Layer::conv("CONV1", b, 32, 3, 112, 112, 3, 2)];
    for (i, (cin, cout, s, stride)) in BLOCKS.iter().enumerate() {
        v.push(Layer::depthwise(
            &format!("DW{}", i + 1),
            b,
            *cin,
            *s,
            *s,
            3,
            *stride,
        ));
        v.push(Layer::conv(&format!("PW{}", i + 1), b, *cout, *cin, *s, *s, 1, 1));
    }
    v.push(Layer::fc("FC", b, 1000, 1024));
    v
}

fn lstm(b: u64, e: u64) -> Vec<Layer> {
    // 4-layer seq2seq encoder timestep; hidden size == embedding size.
    let mut v = Vec::new();
    for l in 0..4 {
        v.push(Layer::lstm_gate(&format!("L{l}_IH"), b, e, e));
        v.push(Layer::lstm_gate(&format!("L{l}_HH"), b, e, e));
    }
    v
}

fn rhn(b: u64, h: u64, depth: u64) -> Vec<Layer> {
    // Recurrent Highway Network: depth micro-layers, each with H and T
    // transforms (2 matmuls of h x h); the first also takes the input.
    let mut v = vec![
        Layer::fc("IN_H", b, h, h),
        Layer::fc("IN_T", b, h, h),
    ];
    for d in 0..depth {
        v.push(Layer::fc(&format!("D{d}_H"), b, h, h));
        v.push(Layer::fc(&format!("D{d}_T"), b, h, h));
    }
    v
}

fn mlp(b: u64, widths: &[u64]) -> Vec<Layer> {
    widths
        .windows(2)
        .enumerate()
        .map(|(i, w)| Layer::fc(&format!("FC{}", i + 1), b, w[1], w[0]))
        .collect()
}
