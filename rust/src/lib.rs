//! # Interstellar
//!
//! A reproduction of *"Interstellar: Using Halide's Scheduling Language to
//! Analyze DNN Accelerators"* (Yang et al., ASPLOS 2020) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! The paper's insight: every dense DNN accelerator is a particular
//! transformation — blocking, reordering, spatial unrolling — of the
//! seven-level CONV loop nest, plus a hardware resource allocation. This
//! crate implements:
//!
//! - [`loopnest`] — the seven-dim loop-nest IR, blocking factors, tiling;
//! - [`nn`] — layer shapes and the paper's nine benchmark networks;
//! - [`arch`] — memory hierarchies, PE arrays, the paper's configurations;
//! - [`energy`] — the Table 3 access-energy cost model;
//! - [`dataflow`] — the `U | V` dataflow taxonomy with replication;
//! - [`xmodel`] — the analytical access-count / energy / performance model;
//! - [`engine`] — the staged, pruning-aware evaluation pipeline the
//!   search and all sweeps run on (footprint caches, divisor memoization,
//!   admissible partial bounds, branch-and-bound incumbents);
//! - [`sim`] — a trace-driven simulator that counts accesses exactly
//!   (the stand-in for the paper's post-synthesis validation, Fig 7);
//! - [`fastmap`] — the microsecond greedy heuristic mapper: the serving
//!   fast path (deadline remaps publish its plan immediately) and the
//!   scout that primes every exact search's incumbent without moving a
//!   single argmin bit;
//! - [`halide`] — the schedule DSL (`split`, `reorder`, `in_`/`compute_at`,
//!   `unroll`, `systolic`, `accelerate`) and its lowering;
//! - [`search`] — design-space enumeration and the efficient per-layer
//!   auto-optimizer;
//! - [`netopt`] — network-level resource co-optimization (§6.3: fix
//!   `C|K`, 4–16 size-ratio rule): architecture design-space generation
//!   and a cross-architecture branch-and-bound sharing one incumbent
//!   across the whole memory-hierarchy sweep;
//! - [`pareto`] — multi-objective frontier co-optimization: a dominance
//!   archive in `(energy, cycles)` with vector lower bounds, exact
//!   dominance-pruned frontiers over the same design spaces,
//!   shard-mergeable frontier checkpoints, and budget-aware plan
//!   selection for serving;
//! - [`orchestrator`] — distributed sweep fan-out: shard workers across
//!   OS processes with work stealing over sub-sharded grids and live
//!   incumbent/frontier bound streaming through an append-only bounds
//!   file, merging back to bit-identical winners and frontiers;
//! - [`fleet`] — the production serving fleet: N serving workers (OS
//!   processes or threads) over interleaved trace shards, per-worker mix
//!   windows streamed into an append-only `mix.jsonl`, a controller-side
//!   drift signal driving one async remapper whose plans broadcast to
//!   every worker via `plans.jsonl`, crash + rejoin with plan
//!   re-adoption, and a deterministic scenario/load-test harness;
//! - [`bench`] — the measurement backbone: every perf gate's metrics
//!   appended to a torn-write-safe `bench_history.jsonl`, with
//!   trajectory views and the median/MAD regression rule behind the
//!   `bench-report --check` CI gate;
//! - [`telemetry`] — zero-cost-when-disabled structured tracing: spans,
//!   counters, gauges, and mergeable log-bucketed latency histograms
//!   into an append-only `trace.jsonl` shared across fleet and
//!   orchestrator processes, explained by the `trace-report` CLI;
//! - [`runtime`] — PJRT CPU executor for the AOT-compiled JAX/Pallas
//!   artifacts (the request-path compute; Python is build-time only);
//! - [`coordinator`] — CLI, sweep orchestration, reports.
//!
//! See `ARCHITECTURE.md` for the layer map and subsystem tours, and
//! `ROADMAP.md` for the experiment plan and measured milestones.

pub mod arch;
pub mod bench;
pub mod coordinator;
pub mod dataflow;
pub mod energy;
pub mod engine;
pub mod fastmap;
pub mod fleet;
pub mod halide;
pub mod loopnest;
pub mod netopt;
pub mod nn;
pub mod orchestrator;
pub mod pareto;
pub mod runtime;
pub mod search;
pub mod sim;
pub mod telemetry;
pub mod util;
pub mod xmodel;
