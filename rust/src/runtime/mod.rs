//! PJRT runtime: load the AOT-compiled HLO artifacts (produced once by
//! `python/compile/aot.py`) and execute them on the CPU PJRT client.
//! This is the request-path compute engine — Python never runs here.

mod manifest;

pub use manifest::{Manifest, ManifestEntry, TensorSpec};

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// A loaded, compiled artifact registry.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Load every artifact listed in `<dir>/manifest.txt`, compiling each
    /// HLO text module on the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&dir.join("manifest.txt"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt client: {e:?}"))?;
        let mut executables = HashMap::new();
        for entry in &manifest.entries {
            let path = dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", entry.name))?;
            executables.insert(entry.name.clone(), exe);
        }
        Ok(Runtime {
            client,
            manifest,
            executables,
        })
    }

    /// Artifact names available.
    pub fn names(&self) -> Vec<&str> {
        self.manifest.entries.iter().map(|e| e.name.as_str()).collect()
    }

    /// PJRT platform string (for logs).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Manifest entry for an artifact.
    pub fn entry(&self, name: &str) -> Option<&ManifestEntry> {
        self.manifest.entries.iter().find(|e| e.name == name)
    }

    /// Execute an artifact on f32 input buffers (shapes validated against
    /// the manifest). Returns one `Vec<f32>` per output.
    pub fn execute_f32(&self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let entry = self
            .entry(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        if inputs.len() != entry.inputs.len() {
            bail!(
                "{name}: {} inputs given, manifest wants {}",
                inputs.len(),
                entry.inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, spec) in inputs.iter().zip(entry.inputs.iter()) {
            if data.len() as u64 != spec.elems() {
                bail!(
                    "{name}: input has {} elems, manifest wants {} ({:?})",
                    data.len(),
                    spec.elems(),
                    spec.dims
                );
            }
            let lit = xla::Literal::vec1(data)
                .reshape(&spec.dims)
                .map_err(|e| anyhow!("reshape: {e:?}"))?;
            literals.push(lit);
        }
        let exe = self.executables.get(name).expect("compiled with manifest");
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // lowered with return_tuple=True: unpack the tuple
        let parts = result.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))?;
        let mut out = Vec::with_capacity(parts.len());
        for (i, p) in parts.into_iter().enumerate() {
            let v = p
                .to_vec::<f32>()
                .map_err(|e| anyhow!("{name} output {i} to_vec: {e:?}"))?;
            out.push(v);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn runtime() -> Option<Runtime> {
        let dir = artifacts_dir();
        if !dir.join("manifest.txt").exists() {
            eprintln!("artifacts not built; skipping runtime test");
            return None;
        }
        Some(Runtime::load(&dir).expect("load artifacts"))
    }

    #[test]
    fn loads_all_artifacts() {
        let Some(rt) = runtime() else { return };
        let names = rt.names();
        for expect in ["conv3x3", "conv1x1", "fc", "lstm_cell", "conv_chain"] {
            assert!(names.contains(&expect), "missing {expect}");
        }
        assert_eq!(rt.platform(), "cpu");
    }

    #[test]
    fn fc_artifact_matches_cpu_matmul() {
        let Some(rt) = runtime() else { return };
        let entry = rt.entry("fc").unwrap().clone();
        let (m, c) = (entry.inputs[0].dims[0] as usize, entry.inputs[0].dims[1] as usize);
        let n = entry.inputs[1].dims[1] as usize;
        let mut rng = crate::util::XorShift::new(5);
        let a = rng.f32_vec(m * c);
        let b = rng.f32_vec(c * n);
        let out = rt.execute_f32("fc", &[a.clone(), b.clone()]).unwrap();
        assert_eq!(out.len(), 1);
        // reference matmul
        let mut want = vec![0.0f32; m * n];
        for i in 0..m {
            for k in 0..c {
                let av = a[i * c + k];
                for j in 0..n {
                    want[i * n + j] += av * b[k * n + j];
                }
            }
        }
        for (g, w) in out[0].iter().zip(want.iter()) {
            assert!((g - w).abs() < 1e-3 * w.abs().max(1.0), "{g} vs {w}");
        }
    }

    #[test]
    fn conv3x3_artifact_matches_trace_simulator() {
        // The cross-layer check: PJRT-executed JAX/Pallas conv ==
        // the Rust functional simulator on the same data.
        let Some(rt) = runtime() else { return };
        let entry = rt.entry("conv3x3").unwrap().clone();
        // manifest: input [2,10,10,16] NHWC, weight [3,3,16,32] HWIO
        let (b, xh, _yh, c) = (
            entry.inputs[0].dims[0] as u64,
            entry.inputs[0].dims[1] as u64,
            entry.inputs[0].dims[2] as u64,
            entry.inputs[0].dims[3] as u64,
        );
        let (fx, fy, _, k) = (
            entry.inputs[1].dims[0] as u64,
            entry.inputs[1].dims[1] as u64,
            entry.inputs[1].dims[2] as u64,
            entry.inputs[1].dims[3] as u64,
        );
        let x = xh - fx + 1;
        let shape = crate::loopnest::Shape::new(b, k, c, x, x, fx, fy, 1);
        let data = crate::sim::ConvData::random(shape, 777);

        // repack sim layouts (BCHW-ish) into the artifact's NHWC / HWIO
        let ix = shape.input_x();
        let mut inp = vec![0.0f32; (b * ix * ix * c) as usize];
        for bb in 0..b {
            for cc in 0..c {
                for i in 0..ix {
                    for j in 0..ix {
                        let src = (((bb * c + cc) * ix + i) * ix + j) as usize;
                        let dst = (((bb * ix + i) * ix + j) * c + cc) as usize;
                        inp[dst] = data.input[src];
                    }
                }
            }
        }
        let mut w = vec![0.0f32; (fx * fy * c * k) as usize];
        for kk in 0..k {
            for cc in 0..c {
                for i in 0..fx {
                    for j in 0..fy {
                        let src = (((kk * c + cc) * fx + i) * fy + j) as usize;
                        let dst = (((i * fy + j) * c + cc) * k + kk) as usize;
                        w[dst] = data.weight[src];
                    }
                }
            }
        }

        let out = rt.execute_f32("conv3x3", &[inp, w]).unwrap();
        let want = crate::sim::reference_conv(&data); // [B][K][X][Y]
        // artifact output is NHWC [B][X][Y][K]
        let mut max_err = 0.0f32;
        for bb in 0..b {
            for kk in 0..k {
                for i in 0..x {
                    for j in 0..x {
                        let g = out[0][(((bb * x + i) * x + j) * k + kk) as usize];
                        let e = want[(((bb * k + kk) * x + i) * x + j) as usize];
                        max_err = max_err.max((g - e).abs());
                    }
                }
            }
        }
        assert!(max_err < 1e-2, "max abs err {max_err}");
    }

    #[test]
    fn execute_rejects_bad_shapes() {
        let Some(rt) = runtime() else { return };
        assert!(rt.execute_f32("fc", &[vec![0.0; 3]]).is_err());
        assert!(rt.execute_f32("nonexistent", &[]).is_err());
    }
}
