//! PJRT runtime: load the AOT-compiled HLO artifacts (produced once by
//! `python/compile/aot.py`) and execute them on the CPU PJRT client.
//! This is the request-path compute engine — Python never runs here.
//!
//! The PJRT client itself lives behind the `pjrt` cargo feature because
//! it needs the vendored `xla` crate closure, which is not part of the
//! dependency-free default build. Without the feature, [`Runtime::load`]
//! fails with a clear message and everything else in the crate (model,
//! simulator, search, experiments) works normally; the serving paths and
//! benches skip cleanly when no artifacts are present.

mod manifest;

pub use manifest::{Manifest, ManifestEntry, TensorSpec};

use std::path::Path;

#[cfg(feature = "pjrt")]
pub use pjrt_impl::Runtime;

#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use super::{Manifest, ManifestEntry};
    use anyhow::{anyhow, bail, Result};
    use std::collections::HashMap;
    use std::path::Path;

    /// A loaded, compiled artifact registry.
    pub struct Runtime {
        client: xla::PjRtClient,
        manifest: Manifest,
        executables: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl Runtime {
        /// Load every artifact listed in `<dir>/manifest.txt`, compiling
        /// each HLO text module on the PJRT CPU client.
        pub fn load(dir: &Path) -> Result<Self> {
            let manifest = Manifest::load(&dir.join("manifest.txt"))
                .map_err(|e| anyhow!("loading manifest from {}: {e}", dir.display()))?;
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt client: {e:?}"))?;
            let mut executables = HashMap::new();
            for entry in &manifest.entries {
                let path = dir.join(&entry.file);
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compile {}: {e:?}", entry.name))?;
                executables.insert(entry.name.clone(), exe);
            }
            Ok(Runtime {
                client,
                manifest,
                executables,
            })
        }

        /// Artifact names available.
        pub fn names(&self) -> Vec<&str> {
            self.manifest.entries.iter().map(|e| e.name.as_str()).collect()
        }

        /// PJRT platform string (for logs).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Manifest entry for an artifact.
        pub fn entry(&self, name: &str) -> Option<&ManifestEntry> {
            self.manifest.entries.iter().find(|e| e.name == name)
        }

        /// Execute an artifact on f32 input buffers (shapes validated
        /// against the manifest). Returns one `Vec<f32>` per output.
        pub fn execute_f32(&self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            let entry = self
                .entry(name)
                .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
            if inputs.len() != entry.inputs.len() {
                bail!(
                    "{name}: {} inputs given, manifest wants {}",
                    inputs.len(),
                    entry.inputs.len()
                );
            }
            let mut literals = Vec::with_capacity(inputs.len());
            for (data, spec) in inputs.iter().zip(entry.inputs.iter()) {
                if data.len() as u64 != spec.elems() {
                    bail!(
                        "{name}: input has {} elems, manifest wants {} ({:?})",
                        data.len(),
                        spec.elems(),
                        spec.dims
                    );
                }
                let lit = xla::Literal::vec1(data)
                    .reshape(&spec.dims)
                    .map_err(|e| anyhow!("reshape: {e:?}"))?;
                literals.push(lit);
            }
            let exe = self.executables.get(name).expect("compiled with manifest");
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result: {e:?}"))?;
            // lowered with return_tuple=True: unpack the tuple
            let parts = result.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))?;
            let mut out = Vec::with_capacity(parts.len());
            for (i, p) in parts.into_iter().enumerate() {
                let v = p
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("{name} output {i} to_vec: {e:?}"))?;
                out.push(v);
            }
            Ok(out)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use super::ManifestEntry;
    use anyhow::{anyhow, bail, Result};
    use std::path::Path;

    /// Stub runtime used when the crate is built without the `pjrt`
    /// feature: loading always fails with an explanatory error, so every
    /// serving path degrades to a clean "artifacts unavailable" result.
    pub struct Runtime {
        _private: (),
    }

    impl Runtime {
        /// Always fails: the PJRT client is not compiled in.
        pub fn load(dir: &Path) -> Result<Self> {
            bail!(
                "cannot load artifacts from {}: interstellar was built without the \
                 `pjrt` feature (the vendored xla crate); rebuild with \
                 `--features pjrt` to enable the PJRT runtime",
                dir.display()
            );
        }

        /// Artifact names available (stub: none).
        pub fn names(&self) -> Vec<&str> {
            Vec::new()
        }

        /// PJRT platform string (stub).
        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        /// Manifest entry for an artifact (stub: none).
        pub fn entry(&self, _name: &str) -> Option<&ManifestEntry> {
            None
        }

        /// Execute an artifact (stub: always fails).
        pub fn execute_f32(&self, name: &str, _inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            Err(anyhow!("artifact {name} unavailable: built without `pjrt`"))
        }
    }
}

/// True when an artifact registry looks present on disk (used by benches
/// and the e2e example to skip cleanly).
pub fn artifacts_present(dir: &Path) -> bool {
    dir.join("manifest.txt").exists()
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn runtime() -> Option<Runtime> {
        let dir = artifacts_dir();
        if !dir.join("manifest.txt").exists() {
            eprintln!("artifacts not built; skipping runtime test");
            return None;
        }
        Some(Runtime::load(&dir).expect("load artifacts"))
    }

    #[test]
    fn loads_all_artifacts() {
        let Some(rt) = runtime() else { return };
        let names = rt.names();
        for expect in ["conv3x3", "conv1x1", "fc", "lstm_cell", "conv_chain"] {
            assert!(names.contains(&expect), "missing {expect}");
        }
        assert_eq!(rt.platform(), "cpu");
    }

    #[test]
    fn fc_artifact_matches_cpu_matmul() {
        let Some(rt) = runtime() else { return };
        let entry = rt.entry("fc").unwrap().clone();
        let (m, c) = (entry.inputs[0].dims[0] as usize, entry.inputs[0].dims[1] as usize);
        let n = entry.inputs[1].dims[1] as usize;
        let mut rng = crate::util::XorShift::new(5);
        let a = rng.f32_vec(m * c);
        let b = rng.f32_vec(c * n);
        let out = rt.execute_f32("fc", &[a.clone(), b.clone()]).unwrap();
        assert_eq!(out.len(), 1);
        // reference matmul
        let mut want = vec![0.0f32; m * n];
        for i in 0..m {
            for k in 0..c {
                let av = a[i * c + k];
                for j in 0..n {
                    want[i * n + j] += av * b[k * n + j];
                }
            }
        }
        for (g, w) in out[0].iter().zip(want.iter()) {
            assert!((g - w).abs() < 1e-3 * w.abs().max(1.0), "{g} vs {w}");
        }
    }

    #[test]
    fn execute_rejects_bad_shapes() {
        let Some(rt) = runtime() else { return };
        assert!(rt.execute_f32("fc", &[vec![0.0; 3]]).is_err());
        assert!(rt.execute_f32("nonexistent", &[]).is_err());
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_load_reports_missing_feature() {
        let err = Runtime::load(Path::new("artifacts"))
            .err()
            .expect("stub load must fail");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[test]
    fn artifacts_present_checks_manifest() {
        assert!(!artifacts_present(Path::new("/definitely/not/there")));
    }
}
