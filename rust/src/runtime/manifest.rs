//! Plain-text artifact manifest parser (`manifest.txt`, one line per
//! artifact; format written by `python/compile/aot.py`):
//!
//! ```text
//! name=fc file=fc.hlo.txt inputs=f32[8,64];f32[64,32] outputs=f32[8,32]
//! ```

use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// One tensor's dtype + dims.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// Element type (always "f32" in this project).
    pub dtype: String,
    /// Dimensions.
    pub dims: Vec<i64>,
}

impl TensorSpec {
    /// Parse `"f32[8,64]"` (scalar: `"f32[]"`).
    pub fn parse(s: &str) -> Result<TensorSpec> {
        let open = s.find('[').ok_or_else(|| anyhow!("no [ in {s}"))?;
        if !s.ends_with(']') {
            bail!("no closing ] in {s}");
        }
        let dtype = s[..open].to_string();
        let body = &s[open + 1..s.len() - 1];
        let dims = if body.is_empty() {
            Vec::new()
        } else {
            body.split(',')
                .map(|d| d.trim().parse::<i64>().context("dim"))
                .collect::<Result<Vec<_>>>()?
        };
        Ok(TensorSpec { dtype, dims })
    }

    /// Total element count.
    pub fn elems(&self) -> u64 {
        self.dims.iter().map(|&d| d as u64).product()
    }
}

/// One artifact line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestEntry {
    /// Artifact name ("conv3x3").
    pub name: String,
    /// HLO text file name relative to the manifest.
    pub file: String,
    /// Input tensor specs in call order.
    pub inputs: Vec<TensorSpec>,
    /// Output tensor specs.
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    /// All entries, in file order.
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    /// Parse a manifest from text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut entries = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut name = None;
            let mut file = None;
            let mut inputs = None;
            let mut outputs = None;
            for tok in line.split_whitespace() {
                let (k, v) = tok
                    .split_once('=')
                    .ok_or_else(|| anyhow!("line {}: bad token {tok}", ln + 1))?;
                match k {
                    "name" => name = Some(v.to_string()),
                    "file" => file = Some(v.to_string()),
                    "inputs" => inputs = Some(parse_specs(v)?),
                    "outputs" => outputs = Some(parse_specs(v)?),
                    other => bail!("line {}: unknown key {other}", ln + 1),
                }
            }
            entries.push(ManifestEntry {
                name: name.ok_or_else(|| anyhow!("line {}: missing name", ln + 1))?,
                file: file.ok_or_else(|| anyhow!("line {}: missing file", ln + 1))?,
                inputs: inputs.ok_or_else(|| anyhow!("line {}: missing inputs", ln + 1))?,
                outputs: outputs.ok_or_else(|| anyhow!("line {}: missing outputs", ln + 1))?,
            });
        }
        Ok(Manifest { entries })
    }

    /// Load and parse from a file.
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }
}

fn parse_specs(s: &str) -> Result<Vec<TensorSpec>> {
    s.split(';').map(TensorSpec::parse).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_spec() {
        let t = TensorSpec::parse("f32[8,64]").unwrap();
        assert_eq!(t.dtype, "f32");
        assert_eq!(t.dims, vec![8, 64]);
        assert_eq!(t.elems(), 512);
        let s = TensorSpec::parse("f32[]").unwrap();
        assert!(s.dims.is_empty());
        assert_eq!(s.elems(), 1);
    }

    #[test]
    fn parse_spec_rejects_garbage() {
        assert!(TensorSpec::parse("f32").is_err());
        assert!(TensorSpec::parse("f32[1,2").is_err());
        assert!(TensorSpec::parse("f32[a]").is_err());
    }

    #[test]
    fn parse_manifest_line() {
        let m = Manifest::parse(
            "name=fc file=fc.hlo.txt inputs=f32[8,64];f32[64,32] outputs=f32[8,32]\n",
        )
        .unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = &m.entries[0];
        assert_eq!(e.name, "fc");
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.outputs[0].dims, vec![8, 32]);
    }

    #[test]
    fn parse_skips_comments_and_blanks() {
        let m = Manifest::parse("# hello\n\nname=a file=a.hlo.txt inputs=f32[1] outputs=f32[1]\n")
            .unwrap();
        assert_eq!(m.entries.len(), 1);
    }

    #[test]
    fn parse_rejects_missing_fields() {
        assert!(Manifest::parse("name=a file=b.hlo.txt inputs=f32[1]").is_err());
        assert!(Manifest::parse("name=a inputs=f32[1] outputs=f32[1]").is_err());
        assert!(Manifest::parse("bogus line").is_err());
    }
}
