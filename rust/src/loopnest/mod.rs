//! The seven-dimension loop-nest IR (Algorithm 1 of the paper).
//!
//! Every dense DNN layer is the nest
//! `for b,k,c,y,x,fy,fx: O[b][k][x][y] += I[b][c][x+fx][y+fy] * W[k][c][fx][fy]`
//! and every accelerator is a blocking / reordering / spatial-unrolling of
//! it. This module defines the dims, tensors, per-level blocking factors,
//! per-level loop orders, and tile-size arithmetic (with the input halo).

mod blocking;
mod dims;

pub use blocking::{Blocking, LevelOrder, Mapping, Shape};
pub use dims::{Dim, Tensor, ALL_DIMS, ALL_TENSORS, NDIMS};

#[cfg(test)]
mod tests;
