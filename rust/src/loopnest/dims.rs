//! Loop dimensions and tensors of the seven-level CONV nest.

/// The seven loop dimensions of Algorithm 1.
///
/// `B` batch, `K` output channels, `C` input channels, `X`/`Y` output
/// spatial, `FX`/`FY` filter spatial.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Dim {
    /// Batch.
    B,
    /// Output channels (filters).
    K,
    /// Input channels.
    C,
    /// Output width.
    X,
    /// Output height.
    Y,
    /// Filter width.
    FX,
    /// Filter height.
    FY,
}

/// Number of loop dimensions.
pub const NDIMS: usize = 7;

/// All dims in canonical (index) order: B, K, C, X, Y, FX, FY.
pub const ALL_DIMS: [Dim; NDIMS] = [Dim::B, Dim::K, Dim::C, Dim::X, Dim::Y, Dim::FX, Dim::FY];

impl Dim {
    /// Canonical index (position in [`ALL_DIMS`]).
    #[inline]
    pub fn idx(self) -> usize {
        match self {
            Dim::B => 0,
            Dim::K => 1,
            Dim::C => 2,
            Dim::X => 3,
            Dim::Y => 4,
            Dim::FX => 5,
            Dim::FY => 6,
        }
    }

    /// Dim from canonical index.
    pub fn from_idx(i: usize) -> Dim {
        ALL_DIMS[i]
    }

    /// Short name used in dataflow syntax ("C|K", "FY|Y").
    pub fn name(self) -> &'static str {
        match self {
            Dim::B => "B",
            Dim::K => "K",
            Dim::C => "C",
            Dim::X => "X",
            Dim::Y => "Y",
            Dim::FX => "FX",
            Dim::FY => "FY",
        }
    }

    /// Parse a short name (case-insensitive).
    pub fn parse(s: &str) -> Option<Dim> {
        match s.to_ascii_uppercase().as_str() {
            "B" => Some(Dim::B),
            "K" => Some(Dim::K),
            "C" => Some(Dim::C),
            "X" => Some(Dim::X),
            "Y" => Some(Dim::Y),
            "FX" => Some(Dim::FX),
            "FY" => Some(Dim::FY),
            _ => None,
        }
    }

    /// Is this a reduction dim (irrelevant to the output tensor)?
    #[inline]
    pub fn is_reduction(self) -> bool {
        matches!(self, Dim::C | Dim::FX | Dim::FY)
    }
}

impl std::fmt::Display for Dim {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The three tensors of the CONV nest.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Tensor {
    /// Input feature maps `I[b][c][x+fx][y+fy]`.
    Input,
    /// Weights `W[k][c][fx][fy]`.
    Weight,
    /// Output feature maps `O[b][k][x][y]`.
    Output,
}

/// All tensors, canonical order.
pub const ALL_TENSORS: [Tensor; 3] = [Tensor::Input, Tensor::Weight, Tensor::Output];

impl Tensor {
    /// Canonical index.
    #[inline]
    pub fn idx(self) -> usize {
        match self {
            Tensor::Input => 0,
            Tensor::Weight => 1,
            Tensor::Output => 2,
        }
    }

    /// Short label for reports.
    pub fn name(self) -> &'static str {
        match self {
            Tensor::Input => "I",
            Tensor::Weight => "W",
            Tensor::Output => "O",
        }
    }

    /// Is `d` an index dimension of this tensor?
    ///
    /// `X`/`Y` count as relevant to the input (via the `x+fx` halo);
    /// reduction dims are irrelevant to the output.
    #[inline]
    pub fn relevant(self, d: Dim) -> bool {
        match self {
            Tensor::Input => matches!(d, Dim::B | Dim::C | Dim::X | Dim::Y | Dim::FX | Dim::FY),
            Tensor::Weight => matches!(d, Dim::K | Dim::C | Dim::FX | Dim::FY),
            Tensor::Output => matches!(d, Dim::B | Dim::K | Dim::X | Dim::Y),
        }
    }
}

impl std::fmt::Display for Tensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}
