//! Blocking factors, per-level loop orders, and the [`Mapping`] — a fully
//! scheduled loop nest (the paper's "loop blocking + dataflow" pair).

use super::dims::{Dim, Tensor, ALL_DIMS, NDIMS};

/// The seven loop bounds of one layer plus its spatial stride.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shape {
    /// Bounds in canonical dim order `[B, K, C, X, Y, FX, FY]`.
    pub bounds: [u64; NDIMS],
    /// Spatial stride (input step per output pixel).
    pub stride: u32,
}

impl Shape {
    /// Construct from named bounds.
    #[allow(clippy::too_many_arguments)]
    pub fn new(b: u64, k: u64, c: u64, x: u64, y: u64, fx: u64, fy: u64, stride: u32) -> Self {
        Shape {
            bounds: [b, k, c, x, y, fx, fy],
            stride,
        }
    }

    /// Bound of one dim.
    #[inline]
    pub fn bound(&self, d: Dim) -> u64 {
        self.bounds[d.idx()]
    }

    /// Total multiply-accumulate operations.
    pub fn macs(&self) -> u64 {
        self.bounds.iter().product()
    }

    /// Input width in elements: `(X-1)*stride + FX`.
    pub fn input_x(&self) -> u64 {
        (self.bound(Dim::X) - 1) * self.stride as u64 + self.bound(Dim::FX)
    }

    /// Input height in elements: `(Y-1)*stride + FY`.
    pub fn input_y(&self) -> u64 {
        (self.bound(Dim::Y) - 1) * self.stride as u64 + self.bound(Dim::FY)
    }

    /// Total elements of one tensor.
    pub fn tensor_elems(&self, t: Tensor) -> u64 {
        match t {
            Tensor::Weight => {
                self.bound(Dim::K) * self.bound(Dim::C) * self.bound(Dim::FX) * self.bound(Dim::FY)
            }
            Tensor::Output => {
                self.bound(Dim::B) * self.bound(Dim::K) * self.bound(Dim::X) * self.bound(Dim::Y)
            }
            Tensor::Input => {
                self.bound(Dim::B) * self.bound(Dim::C) * self.input_x() * self.input_y()
            }
        }
    }
}

/// Intra-level loop order: all seven dims, **innermost first**.
///
/// The order decides stationarity: a dim irrelevant to tensor `t` that is
/// nested inside every `t`-relevant dim (with factor > 1) at this level
/// does not force refetches of `t`'s tile below this level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LevelOrder(pub [Dim; NDIMS]);

impl LevelOrder {
    /// Canonical order (FX,FY innermost ... B outermost) — a sensible
    /// weight-stationary-ish default.
    pub fn canonical() -> Self {
        LevelOrder([Dim::FX, Dim::FY, Dim::C, Dim::X, Dim::Y, Dim::K, Dim::B])
    }

    /// An order that keeps `t` stationary at this level: all dims
    /// irrelevant to `t` innermost (so iterating them does not evict `t`'s
    /// tile below), relevant dims outermost.
    pub fn stationary_for(t: Tensor) -> Self {
        let mut dims = [Dim::B; NDIMS];
        let mut i = 0;
        for d in ALL_DIMS {
            if !t.relevant(d) {
                dims[i] = d;
                i += 1;
            }
        }
        for d in ALL_DIMS {
            if t.relevant(d) {
                dims[i] = d;
                i += 1;
            }
        }
        LevelOrder(dims)
    }

    /// Validate: a permutation of all seven dims.
    pub fn is_valid(&self) -> bool {
        let mut seen = [false; NDIMS];
        for d in self.0 {
            if seen[d.idx()] {
                return false;
            }
            seen[d.idx()] = true;
        }
        true
    }

    /// Position of a dim (0 = innermost).
    pub fn pos(&self, d: Dim) -> usize {
        self.0.iter().position(|&x| x == d).unwrap()
    }
}

/// Per-level temporal blocking factors.
///
/// `factors[level][dim]`; level 0 is the innermost storage level (RF),
/// the last level is DRAM. The product over levels of `factors[_][d]`
/// times the spatial factor of `d` must equal the layer bound of `d`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Blocking {
    /// `factors[level][dim_idx]`.
    pub factors: Vec<[u64; NDIMS]>,
}

impl Blocking {
    /// All-ones blocking with `levels` levels (everything at DRAM level 0
    /// iteration... i.e. no blocking yet).
    pub fn ones(levels: usize) -> Self {
        Blocking {
            factors: vec![[1; NDIMS]; levels],
        }
    }

    /// Number of temporal levels.
    pub fn levels(&self) -> usize {
        self.factors.len()
    }

    /// Factor of `d` at `level`.
    #[inline]
    pub fn factor(&self, level: usize, d: Dim) -> u64 {
        self.factors[level][d.idx()]
    }

    /// Set a factor.
    pub fn set(&mut self, level: usize, d: Dim, f: u64) {
        self.factors[level][d.idx()] = f;
    }
}

/// A fully scheduled loop nest: shape + temporal blocking + per-level
/// orders + spatial unrolling position.
///
/// Hierarchy layout (innermost → outermost):
/// temporal levels `0 .. spatial_at` are **per-PE** (register files);
/// the PE array's spatial unrolling sits between `spatial_at - 1` and
/// `spatial_at`; temporal levels `spatial_at ..` are **shared**
/// (SRAM buffers, then DRAM last).
#[derive(Debug, Clone, PartialEq)]
pub struct Mapping {
    /// The layer being scheduled.
    pub shape: Shape,
    /// Temporal blocking factors (innermost level first, DRAM last).
    pub blocking: Blocking,
    /// Intra-level loop orders, one per temporal level.
    pub orders: Vec<LevelOrder>,
    /// Spatially unrolled factors per dim (the dataflow extents).
    pub spatial: [u64; NDIMS],
    /// Index of the first *shared* temporal level (the array sits just
    /// below it). Also the number of per-PE register levels.
    pub spatial_at: usize,
}

impl Mapping {
    /// A trivial mapping: everything iterated at DRAM with `rf_levels`
    /// per-PE levels and `shared_levels` shared levels, no unrolling.
    pub fn trivial(shape: Shape, rf_levels: usize, shared_levels: usize) -> Self {
        let levels = rf_levels + shared_levels;
        let mut blocking = Blocking::ones(levels);
        // all iteration at the outermost (DRAM) level
        for d in ALL_DIMS {
            blocking.set(levels - 1, d, shape.bound(d));
        }
        Mapping {
            shape,
            blocking,
            orders: vec![LevelOrder::canonical(); levels],
            spatial: [1; NDIMS],
            spatial_at: rf_levels,
        }
    }

    /// Number of temporal levels.
    pub fn levels(&self) -> usize {
        self.blocking.levels()
    }

    /// Total PEs used (product of spatial factors).
    pub fn pe_count(&self) -> u64 {
        self.spatial.iter().product()
    }

    /// Check factorization: per dim, (Π temporal factors) × spatial == bound.
    pub fn validate(&self) -> Result<(), String> {
        if self.orders.len() != self.blocking.levels() {
            return Err(format!(
                "orders ({}) != levels ({})",
                self.orders.len(),
                self.blocking.levels()
            ));
        }
        if self.spatial_at == 0 || self.spatial_at > self.blocking.levels() {
            return Err(format!("spatial_at {} out of range", self.spatial_at));
        }
        for o in &self.orders {
            if !o.is_valid() {
                return Err("invalid level order (not a permutation)".into());
            }
        }
        for d in ALL_DIMS {
            let prod: u64 = (0..self.blocking.levels())
                .map(|l| self.blocking.factor(l, d))
                .product::<u64>()
                * self.spatial[d.idx()];
            if prod != self.shape.bound(d) {
                return Err(format!(
                    "dim {}: factors product {} != bound {}",
                    d,
                    prod,
                    self.shape.bound(d)
                ));
            }
        }
        Ok(())
    }

    /// Cumulative bound of dim `d` visible at temporal level `level`
    /// (inclusive): per-PE below `spatial_at`, aggregate (× spatial) at or
    /// above it.
    pub fn cum(&self, level: usize, d: Dim) -> u64 {
        let mut p: u64 = (0..=level).map(|l| self.blocking.factor(l, d)).product();
        if level >= self.spatial_at {
            p *= self.spatial[d.idx()];
        }
        p
    }

    /// Cumulative bound including the spatial factor regardless of level —
    /// the "unique data across the whole array" view used for shared-level
    /// access counting.
    pub fn cum_with_spatial(&self, level: usize, d: Dim) -> u64 {
        let p: u64 = (0..=level).map(|l| self.blocking.factor(l, d)).product();
        p * self.spatial[d.idx()]
    }

    /// Tile size (elements) of tensor `t` held at temporal level `level`.
    ///
    /// For levels below `spatial_at` this is the per-PE tile; at or above,
    /// the aggregate tile across the array. Input tiles use halo
    /// arithmetic: `ix = (cx-1)*stride + cfx`.
    pub fn tile_elems(&self, t: Tensor, level: usize) -> u64 {
        let c = |d: Dim| self.cum(level, d);
        match t {
            Tensor::Weight => c(Dim::K) * c(Dim::C) * c(Dim::FX) * c(Dim::FY),
            Tensor::Output => c(Dim::B) * c(Dim::K) * c(Dim::X) * c(Dim::Y),
            Tensor::Input => {
                let ix = (c(Dim::X) - 1) * self.shape.stride as u64 + c(Dim::FX);
                let iy = (c(Dim::Y) - 1) * self.shape.stride as u64 + c(Dim::FY);
                c(Dim::B) * c(Dim::C) * ix.min(self.shape.input_x()) * iy.min(self.shape.input_y())
            }
        }
    }

    /// Unique elements of `t` needed by the whole array for one pass of
    /// temporal level `level` (i.e. `tile_elems` but always counting the
    /// spatial extent, with multicast dedup along `t`-irrelevant spatial
    /// dims).
    pub fn tile_elems_array(&self, t: Tensor, level: usize) -> u64 {
        let c = |d: Dim| {
            let mut p: u64 = (0..=level).map(|l| self.blocking.factor(l, d)).product();
            if level >= self.spatial_at || t.relevant(d) {
                p *= self.spatial[d.idx()];
            }
            p
        };
        match t {
            Tensor::Weight => c(Dim::K) * c(Dim::C) * c(Dim::FX) * c(Dim::FY),
            Tensor::Output => c(Dim::B) * c(Dim::K) * c(Dim::X) * c(Dim::Y),
            Tensor::Input => {
                let ix = (c(Dim::X) - 1) * self.shape.stride as u64 + c(Dim::FX);
                let iy = (c(Dim::Y) - 1) * self.shape.stride as u64 + c(Dim::FY);
                c(Dim::B) * c(Dim::C) * ix.min(self.shape.input_x()) * iy.min(self.shape.input_y())
            }
        }
    }
}
