//! Unit + property tests for the loop-nest IR.

use super::*;
use crate::util::prop;

fn conv3_like() -> Shape {
    // AlexNet CONV3 at full scale: B=16, K=384, C=256, X=Y=13, F=3x3
    Shape::new(16, 384, 256, 13, 13, 3, 3, 1)
}

#[test]
fn dim_roundtrip() {
    for d in ALL_DIMS {
        assert_eq!(Dim::from_idx(d.idx()), d);
        assert_eq!(Dim::parse(d.name()), Some(d));
    }
    assert_eq!(Dim::parse("fy"), Some(Dim::FY));
    assert_eq!(Dim::parse("Z"), None);
}

#[test]
fn reduction_dims() {
    assert!(Dim::C.is_reduction());
    assert!(Dim::FX.is_reduction());
    assert!(Dim::FY.is_reduction());
    assert!(!Dim::B.is_reduction());
    assert!(!Dim::K.is_reduction());
    assert!(!Dim::X.is_reduction());
}

#[test]
fn tensor_relevance_matches_algorithm1() {
    use Tensor::*;
    // O[b][k][x][y]
    for d in [Dim::B, Dim::K, Dim::X, Dim::Y] {
        assert!(Output.relevant(d));
    }
    for d in [Dim::C, Dim::FX, Dim::FY] {
        assert!(!Output.relevant(d));
    }
    // W[k][c][fx][fy]
    for d in [Dim::K, Dim::C, Dim::FX, Dim::FY] {
        assert!(Weight.relevant(d));
    }
    for d in [Dim::B, Dim::X, Dim::Y] {
        assert!(!Weight.relevant(d));
    }
    // I[b][c][x+fx][y+fy]
    for d in ALL_DIMS {
        assert_eq!(Input.relevant(d), d != Dim::K);
    }
}

#[test]
fn reduction_iff_output_irrelevant() {
    for d in ALL_DIMS {
        assert_eq!(d.is_reduction(), !Tensor::Output.relevant(d));
    }
}

#[test]
fn shape_macs_and_sizes() {
    let s = conv3_like();
    assert_eq!(s.macs(), 16 * 384 * 256 * 13 * 13 * 3 * 3);
    assert_eq!(s.tensor_elems(Tensor::Weight), 384 * 256 * 3 * 3);
    assert_eq!(s.tensor_elems(Tensor::Output), 16 * 384 * 13 * 13);
    assert_eq!(s.input_x(), 15);
    assert_eq!(s.tensor_elems(Tensor::Input), 16 * 256 * 15 * 15);
}

#[test]
fn fc_layer_as_degenerate_conv() {
    // FC: only B, K, C loops (paper §3)
    let s = Shape::new(128, 1000, 4096, 1, 1, 1, 1, 1);
    assert_eq!(s.macs(), 128 * 1000 * 4096);
    assert_eq!(s.tensor_elems(Tensor::Weight), 1000 * 4096);
    assert_eq!(s.tensor_elems(Tensor::Input), 128 * 4096);
    assert_eq!(s.tensor_elems(Tensor::Output), 128 * 1000);
}

#[test]
fn strided_input_halo() {
    // AlexNet CONV1-like: 11x11 filter, stride 4, X=Y=55
    let s = Shape::new(1, 96, 3, 55, 55, 11, 11, 4);
    assert_eq!(s.input_x(), 54 * 4 + 11); // 227
    assert_eq!(s.input_y(), 227);
}

#[test]
fn level_order_validity() {
    assert!(LevelOrder::canonical().is_valid());
    for t in ALL_TENSORS {
        let o = LevelOrder::stationary_for(t);
        assert!(o.is_valid());
        // irrelevant dims must all be innermost
        let n_irrel = ALL_DIMS.iter().filter(|&&d| !t.relevant(d)).count();
        for (i, d) in o.0.iter().enumerate() {
            assert_eq!(t.relevant(*d), i >= n_irrel, "{t} order {:?}", o.0);
        }
    }
    let bad = LevelOrder([Dim::B; NDIMS]);
    assert!(!bad.is_valid());
}

#[test]
fn trivial_mapping_validates() {
    let m = Mapping::trivial(conv3_like(), 1, 2);
    m.validate().unwrap();
    assert_eq!(m.levels(), 3);
    assert_eq!(m.pe_count(), 1);
    // full tensor resident only at the top level
    assert_eq!(
        m.tile_elems(Tensor::Weight, 2),
        conv3_like().tensor_elems(Tensor::Weight)
    );
    assert_eq!(m.tile_elems(Tensor::Weight, 0), 1);
}

#[test]
fn mapping_validate_catches_bad_product() {
    let mut m = Mapping::trivial(conv3_like(), 1, 2);
    m.blocking.set(0, Dim::K, 2); // 2*384 != 384
    assert!(m.validate().is_err());
}

#[test]
fn mapping_cum_and_tiles() {
    let shape = Shape::new(2, 8, 4, 6, 6, 3, 3, 1);
    let mut m = Mapping::trivial(shape, 1, 2);
    // move K=2, C=4, FX=3, FY=3, X=6, Y=6 into RF; spatial K=2; rest stays up
    m.blocking.set(0, Dim::K, 2);
    m.blocking.set(0, Dim::C, 4);
    m.blocking.set(0, Dim::FX, 3);
    m.blocking.set(0, Dim::FY, 3);
    m.blocking.set(0, Dim::X, 6);
    m.blocking.set(0, Dim::Y, 6);
    m.spatial[Dim::K.idx()] = 2;
    m.blocking.set(2, Dim::K, 2);
    m.blocking.set(2, Dim::C, 1);
    m.blocking.set(2, Dim::FX, 1);
    m.blocking.set(2, Dim::FY, 1);
    m.blocking.set(2, Dim::X, 1);
    m.blocking.set(2, Dim::Y, 1);
    m.validate().unwrap();

    // per-PE RF tile
    assert_eq!(m.cum(0, Dim::K), 2);
    assert_eq!(m.tile_elems(Tensor::Weight, 0), 2 * 4 * 3 * 3);
    // input halo at RF: ix = (6-1)*1+3 = 8
    assert_eq!(m.tile_elems(Tensor::Input, 0), 4 * 8 * 8);
    // shared level sees spatial: K cum at level 1 = 2(rf) * 2(spatial)
    assert_eq!(m.cum(1, Dim::K), 4);
    // array-unique weight tile for one RF pass: K spans spatial
    assert_eq!(m.tile_elems_array(Tensor::Weight, 0), 4 * 4 * 3 * 3);
    // input is K-irrelevant: multicast, no K multiplier
    assert_eq!(m.tile_elems_array(Tensor::Input, 0), 4 * 8 * 8);
}

#[test]
fn halo_clamps_to_full_input() {
    // cum X tile of 5 with stride 2 and FX 3 -> ix = 4*2+3 = 11, but the
    // real input is only (5-1)*2+3 = 11 as well; craft a case where the
    // naive halo would exceed: X split 5 = 5 at RF, full
    let shape = Shape::new(1, 1, 1, 5, 5, 3, 3, 2);
    let m = Mapping::trivial(shape, 1, 1);
    assert_eq!(m.tile_elems(Tensor::Input, 1), shape.tensor_elems(Tensor::Input));
}

#[test]
fn prop_random_blockings_validate_and_tile_monotone() {
    prop::for_cases(0xb10c, 200, |rng| {
        // random small shape
        let shape = Shape::new(
            rng.range(1, 4),
            rng.range(1, 16),
            rng.range(1, 16),
            rng.range(1, 8),
            rng.range(1, 8),
            rng.range(1, 3),
            rng.range(1, 3),
            rng.range(1, 2) as u32,
        );
        let levels = rng.range(2, 4) as usize;
        let m = crate::search::random_mapping(shape, levels, 1, rng);
        m.validate().unwrap_or_else(|e| panic!("{e}"));
        // tiles grow monotonically with level
        for t in ALL_TENSORS {
            for l in 1..m.levels() {
                assert!(
                    m.tile_elems(t, l) >= m.tile_elems(t, l - 1),
                    "tile of {t} shrank at level {l}"
                );
            }
            // top level holds the whole tensor
            assert_eq!(m.tile_elems(t, m.levels() - 1), shape.tensor_elems(t));
        }
    });
}
