//! Stage 2: per-level tile footprints and the capacity (fit) check.
//!
//! A [`Footprints`] table is computed once per blocking table and shared
//! by every loop-order candidate of that blocking (orders never change
//! tile sizes), and by both the fit check and the stage-3 access-count
//! accumulation — the seed recomputed the same products three times per
//! candidate.

use crate::arch::{Arch, LevelKind};
use crate::loopnest::{Mapping, Tensor};
use crate::xmodel::{EvalError, MAX_LEVELS};

/// Per-level, per-tensor resident tile sizes, in elements.
///
/// `tiles[tensor.idx()][level]`: per-PE below `spatial_at`, aggregate
/// (array-wide, including the spatial extents) at or above it. Input
/// tiles use halo arithmetic, clamped to the layer's input extent.
/// Entries at levels `>= levels()` are zero.
#[derive(Debug, Clone)]
pub struct Footprints {
    /// `tiles[tensor][level]`, elements.
    pub tiles: [[u64; MAX_LEVELS]; 3],
    levels: usize,
}

impl Footprints {
    /// One cumulative-product pass over the blocking table (the same
    /// arithmetic as `Mapping::tile_elems`, amortized across levels).
    pub fn compute(m: &Mapping) -> Footprints {
        let nlv = m.levels();
        assert!(nlv <= MAX_LEVELS, "more than {MAX_LEVELS} levels");
        let stride = m.shape.stride as u64;
        let (in_x, in_y) = (m.shape.input_x(), m.shape.input_y());
        let mut cum = [1u64; 7];
        let mut tiles = [[0u64; MAX_LEVELS]; 3];
        for i in 0..nlv {
            for (d, c) in cum.iter_mut().enumerate() {
                *c *= m.blocking.factors[i][d];
            }
            // at or above the first shared level the aggregate
            // (array-wide) tile includes the spatial factors
            let with_spatial = |d: usize| -> u64 {
                if i >= m.spatial_at {
                    cum[d] * m.spatial[d]
                } else {
                    cum[d]
                }
            };
            let (b, k, c, x, y, fx, fy) = (
                with_spatial(0),
                with_spatial(1),
                with_spatial(2),
                with_spatial(3),
                with_spatial(4),
                with_spatial(5),
                with_spatial(6),
            );
            let ix = ((x - 1) * stride + fx).min(in_x);
            let iy = ((y - 1) * stride + fy).min(in_y);
            tiles[Tensor::Input.idx()][i] = b * c * ix * iy;
            tiles[Tensor::Weight.idx()][i] = k * c * fx * fy;
            tiles[Tensor::Output.idx()][i] = b * k * x * y;
        }
        Footprints { tiles, levels: nlv }
    }

    /// Number of temporal levels covered.
    pub fn levels(&self) -> usize {
        self.levels
    }

    /// Tile of `t` at `level`, in elements.
    pub fn tile(&self, t: Tensor, level: usize) -> u64 {
        self.tiles[t.idx()][level]
    }

    /// Capacity check: at every on-chip level the three tiles (double
    /// buffered, Fig 5) must fit. DRAM always fits. Same contract as the
    /// legacy `xmodel::fits`.
    pub fn fit(&self, arch: &Arch) -> Result<(), EvalError> {
        for (i, lvl) in arch.levels.iter().enumerate().take(self.levels) {
            if lvl.kind == LevelKind::Dram {
                continue;
            }
            let need = (self.tiles[0][i] + self.tiles[1][i] + self.tiles[2][i]) * 2;
            let have = arch.level_words(i);
            if need > have {
                return Err(EvalError::DoesNotFit { level: i, need, have });
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loopnest::{Shape, ALL_TENSORS};

    #[test]
    fn footprints_match_tile_elems_reference() {
        crate::util::prop::for_cases(0xf007, 150, |rng| {
            let shape = Shape::new(
                rng.range(1, 4),
                rng.range(1, 24),
                rng.range(1, 24),
                rng.range(1, 10),
                rng.range(1, 10),
                rng.range(1, 4),
                rng.range(1, 4),
                rng.range(1, 2) as u32,
            );
            let arch = crate::arch::eyeriss_like();
            let (m, _) = crate::search::random_mapping_for_arch(shape, &arch, rng);
            let fp = Footprints::compute(&m);
            assert_eq!(fp.levels(), m.levels());
            for t in ALL_TENSORS {
                for i in 0..m.levels() {
                    assert_eq!(fp.tile(t, i), m.tile_elems(t, i), "{t} level {i}: {m:?}");
                }
            }
        });
    }

    #[test]
    fn fit_agrees_with_legacy_fits() {
        crate::util::prop::for_cases(0xf17, 150, |rng| {
            let shape = Shape::new(
                rng.range(1, 3),
                rng.range(1, 48),
                rng.range(1, 48),
                rng.range(1, 12),
                rng.range(1, 12),
                rng.range(1, 4),
                rng.range(1, 4),
                1,
            );
            let arch = crate::arch::eyeriss_like();
            let (m, _) = crate::search::random_mapping_for_arch(shape, &arch, rng);
            let fp = Footprints::compute(&m);
            assert_eq!(fp.fit(&arch), crate::xmodel::fits(&m, &arch));
        });
    }
}
