//! The staged mapping-evaluation engine — the hot path of every sweep.
//!
//! The seed evaluated every `(blocking, order)` candidate monolithically
//! through `xmodel::evaluate`: tile tables, round tables, access counts
//! and a fully allocated [`ModelResult`] per candidate, millions of times
//! per figure sweep. This module decomposes that evaluation into explicit
//! stages so enumeration can stop paying for a candidate the moment it is
//! provably worse than the incumbent:
//!
//! | stage | work | output | shared across |
//! |-------|------|--------|---------------|
//! | 1 | shape / level / spatial validation | `Result<(), EvalError>` | whole layer |
//! | 2 | per-level tile footprints + fit check | [`Footprints`] | all orders of a blocking |
//! | 3 | per-tensor round tables + access counts | scalar energy | — (bounded, abortable) |
//! | 4 | energy/latency roll-up | [`ModelResult`] | winner only |
//!
//! ## Pruning contract
//!
//! [`Engine::energy_bounded`] accumulates tensors in canonical order
//! (I, W, O) and, between tensors, compares an **admissible lower bound**
//! against the caller's bound. The bound is the canonical roll-up of the
//! partially filled counts buffer ([`counts::energy_total`]) plus the
//! compulsory last-level (DRAM) traffic of the tensors not yet
//! accumulated (weights and outputs must each cross the top boundary at
//! least once in full — rigorous regardless of blocking, order or
//! multicast; the input floor is deliberately omitted because strided
//! halos can skip input elements). Because counts only grow, additions
//! are non-negative, and f64 addition is monotone, the partial roll-up
//! never exceeds the final energy; the compulsory-floor term is exact in
//! real arithmetic, so a relative slack of `1e-9` absorbs its f64
//! rounding. Consequences:
//!
//! - a candidate whose true energy is `<=` the bound is **never** pruned,
//!   so branch-and-bound returns the identical winner (same argmin under
//!   the same iteration order) as exhaustive evaluation;
//! - a completed stage 3 returns the exact final energy, bit-identical to
//!   what stage 4 / the legacy `xmodel::evaluate` reports.
//!
//! `xmodel::evaluate` remains the compatibility shim over the full
//! pipeline; the search, the experiments and the sim cross-checks consume
//! the staged API directly.

mod cache;
mod counts;
mod footprint;
mod rollup;
mod stats;

pub use cache::DivisorCache;
pub use counts::{accumulate_tensor, analytic_rows, energy_total, CountsBuf};
pub use footprint::Footprints;
pub use rollup::{assemble, model_result};
pub use stats::{EvalSnapshot, EvalStats, Incumbent};

use crate::arch::Arch;
use crate::dataflow::SpatialMap;
use crate::energy::CostModel;
use crate::loopnest::{Mapping, Shape, Tensor, ALL_TENSORS};
use crate::xmodel::{EvalError, ModelResult, MAX_LEVELS};

/// Relative slack applied to pruning comparisons: absorbs f64 rounding of
/// the compulsory-floor bound so exact ties with the incumbent are never
/// pruned (see the module docs' pruning contract).
pub const PRUNE_SLACK: f64 = 1e-9;

/// Admissible lower bound on any mapping's **cycles** for `shape` on
/// `arch` — the second coordinate of the vector bound the Pareto
/// co-optimizer (`crate::pareto`) prunes against, complementing the
/// energy floor (`EvalCtx::floor_pj`):
///
/// - *compute bound*: the roll-up computes
///   `macs / (array PEs × utilization)` with `utilization <= 1`, so
///   `macs / array PEs` never exceeds it (a zero-utilization candidate
///   reports infinite cycles, trivially above any floor);
/// - *compulsory-DRAM bound*: weights and outputs must each cross the
///   top (DRAM) boundary at least once in full regardless of blocking,
///   order or multicast (the same argument as the energy floor; the
///   input floor is again deliberately omitted because strided halos can
///   skip input elements), and the roll-up charges that traffic at
///   `word_bytes / dram_bw_bytes_per_cycle` per element.
///
/// [`model_result`] takes the max of the same two terms over the
/// *achieved* utilization and traffic, both no better than the floor's,
/// so in real arithmetic this never exceeds the final cycles; callers
/// compare with the relative [`PRUNE_SLACK`] to absorb f64 rounding.
pub fn cycle_floor(shape: &Shape, arch: &Arch) -> f64 {
    let compute = shape.macs() as f64 / arch.array.pes() as f64;
    let compulsory =
        (shape.tensor_elems(Tensor::Weight) + shape.tensor_elems(Tensor::Output)) as f64;
    let dram = compulsory * arch.word_bytes as f64 / arch.dram_bw_bytes_per_cycle;
    compute.max(dram)
}

/// How a search treats the incumbent bound.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PruneMode {
    /// Evaluate every candidate fully (the seed's behavior).
    Exhaustive,
    /// Branch-and-bound: share an incumbent and abandon candidates whose
    /// stage-2/3 lower bounds exceed it. Identical winner, fewer full
    /// evaluations.
    #[default]
    BranchAndBound,
}

/// Per-layer evaluation context: everything that is constant across the
/// candidates of one `(shape, spatial map, arch, cost)` search, hoisted
/// out of the per-candidate path.
#[derive(Debug, Clone)]
pub struct EvalCtx {
    /// Temporal levels of the architecture.
    pub nlv: usize,
    /// First shared level (== `Mapping::spatial_at` of every candidate).
    pub sp: usize,
    /// Active PEs (product of the spatial map's extents), as f64.
    pub pes: f64,
    /// Energy per access per level (entries `>= nlv` unused).
    pub level_cost: [f64; MAX_LEVELS],
    /// Energy per fabric hop.
    pub hop_pj: f64,
    /// Total MAC energy of the layer.
    pub mac_energy: f64,
    /// Stage-1 lower bound: MAC energy plus compulsory top-level traffic
    /// of weights and outputs.
    pub floor_pj: f64,
    /// Compulsory top-level energy of the tensors *after* index `k` in
    /// canonical accumulation order (I=0, W=1, O=2).
    pub floor_after: [f64; 3],
    /// Cycles half of the layer's vector lower bound ([`cycle_floor`]):
    /// no mapping of this `(shape, arch)` pair can finish faster.
    pub cycle_floor: f64,
}

/// Outcome of a bounded stage-3 evaluation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Staged {
    /// Abandoned: the given admissible lower bound exceeded the caller's
    /// bound (the candidate's true energy is at least this).
    Pruned(f64),
    /// Completed: the exact final energy (bit-identical to stage 4).
    Energy(f64),
}

impl Staged {
    /// The exact energy when the evaluation completed.
    pub fn energy(self) -> Option<f64> {
        match self {
            Staged::Energy(e) => Some(e),
            Staged::Pruned(_) => None,
        }
    }
}

/// The staged evaluation engine for one `(arch, cost model)` pair.
#[derive(Clone, Copy)]
pub struct Engine<'a> {
    /// Target architecture.
    pub arch: &'a Arch,
    /// Energy cost model.
    pub cost: &'a dyn CostModel,
}

impl<'a> Engine<'a> {
    /// New engine over an architecture and cost model.
    pub fn new(arch: &'a Arch, cost: &'a dyn CostModel) -> Self {
        Engine { arch, cost }
    }

    /// Build the per-layer [`EvalCtx`] for a `(shape, spatial map)` pair.
    pub fn context(&self, shape: &Shape, smap: &SpatialMap) -> EvalCtx {
        let nlv = self.arch.num_levels();
        assert!(nlv <= MAX_LEVELS, "more than {MAX_LEVELS} levels");
        let mut level_cost = [0.0; MAX_LEVELS];
        for (i, c) in level_cost.iter_mut().enumerate().take(nlv) {
            *c = self.cost.level_access(self.arch, i);
        }
        let top_cost = level_cost[nlv - 1];
        let mac_energy = shape.macs() as f64 * self.cost.mac();
        let w_floor = shape.tensor_elems(Tensor::Weight) as f64 * top_cost;
        let o_floor = shape.tensor_elems(Tensor::Output) as f64 * top_cost;
        EvalCtx {
            nlv,
            sp: self.arch.rf_levels(),
            pes: smap.pes_used() as f64,
            level_cost,
            hop_pj: self.cost.hop(),
            mac_energy,
            floor_pj: mac_energy + w_floor + o_floor,
            floor_after: [w_floor + o_floor, o_floor, 0.0],
            cycle_floor: cycle_floor(shape, self.arch),
        }
    }

    /// Stage 1: consistency checks (same order and errors as the legacy
    /// `xmodel::evaluate` preamble).
    pub fn validate(&self, m: &Mapping, smap: &SpatialMap) -> Result<(), EvalError> {
        m.validate().map_err(EvalError::BadMapping)?;
        if m.levels() != self.arch.num_levels() {
            return Err(EvalError::LevelMismatch {
                mapping: m.levels(),
                arch: self.arch.num_levels(),
            });
        }
        if m.spatial != smap.factors() {
            return Err(EvalError::SpatialMismatch);
        }
        if m.spatial_at != self.arch.rf_levels() {
            return Err(EvalError::BadMapping(format!(
                "spatial_at {} != arch rf levels {}",
                m.spatial_at,
                self.arch.rf_levels()
            )));
        }
        Ok(())
    }

    /// Stage 2: tile footprints plus the capacity check. The returned
    /// table is shared by every loop-order candidate of the blocking and
    /// by stage 3.
    pub fn footprints(&self, m: &Mapping, stats: &EvalStats) -> Result<Footprints, EvalError> {
        EvalStats::bump(&stats.stage2);
        let fp = Footprints::compute(m);
        if let Err(e) = fp.fit(self.arch) {
            EvalStats::bump(&stats.fit_rejected);
            return Err(e);
        }
        Ok(fp)
    }

    /// Stage 3: bounded scalar evaluation. Accumulates per-tensor access
    /// counts, checking the admissible lower bound against `bound`
    /// between tensors (see the module docs). Returns the exact final
    /// energy on completion — callers compare it to their own incumbent;
    /// a completed evaluation above the bound is *not* reported as
    /// pruned.
    pub fn energy_bounded(
        &self,
        m: &Mapping,
        smap: &SpatialMap,
        ctx: &EvalCtx,
        fp: &Footprints,
        bound: f64,
        stats: &EvalStats,
    ) -> Staged {
        EvalStats::bump(&stats.stage3);
        let cutoff = bound * (1.0 + PRUNE_SLACK);
        if ctx.floor_pj > cutoff {
            EvalStats::bump(&stats.pruned);
            return Staged::Pruned(ctx.floor_pj);
        }
        let mut buf = CountsBuf::default();
        for (k, t) in ALL_TENSORS.into_iter().enumerate() {
            let (rounds_row, distinct_row) = analytic_rows(m, t);
            accumulate_tensor(
                &mut buf,
                t,
                &rounds_row,
                &distinct_row,
                &fp.tiles,
                ctx.nlv,
                ctx.sp,
                ctx.pes,
                smap,
                self.arch,
            );
            let partial =
                energy_total(&buf, ctx.nlv, &ctx.level_cost, ctx.hop_pj, ctx.mac_energy);
            if k + 1 == ALL_TENSORS.len() {
                // fully accumulated: `partial` is the exact energy
                EvalStats::bump(&stats.full);
                return Staged::Energy(partial);
            }
            let lb = partial + ctx.floor_after[k];
            if lb > cutoff {
                EvalStats::bump(&stats.pruned);
                return Staged::Pruned(lb);
            }
        }
        unreachable!("ALL_TENSORS is non-empty")
    }

    /// Stage 4 for one candidate whose stages 1–2 already ran: full
    /// evaluation into a [`ModelResult`] (counts, per-level energies,
    /// cycles, utilization).
    pub fn rollup(&self, m: &Mapping, smap: &SpatialMap, fp: &Footprints) -> ModelResult {
        let nlv = m.levels();
        let sp = m.spatial_at;
        let pes = m.pe_count() as f64;
        let mut buf = CountsBuf::default();
        for t in ALL_TENSORS {
            let (rounds_row, distinct_row) = analytic_rows(m, t);
            accumulate_tensor(
                &mut buf,
                t,
                &rounds_row,
                &distinct_row,
                &fp.tiles,
                nlv,
                sp,
                pes,
                smap,
                self.arch,
            );
        }
        model_result(m, smap, self.arch, self.cost, &buf)
    }

    /// The full pipeline (stages 1–4) with all checks — the semantics of
    /// the legacy `xmodel::evaluate`, which now delegates here.
    pub fn evaluate(&self, m: &Mapping, smap: &SpatialMap) -> Result<ModelResult, EvalError> {
        self.validate(m, smap)?;
        let fp = Footprints::compute(m);
        fp.fit(self.arch)?;
        Ok(self.rollup(m, smap, &fp))
    }

    /// Stages 2–4 without the consistency/capacity checks — the semantics
    /// of the legacy `xmodel::evaluate_prechecked`.
    pub fn evaluate_prechecked(&self, m: &Mapping, smap: &SpatialMap) -> ModelResult {
        let fp = Footprints::compute(m);
        self.rollup(m, smap, &fp)
    }
}

#[cfg(test)]
mod tests;
