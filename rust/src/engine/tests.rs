//! Engine tests: staged results bit-match the legacy `xmodel::evaluate`
//! and the exact trace simulator on randomized mappings, and pruning is
//! admissible (never drops a candidate at or below the bound).

use super::*;
use crate::arch::{eyeriss_like, optimized_mobile, small_rf};
use crate::energy::Table3;
use crate::loopnest::Shape;
use crate::util::prop;
use crate::util::XorShift;

fn random_shape(rng: &mut XorShift) -> Shape {
    Shape::new(
        rng.range(1, 3),
        rng.range(1, 12),
        rng.range(1, 12),
        rng.range(1, 7),
        rng.range(1, 7),
        rng.range(1, 3),
        rng.range(1, 3),
        rng.range(1, 2) as u32,
    )
}

fn random_arch(rng: &mut XorShift) -> crate::arch::Arch {
    match rng.below(3) {
        0 => eyeriss_like(),
        1 => small_rf(),
        _ => optimized_mobile(),
    }
}

#[test]
fn prop_staged_bitmatches_legacy_evaluate_and_sim() {
    prop::for_cases(0xe41e, 120, |rng| {
        let shape = random_shape(rng);
        let arch = random_arch(rng);
        let (m, smap) = crate::search::random_mapping_for_arch(shape, &arch, rng);
        let engine = Engine::new(&arch, &Table3);
        let legacy = match crate::xmodel::evaluate(&m, &smap, &arch, &Table3) {
            Ok(r) => r,
            Err(_) => return, // capacity misses are fine here
        };

        // full staged pipeline
        let staged = engine.evaluate(&m, &smap).expect("legacy accepted it");
        assert_eq!(staged.energy_pj, legacy.energy_pj, "energy: {m:?}");
        assert_eq!(staged.cycles, legacy.cycles);
        assert_eq!(staged.levels, legacy.levels);
        assert_eq!(staged.fabric_words, legacy.fabric_words);
        assert_eq!(staged.fabric_hops, legacy.fabric_hops);
        assert_eq!(staged.energy_by_level, legacy.energy_by_level);

        // bounded stage-3 with an infinite bound completes with the same
        // bits as the full roll-up
        let stats = EvalStats::default();
        let ctx = engine.context(&shape, &smap);
        let fp = engine.footprints(&m, &stats).expect("fits");
        let e = engine
            .energy_bounded(&m, &smap, &ctx, &fp, f64::INFINITY, &stats)
            .energy()
            .expect("infinite bound never prunes");
        assert_eq!(e, legacy.energy_pj, "stage-3 scalar drifted: {m:?}");

        // assembling from externally supplied (analytic) tables is the
        // same arithmetic
        let tables = crate::xmodel::RoundTables::analytic(&m);
        let via_tables = assemble(&m, &smap, &arch, &Table3, &tables);
        assert_eq!(via_tables.energy_pj, legacy.energy_pj);

        // the exact trace walk counts the same rounds, so the simulator's
        // energy is bit-identical too
        if let Ok(sim) = crate::sim::simulate(&m, &smap, &arch, &Table3, 50_000_000) {
            assert_eq!(sim.energy_pj, legacy.energy_pj, "sim drifted: {m:?}");
        }
    });
}

#[test]
fn prop_analytic_rows_match_round_tables() {
    prop::for_cases(0xa9a, 100, |rng| {
        let shape = random_shape(rng);
        let levels = rng.range(2, 4) as usize;
        let m = crate::search::random_mapping(shape, levels, 1, rng);
        let tables = crate::xmodel::RoundTables::analytic(&m);
        for t in crate::loopnest::ALL_TENSORS {
            let (rounds, distinct) = analytic_rows(&m, t);
            assert_eq!(rounds, tables.rounds[t.idx()]);
            assert_eq!(distinct, tables.distinct[t.idx()]);
        }
    });
}

#[test]
fn prop_pruning_is_admissible() {
    prop::for_cases(0xb0d, 150, |rng| {
        let shape = random_shape(rng);
        let arch = random_arch(rng);
        let (m, smap) = crate::search::random_mapping_for_arch(shape, &arch, rng);
        let engine = Engine::new(&arch, &Table3);
        if crate::xmodel::evaluate(&m, &smap, &arch, &Table3).is_err() {
            return;
        }
        let stats = EvalStats::default();
        let ctx = engine.context(&shape, &smap);
        let fp = engine.footprints(&m, &stats).expect("fits");
        let e_true = engine.evaluate(&m, &smap).unwrap().energy_pj;

        // bound exactly at the candidate's own energy: must complete
        match engine.energy_bounded(&m, &smap, &ctx, &fp, e_true, &stats) {
            Staged::Energy(e) => assert_eq!(e, e_true),
            Staged::Pruned(lb) => panic!("pruned at its own energy (lb {lb} vs {e_true}): {m:?}"),
        }

        // any tighter bound: either completes exactly, or reports an
        // admissible lower bound (never above the true energy)
        let bound = e_true * 0.7;
        match engine.energy_bounded(&m, &smap, &ctx, &fp, bound, &stats) {
            Staged::Energy(e) => assert_eq!(e, e_true),
            Staged::Pruned(lb) => assert!(
                lb <= e_true * (1.0 + PRUNE_SLACK),
                "inadmissible bound {lb} > true {e_true}: {m:?}"
            ),
        }

        // a bound below the MAC-energy floor always prunes before any
        // tensor work
        let before = stats.snapshot().pruned;
        match engine.energy_bounded(&m, &smap, &ctx, &fp, ctx.mac_energy * 0.5, &stats) {
            Staged::Pruned(lb) => assert!(lb >= ctx.floor_pj),
            Staged::Energy(e) => panic!("floor check missed: {e} vs floor {}", ctx.floor_pj),
        }
        assert_eq!(stats.snapshot().pruned, before + 1);
    });
}

#[test]
fn stats_counters_track_pipeline() {
    let shape = Shape::new(2, 8, 8, 4, 4, 3, 3, 1);
    let arch = eyeriss_like();
    let mut rng = XorShift::new(42);
    let (m, smap) = crate::search::random_mapping_for_arch(shape, &arch, &mut rng);
    let engine = Engine::new(&arch, &Table3);
    let stats = EvalStats::default();
    if let Ok(fp) = engine.footprints(&m, &stats) {
        let ctx = engine.context(&shape, &smap);
        let _ = engine.energy_bounded(&m, &smap, &ctx, &fp, f64::INFINITY, &stats);
        let snap = stats.snapshot();
        assert_eq!(snap.stage2, 1);
        assert_eq!(snap.stage3, 1);
        assert_eq!(snap.full, 1);
        assert_eq!(snap.pruned, 0);
    } else {
        assert_eq!(stats.snapshot().fit_rejected, 1);
    }
}

#[test]
fn context_floor_is_below_any_feasible_energy() {
    // the stage-1 floor must lower-bound every evaluable candidate
    prop::for_cases(0xf100, 80, |rng| {
        let shape = random_shape(rng);
        let arch = random_arch(rng);
        let (m, smap) = crate::search::random_mapping_for_arch(shape, &arch, rng);
        if let Ok(r) = crate::xmodel::evaluate(&m, &smap, &arch, &Table3) {
            let engine = Engine::new(&arch, &Table3);
            let ctx = engine.context(&shape, &smap);
            assert!(
                ctx.floor_pj <= r.energy_pj * (1.0 + PRUNE_SLACK),
                "floor {} above feasible energy {}: {m:?}",
                ctx.floor_pj,
                r.energy_pj
            );
        }
    });
}
