//! Per-layer memoization shared across candidates.
//!
//! The enumerator and the random-mapping generators repeatedly factor the
//! same per-dim remainders; [`DivisorCache`] memoizes `divisors(n)` so a
//! layer's whole search pays the trial division once per distinct value.

use std::collections::HashMap;
use std::sync::Arc;

use crate::util::divisors;

/// Memoized divisor tables, typically one per layer search.
#[derive(Debug, Default)]
pub struct DivisorCache {
    map: HashMap<u64, Arc<Vec<u64>>>,
    hits: u64,
    misses: u64,
}

impl DivisorCache {
    /// Fresh, empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// All divisors of `n`, ascending (memoized).
    pub fn divisors(&mut self, n: u64) -> Arc<Vec<u64>> {
        if let Some(d) = self.map.get(&n) {
            self.hits += 1;
            return d.clone();
        }
        self.misses += 1;
        let d = Arc::new(divisors(n));
        self.map.insert(n, d.clone());
        d
    }

    /// `(hits, misses)` since construction.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_util_divisors() {
        let mut c = DivisorCache::new();
        for n in [1u64, 2, 12, 13, 36, 360, 9216] {
            assert_eq!(*c.divisors(n), divisors(n), "divisors({n})");
        }
    }

    #[test]
    fn caches_repeat_queries() {
        let mut c = DivisorCache::new();
        let a = c.divisors(720);
        let b = c.divisors(720);
        assert_eq!(a, b);
        let (hits, misses) = c.stats();
        assert_eq!(hits, 1);
        assert_eq!(misses, 1);
    }
}
