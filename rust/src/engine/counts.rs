//! Stage 3: per-boundary round tables and bounded access-count
//! accumulation.
//!
//! The arithmetic here is an exact port of the seed's monolithic
//! `xmodel::assemble` loop, restructured per tensor so a candidate can be
//! abandoned the moment its running cost exceeds the incumbent: counts
//! only ever grow, every contribution is non-negative, and f64 addition
//! is monotone, so the canonical roll-up of a partially filled
//! [`CountsBuf`] is an *admissible* lower bound of the final energy.

use crate::arch::{Arch, ArrayBus};
use crate::dataflow::SpatialMap;
use crate::loopnest::{Dim, Mapping, Tensor};
use crate::xmodel::{refetch_factor, LevelCounts, MAX_LEVELS};

/// Fixed-size stage-3 accumulation buffer (no allocation on the search's
/// hot path; only the winning candidate materializes a `ModelResult`).
#[derive(Debug, Clone)]
pub struct CountsBuf {
    /// Per-level access counts (same indexing as `arch.levels`).
    pub levels: [LevelCounts; MAX_LEVELS],
    /// Words delivered over the array fabric per tensor.
    pub fabric_words: [f64; 3],
    /// Hop-weighted fabric transfers.
    pub fabric_hops: f64,
}

impl Default for CountsBuf {
    fn default() -> Self {
        CountsBuf {
            levels: [LevelCounts::default(); MAX_LEVELS],
            fabric_words: [0.0; 3],
            fabric_hops: 0.0,
        }
    }
}

/// One tensor's analytic per-boundary rounds and distinct-tile counts —
/// one row pair of [`crate::xmodel::RoundTables`], computed lazily so a
/// pruned candidate never pays for the remaining tensors.
///
/// Exact port of the per-tensor body of the seed's
/// `RoundTables::analytic`.
pub fn analytic_rows(m: &Mapping, t: Tensor) -> ([f64; MAX_LEVELS], [f64; MAX_LEVELS]) {
    let nlv = m.levels();
    assert!(nlv <= MAX_LEVELS, "more than {MAX_LEVELS} levels");
    // per level: (r when a relevant loop was already seen below, r when
    // not, does this level set the seen flag, relevant-only product)
    let mut per: [(f64, f64, bool, f64); MAX_LEVELS] = [(1.0, 1.0, false, 1.0); MAX_LEVELS];
    for j in 0..nlv {
        let (r_unseen, sets) = refetch_factor(m, t, j, false);
        let (r_seen, _) = refetch_factor(m, t, j, true);
        let rel: f64 = (0..7)
            .filter(|&i| t.relevant(Dim::from_idx(i)))
            .map(|i| m.blocking.factors[j][i] as f64)
            .product();
        per[j] = (r_seen as f64, r_unseen as f64, sets, rel);
    }
    let mut rounds_row = [0.0; MAX_LEVELS];
    let mut distinct_row = [0.0; MAX_LEVELS];
    for i in 0..nlv {
        let mut seen = false;
        let mut rounds = 1.0;
        let mut distinct = 1.0;
        for (r_seen, r_unseen, sets, rel) in per.iter().take(nlv).skip(i) {
            rounds *= if seen { *r_seen } else { *r_unseen };
            seen |= *sets;
            distinct *= rel;
        }
        rounds_row[i] = rounds;
        distinct_row[i] = distinct;
    }
    (rounds_row, distinct_row)
}

/// Accumulate tensor `t`'s contributions to every boundary into `buf` —
/// an exact port of the seed `xmodel::assemble` inner loop (same
/// statement order, so per-cell f64 accumulation order is preserved and
/// results bit-match the legacy model).
///
/// `tiles` is the stage-2 footprint table; `pes` is the mapping's active
/// PE count as f64; `sp` its `spatial_at`.
#[allow(clippy::too_many_arguments)]
pub fn accumulate_tensor(
    buf: &mut CountsBuf,
    t: Tensor,
    rounds_row: &[f64; MAX_LEVELS],
    distinct_row: &[f64; MAX_LEVELS],
    tiles: &[[u64; MAX_LEVELS]; 3],
    nlv: usize,
    sp: usize,
    pes: f64,
    smap: &SpatialMap,
    arch: &Arch,
) {
    let ti = t.idx();
    // Boundary i: between level i (upper) and level i-1 / operand
    // register (lower).
    for i in 0..nlv {
        let rounds = rounds_row[i];
        let tile = if i == 0 { 1.0 } else { tiles[ti][i - 1] as f64 };

        // Multiplicities on the two sides of the boundary.
        // lower_mult: copies delivered below; upper_mult: unique words
        // the upper level serves (multicast dedup at the array edge).
        let (lower_mult, upper_mult, crosses_fabric) = if i < sp {
            (pes, pes, false)
        } else if i == sp {
            (pes, smap.unique_factor(t) as f64, true)
        } else {
            (1.0, 1.0, false)
        };

        if t == Tensor::Output {
            let wb = rounds * tile; // writeback rounds (per lower instance)
            let rr = (rounds - distinct_row[i]).max(0.0) * tile; // partial re-reads

            // Up: lower reads, upper writes.
            buf.levels[i].writes[ti] += wb * upper_mult;
            if i >= 1 {
                buf.levels[i - 1].reads[ti] += wb * lower_mult;
            }
            // Down (partial refill): upper reads, lower writes.
            buf.levels[i].reads[ti] += rr * upper_mult;
            if i >= 1 {
                buf.levels[i - 1].writes[ti] += rr * lower_mult;
            }
            if crosses_fabric {
                buf.fabric_words[ti] += (wb + rr) * pes;
                if arch.bus == ArrayBus::Broadcast {
                    // no in-fabric accumulation: the buffer absorbs and
                    // merges every PE's partial sums itself
                    let extra = (wb + rr) * (pes - upper_mult).max(0.0);
                    buf.levels[i].writes[ti] += extra;
                    buf.levels[i].reads[ti] += extra;
                }
            }
        } else {
            let words = rounds * tile;
            // Down: upper reads, lower writes.
            buf.levels[i].reads[ti] += words * upper_mult;
            if i >= 1 {
                buf.levels[i - 1].writes[ti] += words * lower_mult;
            }
            if crosses_fabric {
                buf.fabric_words[ti] += words * pes;
            }
        }
    }

    let hops_per_word = match arch.bus {
        ArrayBus::Systolic => 1.0 + smap.share_hops(t),
        ArrayBus::Broadcast => (arch.array.rows as f64 + arch.array.cols as f64) / 4.0,
    };
    buf.fabric_hops += buf.fabric_words[ti] * hops_per_word;
}

/// Canonical energy roll-up over a (possibly partially accumulated)
/// counts buffer: level energies summed innermost-out, plus fabric and
/// MAC energy — the identical summation order the legacy `assemble` used,
/// so on a fully accumulated buffer this **is** the final `energy_pj`
/// bit-for-bit, and on a partial buffer it is an admissible lower bound.
pub fn energy_total(
    buf: &CountsBuf,
    nlv: usize,
    level_cost: &[f64; MAX_LEVELS],
    hop_pj: f64,
    mac_energy: f64,
) -> f64 {
    let mut sum = 0.0;
    for i in 0..nlv {
        sum += buf.levels[i].total() * level_cost[i];
    }
    sum + buf.fabric_hops * hop_pj + mac_energy
}
