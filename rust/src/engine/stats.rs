//! Evaluation counters and the shared incumbent bound.
//!
//! [`EvalStats`] counts how far candidates travel through the staged
//! pipeline (thread-safe, relaxed atomics — the counts are telemetry, not
//! synchronization). [`Incumbent`] is the best energy seen so far, shared
//! across worker threads as a monotonically decreasing atomic f64.

use std::sync::atomic::{AtomicU64, Ordering};

/// Thread-safe pipeline counters, bumped by the engine as candidates move
/// through the stages.
#[derive(Debug, Default)]
pub struct EvalStats {
    /// Stage-2 footprint computations (one per blocking table).
    pub stage2: AtomicU64,
    /// Candidates rejected by the stage-2 capacity check. Note: tables
    /// coming out of `enumerate_blockings` already passed the same check
    /// inside the enumeration recursion, so search paths report 0 here;
    /// this counts direct engine callers (random mappings, presets).
    pub fit_rejected: AtomicU64,
    /// Stage-3 bounded evaluations started (one per blocking × order).
    pub stage3: AtomicU64,
    /// Stage-3 evaluations abandoned because a partial lower bound
    /// exceeded the incumbent.
    pub pruned: AtomicU64,
    /// Full evaluations: candidates that completed stage 3 and had their
    /// exact energy rolled up (stage 4).
    pub full: AtomicU64,
}

impl EvalStats {
    /// Plain-value copy of the counters.
    pub fn snapshot(&self) -> EvalSnapshot {
        EvalSnapshot {
            stage2: self.stage2.load(Ordering::Relaxed),
            fit_rejected: self.fit_rejected.load(Ordering::Relaxed),
            stage3: self.stage3.load(Ordering::Relaxed),
            pruned: self.pruned.load(Ordering::Relaxed),
            full: self.full.load(Ordering::Relaxed),
        }
    }

    #[inline]
    pub(crate) fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }
}

/// Plain-value snapshot of [`EvalStats`].
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct EvalSnapshot {
    /// Stage-2 footprint computations.
    pub stage2: u64,
    /// Stage-2 capacity rejections.
    pub fit_rejected: u64,
    /// Stage-3 bounded evaluations started.
    pub stage3: u64,
    /// Stage-3 evaluations pruned by bound.
    pub pruned: u64,
    /// Completed full (stage-4) evaluations.
    pub full: u64,
}

impl EvalSnapshot {
    /// Accumulate another snapshot's counters — the roll-up used by
    /// network- and fleet-level reports ([`crate::search::NetworkOpt`],
    /// [`crate::netopt::NetOptStats`]).
    pub fn absorb(&mut self, other: &EvalSnapshot) {
        self.stage2 += other.stage2;
        self.fit_rejected += other.fit_rejected;
        self.stage3 += other.stage3;
        self.pruned += other.pruned;
        self.full += other.full;
    }

    /// Fraction of started stage-3 evaluations that were pruned.
    pub fn prune_rate(&self) -> f64 {
        if self.stage3 == 0 {
            0.0
        } else {
            self.pruned as f64 / self.stage3 as f64
        }
    }
}

impl std::fmt::Display for EvalSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "stage2 {} (fit- {}), stage3 {}, pruned {} ({:.1}%), full {}",
            self.stage2,
            self.fit_rejected,
            self.stage3,
            self.pruned,
            100.0 * self.prune_rate(),
            self.full
        )
    }
}

/// The best (lowest) energy observed so far, shared across threads.
///
/// Energies are positive finite f64s, stored as bits; updates are
/// monotonic minima via compare-and-swap, so a racy read only ever
/// returns a value that *was* the incumbent — always a correct (possibly
/// stale, i.e. looser) pruning bound.
#[derive(Debug)]
pub struct Incumbent(AtomicU64);

impl Incumbent {
    /// Fresh incumbent at +infinity (nothing prunes).
    pub fn new() -> Self {
        Self::with_bound(f64::INFINITY)
    }

    /// Incumbent pre-seeded at `bound` — e.g. a best-known energy carried
    /// over from an earlier search. `f64::INFINITY` behaves like [`new`].
    /// Seeding prunes candidates against `bound` from the start, so the
    /// search result is only guaranteed to equal the unseeded optimum
    /// when that optimum is `<= bound` (see `netopt`'s rerun fallback).
    ///
    /// [`new`]: Incumbent::new
    pub fn with_bound(bound: f64) -> Self {
        Incumbent(AtomicU64::new(bound.to_bits()))
    }

    /// Current bound.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }

    /// Lower the incumbent to `energy` if it improves on the current one.
    pub fn observe(&self, energy: f64) {
        let _ = self
            .0
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
                if f64::from_bits(cur) <= energy {
                    None
                } else {
                    Some(energy.to_bits())
                }
            });
    }
}

impl Default for Incumbent {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn incumbent_takes_minimum() {
        let inc = Incumbent::new();
        assert_eq!(inc.get(), f64::INFINITY);
        inc.observe(5.0);
        inc.observe(9.0);
        assert_eq!(inc.get(), 5.0);
        inc.observe(2.5);
        assert_eq!(inc.get(), 2.5);
    }

    #[test]
    fn incumbent_concurrent_minimum() {
        let inc = Incumbent::new();
        std::thread::scope(|s| {
            for k in 0..8u64 {
                let inc = &inc;
                s.spawn(move || {
                    for i in 0..1000u64 {
                        inc.observe(1.0 + ((i * 7 + k * 13) % 100) as f64);
                    }
                });
            }
        });
        assert_eq!(inc.get(), 1.0);
    }

    #[test]
    fn seeded_incumbent_prunes_from_the_start() {
        let inc = Incumbent::with_bound(10.0);
        assert_eq!(inc.get(), 10.0);
        inc.observe(12.0); // worse than the seed: ignored
        assert_eq!(inc.get(), 10.0);
        inc.observe(4.0);
        assert_eq!(inc.get(), 4.0);
    }

    #[test]
    fn snapshot_absorb_sums_counters() {
        let mut a = EvalSnapshot {
            stage2: 1,
            fit_rejected: 2,
            stage3: 3,
            pruned: 4,
            full: 5,
        };
        let b = EvalSnapshot {
            stage2: 10,
            fit_rejected: 20,
            stage3: 30,
            pruned: 40,
            full: 50,
        };
        a.absorb(&b);
        assert_eq!(a.stage2, 11);
        assert_eq!(a.fit_rejected, 22);
        assert_eq!(a.stage3, 33);
        assert_eq!(a.pruned, 44);
        assert_eq!(a.full, 55);
    }

    #[test]
    fn snapshot_and_display() {
        let stats = EvalStats::default();
        EvalStats::bump(&stats.stage3);
        EvalStats::bump(&stats.stage3);
        EvalStats::bump(&stats.pruned);
        let snap = stats.snapshot();
        assert_eq!(snap.stage3, 2);
        assert_eq!(snap.pruned, 1);
        assert!((snap.prune_rate() - 0.5).abs() < 1e-12);
        assert!(format!("{snap}").contains("pruned 1"));
    }
}
