//! Stage 4: energy / performance roll-up into a [`ModelResult`].
//!
//! Only candidates that survive pruning (in practice: the winner, plus
//! every candidate in exhaustive mode) pay for the allocations here; the
//! scalar energy used during the search comes from
//! [`super::counts::energy_total`] and bit-matches this roll-up by
//! construction (same summation order).

use super::counts::{accumulate_tensor, CountsBuf};
use super::footprint::Footprints;
use crate::arch::Arch;
use crate::dataflow::{utilization, SpatialMap};
use crate::energy::CostModel;
use crate::loopnest::{Mapping, ALL_TENSORS};
use crate::xmodel::{ModelResult, RoundTables};

/// Materialize the full [`ModelResult`] from an accumulated counts
/// buffer — identical arithmetic to the tail of the seed's monolithic
/// `xmodel::assemble`.
pub fn model_result(
    m: &Mapping,
    smap: &SpatialMap,
    arch: &Arch,
    cost: &dyn CostModel,
    buf: &CountsBuf,
) -> ModelResult {
    let nlv = m.levels();

    // Energy.
    let mut energy_by_level = Vec::with_capacity(nlv);
    for (i, lc) in buf.levels.iter().enumerate().take(nlv) {
        energy_by_level.push(lc.total() * cost.level_access(arch, i));
    }
    let fabric_energy = buf.fabric_hops * cost.hop();
    let macs = m.shape.macs();
    let mac_energy = macs as f64 * cost.mac();
    let energy_pj = energy_by_level.iter().sum::<f64>() + fabric_energy + mac_energy;

    // Performance.
    let util = utilization(&m.shape, smap, &arch.array);
    let compute_cycles = if util > 0.0 {
        macs as f64 / (arch.array.pes() as f64 * util)
    } else {
        f64::INFINITY
    };
    let dram = buf.levels[..nlv].last().map(|lc| lc.total()).unwrap_or(0.0);
    let dram_cycles = dram * arch.word_bytes as f64 / arch.dram_bw_bytes_per_cycle;
    let cycles = compute_cycles.max(dram_cycles);

    ModelResult {
        levels: buf.levels[..nlv].to_vec(),
        fabric_words: buf.fabric_words,
        fabric_hops: buf.fabric_hops,
        macs,
        active_pes: m.pe_count(),
        energy_by_level,
        fabric_energy,
        mac_energy,
        energy_pj,
        cycles,
        utilization: util,
    }
}

/// Assemble a [`ModelResult`] from externally supplied per-boundary round
/// tables — the shared back half of the analytical model and the trace
/// simulator ([`crate::sim::simulate`] feeds exact walked counts through
/// here; `xmodel::assemble` is a shim over this).
pub fn assemble(
    m: &Mapping,
    smap: &SpatialMap,
    arch: &Arch,
    cost: &dyn CostModel,
    tables: &RoundTables,
) -> ModelResult {
    let fp = Footprints::compute(m);
    let nlv = m.levels();
    let sp = m.spatial_at;
    let pes = m.pe_count() as f64;
    let mut buf = CountsBuf::default();
    for t in ALL_TENSORS {
        accumulate_tensor(
            &mut buf,
            t,
            &tables.rounds[t.idx()],
            &tables.distinct[t.idx()],
            &fp.tiles,
            nlv,
            sp,
            pes,
            smap,
            arch,
        );
    }
    model_result(m, smap, arch, cost, &buf)
}
