//! Serving-time remapping: mix-aware online re-optimization of the
//! active accelerator mapping plan.
//!
//! The paper's central result is that resource allocation and mapping —
//! not dataflow — dominate energy, which means a serving system whose
//! workload mix shifts should *re-derive* its mappings online rather
//! than pin the offline winner. This module closes that loop:
//!
//! 1. **Mix tracking** — a [`MixWindow`] holds the last `W` served
//!    artifact names and their counts (deterministic `BTreeMap`
//!    ordering, so every downstream decision is a pure function of the
//!    trace).
//! 2. **Drift detection** — after every serving batch the
//!    [`Remapper`] compares the window mix against the mix the active
//!    plan was optimized for; when the total-variation distance
//!    ([`mix_drift`]) exceeds [`RemapPolicy::drift`], it re-optimizes.
//! 3. **Re-optimization** — the window counts become a *mix network*
//!    ([`mix_network`]: each artifact's representative layers, weighted
//!    by its window count) and
//!    [`co_optimize_arches_seeded`](crate::netopt::co_optimize_arches_seeded)
//!    searches the candidate architecture list **warm-started from the
//!    [`SeedTable`]** accumulated across every earlier remap — the same
//!    seeds representation the sharded sweeps checkpoint. Seeds only
//!    prune (the netopt rerun fallback keeps the argmin exact), so the
//!    online winner is bit-identical to an offline
//!    [`co_optimize_arches`](crate::netopt::co_optimize_arches) run on
//!    the same mix — `coordinator::tests` asserts it.
//! 4. **Plan swap** — the new [`MappingPlan`] is published through an
//!    mpsc plan-swap channel; the serving loop
//!    ([`serve_with`](super::serve::serve_with)) drains it **between
//!    batches** and hands it to every worker's executor via
//!    [`Executor::adopt_plan`](super::serve::Executor::adopt_plan) at
//!    the next batch's start, so worker replicas are never stopped and
//!    an in-flight batch always completes under the plan it started
//!    with (the swap itself is an `Arc` pointer move — no worker ever
//!    observes a partially built plan).
//!
//! Because observation, drift, and re-optimization are all pure
//! functions of the request trace (never of timing or thread count),
//! serving statistics — including the remap count — stay byte-identical
//! across worker counts, extending the serve-loop determinism contract.
//!
//! ## Deadline fast path
//!
//! [`RemapPolicy::with_deadline`] bounds the drift-to-first-plan latency
//! by the microsecond heuristic mapper ([`crate::fastmap`]): on drift,
//! the heuristic plan over the same candidates is published immediately
//! (tagged [`MappingPlan::fast`]) and the exact search is *deferred* —
//! the triggering window counts are snapshotted and the branch-and-bound
//! runs at the next batch boundary (or the end-of-trace
//! `flush_pending`), hot-swapping the exact plan through the same
//! channel. The deferral is trace-deterministic, never wall-clock: a
//! fast attempt stamps `last_mix` at the same boundaries as an exact
//! one, so the trigger sequence — and therefore the final adopted plan,
//! bit for bit — matches the no-deadline run (`coordinator::tests`
//! asserts it). A fresh trigger drops a stale pending snapshot (its
//! exact plan would be immediately superseded anyway), so a fast-moving
//! mix can legitimately run *fewer* exact searches than the no-deadline
//! path. One corner is intentionally out of scope: with a
//! `latency_budget`, a heuristic plan can publish for a mix whose exact
//! frontier later has no point inside the budget — the fast plan then
//! stays active where the no-deadline path would have kept the previous
//! plan; combine the deadline with budgets only when that transient is
//! acceptable.

use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::arch::{eyeriss_like, no_local_reuse, small_rf, Arch, ArrayShape};
use crate::dataflow::Dataflow;
use crate::energy::Table3;
use crate::fastmap;
use crate::netopt::{co_optimize_arches_seeded, DesignSpace, NetOptConfig, SeedTable};
use crate::nn::{Layer, Network};
use crate::pareto::{
    pareto_optimize_arches_seeded, pareto_optimize_seeded, ParetoConfig, PlanSelector,
};
use crate::search::{HierarchyResult, LayerOpt, SearchOpts};
use crate::telemetry;
use crate::util::json::Json;

/// When to re-optimize: window size and drift threshold, plus the
/// search budget each re-optimization is allowed.
#[derive(Debug, Clone)]
pub struct RemapPolicy {
    /// Sliding-window length, in requests (`>= 1`).
    pub window: usize,
    /// Total-variation drift threshold in `[0, 1]`: re-optimize when the
    /// window mix moved further than this from the active plan's mix.
    pub drift: f64,
    /// Per-layer search options for re-optimizations (request-path
    /// budget: keep the caps small).
    pub opts: SearchOpts,
    /// Worker threads for the re-optimization search (independent of
    /// the serving worker count — determinism across serving thread
    /// counts never depends on this).
    pub threads: usize,
    /// Latency budget for plan selection, in weighted cycles over one
    /// full mix window ("cycles to serve a window of requests"). When
    /// set, each remap computes the candidates' energy/latency frontier
    /// and a [`PlanSelector`] picks the min-energy point within the
    /// budget, instead of the unconstrained scalar argmin. A remap whose
    /// frontier has no point inside the budget keeps the current plan.
    pub latency_budget: Option<f64>,
    /// Deadline mode: on drift, publish the microsecond heuristic plan
    /// ([`crate::fastmap::heuristic_plan`]) immediately and defer the
    /// exact search to the next batch boundary (see the module docs'
    /// "Deadline fast path"). The final adopted plan stays bit-identical
    /// to the no-deadline run; only the transient differs.
    pub deadline: bool,
}

impl RemapPolicy {
    /// A policy with the default request-path search budget.
    pub fn new(window: usize, drift: f64) -> RemapPolicy {
        let mut opts = SearchOpts::capped(150, 4);
        opts.max_order_combos = 9;
        RemapPolicy {
            window,
            drift,
            opts,
            threads: 1,
            latency_budget: None,
            deadline: false,
        }
    }

    /// Same policy with a latency budget (weighted cycles per window).
    pub fn with_latency_budget(mut self, cycles: f64) -> RemapPolicy {
        self.latency_budget = Some(cycles);
        self
    }

    /// Same policy with the deadline fast path enabled (see
    /// [`deadline`](Self::deadline)).
    pub fn with_deadline(mut self) -> RemapPolicy {
        self.deadline = true;
        self
    }
}

/// Sliding window over served artifact names with deterministic
/// (name-sorted) counts.
#[derive(Debug, Clone)]
pub struct MixWindow {
    cap: usize,
    order: VecDeque<String>,
    counts: BTreeMap<String, usize>,
}

impl MixWindow {
    /// An empty window holding at most `cap` requests.
    pub fn new(cap: usize) -> MixWindow {
        assert!(cap >= 1, "mix window must hold at least one request");
        MixWindow {
            cap,
            order: VecDeque::with_capacity(cap),
            counts: BTreeMap::new(),
        }
    }

    /// Record one served request, evicting the oldest once full.
    pub fn push(&mut self, artifact: &str) {
        if self.order.len() == self.cap {
            let old = self.order.pop_front().expect("full window");
            let emptied = match self.counts.get_mut(&old) {
                Some(c) if *c > 1 => {
                    *c -= 1;
                    false
                }
                _ => true,
            };
            if emptied {
                self.counts.remove(&old);
            }
        }
        self.order.push_back(artifact.to_string());
        *self.counts.entry(artifact.to_string()).or_insert(0) += 1;
    }

    /// Requests currently in the window.
    pub fn len(&self) -> usize {
        self.order.len()
    }

    /// True before any request was observed.
    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// Name-sorted `(artifact, count)` pairs.
    pub fn counts(&self) -> Vec<(String, usize)> {
        self.counts.iter().map(|(k, v)| (k.clone(), *v)).collect()
    }

    /// Name-sorted `(artifact, frequency)` pairs (frequencies sum to 1).
    pub fn mix(&self) -> Vec<(String, f64)> {
        let n = self.order.len().max(1) as f64;
        self.counts
            .iter()
            .map(|(k, v)| (k.clone(), *v as f64 / n))
            .collect()
    }
}

/// Total-variation distance between two name-sorted frequency vectors:
/// `0.5 × Σ |p − q|` over the union of artifact names, in `[0, 1]`.
pub fn mix_drift(a: &[(String, f64)], b: &[(String, f64)]) -> f64 {
    let mut sum = 0.0;
    let (mut ia, mut ib) = (0usize, 0usize);
    while ia < a.len() || ib < b.len() {
        match (a.get(ia), b.get(ib)) {
            (Some(x), Some(y)) => match x.0.cmp(&y.0) {
                std::cmp::Ordering::Less => {
                    sum += x.1;
                    ia += 1;
                }
                std::cmp::Ordering::Greater => {
                    sum += y.1;
                    ib += 1;
                }
                std::cmp::Ordering::Equal => {
                    sum += (x.1 - y.1).abs();
                    ia += 1;
                    ib += 1;
                }
            },
            (Some(x), None) => {
                sum += x.1;
                ia += 1;
            }
            (None, Some(y)) => {
                sum += y.1;
                ib += 1;
            }
            (None, None) => unreachable!(),
        }
    }
    0.5 * sum
}

/// The analytical layer model of one serving artifact — the same shapes
/// `python/compile/aot.py` lowers to HLO (reduced-scale stand-ins for
/// the paper's workload families), expressed in the seven-loop nest so
/// the netopt machinery can optimize them.
pub fn artifact_network(name: &str) -> Option<Network> {
    let layers = match name {
        // input (2,10,10,16) ⊛ (3,3,16,32), stride 1 → 8×8 output
        "conv3x3" => vec![Layer::conv("conv3x3", 2, 32, 16, 8, 8, 3, 1)],
        // input (2,8,8,32) × (32,16) pointwise reduction
        "conv1x1" => vec![Layer::conv("conv1x1", 2, 16, 32, 8, 8, 1, 1)],
        // input (1,13,13,8) ⊛ (5,5,8,16), stride 2 → 5×5 output
        "conv5x5_s2" => vec![Layer::conv("conv5x5_s2", 1, 16, 8, 5, 5, 5, 2)],
        // input (2,10,10,16) ⊛ (3,3,16) depthwise → 8×8 output
        "depthwise" => vec![Layer::depthwise("depthwise", 2, 16, 8, 8, 3, 1)],
        // (8,64) × (64,32)
        "fc" => vec![Layer::fc("fc", 8, 32, 64)],
        // x(4,32) × w_ih(32,128) and h(4,32) × w_hh(32,128): two gate
        // banks of hidden size 32
        "lstm_cell" => vec![
            Layer::lstm_gate("lstm_ih", 4, 32, 32),
            Layer::lstm_gate("lstm_hh", 4, 32, 32),
        ],
        // (1,8,8,8) ⊛ (3,3,8,16) → 6×6, then ⊛ (3,3,16,16) → 4×4
        "conv_chain" => vec![
            Layer::conv("chain1", 1, 16, 8, 6, 6, 3, 1),
            Layer::conv("chain2", 1, 16, 16, 4, 4, 3, 1),
        ],
        _ => return None,
    };
    Some(Network {
        name: name.to_string(),
        layers,
        batch: 1,
    })
}

/// Build the mix network for a window: each artifact's representative
/// layers concatenated in name order, every layer weighted by its
/// artifact's window count. Returns the network, the per-layer weight
/// vector (for [`NetOptConfig::layer_weights`]) and the per-artifact
/// `(name, start, len)` spans into the layer list.
pub fn mix_network(counts: &[(String, usize)]) -> (Network, Vec<f64>, Vec<(String, usize, usize)>) {
    let mut layers = Vec::new();
    let mut weights = Vec::new();
    let mut spans = Vec::new();
    for (name, count) in counts {
        assert!(*count > 0, "zero-count artifact `{name}` in mix");
        let net = artifact_network(name)
            .unwrap_or_else(|| panic!("unknown artifact `{name}` in serving mix"));
        spans.push((name.clone(), layers.len(), net.layers.len()));
        for l in net.layers {
            layers.push(l);
            weights.push(*count as f64);
        }
    }
    (
        Network {
            name: "mix".to_string(),
            layers,
            batch: 1,
        },
        weights,
        spans,
    )
}

/// One generation of the active serving plan: the mix it was optimized
/// for, the winning architecture point with its per-layer mappings, and
/// where each artifact's layers live in that result.
#[derive(Debug, Clone)]
pub struct MappingPlan {
    /// Monotonic plan generation (0 = first plan of a remapper).
    pub epoch: usize,
    /// The window counts the plan was optimized for (name-sorted).
    pub mix: Vec<(String, usize)>,
    /// The winning architecture and the mix network's optimization.
    pub winner: HierarchyResult,
    /// Per-artifact `(name, start, len)` spans into
    /// `winner.opt.per_layer`.
    pub spans: Vec<(String, usize, usize)>,
    /// `true` for a transient heuristic plan published by the deadline
    /// fast path; the exact plan for the same mix (or a fresher one)
    /// always follows through the same channel.
    pub fast: bool,
}

impl MappingPlan {
    /// The per-layer mappings chosen for one artifact under this plan.
    pub fn artifact_layers(&self, name: &str) -> Option<&[Option<LayerOpt>]> {
        self.spans
            .iter()
            .find(|(n, _, _)| n == name)
            .map(|(_, start, len)| &self.winner.opt.per_layer[*start..*start + *len])
    }
}

/// Where remap candidates come from.
enum PlanSource {
    /// A fixed explicit architecture list (the original behavior).
    Fixed(Vec<Arch>),
    /// A live [`DesignSpace`]: every remap re-enumerates the space and
    /// re-selects from its Pareto frontier, so serving is never pinned
    /// to a hand-picked candidate list.
    Space(DesignSpace),
}

/// The serving-time remapper: tracks the request mix, detects drift,
/// re-optimizes warm-started from the accumulated [`SeedTable`], and
/// publishes new [`MappingPlan`]s through the plan-swap channel. With a
/// latency budget (or a live-space source) the re-optimization computes
/// the full energy/latency frontier and a [`PlanSelector`] picks the
/// min-energy point inside the budget.
pub struct Remapper {
    policy: RemapPolicy,
    source: PlanSource,
    /// The frontier the active plan was selected from (`None` until a
    /// frontier-mode remap ran; the fixed-list scalar path leaves it
    /// empty).
    selector: Option<PlanSelector>,
    window: MixWindow,
    /// The window mix at the last re-optimization *attempt* (`None`
    /// until the first attempt — any traffic then triggers one).
    /// Failed attempts record it too: re-optimization is a pure
    /// function of the mix, so retrying before the mix drifts again
    /// could only repeat the failure.
    last_mix: Option<Vec<(String, f64)>>,
    /// Deadline mode: the window-counts snapshot of a drift whose fast
    /// plan was published but whose exact search is still owed. Serviced
    /// at the next batch boundary (or `flush_pending`); dropped when a
    /// fresh drift supersedes it.
    pending_exact: Option<Vec<(String, usize)>>,
    seeds: SeedTable,
    plan: Option<Arc<MappingPlan>>,
    epoch: usize,
    tx: Sender<Arc<MappingPlan>>,
    rx: Receiver<Arc<MappingPlan>>,
    /// Drift checks performed.
    pub checks: usize,
    /// Re-optimizations that produced (and published) a plan.
    pub remaps: usize,
    /// Heuristic fast-path plans published (deadline mode only).
    pub fast_plans: usize,
}

impl Remapper {
    /// A remapper over an explicit candidate architecture list.
    pub fn new(policy: RemapPolicy, arches: Vec<Arch>) -> Remapper {
        assert!(!arches.is_empty(), "need at least one candidate arch");
        Self::with_source(policy, PlanSource::Fixed(arches))
    }

    /// A remapper whose candidates are a live [`DesignSpace`]: every
    /// remap re-enumerates the space and selects from its frontier
    /// (under [`RemapPolicy::latency_budget`] when set). Keep serving
    /// spaces small — the enumeration runs on the remap path.
    pub fn with_space(policy: RemapPolicy, space: DesignSpace) -> Remapper {
        Self::with_source(policy, PlanSource::Space(space))
    }

    fn with_source(policy: RemapPolicy, source: PlanSource) -> Remapper {
        let window = MixWindow::new(policy.window);
        let (tx, rx) = channel();
        Remapper {
            policy,
            source,
            selector: None,
            window,
            last_mix: None,
            pending_exact: None,
            seeds: SeedTable::new(),
            plan: None,
            epoch: 0,
            tx,
            rx,
            checks: 0,
            remaps: 0,
            fast_plans: 0,
        }
    }

    /// Default candidate points for serving: the paper's three
    /// small-chip configurations (grid-inexpressible candidates ride the
    /// same explicit-list entry point the TPU-like baseline uses).
    pub fn default_candidates() -> Vec<Arch> {
        vec![eyeriss_like(), no_local_reuse(), small_rf()]
    }

    /// A compact live design space for serving-time re-selection: a
    /// trimmed paper grid (two RF sizes, one two-level step, two buffer
    /// sizes on 16×16 PEs, ratio rule widened so the single-level
    /// points survive) — 8 raw points, small enough for the remap path.
    pub fn default_space() -> DesignSpace {
        let mut s = DesignSpace::paper_default(ArrayShape { rows: 16, cols: 16 });
        s.rf1_sizes = vec![16, 64];
        s.rf2_ratios = vec![8];
        s.gbuf_sizes = vec![64 << 10, 128 << 10];
        s.ratio_min = 0.25;
        s.ratio_max = 64.0;
        s
    }

    /// Record one served request into the sliding window.
    pub fn observe(&mut self, artifact: &str) {
        self.window.push(artifact);
    }

    /// Current drift of the window mix from the last re-optimization
    /// attempt's mix (`1.0` before the first attempt).
    pub fn drift(&self) -> f64 {
        match &self.last_mix {
            None => 1.0,
            Some(m) => mix_drift(m, &self.window.mix()),
        }
    }

    /// Batch-boundary hook: re-optimize when the mix drifted past the
    /// policy threshold (or no plan exists yet). Returns whether a
    /// remap ran. A pure function of the observed trace — never of
    /// timing or thread count.
    pub fn maybe_remap(&mut self) -> bool {
        if self.window.is_empty() {
            return self.flush_pending();
        }
        self.checks += 1;
        let trigger = match &self.last_mix {
            None => true,
            Some(m) => mix_drift(m, &self.window.mix()) > self.policy.drift,
        };
        if !trigger {
            // quiet boundary: pay off a deferred exact search, if owed
            return self.flush_pending();
        }
        telemetry::event("fleet", "drift", || {
            vec![
                ("drift".into(), Json::num(self.drift())),
                ("threshold".into(), Json::num(self.policy.drift)),
            ]
        });
        // a fresh drift supersedes any owed exact search — its plan
        // would be replaced by this remap's anyway
        self.pending_exact = None;
        self.remap_now().is_some()
    }

    /// Re-optimize for the current window mix unconditionally,
    /// warm-started from the accumulated seeds, and publish the new plan
    /// through the plan-swap channel. Returns `None` (keeping the old
    /// plan active) when no candidate architecture maps every layer of
    /// the mix — or, under a latency budget, when no frontier point
    /// fits the budget. In deadline mode the returned plan is the
    /// immediately-published heuristic one and the exact search is owed
    /// (see [`flush_pending`](Self::flush_pending)); without a deadline
    /// it is the exact winner.
    pub fn remap_now(&mut self) -> Option<Arc<MappingPlan>> {
        let counts = self.window.counts();
        if counts.is_empty() {
            return None;
        }
        // Stamp the attempted mix up front — the window cannot change
        // mid-call, so this is equivalent to the historical success- and
        // failed-attempt-path writes. Re-optimization is a pure function
        // of the mix, so an identical mix is never retried before it
        // drifts again. Deadline mode relies on the stamp landing here,
        // at the *trigger* boundary: the deferred exact search runs
        // against a moved window and must never re-stamp, or the
        // trigger sequence would diverge from the no-deadline run.
        self.last_mix = Some(self.window.mix());
        if self.policy.deadline {
            if let Some(plan) = self.publish_fast(&counts) {
                self.pending_exact = Some(counts);
                return Some(plan);
            }
            // no feasible heuristic plan — run the exact search
            // synchronously; nothing was published yet
        }
        self.exact_remap(counts)
    }

    /// Build and publish the heuristic fast-path plan for a triggering
    /// mix ([`crate::fastmap::heuristic_plan`] — microseconds per
    /// candidate). Candidates mirror the exact path's: the fixed list,
    /// or the live space's current enumeration. Returns `None` when no
    /// candidate heuristically maps the whole mix (within the latency
    /// budget, when set).
    fn publish_fast(&mut self, counts: &[(String, usize)]) -> Option<Arc<MappingPlan>> {
        let (net, weights, spans) = mix_network(counts);
        let df = Dataflow::parse("C|K").unwrap();
        let winner = match &self.source {
            PlanSource::Fixed(arches) => fastmap::heuristic_plan(
                &net,
                arches,
                &df,
                &Table3,
                Some(weights.as_slice()),
                self.policy.latency_budget,
            ),
            PlanSource::Space(space) => fastmap::heuristic_plan(
                &net,
                &space.enumerate().candidates,
                &df,
                &Table3,
                Some(weights.as_slice()),
                self.policy.latency_budget,
            ),
        }?;
        let plan = Arc::new(MappingPlan {
            epoch: self.epoch,
            mix: counts.to_vec(),
            winner,
            spans,
            fast: true,
        });
        self.epoch += 1;
        self.fast_plans += 1;
        self.plan = Some(plan.clone());
        // receiver lives in self, so the channel can never be closed
        self.tx.send(plan.clone()).expect("plan-swap channel");
        Some(plan)
    }

    /// Service a deferred exact search, if one is owed. Returns whether
    /// a plan was published. The serving loop calls this through
    /// [`maybe_remap`](Self::maybe_remap) at quiet batch boundaries and
    /// directly once after the trace ends, so a deadline run always
    /// converges to the exact plan of its last triggering mix.
    pub fn flush_pending(&mut self) -> bool {
        match self.pending_exact.take() {
            Some(counts) => self.exact_remap(counts).is_some(),
            None => false,
        }
    }

    /// The branch-and-bound re-optimization for a counts snapshot —
    /// shared by the synchronous path and the deferred deadline path.
    /// Never touches `last_mix` (the caller stamped it at the trigger
    /// boundary); failure keeps the old plan active.
    fn exact_remap(&mut self, counts: Vec<(String, usize)>) -> Option<Arc<MappingPlan>> {
        let (net, weights, spans) = mix_network(&counts);
        let cfg = NetOptConfig::new(self.policy.opts.clone(), self.policy.threads)
            .with_layer_weights(weights);
        // The frontier path serves live spaces and latency budgets; the
        // fixed-list unconstrained path keeps the original scalar
        // argmin, bit for bit.
        let frontier_mode =
            self.policy.latency_budget.is_some() || matches!(self.source, PlanSource::Space(_));
        let winner = if frontier_mode {
            let pcfg = ParetoConfig::default();
            let res = match &self.source {
                PlanSource::Fixed(arches) => pareto_optimize_arches_seeded(
                    &net,
                    arches,
                    &Table3,
                    &cfg,
                    &pcfg,
                    &self.seeds,
                ),
                PlanSource::Space(space) => {
                    pareto_optimize_seeded(&net, space, &Table3, &cfg, &pcfg, &self.seeds)
                }
            };
            // carry everything this run learned into the next warm start
            self.seeds.merge(&res.seeds);
            let sel = PlanSelector::new(res.frontier);
            let chosen = sel
                .select(self.policy.latency_budget)
                .map(|e| e.result.clone());
            match chosen {
                Some(w) => {
                    // `selector` documents the frontier the *active*
                    // plan was selected from — only replace it when a
                    // plan is actually installed.
                    self.selector = Some(sel);
                    w
                }
                None => return None,
            }
        } else {
            let PlanSource::Fixed(arches) = &self.source else {
                unreachable!("non-frontier mode implies a fixed list")
            };
            let res = co_optimize_arches_seeded(&net, arches, &Table3, &cfg, &self.seeds);
            // carry everything this run learned into the next warm start
            self.seeds.merge(&res.seeds);
            match res.best() {
                Some(w) => w.clone(),
                None => return None,
            }
        };
        let plan = Arc::new(MappingPlan {
            epoch: self.epoch,
            mix: counts,
            winner,
            spans,
            fast: false,
        });
        self.epoch += 1;
        self.remaps += 1;
        self.plan = Some(plan.clone());
        // receiver lives in self, so the channel can never be closed
        self.tx.send(plan.clone()).expect("plan-swap channel");
        Some(plan)
    }

    /// Drain one pending plan from the plan-swap channel (the serving
    /// loop calls this between batches until it returns `None`).
    pub fn take_plan(&mut self) -> Option<Arc<MappingPlan>> {
        self.rx.try_recv().ok()
    }

    /// The active plan, if any remap has succeeded.
    pub fn plan(&self) -> Option<Arc<MappingPlan>> {
        self.plan.clone()
    }

    /// The accumulated cross-remap seeds table.
    pub fn seeds(&self) -> &SeedTable {
        &self.seeds
    }

    /// Warm-start: min-merge a pre-existing seeds table (e.g. a
    /// checkpointed sweep's — the fleet loads one from a
    /// `ShardCheckpoint` / `FrontierCheckpoint` and primes every
    /// worker's remapper with it) into the accumulated table, so the
    /// first remap already prunes with everything the sweep learned.
    /// Seeds are hints, never trusted results (netopt's rerun fallback),
    /// so priming can only prune work — every published plan stays
    /// bit-identical to the cold-start plan.
    pub fn prime_seeds(&mut self, seeds: &SeedTable) {
        self.seeds.merge(seeds);
    }

    /// The candidate architecture list (`None` for a live-space source,
    /// whose candidates are re-enumerated at every remap).
    pub fn candidates(&self) -> Option<&[Arch]> {
        match &self.source {
            PlanSource::Fixed(arches) => Some(arches),
            PlanSource::Space(_) => None,
        }
    }

    /// The frontier the active plan was selected from (`None` before the
    /// first frontier-mode remap, and always for the fixed-list scalar
    /// path).
    pub fn selector(&self) -> Option<&PlanSelector> {
        self.selector.as_ref()
    }

    /// The policy in force.
    pub fn policy(&self) -> &RemapPolicy {
        &self.policy
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_evicts_oldest_and_counts_deterministically() {
        let mut w = MixWindow::new(3);
        for a in ["x", "y", "x", "z"] {
            w.push(a);
        }
        // "x" (the first) evicted; window = [y, x, z]
        assert_eq!(w.len(), 3);
        assert_eq!(
            w.counts(),
            vec![
                ("x".to_string(), 1),
                ("y".to_string(), 1),
                ("z".to_string(), 1)
            ]
        );
        w.push("z");
        w.push("z");
        // window = [z, z, z]
        assert_eq!(w.counts(), vec![("z".to_string(), 3)]);
        assert_eq!(w.mix(), vec![("z".to_string(), 1.0)]);
    }

    #[test]
    fn drift_is_total_variation() {
        let a = vec![("a".to_string(), 0.5), ("b".to_string(), 0.5)];
        let b = vec![("b".to_string(), 0.5), ("c".to_string(), 0.5)];
        assert!((mix_drift(&a, &a)).abs() < 1e-12);
        assert!((mix_drift(&a, &b) - 0.5).abs() < 1e-12);
        let c = vec![("c".to_string(), 1.0)];
        assert!((mix_drift(&a, &c) - 1.0).abs() < 1e-12);
        // symmetric
        assert_eq!(mix_drift(&a, &b), mix_drift(&b, &a));
    }

    #[test]
    fn every_serving_artifact_has_a_network() {
        for name in [
            "conv3x3",
            "conv1x1",
            "conv5x5_s2",
            "depthwise",
            "fc",
            "lstm_cell",
            "conv_chain",
        ] {
            let net = artifact_network(name).unwrap_or_else(|| panic!("missing {name}"));
            assert!(!net.layers.is_empty());
            for l in &net.layers {
                assert!(l.macs() > 0, "{name}/{} has zero MACs", l.name);
            }
        }
        assert!(artifact_network("bogus").is_none());
    }

    #[test]
    fn mix_network_concatenates_with_count_weights() {
        let counts = vec![("conv3x3".to_string(), 3), ("lstm_cell".to_string(), 2)];
        let (net, weights, spans) = mix_network(&counts);
        assert_eq!(net.layers.len(), 3); // 1 conv + 2 gate banks
        assert_eq!(weights, vec![3.0, 2.0, 2.0]);
        assert_eq!(
            spans,
            vec![
                ("conv3x3".to_string(), 0, 1),
                ("lstm_cell".to_string(), 1, 2)
            ]
        );
    }
}
