//! Coordinator tests: experiment drivers produce well-formed tables and
//! the serving trace generator is deterministic.

use super::experiments::{self, Effort};
use super::serve::mixed_trace;

#[test]
fn table3_has_all_anchor_rows() {
    let t = experiments::table3();
    let txt = t.to_text();
    for needle in ["16 B", "512 B", "32 KB", "512 KB", "MAC", "Hop", "DRAM", "28 MB"] {
        assert!(txt.contains(needle), "missing {needle} in\n{txt}");
    }
}

#[test]
fn fig9_table_covers_all_dataflows() {
    let t = experiments::fig9_utilization(experiments::alexnet_conv3(4));
    assert_eq!(t.len(), 21, "CONV layer has (7 choose 2) dataflows");
    // every row's utilizations parse and are in (0, 1]
    for line in t.to_csv().lines().skip(1) {
        let mut cells = line.split(',');
        cells.next();
        let u0: f64 = cells.next().unwrap().parse().unwrap();
        let u1: f64 = cells.next().unwrap().parse().unwrap();
        assert!(u0 > 0.0 && u0 <= 1.0);
        assert!(u1 > 0.0 && u1 <= 1.0);
        assert!(u1 + 1e-9 >= u0, "replication must not hurt: {line}");
    }
}

#[test]
fn spotlight_layers_shapes() {
    let layers = experiments::spotlight_layers(Effort::Fast);
    assert_eq!(layers.len(), 4);
    // 4C3R is a pointwise layer
    assert_eq!(layers[2].1.bounds[5], 1);
    // CONV3 has a 3x3 filter
    assert_eq!(layers[0].1.bounds[5], 3);
}

#[test]
fn fig10_metrics_present() {
    let t = experiments::fig10_blocking(
        crate::loopnest::Shape::new(1, 16, 16, 6, 6, 3, 3, 1),
        Effort::Fast,
        1,
    );
    let txt = t.to_text();
    assert!(txt.contains("schemes evaluated"));
    assert!(txt.contains("% within 1.25x of min"));
    assert!(txt.contains("bucket"));
}

#[test]
fn search_pruning_table_confirms_identical_winners() {
    let t = experiments::search_pruning(Effort::Fast, 1);
    assert!(t.len() >= 3, "expected rows for AlexNet layers");
    for line in t.to_csv().lines().skip(1) {
        let cells: Vec<&str> = line.split(',').collect();
        assert_eq!(cells[6], "true", "b&b winner diverged: {line}");
        let ex: u64 = cells[2].parse().unwrap();
        let bb: u64 = cells[3].parse().unwrap();
        assert!(bb <= ex, "b&b ran more full evals than exhaustive: {line}");
    }
}

#[test]
fn mixed_trace_deterministic_and_mixed() {
    let a = mixed_trace(50, 7);
    let b = mixed_trace(50, 7);
    assert_eq!(a.len(), 50);
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.artifact, y.artifact);
        assert_eq!(x.seed, y.seed);
    }
    // different seeds give a different mix
    let c = mixed_trace(50, 8);
    assert!(a.iter().zip(c.iter()).any(|(x, y)| x.artifact != y.artifact));
    // at least 3 artifact kinds appear
    let kinds: std::collections::HashSet<_> = a.iter().map(|r| r.artifact.clone()).collect();
    assert!(kinds.len() >= 3, "{kinds:?}");
}

#[test]
fn ablation_cost_models_runs() {
    let t = experiments::ablation_cost_models(
        crate::loopnest::Shape::new(1, 8, 8, 4, 4, 3, 3, 1),
        1,
    );
    assert_eq!(t.len(), 4);
    // spreads parse as "N.NNx" and stay sane under every cost model
    for line in t.to_csv().lines().skip(1) {
        let spread: f64 = line
            .split(',')
            .nth(1)
            .unwrap()
            .trim_end_matches('x')
            .parse()
            .unwrap();
        assert!(spread >= 1.0 && spread < 20.0, "{line}");
    }
}
