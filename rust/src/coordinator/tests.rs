//! Coordinator tests: experiment drivers produce well-formed tables, the
//! serving loop is deterministic across worker counts (checksum bits and
//! latency counts), the serving trace generator is deterministic and
//! collision-free, and serving-time remapping reproduces the offline
//! optimizer bit for bit.

use super::experiments::{self, Effort};
use super::remap::{mix_network, MappingPlan, RemapPolicy, Remapper};
use super::serve::{
    drift_trace, mixed_trace, serve_with, Executor, Request, ServeConfig, ServeStats,
    SyntheticExecutor,
};
use crate::arch::{eyeriss_like, small_rf};
use crate::energy::Table3;
use crate::netopt::{co_optimize_arches, NetOptConfig};
use crate::search::HierarchyResult;

/// Serve a trace through the full `serve_with` loop on the deterministic
/// synthetic executor (no artifacts / `pjrt` needed).
fn serve_synthetic(
    trace: Vec<Request>,
    threads: usize,
    batch: usize,
    remapper: Option<&mut Remapper>,
) -> ServeStats {
    serve_with(
        trace,
        &ServeConfig::new(threads).with_batch(batch),
        || Ok(SyntheticExecutor),
        remapper,
    )
    .expect("synthetic serve cannot fail")
}

/// The cheap candidate list + policy the remap tests share.
fn test_remapper(window: usize, drift: f64) -> Remapper {
    Remapper::new(
        RemapPolicy::new(window, drift),
        vec![eyeriss_like(), small_rf()],
    )
}

/// Bit-level equality on the plan-winner contract surface: architecture,
/// totals, and every per-layer (mapping, smap, model result).
fn assert_winner_bits_eq(tag: &str, a: &HierarchyResult, b: &HierarchyResult) {
    assert_eq!(a.arch, b.arch, "{tag}: arch differs");
    assert_eq!(
        a.opt.total_energy_pj.to_bits(),
        b.opt.total_energy_pj.to_bits(),
        "{tag}: energy bits differ"
    );
    assert_eq!(
        a.opt.total_cycles.to_bits(),
        b.opt.total_cycles.to_bits(),
        "{tag}: cycle bits differ"
    );
    assert_eq!(a.opt.unmapped, 0, "{tag}: winner must be fully mapped");
    assert_eq!(b.opt.unmapped, 0, "{tag}: winner must be fully mapped");
    assert_eq!(a.opt.per_layer.len(), b.opt.per_layer.len());
    for (x, y) in a.opt.per_layer.iter().zip(b.opt.per_layer.iter()) {
        let (x, y) = (x.as_ref().unwrap(), y.as_ref().unwrap());
        assert_eq!(x.mapping, y.mapping, "{tag}: mapping differs");
        assert_eq!(x.smap, y.smap, "{tag}: spatial map differs");
        assert_eq!(x.result, y.result, "{tag}: model result differs");
    }
}

#[test]
fn table3_has_all_anchor_rows() {
    let t = experiments::table3();
    let txt = t.to_text();
    for needle in ["16 B", "512 B", "32 KB", "512 KB", "MAC", "Hop", "DRAM", "28 MB"] {
        assert!(txt.contains(needle), "missing {needle} in\n{txt}");
    }
}

#[test]
fn fig9_table_covers_all_dataflows() {
    let t = experiments::fig9_utilization(experiments::alexnet_conv3(4));
    assert_eq!(t.len(), 21, "CONV layer has (7 choose 2) dataflows");
    // every row's utilizations parse and are in (0, 1]
    for line in t.to_csv().lines().skip(1) {
        let mut cells = line.split(',');
        cells.next();
        let u0: f64 = cells.next().unwrap().parse().unwrap();
        let u1: f64 = cells.next().unwrap().parse().unwrap();
        assert!(u0 > 0.0 && u0 <= 1.0);
        assert!(u1 > 0.0 && u1 <= 1.0);
        assert!(u1 + 1e-9 >= u0, "replication must not hurt: {line}");
    }
}

#[test]
fn spotlight_layers_shapes() {
    let layers = experiments::spotlight_layers(Effort::Fast);
    assert_eq!(layers.len(), 4);
    // 4C3R is a pointwise layer
    assert_eq!(layers[2].1.bounds[5], 1);
    // CONV3 has a 3x3 filter
    assert_eq!(layers[0].1.bounds[5], 3);
}

#[test]
fn fig10_metrics_present() {
    let t = experiments::fig10_blocking(
        crate::loopnest::Shape::new(1, 16, 16, 6, 6, 3, 3, 1),
        Effort::Fast,
        1,
    );
    let txt = t.to_text();
    assert!(txt.contains("schemes evaluated"));
    assert!(txt.contains("% within 1.25x of min"));
    assert!(txt.contains("bucket"));
}

#[test]
fn search_pruning_table_confirms_identical_winners() {
    let t = experiments::search_pruning(Effort::Fast, 1);
    assert!(t.len() >= 3, "expected rows for AlexNet layers");
    for line in t.to_csv().lines().skip(1) {
        let cells: Vec<&str> = line.split(',').collect();
        assert_eq!(cells[6], "true", "b&b winner diverged: {line}");
        let ex: u64 = cells[2].parse().unwrap();
        let bb: u64 = cells[3].parse().unwrap();
        assert!(bb <= ex, "b&b ran more full evals than exhaustive: {line}");
    }
}

#[test]
fn serve_is_deterministic_across_thread_counts() {
    // Locks in the order-preserving serve loop at the serve() level:
    // ServeStats.checksum is byte-identical across threads ∈ {1, 2, 4}
    // and across two runs of the same trace, and the latency *count*
    // equals the trace length everywhere.
    let trace = mixed_trace(60, 7);
    let runs: Vec<ServeStats> = [1usize, 2, 4]
        .iter()
        .map(|&t| serve_synthetic(trace.clone(), t, 16, None))
        .collect();
    for (i, s) in runs.iter().enumerate() {
        assert_eq!(s.completed, 60, "run {i}: lost requests");
        assert_eq!(s.batches, 4, "run {i}: 60 requests / batch 16 = 4 batches");
        assert_eq!(
            s.checksum.to_bits(),
            runs[0].checksum.to_bits(),
            "checksum bits differ between threads=1 and threads={}",
            [1, 2, 4][i]
        );
    }
    // repeat runs are byte-identical too
    for t in [1usize, 2, 4] {
        let a = serve_synthetic(trace.clone(), t, 16, None);
        let b = serve_synthetic(trace.clone(), t, 16, None);
        assert_eq!(a.checksum.to_bits(), b.checksum.to_bits(), "t={t}");
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.batches, b.batches);
    }
    // batching must not move the checksum either (trace-order reduction)
    let unbatched = serve_synthetic(trace, 3, 0, None);
    assert_eq!(unbatched.checksum.to_bits(), runs[0].checksum.to_bits());
    assert_eq!(unbatched.batches, 1);
}

#[test]
fn serve_with_remap_is_deterministic_across_thread_counts() {
    // Remap decisions are pure functions of the trace, so enabling the
    // remapper preserves the determinism contract — including the remap
    // count and the final plan — across worker counts.
    let trace = mixed_trace(48, 3);
    let mut reference: Option<(ServeStats, usize, Vec<(String, usize)>, String)> = None;
    for t in [1usize, 2, 4] {
        let mut r = test_remapper(16, 0.3);
        let stats = serve_synthetic(trace.clone(), t, 12, Some(&mut r));
        let plan = r.plan().expect("a plan after serving");
        match &reference {
            None => {
                let arch = plan.winner.arch.name.clone();
                reference = Some((stats, r.remaps, plan.mix.clone(), arch));
            }
            Some((s0, remaps0, mix0, arch0)) => {
                assert_eq!(stats.checksum.to_bits(), s0.checksum.to_bits(), "t={t}");
                assert_eq!(stats.completed, s0.completed, "t={t}");
                assert_eq!(stats.remaps, s0.remaps, "t={t}: plan swaps differ");
                assert_eq!(stats.plan_epoch, s0.plan_epoch, "t={t}: final epoch differs");
                assert_eq!(&r.remaps, remaps0, "t={t}: remap count differs");
                assert_eq!(&plan.mix, mix0, "t={t}: final plan mix differs");
                assert_eq!(&plan.winner.arch.name, arch0, "t={t}");
            }
        }
    }
    let (s0, remaps0, ..) = reference.unwrap();
    assert!(remaps0 >= 1, "the first batch must produce a plan");
    assert_eq!(s0.remaps, remaps0, "every published plan must be drained");
}

#[test]
fn remap_on_static_mix_matches_offline_co_optimize() {
    // On a static mix the remapped plan must be bit-identical to the
    // offline optimizer on the same candidate points and the same
    // mix-weighted network — cold on the first remap, and still
    // identical warm-started on the second.
    let trace = mixed_trace(40, 9);
    let mut r = test_remapper(40, 0.9);
    let stats = serve_synthetic(trace, 1, 40, Some(&mut r));
    assert_eq!(stats.remaps, 1, "single batch, single plan");
    let plan = r.plan().expect("plan");
    assert_eq!(plan.mix.iter().map(|(_, c)| c).sum::<usize>(), 40);

    let (net, weights, spans) = mix_network(&plan.mix);
    assert_eq!(spans, plan.spans);
    let cfg = NetOptConfig::new(r.policy().opts.clone(), 1).with_layer_weights(weights);
    let offline = co_optimize_arches(&net, r.candidates().expect("fixed list"), &Table3, &cfg);
    let ow = offline.best().expect("offline winner");
    assert_winner_bits_eq("static-mix remap vs offline", &plan.winner, ow);

    // second remap on the same window: warm-started from the first
    // run's seeds, still bit-identical to the cold offline optimum
    assert!(!r.seeds().is_empty(), "first remap must learn seeds");
    let plan2 = r.remap_now().expect("warm remap");
    assert_winner_bits_eq("warm remap vs offline", &plan2.winner, ow);

    // per-artifact span lookup exposes every layer of the winner
    for (name, _, len) in &plan.spans {
        let layers = plan.artifact_layers(name).expect("span");
        assert_eq!(layers.len(), *len);
        assert!(layers.iter().all(|l| l.is_some()));
    }
}

#[test]
fn remap_follows_drift_to_the_post_drift_optimum() {
    // Synthetic drift trace: {conv3x3, fc} for the first half, pure
    // lstm_cell after. Once the window fills with post-drift traffic the
    // remapper must re-optimize, and the final plan must equal the
    // offline optimum for the post-drift mix.
    let trace = drift_trace(96, 48, &["conv3x3", "fc"], &["lstm_cell"], 11);
    let mut r = test_remapper(24, 0.4);
    let stats = serve_synthetic(trace, 2, 12, Some(&mut r));
    assert_eq!(stats.completed, 96);
    assert!(
        r.remaps >= 2,
        "expected at least the initial and the post-drift remap, got {}",
        r.remaps
    );
    assert_eq!(stats.remaps, r.remaps, "every plan swap must reach serve");

    let plan = r.plan().expect("final plan");
    assert_eq!(
        plan.mix,
        vec![("lstm_cell".to_string(), 24)],
        "final window must be pure post-drift traffic"
    );
    let (net, weights, _) = mix_network(&plan.mix);
    let cfg = NetOptConfig::new(r.policy().opts.clone(), 1).with_layer_weights(weights);
    let offline = co_optimize_arches(&net, r.candidates().expect("fixed list"), &Table3, &cfg);
    let ow = offline.best().expect("offline post-drift winner");
    assert_winner_bits_eq("post-drift remap vs offline", &plan.winner, ow);
    // drift settles once the plan tracks the window
    assert!(r.drift() < 1e-12, "drift should be zero on a settled mix");
}

#[test]
fn deadline_remap_converges_to_the_exact_plan_bit_for_bit() {
    // The deadline fast path defers the exact search behind an instant
    // heuristic plan, but because the mix window is stamped at remap
    // *trigger* time in both modes, the trigger sequence — and therefore
    // the final adopted plan — is bit-identical with and without the
    // deadline. (Remap *counts* may legitimately differ: a fresh trigger
    // supersedes a still-pending exact search, so deadline runs can run
    // fewer exact searches than eager runs.)
    let trace = drift_trace(96, 48, &["conv3x3", "fc"], &["lstm_cell"], 11);

    let mut plain = test_remapper(24, 0.4);
    let pstats = serve_synthetic(trace.clone(), 2, 12, Some(&mut plain));
    let pplan = plain.plan().expect("plain final plan");
    assert_eq!(pstats.fast_remaps, 0, "no deadline, no fast plans");

    let mut reference: Option<ServeStats> = None;
    for t in [1usize, 2, 4] {
        let mut r = Remapper::new(
            RemapPolicy::new(24, 0.4).with_deadline(),
            vec![eyeriss_like(), small_rf()],
        );
        let stats = serve_synthetic(trace.clone(), t, 12, Some(&mut r));
        let plan = r.plan().expect("deadline final plan");

        // the fast path actually fired, and serve drained every plan it
        // (and the deferred exact searches) published
        assert!(r.fast_plans >= 1, "t={t}: deadline never published fast");
        assert!(stats.fast_remaps >= 1, "t={t}: fast plans never reached serve");
        assert_eq!(stats.fast_remaps, r.fast_plans, "t={t}: fast swap count");
        assert_eq!(stats.remaps, r.remaps + r.fast_plans, "t={t}: swap count");

        // convergence: the end-of-trace flush leaves the *exact* plan of
        // the last triggering mix active — bit-identical to the eager run
        assert!(!plan.fast, "t={t}: final plan must be the exact one");
        assert_eq!(plan.mix, pplan.mix, "t={t}: final mix differs");
        assert_winner_bits_eq("deadline vs eager final plan", &plan.winner, &pplan.winner);
        assert_eq!(
            stats.checksum.to_bits(),
            pstats.checksum.to_bits(),
            "t={t}: serving results must not depend on the remap mode"
        );

        // and the deadline mode is itself deterministic across threads
        match &reference {
            None => reference = Some(stats),
            Some(s0) => {
                assert_eq!(stats.remaps, s0.remaps, "t={t}");
                assert_eq!(stats.fast_remaps, s0.fast_remaps, "t={t}");
                assert_eq!(stats.plan_epoch, s0.plan_epoch, "t={t}");
            }
        }
    }
}

#[test]
fn workers_adopt_the_active_plan_at_batch_boundaries() {
    // The plan-swap contract: a plan published after batch k is handed
    // to every serving worker's executor (Executor::adopt_plan) at the
    // start of batch k+1 and of every batch after that — never mid-batch.
    use std::sync::{Arc, Mutex};

    struct Tracking {
        epochs: Arc<Mutex<Vec<usize>>>,
    }
    impl Executor for Tracking {
        fn execute(&mut self, req: &Request) -> anyhow::Result<f64> {
            let mut inner = SyntheticExecutor;
            inner.execute(req)
        }
        fn adopt_plan(&mut self, plan: &MappingPlan) {
            self.epochs.lock().expect("tracking log").push(plan.epoch);
        }
    }

    let log: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
    // drift 2.0 is unreachable (total variation <= 1), so exactly the
    // initial (epoch-0) remap fires, whatever the sampled mix looks like
    let mut r = test_remapper(16, 2.0);
    let stats = serve_with(
        mixed_trace(32, 4),
        &ServeConfig::new(1).with_batch(8),
        || {
            Ok(Tracking {
                epochs: log.clone(),
            })
        },
        Some(&mut r),
    )
    .expect("synthetic serve");
    assert_eq!(stats.batches, 4);
    assert_eq!(stats.remaps, 1);
    assert_eq!(stats.plan_epoch, Some(0));
    // no plan exists during batch 1; the epoch-0 plan is adopted at the
    // start of batches 2, 3 and 4
    assert_eq!(*log.lock().expect("tracking log"), vec![0, 0, 0]);
}

#[test]
fn serve_handles_tiny_and_empty_traces() {
    let empty = serve_synthetic(Vec::new(), 4, 8, None);
    assert_eq!(empty.completed, 0);
    assert_eq!(empty.batches, 0);
    assert_eq!(empty.checksum, 0.0);
    // more workers than requests in the final (short) batch
    let five = serve_synthetic(mixed_trace(5, 1), 8, 2, None);
    assert_eq!(five.completed, 5);
    assert_eq!(five.batches, 3);
    let one_worker = serve_synthetic(mixed_trace(5, 1), 1, 2, None);
    assert_eq!(one_worker.checksum.to_bits(), five.checksum.to_bits());
}

#[test]
fn mixed_trace_deterministic_and_mixed() {
    let a = mixed_trace(50, 7);
    let b = mixed_trace(50, 7);
    assert_eq!(a.len(), 50);
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.artifact, y.artifact);
        assert_eq!(x.seed, y.seed);
    }
    // different seeds give a different mix
    let c = mixed_trace(50, 8);
    assert!(a.iter().zip(c.iter()).any(|(x, y)| x.artifact != y.artifact));
    // at least 3 artifact kinds appear
    let kinds: std::collections::HashSet<_> = a.iter().map(|r| r.artifact.clone()).collect();
    assert!(kinds.len() >= 3, "{kinds:?}");
}

#[test]
fn trace_request_seeds_are_collision_free() {
    // Regression for the old `seed ^ (i · 0x9E37)` per-request mixing:
    // it aliased across related trace seeds (e.g. trace 0's request 1
    // equals trace 0x9E37's request 0, and generally seed a's request i
    // collides with seed a ^ 0x9E37's request i ± 1), and adjacent
    // requests at small seeds differed only in low state bits. Stream
    // splitting makes within-trace seeds distinct by construction
    // (xorshift64* outputs are a bijection of the never-repeating state
    // sequence) and decorrelates related trace seeds.
    let mut seen = std::collections::HashSet::new();
    for r in mixed_trace(4096, 1) {
        assert!(seen.insert(r.seed), "within-trace request seed collision");
        assert_ne!(r.seed, 0, "zero would collapse the input stream");
    }
    // the exact small/related seeds the old mixing aliased on
    let mut seen = std::collections::HashSet::new();
    for s in [0u64, 1, 2, 3, 0x9E37, 2 * 0x9E37, 3 * 0x9E37] {
        for r in mixed_trace(512, s) {
            assert!(seen.insert(r.seed), "cross-trace collision at trace seed {s:#x}");
        }
    }
}

#[test]
fn drift_trace_switches_pools_deterministically() {
    let t = drift_trace(30, 10, &["fc"], &["conv3x3", "conv1x1"], 5);
    assert_eq!(t.len(), 30);
    assert!(t[..10].iter().all(|r| r.artifact == "fc"));
    assert!(t[10..]
        .iter()
        .all(|r| r.artifact == "conv3x3" || r.artifact == "conv1x1"));
    let u = drift_trace(30, 10, &["fc"], &["conv3x3", "conv1x1"], 5);
    for (a, b) in t.iter().zip(u.iter()) {
        assert_eq!(a.artifact, b.artifact);
        assert_eq!(a.seed, b.seed);
    }
}

#[test]
fn ablation_cost_models_runs() {
    let t = experiments::ablation_cost_models(
        crate::loopnest::Shape::new(1, 8, 8, 4, 4, 3, 3, 1),
        1,
    );
    assert_eq!(t.len(), 4);
    // spreads parse as "N.NNx" and stay sane under every cost model
    for line in t.to_csv().lines().skip(1) {
        let spread: f64 = line
            .split(',')
            .nth(1)
            .unwrap()
            .trim_end_matches('x')
            .parse()
            .unwrap();
        assert!(spread >= 1.0 && spread < 20.0, "{line}");
    }
}

#[test]
fn budget_remap_selects_within_budget_from_the_live_space() {
    // A latency-budgeted remapper draws candidates from a live
    // DesignSpace, computes the mix frontier, and picks the min-energy
    // point whose weighted window cycles fit the budget.
    use crate::pareto::{pareto_optimize_arches, ParetoConfig, PlanSelector};

    let trace = mixed_trace(32, 5);
    // First pass with an unbounded budget to learn the frontier's range.
    let mut probe = Remapper::with_space(
        RemapPolicy::new(32, 0.9).with_latency_budget(f64::INFINITY),
        Remapper::default_space(),
    );
    serve_synthetic(trace.clone(), 1, 32, Some(&mut probe));
    let sel = probe.selector().expect("frontier-mode remap ran").clone();
    assert!(!sel.is_empty(), "live space produced no feasible point");
    let min_energy_plan = probe.plan().expect("plan under infinite budget");

    // An infinite budget selects the min-energy frontier point.
    assert_eq!(
        min_energy_plan.winner.arch.name,
        sel.entries()[0].result.arch.name
    );

    // A budget pinned at the fastest point's cycles selects that point
    // (and every selected plan respects the budget).
    let fastest = sel.entries().last().unwrap();
    let tight = fastest.result.opt.total_cycles;
    let mut r = Remapper::with_space(
        RemapPolicy::new(32, 0.9).with_latency_budget(tight),
        Remapper::default_space(),
    );
    serve_synthetic(trace.clone(), 2, 32, Some(&mut r));
    let plan = r.plan().expect("plan under the tight budget");
    assert!(
        plan.winner.opt.total_cycles <= tight,
        "selected plan busts the budget: {} > {tight}",
        plan.winner.opt.total_cycles
    );
    assert_eq!(plan.winner.arch.name, fastest.result.arch.name);

    // An unmeetable budget keeps serving but never installs a plan.
    let mut none = Remapper::with_space(
        RemapPolicy::new(32, 0.9).with_latency_budget(0.0),
        Remapper::default_space(),
    );
    let stats = serve_synthetic(trace.clone(), 1, 32, Some(&mut none));
    assert_eq!(stats.completed, 32);
    assert!(none.plan().is_none(), "no plan fits a zero budget");
    assert_eq!(stats.remaps, 0);

    // The live-space frontier is the offline pareto frontier of the
    // enumerated candidates on the same mix-weighted network, bit for
    // bit (seeds are hints only).
    let (net, weights, _) = mix_network(&min_energy_plan.mix);
    let cfg = NetOptConfig::new(probe.policy().opts.clone(), 1).with_layer_weights(weights);
    let cands = Remapper::default_space().enumerate().candidates;
    let offline = pareto_optimize_arches(&net, &cands, &Table3, &cfg, &ParetoConfig::default());
    let offline_sel = PlanSelector::new(offline.frontier);
    assert_eq!(offline_sel.len(), sel.len(), "online frontier size differs");
    for (a, b) in sel.entries().iter().zip(offline_sel.entries().iter()) {
        assert_winner_bits_eq("live-space frontier vs offline", &a.result, &b.result);
    }
}

#[test]
fn loose_budget_frontier_remap_matches_the_scalar_path() {
    // With an effectively-infinite budget over the same fixed candidate
    // list, the frontier path must select exactly the scalar argmin —
    // the two remap modes agree bit for bit.
    let trace = mixed_trace(40, 9);
    let mut scalar = test_remapper(40, 0.9);
    serve_synthetic(trace.clone(), 1, 40, Some(&mut scalar));
    let scalar_plan = scalar.plan().expect("scalar plan");

    let mut frontier = Remapper::new(
        RemapPolicy::new(40, 0.9).with_latency_budget(f64::INFINITY),
        vec![eyeriss_like(), small_rf()],
    );
    serve_synthetic(trace, 1, 40, Some(&mut frontier));
    let frontier_plan = frontier.plan().expect("frontier plan");
    assert_eq!(frontier_plan.mix, scalar_plan.mix);
    assert_winner_bits_eq(
        "frontier-mode vs scalar remap",
        &frontier_plan.winner,
        &scalar_plan.winner,
    );
    assert!(frontier.selector().is_some());
    assert!(scalar.selector().is_none(), "scalar path has no frontier");
}

#[test]
fn pareto_curve_table_is_a_descending_energy_ascending_tops_curve() {
    let t = experiments::pareto_curve(Effort::Fast, 2);
    assert!(!t.is_empty(), "frontier must have at least one point");
    // The table prints TOPS at 3 decimals, so adjacent frontier points
    // can legitimately round to the same printed value — assert
    // non-decreasing on the presentation; the strict bit-level frontier
    // ordering is locked down in pareto::tests on the raw results.
    let mut last_tops = f64::NEG_INFINITY;
    for line in t.to_csv().lines().skip(1) {
        let cells: Vec<&str> = line.split(',').collect();
        let tops: f64 = cells[3].parse().unwrap();
        assert!(
            tops >= last_tops,
            "frontier rows must not lose throughput: {line}"
        );
        last_tops = tops;
    }
}

#[test]
fn report_all_produces_every_artifact() {
    // One-command paper-artifact regeneration (REPRODUCING.md): under
    // Smoke effort every fig7–14/table3 artifact plus the trajectory
    // curve must land in the output directory, in manifest order.
    let dir = std::env::temp_dir().join(format!(
        "interstellar-report-all-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let hist = dir.join("bench_history.jsonl");
    std::fs::create_dir_all(&dir).unwrap();

    // Seed a tiny history so bench_trajectory.csv has real rows.
    for (ts, ns) in [(1u64, 101.0), (2, 103.0)] {
        let rec = crate::bench::HistoryRecord {
            bench: "perf_probe".into(),
            git_rev: "test".into(),
            unix_ts: ts,
            metrics: vec![("probe_mean_ns".into(), ns)],
            labels: Vec::new(),
        };
        crate::bench::append_record(&hist, &rec).unwrap();
    }

    let written = experiments::report_all(&dir, Effort::Smoke, 2, &hist).expect("report_all");
    assert_eq!(written.len(), experiments::REPORT_ARTIFACTS.len());
    for (path, name) in written.iter().zip(experiments::REPORT_ARTIFACTS) {
        assert_eq!(path.file_name().unwrap().to_str().unwrap(), *name);
        let text = std::fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("{name} unreadable: {e}"));
        assert!(!text.trim().is_empty(), "{name} is empty");
    }
    let traj = std::fs::read_to_string(dir.join("bench_trajectory.csv")).unwrap();
    assert!(
        traj.contains("probe_mean_ns"),
        "trajectory curve must include the seeded history metric"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn failed_executor_slot_fails_over_without_perturbing_the_checksum() {
    // Satellite regression for the serve failover path: an executor
    // factory that fails on its first invocation used to abort the whole
    // batch loop; now the affected worker's shard is retried on a fresh
    // replica, counted in `failovers`, with checksum and digest
    // untouched.
    use std::sync::atomic::{AtomicUsize, Ordering};
    let trace = mixed_trace(60, 7);
    let clean = serve_synthetic(trace.clone(), 3, 10, None);
    assert_eq!(clean.failovers, 0);

    let calls = AtomicUsize::new(0);
    let flaky = serve_with(
        trace.clone(),
        &ServeConfig::new(3).with_batch(10),
        || {
            if calls.fetch_add(1, Ordering::SeqCst) == 0 {
                anyhow::bail!("injected executor-slot init failure");
            }
            Ok(SyntheticExecutor)
        },
        None,
    )
    .expect("one failed slot must fail over, not abort");
    assert_eq!(flaky.failovers, 1, "exactly the injected failure");
    assert_eq!(flaky.completed, clean.completed);
    assert_eq!(
        flaky.checksum.to_bits(),
        clean.checksum.to_bits(),
        "failover must re-serve the identical shard in shard order"
    );
    assert_eq!(flaky.digest, clean.digest);

    // A replacement replica that also fails is surfaced, naming the worker.
    let always = serve_with(
        trace,
        &ServeConfig::new(3).with_batch(10),
        || -> anyhow::Result<SyntheticExecutor> {
            anyhow::bail!("executor is down")
        },
        None,
    );
    let err = format!("{:#}", always.expect_err("two failures must surface"));
    assert!(err.contains("failed twice"), "unexpected error: {err}");
}

#[test]
fn digest_merges_across_interleaved_shards_bit_for_bit() {
    // The fleet merge contract at the serve level: worker w of N serving
    // the interleaved shard under with_index_map(w, N) produces digests
    // whose wrapping sum equals the single-process digest, while the
    // order-dependent f64 checksum is left to trace-order runs.
    let trace = mixed_trace(90, 13);
    let whole = serve_synthetic(trace.clone(), 2, 16, None);
    for fleet in [2usize, 3, 5] {
        let mut merged = 0u64;
        let mut completed = 0usize;
        for w in 0..fleet {
            let shard: Vec<Request> = trace
                .iter()
                .enumerate()
                .filter(|(i, _)| i % fleet == w)
                .map(|(_, r)| r.clone())
                .collect();
            let st = serve_with(
                shard,
                &ServeConfig::new(2)
                    .with_batch(8)
                    .with_index_map(w as u64, fleet as u64),
                || Ok(SyntheticExecutor),
                None,
            )
            .expect("shard serve");
            merged = merged.wrapping_add(st.digest);
            completed += st.completed;
        }
        assert_eq!(completed, 90);
        assert_eq!(merged, whole.digest, "{fleet}-way shard merge");
    }
}
