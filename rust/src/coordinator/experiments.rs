//! Experiment drivers: one function per paper table/figure. Each returns
//! a [`Table`] whose rows mirror what the paper plots, so the benches,
//! the CLI, and the one-command artifact regeneration ([`report_all`],
//! CLI `report --all`; see REPRODUCING.md for the paper-artifact map)
//! all print the same data.

use std::collections::HashMap;

use crate::arch::{
    eyeriss_like, no_local_reuse, small_rf, tpu_like, validation_designs, ArrayShape,
};
use crate::dataflow::{
    best_replication, enumerate_dataflows, single_loop_map, utilization, Dataflow,
};
use crate::energy::{table3_anchors, CostModel, Table3};
use crate::engine::PruneMode;
use crate::loopnest::Shape;
use crate::netopt::{
    co_optimize, co_optimize_arches, co_optimize_sharded, CoOptResult, DesignSpace, NetOptConfig,
};
use crate::nn::{network, Network};
use crate::pareto::{pareto_optimize, ParetoConfig};
use crate::search::{
    optimize_layer, optimize_network, sweep_blockings, HierarchyResult, SearchOpts,
};
use crate::sim::simulate;
use crate::util::{fmt_bytes, fmt_sig, stats, table::Table};

/// Experiment scale: `Smoke` is sized for debug-mode smoke tests,
/// `Fast` keeps bench wall-time low, `Full` matches the paper's
/// workload sizes more closely.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Effort {
    /// Tiny caps and trimmed grids so `report --all --smoke` (and the
    /// tier-1 smoke test over it) regenerates every artifact quickly
    /// even in a debug build. Same drivers, same artifact set — only
    /// the scale shrinks.
    Smoke,
    /// Reduced batch / search caps (default for `cargo bench`).
    Fast,
    /// Paper-scale workloads (CLI `--full`).
    Full,
}

impl Effort {
    fn opts(self) -> SearchOpts {
        match self {
            Effort::Smoke => SearchOpts::capped(150, 4),
            Effort::Fast => SearchOpts::capped(600, 5),
            Effort::Full => SearchOpts::capped(20_000, 8),
        }
    }

    fn batch(self) -> u64 {
        match self {
            Effort::Smoke => 1,
            Effort::Fast => 4,
            Effort::Full => 16,
        }
    }
}

/// The hierarchy-sweep design space at an effort: the paper grid,
/// trimmed to two points under [`Effort::Smoke`] (Fast and Full sweep
/// the unchanged paper grid).
fn space_for_effort(array: ArrayShape, effort: Effort) -> DesignSpace {
    let mut s = DesignSpace::paper_default(array);
    if effort == Effort::Smoke {
        s.rf1_sizes = vec![64, 512];
        s.rf2_ratios = vec![4];
        s.gbuf_sizes = vec![128 << 10];
    }
    s
}

/// Sharding knob for the sweep drivers: when `INTERSTELLAR_SHARDS` is
/// set above 1, the fig12–14 hierarchy sweeps (and anything else calling
/// `sweep_space`) run through the in-process sharded runner
/// ([`co_optimize_sharded`]) — the same partition/merge machinery the
/// multi-process `co-opt --shard` CLI path uses, whose winner-identity
/// contract guarantees identical tables either way.
pub fn shard_count() -> usize {
    std::env::var("INTERSTELLAR_SHARDS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(1)
}

/// Exhaustively sweep a design space, via the sharded path when
/// [`shard_count`] asks for it. Exhaustive mode has no cross-point
/// state, so the sharded union equals the single-process ranking point
/// for point — the drivers below index into it freely.
fn sweep_space(
    net: &Network,
    space: &DesignSpace,
    opts: &SearchOpts,
    threads: usize,
) -> CoOptResult {
    let cfg = NetOptConfig::exhaustive(opts.clone(), threads);
    match shard_count() {
        1 => co_optimize(net, space, &Table3, &cfg),
        n => co_optimize_sharded(net, space, &Table3, &cfg, n),
    }
}

/// AlexNet CONV3 at a given batch.
pub fn alexnet_conv3(batch: u64) -> Shape {
    Shape::new(batch, 384, 256, 13, 13, 3, 3, 1)
}

/// GoogLeNet 4C3R (1×1 reduction) at a given batch.
pub fn googlenet_4c3r(batch: u64) -> Shape {
    Shape::new(batch, 128, 512, 14, 14, 1, 1, 1)
}

/// Table 3: the energy cost table, anchors + interpolated sizes.
pub fn table3() -> Table {
    let m = Table3;
    let mut t = Table::new(vec!["kind", "size", "energy (pJ/16b access)"]);
    for (kind, size, pj) in table3_anchors() {
        t.row(vec![
            format!("{kind:?}"),
            fmt_bytes(size),
            format!("{pj}"),
        ]);
    }
    t.row(vec!["MAC".into(), "-".into(), format!("{}", m.mac())]);
    t.row(vec!["Hop".into(), "-".into(), format!("{}", m.hop())]);
    t.row(vec![
        "DRAM".into(),
        "-".into(),
        format!("{}", m.dram_access()),
    ]);
    // interpolated points used by the optimizer
    t.row(vec!["Reg".into(), "8 B (interp)".into(), format!("{}", m.reg_access(8))]);
    t.row(vec![
        "Sram".into(),
        "28 MB (interp)".into(),
        format!("{:.2}", m.sram_access(28 << 20)),
    ]);
    t
}

/// Fig 7a / Table 4: analytical model vs trace simulator on the three
/// validation designs, over AlexNet conv layers (batch 1 to keep the
/// exact walk tractable). Paper reports < 2 % error vs synthesis; our
/// ground truth is the exact walk, so the assertion is equality.
pub fn fig7_validation(threads: usize) -> Table {
    let net = network("alexnet", 1).unwrap();
    let layers: Vec<(String, Shape)> = net
        .layers
        .iter()
        .filter(|l| !l.is_fc_family())
        .map(|l| (l.name.clone(), l.shape))
        .collect();
    fig7_validation_over(&layers, &SearchOpts::capped(300, 5), 2_000_000_000, threads)
}

/// Core of [`fig7_validation`], parameterized over the layer list,
/// search caps, and simulator step budget so `report --all --smoke` can
/// run the same model-vs-simulator comparison on a single small layer.
pub fn fig7_validation_over(
    layers: &[(String, Shape)],
    opts: &SearchOpts,
    sim_budget: u64,
    threads: usize,
) -> Table {
    let mut t = Table::new(vec![
        "design", "layer", "model (uJ)", "sim (uJ)", "err %", "dataflow",
    ]);
    for (arch, df_str) in validation_designs() {
        let df = Dataflow::parse(df_str).unwrap();
        for (name, shape) in layers {
            let Some(lo) = optimize_layer(shape, &arch, &df, &Table3, opts, threads) else {
                continue;
            };
            let sim = match simulate(&lo.mapping, &lo.smap, &arch, &Table3, sim_budget) {
                Ok(s) => s,
                Err(_) => continue,
            };
            let err = 100.0 * (lo.result.energy_pj - sim.energy_pj).abs() / sim.energy_pj;
            t.row(vec![
                arch.name.clone(),
                name.clone(),
                fmt_sig(lo.result.energy_uj()),
                fmt_sig(sim.energy_uj()),
                format!("{err:.4}"),
                df_str.to_string(),
            ]);
        }
    }
    t
}

/// Fig 7b: our model's AlexNet energy breakdown under the Eyeriss
/// row-stationary configuration, by hierarchy level (to compare against
/// the published Eyeriss breakdown shape: RF-dominated).
pub fn fig7b_eyeriss_breakdown(effort: Effort, threads: usize) -> Table {
    let arch = eyeriss_like();
    let df = Dataflow::parse("FY|Y").unwrap();
    let net = network("alexnet", effort.batch()).unwrap();
    let opts = effort.opts();
    let mut t = Table::new(vec!["layer", "RF %", "fabric %", "GBUF %", "DRAM %", "MAC %"]);
    for layer in net.layers.iter().filter(|l| !l.is_fc_family()) {
        let Some(lo) = optimize_layer(&layer.shape, &arch, &df, &Table3, &opts, threads) else {
            continue;
        };
        let r = &lo.result;
        t.row(vec![
            layer.name.clone(),
            format!("{:.1}", 100.0 * r.level_fraction(0)),
            format!("{:.1}", 100.0 * r.fabric_energy / r.energy_pj),
            format!("{:.1}", 100.0 * r.level_fraction(1)),
            format!("{:.1}", 100.0 * r.level_fraction(2)),
            format!("{:.1}", 100.0 * r.mac_energy / r.energy_pj),
        ]);
    }
    t
}

/// Fig 8: dataflow design space. For each enumerated dataflow (with
/// replication and optimal blocking), energy on the three hardware
/// configurations.
pub fn fig8_dataflow(shape: Shape, effort: Effort, threads: usize) -> Table {
    let opts = effort.opts();
    let archs = [eyeriss_like(), no_local_reuse(), small_rf()];
    let mut t = Table::new(vec![
        "dataflow",
        "eyeriss-like (uJ)",
        "broadcast-bus (uJ)",
        "small-rf (uJ)",
    ]);
    for df in enumerate_dataflows(&shape) {
        let mut cells = vec![df.to_string()];
        for arch in &archs {
            let e = optimize_layer(&shape, arch, &df, &Table3, &opts, threads)
                .map(|lo| fmt_sig(lo.result.energy_uj()))
                .unwrap_or_else(|| "-".into());
            cells.push(e);
        }
        t.row(cells);
    }
    t
}

/// Summary stats over a Fig 8 sweep per arch: `(name, max/min spread,
/// median/min)` — the paper's claim is that *many* dataflows land near
/// the optimum (small median/min), not that every outlier does.
pub fn fig8_spread(shape: Shape, effort: Effort, threads: usize) -> Vec<(String, f64, f64)> {
    let opts = effort.opts();
    let mut out = Vec::new();
    for arch in [eyeriss_like(), no_local_reuse(), small_rf()] {
        let energies: Vec<f64> = enumerate_dataflows(&shape)
            .into_iter()
            .filter_map(|df| {
                optimize_layer(&shape, &arch, &df, &Table3, &opts, threads)
                    .map(|lo| lo.result.energy_pj)
            })
            .collect();
        let lo = stats::min(&energies).max(1e-30);
        out.push((
            arch.name.clone(),
            stats::max(&energies) / lo,
            stats::percentile(&energies, 50.0) / lo,
        ));
    }
    out
}

/// Fig 9: PE-array utilization per dataflow, without and with
/// replication, on a 16×16 array.
pub fn fig9_utilization(shape: Shape) -> Table {
    let array = ArrayShape { rows: 16, cols: 16 };
    let mut t = Table::new(vec!["dataflow", "util (no repl)", "util (repl)", "repl map"]);
    for df in enumerate_dataflows(&shape) {
        let plain = single_loop_map(&shape, &df, &array);
        let repl = best_replication(&shape, &df, &array);
        t.row(vec![
            df.to_string(),
            format!("{:.3}", utilization(&shape, &plain, &array)),
            format!("{:.3}", utilization(&shape, &repl, &array)),
            repl.to_string(),
        ]);
    }
    t
}

/// Fig 10: the loop-blocking design space for one layer / dataflow /
/// arch: energy distribution over enumerated blockings.
pub fn fig10_blocking(shape: Shape, effort: Effort, threads: usize) -> Table {
    let arch = eyeriss_like();
    let df = Dataflow::parse("C|K").unwrap();
    let mut opts = effort.opts();
    if effort != Effort::Smoke {
        opts.max_blockings = opts.max_blockings.max(2000);
    }
    let energies = sweep_blockings(&shape, &arch, &df, &Table3, &opts, threads);
    let lo = stats::min(&energies);
    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["schemes evaluated".to_string(), format!("{}", energies.len())]);
    t.row(vec!["min energy (uJ)".to_string(), fmt_sig(lo / 1e6)]);
    t.row(vec![
        "max / min".to_string(),
        format!("{:.2}x", stats::max(&energies) / lo),
    ]);
    t.row(vec![
        "median / min".to_string(),
        format!("{:.2}x", stats::percentile(&energies, 50.0) / lo),
    ]);
    t.row(vec![
        "% within 1.25x of min".to_string(),
        format!("{:.1}%", 100.0 * stats::frac_within_of_min(&energies, 1.25)),
    ]);
    // histogram rows (energy relative to min)
    for (lo_b, hi_b) in [(1.0, 1.25), (1.25, 2.0), (2.0, 4.0), (4.0, f64::INFINITY)] {
        let n = energies
            .iter()
            .filter(|&&e| e / lo >= lo_b && e / lo < hi_b)
            .count();
        t.row(vec![
            format!("bucket {lo_b}x..{hi_b}x"),
            format!("{:.1}%", 100.0 * n as f64 / energies.len() as f64),
        ]);
    }
    t
}

/// Fig 11: per-layer energy breakdown, 512 B vs 64 B RF, AlexNet
/// (same `C|K` dataflow, optimal blocking each).
pub fn fig11_breakdown(effort: Effort, threads: usize) -> Table {
    let df = Dataflow::parse("C|K").unwrap();
    let opts = effort.opts();
    let net = network("alexnet", effort.batch()).unwrap();
    let mut t = Table::new(vec![
        "layer", "RF", "uJ@512B", "RF frac", "uJ@64B", "RF frac", "gain",
    ]);
    for layer in &net.layers {
        let big = optimize_layer(&layer.shape, &eyeriss_like(), &df, &Table3, &opts, threads);
        let small = optimize_layer(&layer.shape, &small_rf(), &df, &Table3, &opts, threads);
        if let (Some(b), Some(s)) = (big, small) {
            t.row(vec![
                layer.name.clone(),
                "512/64".into(),
                fmt_sig(b.result.energy_uj()),
                format!("{:.0}%", 100.0 * b.result.level_fraction(0)),
                fmt_sig(s.result.energy_uj()),
                format!("{:.0}%", 100.0 * s.result.level_fraction(0)),
                format!("{:.2}x", b.result.energy_pj / s.result.energy_pj),
            ]);
        }
    }
    t
}

/// Fig 12: memory-hierarchy exploration — total AlexNet energy as a
/// function of RF size (columns) and SRAM buffer size (rows). The grid
/// is expressed as a [`DesignSpace`] (single-level RFs, ratio filter
/// wide open) and swept through the netopt runner — sharded when
/// `INTERSTELLAR_SHARDS` asks for it.
pub fn fig12_memory(effort: Effort, threads: usize) -> Table {
    let opts = effort.opts();
    let mut net = network("alexnet", effort.batch()).unwrap();
    if effort == Effort::Smoke {
        net = net.dedup_shapes();
    }
    let (rf_sizes, sram_sizes): (&[u64], &[u64]) = match effort {
        Effort::Smoke => (&[32, 64], &[64 << 10, 128 << 10]),
        _ => (
            &[32, 64, 128, 256, 512],
            &[64 << 10, 128 << 10, 256 << 10, 512 << 10],
        ),
    };
    let mut space = DesignSpace::paper_default(ArrayShape { rows: 16, cols: 16 });
    space.rf1_sizes = rf_sizes.to_vec();
    space.rf2_ratios = Vec::new();
    space.gbuf_sizes = sram_sizes.to_vec();
    space.ratio_min = 0.0;
    space.ratio_max = f64::INFINITY;
    let res = sweep_space(&net, &space, &opts, threads);
    let by_name: HashMap<&str, &HierarchyResult> = res
        .ranked
        .iter()
        .map(|r| (r.arch.name.as_str(), r))
        .collect();
    let mut header = vec!["SRAM \\ RF".to_string()];
    header.extend(rf_sizes.iter().map(|r| format!("{} B", r)));
    let mut t = Table::new(header);
    for &sram in &sram_sizes {
        let mut row = vec![fmt_bytes(sram)];
        for &rf in &rf_sizes {
            let name = format!("rf{rf}-sram{}", sram >> 10);
            let cell = match by_name.get(name.as_str()) {
                Some(r) => fmt_sig(r.opt.total_energy_pj / 1e6) + &unmapped_note(r.opt.unmapped),
                None => "-".into(),
            };
            row.push(cell);
        }
        t.row(row);
    }
    t
}

/// Fig 13: optimal memory allocation and total energy vs PE array size.
pub fn fig13_scaling(effort: Effort, threads: usize) -> Table {
    let net = network("alexnet", effort.batch()).unwrap();
    let net = if effort == Effort::Smoke {
        net.dedup_shapes()
    } else {
        net
    };
    let mut opts = effort.opts();
    if effort != Effort::Full {
        opts.max_order_combos = 9; // hierarchy sweeps multiply everything
    }
    let mut t = Table::new(vec![
        "array", "best RF", "best SRAM", "energy (uJ)", "RF bytes/PE",
    ]);
    let sizes: &[u32] = match effort {
        Effort::Smoke => &[8, 16],
        Effort::Fast => &[8, 16, 32],
        Effort::Full => &[8, 16, 32, 64],
    };
    for &n in sizes {
        let space = space_for_effort(ArrayShape { rows: n, cols: n }, effort);
        let results = sweep_space(&net, &space, &opts, threads).ranked;
        if let Some(best) = results.first() {
            let rf = best.arch.levels[0].size_bytes;
            let sram = best
                .arch
                .levels
                .iter()
                .find(|l| l.kind == crate::arch::LevelKind::Sram)
                .map(|l| l.size_bytes)
                .unwrap_or(0);
            let energy =
                fmt_sig(best.opt.total_energy_pj / 1e6) + &unmapped_note(best.opt.unmapped);
            t.row(vec![
                format!("{n}x{n}"),
                fmt_bytes(rf),
                fmt_bytes(sram),
                energy,
                format!("{rf}"),
            ]);
        }
    }
    t
}

/// Fig 14: the auto-optimizer across all nine benchmarks: energy on the
/// Eyeriss-like baseline, on the optimized hierarchy, and the gain.
pub fn fig14_optimizer(effort: Effort, threads: usize) -> Table {
    let df = Dataflow::parse("C|K").unwrap();
    let mut opts = effort.opts();
    match effort {
        Effort::Smoke => {
            opts.max_blockings = 150;
            opts.max_order_combos = 4;
        }
        Effort::Fast => {
            opts.max_blockings = 400;
            opts.max_order_combos = 9;
        }
        Effort::Full => {}
    }
    let mut t = Table::new(vec![
        "network",
        "baseline (uJ)",
        "optimized (uJ)",
        "gain",
        "opt arch",
        "TOPS/W",
    ]);
    let names = crate::nn::network_names();
    // Smoke: one representative per family (conv / mlp / recurrent) —
    // same driver and columns, three rows instead of nine
    let names: &[&str] = match effort {
        Effort::Smoke => &["alexnet", "mlp-m", "lstm-m"],
        _ => &names[..],
    };
    for &name in names {
        let batch = match effort {
            _ if name.starts_with("lstm") || name == "rhn" => 1,
            _ if name.starts_with("mlp") => 32,
            _ => effort.batch(),
        };
        let Some(net) = network(name, batch) else { continue };
        let net = reduce_for_effort(net, effort);
        let baseline = optimize_network(&net, &eyeriss_like(), &df, &Table3, &opts, threads);
        let space = space_for_effort(ArrayShape { rows: 16, cols: 16 }, effort);
        let results = sweep_space(&net, &space, &opts, threads).ranked;
        if let Some(best) = results.first() {
            // flag each side's unmapped layers on its own column, so an
            // incomplete baseline is not misread as an optimizer defect
            let base_cell =
                fmt_sig(baseline.total_energy_pj / 1e6) + &unmapped_note(baseline.unmapped);
            let arch_name = best.arch.name.clone() + &unmapped_note(best.opt.unmapped);
            t.row(vec![
                name.to_string(),
                base_cell,
                fmt_sig(best.opt.total_energy_pj / 1e6),
                format!(
                    "{:.2}x",
                    baseline.total_energy_pj / best.opt.total_energy_pj
                ),
                arch_name,
                format!("{:.2}", best.opt.tops_per_watt()),
            ]);
        }
    }
    t
}

/// Fig 14 companion: the large (TPU-like) baseline for one network.
/// Returns `None` for unknown networks *and* when any layer came back
/// unmappable — a partial total would silently under-report the chip.
/// The TPU-like point has two SRAM levels, which the grid generator
/// cannot express, so it rides the explicit-architecture entry point of
/// the same netopt runner the sharded sweeps use
/// ([`co_optimize_arches`]).
pub fn large_chip_energy(name: &str, effort: Effort, threads: usize) -> Option<f64> {
    let opts = effort.opts();
    let net = reduce_for_effort(network(name, effort.batch())?, effort);
    let cfg = NetOptConfig::exhaustive(opts, threads);
    let res = co_optimize_arches(&net, &[tpu_like()], &Table3, &cfg);
    let point = res.ranked.first()?;
    if point.opt.unmapped > 0 {
        return None;
    }
    Some(point.opt.total_energy_pj)
}

/// In Fast mode, trim very deep networks to their unique layer shapes to
/// bound bench time (energies remain representative per-layer; Full mode
/// keeps every layer).
fn reduce_for_effort(net: Network, effort: Effort) -> Network {
    match effort {
        Effort::Full => net,
        Effort::Fast | Effort::Smoke => net.dedup_shapes(),
    }
}

/// Cell/line annotation for results with unmappable layers: empty when
/// fully mapped, `" (N unmapped)"` otherwise — their totals under-report
/// and must not read as valid design points.
pub(crate) fn unmapped_note(unmapped: usize) -> String {
    if unmapped == 0 {
        String::new()
    } else {
        format!(" ({unmapped} unmapped)")
    }
}

/// Search-efficiency companion to Fig 14: per AlexNet layer, the staged
/// engine's full (stage-4) evaluation counts under exhaustive evaluation
/// vs branch-and-bound, and whether both found the identical winner (the
/// engine's pruning contract says they must; the `perf_search` bench
/// asserts it).
pub fn search_pruning(effort: Effort, threads: usize) -> Table {
    let df = Dataflow::parse("C|K").unwrap();
    let arch = eyeriss_like();
    let net = network("alexnet", effort.batch()).unwrap();
    let mut t = Table::new(vec![
        "layer",
        "candidates",
        "full (exhaustive)",
        "full (b&b)",
        "reduction",
        "pruned@bound",
        "same best",
    ]);
    for layer in &net.layers {
        let ex_opts = effort.opts().with_prune(PruneMode::Exhaustive);
        let bb_opts = effort.opts().with_prune(PruneMode::BranchAndBound);
        let ex = optimize_layer(&layer.shape, &arch, &df, &Table3, &ex_opts, threads);
        let bb = optimize_layer(&layer.shape, &arch, &df, &Table3, &bb_opts, threads);
        let (Some(ex), Some(bb)) = (ex, bb) else { continue };
        let same = ex.result.energy_pj == bb.result.energy_pj && ex.mapping == bb.mapping;
        let reduction = ex.stats.full as f64 / bb.stats.full.max(1) as f64;
        t.row(vec![
            layer.name.clone(),
            format!("{}", ex.evaluated),
            format!("{}", ex.stats.full),
            format!("{}", bb.stats.full),
            format!("{reduction:.1}x"),
            format!("{}", bb.stats.pruned),
            format!("{same}"),
        ]);
    }
    t
}

/// Network-level companion to [`search_pruning`] (CLI `search-stats`):
/// runs the §6.3 hierarchy sweep once with the cross-architecture
/// branch-and-bound and once exhaustively, and reports the aggregated
/// [`crate::netopt::NetOptStats`] counters side by side — architecture
/// points generated / ratio-filtered / pruned / fully evaluated, plus
/// the rolled-up engine counters and whether the winners matched (the
/// netopt winner-identity contract says they must; `perf_netopt`
/// asserts it).
pub fn netopt_pruning(effort: Effort, threads: usize) -> Table {
    let mut opts = effort.opts();
    opts.max_order_combos = 9;
    let net = reduce_for_effort(network("mlp-m", 32).unwrap(), effort);
    let space = DesignSpace::paper_default(ArrayShape { rows: 16, cols: 16 });
    let bb_cfg = NetOptConfig::new(opts.clone(), threads);
    let ex_cfg = NetOptConfig::exhaustive(opts, threads);
    let bb = co_optimize(&net, &space, &Table3, &bb_cfg);
    let ex = co_optimize(&net, &space, &Table3, &ex_cfg);
    let same = match (bb.best(), ex.best()) {
        (Some(a), Some(b)) => {
            a.arch.name == b.arch.name && a.opt.total_energy_pj == b.opt.total_energy_pj
        }
        _ => false,
    };
    let (sb, se) = (&bb.stats, &ex.stats);
    let mut t = Table::new(vec!["metric", "b&b", "exhaustive"]);
    let counters: Vec<(&str, u64, u64)> = vec![
        ("arch points generated", sb.generated as u64, se.generated as u64),
        ("budget-filtered", sb.budget_filtered as u64, se.budget_filtered as u64),
        ("ratio-filtered (Obs 2)", sb.ratio_filtered as u64, se.ratio_filtered as u64),
        ("candidates", sb.candidates as u64, se.candidates as u64),
        ("pruned (network bound)", sb.pruned as u64, se.pruned as u64),
        ("fully evaluated", sb.evaluated_full as u64, se.evaluated_full as u64),
        ("infeasible", sb.infeasible as u64, se.infeasible as u64),
        ("layer searches", sb.layer_searches as u64, se.layer_searches as u64),
        ("seed reruns", sb.layer_reruns as u64, se.layer_reruns as u64),
        ("engine full evals", sb.engine.full, se.engine.full),
        ("engine pruned@bound", sb.engine.pruned, se.engine.pruned),
    ];
    for (metric, b, e) in counters {
        t.row(vec![metric.to_string(), format!("{b}"), format!("{e}")]);
    }
    let winner = |r: &CoOptResult| -> String {
        r.best()
            .map(|w| w.arch.name.clone())
            .unwrap_or_else(|| "-".into())
    };
    t.row(vec!["winner".to_string(), winner(&bb), winner(&ex)]);
    let same_cell = format!("{same}");
    t.row(vec!["same winner".to_string(), same_cell, String::new()]);
    t
}

/// §6.3 frontier companion (CLI `report` and `pareto`, `perf_pareto`
/// bench): instead of collapsing the default design space to one
/// `min_tops`-constrained winner, report the whole energy/throughput
/// trade curve — every dominance-surviving `(energy, cycles)` point of
/// the sweep, ascending in energy. The paper's iso-throughput
/// comparison then reads off the min-energy point at each latency
/// budget (`pareto::PlanSelector`), which matches the scalar
/// co-optimizer's constrained winner bit for bit (gated by
/// `benches/perf_pareto.rs`).
pub fn pareto_curve(effort: Effort, threads: usize) -> Table {
    let mut opts = effort.opts();
    opts.max_order_combos = 9;
    let net = reduce_for_effort(network("mlp-m", 32).unwrap(), effort);
    let space = space_for_effort(ArrayShape { rows: 16, cols: 16 }, effort);
    let cfg = NetOptConfig::new(opts, threads);
    let res = pareto_optimize(&net, &space, &Table3, &cfg, &ParetoConfig::default());
    let mut t = Table::new(vec![
        "arch",
        "energy (uJ)",
        "Mcycles",
        "TOPS @1GHz",
        "TOPS/W",
    ]);
    for e in &res.frontier {
        let o = &e.result.opt;
        t.row(vec![
            e.result.arch.name.clone(),
            fmt_sig(o.total_energy_pj / 1e6),
            format!("{:.3}", o.total_cycles / 1e6),
            format!("{:.3}", o.tops(1.0)),
            format!("{:.2}", o.tops_per_watt()),
        ]);
    }
    t
}

/// Serving-time remapping companion (CLI `report`, `perf_remap` bench):
/// drive a synthetic drift trace — front half `{conv3x3, fc}`, back half
/// pure `lstm_cell` — through the batched serve loop with remapping
/// enabled (synthetic executor, so no artifacts or `pjrt` are needed)
/// and report how the plan tracked the mix. The equivalence contract
/// (online plan == offline `co_optimize_arches` on the final mix, bit
/// for bit) is asserted by `coordinator::tests` and gated in CI by
/// `benches/perf_remap.rs`, which emits `BENCH_remap.json`.
pub fn remap_drift(threads: usize) -> Table {
    use super::remap::{RemapPolicy, Remapper};
    use super::serve::{drift_trace, serve_with, ServeConfig, SyntheticExecutor};
    let trace = drift_trace(96, 48, &["conv3x3", "fc"], &["lstm_cell"], 11);
    let mut r = Remapper::new(RemapPolicy::new(24, 0.4), Remapper::default_candidates());
    let stats = serve_with(
        trace,
        &ServeConfig::new(threads).with_batch(12),
        || Ok(SyntheticExecutor),
        Some(&mut r),
    )
    .expect("synthetic serving cannot fail");
    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["requests served".into(), format!("{}", stats.completed)]);
    t.row(vec!["scheduling batches".into(), format!("{}", stats.batches)]);
    t.row(vec!["plan swaps".into(), format!("{}", stats.remaps)]);
    t.row(vec!["drift checks".into(), format!("{}", r.checks)]);
    t.row(vec!["seeded shapes".into(), format!("{}", r.seeds().len())]);
    match r.plan() {
        Some(p) => {
            t.row(vec!["final plan arch".into(), p.winner.arch.describe()]);
            t.row(vec![
                "final plan energy (uJ)".into(),
                fmt_sig(p.winner.opt.total_energy_pj / 1e6),
            ]);
            t.row(vec!["final mix".into(), format!("{:?}", p.mix)]);
        }
        None => {
            t.row(vec!["final plan arch".into(), "-".into()]);
        }
    }
    t
}

/// Robustness ablation (§6.1 "different energy cost models"): the Fig 8
/// dataflow spread under scaled cost models.
pub fn ablation_cost_models(shape: Shape, threads: usize) -> Table {
    use crate::energy::ScaledCost;
    let opts = Effort::Fast.opts();
    let models: Vec<(String, Box<dyn CostModel>)> = vec![
        ("table3".into(), Box::new(Table3)),
        (
            "mem x2".into(),
            Box::new(ScaledCost {
                mem_scale: 2.0,
                mac_scale: 1.0,
                dram_scale: 1.0,
            }),
        ),
        (
            "dram x0.5".into(),
            Box::new(ScaledCost {
                mem_scale: 1.0,
                mac_scale: 1.0,
                dram_scale: 0.5,
            }),
        ),
        (
            "mac x4".into(),
            Box::new(ScaledCost {
                mem_scale: 1.0,
                mac_scale: 4.0,
                dram_scale: 1.0,
            }),
        ),
    ];
    let mut t = Table::new(vec!["cost model", "dataflow spread (max/min)"]);
    for (name, cost) in &models {
        let energies: Vec<f64> = enumerate_dataflows(&shape)
            .into_iter()
            .filter_map(|df| {
                optimize_layer(&shape, &eyeriss_like(), &df, cost.as_ref(), &opts, threads)
                    .map(|lo| lo.result.energy_pj)
            })
            .collect();
        t.row(vec![
            name.clone(),
            format!("{:.2}x", stats::max(&energies) / stats::min(&energies).max(1e-30)),
        ]);
    }
    t
}

/// Every artifact `report_all` writes, in write order — the paper map
/// (table 3, figs 7–14), the frontier/serving companions, and the
/// perf-trajectory table. The `report --all` smoke test iterates this
/// list, so an artifact silently dropped from [`report_all`] fails
/// tier-1.
pub const REPORT_ARTIFACTS: &[&str] = &[
    "table3.csv",
    "fig7_validation.csv",
    "fig7b_eyeriss_breakdown.csv",
    "fig8_dataflow.csv",
    "fig9_utilization.csv",
    "fig10_blocking.csv",
    "fig11_breakdown.csv",
    "fig12_memory.csv",
    "fig13_scaling.csv",
    "fig14_optimizer.csv",
    "pareto_curve.csv",
    "remap_drift.csv",
    "bench_trajectory.csv",
];

/// One-command paper-artifact regeneration (CLI `report --all`,
/// documented in REPRODUCING.md): run every experiment driver at the
/// given effort and write each table as CSV into `dir`, plus the
/// perf-trajectory table rendered from `history` (an absent history
/// yields a header-only table — the artifact set never thins out).
/// Returns the written paths in [`REPORT_ARTIFACTS`] order.
pub fn report_all(
    dir: &std::path::Path,
    effort: Effort,
    threads: usize,
    history: &std::path::Path,
) -> anyhow::Result<Vec<std::path::PathBuf>> {
    use anyhow::Context;
    std::fs::create_dir_all(dir).with_context(|| format!("create {}", dir.display()))?;
    let shape = alexnet_conv3(effort.batch());
    let fig7 = match effort {
        // same comparison, one small layer, reduced exact-walk budget
        Effort::Smoke => fig7_validation_over(
            &[("CONV-S".into(), Shape::new(1, 32, 16, 8, 8, 3, 3, 1))],
            &SearchOpts::capped(150, 4),
            500_000_000,
            threads,
        ),
        _ => fig7_validation(threads),
    };
    let trajectory = {
        let h = crate::bench::read_history(history);
        crate::bench::trajectory_table(&crate::bench::trajectory(&h))
    };
    let tables: Vec<(&str, Table)> = vec![
        ("table3.csv", table3()),
        ("fig7_validation.csv", fig7),
        ("fig7b_eyeriss_breakdown.csv", fig7b_eyeriss_breakdown(effort, threads)),
        ("fig8_dataflow.csv", fig8_dataflow(shape, effort, threads)),
        ("fig9_utilization.csv", fig9_utilization(shape)),
        ("fig10_blocking.csv", fig10_blocking(shape, effort, threads)),
        ("fig11_breakdown.csv", fig11_breakdown(effort, threads)),
        ("fig12_memory.csv", fig12_memory(effort, threads)),
        ("fig13_scaling.csv", fig13_scaling(effort, threads)),
        ("fig14_optimizer.csv", fig14_optimizer(effort, threads)),
        ("pareto_curve.csv", pareto_curve(effort, threads)),
        ("remap_drift.csv", remap_drift(threads)),
        ("bench_trajectory.csv", trajectory),
    ];
    let names: Vec<&str> = tables.iter().map(|(n, _)| *n).collect();
    assert_eq!(
        names, REPORT_ARTIFACTS,
        "REPORT_ARTIFACTS must list exactly the tables report_all writes"
    );
    let mut written = Vec::new();
    for (name, t) in &tables {
        let path = dir.join(name);
        std::fs::write(&path, t.to_csv()).with_context(|| format!("write {}", path.display()))?;
        written.push(path);
    }
    Ok(written)
}

/// Handy accessor used by several benches: CONV3 dims are divisor-awkward
/// (13, 3) which exercises replication.
pub fn spotlight_layers(effort: Effort) -> Vec<(String, Shape)> {
    vec![
        (
            format!("AlexNet CONV3 (b={})", effort.batch()),
            alexnet_conv3(effort.batch()),
        ),
        ("AlexNet CONV3 (b=1)".into(), alexnet_conv3(1)),
        (
            format!("GoogLeNet 4C3R (b={})", effort.batch()),
            googlenet_4c3r(effort.batch()),
        ),
        ("GoogLeNet 4C3R (b=1)".into(), googlenet_4c3r(1)),
    ]
}
