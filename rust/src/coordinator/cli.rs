//! The `interstellar` CLI: subcommands for optimization, sweeps,
//! validation, schedule display, and the end-to-end serving driver.

use std::path::PathBuf;

use anyhow::{bail, Result};

use super::experiments::{self, Effort};
use super::serve;
use crate::arch::{eyeriss_like, ArrayShape};
use crate::dataflow::Dataflow;
use crate::energy::Table3;
use crate::engine::PruneMode;
use crate::netopt::{co_optimize, CoOptResult, DesignSpace, NetOptConfig};
use crate::nn::{network, Network};
use crate::search::{default_threads, optimize_network, search_hierarchy, SearchOpts};
use crate::util::{fmt_sig, Args};

const USAGE: &str = "interstellar — Halide-schedule analysis of DNN accelerators (ASPLOS'20 reproduction)

USAGE: interstellar <command> [options]

COMMANDS:
  optimize        --net <name> [--batch N] [--rows 16 --cols 16] [--full]
                  run the auto-optimizer (fix C|K + ratio rule) on a network
  co-opt          --net <name> [--batch N] [--rows 16 --cols 16] [--full]
                  [--budget BYTES] [--min-tops T] [--clock-ghz G] [--json]
                  network-level co-optimizer: cross-architecture b&b over
                  the design space, with capacity/throughput constraints
  sweep-dataflow  [--layer conv3|4c3r] [--batch N] [--full]   (Fig 8)
  utilization     [--layer conv3|4c3r] [--batch N]            (Fig 9)
  sweep-blocking  [--layer conv3|4c3r] [--batch N] [--full]   (Fig 10)
  breakdown       [--full]                                    (Fig 11)
  sweep-memory    [--full]                                    (Fig 12)
  scaling         [--full]                                    (Fig 13)
  optimizer-gains [--full]                                    (Fig 14)
  validate        model-vs-simulator validation               (Fig 7 / Table 4)
  search-stats    staged-engine + network-level pruning counters
  table3          print the energy cost table                 (Table 3)
  schedules       print prior-work schedules lowered to IR    (Listing 2 / Fig 6)
  run-e2e         [--requests N] [--threads N] [--artifacts DIR]
                  serve a mixed trace through the PJRT artifacts
  report          run every experiment at fast effort

Common options: --threads N (default: cores-1), --csv (CSV output), --full";

/// CLI entrypoint.
pub fn run(args: Args) -> Result<()> {
    let Some(cmd) = args.positional.first().map(|s| s.as_str()) else {
        println!("{USAGE}");
        return Ok(());
    };
    let threads = args.get_usize("threads", default_threads());
    let effort = if args.has_flag("full") {
        Effort::Full
    } else {
        Effort::Fast
    };
    let csv = args.has_flag("csv");
    let show = |t: &crate::util::table::Table| {
        if csv {
            print!("{}", t.to_csv());
        } else {
            print!("{}", t.to_text());
        }
    };

    let layer_shape = |args: &Args| {
        let batch = args.get_u64("batch", effort.batch_for_cli());
        match args.get_str("layer", "conv3") {
            "4c3r" => experiments::googlenet_4c3r(batch),
            _ => experiments::alexnet_conv3(batch),
        }
    };

    match cmd {
        "optimize" => {
            let name = args.get_str("net", "alexnet");
            let batch = args.get_u64("batch", 4);
            let Some(net) = network(name, batch) else {
                bail!("unknown network {name} (try: {:?})", crate::nn::network_names());
            };
            let rows = args.get_u64("rows", 16) as u32;
            let cols = args.get_u64("cols", 16) as u32;
            println!("optimizing {name} (batch {batch}) on {rows}x{cols} PEs...");
            let opts = effort_opts(effort);
            let df = Dataflow::parse("C|K").unwrap();
            let baseline =
                optimize_network(&net, &eyeriss_like(), &df, &Table3, &opts, threads);
            let results =
                search_hierarchy(&net, ArrayShape { rows, cols }, &Table3, &opts, threads);
            let Some(best) = results.first() else {
                bail!("no feasible hierarchy found");
            };
            println!(
                "baseline (Eyeriss-like): {} uJ{}",
                fmt_sig(baseline.total_energy_pj / 1e6),
                experiments::unmapped_note(baseline.unmapped)
            );
            println!(
                "optimized: {} uJ on {}  ({:.2}x better, {:.2} TOPS/W){}",
                fmt_sig(best.opt.total_energy_pj / 1e6),
                best.arch.describe(),
                baseline.total_energy_pj / best.opt.total_energy_pj,
                best.opt.tops_per_watt(),
                experiments::unmapped_note(best.opt.unmapped),
            );
            println!("\ntop-5 hierarchies:");
            for r in results.iter().take(5) {
                println!(
                    "  {:<24} {} uJ{}",
                    r.arch.name,
                    fmt_sig(r.opt.total_energy_pj / 1e6),
                    experiments::unmapped_note(r.opt.unmapped)
                );
            }
        }
        "co-opt" => {
            let name = args.get_str("net", "alexnet");
            let batch = args.get_u64("batch", 4);
            let Some(net) = network(name, batch) else {
                bail!("unknown network {name} (try: {:?})", crate::nn::network_names());
            };
            let rows = args.get_u64("rows", 16) as u32;
            let cols = args.get_u64("cols", 16) as u32;
            let mut space = DesignSpace::paper_default(ArrayShape { rows, cols });
            if args.get("budget").is_some() {
                space.max_onchip_bytes = Some(args.get_u64("budget", u64::MAX));
            }
            let mut cfg = NetOptConfig::new(effort_opts(effort), threads);
            cfg.clock_ghz = args.get_f64("clock-ghz", 1.0);
            if args.get("min-tops").is_some() {
                cfg.min_tops = Some(args.get_f64("min-tops", 0.0));
            }
            let res = co_optimize(&net, &space, &Table3, &cfg);
            if args.has_flag("json") {
                println!("{}", co_opt_json(&net, &res, &cfg));
            } else {
                print_co_opt(&net, &res, &cfg);
            }
        }
        "sweep-dataflow" => show(&experiments::fig8_dataflow(layer_shape(&args), effort, threads)),
        "utilization" => show(&experiments::fig9_utilization(layer_shape(&args))),
        "sweep-blocking" => show(&experiments::fig10_blocking(layer_shape(&args), effort, threads)),
        "breakdown" => show(&experiments::fig11_breakdown(effort, threads)),
        "sweep-memory" => show(&experiments::fig12_memory(effort, threads)),
        "scaling" => show(&experiments::fig13_scaling(effort, threads)),
        "optimizer-gains" => show(&experiments::fig14_optimizer(effort, threads)),
        "validate" => show(&experiments::fig7_validation(threads)),
        "search-stats" => {
            println!("== per-layer staged-engine pruning (exhaustive vs b&b) ==");
            show(&experiments::search_pruning(effort, threads));
            println!("\n== network-level co-optimizer (arch points, b&b vs exhaustive) ==");
            show(&experiments::netopt_pruning(effort, threads));
        }
        "table3" => show(&experiments::table3()),
        "schedules" => print_schedules(),
        "run-e2e" => {
            let n = args.get_usize("requests", 200);
            let dir = PathBuf::from(args.get_str("artifacts", "artifacts"));
            let trace = serve::mixed_trace(n, 42);
            println!("serving {n} requests from {} on {threads} workers...", dir.display());
            let stats = serve::serve(&dir, trace, threads)?;
            println!(
                "completed {}  wall {:.2}s  mean {:.2} ms  p95 {:.2} ms  {:.1} req/s  checksum {:.3}",
                stats.completed,
                stats.wall_s,
                stats.mean_latency_ms,
                stats.p95_latency_ms,
                stats.rps,
                stats.checksum
            );
        }
        "report" => {
            println!("== Table 3 ==");
            show(&experiments::table3());
            println!("\n== Fig 7 (validation) ==");
            show(&experiments::fig7_validation(threads));
            println!("\n== Fig 8 (dataflows, AlexNet CONV3) ==");
            show(&experiments::fig8_dataflow(
                experiments::alexnet_conv3(4),
                effort,
                threads,
            ));
            println!("\n== Fig 9 (utilization) ==");
            show(&experiments::fig9_utilization(experiments::alexnet_conv3(4)));
            println!("\n== Fig 10 (blocking) ==");
            show(&experiments::fig10_blocking(
                experiments::alexnet_conv3(4),
                effort,
                threads,
            ));
            println!("\n== Fig 11 (RF breakdown) ==");
            show(&experiments::fig11_breakdown(effort, threads));
            println!("\n== Fig 12 (memory sweep) ==");
            show(&experiments::fig12_memory(effort, threads));
            println!("\n== Fig 13 (scaling) ==");
            show(&experiments::fig13_scaling(effort, threads));
            println!("\n== Fig 14 (optimizer gains) ==");
            show(&experiments::fig14_optimizer(effort, threads));
        }
        other => {
            println!("unknown command: {other}\n\n{USAGE}");
        }
    }
    Ok(())
}

fn effort_opts(e: Effort) -> SearchOpts {
    match e {
        Effort::Fast => SearchOpts::capped(600, 5),
        Effort::Full => SearchOpts::capped(20_000, 8),
    }
}

impl Effort {
    fn batch_for_cli(self) -> u64 {
        match self {
            Effort::Fast => 4,
            Effort::Full => 16,
        }
    }
}

/// Human-readable `co-opt` report: winner, top-5, stats line.
fn print_co_opt(net: &Network, res: &CoOptResult, cfg: &NetOptConfig) {
    println!(
        "co-optimizing {} (batch {}, {} layers)...",
        net.name,
        net.batch,
        net.layers.len()
    );
    match res.best() {
        Some(best) => {
            println!(
                "best: {} — {} uJ, {:.2} TOPS/W, {:.3} TOPS @ {} GHz",
                best.arch.describe(),
                fmt_sig(best.opt.total_energy_pj / 1e6),
                best.opt.tops_per_watt(),
                best.opt.tops(cfg.clock_ghz),
                cfg.clock_ghz
            );
        }
        None => println!("no feasible architecture point (see stats below)"),
    }
    println!("\ntop-5 points:");
    for r in res.ranked.iter().take(5) {
        println!(
            "  {:<24} {} uJ{}",
            r.arch.name,
            fmt_sig(r.opt.total_energy_pj / 1e6),
            experiments::unmapped_note(r.opt.unmapped)
        );
    }
    if cfg.prune == PruneMode::BranchAndBound {
        println!("(b&b ranking: pruned points omitted; only the best point's");
        println!(" energy is exact — `optimize` prints a fully exact ranking)");
    }
    println!("\n{}", res.stats);
}

/// Minimal JSON escaping for the hand-rolled reports (arch and network
/// names are plain ASCII, but stay safe on quotes and backslashes).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Format a float as a JSON number — `null` for non-finite values
/// (e.g. the NaN TOPS of a point whose every layer is unmapped).
fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".into()
    }
}

/// Machine-readable `co-opt` report (the `--json` flag): every ranked
/// point plus the netopt counters.
fn co_opt_json(net: &Network, res: &CoOptResult, cfg: &NetOptConfig) -> String {
    let mut points = Vec::with_capacity(res.ranked.len());
    for r in &res.ranked {
        points.push(format!(
            "{{\"arch\":{},\"energy_pj\":{},\"cycles\":{},\"macs\":{},\
             \"tops_per_watt\":{},\"tops\":{},\"unmapped\":{}}}",
            json_str(&r.arch.name),
            json_num(r.opt.total_energy_pj),
            json_num(r.opt.total_cycles),
            r.opt.total_macs,
            json_num(r.opt.tops_per_watt()),
            json_num(r.opt.tops(cfg.clock_ghz)),
            r.opt.unmapped
        ));
    }
    let s = &res.stats;
    format!(
        "{{\"network\":{},\"batch\":{},\"layers\":{},\"clock_ghz\":{},\
         \"best\":{},\"points\":[{}],\
         \"stats\":{{\"generated\":{},\"budget_filtered\":{},\"ratio_filtered\":{},\
         \"candidates\":{},\"pruned\":{},\"evaluated_full\":{},\"infeasible\":{},\
         \"throughput_filtered\":{},\"layer_searches\":{},\"layer_reruns\":{},\
         \"engine\":{{\"stage2\":{},\"stage3\":{},\"pruned\":{},\"full\":{}}}}}}}",
        json_str(&net.name),
        net.batch,
        net.layers.len(),
        cfg.clock_ghz,
        res.best()
            .map(|b| json_str(&b.arch.name))
            .unwrap_or_else(|| "null".into()),
        points.join(","),
        s.generated,
        s.budget_filtered,
        s.ratio_filtered,
        s.candidates,
        s.pruned,
        s.evaluated_full,
        s.infeasible,
        s.throughput_filtered,
        s.layer_searches,
        s.layer_reruns,
        s.engine.stage2,
        s.engine.stage3,
        s.engine.pruned,
        s.engine.full
    )
}

fn print_schedules() {
    use crate::halide::{diannao_tree, eyeriss_rs, nvdla_like, print_ir, shidiannao_os, tpu_ck};
    let conv3 = experiments::alexnet_conv3(4);
    for s in [
        eyeriss_rs(conv3, 16, 16),
        tpu_ck(conv3, 16, 16),
        shidiannao_os(conv3, 16, 16),
        diannao_tree(conv3, 16),
        nvdla_like(conv3, 16, 16),
    ] {
        println!("== {} ==", s.name);
        println!("{}", print_ir(&s));
    }
}
