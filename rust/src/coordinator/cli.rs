//! The `interstellar` CLI: subcommands for optimization, sweeps,
//! validation, schedule display, and the end-to-end serving driver.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::experiments::{self, Effort};
use super::remap::{RemapPolicy, Remapper};
use super::serve;
use super::trace::TraceSpec;
use crate::arch::{eyeriss_like, ArrayShape};
use crate::dataflow::Dataflow;
use crate::energy::Table3;
use crate::engine::PruneMode;
use crate::fleet::{run_fleet, run_worker, FleetConfig, WorkerConfig};
use crate::netopt::{
    co_optimize, co_optimize_shard, merge_all, CoOptResult, DesignSpace, NetOptConfig,
    ShardCheckpoint,
};
use crate::nn::{network, Network};
use crate::orchestrator::{
    orchestrate, run_coopt_shard_streamed, run_pareto_shard_streamed, BoundsLink, MergedSweep,
    OrchestrateConfig, SweepMode, TaskOutcome,
};
use crate::pareto::{
    merge_all_frontiers, pareto_optimize, pareto_optimize_shard, FrontierCheckpoint,
    FrontierEntry, ParetoConfig, ParetoResult, PlanSelector,
};
use crate::search::{
    default_threads, optimize_layer, optimize_network, search_hierarchy, SearchOpts,
};
use crate::telemetry;
use crate::util::json::Json;
use crate::util::{fmt_sig, Args};

const USAGE: &str = "interstellar — Halide-schedule analysis of DNN accelerators (ASPLOS'20 reproduction)

USAGE: interstellar <command> [options]

COMMANDS:
  optimize        --net <name> [--batch N] [--rows 16 --cols 16] [--full]
                  run the auto-optimizer (fix C|K + ratio rule) on a network
  co-opt          --net <name> [--batch N] [--head N] [--rows 16 --cols 16]
                  [--full] [--budget BYTES] [--min-tops T] [--clock-ghz G]
                  [--rf1 L] [--rf2-ratio L] [--gbuf L] [--ratio-min R]
                  [--ratio-max R] [--cap N] [--divisors N] [--orders N]
                  [--no-prime] [--shard I/N --checkpoint PATH] [--json]
                  [--bounds PATH --bounds-interval MS --worker-id K]
                  network-level co-optimizer: cross-architecture b&b over
                  the design space, with capacity/throughput constraints;
                  L are comma-separated byte sizes. --shard runs one
                  worker slice and writes a mergeable JSON checkpoint;
                  --bounds streams the live incumbent through a shared
                  bounds file (admissible hints: same winner bits);
                  the heuristic scout primes the b&b incumbent unless
                  --no-prime (the winner is bit-identical either way)
  co-opt-merge    <ckpt.json>... [--out PATH] [--json]
                  merge shard checkpoints (any order): winner is
                  bit-identical to the single-process co-opt run
  pareto          --net <name> [--batch N] [--head N] [--space paper|full]
                  [--eps E] [--points N] [--latency-budget CYCLES]
                  [--no-prime] [co-opt's space/search/constraint knobs]
                  [--shard I/N --checkpoint PATH] [--json]
                  [--bounds PATH --bounds-interval MS --worker-id K]
                  exact (energy, cycles) frontier of the design space
                  instead of a single winner; --latency-budget also picks
                  the min-energy point within the cycle budget; --bounds
                  streams live frontier snapshots between shard workers
  orchestrate     --mode co-opt|pareto --net <name> [--workers N]
                  [--nshards M] [--steal | --no-steal] [--steal-split K]
                  [--straggler-factor F] [--no-bounds]
                  [--bounds-interval MS] [--dir PATH] [--out PATH]
                  [--worker-threads N] [--hosts 'CMD;CMD'] [--json]
                  [co-opt/pareto's space/search/constraint knobs]
                  fan the sweep across worker processes: work stealing
                  re-splits failed or straggling shards into sub-shards
                  for idle workers (on by default; --no-steal disables),
                  live bounds stream between workers through a shared
                  append-only file, and the merged winner/frontier is
                  bit-identical to the single-process run. --hosts gives
                  semicolon-separated launcher prefixes (e.g. ssh hosts)
                  round-robined over workers
  fastmap         --net <name> [--batch N] [--full]
                  microsecond greedy heuristic mapper vs the exact
                  per-layer search on the Eyeriss-like baseline: energy
                  gap and mapping-evaluation counts per unique layer
  pareto-merge    <ckpt.json>... [--out PATH] [--json]
                  merge frontier checkpoints (any order): frontier is
                  bit-identical to the single-process pareto run
  sweep-dataflow  [--layer conv3|4c3r] [--batch N] [--full]   (Fig 8)
  utilization     [--layer conv3|4c3r] [--batch N]            (Fig 9)
  sweep-blocking  [--layer conv3|4c3r] [--batch N] [--full]   (Fig 10)
  breakdown       [--full]                                    (Fig 11)
  sweep-memory    [--full]                                    (Fig 12)
  scaling         [--full]                                    (Fig 13)
  optimizer-gains [--full]                                    (Fig 14)
  validate        model-vs-simulator validation               (Fig 7 / Table 4)
  search-stats    staged-engine + network-level pruning counters
  table3          print the energy cost table                 (Table 3)
  schedules       print prior-work schedules lowered to IR    (Listing 2 / Fig 6)
  run-e2e         [--requests N] [--threads N] [--artifacts DIR]
                  serve a mixed trace through the PJRT artifacts
  serve           [--requests N] [--threads N] [--artifacts DIR]
                  [--batch-requests B] [--synthetic] [--remap]
                  [--window W] [--drift D] [--latency-budget CYCLES]
                  [--deadline]
                  batched serving loop; --remap re-optimizes mappings
                  online when the window mix drifts past D (plans swap
                  between batches); --latency-budget re-selects the
                  min-energy plan within the budget from a live
                  design-space frontier; --deadline publishes the
                  heuristic fast-path plan immediately on drift and
                  swaps in the exact plan when its search lands;
                  --synthetic runs the deterministic stand-in executor
                  (no artifacts needed)
  fleet           [--workers N] [--requests N] [--trace SPEC]
                  [--batch-requests B] [--worker-threads T] [--window W]
                  [--drift D] [--latency-budget CYCLES] [--deadline]
                  [--warm-start CKPT] [--dir PATH] [--bin PATH]
                  [--hosts 'CMD;CMD'] [--in-process] [--json]
                  multi-worker serving fleet over the synthetic executor:
                  N workers (OS processes round-robined over --hosts
                  launcher prefixes, or threads with --in-process) serve
                  interleaved shards of one seeded trace (--trace takes a
                  TraceSpec encoding, e.g. 240:42:steady@0:uniform@fc);
                  per-batch mixes stream into mix.jsonl, the controller
                  re-optimizes on fleet-level drift when --window W > 0
                  and broadcasts plan epochs through plans.jsonl;
                  --warm-start primes the re-optimizer from a sweep
                  checkpoint; the merged digest is bit-identical to the
                  single-process serve of the same trace
  fleet-worker    --worker=I --fleet=N --trace=SPEC --dir=PATH
                  [--threads=T] [--batch-requests=B] [--slow-ns=NS]
                  [--crash-after=B] [--pace]
                  one fleet serving worker (spawned by fleet)
  report          run every experiment at fast effort and print the tables
                  --all [--out DIR] [--smoke] [--history PATH]
                  regenerate every paper artifact (table3, figs 7-14, the
                  pareto/remap companions, the perf-trajectory table) as
                  CSV files in DIR (default report-artifacts/) in one
                  command; --smoke shrinks grids/caps for quick runs
  trace-report    [--trace PATH] [--check] [--require-planes P1,P2,..]
                  explain a telemetry trace written under
                  INTERSTELLAR_TRACE: self-time profile tree, per-worker
                  utilization, straggler and per-shard task tables, and
                  the merged serving-latency histogram; --check validates
                  instead of rendering (schema-valid records, zero
                  orphaned spans, --require-planes coverage — the CI
                  full-tier gate; see OBSERVABILITY.md)
  bench-report    [--history PATH] [--bench NAME] [--metric SUBSTR]
                  [--last N] [--check]
                  per-metric perf-trajectory tables (baseline median,
                  min/max, MAD dispersion band, latest + drift) from
                  bench_history.jsonl; --check exits nonzero when the
                  newest sample regresses against the historical
                  distribution (the CI gate; see BENCHMARKS.md)

Common options: --threads N (default: cores-1), --csv (CSV output), --full";

/// CLI entrypoint.
pub fn run(args: Args) -> Result<()> {
    let Some(cmd) = args.positional.first().map(|s| s.as_str()) else {
        println!("{USAGE}");
        return Ok(());
    };
    let threads = args.get_usize("threads", default_threads());
    let effort = if args.has_flag("full") {
        Effort::Full
    } else {
        Effort::Fast
    };
    let csv = args.has_flag("csv");
    let show = |t: &crate::util::table::Table| {
        if csv {
            print!("{}", t.to_csv());
        } else {
            print!("{}", t.to_text());
        }
    };

    let layer_shape = |args: &Args| {
        let batch = args.get_u64("batch", effort.batch_for_cli());
        match args.get_str("layer", "conv3") {
            "4c3r" => experiments::googlenet_4c3r(batch),
            _ => experiments::alexnet_conv3(batch),
        }
    };

    match cmd {
        "optimize" => {
            let name = args.get_str("net", "alexnet");
            let batch = args.get_u64("batch", 4);
            let Some(net) = network(name, batch) else {
                bail!("unknown network {name} (try: {:?})", crate::nn::network_names());
            };
            let rows = args.get_u64("rows", 16) as u32;
            let cols = args.get_u64("cols", 16) as u32;
            println!("optimizing {name} (batch {batch}) on {rows}x{cols} PEs...");
            let opts = effort_opts(effort);
            let df = Dataflow::parse("C|K").unwrap();
            let baseline =
                optimize_network(&net, &eyeriss_like(), &df, &Table3, &opts, threads);
            let results =
                search_hierarchy(&net, ArrayShape { rows, cols }, &Table3, &opts, threads);
            let Some(best) = results.first() else {
                bail!("no feasible hierarchy found");
            };
            println!(
                "baseline (Eyeriss-like): {} uJ{}",
                fmt_sig(baseline.total_energy_pj / 1e6),
                experiments::unmapped_note(baseline.unmapped)
            );
            println!(
                "optimized: {} uJ on {}  ({:.2}x better, {:.2} TOPS/W){}",
                fmt_sig(best.opt.total_energy_pj / 1e6),
                best.arch.describe(),
                baseline.total_energy_pj / best.opt.total_energy_pj,
                best.opt.tops_per_watt(),
                experiments::unmapped_note(best.opt.unmapped),
            );
            println!("\ntop-5 hierarchies:");
            for r in results.iter().take(5) {
                println!(
                    "  {:<24} {} uJ{}",
                    r.arch.name,
                    fmt_sig(r.opt.total_energy_pj / 1e6),
                    experiments::unmapped_note(r.opt.unmapped)
                );
            }
        }
        "co-opt" => {
            let name = args.get_str("net", "alexnet");
            let batch = args.get_u64("batch", 4);
            let Some(mut net) = network(name, batch) else {
                bail!("unknown network {name} (try: {:?})", crate::nn::network_names());
            };
            if args.get("head").is_some() {
                net = net.head(args.get_usize("head", net.layers.len()));
            }
            let (space, opts) = space_and_search_from_args(&args, effort)?;
            let mut cfg = NetOptConfig::new(opts, threads);
            cfg.clock_ghz = args.get_f64("clock-ghz", 1.0);
            if args.get("min-tops").is_some() {
                cfg.min_tops = Some(args.get_f64("min-tops", 0.0));
            }
            // scout priming is on by default: the winner is bit-identical,
            // only the b&b incumbent warms up faster
            cfg = cfg.with_prime(!args.has_flag("no-prime"));
            if let Some(spec) = args.get("shard") {
                let (index, nshards) = parse_shard_spec(spec)?;
                let Some(path) = args.get("checkpoint") else {
                    bail!("--shard needs --checkpoint PATH to write to");
                };
                let run = match args.get("bounds") {
                    Some(bounds) => {
                        let link = shard_bounds_link(&args, bounds);
                        run_coopt_shard_streamed(
                            &net, &space, &Table3, &cfg, index, nshards, &link,
                        )
                    }
                    None => co_optimize_shard(&net, &space, &Table3, &cfg, index, nshards),
                };
                std::fs::write(path, run.checkpoint.to_json())
                    .with_context(|| format!("writing checkpoint {path}"))?;
                if args.has_flag("json") {
                    println!("{}", run.checkpoint.to_json());
                } else {
                    match run.checkpoint.winner_result() {
                        Some(w) => println!(
                            "shard {index}/{nshards}: winner {} — {} uJ",
                            w.arch.describe(),
                            fmt_sig(w.opt.total_energy_pj / 1e6)
                        ),
                        None => println!("shard {index}/{nshards}: no feasible point"),
                    }
                    println!("{}", run.checkpoint.stats);
                    println!("wrote {path}");
                }
            } else {
                let res = co_optimize(&net, &space, &Table3, &cfg);
                if args.has_flag("json") {
                    println!("{}", co_opt_json(&net, &res, &cfg));
                } else {
                    print_co_opt(&net, &res, &cfg);
                }
            }
        }
        "co-opt-merge" => {
            let (paths, want_json) =
                merge_paths_from_args(&args, "co-opt-merge <ckpt.json>... [--out PATH] [--json]")?;
            let ckpts = read_checkpoints(&paths, ShardCheckpoint::from_json)?;
            let merged = merge_all(&ckpts)?;
            if let Some(out) = args.get("out") {
                std::fs::write(out, merged.to_json())
                    .with_context(|| format!("writing merged checkpoint {out}"))?;
            }
            if want_json {
                println!("{}", merged.to_json());
            } else {
                print_merge_banner(
                    paths.len(),
                    &merged.shards,
                    merged.nshards,
                    &merged.network,
                    merged.batch,
                    "winner",
                );
                match merged.winner_result() {
                    Some(w) => println!(
                        "winner: {} — {} uJ, {:.2} TOPS/W",
                        w.arch.describe(),
                        fmt_sig(w.opt.total_energy_pj / 1e6),
                        w.opt.tops_per_watt()
                    ),
                    None => println!("no feasible point in the covered shards"),
                }
                println!("{}", merged.stats);
            }
        }
        "pareto" => {
            let name = args.get_str("net", "alexnet");
            let batch = args.get_u64("batch", 4);
            let Some(mut net) = network(name, batch) else {
                bail!("unknown network {name} (try: {:?})", crate::nn::network_names());
            };
            if args.get("head").is_some() {
                net = net.head(args.get_usize("head", net.layers.len()));
            }
            let (space, opts) = space_and_search_from_args(&args, effort)?;
            let mut cfg = NetOptConfig::new(opts, threads);
            cfg.clock_ghz = args.get_f64("clock-ghz", 1.0);
            if args.get("min-tops").is_some() {
                cfg.min_tops = Some(args.get_f64("min-tops", 0.0));
            }
            cfg = cfg.with_prime(!args.has_flag("no-prime"));
            let pcfg = ParetoConfig {
                eps: args.get_f64("eps", 0.0),
                max_points: args.get("points").map(|_| args.get_usize("points", usize::MAX)),
            };
            if let Some(spec) = args.get("shard") {
                let (index, nshards) = parse_shard_spec(spec)?;
                let Some(path) = args.get("checkpoint") else {
                    bail!("--shard needs --checkpoint PATH to write to");
                };
                if args.get("eps").is_some()
                    || args.get("points").is_some()
                    || args.get("latency-budget").is_some()
                {
                    println!(
                        "note: --eps/--points/--latency-budget are reporting/selection \
                         knobs — shard checkpoints stay exact; apply them on the merged \
                         frontier (pareto without --shard, or pareto-merge + selection)"
                    );
                }
                let ckpt = match args.get("bounds") {
                    Some(bounds) => {
                        let link = shard_bounds_link(&args, bounds);
                        run_pareto_shard_streamed(
                            &net, &space, &Table3, &cfg, index, nshards, &link,
                        )
                    }
                    None => pareto_optimize_shard(&net, &space, &Table3, &cfg, index, nshards),
                };
                std::fs::write(path, ckpt.to_json())
                    .with_context(|| format!("writing checkpoint {path}"))?;
                if args.has_flag("json") {
                    println!("{}", ckpt.to_json());
                } else {
                    println!(
                        "shard {index}/{nshards}: {} frontier points",
                        ckpt.frontier.len()
                    );
                    println!("{}", ckpt.stats);
                    println!("wrote {path}");
                }
            } else {
                let res = pareto_optimize(&net, &space, &Table3, &cfg, &pcfg);
                // Budget selection rides inside the JSON document (so
                // `--json` stays machine-parseable) and prints as a
                // trailing line only in human mode.
                let selected: Option<(f64, Option<FrontierEntry>)> =
                    args.get("latency-budget").map(|_| {
                        let budget = args.get_f64("latency-budget", f64::INFINITY);
                        let sel = PlanSelector::new(res.frontier.clone());
                        (budget, sel.select(Some(budget)).cloned())
                    });
                if args.has_flag("json") {
                    println!("{}", pareto_json(&net, &res, &cfg, selected.as_ref()));
                } else {
                    print_pareto(&net, &res, &cfg);
                    if let Some((budget, pick)) = &selected {
                        match pick {
                            Some(e) => println!(
                                "selected under budget {budget} cycles: {} — {} uJ, {:.0} cycles",
                                e.result.arch.describe(),
                                fmt_sig(e.result.opt.total_energy_pj / 1e6),
                                e.result.opt.total_cycles
                            ),
                            None => println!("no frontier point within {budget} cycles"),
                        }
                    }
                }
            }
        }
        "orchestrate" => {
            let mode = match args.get_str("mode", "co-opt") {
                "co-opt" => SweepMode::CoOpt,
                "pareto" => SweepMode::Pareto,
                other => bail!("unknown --mode `{other}` (expected co-opt|pareto)"),
            };
            let workers = args.get_usize("workers", 4);
            let bin = match args.get("bin") {
                Some(b) => PathBuf::from(b),
                None => std::env::current_exe()
                    .context("resolve the interstellar binary for workers (or pass --bin)")?,
            };
            let dir = PathBuf::from(args.get_str("dir", "orchestrate-scratch"));
            let mut ocfg = OrchestrateConfig::new(mode, bin, dir, workers);
            ocfg.nshards = args.get_usize("nshards", workers.max(1));
            ocfg.worker_args = forward_worker_args(&args);
            ocfg.steal = !args.has_flag("no-steal");
            ocfg.steal_split = args.get_usize("steal-split", ocfg.steal_split);
            ocfg.straggler_factor = args.get_f64("straggler-factor", ocfg.straggler_factor);
            ocfg.bounds_interval = if args.has_flag("no-bounds") {
                None
            } else {
                Some(Duration::from_millis(args.get_u64("bounds-interval", 50)))
            };
            if let Some(hosts) = args.get("hosts") {
                ocfg.launchers = hosts
                    .split(';')
                    .map(|h| h.split_whitespace().map(str::to_string).collect::<Vec<_>>())
                    .filter(|v| !v.is_empty())
                    .collect();
            }
            println!(
                "orchestrating {} across {} workers ({} shards, steal {}, bounds {})...",
                mode_name(mode),
                ocfg.workers,
                ocfg.nshards,
                if ocfg.steal { "on" } else { "off" },
                match ocfg.bounds_interval {
                    Some(i) => format!("every {} ms", i.as_millis()),
                    None => "off".into(),
                }
            );
            let report = orchestrate(&ocfg)?;
            let merged_json = match &report.merged {
                MergedSweep::CoOpt(c) => c.to_json(),
                MergedSweep::Pareto(c) => c.to_json(),
            };
            if let Some(out) = args.get("out") {
                std::fs::write(out, &merged_json)
                    .with_context(|| format!("writing merged checkpoint {out}"))?;
            }
            if args.has_flag("json") {
                // Envelope: the merged checkpoint plus per-task
                // scheduling telemetry (shard class, 1-based attempt,
                // outcome, wall) — retries are distinguishable from
                // first launches without parsing worker filenames.
                let tasks: Vec<Json> = report
                    .tasks
                    .iter()
                    .map(|t| {
                        Json::Obj(vec![
                            ("seq".into(), Json::int(t.seq as u64)),
                            (
                                "shard".into(),
                                Json::str(format!("{}/{}", t.class.0, t.class.1)),
                            ),
                            ("attempt".into(), Json::int(t.attempt as u64)),
                            (
                                "outcome".into(),
                                Json::str(match t.outcome {
                                    TaskOutcome::Done => "done",
                                    TaskOutcome::Failed => "failed",
                                    TaskOutcome::Cancelled => "cancelled",
                                }),
                            ),
                            ("wall_ms".into(), Json::num(t.wall.as_secs_f64() * 1e3)),
                        ])
                    })
                    .collect();
                let envelope = Json::Obj(vec![
                    (
                        "merged".into(),
                        Json::parse(&merged_json).context("re-parse merged checkpoint")?,
                    ),
                    ("tasks".into(), Json::Arr(tasks)),
                    ("launched".into(), Json::int(report.launched as u64)),
                    ("failures".into(), Json::int(report.failures as u64)),
                    ("steals".into(), Json::int(report.steals as u64)),
                    ("cancelled".into(), Json::int(report.cancelled as u64)),
                ]);
                let mut out = String::new();
                envelope.write(&mut out);
                println!("{out}");
            } else {
                match &report.merged {
                    MergedSweep::CoOpt(c) => match c.winner_result() {
                        Some(w) => println!(
                            "winner: {} — {} uJ, {:.2} TOPS/W",
                            w.arch.describe(),
                            fmt_sig(w.opt.total_energy_pj / 1e6),
                            w.opt.tops_per_watt()
                        ),
                        None => println!("no feasible point in the design space"),
                    },
                    MergedSweep::Pareto(c) => {
                        println!("{} frontier points:", c.frontier.len());
                        for (_, r) in c.frontier.iter().take(10) {
                            println!(
                                "  {:<24} {} uJ  {:.0} cycles",
                                r.arch.name,
                                fmt_sig(r.opt.total_energy_pj / 1e6),
                                r.opt.total_cycles
                            );
                        }
                    }
                }
                println!(
                    "workers: {} launched, {} failed, {} steals, {} cancelled; \
                     {} full evaluations; wall {:.2}s",
                    report.launched,
                    report.failures,
                    report.steals,
                    report.cancelled,
                    report.aggregate_evaluated_full,
                    report.wall.as_secs_f64()
                );
            }
        }
        "pareto-merge" => {
            let (paths, want_json) =
                merge_paths_from_args(&args, "pareto-merge <ckpt.json>... [--out PATH] [--json]")?;
            let ckpts = read_checkpoints(&paths, FrontierCheckpoint::from_json)?;
            let merged = merge_all_frontiers(&ckpts)?;
            if let Some(out) = args.get("out") {
                std::fs::write(out, merged.to_json())
                    .with_context(|| format!("writing merged checkpoint {out}"))?;
            }
            if want_json {
                println!("{}", merged.to_json());
            } else {
                print_merge_banner(
                    paths.len(),
                    &merged.shards,
                    merged.nshards,
                    &merged.network,
                    merged.batch,
                    "frontier",
                );
                println!("{} frontier points:", merged.frontier.len());
                for (_, r) in merged.frontier.iter().take(10) {
                    println!(
                        "  {:<24} {} uJ  {:.0} cycles",
                        r.arch.name,
                        fmt_sig(r.opt.total_energy_pj / 1e6),
                        r.opt.total_cycles
                    );
                }
                println!("{}", merged.stats);
            }
        }
        "fastmap" => {
            let name = args.get_str("net", "alexnet");
            let batch = args.get_u64("batch", 4);
            let Some(net) = network(name, batch) else {
                bail!("unknown network {name} (try: {:?})", crate::nn::network_names());
            };
            let arch = eyeriss_like();
            let df = Dataflow::parse("C|K").unwrap();
            let opts = effort_opts(effort);
            println!(
                "heuristic mapper vs exact per-layer search on {} — {} (batch {batch}):",
                arch.describe(),
                net.name
            );
            let mut t = crate::util::table::Table::new(vec![
                "layer", "heur uJ", "exact uJ", "gap %", "heur us", "exact us", "speedup",
            ]);
            let mut cache = crate::engine::DivisorCache::new();
            let mut seen: std::collections::HashSet<crate::netopt::LayerKey> =
                Default::default();
            let (mut heur_ns, mut exact_ns) = (0u128, 0u128);
            for l in &net.layers {
                if !seen.insert((l.shape.bounds, l.shape.stride)) {
                    continue; // repeated shape: same mapping, nothing new to time
                }
                let t0 = std::time::Instant::now();
                let heur =
                    crate::fastmap::heuristic_layer(&l.shape, &arch, &df, &Table3, &mut cache);
                let dh = t0.elapsed().as_nanos();
                let t1 = std::time::Instant::now();
                let exact = optimize_layer(&l.shape, &arch, &df, &Table3, &opts, threads);
                let dx = t1.elapsed().as_nanos();
                heur_ns += dh;
                exact_ns += dx;
                match (heur, exact) {
                    (Some(h), Some(x)) => {
                        let gap = (h.result.energy_pj / x.result.energy_pj - 1.0) * 100.0;
                        t.row(vec![
                            l.name.clone(),
                            fmt_sig(h.result.energy_pj / 1e6),
                            fmt_sig(x.result.energy_pj / 1e6),
                            format!("{gap:+.2}"),
                            format!("{:.1}", dh as f64 / 1e3),
                            format!("{:.1}", dx as f64 / 1e3),
                            format!("{:.0}x", dx as f64 / dh.max(1) as f64),
                        ]);
                    }
                    (h, x) => {
                        // both None on truly unmappable layers (the
                        // heuristic is infeasible exactly when the exact
                        // search is); print whatever side exists
                        t.row(vec![
                            l.name.clone(),
                            h.map_or("-".into(), |h| fmt_sig(h.result.energy_pj / 1e6)),
                            x.map_or("-".into(), |x| fmt_sig(x.result.energy_pj / 1e6)),
                            "-".into(),
                            "-".into(),
                            "-".into(),
                            "-".into(),
                        ]);
                    }
                }
            }
            show(&t);
            println!(
                "aggregate over {} unique layers: heuristic {:.1} us, exact {:.1} us ({:.0}x)",
                seen.len(),
                heur_ns as f64 / 1e3,
                exact_ns as f64 / 1e3,
                exact_ns as f64 / (heur_ns.max(1)) as f64
            );
        }
        "sweep-dataflow" => show(&experiments::fig8_dataflow(layer_shape(&args), effort, threads)),
        "utilization" => show(&experiments::fig9_utilization(layer_shape(&args))),
        "sweep-blocking" => show(&experiments::fig10_blocking(layer_shape(&args), effort, threads)),
        "breakdown" => show(&experiments::fig11_breakdown(effort, threads)),
        "sweep-memory" => show(&experiments::fig12_memory(effort, threads)),
        "scaling" => show(&experiments::fig13_scaling(effort, threads)),
        "optimizer-gains" => show(&experiments::fig14_optimizer(effort, threads)),
        "validate" => show(&experiments::fig7_validation(threads)),
        "search-stats" => {
            println!("== per-layer staged-engine pruning (exhaustive vs b&b) ==");
            show(&experiments::search_pruning(effort, threads));
            println!("\n== network-level co-optimizer (arch points, b&b vs exhaustive) ==");
            show(&experiments::netopt_pruning(effort, threads));
        }
        "table3" => show(&experiments::table3()),
        "schedules" => print_schedules(),
        "run-e2e" => {
            let n = args.get_usize("requests", 200);
            let dir = PathBuf::from(args.get_str("artifacts", "artifacts"));
            let trace = serve::mixed_trace(n, 42);
            println!("serving {n} requests from {} on {threads} workers...", dir.display());
            let stats = serve::serve(&dir, trace, threads)?;
            print_serve_stats(&stats);
        }
        "serve" => {
            let n = args.get_usize("requests", 200);
            let dir = PathBuf::from(args.get_str("artifacts", "artifacts"));
            let batch = args.get_usize("batch-requests", 64);
            let trace = serve::mixed_trace(n, 42);
            let cfg = serve::ServeConfig::new(threads).with_batch(batch);
            let budget = args.get("latency-budget").map(|_| {
                args.get_f64("latency-budget", f64::INFINITY)
            });
            let mut remapper = if args.has_flag("remap") || budget.is_some() {
                let window = args.get_usize("window", 64);
                let drift = args.get_f64("drift", 0.25);
                let mut policy = RemapPolicy::new(window, drift);
                if args.has_flag("deadline") {
                    policy = policy.with_deadline();
                }
                if let Some(b) = budget {
                    policy = policy.with_latency_budget(b);
                    // a budget implies frontier re-selection from a live
                    // design space instead of the fixed candidate list
                    Some(Remapper::with_space(policy, Remapper::default_space()))
                } else {
                    Some(Remapper::new(policy, Remapper::default_candidates()))
                }
            } else {
                None
            };
            println!(
                "serving {n} requests on {threads} workers (batches of {batch}{})...",
                if remapper.is_some() { ", remap on" } else { "" }
            );
            let stats = if args.has_flag("synthetic") {
                serve::serve_with(
                    trace,
                    &cfg,
                    || Ok(serve::SyntheticExecutor),
                    remapper.as_mut(),
                )?
            } else {
                serve::serve_with(
                    trace,
                    &cfg,
                    || serve::PjrtExecutor::load(&dir),
                    remapper.as_mut(),
                )?
            };
            print_serve_stats(&stats);
            if let Some(r) = &remapper {
                match r.plan() {
                    Some(p) => {
                        println!(
                            "active plan (epoch {}): {} for mix {:?} ({} shapes seeded)",
                            p.epoch,
                            p.winner.arch.describe(),
                            p.mix,
                            r.seeds().len()
                        );
                        if let Some(sel) = r.selector() {
                            println!(
                                "selected from a {}-point frontier{}",
                                sel.len(),
                                match r.policy().latency_budget {
                                    Some(b) => format!(" under a {b} cycle budget"),
                                    None => String::new(),
                                }
                            );
                        }
                    }
                    None => println!("no feasible plan for the observed mix"),
                }
            }
        }
        "fleet" => {
            let workers = args.get_usize("workers", 4);
            let spec = match args.get("trace") {
                Some(t) => TraceSpec::decode(t)?,
                None => TraceSpec::mixed(args.get_usize("requests", 240), 42),
            };
            let dir = PathBuf::from(args.get_str("dir", "fleet-scratch"));
            let mut fcfg = FleetConfig::new(workers, spec, &dir);
            fcfg.threads = args.get_usize("worker-threads", 2);
            fcfg.batch = args.get_usize("batch-requests", 24);
            fcfg.window = args.get_usize("window", 0);
            fcfg.drift = args.get_f64("drift", 0.25);
            fcfg.deadline = args.has_flag("deadline");
            if args.get("latency-budget").is_some() {
                fcfg.latency_budget = Some(args.get_f64("latency-budget", f64::INFINITY));
                if fcfg.window == 0 {
                    fcfg.window = 64; // a budget needs a live mix window
                }
            }
            if let Some(p) = args.get("warm-start") {
                fcfg.warm_start = Some(PathBuf::from(p));
            }
            if !args.has_flag("in-process") {
                fcfg.bin = Some(match args.get("bin") {
                    Some(b) => PathBuf::from(b),
                    None => std::env::current_exe().context(
                        "resolve the interstellar binary for fleet workers \
                         (or pass --bin / --in-process)",
                    )?,
                });
            }
            if let Some(hosts) = args.get("hosts") {
                fcfg.launchers = hosts
                    .split(';')
                    .map(|h| h.split_whitespace().map(str::to_string).collect::<Vec<_>>())
                    .filter(|v| !v.is_empty())
                    .collect();
            }
            println!(
                "fleet: {workers} workers x {} threads over {} requests ({}, window {}{})...",
                fcfg.threads,
                fcfg.spec.n,
                if fcfg.bin.is_some() {
                    "OS processes"
                } else {
                    "in-process threads"
                },
                fcfg.window,
                match fcfg.latency_budget {
                    Some(b) => format!(", budget {b} cycles"),
                    None => String::new(),
                }
            );
            let stats = run_fleet(&fcfg)?;
            if args.has_flag("json") {
                println!("{}", stats.to_json());
            } else {
                println!(
                    "completed {}  wall {:.2}s  p50 {:.3} ms  p99 {:.3} ms  \
                     p99.9 {:.3} ms  mean {:.3} ms",
                    stats.completed,
                    stats.wall_s,
                    stats.p50_ms,
                    stats.p99_ms,
                    stats.p999_ms,
                    stats.mean_ms
                );
                println!(
                    "digest {:016x}  checksum {:.3}  remaps {} (fast {})  \
                     epoch {:?}  respawns {}  failovers {}  mix records {}",
                    stats.digest,
                    stats.checksum,
                    stats.remaps,
                    stats.fast_remaps,
                    stats.plan_epoch,
                    stats.respawns,
                    stats.failovers,
                    stats.mix_records
                );
            }
        }
        "fleet-worker" => {
            let Some(trace) = args.get("trace") else {
                bail!("fleet-worker needs --trace=SPEC (a TraceSpec encoding)");
            };
            let mut wcfg = WorkerConfig::new(
                args.get_usize("worker", 0),
                args.get_usize("fleet", 1),
                TraceSpec::decode(trace)?,
                PathBuf::from(args.get_str("dir", "fleet-scratch")),
            );
            wcfg.threads = args.get_usize("threads", 2);
            wcfg.batch = args.get_usize("batch-requests", 16);
            wcfg.slow_ns = args.get_u64("slow-ns", 0);
            wcfg.pace = args.has_flag("pace");
            if args.get("crash-after").is_some() {
                wcfg.crash_after_batches = Some(args.get_usize("crash-after", 1));
            }
            let report = run_worker(&wcfg)?;
            println!(
                "fleet worker {} done: {} requests, {} batches, digest {:016x}, epoch {:?}",
                report.worker,
                report.completed,
                report.batches,
                report.digest,
                report.plan_epoch
            );
        }
        "bench-report" => {
            let hpath = PathBuf::from(args.get_str("history", crate::bench::DEFAULT_HISTORY_PATH));
            let check = args.has_flag("check");
            if !hpath.is_file() {
                if check {
                    bail!(
                        "perf-trajectory history {} not found — run the perf benches \
                         (full ./ci.sh) first",
                        hpath.display()
                    );
                }
                println!(
                    "no perf-trajectory history at {} (the perf benches append it)",
                    hpath.display()
                );
                return Ok(());
            }
            let mut h = crate::bench::read_history(&hpath);
            if h.skipped > 0 {
                println!(
                    "note: skipped {} torn/foreign line(s) in {}",
                    h.skipped,
                    hpath.display()
                );
            }
            let last = args.get_usize("last", 0);
            if last > 0 && h.records.len() > last {
                h.records.drain(..h.records.len() - last);
            }
            let mut rows = crate::bench::trajectory(&h);
            if let Some(b) = args.get("bench") {
                rows.retain(|r| r.bench == b);
            }
            if let Some(m) = args.get("metric") {
                rows.retain(|r| r.metric.contains(m));
            }
            show(&crate::bench::trajectory_table(&rows));
            let regs = crate::bench::regressions(&rows);
            let gated = rows
                .iter()
                .filter(|r| {
                    matches!(
                        r.verdict,
                        crate::bench::Verdict::Ok | crate::bench::Verdict::Regressed { .. }
                    )
                })
                .count();
            println!(
                "{} series over {} records ({} gated, {} regression(s))",
                rows.len(),
                h.records.len(),
                gated,
                regs.len()
            );
            if check && !regs.is_empty() {
                let detail: Vec<String> = regs
                    .iter()
                    .map(|r| {
                        let (med, thr) = match r.verdict {
                            crate::bench::Verdict::Regressed {
                                baseline_median,
                                threshold,
                            } => (baseline_median, threshold),
                            _ => unreachable!("regressions() only returns Regressed rows"),
                        };
                        format!(
                            "  {} {}: latest {} (rev {}) vs baseline median {} \
                             (allowed deviation {})",
                            r.bench,
                            r.metric,
                            fmt_sig(r.latest),
                            r.latest_rev,
                            fmt_sig(med),
                            fmt_sig(thr)
                        )
                    })
                    .collect();
                bail!(
                    "perf regression(s) against the historical distribution:\n{}",
                    detail.join("\n")
                );
            }
        }
        "trace-report" => {
            let default_trace =
                std::env::var(telemetry::TRACE_ENV).unwrap_or_else(|_| "trace.jsonl".into());
            let path = PathBuf::from(args.get_str("trace", &default_trace));
            let (records, skipped) = telemetry::read_trace(&path)
                .with_context(|| format!("read trace {}", path.display()))?;
            if args.has_flag("check") {
                let summary = telemetry::report::check_trace(&records, skipped);
                let mut problems = summary.violations.clone();
                if summary.records == 0 {
                    problems.push("trace has no records".into());
                }
                for plane in args
                    .get_str("require-planes", "")
                    .split(',')
                    .map(str::trim)
                    .filter(|p| !p.is_empty())
                {
                    if !summary.planes.iter().any(|p| p == plane) {
                        problems.push(format!("required plane `{plane}` has no records"));
                    }
                }
                if !problems.is_empty() {
                    bail!(
                        "trace-report --check failed on {} ({} problem(s)):\n  {}",
                        path.display(),
                        problems.len(),
                        problems.join("\n  ")
                    );
                }
                println!(
                    "trace ok: {} records ({} skipped line(s)), {} worker(s), {} span(s), \
                     {} counter/gauge/event(s), planes [{}]",
                    summary.records,
                    summary.skipped,
                    summary.workers,
                    summary.spans,
                    summary.points,
                    summary.planes.join(", ")
                );
            } else {
                print!("{}", telemetry::report::render(&records, skipped));
            }
        }
        "report" if args.has_flag("all") => {
            let dir = PathBuf::from(args.get_str("out", "report-artifacts"));
            let hpath = PathBuf::from(args.get_str("history", crate::bench::DEFAULT_HISTORY_PATH));
            let eff = if args.has_flag("smoke") {
                Effort::Smoke
            } else {
                effort
            };
            let written = experiments::report_all(&dir, eff, threads, &hpath)?;
            for p in &written {
                println!("wrote {}", p.display());
            }
            println!(
                "report --all: {} artifacts regenerated under {}",
                written.len(),
                dir.display()
            );
        }
        "report" => {
            println!("== Table 3 ==");
            show(&experiments::table3());
            println!("\n== Fig 7 (validation) ==");
            show(&experiments::fig7_validation(threads));
            println!("\n== Fig 8 (dataflows, AlexNet CONV3) ==");
            show(&experiments::fig8_dataflow(
                experiments::alexnet_conv3(4),
                effort,
                threads,
            ));
            println!("\n== Fig 9 (utilization) ==");
            show(&experiments::fig9_utilization(experiments::alexnet_conv3(4)));
            println!("\n== Fig 10 (blocking) ==");
            show(&experiments::fig10_blocking(
                experiments::alexnet_conv3(4),
                effort,
                threads,
            ));
            println!("\n== Fig 11 (RF breakdown) ==");
            show(&experiments::fig11_breakdown(effort, threads));
            println!("\n== Fig 12 (memory sweep) ==");
            show(&experiments::fig12_memory(effort, threads));
            println!("\n== Fig 13 (scaling) ==");
            show(&experiments::fig13_scaling(effort, threads));
            println!("\n== Fig 14 (optimizer gains) ==");
            show(&experiments::fig14_optimizer(effort, threads));
            println!("\n== Pareto frontier (mlp-m, energy/throughput) ==");
            show(&experiments::pareto_curve(effort, threads));
            println!("\n== Serving-time remapping (drift trace) ==");
            show(&experiments::remap_drift(threads));
        }
        other => {
            println!("unknown command: {other}\n\n{USAGE}");
        }
    }
    Ok(())
}

/// One-line serving report shared by `run-e2e` and `serve`.
fn print_serve_stats(stats: &serve::ServeStats) {
    println!(
        "completed {}  wall {:.2}s  mean {:.2} ms  p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms  {:.1} req/s  checksum {:.3}  batches {}  remaps {} (fast {})",
        stats.completed,
        stats.wall_s,
        stats.mean_latency_ms,
        stats.p50_latency_ms,
        stats.p95_latency_ms,
        stats.p99_latency_ms,
        stats.rps,
        stats.checksum,
        stats.batches,
        stats.remaps,
        stats.fast_remaps
    );
}

/// Parse the design-space and per-layer search knobs shared by
/// `co-opt`, `co-opt --shard`, and `pareto` — one parser so the three
/// paths can never drift: `--rows/--cols` pick the PE array,
/// `--space paper|full` the generator axes, `--budget` the on-chip
/// capacity cap, `--rf1/--rf2-ratio/--gbuf` the size lists (comma-
/// separated bytes), `--ratio-min/--ratio-max` the Observation-2
/// widening, and `--cap/--divisors/--orders` the per-layer search caps.
fn space_and_search_from_args(
    args: &Args,
    effort: Effort,
) -> Result<(DesignSpace, SearchOpts)> {
    let rows = args.get_u64("rows", 16) as u32;
    let cols = args.get_u64("cols", 16) as u32;
    let array = ArrayShape { rows, cols };
    let mut space = match args.get_str("space", "paper") {
        "paper" => DesignSpace::paper_default(array),
        "full" => DesignSpace::full(array),
        other => bail!("unknown --space `{other}` (expected paper|full)"),
    };
    if args.get("budget").is_some() {
        space.max_onchip_bytes = Some(args.get_u64("budget", u64::MAX));
    }
    if let Some(list) = args.get("rf1") {
        space.rf1_sizes = parse_u64_list(list)?;
    }
    if let Some(list) = args.get("rf2-ratio") {
        space.rf2_ratios = parse_u64_list(list)?;
    }
    if let Some(list) = args.get("gbuf") {
        space.gbuf_sizes = parse_u64_list(list)?;
    }
    space.ratio_min = args.get_f64("ratio-min", space.ratio_min);
    space.ratio_max = args.get_f64("ratio-max", space.ratio_max);
    let mut opts = effort_opts(effort);
    opts.max_blockings = args.get_usize("cap", opts.max_blockings);
    opts.max_divisors = args.get_usize("divisors", opts.max_divisors);
    opts.max_order_combos = args.get_usize("orders", opts.max_order_combos);
    Ok((space, opts))
}

/// Shared front half of the merge subcommands (`co-opt-merge`,
/// `pareto-merge`): the positional checkpoint paths and whether JSON
/// output was requested. `--json` takes no value, but the greedy option
/// parser binds `--json a.json b.json` as json="a.json" (see
/// `util::args`) — the swallowed path is recovered instead of silently
/// dropped. Errors when no paths remain.
fn merge_paths_from_args(args: &Args, usage: &str) -> Result<(Vec<String>, bool)> {
    let mut paths: Vec<String> = args.positional[1..].to_vec();
    let mut want_json = args.has_flag("json");
    if let Some(stolen) = args.get("json") {
        want_json = true;
        paths.insert(0, stolen.to_string());
    }
    if paths.is_empty() {
        bail!("usage: {usage}");
    }
    Ok((paths, want_json))
}

/// Read and parse every checkpoint path with per-path error context —
/// shared by both merge subcommands over their respective `from_json`.
fn read_checkpoints<T>(paths: &[String], parse: impl Fn(&str) -> Result<T>) -> Result<Vec<T>> {
    let mut ckpts = Vec::with_capacity(paths.len());
    for p in paths {
        let text =
            std::fs::read_to_string(p).with_context(|| format!("reading checkpoint {p}"))?;
        ckpts.push(parse(&text).map_err(|e| e.context(format!("parsing checkpoint {p}")))?);
    }
    Ok(ckpts)
}

/// The merge coverage banner (+ provisional-result note when shards are
/// missing) shared by both merge subcommands; `what` names the result
/// kind ("winner" / "frontier").
fn print_merge_banner(
    n: usize,
    shards: &[usize],
    nshards: usize,
    network: &str,
    batch: u64,
    what: &str,
) {
    println!(
        "merged {n} checkpoints covering shards {shards:?} of {nshards} ({network} @ batch {batch})"
    );
    if shards.len() < nshards {
        println!(
            "note: {} of {} shards still missing — {what} is provisional",
            nshards - shards.len(),
            nshards
        );
    }
}

/// Comma-separated byte-size list for the design-space knobs
/// (`--rf1 16,64,512`).
fn parse_u64_list(list: &str) -> Result<Vec<u64>> {
    list.split(',')
        .map(|tok| {
            tok.trim()
                .parse::<u64>()
                .map_err(|e| anyhow::anyhow!("bad list entry `{tok}`: {e}"))
        })
        .collect()
}

fn mode_name(mode: SweepMode) -> &'static str {
    match mode {
        SweepMode::CoOpt => "co-opt",
        SweepMode::Pareto => "pareto",
    }
}

/// Build a shard worker's [`BoundsLink`] from the `--bounds`,
/// `--bounds-interval`, and `--worker-id` flags.
fn shard_bounds_link(args: &Args, bounds: &str) -> BoundsLink {
    BoundsLink::new(
        bounds,
        args.get_usize("worker-id", 0),
        Duration::from_millis(args.get_u64("bounds-interval", 50)),
    )
}

/// Reconstruct the worker-facing sweep arguments from an `orchestrate`
/// invocation: every knob the shared `co-opt`/`pareto` parser reads is
/// forwarded verbatim — in `--key=value` form, so the workers' greedy
/// option parser can never mis-bind them — because identical worker
/// configuration is the checkpoint-merge contract. Orchestrator-only
/// knobs (`--workers`, `--nshards`, steal/bounds scheduling, `--dir`,
/// `--hosts`) are deliberately not forwarded; `--worker-threads N`
/// forwards as the workers' `--threads N`.
fn forward_worker_args(args: &Args) -> Vec<String> {
    const FORWARD_OPTIONS: &[&str] = &[
        "net",
        "batch",
        "head",
        "rows",
        "cols",
        "space",
        "budget",
        "rf1",
        "rf2-ratio",
        "gbuf",
        "ratio-min",
        "ratio-max",
        "cap",
        "divisors",
        "orders",
        "min-tops",
        "clock-ghz",
    ];
    const FORWARD_FLAGS: &[&str] = &["full", "no-prime"];
    let mut out = Vec::new();
    for k in FORWARD_OPTIONS {
        if let Some(v) = args.get(k) {
            out.push(format!("--{k}={v}"));
        }
    }
    for f in FORWARD_FLAGS {
        if args.has_flag(f) {
            out.push(format!("--{f}"));
        }
    }
    if let Some(t) = args.get("worker-threads") {
        out.push(format!("--threads={t}"));
    }
    out
}

/// `I/N` shard spec for `co-opt --shard`.
fn parse_shard_spec(spec: &str) -> Result<(usize, usize)> {
    let Some((index, nshards)) = spec.split_once('/') else {
        bail!("--shard wants I/N (e.g. 0/4), got `{spec}`");
    };
    let index: usize = index
        .trim()
        .parse()
        .map_err(|e| anyhow::anyhow!("bad shard index `{index}`: {e}"))?;
    let nshards: usize = nshards
        .trim()
        .parse()
        .map_err(|e| anyhow::anyhow!("bad shard count `{nshards}`: {e}"))?;
    if nshards == 0 || index >= nshards {
        bail!("shard index {index} out of range 0..{nshards}");
    }
    Ok((index, nshards))
}

fn effort_opts(e: Effort) -> SearchOpts {
    match e {
        Effort::Smoke => SearchOpts::capped(150, 4),
        Effort::Fast => SearchOpts::capped(600, 5),
        Effort::Full => SearchOpts::capped(20_000, 8),
    }
}

impl Effort {
    fn batch_for_cli(self) -> u64 {
        match self {
            Effort::Smoke => 1,
            Effort::Fast => 4,
            Effort::Full => 16,
        }
    }
}

/// Human-readable `co-opt` report: winner, top-5, stats line.
fn print_co_opt(net: &Network, res: &CoOptResult, cfg: &NetOptConfig) {
    println!(
        "co-optimizing {} (batch {}, {} layers)...",
        net.name,
        net.batch,
        net.layers.len()
    );
    match res.best() {
        Some(best) => {
            println!(
                "best: {} — {} uJ, {:.2} TOPS/W, {:.3} TOPS @ {} GHz",
                best.arch.describe(),
                fmt_sig(best.opt.total_energy_pj / 1e6),
                best.opt.tops_per_watt(),
                best.opt.tops(cfg.clock_ghz),
                cfg.clock_ghz
            );
        }
        None => println!("no feasible architecture point (see stats below)"),
    }
    println!("\ntop-5 points:");
    for r in res.ranked.iter().take(5) {
        println!(
            "  {:<24} {} uJ{}",
            r.arch.name,
            fmt_sig(r.opt.total_energy_pj / 1e6),
            experiments::unmapped_note(r.opt.unmapped)
        );
    }
    if cfg.prune == PruneMode::BranchAndBound {
        println!("(b&b ranking: pruned points omitted; only the best point's");
        println!(" energy is exact — `optimize` prints a fully exact ranking)");
    }
    println!("\n{}", res.stats);
}

/// Minimal JSON escaping for the hand-rolled reports (arch and network
/// names are plain ASCII, but stay safe on quotes and backslashes).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Format a float as a JSON number — `null` for non-finite values
/// (e.g. the NaN TOPS of a point whose every layer is unmapped).
fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".into()
    }
}

/// Machine-readable `co-opt` report (the `--json` flag): every ranked
/// point plus the netopt counters.
fn co_opt_json(net: &Network, res: &CoOptResult, cfg: &NetOptConfig) -> String {
    let mut points = Vec::with_capacity(res.ranked.len());
    for r in &res.ranked {
        points.push(format!(
            "{{\"arch\":{},\"energy_pj\":{},\"cycles\":{},\"macs\":{},\
             \"tops_per_watt\":{},\"tops\":{},\"unmapped\":{}}}",
            json_str(&r.arch.name),
            json_num(r.opt.total_energy_pj),
            json_num(r.opt.total_cycles),
            r.opt.total_macs,
            json_num(r.opt.tops_per_watt()),
            json_num(r.opt.tops(cfg.clock_ghz)),
            r.opt.unmapped
        ));
    }
    let s = &res.stats;
    format!(
        "{{\"network\":{},\"batch\":{},\"layers\":{},\"clock_ghz\":{},\
         \"best\":{},\"points\":[{}],\
         \"stats\":{{\"generated\":{},\"budget_filtered\":{},\"ratio_filtered\":{},\
         \"candidates\":{},\"pruned\":{},\"evaluated_full\":{},\"infeasible\":{},\
         \"throughput_filtered\":{},\"layer_searches\":{},\"layer_reruns\":{},\
         \"engine\":{{\"stage2\":{},\"stage3\":{},\"pruned\":{},\"full\":{}}}}}}}",
        json_str(&net.name),
        net.batch,
        net.layers.len(),
        cfg.clock_ghz,
        res.best()
            .map(|b| json_str(&b.arch.name))
            .unwrap_or_else(|| "null".into()),
        points.join(","),
        s.generated,
        s.budget_filtered,
        s.ratio_filtered,
        s.candidates,
        s.pruned,
        s.evaluated_full,
        s.infeasible,
        s.throughput_filtered,
        s.layer_searches,
        s.layer_reruns,
        s.engine.stage2,
        s.engine.stage3,
        s.engine.pruned,
        s.engine.full
    )
}

/// Human-readable `pareto` report: the frontier table plus stats.
fn print_pareto(net: &Network, res: &ParetoResult, cfg: &NetOptConfig) {
    println!(
        "pareto frontier of {} (batch {}, {} layers), {} points:",
        net.name,
        net.batch,
        net.layers.len(),
        res.frontier.len()
    );
    println!(
        "  {:<24} {:>12} {:>14} {:>10} {:>8}",
        "arch", "energy (uJ)", "cycles", "TOPS", "TOPS/W"
    );
    for e in &res.frontier {
        let o = &e.result.opt;
        println!(
            "  {:<24} {:>12} {:>14.0} {:>10.3} {:>8.2}",
            e.result.arch.name,
            fmt_sig(o.total_energy_pj / 1e6),
            o.total_cycles,
            o.tops(cfg.clock_ghz),
            o.tops_per_watt()
        );
    }
    if res.frontier.is_empty() {
        println!("  (no feasible point — see stats below)");
    }
    println!("\n{}", res.stats);
}

/// Machine-readable `pareto` report (the `--json` flag): every frontier
/// point, the optional `--latency-budget` selection, and the netopt
/// counters — one pure JSON document on stdout.
fn pareto_json(
    net: &Network,
    res: &ParetoResult,
    cfg: &NetOptConfig,
    selected: Option<&(f64, Option<FrontierEntry>)>,
) -> String {
    let mut points = Vec::with_capacity(res.frontier.len());
    for e in &res.frontier {
        let o = &e.result.opt;
        points.push(format!(
            "{{\"index\":{},\"arch\":{},\"energy_pj\":{},\"cycles\":{},\
             \"tops\":{},\"tops_per_watt\":{}}}",
            e.index,
            json_str(&e.result.arch.name),
            json_num(o.total_energy_pj),
            json_num(o.total_cycles),
            json_num(o.tops(cfg.clock_ghz)),
            json_num(o.tops_per_watt())
        ));
    }
    let (budget_json, selected_json) = match selected {
        None => ("null".to_string(), "null".to_string()),
        Some((budget, pick)) => (
            json_num(*budget),
            match pick {
                None => "null".to_string(),
                Some(e) => format!(
                    "{{\"index\":{},\"arch\":{},\"energy_pj\":{},\"cycles\":{}}}",
                    e.index,
                    json_str(&e.result.arch.name),
                    json_num(e.result.opt.total_energy_pj),
                    json_num(e.result.opt.total_cycles)
                ),
            },
        ),
    };
    let s = &res.stats;
    format!(
        "{{\"network\":{},\"batch\":{},\"layers\":{},\"clock_ghz\":{},\
         \"frontier\":[{}],\
         \"latency_budget\":{},\"selected\":{},\
         \"stats\":{{\"generated\":{},\"budget_filtered\":{},\"ratio_filtered\":{},\
         \"candidates\":{},\"pruned\":{},\"evaluated_full\":{},\"infeasible\":{},\
         \"throughput_filtered\":{},\"layer_searches\":{},\"layer_reruns\":{}}}}}",
        json_str(&net.name),
        net.batch,
        net.layers.len(),
        cfg.clock_ghz,
        points.join(","),
        budget_json,
        selected_json,
        s.generated,
        s.budget_filtered,
        s.ratio_filtered,
        s.candidates,
        s.pruned,
        s.evaluated_full,
        s.infeasible,
        s.throughput_filtered,
        s.layer_searches,
        s.layer_reruns
    )
}

fn print_schedules() {
    use crate::halide::{diannao_tree, eyeriss_rs, nvdla_like, print_ir, shidiannao_os, tpu_ck};
    let conv3 = experiments::alexnet_conv3(4);
    for s in [
        eyeriss_rs(conv3, 16, 16),
        tpu_ck(conv3, 16, 16),
        shidiannao_os(conv3, 16, 16),
        diannao_tree(conv3, 16),
        nvdla_like(conv3, 16, 16),
    ] {
        println!("== {} ==", s.name);
        println!("{}", print_ir(&s));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArrayBus;

    fn parse(raw: &[&str]) -> Args {
        Args::parse(raw.iter().map(|s| s.to_string()))
    }

    #[test]
    fn space_and_search_defaults_are_the_paper_grid() {
        let (space, opts) = space_and_search_from_args(&parse(&[]), Effort::Fast).unwrap();
        let paper = DesignSpace::paper_default(ArrayShape { rows: 16, cols: 16 });
        assert_eq!(space.rf1_sizes, paper.rf1_sizes);
        assert_eq!(space.rf2_ratios, paper.rf2_ratios);
        assert_eq!(space.gbuf_sizes, paper.gbuf_sizes);
        assert_eq!(space.arrays, paper.arrays);
        assert_eq!(space.buses, paper.buses);
        assert_eq!(space.ratio_min, paper.ratio_min);
        assert_eq!(space.ratio_max, paper.ratio_max);
        assert_eq!(space.max_onchip_bytes, None);
        assert_eq!(opts.max_blockings, effort_opts(Effort::Fast).max_blockings);
    }

    #[test]
    fn space_and_search_parses_every_shared_knob() {
        let args = parse(&[
            "--rows=8",
            "--cols=8",
            "--space=full",
            "--budget=200000",
            "--rf1=16,64,512",
            "--rf2-ratio=8",
            "--gbuf=65536",
            "--ratio-min=0.25",
            "--ratio-max=64",
            "--cap=123",
            "--divisors=4",
            "--orders=9",
        ]);
        let (space, opts) = space_and_search_from_args(&args, Effort::Fast).unwrap();
        assert_eq!(space.rf1_sizes, vec![16, 64, 512]);
        assert_eq!(space.rf2_ratios, vec![8]);
        assert_eq!(space.gbuf_sizes, vec![65536]);
        assert_eq!(space.max_onchip_bytes, Some(200000));
        assert_eq!(space.ratio_min, 0.25);
        assert_eq!(space.ratio_max, 64.0);
        // --space full widens the array and bus axes, honoring --rows/cols
        assert!(space.arrays.contains(&ArrayShape { rows: 8, cols: 8 }));
        assert!(space.arrays.len() > 1);
        assert_eq!(space.buses, vec![ArrayBus::Systolic, ArrayBus::Broadcast]);
        assert_eq!(opts.max_blockings, 123);
        assert_eq!(opts.max_divisors, 4);
        assert_eq!(opts.max_order_combos, 9);
    }

    #[test]
    fn space_and_search_rejects_bad_input() {
        let bad_space = parse(&["--space=bogus"]);
        assert!(space_and_search_from_args(&bad_space, Effort::Fast).is_err());
        let bad_list = parse(&["--rf1=16,notanumber"]);
        assert!(space_and_search_from_args(&bad_list, Effort::Fast).is_err());
    }

    #[test]
    fn forward_worker_args_round_trips_the_shared_knobs() {
        let args = parse(&[
            "orchestrate",
            "--net=mlp-m",
            "--batch=16",
            "--space=full",
            "--rf1=16,64",
            "--budget=200000",
            "--clock-ghz=0.8",
            "--worker-threads=1",
            "--workers=4",
            "--nshards=8",
            "--bounds-interval=25",
            "--full",
            "--no-prime",
        ]);
        let fwd = forward_worker_args(&args);
        for want in [
            "--net=mlp-m",
            "--batch=16",
            "--space=full",
            "--rf1=16,64",
            "--budget=200000",
            "--clock-ghz=0.8",
            "--threads=1",
            "--full",
            "--no-prime",
        ] {
            assert!(fwd.contains(&want.to_string()), "missing {want} in {fwd:?}");
        }
        // orchestrator-only scheduling knobs must not leak into workers
        assert!(
            !fwd.iter().any(|a| a.contains("workers")
                || a.contains("nshards")
                || a.contains("bounds-interval")),
            "scheduling knob leaked: {fwd:?}"
        );
        // re-parsing the forwarded form reproduces the same space/opts
        let re = Args::parse(fwd.iter().cloned());
        let (s1, o1) = space_and_search_from_args(&args, Effort::Full).unwrap();
        let (s2, o2) = space_and_search_from_args(&re, Effort::Full).unwrap();
        assert_eq!(s1.rf1_sizes, s2.rf1_sizes);
        assert_eq!(s1.rf2_ratios, s2.rf2_ratios);
        assert_eq!(s1.gbuf_sizes, s2.gbuf_sizes);
        assert_eq!(s1.max_onchip_bytes, s2.max_onchip_bytes);
        assert_eq!(s1.arrays, s2.arrays);
        assert_eq!(s1.buses, s2.buses);
        assert_eq!(o1.max_blockings, o2.max_blockings);
        assert_eq!(o1.max_divisors, o2.max_divisors);
        assert_eq!(o1.max_order_combos, o2.max_order_combos);
    }

    #[test]
    fn shard_spec_parses_and_rejects() {
        assert_eq!(parse_shard_spec("0/4").unwrap(), (0, 4));
        assert_eq!(parse_shard_spec("3/4").unwrap(), (3, 4));
        assert!(parse_shard_spec("4/4").is_err());
        assert!(parse_shard_spec("x/4").is_err());
        assert!(parse_shard_spec("1").is_err());
        assert!(parse_shard_spec("1/0").is_err());
    }
}
