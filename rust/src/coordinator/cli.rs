//! The `interstellar` CLI: subcommands for optimization, sweeps,
//! validation, schedule display, and the end-to-end serving driver.

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use super::experiments::{self, Effort};
use super::remap::{RemapPolicy, Remapper};
use super::serve;
use crate::arch::{eyeriss_like, ArrayShape};
use crate::dataflow::Dataflow;
use crate::energy::Table3;
use crate::engine::PruneMode;
use crate::netopt::{
    co_optimize, co_optimize_shard, merge_all, CoOptResult, DesignSpace, NetOptConfig,
    ShardCheckpoint,
};
use crate::nn::{network, Network};
use crate::search::{default_threads, optimize_network, search_hierarchy, SearchOpts};
use crate::util::{fmt_sig, Args};

const USAGE: &str = "interstellar — Halide-schedule analysis of DNN accelerators (ASPLOS'20 reproduction)

USAGE: interstellar <command> [options]

COMMANDS:
  optimize        --net <name> [--batch N] [--rows 16 --cols 16] [--full]
                  run the auto-optimizer (fix C|K + ratio rule) on a network
  co-opt          --net <name> [--batch N] [--head N] [--rows 16 --cols 16]
                  [--full] [--budget BYTES] [--min-tops T] [--clock-ghz G]
                  [--rf1 L] [--rf2-ratio L] [--gbuf L] [--ratio-min R]
                  [--ratio-max R] [--cap N] [--divisors N] [--orders N]
                  [--shard I/N --checkpoint PATH] [--json]
                  network-level co-optimizer: cross-architecture b&b over
                  the design space, with capacity/throughput constraints;
                  L are comma-separated byte sizes. --shard runs one
                  worker slice and writes a mergeable JSON checkpoint
  co-opt-merge    <ckpt.json>... [--out PATH] [--json]
                  merge shard checkpoints (any order): winner is
                  bit-identical to the single-process co-opt run
  sweep-dataflow  [--layer conv3|4c3r] [--batch N] [--full]   (Fig 8)
  utilization     [--layer conv3|4c3r] [--batch N]            (Fig 9)
  sweep-blocking  [--layer conv3|4c3r] [--batch N] [--full]   (Fig 10)
  breakdown       [--full]                                    (Fig 11)
  sweep-memory    [--full]                                    (Fig 12)
  scaling         [--full]                                    (Fig 13)
  optimizer-gains [--full]                                    (Fig 14)
  validate        model-vs-simulator validation               (Fig 7 / Table 4)
  search-stats    staged-engine + network-level pruning counters
  table3          print the energy cost table                 (Table 3)
  schedules       print prior-work schedules lowered to IR    (Listing 2 / Fig 6)
  run-e2e         [--requests N] [--threads N] [--artifacts DIR]
                  serve a mixed trace through the PJRT artifacts
  serve           [--requests N] [--threads N] [--artifacts DIR]
                  [--batch-requests B] [--synthetic] [--remap]
                  [--window W] [--drift D]
                  batched serving loop; --remap re-optimizes mappings
                  online when the window mix drifts past D (plans swap
                  between batches); --synthetic runs the deterministic
                  stand-in executor (no artifacts needed)
  report          run every experiment at fast effort

Common options: --threads N (default: cores-1), --csv (CSV output), --full";

/// CLI entrypoint.
pub fn run(args: Args) -> Result<()> {
    let Some(cmd) = args.positional.first().map(|s| s.as_str()) else {
        println!("{USAGE}");
        return Ok(());
    };
    let threads = args.get_usize("threads", default_threads());
    let effort = if args.has_flag("full") {
        Effort::Full
    } else {
        Effort::Fast
    };
    let csv = args.has_flag("csv");
    let show = |t: &crate::util::table::Table| {
        if csv {
            print!("{}", t.to_csv());
        } else {
            print!("{}", t.to_text());
        }
    };

    let layer_shape = |args: &Args| {
        let batch = args.get_u64("batch", effort.batch_for_cli());
        match args.get_str("layer", "conv3") {
            "4c3r" => experiments::googlenet_4c3r(batch),
            _ => experiments::alexnet_conv3(batch),
        }
    };

    match cmd {
        "optimize" => {
            let name = args.get_str("net", "alexnet");
            let batch = args.get_u64("batch", 4);
            let Some(net) = network(name, batch) else {
                bail!("unknown network {name} (try: {:?})", crate::nn::network_names());
            };
            let rows = args.get_u64("rows", 16) as u32;
            let cols = args.get_u64("cols", 16) as u32;
            println!("optimizing {name} (batch {batch}) on {rows}x{cols} PEs...");
            let opts = effort_opts(effort);
            let df = Dataflow::parse("C|K").unwrap();
            let baseline =
                optimize_network(&net, &eyeriss_like(), &df, &Table3, &opts, threads);
            let results =
                search_hierarchy(&net, ArrayShape { rows, cols }, &Table3, &opts, threads);
            let Some(best) = results.first() else {
                bail!("no feasible hierarchy found");
            };
            println!(
                "baseline (Eyeriss-like): {} uJ{}",
                fmt_sig(baseline.total_energy_pj / 1e6),
                experiments::unmapped_note(baseline.unmapped)
            );
            println!(
                "optimized: {} uJ on {}  ({:.2}x better, {:.2} TOPS/W){}",
                fmt_sig(best.opt.total_energy_pj / 1e6),
                best.arch.describe(),
                baseline.total_energy_pj / best.opt.total_energy_pj,
                best.opt.tops_per_watt(),
                experiments::unmapped_note(best.opt.unmapped),
            );
            println!("\ntop-5 hierarchies:");
            for r in results.iter().take(5) {
                println!(
                    "  {:<24} {} uJ{}",
                    r.arch.name,
                    fmt_sig(r.opt.total_energy_pj / 1e6),
                    experiments::unmapped_note(r.opt.unmapped)
                );
            }
        }
        "co-opt" => {
            let name = args.get_str("net", "alexnet");
            let batch = args.get_u64("batch", 4);
            let Some(mut net) = network(name, batch) else {
                bail!("unknown network {name} (try: {:?})", crate::nn::network_names());
            };
            if args.get("head").is_some() {
                net = net.head(args.get_usize("head", net.layers.len()));
            }
            let rows = args.get_u64("rows", 16) as u32;
            let cols = args.get_u64("cols", 16) as u32;
            let mut space = DesignSpace::paper_default(ArrayShape { rows, cols });
            if args.get("budget").is_some() {
                space.max_onchip_bytes = Some(args.get_u64("budget", u64::MAX));
            }
            if let Some(list) = args.get("rf1") {
                space.rf1_sizes = parse_u64_list(list)?;
            }
            if let Some(list) = args.get("rf2-ratio") {
                space.rf2_ratios = parse_u64_list(list)?;
            }
            if let Some(list) = args.get("gbuf") {
                space.gbuf_sizes = parse_u64_list(list)?;
            }
            space.ratio_min = args.get_f64("ratio-min", space.ratio_min);
            space.ratio_max = args.get_f64("ratio-max", space.ratio_max);
            let mut opts = effort_opts(effort);
            opts.max_blockings = args.get_usize("cap", opts.max_blockings);
            opts.max_divisors = args.get_usize("divisors", opts.max_divisors);
            opts.max_order_combos = args.get_usize("orders", opts.max_order_combos);
            let mut cfg = NetOptConfig::new(opts, threads);
            cfg.clock_ghz = args.get_f64("clock-ghz", 1.0);
            if args.get("min-tops").is_some() {
                cfg.min_tops = Some(args.get_f64("min-tops", 0.0));
            }
            if let Some(spec) = args.get("shard") {
                let (index, nshards) = parse_shard_spec(spec)?;
                let Some(path) = args.get("checkpoint") else {
                    bail!("--shard needs --checkpoint PATH to write to");
                };
                let run = co_optimize_shard(&net, &space, &Table3, &cfg, index, nshards);
                std::fs::write(path, run.checkpoint.to_json())
                    .with_context(|| format!("writing checkpoint {path}"))?;
                if args.has_flag("json") {
                    println!("{}", run.checkpoint.to_json());
                } else {
                    match run.checkpoint.winner_result() {
                        Some(w) => println!(
                            "shard {index}/{nshards}: winner {} — {} uJ",
                            w.arch.describe(),
                            fmt_sig(w.opt.total_energy_pj / 1e6)
                        ),
                        None => println!("shard {index}/{nshards}: no feasible point"),
                    }
                    println!("{}", run.checkpoint.stats);
                    println!("wrote {path}");
                }
            } else {
                let res = co_optimize(&net, &space, &Table3, &cfg);
                if args.has_flag("json") {
                    println!("{}", co_opt_json(&net, &res, &cfg));
                } else {
                    print_co_opt(&net, &res, &cfg);
                }
            }
        }
        "co-opt-merge" => {
            let mut paths: Vec<String> = args.positional[1..].to_vec();
            let mut want_json = args.has_flag("json");
            // `--json` takes no value, but the greedy option parser binds
            // `--json a.json b.json` as json="a.json" (see util::args) —
            // recover the swallowed path instead of silently dropping it.
            if let Some(stolen) = args.get("json") {
                want_json = true;
                paths.insert(0, stolen.to_string());
            }
            if paths.is_empty() {
                bail!("usage: co-opt-merge <ckpt.json>... [--out PATH] [--json]");
            }
            let mut ckpts = Vec::with_capacity(paths.len());
            for p in &paths {
                let text = std::fs::read_to_string(p)
                    .with_context(|| format!("reading checkpoint {p}"))?;
                ckpts.push(
                    ShardCheckpoint::from_json(&text)
                        .map_err(|e| e.context(format!("parsing checkpoint {p}")))?,
                );
            }
            let merged = merge_all(&ckpts)?;
            if let Some(out) = args.get("out") {
                std::fs::write(out, merged.to_json())
                    .with_context(|| format!("writing merged checkpoint {out}"))?;
            }
            if want_json {
                println!("{}", merged.to_json());
            } else {
                println!(
                    "merged {} checkpoints covering shards {:?} of {} ({} @ batch {})",
                    paths.len(),
                    merged.shards,
                    merged.nshards,
                    merged.network,
                    merged.batch
                );
                if merged.shards.len() < merged.nshards {
                    println!(
                        "note: {} of {} shards still missing — winner is provisional",
                        merged.nshards - merged.shards.len(),
                        merged.nshards
                    );
                }
                match merged.winner_result() {
                    Some(w) => println!(
                        "winner: {} — {} uJ, {:.2} TOPS/W",
                        w.arch.describe(),
                        fmt_sig(w.opt.total_energy_pj / 1e6),
                        w.opt.tops_per_watt()
                    ),
                    None => println!("no feasible point in the covered shards"),
                }
                println!("{}", merged.stats);
            }
        }
        "sweep-dataflow" => show(&experiments::fig8_dataflow(layer_shape(&args), effort, threads)),
        "utilization" => show(&experiments::fig9_utilization(layer_shape(&args))),
        "sweep-blocking" => show(&experiments::fig10_blocking(layer_shape(&args), effort, threads)),
        "breakdown" => show(&experiments::fig11_breakdown(effort, threads)),
        "sweep-memory" => show(&experiments::fig12_memory(effort, threads)),
        "scaling" => show(&experiments::fig13_scaling(effort, threads)),
        "optimizer-gains" => show(&experiments::fig14_optimizer(effort, threads)),
        "validate" => show(&experiments::fig7_validation(threads)),
        "search-stats" => {
            println!("== per-layer staged-engine pruning (exhaustive vs b&b) ==");
            show(&experiments::search_pruning(effort, threads));
            println!("\n== network-level co-optimizer (arch points, b&b vs exhaustive) ==");
            show(&experiments::netopt_pruning(effort, threads));
        }
        "table3" => show(&experiments::table3()),
        "schedules" => print_schedules(),
        "run-e2e" => {
            let n = args.get_usize("requests", 200);
            let dir = PathBuf::from(args.get_str("artifacts", "artifacts"));
            let trace = serve::mixed_trace(n, 42);
            println!("serving {n} requests from {} on {threads} workers...", dir.display());
            let stats = serve::serve(&dir, trace, threads)?;
            print_serve_stats(&stats);
        }
        "serve" => {
            let n = args.get_usize("requests", 200);
            let dir = PathBuf::from(args.get_str("artifacts", "artifacts"));
            let batch = args.get_usize("batch-requests", 64);
            let trace = serve::mixed_trace(n, 42);
            let cfg = serve::ServeConfig::new(threads).with_batch(batch);
            let mut remapper = if args.has_flag("remap") {
                let window = args.get_usize("window", 64);
                let drift = args.get_f64("drift", 0.25);
                Some(Remapper::new(
                    RemapPolicy::new(window, drift),
                    Remapper::default_candidates(),
                ))
            } else {
                None
            };
            println!(
                "serving {n} requests on {threads} workers (batches of {batch}{})...",
                if remapper.is_some() { ", remap on" } else { "" }
            );
            let stats = if args.has_flag("synthetic") {
                serve::serve_with(
                    trace,
                    &cfg,
                    || Ok(serve::SyntheticExecutor),
                    remapper.as_mut(),
                )?
            } else {
                serve::serve_with(
                    trace,
                    &cfg,
                    || serve::PjrtExecutor::load(&dir),
                    remapper.as_mut(),
                )?
            };
            print_serve_stats(&stats);
            if let Some(r) = &remapper {
                match r.plan() {
                    Some(p) => println!(
                        "active plan (epoch {}): {} for mix {:?} ({} shapes seeded)",
                        p.epoch,
                        p.winner.arch.describe(),
                        p.mix,
                        r.seeds().len()
                    ),
                    None => println!("no feasible plan for the observed mix"),
                }
            }
        }
        "report" => {
            println!("== Table 3 ==");
            show(&experiments::table3());
            println!("\n== Fig 7 (validation) ==");
            show(&experiments::fig7_validation(threads));
            println!("\n== Fig 8 (dataflows, AlexNet CONV3) ==");
            show(&experiments::fig8_dataflow(
                experiments::alexnet_conv3(4),
                effort,
                threads,
            ));
            println!("\n== Fig 9 (utilization) ==");
            show(&experiments::fig9_utilization(experiments::alexnet_conv3(4)));
            println!("\n== Fig 10 (blocking) ==");
            show(&experiments::fig10_blocking(
                experiments::alexnet_conv3(4),
                effort,
                threads,
            ));
            println!("\n== Fig 11 (RF breakdown) ==");
            show(&experiments::fig11_breakdown(effort, threads));
            println!("\n== Fig 12 (memory sweep) ==");
            show(&experiments::fig12_memory(effort, threads));
            println!("\n== Fig 13 (scaling) ==");
            show(&experiments::fig13_scaling(effort, threads));
            println!("\n== Fig 14 (optimizer gains) ==");
            show(&experiments::fig14_optimizer(effort, threads));
            println!("\n== Serving-time remapping (drift trace) ==");
            show(&experiments::remap_drift(threads));
        }
        other => {
            println!("unknown command: {other}\n\n{USAGE}");
        }
    }
    Ok(())
}

/// One-line serving report shared by `run-e2e` and `serve`.
fn print_serve_stats(stats: &serve::ServeStats) {
    println!(
        "completed {}  wall {:.2}s  mean {:.2} ms  p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms  {:.1} req/s  checksum {:.3}  batches {}  remaps {}",
        stats.completed,
        stats.wall_s,
        stats.mean_latency_ms,
        stats.p50_latency_ms,
        stats.p95_latency_ms,
        stats.p99_latency_ms,
        stats.rps,
        stats.checksum,
        stats.batches,
        stats.remaps
    );
}

/// Comma-separated byte-size list for the design-space knobs
/// (`--rf1 16,64,512`).
fn parse_u64_list(list: &str) -> Result<Vec<u64>> {
    list.split(',')
        .map(|tok| {
            tok.trim()
                .parse::<u64>()
                .map_err(|e| anyhow::anyhow!("bad list entry `{tok}`: {e}"))
        })
        .collect()
}

/// `I/N` shard spec for `co-opt --shard`.
fn parse_shard_spec(spec: &str) -> Result<(usize, usize)> {
    let Some((index, nshards)) = spec.split_once('/') else {
        bail!("--shard wants I/N (e.g. 0/4), got `{spec}`");
    };
    let index: usize = index
        .trim()
        .parse()
        .map_err(|e| anyhow::anyhow!("bad shard index `{index}`: {e}"))?;
    let nshards: usize = nshards
        .trim()
        .parse()
        .map_err(|e| anyhow::anyhow!("bad shard count `{nshards}`: {e}"))?;
    if nshards == 0 || index >= nshards {
        bail!("shard index {index} out of range 0..{nshards}");
    }
    Ok((index, nshards))
}

fn effort_opts(e: Effort) -> SearchOpts {
    match e {
        Effort::Fast => SearchOpts::capped(600, 5),
        Effort::Full => SearchOpts::capped(20_000, 8),
    }
}

impl Effort {
    fn batch_for_cli(self) -> u64 {
        match self {
            Effort::Fast => 4,
            Effort::Full => 16,
        }
    }
}

/// Human-readable `co-opt` report: winner, top-5, stats line.
fn print_co_opt(net: &Network, res: &CoOptResult, cfg: &NetOptConfig) {
    println!(
        "co-optimizing {} (batch {}, {} layers)...",
        net.name,
        net.batch,
        net.layers.len()
    );
    match res.best() {
        Some(best) => {
            println!(
                "best: {} — {} uJ, {:.2} TOPS/W, {:.3} TOPS @ {} GHz",
                best.arch.describe(),
                fmt_sig(best.opt.total_energy_pj / 1e6),
                best.opt.tops_per_watt(),
                best.opt.tops(cfg.clock_ghz),
                cfg.clock_ghz
            );
        }
        None => println!("no feasible architecture point (see stats below)"),
    }
    println!("\ntop-5 points:");
    for r in res.ranked.iter().take(5) {
        println!(
            "  {:<24} {} uJ{}",
            r.arch.name,
            fmt_sig(r.opt.total_energy_pj / 1e6),
            experiments::unmapped_note(r.opt.unmapped)
        );
    }
    if cfg.prune == PruneMode::BranchAndBound {
        println!("(b&b ranking: pruned points omitted; only the best point's");
        println!(" energy is exact — `optimize` prints a fully exact ranking)");
    }
    println!("\n{}", res.stats);
}

/// Minimal JSON escaping for the hand-rolled reports (arch and network
/// names are plain ASCII, but stay safe on quotes and backslashes).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Format a float as a JSON number — `null` for non-finite values
/// (e.g. the NaN TOPS of a point whose every layer is unmapped).
fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".into()
    }
}

/// Machine-readable `co-opt` report (the `--json` flag): every ranked
/// point plus the netopt counters.
fn co_opt_json(net: &Network, res: &CoOptResult, cfg: &NetOptConfig) -> String {
    let mut points = Vec::with_capacity(res.ranked.len());
    for r in &res.ranked {
        points.push(format!(
            "{{\"arch\":{},\"energy_pj\":{},\"cycles\":{},\"macs\":{},\
             \"tops_per_watt\":{},\"tops\":{},\"unmapped\":{}}}",
            json_str(&r.arch.name),
            json_num(r.opt.total_energy_pj),
            json_num(r.opt.total_cycles),
            r.opt.total_macs,
            json_num(r.opt.tops_per_watt()),
            json_num(r.opt.tops(cfg.clock_ghz)),
            r.opt.unmapped
        ));
    }
    let s = &res.stats;
    format!(
        "{{\"network\":{},\"batch\":{},\"layers\":{},\"clock_ghz\":{},\
         \"best\":{},\"points\":[{}],\
         \"stats\":{{\"generated\":{},\"budget_filtered\":{},\"ratio_filtered\":{},\
         \"candidates\":{},\"pruned\":{},\"evaluated_full\":{},\"infeasible\":{},\
         \"throughput_filtered\":{},\"layer_searches\":{},\"layer_reruns\":{},\
         \"engine\":{{\"stage2\":{},\"stage3\":{},\"pruned\":{},\"full\":{}}}}}}}",
        json_str(&net.name),
        net.batch,
        net.layers.len(),
        cfg.clock_ghz,
        res.best()
            .map(|b| json_str(&b.arch.name))
            .unwrap_or_else(|| "null".into()),
        points.join(","),
        s.generated,
        s.budget_filtered,
        s.ratio_filtered,
        s.candidates,
        s.pruned,
        s.evaluated_full,
        s.infeasible,
        s.throughput_filtered,
        s.layer_searches,
        s.layer_reruns,
        s.engine.stage2,
        s.engine.stage3,
        s.engine.pruned,
        s.engine.full
    )
}

fn print_schedules() {
    use crate::halide::{diannao_tree, eyeriss_rs, nvdla_like, print_ir, shidiannao_os, tpu_ck};
    let conv3 = experiments::alexnet_conv3(4);
    for s in [
        eyeriss_rs(conv3, 16, 16),
        tpu_ck(conv3, 16, 16),
        shidiannao_os(conv3, 16, 16),
        diannao_tree(conv3, 16),
        nvdla_like(conv3, 16, 16),
    ] {
        println!("== {} ==", s.name);
        println!("{}", print_ir(&s));
    }
}
