//! The `interstellar` CLI: subcommands for optimization, sweeps,
//! validation, schedule display, and the end-to-end serving driver.

use std::path::PathBuf;

use anyhow::{bail, Result};

use super::experiments::{self, Effort};
use super::serve;
use crate::arch::{eyeriss_like, ArrayShape};
use crate::dataflow::Dataflow;
use crate::energy::Table3;
use crate::nn::network;
use crate::search::{default_threads, optimize_network, search_hierarchy, SearchOpts};
use crate::util::{fmt_sig, Args};

const USAGE: &str = "interstellar — Halide-schedule analysis of DNN accelerators (ASPLOS'20 reproduction)

USAGE: interstellar <command> [options]

COMMANDS:
  optimize        --net <name> [--batch N] [--rows 16 --cols 16] [--full]
                  run the auto-optimizer (fix C|K + ratio rule) on a network
  sweep-dataflow  [--layer conv3|4c3r] [--batch N] [--full]   (Fig 8)
  utilization     [--layer conv3|4c3r] [--batch N]            (Fig 9)
  sweep-blocking  [--layer conv3|4c3r] [--batch N] [--full]   (Fig 10)
  breakdown       [--full]                                    (Fig 11)
  sweep-memory    [--full]                                    (Fig 12)
  scaling         [--full]                                    (Fig 13)
  optimizer-gains [--full]                                    (Fig 14)
  validate        model-vs-simulator validation               (Fig 7 / Table 4)
  search-stats    staged-engine pruning: exhaustive vs b&b    (perf companion)
  table3          print the energy cost table                 (Table 3)
  schedules       print prior-work schedules lowered to IR    (Listing 2 / Fig 6)
  run-e2e         [--requests N] [--threads N] [--artifacts DIR]
                  serve a mixed trace through the PJRT artifacts
  report          run every experiment at fast effort

Common options: --threads N (default: cores-1), --csv (CSV output), --full";

/// CLI entrypoint.
pub fn run(args: Args) -> Result<()> {
    let Some(cmd) = args.positional.first().map(|s| s.as_str()) else {
        println!("{USAGE}");
        return Ok(());
    };
    let threads = args.get_usize("threads", default_threads());
    let effort = if args.has_flag("full") {
        Effort::Full
    } else {
        Effort::Fast
    };
    let csv = args.has_flag("csv");
    let show = |t: &crate::util::table::Table| {
        if csv {
            print!("{}", t.to_csv());
        } else {
            print!("{}", t.to_text());
        }
    };

    let layer_shape = |args: &Args| {
        let batch = args.get_u64("batch", effort.batch_for_cli());
        match args.get_str("layer", "conv3") {
            "4c3r" => experiments::googlenet_4c3r(batch),
            _ => experiments::alexnet_conv3(batch),
        }
    };

    match cmd {
        "optimize" => {
            let name = args.get_str("net", "alexnet");
            let batch = args.get_u64("batch", 4);
            let Some(net) = network(name, batch) else {
                bail!("unknown network {name} (try: {:?})", crate::nn::network_names());
            };
            let rows = args.get_u64("rows", 16) as u32;
            let cols = args.get_u64("cols", 16) as u32;
            println!("optimizing {name} (batch {batch}) on {rows}x{cols} PEs...");
            let opts = effort_opts(effort);
            let df = Dataflow::parse("C|K").unwrap();
            let baseline =
                optimize_network(&net, &eyeriss_like(), &df, &Table3, &opts, threads);
            let results =
                search_hierarchy(&net, ArrayShape { rows, cols }, &Table3, &opts, threads);
            let Some(best) = results.first() else {
                bail!("no feasible hierarchy found");
            };
            println!("baseline (Eyeriss-like): {} uJ", fmt_sig(baseline.total_energy_pj / 1e6));
            println!(
                "optimized: {} uJ on {}  ({:.2}x better, {:.2} TOPS/W)",
                fmt_sig(best.opt.total_energy_pj / 1e6),
                best.arch.describe(),
                baseline.total_energy_pj / best.opt.total_energy_pj,
                best.opt.tops_per_watt(),
            );
            println!("\ntop-5 hierarchies:");
            for r in results.iter().take(5) {
                println!(
                    "  {:<24} {} uJ",
                    r.arch.name,
                    fmt_sig(r.opt.total_energy_pj / 1e6)
                );
            }
        }
        "sweep-dataflow" => show(&experiments::fig8_dataflow(layer_shape(&args), effort, threads)),
        "utilization" => show(&experiments::fig9_utilization(layer_shape(&args))),
        "sweep-blocking" => show(&experiments::fig10_blocking(layer_shape(&args), effort, threads)),
        "breakdown" => show(&experiments::fig11_breakdown(effort, threads)),
        "sweep-memory" => show(&experiments::fig12_memory(effort, threads)),
        "scaling" => show(&experiments::fig13_scaling(effort, threads)),
        "optimizer-gains" => show(&experiments::fig14_optimizer(effort, threads)),
        "validate" => show(&experiments::fig7_validation(threads)),
        "search-stats" => show(&experiments::search_pruning(effort, threads)),
        "table3" => show(&experiments::table3()),
        "schedules" => print_schedules(),
        "run-e2e" => {
            let n = args.get_usize("requests", 200);
            let dir = PathBuf::from(args.get_str("artifacts", "artifacts"));
            let trace = serve::mixed_trace(n, 42);
            println!("serving {n} requests from {} on {threads} workers...", dir.display());
            let stats = serve::serve(&dir, trace, threads)?;
            println!(
                "completed {}  wall {:.2}s  mean {:.2} ms  p95 {:.2} ms  {:.1} req/s  checksum {:.3}",
                stats.completed,
                stats.wall_s,
                stats.mean_latency_ms,
                stats.p95_latency_ms,
                stats.rps,
                stats.checksum
            );
        }
        "report" => {
            println!("== Table 3 ==");
            show(&experiments::table3());
            println!("\n== Fig 7 (validation) ==");
            show(&experiments::fig7_validation(threads));
            println!("\n== Fig 8 (dataflows, AlexNet CONV3) ==");
            show(&experiments::fig8_dataflow(
                experiments::alexnet_conv3(4),
                effort,
                threads,
            ));
            println!("\n== Fig 9 (utilization) ==");
            show(&experiments::fig9_utilization(experiments::alexnet_conv3(4)));
            println!("\n== Fig 10 (blocking) ==");
            show(&experiments::fig10_blocking(
                experiments::alexnet_conv3(4),
                effort,
                threads,
            ));
            println!("\n== Fig 11 (RF breakdown) ==");
            show(&experiments::fig11_breakdown(effort, threads));
            println!("\n== Fig 12 (memory sweep) ==");
            show(&experiments::fig12_memory(effort, threads));
            println!("\n== Fig 13 (scaling) ==");
            show(&experiments::fig13_scaling(effort, threads));
            println!("\n== Fig 14 (optimizer gains) ==");
            show(&experiments::fig14_optimizer(effort, threads));
        }
        other => {
            println!("unknown command: {other}\n\n{USAGE}");
        }
    }
    Ok(())
}

fn effort_opts(e: Effort) -> SearchOpts {
    match e {
        Effort::Fast => SearchOpts::capped(600, 5),
        Effort::Full => SearchOpts::capped(20_000, 8),
    }
}

impl Effort {
    fn batch_for_cli(self) -> u64 {
        match self {
            Effort::Fast => 4,
            Effort::Full => 16,
        }
    }
}

fn print_schedules() {
    use crate::halide::{diannao_tree, eyeriss_rs, nvdla_like, print_ir, shidiannao_os, tpu_ck};
    let conv3 = experiments::alexnet_conv3(4);
    for s in [
        eyeriss_rs(conv3, 16, 16),
        tpu_ck(conv3, 16, 16),
        shidiannao_os(conv3, 16, 16),
        diannao_tree(conv3, 16),
        nvdla_like(conv3, 16, 16),
    ] {
        println!("== {} ==", s.name);
        println!("{}", print_ir(&s));
    }
}
