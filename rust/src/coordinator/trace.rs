//! Seeded, reusable request-trace specifications.
//!
//! `serve`, the fleet CLI and the fleet scenario harness all need the
//! *same* deterministic traffic: a [`TraceSpec`] is the one seeded
//! description — an arrival pattern × a mix schedule — that each of them
//! expands with [`TraceSpec::requests`]. The expansion is a pure
//! function of the spec (one [`XorShift`] stream, two draws per request:
//! a pool pick and a [`XorShift::split`] input seed), so every consumer
//! regenerates bit-identical requests from the spec alone — a fleet
//! worker needs no trace file, only the spec's compact string encoding
//! ([`TraceSpec::encode`] / [`TraceSpec::decode`]) forwarded on its
//! command line.
//!
//! The legacy generators [`mixed_trace`](super::serve::mixed_trace) and
//! [`drift_trace`](super::serve::drift_trace) are thin wrappers over
//! `TraceSpec` and are pinned bit-identical to their pre-extraction
//! output by `coordinator::tests` (the RNG call sequence per request is
//! part of the contract: exactly one `below` then one `split`).
//!
//! Arrival offsets ([`TraceSpec::arrival_ns`]) are deliberately RNG-free
//! — pacing must never perturb the request values — and only shape *when*
//! scenario load is offered, never *what* is served.

use anyhow::{anyhow, bail, Result};

use super::serve::Request;
use crate::util::XorShift;

/// The canonical serving artifact pool (every artifact
/// [`super::remap::artifact_network`] models and `python/compile/aot.py`
/// lowers).
pub const MIXED_KINDS: [&str; 5] = ["conv3x3", "conv1x1", "fc", "lstm_cell", "conv_chain"];

/// When requests are *offered* (nanosecond offsets from trace start).
/// Pure pacing metadata: expansion is RNG-free so arrival shaping can
/// never change the served values.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ArrivalPattern {
    /// One request every `gap_ns` (0 = as fast as possible).
    Steady {
        /// Gap between consecutive arrivals, nanoseconds.
        gap_ns: u64,
    },
    /// Requests arrive `burst` at a time, bursts spaced `gap_ns` apart —
    /// the bursty-load scenario shape.
    Bursty {
        /// Requests per burst (≥ 1).
        burst: usize,
        /// Gap between consecutive bursts, nanoseconds.
        gap_ns: u64,
    },
}

/// Which artifact pool each request index draws from.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MixSchedule {
    /// Every request drawn uniformly from one pool.
    Uniform(Vec<String>),
    /// Requests before `switch_at` draw from `before`, the rest from
    /// `after` — the adversarial mix-flip / drift shape.
    Flip {
        /// First request index served from `after`.
        switch_at: usize,
        /// Pool before the flip.
        before: Vec<String>,
        /// Pool after the flip.
        after: Vec<String>,
    },
}

impl MixSchedule {
    /// The pool request `i` draws from.
    fn pool_at(&self, i: usize) -> &[String] {
        match self {
            MixSchedule::Uniform(pool) => pool,
            MixSchedule::Flip {
                switch_at,
                before,
                after,
            } => {
                if i < *switch_at {
                    before
                } else {
                    after
                }
            }
        }
    }

    /// Every pool must be non-empty (a draw from an empty pool has no
    /// meaning; the legacy `drift_trace` asserted the same).
    fn validate(&self) -> Result<()> {
        let empty = match self {
            MixSchedule::Uniform(pool) => pool.is_empty(),
            MixSchedule::Flip { before, after, .. } => before.is_empty() || after.is_empty(),
        };
        if empty {
            bail!("trace mix schedule has an empty artifact pool");
        }
        Ok(())
    }
}

/// A seeded request-trace specification: `n` requests, an arrival
/// pattern, and a mix schedule. Expansion ([`requests`](Self::requests))
/// is a pure function of the spec — the determinism root every serving
/// test, the fleet, and the scenario harness share.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceSpec {
    /// Trace length.
    pub n: usize,
    /// RNG seed for pool picks and per-request input seeds.
    pub seed: u64,
    /// Offered-load pacing.
    pub arrival: ArrivalPattern,
    /// Artifact pool schedule.
    pub mix: MixSchedule,
}

impl TraceSpec {
    /// Uniform mix over `pool`, back-to-back arrivals.
    pub fn uniform(n: usize, seed: u64, pool: &[&str]) -> TraceSpec {
        TraceSpec {
            n,
            seed,
            arrival: ArrivalPattern::Steady { gap_ns: 0 },
            mix: MixSchedule::Uniform(pool.iter().map(|s| s.to_string()).collect()),
        }
    }

    /// The canonical mixed trace over [`MIXED_KINDS`]
    /// (what `mixed_trace(n, seed)` expands).
    pub fn mixed(n: usize, seed: u64) -> TraceSpec {
        TraceSpec::uniform(n, seed, &MIXED_KINDS)
    }

    /// A mix flip at `switch_at`, back-to-back arrivals
    /// (what `drift_trace` expands).
    pub fn flip(n: usize, seed: u64, switch_at: usize, before: &[&str], after: &[&str]) -> TraceSpec {
        TraceSpec {
            n,
            seed,
            arrival: ArrivalPattern::Steady { gap_ns: 0 },
            mix: MixSchedule::Flip {
                switch_at,
                before: before.iter().map(|s| s.to_string()).collect(),
                after: after.iter().map(|s| s.to_string()).collect(),
            },
        }
    }

    /// Same spec with a different arrival pattern.
    pub fn with_arrival(mut self, arrival: ArrivalPattern) -> TraceSpec {
        self.arrival = arrival;
        self
    }

    /// Expand the spec into its request trace. Exactly two RNG draws per
    /// request — a pool pick (`below`) then a split input seed — off one
    /// stream seeded with `self.seed`, so the expansion is bit-identical
    /// on every call, in every process, at any thread count (expansion
    /// itself is single-threaded by construction; `coordinator::tests`
    /// and `fleet::tests` pin both properties).
    pub fn requests(&self) -> Result<Vec<Request>> {
        self.mix.validate()?;
        let mut rng = XorShift::new(self.seed);
        Ok((0..self.n)
            .map(|i| {
                let pool = self.mix.pool_at(i);
                Request {
                    artifact: pool[rng.below(pool.len() as u64) as usize].clone(),
                    seed: rng.split().next_u64(),
                }
            })
            .collect())
    }

    /// Nanosecond arrival offset of every request — RNG-free pacing for
    /// the scenario harness's offered-load clock.
    pub fn arrival_ns(&self) -> Vec<u64> {
        (0..self.n)
            .map(|i| match self.arrival {
                ArrivalPattern::Steady { gap_ns } => i as u64 * gap_ns,
                ArrivalPattern::Bursty { burst, gap_ns } => (i / burst.max(1)) as u64 * gap_ns,
            })
            .collect()
    }

    /// Compact single-token encoding, safe to forward as one CLI value:
    /// `N:SEED:ARRIVAL:MIX` with `ARRIVAL` = `steady@GAP` |
    /// `bursty@BURSTxGAP` and `MIX` = `uniform@a,b,c` |
    /// `flip@AT@a,b>c,d`. [`decode`](Self::decode) inverts it exactly.
    pub fn encode(&self) -> String {
        let arrival = match &self.arrival {
            ArrivalPattern::Steady { gap_ns } => format!("steady@{gap_ns}"),
            ArrivalPattern::Bursty { burst, gap_ns } => format!("bursty@{burst}x{gap_ns}"),
        };
        let mix = match &self.mix {
            MixSchedule::Uniform(pool) => format!("uniform@{}", pool.join(",")),
            MixSchedule::Flip {
                switch_at,
                before,
                after,
            } => format!("flip@{switch_at}@{}>{}", before.join(","), after.join(",")),
        };
        format!("{}:{}:{arrival}:{mix}", self.n, self.seed)
    }

    /// Parse [`encode`](Self::encode)'s format.
    pub fn decode(text: &str) -> Result<TraceSpec> {
        let parts: Vec<&str> = text.splitn(4, ':').collect();
        let [n, seed, arrival, mix] = parts[..] else {
            bail!("trace spec `{text}` needs 4 `:`-separated fields (N:SEED:ARRIVAL:MIX)");
        };
        let n: usize = n.parse().map_err(|_| anyhow!("bad trace length `{n}`"))?;
        let seed: u64 = seed.parse().map_err(|_| anyhow!("bad trace seed `{seed}`"))?;
        let arrival = match arrival.split_once('@') {
            Some(("steady", gap)) => ArrivalPattern::Steady {
                gap_ns: gap.parse().map_err(|_| anyhow!("bad steady gap `{gap}`"))?,
            },
            Some(("bursty", spec)) => {
                let (burst, gap) = spec
                    .split_once('x')
                    .ok_or_else(|| anyhow!("bursty arrival needs BURSTxGAP, got `{spec}`"))?;
                ArrivalPattern::Bursty {
                    burst: burst.parse().map_err(|_| anyhow!("bad burst size `{burst}`"))?,
                    gap_ns: gap.parse().map_err(|_| anyhow!("bad burst gap `{gap}`"))?,
                }
            }
            _ => bail!("unknown arrival pattern `{arrival}`"),
        };
        let pool = |s: &str| -> Vec<String> {
            s.split(',').filter(|p| !p.is_empty()).map(|p| p.to_string()).collect()
        };
        let mix = match mix.split_once('@') {
            Some(("uniform", pools)) => MixSchedule::Uniform(pool(pools)),
            Some(("flip", spec)) => {
                let (at, pools) = spec
                    .split_once('@')
                    .ok_or_else(|| anyhow!("flip mix needs AT@BEFORE>AFTER, got `{spec}`"))?;
                let (before, after) = pools
                    .split_once('>')
                    .ok_or_else(|| anyhow!("flip mix needs BEFORE>AFTER pools, got `{pools}`"))?;
                MixSchedule::Flip {
                    switch_at: at.parse().map_err(|_| anyhow!("bad flip index `{at}`"))?,
                    before: pool(before),
                    after: pool(after),
                }
            }
            _ => bail!("unknown mix schedule `{mix}`"),
        };
        let spec = TraceSpec {
            n,
            seed,
            arrival,
            mix,
        };
        spec.mix.validate()?;
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_cases;

    #[test]
    fn expansion_is_bit_identical_across_calls_and_specs_round_trip() {
        for_cases(0x72_ace0, 24, |rng| {
            let n = 1 + (rng.below(64) as usize);
            let seed = rng.next_u64();
            let spec = if rng.below(2) == 0 {
                TraceSpec::mixed(n, seed)
            } else {
                TraceSpec::flip(n, seed, n / 2, &["conv3x3", "fc"], &["lstm_cell"])
            };
            let a = spec.requests().expect("expand a");
            let b = spec.requests().expect("expand b");
            assert_eq!(a.len(), n);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.artifact, y.artifact);
                assert_eq!(x.seed, y.seed);
            }
            let round = TraceSpec::decode(&spec.encode()).expect("decode own encoding");
            assert_eq!(round, spec);
            let c = round.requests().expect("expand decoded");
            for (x, y) in a.iter().zip(&c) {
                assert_eq!(x.artifact, y.artifact);
                assert_eq!(x.seed, y.seed);
            }
        });
    }

    #[test]
    fn encode_decode_covers_all_shapes() {
        let bursty = TraceSpec::mixed(40, 7).with_arrival(ArrivalPattern::Bursty {
            burst: 8,
            gap_ns: 1_000,
        });
        assert_eq!(TraceSpec::decode(&bursty.encode()).unwrap(), bursty);
        let flip = TraceSpec::flip(96, 11, 48, &["conv3x3", "fc"], &["lstm_cell"]);
        assert_eq!(TraceSpec::decode(&flip.encode()).unwrap(), flip);
        assert!(TraceSpec::decode("12:3:steady@0").is_err());
        assert!(TraceSpec::decode("12:3:steady@0:uniform@").is_err());
        assert!(TraceSpec::decode("12:3:warp@0:uniform@fc").is_err());
        assert!(TraceSpec::decode("12:3:steady@0:flip@4@fc>").is_err());
    }

    #[test]
    fn arrival_offsets_are_deterministic_and_shaped() {
        let steady = TraceSpec::mixed(5, 1).with_arrival(ArrivalPattern::Steady { gap_ns: 10 });
        assert_eq!(steady.arrival_ns(), vec![0, 10, 20, 30, 40]);
        let bursty = TraceSpec::mixed(6, 1).with_arrival(ArrivalPattern::Bursty {
            burst: 3,
            gap_ns: 100,
        });
        assert_eq!(bursty.arrival_ns(), vec![0, 0, 0, 100, 100, 100]);
    }

    #[test]
    fn empty_pool_is_rejected() {
        let spec = TraceSpec::uniform(4, 9, &[]);
        assert!(spec.requests().is_err());
    }
}
