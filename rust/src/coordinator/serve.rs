//! Batched request serving over the PJRT runtime — the request-path loop
//! of the e2e driver. Worker threads pull layer-inference requests from a
//! shared queue, batch-execute the AOT artifact, and report per-request
//! latency; Python is never involved.

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::Result;

use crate::runtime::Runtime;
use crate::util::XorShift;

/// One serving request: which artifact to run (inputs are generated
/// per-request from the seed).
#[derive(Debug, Clone)]
pub struct Request {
    /// Artifact name.
    pub artifact: String,
    /// Input seed.
    pub seed: u64,
}

/// Serving statistics.
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// Requests completed.
    pub completed: usize,
    /// Wall time of the whole run, seconds.
    pub wall_s: f64,
    /// Mean per-request latency, milliseconds.
    pub mean_latency_ms: f64,
    /// p95 per-request latency, milliseconds.
    pub p95_latency_ms: f64,
    /// Throughput, requests/second.
    pub rps: f64,
    /// Output checksum (sum of all output elements) for determinism
    /// checks.
    pub checksum: f64,
}

/// Run `requests` against the artifact registry in `artifacts_dir` using
/// `threads` workers. PJRT clients are not `Sync`, so each worker owns a
/// full runtime replica (the standard per-worker-model-replica serving
/// layout); request pulling is work-stealing over a shared counter.
pub fn serve(artifacts_dir: &Path, requests: Vec<Request>, threads: usize) -> Result<ServeStats> {
    let n = requests.len();
    let next = AtomicUsize::new(0);
    let latencies: Mutex<Vec<f64>> = Mutex::new(Vec::with_capacity(n));
    let checksum = Mutex::new(0.0f64);

    let t0 = Instant::now();
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        for _ in 0..threads.max(1) {
            let requests = &requests;
            let next = &next;
            let latencies = &latencies;
            let checksum = &checksum;
            handles.push(scope.spawn(move || -> Result<()> {
                let rt = Runtime::load(artifacts_dir)?; // per-worker replica
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        return Ok(());
                    }
                    let req = &requests[i];
                    let entry = rt
                        .entry(&req.artifact)
                        .ok_or_else(|| anyhow::anyhow!("unknown artifact {}", req.artifact))?
                        .clone();
                    let mut rng = XorShift::new(req.seed);
                    let inputs: Vec<Vec<f32>> = entry
                        .inputs
                        .iter()
                        .map(|spec| rng.f32_vec(spec.elems() as usize))
                        .collect();
                    let t = Instant::now();
                    let outs = rt.execute_f32(&req.artifact, &inputs)?;
                    let dt = t.elapsed().as_secs_f64() * 1e3;
                    let s: f64 = outs
                        .iter()
                        .map(|o| o.iter().map(|&v| v as f64).sum::<f64>())
                        .sum();
                    latencies.lock().unwrap().push(dt);
                    *checksum.lock().unwrap() += s;
                }
            }));
        }
        for h in handles {
            h.join().expect("worker panicked")?;
        }
        Ok(())
    })?;
    let wall = t0.elapsed().as_secs_f64();

    let lat = latencies.into_inner().unwrap();
    Ok(ServeStats {
        completed: lat.len(),
        wall_s: wall,
        mean_latency_ms: crate::util::stats::mean(&lat),
        p95_latency_ms: crate::util::stats::percentile(&lat, 95.0),
        rps: lat.len() as f64 / wall,
        checksum: checksum.into_inner().unwrap(),
    })
}

/// Build a mixed request trace over the available artifacts.
pub fn mixed_trace(n: usize, seed: u64) -> Vec<Request> {
    let kinds = ["conv3x3", "conv1x1", "fc", "lstm_cell", "conv_chain"];
    let mut rng = XorShift::new(seed);
    (0..n)
        .map(|i| Request {
            artifact: kinds[rng.below(kinds.len() as u64) as usize].to_string(),
            seed: seed ^ (i as u64).wrapping_mul(0x9E37),
        })
        .collect()
}
