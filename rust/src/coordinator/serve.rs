//! Batched request serving — the request-path loop of the e2e driver.
//! Worker threads serve interleaved slices of the request trace in
//! scheduling batches, execute each request on a per-worker executor
//! replica, and report per-request latency; Python is never involved.
//!
//! ## Determinism contract
//!
//! Results flow through the order-preserving
//! [`parallel_map`](crate::search::parallel_map) used by every other
//! sweep in the codebase — no shared `Mutex<Vec<_>>` accumulator, no
//! lock-order nondeterminism — and the per-worker result vectors are
//! re-interleaved into **trace order** before reduction. The checksum is
//! therefore a fixed-order f64 sum over the trace: byte-identical across
//! runs *and across worker counts* (f64 addition is not associative, so
//! summing in worker order — as the pre-remap implementation did — would
//! tie the bits to `threads`). The latency *count* is likewise exactly
//! the trace length. `coordinator::tests` locks both down at the
//! `serve()` level.
//!
//! Alongside the order-pinning checksum, [`ServeStats::digest`] is an
//! **order-free but order-binding** u64: the wrapping sum of
//! [`digest_term`]`(global_index, value)` over the trace. Each term mixes
//! the request's *global trace index* with its value bits (splitmix64
//! finalizer), so any reordering or cross-request value swap changes the
//! digest — but wrapping addition is associative and commutative, so
//! digests of **disjoint trace shards merge** with `wrapping_add` in any
//! order to exactly the single-process digest. That is the fleet
//! contract ([`crate::fleet`]): worker `w` of `N` serves the interleaved
//! shard `index % N == w` under
//! [`ServeConfig::with_index_map`]`(w, N)`, and the merged fleet digest
//! is bit-identical to one process serving the whole trace.
//!
//! ## Failover
//!
//! A worker whose executor-slot initialization (or a request execution)
//! fails no longer aborts the whole batch loop: its shard is retried
//! sequentially on a fresh replica from `make` (counted in
//! [`ServeStats::failovers`]); only a second consecutive failure — the
//! replacement replica also failing — is surfaced as an error, naming
//! the worker. The retry serves the identical shard in shard order, so
//! failover never perturbs the checksum or digest.
//!
//! ## Executors
//!
//! The executor is pluggable ([`Executor`]): [`PjrtExecutor`] runs the
//! AOT artifacts through the PJRT runtime (the production path; one
//! replica per worker, since PJRT clients are not `Sync`), and
//! [`SyntheticExecutor`] computes a deterministic, dependency-free
//! checksum from the request seed, so the serving loop itself — shard
//! layout, batch scheduling, plan swaps, stat reduction — is testable
//! without the `pjrt` feature or built artifacts.
//!
//! ## Serving-time remapping
//!
//! [`serve_with`] accepts a [`Remapper`](super::remap::Remapper): after
//! each scheduling batch the coordinator feeds the batch's artifacts
//! into the remapper's mix window, lets it re-optimize on drift, and
//! drains the plan-swap channel — the active [`MappingPlan`] is swapped
//! **between** batches (an `Arc` pointer move) and distributed to every
//! worker's executor through [`Executor::adopt_plan`] at the start of
//! the next batch, so worker replicas are never restarted and an
//! in-flight batch always completes under the plan it started with.
//! Remap decisions are pure functions of the trace, so enabling
//! remapping preserves the determinism contract.
//!
//! With a deadline policy ([`RemapPolicy::with_deadline`]
//! (super::remap::RemapPolicy::with_deadline)) a drift trigger first
//! publishes the heuristic fast-path plan (counted in
//! [`ServeStats::fast_remaps`]) and defers the exact search; the serve
//! loop services the deferred search on the next quiet batch via
//! [`Remapper::flush_pending`](super::remap::Remapper::flush_pending),
//! and flushes once more after the trace ends so every run converges to
//! the exact plan of its last triggering mix. Because the mix window is
//! stamped identically in both modes, the *final* adopted plan is
//! bit-identical with and without the deadline.

use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use super::remap::{MappingPlan, Remapper};
use crate::runtime::Runtime;
use crate::search::parallel_map;
use crate::telemetry;
use crate::telemetry::hist::LogHistogram;
use crate::util::json::Json;
use crate::util::XorShift;

/// One serving request: which artifact to run (inputs are generated
/// per-request from the seed).
#[derive(Debug, Clone)]
pub struct Request {
    /// Artifact name.
    pub artifact: String,
    /// Input seed.
    pub seed: u64,
}

/// Serving statistics.
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// Requests completed.
    pub completed: usize,
    /// Wall time of the whole run, seconds.
    pub wall_s: f64,
    /// Mean per-request latency, milliseconds.
    pub mean_latency_ms: f64,
    /// p50 (median) per-request latency, milliseconds.
    pub p50_latency_ms: f64,
    /// p95 per-request latency, milliseconds.
    pub p95_latency_ms: f64,
    /// p99 per-request latency, milliseconds.
    pub p99_latency_ms: f64,
    /// Throughput, requests/second.
    pub rps: f64,
    /// Output checksum (trace-ordered sum of all output elements) for
    /// determinism checks.
    pub checksum: f64,
    /// Order-binding, shard-mergeable output digest: wrapping sum of
    /// [`digest_term`]`(global_index, value)` over the served trace,
    /// where `global_index` comes from [`ServeConfig::with_index_map`].
    /// Digests of disjoint shards `wrapping_add` to the whole-trace
    /// digest (module docs, "Determinism contract").
    pub digest: u64,
    /// Log-bucketed latency histogram, milliseconds — the samples behind
    /// the percentile fields. Histograms merge exactly (integer bucket
    /// counts; [`LogHistogram::merge`]), so a fleet controller combines
    /// workers' histograms before taking fleet-level percentiles
    /// (percentiles do not compose; mergeable histograms do) in bounded
    /// memory, where the raw `Vec<f64>` this replaced grew with the
    /// trace length.
    pub latency_hist: LogHistogram,
    /// Worker shards retried on a fresh executor replica after a
    /// mid-batch executor failure (module docs, "Failover").
    pub failovers: usize,
    /// Scheduling batches served.
    pub batches: usize,
    /// Plan swaps received from the remapper (0 without `--remap`).
    pub remaps: usize,
    /// Of those swaps, how many were transient heuristic fast-path plans
    /// ([`MappingPlan::fast`]; 0 without a deadline policy).
    pub fast_remaps: usize,
    /// Epoch of the plan active when serving finished (`None` when no
    /// remapper was attached or no plan was ever produced).
    pub plan_epoch: Option<usize>,
}

/// A per-worker request executor. Implementations must be pure in the
/// checksum: the returned value may depend only on the request, never on
/// the worker, batch, or wall clock — the determinism contract sums it
/// in trace order.
pub trait Executor {
    /// Serve one request, returning its checksum contribution.
    fn execute(&mut self, req: &Request) -> Result<f64>;

    /// Batch-boundary plan distribution: called once per scheduling
    /// batch (before the worker's first request of that batch) with the
    /// active [`MappingPlan`], whenever one exists. Executors that
    /// reconfigure per plan (e.g. re-tuned kernels for the plan's
    /// mappings) hook here; the default ignores it. Must not affect the
    /// checksum — plans are mapping metadata, not inputs.
    fn adopt_plan(&mut self, _plan: &MappingPlan) {}
}

/// The production executor: one PJRT runtime replica per worker (the
/// standard per-worker-model-replica serving layout).
pub struct PjrtExecutor {
    rt: Runtime,
}

impl PjrtExecutor {
    /// Load the artifact registry in `dir`.
    pub fn load(dir: &Path) -> Result<PjrtExecutor> {
        Ok(PjrtExecutor {
            rt: Runtime::load(dir)?,
        })
    }
}

impl Executor for PjrtExecutor {
    fn execute(&mut self, req: &Request) -> Result<f64> {
        let entry = self
            .rt
            .entry(&req.artifact)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact {}", req.artifact))?
            .clone();
        let mut rng = XorShift::new(req.seed);
        let inputs: Vec<Vec<f32>> = entry
            .inputs
            .iter()
            .map(|spec| rng.f32_vec(spec.elems() as usize))
            .collect();
        let outs = self.rt.execute_f32(&req.artifact, &inputs)?;
        Ok(outs
            .iter()
            .map(|o| o.iter().map(|&v| v as f64).sum::<f64>())
            .sum())
    }
}

/// Deterministic stand-in executor: the checksum is a pure function of
/// `(artifact, seed)` (FNV-1a of the name mixed into an [`XorShift`]
/// stream), so serve-loop tests and benches run without the `pjrt`
/// feature or built artifacts. Latencies are still real wall times —
/// only their *count* is part of the determinism contract.
#[derive(Debug, Default)]
pub struct SyntheticExecutor;

impl Executor for SyntheticExecutor {
    fn execute(&mut self, req: &Request) -> Result<f64> {
        let mut h = 0xcbf29ce484222325u64;
        for b in req.artifact.bytes() {
            h = (h ^ b as u64).wrapping_mul(0x100000001b3);
        }
        let mut rng = XorShift::new(req.seed ^ h);
        Ok(rng.f32_vec(64).iter().map(|&v| v as f64).sum())
    }
}

/// Serving-loop configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Worker threads (each owns one executor replica).
    pub threads: usize,
    /// Requests per scheduling batch — the granularity at which the
    /// remapper observes traffic and plans may swap. `0` serves the
    /// whole trace as a single batch.
    pub batch: usize,
    /// Global trace index of this process's first request (digest index
    /// mapping; see [`ServeStats::digest`]). A standalone process serving
    /// the whole trace uses `0`.
    pub index_base: u64,
    /// Global-index step between consecutive local requests. A
    /// standalone process uses `1`; fleet worker `w` of `N` serves the
    /// interleaved shard with `(index_base, index_stride) = (w, N)`.
    pub index_stride: u64,
}

impl ServeConfig {
    /// Single-batch serving on `threads` workers (the pre-remap layout).
    pub fn new(threads: usize) -> ServeConfig {
        ServeConfig {
            threads,
            batch: 0,
            index_base: 0,
            index_stride: 1,
        }
    }

    /// Same configuration with a scheduling-batch size.
    pub fn with_batch(mut self, batch: usize) -> ServeConfig {
        self.batch = batch;
        self
    }

    /// Same configuration serving the interleaved global-trace shard
    /// whose requests sit at global indices `base + k·stride` — the
    /// digest index mapping for fleet worker `base` of `stride`.
    pub fn with_index_map(mut self, base: u64, stride: u64) -> ServeConfig {
        self.index_base = base;
        self.index_stride = stride.max(1);
        self
    }
}

/// Run `requests` against the artifact registry in `artifacts_dir` using
/// `threads` workers — the production entry point: PJRT executors, one
/// batch, no remapping.
pub fn serve(artifacts_dir: &Path, requests: Vec<Request>, threads: usize) -> Result<ServeStats> {
    serve_with(
        requests,
        &ServeConfig::new(threads),
        || PjrtExecutor::load(artifacts_dir),
        None,
    )
}

/// The full serving loop. The trace is cut into scheduling batches;
/// within each batch requests are dealt to workers round-robin (a mixed
/// trace keeps per-worker load balanced without work stealing), each
/// worker runs them on its own executor replica (created lazily on its
/// first non-empty shard and reused across batches — workers are never
/// restarted on a plan swap), and the per-worker `(latency_ms, checksum)`
/// vectors are re-interleaved into trace order before reduction. Between
/// batches the optional remapper observes the served artifacts, may
/// re-optimize, and the plan-swap channel is drained.
pub fn serve_with<E, F>(
    requests: Vec<Request>,
    cfg: &ServeConfig,
    make: F,
    remapper: Option<&mut Remapper>,
) -> Result<ServeStats>
where
    E: Executor + Send,
    F: Fn() -> Result<E> + Sync,
{
    match remapper {
        Some(r) => {
            let mut hook = RemapHook(r);
            serve_hooked(requests, cfg, make, Some(&mut hook))
        }
        None => serve_hooked(requests, cfg, make, None),
    }
}

/// Per-batch extension point of the serving loop — the reusable worker
/// loop contract. [`serve_with`]'s remapper integration is one
/// implementation ([`Remapper`] behind the scenes); a fleet worker
/// ([`crate::fleet`]) is another (stream the batch's mix to the fleet
/// controller, poll the plan broadcast). Hook calls happen strictly
/// **between** scheduling batches on the coordinator thread, so the
/// plan-swap safety argument (module docs) is unchanged for any hook.
pub trait BatchHook {
    /// Called after each scheduling batch with the requests just served.
    /// Returned plans are adopted in order — the last becomes active for
    /// the next batch ([`MappingPlan::fast`] plans count as fast
    /// remaps).
    fn after_batch(&mut self, served: &[Request]) -> Result<Vec<Arc<MappingPlan>>>;

    /// Called once after the last batch (end-of-trace flush). Returned
    /// plans are adopted the same way.
    fn finish(&mut self) -> Result<Vec<Arc<MappingPlan>>> {
        Ok(Vec::new())
    }
}

/// [`serve_with`]'s remapper as a [`BatchHook`]: observe the batch,
/// re-optimize on drift, drain the plan-swap channel; on finish, run any
/// owed deadline exact search ([`Remapper::flush_pending`]) so every run
/// converges to the exact plan of its last triggering mix.
struct RemapHook<'a>(&'a mut Remapper);

impl RemapHook<'_> {
    fn drain(&mut self) -> Vec<Arc<MappingPlan>> {
        let mut plans = Vec::new();
        while let Some(p) = self.0.take_plan() {
            plans.push(p);
        }
        plans
    }
}

impl BatchHook for RemapHook<'_> {
    fn after_batch(&mut self, served: &[Request]) -> Result<Vec<Arc<MappingPlan>>> {
        for req in served {
            self.0.observe(&req.artifact);
        }
        self.0.maybe_remap();
        Ok(self.drain())
    }

    fn finish(&mut self) -> Result<Vec<Arc<MappingPlan>>> {
        // End-of-trace convergence: a deadline remapper may still owe
        // the exact search for its last fast plan — run it now and adopt
        // the result (the deadline determinism contract).
        self.0.flush_pending();
        Ok(self.drain())
    }
}

/// The serving loop under an arbitrary [`BatchHook`] — what
/// [`serve_with`] wraps and what a fleet worker drives directly.
pub fn serve_hooked<E, F>(
    requests: Vec<Request>,
    cfg: &ServeConfig,
    make: F,
    mut hook: Option<&mut dyn BatchHook>,
) -> Result<ServeStats>
where
    E: Executor + Send,
    F: Fn() -> Result<E> + Sync,
{
    let n = requests.len();
    let threads = cfg.threads.max(1).min(n.max(1));
    let batch = if cfg.batch == 0 { n.max(1) } else { cfg.batch };

    // Per-worker executor slots: created on first use inside the worker
    // (so replica setup runs in parallel), reused across every batch.
    let slots: Vec<Mutex<Option<E>>> = (0..threads).map(|_| Mutex::new(None)).collect();

    let t0 = Instant::now();
    let mut hist = LogHistogram::new();
    let mut completed = 0usize;
    let mut checksum = 0.0f64;
    let mut digest = 0u64;
    let mut batches = 0usize;
    let mut remaps = 0usize;
    let mut fast_remaps = 0usize;
    let mut failovers = 0usize;
    let mut active: Option<Arc<MappingPlan>> = None;

    let mut start = 0usize;
    while start < n {
        let end = (start + batch).min(n);
        let bspan = telemetry::span_with("fleet", "batch", || {
            vec![
                ("batch".into(), Json::int(batches as u64)),
                ("requests".into(), Json::int((end - start) as u64)),
            ]
        });
        // Index shards — requests are served in place, never cloned.
        let shards: Vec<(usize, Vec<usize>)> = (0..threads)
            .map(|w| (w, (start + w..end).step_by(threads).collect()))
            .collect();
        // The plan every worker of THIS batch runs under: swapped only
        // at this boundary, so an in-flight batch never sees a newer one.
        let batch_plan = active.clone();
        let per_worker: Vec<Result<Vec<(f64, f64)>>> =
            parallel_map(shards, threads, |(w, shard)| {
                if shard.is_empty() {
                    return Ok(Vec::new());
                }
                let mut slot = slots[*w].lock().expect("worker executor slot");
                if slot.is_none() {
                    *slot = Some(make()?); // lazy per-worker replica
                }
                let ex = slot.as_mut().expect("slot just filled");
                if let Some(p) = &batch_plan {
                    ex.adopt_plan(p); // batch-boundary plan distribution
                }
                let mut out = Vec::with_capacity(shard.len());
                for &i in shard {
                    let t = Instant::now();
                    let s = ex.execute(&requests[i])?;
                    out.push((t.elapsed().as_secs_f64() * 1e3, s));
                }
                Ok(out)
            });

        // Re-interleave into trace order: worker w's k-th result is
        // batch index w + k·threads. This makes the checksum reduction
        // independent of the worker count.
        let mut batch_vals: Vec<(f64, f64)> = vec![(0.0, 0.0); end - start];
        for (w, worker) in per_worker.into_iter().enumerate() {
            let vals = match worker {
                Ok(vals) => vals,
                // Failover: retry this worker's shard sequentially on a
                // fresh replica instead of aborting the whole loop. The
                // shard and its order are identical, so the checksum and
                // digest are unaffected.
                Err(first) => {
                    failovers += 1;
                    telemetry::event("fleet", "failover", || {
                        vec![
                            ("worker".into(), Json::int(w as u64)),
                            ("batch".into(), Json::int(batches as u64)),
                        ]
                    });
                    let mut slot = slots[w].lock().expect("worker executor slot");
                    *slot = None; // discard the suspect replica, if any
                    *slot = Some(make().map_err(|e| {
                        anyhow::anyhow!(
                            "serve worker {w}: executor failed twice \
                             (initial: {first}; failover replica: {e})"
                        )
                    })?);
                    let ex = slot.as_mut().expect("slot just filled");
                    if let Some(p) = &batch_plan {
                        ex.adopt_plan(p);
                    }
                    let shard: Vec<usize> = (start + w..end).step_by(threads).collect();
                    let mut out = Vec::with_capacity(shard.len());
                    for &i in &shard {
                        let t = Instant::now();
                        let s = ex.execute(&requests[i]).map_err(|e| {
                            anyhow::anyhow!(
                                "serve worker {w}: failover retry failed on \
                                 request {i} ({}): {e}",
                                requests[i].artifact
                            )
                        })?;
                        out.push((t.elapsed().as_secs_f64() * 1e3, s));
                    }
                    out
                }
            };
            for (k, v) in vals.into_iter().enumerate() {
                batch_vals[w + k * threads] = v;
            }
        }
        for (j, (dt, s)) in batch_vals.into_iter().enumerate() {
            let global = cfg
                .index_base
                .wrapping_add(((start + j) as u64).wrapping_mul(cfg.index_stride.max(1)));
            digest = digest.wrapping_add(digest_term(global, s));
            hist.record(dt);
            completed += 1;
            checksum += s;
        }
        batches += 1;

        if let Some(h) = &mut hook {
            for p in h.after_batch(&requests[start..end])? {
                if p.fast {
                    fast_remaps += 1;
                }
                active = Some(p); // hot swap between batches
                remaps += 1;
            }
        }
        drop(bspan);
        start = end;
    }
    if let Some(h) = &mut hook {
        for p in h.finish()? {
            if p.fast {
                fast_remaps += 1;
            }
            active = Some(p);
            remaps += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    telemetry::event("fleet", "latency_hist", || {
        vec![
            ("hist".into(), hist.to_json()),
            ("count".into(), Json::int(hist.count())),
        ]
    });

    Ok(ServeStats {
        completed,
        wall_s: wall,
        mean_latency_ms: hist.mean(),
        p50_latency_ms: hist.quantile(50.0),
        p95_latency_ms: hist.quantile(95.0),
        p99_latency_ms: hist.quantile(99.0),
        rps: completed as f64 / wall,
        checksum,
        digest,
        failovers,
        batches,
        remaps,
        fast_remaps,
        plan_epoch: active.map(|p| p.epoch),
        latency_hist: hist,
    })
}

/// One request's contribution to [`ServeStats::digest`]: the splitmix64
/// finalizer over the value's bits xored with the golden-ratio-spread
/// global trace index. Binding the index into every term makes any
/// reorder or cross-request swap change the digest, while the wrapping
/// *sum* of terms stays associative and commutative — disjoint trace
/// shards merge with `wrapping_add` in any order to the whole-trace
/// digest (the fleet merge contract, [`crate::fleet`]).
pub fn digest_term(global_index: u64, value: f64) -> u64 {
    let mut z = value.to_bits() ^ global_index.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z ^= z >> 30;
    z = z.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z ^= z >> 27;
    z = z.wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Build a mixed request trace over the available artifacts. Per-request
/// input seeds are derived by [`XorShift::split`] stream splitting —
/// xorshift64* outputs are a bijection of the (never-repeating) state
/// sequence, so every request seed in a trace is distinct. The previous
/// `seed ^ (i · 0x9E37)` mixing produced near-identical generator states
/// for adjacent `i` at small seeds and aliased across related trace
/// seeds; `coordinator::tests` keeps a collision regression.
///
/// A thin wrapper over [`TraceSpec::mixed`](super::trace::TraceSpec) —
/// the seeded spec the fleet and the scenario harness share — pinned
/// bit-identical to the pre-extraction generator by
/// `coordinator::tests`.
pub fn mixed_trace(n: usize, seed: u64) -> Vec<Request> {
    super::trace::TraceSpec::mixed(n, seed)
        .requests()
        .expect("the canonical mixed pool is non-empty")
}

/// Synthetic drift trace: requests before `switch_at` are drawn
/// uniformly from `before`, the rest from `after` — the workload-shift
/// fixture the remap tests and the `perf_remap` bench drive. A wrapper
/// over [`TraceSpec::flip`](super::trace::TraceSpec).
pub fn drift_trace(
    n: usize,
    switch_at: usize,
    before: &[&str],
    after: &[&str],
    seed: u64,
) -> Vec<Request> {
    assert!(!before.is_empty() && !after.is_empty());
    super::trace::TraceSpec::flip(n, seed, switch_at, before, after)
        .requests()
        .expect("pools asserted non-empty above")
}
