//! Batched request serving over the PJRT runtime — the request-path loop
//! of the e2e driver. Worker threads serve interleaved slices of the
//! request trace, batch-execute the AOT artifact, and report per-request
//! latency; Python is never involved.
//!
//! Results flow through the order-preserving
//! [`parallel_map`](crate::search::parallel_map) used by every other
//! sweep in the codebase — no shared `Mutex<Vec<_>>` accumulator, no
//! lock-order nondeterminism: the latency vector and the checksum are
//! reduced from the returned per-worker vectors in deterministic trace
//! order, so two runs with the same trace and worker count produce
//! byte-identical stats.

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::runtime::Runtime;
use crate::search::parallel_map;
use crate::util::{stats, XorShift};

/// One serving request: which artifact to run (inputs are generated
/// per-request from the seed).
#[derive(Debug, Clone)]
pub struct Request {
    /// Artifact name.
    pub artifact: String,
    /// Input seed.
    pub seed: u64,
}

/// Serving statistics.
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// Requests completed.
    pub completed: usize,
    /// Wall time of the whole run, seconds.
    pub wall_s: f64,
    /// Mean per-request latency, milliseconds.
    pub mean_latency_ms: f64,
    /// p50 (median) per-request latency, milliseconds.
    pub p50_latency_ms: f64,
    /// p95 per-request latency, milliseconds.
    pub p95_latency_ms: f64,
    /// p99 per-request latency, milliseconds.
    pub p99_latency_ms: f64,
    /// Throughput, requests/second.
    pub rps: f64,
    /// Output checksum (sum of all output elements) for determinism
    /// checks.
    pub checksum: f64,
}

/// Run `requests` against the artifact registry in `artifacts_dir` using
/// `threads` workers. PJRT clients are not `Sync`, so each worker owns a
/// full runtime replica (the standard per-worker-model-replica serving
/// layout). The trace is dealt to workers round-robin — a mixed trace
/// keeps per-worker load balanced without work stealing — and each
/// worker returns its `(latency_ms, checksum)` vector through
/// [`parallel_map`], which preserves worker order.
pub fn serve(artifacts_dir: &Path, requests: Vec<Request>, threads: usize) -> Result<ServeStats> {
    let n = requests.len();
    let threads = threads.max(1).min(n.max(1));
    let mut shards: Vec<Vec<Request>> = (0..threads)
        .map(|_| Vec::with_capacity(n / threads + 1))
        .collect();
    for (i, req) in requests.into_iter().enumerate() {
        shards[i % threads].push(req);
    }

    let t0 = Instant::now();
    let per_worker: Vec<Result<Vec<(f64, f64)>>> = parallel_map(shards, threads, |shard| {
        if shard.is_empty() {
            return Ok(Vec::new());
        }
        let rt = Runtime::load(artifacts_dir)?; // per-worker replica
        let mut out = Vec::with_capacity(shard.len());
        for req in shard {
            let entry = rt
                .entry(&req.artifact)
                .ok_or_else(|| anyhow::anyhow!("unknown artifact {}", req.artifact))?
                .clone();
            let mut rng = XorShift::new(req.seed);
            let inputs: Vec<Vec<f32>> = entry
                .inputs
                .iter()
                .map(|spec| rng.f32_vec(spec.elems() as usize))
                .collect();
            let t = Instant::now();
            let outs = rt.execute_f32(&req.artifact, &inputs)?;
            let dt = t.elapsed().as_secs_f64() * 1e3;
            let s: f64 = outs
                .iter()
                .map(|o| o.iter().map(|&v| v as f64).sum::<f64>())
                .sum();
            out.push((dt, s));
        }
        Ok(out)
    });
    let wall = t0.elapsed().as_secs_f64();

    let mut lat = Vec::with_capacity(n);
    let mut checksum = 0.0f64;
    for worker in per_worker {
        for (dt, s) in worker? {
            lat.push(dt);
            checksum += s;
        }
    }
    Ok(ServeStats {
        completed: lat.len(),
        wall_s: wall,
        mean_latency_ms: stats::mean(&lat),
        p50_latency_ms: stats::percentile(&lat, 50.0),
        p95_latency_ms: stats::percentile(&lat, 95.0),
        p99_latency_ms: stats::percentile(&lat, 99.0),
        rps: lat.len() as f64 / wall,
        checksum,
    })
}

/// Build a mixed request trace over the available artifacts.
pub fn mixed_trace(n: usize, seed: u64) -> Vec<Request> {
    let kinds = ["conv3x3", "conv1x1", "fc", "lstm_cell", "conv_chain"];
    let mut rng = XorShift::new(seed);
    (0..n)
        .map(|i| Request {
            artifact: kinds[rng.below(kinds.len() as u64) as usize].to_string(),
            seed: seed ^ (i as u64).wrapping_mul(0x9E37),
        })
        .collect()
}
