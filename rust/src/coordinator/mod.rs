//! The L3 coordinator: experiment drivers that regenerate every paper
//! table/figure, the batched-serving loop over the PJRT runtime with
//! serving-time remapping, and the CLI that fronts it all.

pub mod cli;
pub mod experiments;
pub mod remap;
pub mod serve;
pub mod trace;

pub use experiments::Effort;

#[cfg(test)]
mod tests;
